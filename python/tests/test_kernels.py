"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (and block sizes for the matmuls) so the kernels
are exercised across ragged/odd dimensions, not just the MXU-friendly ones.
This is the core correctness signal for the compile path — if these pass,
the HLO artifacts the Rust runtime executes compute the right numbers.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, elementwise, matmul, ref

DIMS = st.integers(min_value=1, max_value=96)
SMALL_DIMS = st.integers(min_value=1, max_value=32)


def randn(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


def assert_close(got, want, atol=1e-4, rtol=1e-4):
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=atol, rtol=rtol)


class TestMatmul:
    @settings(max_examples=25, deadline=None)
    @given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31))
    def test_nn_matches_ref(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        x, y = randn(rng, m, k), randn(rng, k, n)
        assert_close(matmul.matmul(x, y), ref.matmul(x, y), atol=1e-3, rtol=1e-3)

    @settings(max_examples=25, deadline=None)
    @given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31))
    def test_nt_matches_ref(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        x, y = randn(rng, m, k), randn(rng, n, k)
        assert_close(matmul.matmul_nt(x, y), ref.matmul_nt(x, y), atol=1e-3, rtol=1e-3)

    @settings(max_examples=25, deadline=None)
    @given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31))
    def test_tn_matches_ref(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        x, y = randn(rng, k, m), randn(rng, k, n)
        assert_close(matmul.matmul_tn(x, y), ref.matmul_tn(x, y), atol=1e-3, rtol=1e-3)

    @pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (16, 32, 8), (128, 128, 128)])
    def test_block_size_invariance(self, bm, bn, bk):
        """Result must not depend on the VMEM tiling."""
        rng = np.random.default_rng(7)
        x, y = randn(rng, 64, 48), randn(rng, 48, 32)
        want = ref.matmul(x, y)
        assert_close(matmul.matmul(x, y, bm=bm, bn=bn, bk=bk), want, atol=1e-3)

    def test_identity(self):
        x = jnp.eye(16, dtype=jnp.float32)
        y = jnp.arange(16 * 8, dtype=jnp.float32).reshape(16, 8)
        assert_close(matmul.matmul(x, y), y)

    def test_vmem_budget_of_default_tiling(self):
        """Default 128³ tiles must fit the 16 MiB/core VMEM with
        double-buffering headroom (DESIGN.md §Perf)."""
        per_step = matmul.vmem_bytes(128, 128, 128)
        assert 2 * per_step < 16 * 1024 * 1024


class TestElementwise:
    @settings(max_examples=20, deadline=None)
    @given(m=DIMS, n=DIMS, seed=st.integers(0, 2**31))
    def test_gelu(self, m, n, seed):
        rng = np.random.default_rng(seed)
        x = randn(rng, m, n)
        assert_close(elementwise.gelu(x), ref.gelu(x), atol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(m=DIMS, n=DIMS, seed=st.integers(0, 2**31))
    def test_bias_gelu(self, m, n, seed):
        rng = np.random.default_rng(seed)
        x, b = randn(rng, m, n), randn(rng, n)
        assert_close(elementwise.bias_gelu(x, b), ref.bias_gelu(x, b), atol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(m=DIMS, n=st.integers(2, 96), seed=st.integers(0, 2**31))
    def test_layernorm(self, m, n, seed):
        rng = np.random.default_rng(seed)
        x, g, b = randn(rng, m, n), randn(rng, n), randn(rng, n)
        assert_close(
            elementwise.layernorm(x, g, b), ref.layernorm(x, g, b), atol=1e-4, rtol=1e-3
        )

    @settings(max_examples=20, deadline=None)
    @given(m=DIMS, n=DIMS, seed=st.integers(0, 2**31))
    def test_softmax(self, m, n, seed):
        rng = np.random.default_rng(seed)
        x = randn(rng, m, n)
        got = elementwise.softmax(x)
        assert_close(got, ref.softmax(x), atol=1e-5)
        np.testing.assert_allclose(np.asarray(got).sum(axis=-1), 1.0, atol=1e-5)

    def test_layernorm_rows_are_normalized(self):
        rng = np.random.default_rng(3)
        x = randn(rng, 8, 64)
        y = np.asarray(
            elementwise.layernorm(x, jnp.ones(64), jnp.zeros(64))
        )
        np.testing.assert_allclose(y.mean(axis=-1), 0.0, atol=1e-5)
        np.testing.assert_allclose(y.std(axis=-1), 1.0, atol=1e-2)


class TestAttention:
    @settings(max_examples=15, deadline=None)
    @given(
        seq=st.sampled_from([1, 2, 4, 8, 16]),
        d=SMALL_DIMS,
        nseq=st.integers(1, 3),
        seed=st.integers(0, 2**31),
    )
    def test_matches_ref_per_sequence(self, seq, d, nseq, seed):
        rng = np.random.default_rng(seed)
        q = randn(rng, nseq * seq, d)
        k = randn(rng, nseq * seq, d)
        v = randn(rng, nseq * seq, d)
        got = attention.causal_attention(q, k, v, seq)
        for i in range(nseq):
            sl = slice(i * seq, (i + 1) * seq)
            want = ref.causal_attention(q[sl], k[sl], v[sl])
            assert_close(got[sl], want, atol=1e-4, rtol=1e-3)

    def test_causality(self):
        """Changing future tokens must not affect earlier outputs."""
        rng = np.random.default_rng(11)
        seq, d = 8, 4
        q, k, v = randn(rng, seq, d), randn(rng, seq, d), randn(rng, seq, d)
        base = np.asarray(attention.causal_attention(q, k, v, seq))
        k2 = k.at[-1].set(99.0)
        v2 = v.at[-1].set(-99.0)
        pert = np.asarray(attention.causal_attention(q, k2, v2, seq))
        np.testing.assert_allclose(base[:-1], pert[:-1], atol=1e-5)

    def test_first_token_attends_only_to_itself(self):
        rng = np.random.default_rng(12)
        seq, d = 4, 8
        q, k, v = randn(rng, seq, d), randn(rng, seq, d), randn(rng, seq, d)
        out = np.asarray(attention.causal_attention(q, k, v, seq))
        np.testing.assert_allclose(out[0], np.asarray(v)[0], atol=1e-5)
