"""L2 correctness: the kernel-composed transformer block vs the pure-jnp
reference, plus shape checks on the shard primitives."""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as l2
from compile.kernels import ref


def test_transformer_block_matches_ref():
    key = jax.random.PRNGKey(0)
    hidden, ffn, heads, seq, nseq = 32, 64, 4, 8, 2
    params = l2.init_block_params(key, hidden, ffn)
    x = jax.random.normal(jax.random.PRNGKey(1), (nseq * seq, hidden), jnp.float32)

    flat = [params[k] for k in l2.PARAM_ORDER]
    got = l2.transformer_block(x, *flat, n_heads=heads, seq=seq)

    # Reference treats each sequence independently.
    want = jnp.concatenate(
        [
            ref.transformer_block(x[i * seq:(i + 1) * seq], params, heads)
            for i in range(nseq)
        ],
        axis=0,
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4, rtol=1e-3)


def test_block_is_jittable_and_shape_stable():
    key = jax.random.PRNGKey(2)
    hidden, ffn, heads, seq = 16, 32, 2, 4
    params = l2.init_block_params(key, hidden, ffn)
    flat = [params[k] for k in l2.PARAM_ORDER]
    block = jax.jit(functools.partial(l2.transformer_block, n_heads=heads, seq=seq))
    x = jnp.zeros((seq, hidden), jnp.float32)
    y = block(x, *flat)
    assert y.shape == x.shape
    assert y.dtype == jnp.float32


def test_shard_primitives_shapes():
    a = jnp.zeros((8, 16), jnp.float32)
    b = jnp.zeros((16, 4), jnp.float32)
    assert l2.shard_matmul_nn(a, b).shape == (8, 4)
    assert l2.shard_matmul_nt(a, jnp.zeros((4, 16))).shape == (8, 4)
    assert l2.shard_matmul_tn(jnp.zeros((16, 8)), b).shape == (8, 4)
    assert l2.shard_bias_gelu(a, jnp.zeros(16)).shape == (8, 16)
    assert l2.shard_layernorm(a, jnp.ones(16), jnp.zeros(16)).shape == (8, 16)
    q = jnp.zeros((8, 4), jnp.float32)
    assert l2.shard_attention(q, q, q, seq=4).shape == (8, 4)


def test_grad_through_ref_block_is_finite():
    """Gradients flow through the reference block (the math the Rust
    hand-written backward mirrors) and are finite and non-trivial.

    Note: the Pallas kernels themselves are forward-only (interpret-mode
    pallas_call has no VJP); the backward pass is owned by the Rust
    coordinator, which is verified against dense numerics in rust tests."""
    key = jax.random.PRNGKey(3)
    hidden, ffn, heads, seq = 16, 32, 2, 4
    params = l2.init_block_params(key, hidden, ffn)
    x = jax.random.normal(jax.random.PRNGKey(4), (seq, hidden), jnp.float32)

    def loss(x, params):
        return jnp.sum(ref.transformer_block(x, params, heads) ** 2)

    gx = jax.grad(loss)(x, params)
    gp = jax.grad(lambda p: loss(x, p))(params)
    assert bool(jnp.all(jnp.isfinite(gx)))
    assert float(jnp.max(jnp.abs(gx))) > 0.0
    for name, g in gp.items():
        assert bool(jnp.all(jnp.isfinite(g))), name
