"""AOT bridge: lower the L2 JAX programs to HLO *text* artifacts for the
Rust PJRT runtime.

Run once at build time (``make artifacts``); Python never runs at train
time. Interchange format is HLO **text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs, under ``artifacts/``:

* ``<entry>.hlo.txt``  — one per shape-specialized program
* ``manifest.tsv``     — ``name  path  in_shapes  out_shape`` rows, parsed
  by ``cubic::runtime::Manifest``

Entry set = the shard primitives at every shape the distributed schedules
of the configured model touch, plus the fused ``block_seq`` transformer
block for the Seq reference path. Shapes are derived from the same model
configs the Rust side uses (keep `CONFIGS` in sync with `cubic::config`).
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as l2

# Keep in sync with cubic::config::ModelConfig presets (rust/src/config).
CONFIGS = {
    # name: (batch, seq, hidden, heads, ffn, cube edge p)
    "tiny": (4, 16, 64, 4, 256, 2),
    "charlm": (8, 32, 128, 4, 512, 2),
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def _fmt_shape(s) -> str:
    return "x".join(str(d) for d in s.shape)


def matmul_entries(batch, seq, hidden, heads, ffn, p):
    """Every (form, m, k, n) the 3-D schedule (fwd+bwd) and the Seq path
    touch for this config. m×k · k×n etc.; see parallel/threed.rs."""
    rows = batch * seq
    rp, hp, fp = rows // p, hidden // p, ffn // p
    shapes = set()
    # 3-D forward local products (Algorithm 1): gathered (·/p) blocks.
    for (m, k, n) in [
        (rp, hp, 3 * hp),   # qkv
        (rp, hp, hp),       # attn out proj
        (rp, hp, fp),       # fc1
        (rp, fp, hp),       # fc2
    ]:
        shapes.add(("nn", m, k, n))
        # Backward (Algorithm 2): dA = dC·Bᵀ (NT), dB = Aᵀ·dC (TN).
        shapes.add(("nt", m, n, k))
        shapes.add(("tn", k, m, n))
    # Seq reference path: full-size products.
    for (m, k, n) in [
        (rows, hidden, 3 * hidden),
        (rows, hidden, hidden),
        (rows, hidden, ffn),
        (rows, ffn, hidden),
    ]:
        shapes.add(("nn", m, k, n))
        shapes.add(("nt", m, n, k))
        shapes.add(("tn", k, m, n))
    return sorted(shapes)


def build_entries(cfg_name):
    """Yield (entry_name, jitted_fn, example_args)."""
    batch, seq, hidden, heads, ffn, p = CONFIGS[cfg_name]
    rows = batch * seq

    for (form, m, k, n) in matmul_entries(batch, seq, hidden, heads, ffn, p):
        fn = {
            "nn": l2.shard_matmul_nn,
            "nt": l2.shard_matmul_nt,
            "tn": l2.shard_matmul_tn,
        }[form]
        if form == "nn":
            args = (_spec(m, k), _spec(k, n))
        elif form == "nt":
            args = (_spec(m, k), _spec(n, k))
        else:  # tn: (k, m)ᵀ · (k, n)
            args = (_spec(k, m), _spec(k, n))
        yield f"mm_{form}_{m}x{k}x{n}", jax.jit(fn), args

    # Fused epilogues at the 3-D shard shape (input layout rows R/p²).
    shard_rows = rows // (p * p)
    yield (
        f"bias_gelu_{shard_rows}x{ffn // p}",
        jax.jit(l2.shard_bias_gelu),
        (_spec(shard_rows, ffn // p), _spec(ffn // p)),
    )
    yield (
        f"bias_gelu_{rows}x{ffn}",
        jax.jit(l2.shard_bias_gelu),
        (_spec(rows, ffn), _spec(ffn)),
    )
    yield (
        f"layernorm_{rows}x{hidden}",
        jax.jit(l2.shard_layernorm),
        (_spec(rows, hidden), _spec(hidden), _spec(hidden)),
    )
    # Fused whole-block forward for the Seq reference path.
    import functools

    block = functools.partial(l2.transformer_block, n_heads=heads, seq=seq)
    params = [
        _spec(hidden), _spec(hidden),
        _spec(hidden, 3 * hidden), _spec(3 * hidden),
        _spec(hidden, hidden), _spec(hidden),
        _spec(hidden), _spec(hidden),
        _spec(hidden, ffn), _spec(ffn),
        _spec(ffn, hidden), _spec(hidden),
    ]
    yield (
        f"block_seq_{rows}x{hidden}",
        jax.jit(block),
        (_spec(rows, hidden), *params),
    )


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--configs", default="tiny,charlm",
        help="comma-separated subset of: " + ",".join(CONFIGS),
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest_rows = []
    seen = set()
    for cfg in args.configs.split(","):
        for name, fn, example in build_entries(cfg):
            if name in seen:
                continue
            seen.add(name)
            lowered = fn.lower(*example)
            text = to_hlo_text(lowered)
            fname = f"{name}.hlo.txt"
            path = os.path.join(args.out_dir, fname)
            with open(path, "w") as f:
                f.write(text)
            in_shapes = ",".join(_fmt_shape(s) for s in example)
            out_shape = _fmt_shape(jax.eval_shape(fn, *example))
            manifest_rows.append(f"{name}\t{fname}\t{in_shapes}\t{out_shape}")
            print(f"  {name}: {len(text)} chars")

    manifest = os.path.join(args.out_dir, "manifest.tsv")
    with open(manifest, "w") as f:
        f.write("\n".join(manifest_rows) + "\n")
    print(f"wrote {len(manifest_rows)} artifacts + {manifest}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
