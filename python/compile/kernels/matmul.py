"""L1 Pallas kernel: blocked matrix multiplication (NN / NT / TN forms).

This is the per-device compute hot-spot of the whole system — the role
cuBLAS GEMM plays on the paper's V100s. The TPU adaptation (DESIGN.md
§Hardware-Adaptation): tile the output into MXU-shaped blocks held in VMEM,
loop the contraction dimension through the grid so each (bm, bk)·(bk, bn)
partial product streams HBM→VMEM exactly once, and accumulate in f32 in the
VMEM-resident output block.

Always lowered with ``interpret=True``: the CPU PJRT client cannot execute
Mosaic custom-calls, and interpret-mode lowers to plain HLO which runs on
any backend. On a real TPU the identical kernel source compiles to an MXU
pipeline; the perf estimate for that path lives in EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(dim: int, pref: int) -> int:
    """Largest divisor of ``dim`` that is <= pref (MXU-aligned when possible)."""
    b = min(dim, pref)
    while dim % b != 0:
        b -= 1
    return b


def _mm_kernel(x_ref, y_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x, y, bm: int = 128, bn: int = 128, bk: int = 128):
    """C = X @ Y with a (bm, bn) output block resident in VMEM and the K
    dimension innermost in the grid (sequential accumulation)."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"inner dims {k} vs {k2}"
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)


def _mm_nt_kernel(x_ref, y_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        x_ref[...],
        y_ref[...],
        # contract x dim 1 with y dim 1  (C = X · Yᵀ)
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul_nt(x, y, bm: int = 128, bn: int = 128, bk: int = 128):
    """C = X @ Yᵀ for X:(m,k), Y:(n,k) — both operands stream row-major."""
    m, k = x.shape
    n, k2 = y.shape
    assert k == k2
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _mm_nt_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)


def _mm_tn_kernel(x_ref, y_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        x_ref[...],
        y_ref[...],
        # contract x dim 0 with y dim 0  (C = Xᵀ · Y)
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul_tn(x, y, bm: int = 128, bn: int = 128, bk: int = 128):
    """C = Xᵀ @ Y for X:(k,m), Y:(k,n)."""
    k, m = x.shape
    k2, n = y.shape
    assert k == k2
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _mm_tn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, bm), lambda i, j, kk: (kk, i)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)


def vmem_bytes(bm: int, bn: int, bk: int, dtype_bytes: int = 4) -> int:
    """VMEM footprint of one grid step: X block + Y block + f32 accumulator.

    Used by the §Perf analysis to confirm the default 128³ tiling fits the
    16 MiB/core VMEM budget with double-buffering headroom.
    """
    return dtype_bytes * (bm * bk + bk * bn) + 4 * bm * bn
