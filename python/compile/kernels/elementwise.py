"""L1 Pallas kernels: fused elementwise / row-wise ops.

These kernels fuse what the paper's PyTorch implementation ran as separate
CUDA kernels (bias add, GeLU, layernorm statistics, softmax) into single
VMEM-resident passes — the TPU analogue of kernel fusion: one HBM read and
one HBM write per activation tile (DESIGN.md §Hardware-Adaptation).

All row-tiled: the grid walks blocks of rows; each program instance holds a
(block_rows, cols) tile in VMEM and does the full row-wise computation
locally, so row reductions (layernorm mean/var, softmax max/sum) never leave
the tile. ``interpret=True`` throughout (see matmul.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SQRT_2_OVER_PI = 0.7978845608028654


def _row_block(rows: int, pref: int = 256) -> int:
    b = min(rows, pref)
    while rows % b != 0:
        b -= 1
    return b


def _gelu(x):
    x3 = x * x * x
    return 0.5 * x * (1.0 + jnp.tanh(SQRT_2_OVER_PI * (x + 0.044715 * x3)))


def _bias_gelu_kernel(x_ref, b_ref, o_ref):
    o_ref[...] = _gelu(x_ref[...] + b_ref[...])


@jax.jit
def bias_gelu(x, b):
    """gelu(x + b) — the fused epilogue of the MLP's first linear layer."""
    m, n = x.shape
    bm = _row_block(m)
    return pl.pallas_call(
        _bias_gelu_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, b.reshape(1, -1))


def _gelu_kernel(x_ref, o_ref):
    o_ref[...] = _gelu(x_ref[...])


@jax.jit
def gelu(x):
    """Standalone tanh-GeLU tile kernel."""
    m, n = x.shape
    bm = _row_block(m)
    return pl.pallas_call(
        _gelu_kernel,
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x)


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    x = x_ref[...]
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    xhat = (x - mean) * jax.lax.rsqrt(var + eps)
    o_ref[...] = xhat * g_ref[...] + b_ref[...]


@functools.partial(jax.jit, static_argnames=("eps",))
def layernorm(x, gamma, beta, eps: float = 1e-5):
    """Fused row layernorm + affine: stats, normalize, scale, shift in one
    VMEM pass."""
    m, n = x.shape
    bm = _row_block(m)
    return pl.pallas_call(
        functools.partial(_layernorm_kernel, eps=eps),
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, gamma.reshape(1, -1), beta.reshape(1, -1))


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...]
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    o_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


@jax.jit
def softmax(x):
    """Row softmax with the max/sum reductions kept inside the tile."""
    m, n = x.shape
    bm = _row_block(m)
    return pl.pallas_call(
        _softmax_kernel,
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x)
