"""Pure-jnp reference oracles for every Pallas kernel.

These are the ground truth the L1 kernels are pytest-checked against
(``python/tests/test_kernels.py``) and the semantics the Rust tensor library
mirrors. Nothing here is ever lowered into an artifact — reference only.
"""

import jax.numpy as jnp

SQRT_2_OVER_PI = 0.7978845608028654


def matmul(x, y):
    """C = X @ Y in f32 accumulation."""
    return jnp.matmul(x, y, preferred_element_type=jnp.float32)


def matmul_nt(x, y):
    """C = X @ Y.T."""
    return jnp.matmul(x, y.T, preferred_element_type=jnp.float32)


def matmul_tn(x, y):
    """C = X.T @ Y."""
    return jnp.matmul(x.T, y, preferred_element_type=jnp.float32)


def gelu(x):
    """Tanh-approximation GeLU (BERT/Megatron variant) — matches
    `cubic::ops::gelu` bit-for-bit in f32 up to transcendental rounding."""
    x3 = x * x * x
    return 0.5 * x * (1.0 + jnp.tanh(SQRT_2_OVER_PI * (x + 0.044715 * x3)))


def bias_gelu(x, b):
    """gelu(x + b) with a broadcast row-vector bias."""
    return gelu(x + b[None, :])


def linear(x, w, b):
    """x @ w + b."""
    return matmul(x, w) + b[None, :]


def layernorm(x, gamma, beta, eps=1e-5):
    """Row-wise layernorm over the last dim with affine params."""
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mean), axis=-1, keepdims=True)
    xhat = (x - mean) / jnp.sqrt(var + eps)
    return xhat * gamma[None, :] + beta[None, :]


def softmax(x):
    """Numerically-stable row softmax."""
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def causal_attention(q, k, v):
    """Single-head causal attention over one sequence.

    q, k, v: (seq, head_dim). Returns (seq, head_dim).
    """
    s, d = q.shape
    scores = matmul_nt(q, k) / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    scores = jnp.where(mask, scores, -1e9)
    return matmul(softmax(scores), v)


def transformer_block(x, params, n_heads, eps=1e-5):
    """Single-device reference transformer block (pre-LN, causal).

    x: (seq, hidden). ``params`` is the dict produced by
    `compile.model.init_block_params`.
    """
    s, h = x.shape
    hd = h // n_heads

    ln1 = layernorm(x, params["ln1_g"], params["ln1_b"], eps)
    qkv = linear(ln1, params["w_qkv"], params["b_qkv"])  # (s, 3h)
    q, k, v = jnp.split(qkv, 3, axis=-1)

    heads = []
    for i in range(n_heads):
        sl = slice(i * hd, (i + 1) * hd)
        heads.append(causal_attention(q[:, sl], k[:, sl], v[:, sl]))
    attn = jnp.concatenate(heads, axis=-1)  # (s, h)
    x = x + linear(attn, params["w_proj"], params["b_proj"])

    ln2 = layernorm(x, params["ln2_g"], params["ln2_b"], eps)
    hmid = bias_gelu(matmul(ln2, params["w_fc1"]), params["b_fc1"])
    x = x + linear(hmid, params["w_fc2"], params["b_fc2"])
    return x
