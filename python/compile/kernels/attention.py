"""L1 Pallas kernel: fused causal attention for one head.

The paper's implementation computes attention with separate batched GEMMs
and a masked softmax (PyTorch). The TPU adaptation fuses the whole
`softmax(QKᵀ/√d + causal_mask)·V` for a sequence tile into one kernel so
the (seq, seq) score matrix lives only in VMEM — the flash-attention-style
restructuring of the same math (DESIGN.md §Hardware-Adaptation).

Grid: one program instance per (sequence) — each instance holds Q, K, V
tiles of a full head and materializes scores only as a VMEM temporary.
``interpret=True`` as everywhere (see matmul.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float):
    q = q_ref[...]  # (s, d)
    k = k_ref[...]
    v = v_ref[...]
    s = q.shape[0]
    scores = jax.lax.dot_general(
        q, k, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale  # (s, s)
    rows = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
    scores = jnp.where(cols <= rows, scores, -1e9)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = jnp.dot(p, v, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("seq",))
def causal_attention(q, k, v, seq: int):
    """Fused causal attention over stacked sequences.

    q, k, v: (n_seqs·seq, head_dim) — row blocks of `seq` rows are
    independent sequences (exactly the layout the Rust coordinator feeds).
    Returns the same shape.
    """
    total, d = q.shape
    assert total % seq == 0, f"rows {total} not a multiple of seq {seq}"
    n_seqs = total // seq
    scale = 1.0 / (d ** 0.5)
    return pl.pallas_call(
        functools.partial(_attn_kernel, scale=scale),
        grid=(n_seqs,),
        in_specs=[
            pl.BlockSpec((seq, d), lambda i: (i, 0)),
            pl.BlockSpec((seq, d), lambda i: (i, 0)),
            pl.BlockSpec((seq, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((seq, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((total, d), jnp.float32),
        interpret=True,
    )(q, k, v)
