"""L1 Pallas kernels (build-time only; lowered to HLO by compile.aot)."""

from . import attention, elementwise, matmul, ref  # noqa: F401
