"""L2: JAX per-shard compute graphs, composed from the L1 Pallas kernels.

These are the programs the Rust coordinator executes through PJRT at train
time. They come in two flavours:

* **Shard primitives** — the local compute between collectives of the
  1-D/2-D/3-D schedules (`matmul` forms, fused `bias_gelu`, `layernorm`,
  fused `causal_attention`). The coordinator stitches these together with
  its own collectives, exactly as the paper stitches cuBLAS GEMMs with NCCL.
* **`transformer_block`** — a whole fused single-shard transformer block
  (pre-LN, causal), used by the Seq reference path and the quickstart
  example, and as the parity check between the Rust model and the JAX model.

Everything is shape-specialized at AOT time by `compile.aot`; nothing here
runs at train time.
"""

import jax.numpy as jnp

from .kernels import attention, elementwise, matmul


def init_block_params(key, hidden: int, ffn: int):
    """Initialize one transformer block's parameters (for tests/AOT example
    inputs). Returns a dict of jnp arrays; layout matches the Rust model."""
    import jax

    ks = jax.random.split(key, 4)
    std = 0.02
    return {
        "ln1_g": jnp.ones((hidden,), jnp.float32),
        "ln1_b": jnp.zeros((hidden,), jnp.float32),
        "w_qkv": std * jax.random.normal(ks[0], (hidden, 3 * hidden), jnp.float32),
        "b_qkv": jnp.zeros((3 * hidden,), jnp.float32),
        "w_proj": std * jax.random.normal(ks[1], (hidden, hidden), jnp.float32),
        "b_proj": jnp.zeros((hidden,), jnp.float32),
        "ln2_g": jnp.ones((hidden,), jnp.float32),
        "ln2_b": jnp.zeros((hidden,), jnp.float32),
        "w_fc1": std * jax.random.normal(ks[2], (hidden, ffn), jnp.float32),
        "b_fc1": jnp.zeros((ffn,), jnp.float32),
        "w_fc2": std * jax.random.normal(ks[3], (ffn, hidden), jnp.float32),
        "b_fc2": jnp.zeros((hidden,), jnp.float32),
    }


PARAM_ORDER = (
    "ln1_g", "ln1_b", "w_qkv", "b_qkv", "w_proj", "b_proj",
    "ln2_g", "ln2_b", "w_fc1", "b_fc1", "w_fc2", "b_fc2",
)


def transformer_block(x, *flat_params, n_heads: int, seq: int, eps: float = 1e-5):
    """Fused single-shard transformer block forward (pre-LN, causal).

    x: (n_seqs·seq, hidden) — stacked sequences, the Rust engine's row
    layout. ``flat_params`` follow ``PARAM_ORDER`` (positional so the
    exported HLO has a stable parameter signature for the Rust runtime).
    """
    p = dict(zip(PARAM_ORDER, flat_params))
    rows, h = x.shape
    hd = h // n_heads

    ln1 = elementwise.layernorm(x, p["ln1_g"], p["ln1_b"], eps=eps)
    qkv = matmul.matmul(ln1, p["w_qkv"]) + p["b_qkv"][None, :]
    q, k, v = jnp.split(qkv, 3, axis=-1)

    heads = []
    for i in range(n_heads):
        sl = slice(i * hd, (i + 1) * hd)
        heads.append(attention.causal_attention(q[:, sl], k[:, sl], v[:, sl], seq))
    attn = jnp.concatenate(heads, axis=-1)

    x = x + matmul.matmul(attn, p["w_proj"]) + p["b_proj"][None, :]

    ln2 = elementwise.layernorm(x, p["ln2_g"], p["ln2_b"], eps=eps)
    hmid = elementwise.bias_gelu(matmul.matmul(ln2, p["w_fc1"]), p["b_fc1"])
    x = x + matmul.matmul(hmid, p["w_fc2"]) + p["b_fc2"][None, :]
    return x


# ---------------------------------------------------------------------
# Shard primitives — the exact local steps of the distributed schedules.
# Thin wrappers so aot.py can enumerate them by name.
# ---------------------------------------------------------------------

def shard_matmul_nn(a, b):
    """Local step 3 of Algorithm 1 (and SUMMA's inner product)."""
    return matmul.matmul(a, b)


def shard_matmul_nt(a, b):
    """Local product of Algorithms 2/3 (`Ċ·Bᵀ`, `A·Bᵀ`)."""
    return matmul.matmul_nt(a, b)


def shard_matmul_tn(a, b):
    """Local product of Algorithms 2/5 (`Aᵀ·Ċ`, `Aᵀ·B`)."""
    return matmul.matmul_tn(a, b)


def shard_bias_gelu(x, b):
    """Fused MLP epilogue on the activation shard."""
    return elementwise.bias_gelu(x, b)


def shard_layernorm(x, g, b):
    """Local layernorm on a shard that holds complete rows (Seq/1-D)."""
    return elementwise.layernorm(x, g, b)


def shard_attention(q, k, v, *, seq: int):
    """Fused per-head causal attention on local sequences."""
    return attention.causal_attention(q, k, v, seq)
