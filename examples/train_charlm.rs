//! End-to-end driver (EXPERIMENTS.md §E2E): train a char-level language
//! model on the synthetic Markov corpus under the paper's 3-D parallelism,
//! logging the loss curve, then cross-check the final loss against the
//! dense Seq reference trained identically.
//!
//! Presets:
//!   --model charlm (default)  ~1M-param model, 300 steps   (minutes)
//!   --model large100m         ~150M-param GPT-2-small-like; runs a few
//!                             steps to prove the full-scale path composes
//!                             (weights shard, memory fits, loss finite)
//! Options: --steps N --par seq|1d|2d|3d --edge N --lr F
//!
//! Run: `cargo run --release --example train_charlm -- --steps 300`

use cubic::cli::Args;
use cubic::comm::NetModel;
use cubic::config::{CubicConfig, ModelConfig, TrainConfig};
use cubic::engine::run_training;
use cubic::topology::Parallelism;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let model_name = args.get("model").unwrap_or_else(|| "charlm".into());
    let (model, default_steps, default_lr) = match model_name.as_str() {
        "charlm" => (ModelConfig::charlm(), 300usize, 2e-3f64),
        "tiny" => (ModelConfig::tiny(), 100, 3e-3),
        "large100m" => (ModelConfig::large100m(), 2, 1e-4),
        other => anyhow::bail!("unknown model {other:?}"),
    };
    let par = match args.get("par") {
        Some(p) => Parallelism::parse(&p).ok_or_else(|| anyhow::anyhow!("bad --par"))?,
        None => Parallelism::ThreeD,
    };
    let edge = args.get_usize("edge", 2).map_err(anyhow::Error::msg)?;
    let steps = args.get_usize("steps", default_steps).map_err(anyhow::Error::msg)?;
    let lr = args.get_f64("lr", default_lr).map_err(anyhow::Error::msg)? as f32;

    let cfg = CubicConfig {
        model,
        train: TrainConfig {
            steps,
            lr,
            warmup: (steps / 10).max(1),
            log_every: (steps / 20).max(1),
            ..Default::default()
        },
        parallelism: par,
        edge,
        ..CubicConfig::default()
    };
    println!("training {}", cubic::config::describe(&cfg));
    println!(
        "corpus: synthetic Markov chain over {} tokens (learnable structure)",
        cfg.model.vocab
    );
    let t0 = std::time::Instant::now();
    let report = run_training(&cfg, NetModel::longhorn_v100())?;
    println!("\nstep   loss");
    for (s, l) in report.losses.iter().enumerate() {
        if s % cfg.train.log_every == 0 || s + 1 == report.losses.len() {
            println!("{s:5}  {l:.4}");
        }
    }
    let uniform = (cfg.model.vocab as f32).ln();
    println!(
        "\nfinal loss {:.4} (uniform baseline ln(V) = {:.3}); {} steps in {:.1}s host time",
        report.losses.last().unwrap(),
        uniform,
        report.losses.len(),
        t0.elapsed().as_secs_f64()
    );
    println!(
        "virtual step time on the simulated V100 cluster: {:.2} ms",
        1e3 * report.avg_step_virtual
    );
    anyhow::ensure!(
        report.losses.last().unwrap().is_finite(),
        "loss diverged"
    );
    if steps >= 50 {
        anyhow::ensure!(
            *report.losses.last().unwrap() < uniform,
            "model failed to beat the uniform baseline"
        );
    }
    Ok(())
}
