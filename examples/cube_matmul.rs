//! Deep-dive example: the paper's Algorithms 1–8 one by one on a p³ cube,
//! printing per-step communication volume and checking every result against
//! dense references — a guided tour of the 3-D linear algebra for readers
//! of §3.1.
//!
//! Run: `cargo run --release --example cube_matmul -- --p 2`

use cubic::cli::Args;
use cubic::comm::NetModel;
use cubic::costmodel;
use cubic::dist::{DiagVec3D, Dirs, Layout3D};
use cubic::parallel::threed::{self, Ctx3D, Layout3DExt};
use cubic::rng::Xoshiro256;
use cubic::spmd::run_spmd_with_stats;
use cubic::tensor::Tensor;
use cubic::topology::Cube;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1)).map_err(anyhow::Error::msg)?;
    let p = args.get_usize("p", 2).map_err(anyhow::Error::msg)?;
    let world = p * p * p;
    let cube = Cube::new(p);
    let dirs = Dirs::canonical();
    let (m, n, k) = (8 * p * p, 4 * p * p, 2 * p * p);
    let mut rng = Xoshiro256::seed_from_u64(1);
    let a = Tensor::randn(&[m, n], 1.0, &mut rng);
    let b = Tensor::randn(&[n, k], 1.0, &mut rng);
    println!("cube p={p} ({world} ranks); A {m}x{n}, B {n}x{k}\n");

    // Algorithm 1: C = AB.
    let a_sh = Layout3D::input(dirs).scatter(&cube, &a);
    let b_sh = Layout3D::weight(dirs).scatter(&cube, &b);
    let res = run_spmd_with_stats(world, NetModel::longhorn_v100(), {
        let (a_sh, b_sh) = (a_sh.clone(), b_sh.clone());
        move |rank, ep| {
            let ctx = Ctx3D::new(Cube::new(p), rank);
            threed::mm_nn(ep, &ctx, &a_sh[rank], &b_sh[rank], dirs)
        }
    });
    let shards: Vec<Tensor> = res.iter().map(|(t, _, _)| t.clone()).collect();
    let c = Layout3D::output(dirs).gather(&cube, &shards, m, k);
    let err = c.max_abs_diff(&a.matmul(&b));
    let bytes = res[0].2.bytes_sent;
    let predicted = costmodel::mm3d_fwd_bytes_per_rank(p as u64, m as u64, n as u64, k as u64);
    println!("Algorithm 1  C = A·B        max err {err:.2e}; {bytes} B/rank sent (model: {predicted})");
    assert_eq!(bytes, predicted);

    // Algorithm 2: backward.
    let dc = Tensor::randn(&[m, k], 1.0, &mut rng);
    let dc_sh = Layout3D::output(dirs).scatter(&cube, &dc);
    let res = run_spmd_with_stats(world, NetModel::longhorn_v100(), {
        let (a_sh, b_sh, dc_sh) = (a_sh.clone(), b_sh.clone(), dc_sh.clone());
        move |rank, ep| {
            let ctx = Ctx3D::new(Cube::new(p), rank);
            threed::mm_nn_backward(ep, &ctx, &dc_sh[rank], &a_sh[rank], &b_sh[rank], dirs)
        }
    });
    let da = Layout3D::input(dirs).gather(
        &cube, &res.iter().map(|(o, _, _)| o.0.clone()).collect::<Vec<_>>(), m, n);
    let db = Layout3D::weight(dirs).gather(
        &cube, &res.iter().map(|(o, _, _)| o.1.clone()).collect::<Vec<_>>(), n, k);
    println!(
        "Algorithm 2  dA, dB         max err {:.2e}, {:.2e}",
        da.max_abs_diff(&dc.matmul_nt(&b)),
        db.max_abs_diff(&a.matmul_tn(&dc))
    );

    // Algorithm 3: C = A·Bᵀ.
    let bt = Tensor::randn(&[k, n], 1.0, &mut rng);
    let bt_sh = Layout3D::nt_rhs(dirs).scatter(&cube, &bt);
    let res = run_spmd_with_stats(world, NetModel::longhorn_v100(), {
        let (a_sh, bt_sh) = (a_sh.clone(), bt_sh.clone());
        move |rank, ep| {
            let ctx = Ctx3D::new(Cube::new(p), rank);
            threed::mm_nt(ep, &ctx, &a_sh[rank], &bt_sh[rank], dirs)
        }
    });
    let c3 = Layout3D::output(dirs).gather(
        &cube, &res.iter().map(|(t, _, _)| t.clone()).collect::<Vec<_>>(), m, k);
    println!("Algorithm 3  C = A·Bᵀ       max err {:.2e}", c3.max_abs_diff(&a.matmul_nt(&bt)));

    // Algorithm 5: C = Aᵀ·B.
    let at = Tensor::randn(&[n, m], 1.0, &mut rng);
    let at_sh = Layout3D::tn_lhs(dirs).scatter(&cube, &at);
    let res = run_spmd_with_stats(world, NetModel::longhorn_v100(), {
        let (at_sh, b_sh) = (at_sh.clone(), b_sh.clone());
        move |rank, ep| {
            let ctx = Ctx3D::new(Cube::new(p), rank);
            threed::mm_tn(ep, &ctx, &at_sh[rank], &b_sh[rank], dirs)
        }
    });
    let c5 = Layout3D::output(dirs).gather(
        &cube, &res.iter().map(|(t, _, _)| t.clone()).collect::<Vec<_>>(), m, k);
    println!("Algorithm 5  C = Aᵀ·B       max err {:.2e}", c5.max_abs_diff(&at.matmul_tn(&b)));

    // Algorithms 7/8: matrix-vector add + backward.
    let v = Tensor::randn(&[n], 1.0, &mut rng);
    let v_sh = DiagVec3D::for_dirs(dirs).scatter(&cube, &v);
    let res = run_spmd_with_stats(world, NetModel::longhorn_v100(), {
        let (a_sh, v_sh) = (a_sh.clone(), v_sh.clone());
        move |rank, ep| {
            let ctx = Ctx3D::new(Cube::new(p), rank);
            let y = threed::vec_op(ep, &ctx, &a_sh[rank], v_sh[rank].as_ref(), dirs, false);
            let (da, dv) = threed::add_vec_backward(ep, &ctx, &a_sh[rank], dirs);
            (y, da, dv)
        }
    });
    let y7 = Layout3D::input(dirs).gather(
        &cube, &res.iter().map(|(o, _, _)| o.0.clone()).collect::<Vec<_>>(), m, n);
    let dv = DiagVec3D::for_dirs(dirs).gather(
        &cube, &res.iter().map(|(o, _, _)| o.2.clone()).collect::<Vec<_>>(), n);
    println!(
        "Algorithm 7  C = A + b      max err {:.2e}",
        y7.max_abs_diff(&a.add_row_vector(&v))
    );
    println!(
        "Algorithm 8  ḃ = Σ rows     max err {:.2e}",
        dv.max_abs_diff(&a.sum_rows())
    );

    println!("\nmemory balance: every rank stores exactly 1/{world} of each matrix:");
    for (name, layout, rows, cols) in [
        ("A (input)", Layout3D::input(dirs), m, n),
        ("B (weight)", Layout3D::weight(dirs), n, k),
        ("C (output)", Layout3D::output(dirs), m, k),
    ] {
        let bytes = layout.bytes_per_rank(p, rows, cols);
        println!("  {name:11} {rows}x{cols}: {bytes} B/rank x {world} = {} B total", bytes * world);
        assert_eq!(bytes * world, rows * cols * 4);
    }
    println!("\ncube_matmul OK");
    Ok(())
}
