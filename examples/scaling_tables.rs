//! Regenerate BOTH of the paper's evaluation tables in one run (the same
//! code paths as `cargo bench --bench table1_weak_scaling` / `table2_...`,
//! packaged as an example for the impatient).
//!
//! Run: `cargo run --release --example scaling_tables`

use cubic::bench::{render, run_rows, strong_scaling_speedups, table1_rows, table2_rows};
use cubic::comm::NetModel;

fn main() {
    let net = NetModel::longhorn_v100();
    eprintln!("running Table 1 rows (weak scaling) on the virtual cluster...");
    let t1 = run_rows(&table1_rows(), &net);
    println!("{}\n", render("Paper Table 1 — weak scaling", &t1));

    eprintln!("running Table 2 rows (strong scaling)...");
    let t2 = run_rows(&table2_rows(), &net);
    println!("{}", render("Paper Table 2 — strong scaling", &t2));
    let (s1, s2) = strong_scaling_speedups(&t2);
    println!("\n3-D speedup at 64 GPUs: {s1:.2}x vs 1-D (paper 2.32x), {s2:.2}x vs 2-D (paper 1.57x)");
}
