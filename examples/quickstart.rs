//! Quickstart: the whole stack in ~60 seconds.
//!
//! 1. runs the paper's 3-D parallel matmul (Algorithm 1) on a 2×2×2 cube
//!    and checks it against the dense product;
//! 2. trains a tiny transformer for 20 steps under 3-D parallelism and
//!    prints the falling loss;
//! 3. if `artifacts/` exists (`make artifacts`), executes an AOT-compiled
//!    JAX+Pallas program through the PJRT runtime from Rust and checks it
//!    against the native kernel.
//!
//! Run: `cargo run --release --example quickstart`

use cubic::comm::NetModel;
use cubic::config::{CubicConfig, ModelConfig, TrainConfig};
use cubic::dist::{Dirs, Layout3D};
use cubic::engine::run_training;
use cubic::parallel::threed::{mm_nn, Ctx3D};
use cubic::rng::Xoshiro256;
use cubic::spmd::run_spmd;
use cubic::tensor::Tensor;
use cubic::topology::{Cube, Parallelism};

fn main() -> anyhow::Result<()> {
    // --- 1. Algorithm 1 on a 2×2×2 cube ------------------------------
    println!("== 3-D parallel matmul (paper Algorithm 1) on 8 ranks ==");
    let p = 2;
    let cube = Cube::new(p);
    let dirs = Dirs::canonical();
    let mut rng = Xoshiro256::seed_from_u64(7);
    let a = Tensor::randn(&[64, 128], 1.0, &mut rng);
    let b = Tensor::randn(&[128, 64], 1.0, &mut rng);
    let c_ref = a.matmul(&b);
    let a_shards = Layout3D::input(dirs).scatter(&cube, &a);
    let b_shards = Layout3D::weight(dirs).scatter(&cube, &b);
    let out = run_spmd(8, NetModel::longhorn_v100(), move |rank, ep| {
        let ctx = Ctx3D::new(Cube::new(p), rank);
        mm_nn(ep, &ctx, &a_shards[rank], &b_shards[rank], dirs)
    });
    let c = Layout3D::output(dirs).gather(&cube, &out, 64, 64);
    println!("   max |dist - dense| = {:.2e}", c.max_abs_diff(&c_ref));
    assert!(c.max_abs_diff(&c_ref) < 1e-3);

    // --- 2. Train a tiny model with 3-D parallelism ------------------
    println!("\n== tiny transformer, 3-D parallel training (8 ranks) ==");
    let cfg = CubicConfig {
        model: ModelConfig::tiny(),
        train: TrainConfig { steps: 20, lr: 2e-3, warmup: 4, ..Default::default() },
        parallelism: Parallelism::ThreeD,
        edge: 2,
        ..CubicConfig::default()
    };
    let report = run_training(&cfg, NetModel::longhorn_v100())?;
    println!(
        "   loss: {:.3} -> {:.3} over {} steps ({:.2} virtual ms/step)",
        report.losses[0],
        report.losses.last().unwrap(),
        report.losses.len(),
        1e3 * report.avg_step_virtual
    );
    assert!(report.losses.last().unwrap() < &report.losses[0]);

    // --- 3. Execute an AOT artifact through PJRT ----------------------
    println!("\n== PJRT: run an AOT-compiled JAX+Pallas kernel from Rust ==");
    let dir = std::path::Path::new("artifacts");
    if dir.join("manifest.tsv").exists() {
        let rt = cubic::runtime::Runtime::load(dir)?;
        let name = rt
            .manifest
            .names()
            .into_iter()
            .find(|n| n.starts_with("mm_nn_"))
            .expect("bundle has matmul artifacts");
        let e = rt.manifest.get(&name).unwrap().clone();
        let x = Tensor::randn(&e.in_shapes[0], 1.0, &mut rng);
        let y = Tensor::randn(&e.in_shapes[1], 1.0, &mut rng);
        let got = rt.handle().execute(&name, &[x.clone(), y.clone()])?;
        let diff = got.max_abs_diff(&x.matmul(&y));
        println!("   {name}: PJRT vs native max diff = {diff:.2e}");
        assert!(diff < 1e-3);
    } else {
        println!("   (artifacts/ not built — run `make artifacts` to enable this step)");
    }

    println!("\nquickstart OK");
    Ok(())
}
