//! Metrics: timing reports, communication/memory accounting, the global
//! bytes-cloned counter (the copy-on-write observability hook of the
//! Arc-backed tensor storage), and the markdown/CSV table writer the
//! benchmark harness uses to print paper-style tables.

use crate::comm::CommStats;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};

/// Global bytes-cloned counter — the companion of the flop counter in
/// [`crate::tensor::matmul`]. Every copy-on-write materialization of a
/// shared tensor buffer (see `Tensor::data_mut`) adds the copied byte count
/// here. Zero-copy paths — message payload handoff, ring-chunk forwarding,
/// clones, views — contribute nothing, which is exactly what the microbench
/// and the collective zero-copy tests assert.
static BYTES_CLONED: AtomicU64 = AtomicU64::new(0);

/// Charge `bytes` of buffer duplication (called from the tensor CoW path).
pub fn add_bytes_cloned(bytes: u64) {
    BYTES_CLONED.fetch_add(bytes, Ordering::Relaxed);
}

/// Total bytes duplicated by copy-on-write since start (or last reset).
pub fn bytes_cloned() -> u64 {
    BYTES_CLONED.load(Ordering::Relaxed)
}

/// Reset the bytes-cloned counter (bench harness only; racy with respect to
/// concurrently running workers, like the flop counter).
pub fn reset_bytes_cloned() {
    BYTES_CLONED.store(0, Ordering::Relaxed);
}

/// Global mirrors of the per-endpoint buffer-pool counters (see
/// `comm::pool`): scratch-buffer requests served by recycling vs. by a
/// fresh heap allocation. `POOL_ALLOCS` staying flat across steady-state
/// iterations is the "hot loop performs zero allocations after warmup"
/// proof the microbench asserts; per-endpoint exact values live in
/// `comm::CommStats` (this global is shared across concurrent worlds, so
/// in-crate tests assert on the endpoint stats instead).
static POOL_HITS: AtomicU64 = AtomicU64::new(0);
static POOL_ALLOCS: AtomicU64 = AtomicU64::new(0);

/// Record a pool request served without allocating (called from comm).
pub fn add_pool_hit() {
    POOL_HITS.fetch_add(1, Ordering::Relaxed);
}

/// Record a pool request that had to heap-allocate (called from comm).
pub fn add_pool_alloc() {
    POOL_ALLOCS.fetch_add(1, Ordering::Relaxed);
}

/// Total pool requests served by recycling since start.
pub fn pool_hits() -> u64 {
    POOL_HITS.load(Ordering::Relaxed)
}

/// Total pool requests that allocated since start.
pub fn pool_allocs() -> u64 {
    POOL_ALLOCS.load(Ordering::Relaxed)
}

/// Bytes written into gemm packing panels (A micro-panels + shared B
/// blocks), fed by the kernel driver's merged per-thread tallies — the
/// multi-core companion of the flop counter: every participating thread
/// tallies the panels it packed and the job's total lands here once, on
/// completion. Useful for spotting pack-traffic regressions (a driver
/// change that re-packs a panel per tile would blow this up long before it
/// shows in wall-clock noise).
static PACK_BYTES: AtomicU64 = AtomicU64::new(0);

/// Credit one gemm's merged packing-traffic tally (called from the kernel
/// driver after the per-thread counters are joined).
pub fn add_pack_bytes(bytes: u64) {
    PACK_BYTES.fetch_add(bytes, Ordering::Relaxed);
}

/// Total bytes written into packing panels since start (or last reset).
pub fn pack_bytes() -> u64 {
    PACK_BYTES.load(Ordering::Relaxed)
}

/// Reset the pack-bytes counter (bench harness only; racy like the rest).
pub fn reset_pack_bytes() {
    PACK_BYTES.store(0, Ordering::Relaxed);
}

/// Result of one timed distributed run (virtual clocks + real traffic).
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    /// Max virtual clock across ranks (the step's makespan), seconds.
    pub virtual_time: f64,
    /// Max per-rank compute share of the virtual time.
    pub compute_time: f64,
    /// Max per-rank comm share.
    pub comm_time: f64,
    /// Max per-rank *exposed* comm (the part compute actually stalled on).
    /// Note: each field is max-merged independently, so the per-rank
    /// identity `exposed + overlapped == comm_time` holds per endpoint,
    /// not necessarily between these three maxima.
    pub exposed_comm_time: f64,
    /// Max per-rank comm hidden behind compute by deferred collectives.
    pub overlapped_comm_time: f64,
    /// Total bytes sent across all ranks.
    pub total_bytes: u64,
    /// Bytes that crossed node boundaries.
    pub inter_node_bytes: u64,
    /// Total messages.
    pub messages: u64,
    /// Dropped delivery attempts retried through (fault injection),
    /// summed across ranks.
    pub retries: u64,
    /// Receives that timed out (retry budget or hang watchdog), summed.
    pub timeouts: u64,
    /// Wall-clock seconds of the host simulation (not the model!).
    pub host_seconds: f64,
}

impl RunMetrics {
    /// Merge per-rank endpoint stats into a run summary.
    pub fn from_ranks(ranks: &[(f64, CommStats)], host_seconds: f64) -> RunMetrics {
        let mut m = RunMetrics { host_seconds, ..Default::default() };
        for (clock, s) in ranks {
            m.virtual_time = m.virtual_time.max(*clock);
            m.compute_time = m.compute_time.max(s.compute_time);
            m.comm_time = m.comm_time.max(s.comm_time);
            m.exposed_comm_time = m.exposed_comm_time.max(s.exposed_comm_time);
            m.overlapped_comm_time = m.overlapped_comm_time.max(s.overlapped_comm_time);
            m.total_bytes += s.bytes_sent;
            m.inter_node_bytes += s.inter_node_bytes;
            m.messages += s.messages_sent;
            m.retries += s.retries;
            m.timeouts += s.timeouts;
        }
        m
    }

    /// Append a later run segment that executed *after* this one (the
    /// supervision loop's restart generations): times add sequentially,
    /// traffic and fault counters accumulate.
    pub fn chain(&mut self, next: &RunMetrics) {
        self.virtual_time += next.virtual_time;
        self.compute_time += next.compute_time;
        self.comm_time += next.comm_time;
        self.exposed_comm_time += next.exposed_comm_time;
        self.overlapped_comm_time += next.overlapped_comm_time;
        self.total_bytes += next.total_bytes;
        self.inter_node_bytes += next.inter_node_bytes;
        self.messages += next.messages;
        self.retries += next.retries;
        self.timeouts += next.timeouts;
    }
}

/// Simple markdown table builder (the bench harness prints paper-style
/// tables with it).
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn to_markdown(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for i in 0..ncols {
                let _ = write!(line, " {:width$} |", cells[i], width = widths[i]);
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{:-<width$}|", "", width = w + 2);
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = self.header.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

/// Wall-clock stopwatch for host-side (criterion-less) benchmarking.
pub struct Stopwatch(std::time::Instant);

impl Stopwatch {
    pub fn start() -> Stopwatch {
        Stopwatch(std::time::Instant::now())
    }

    pub fn seconds(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

/// Format seconds the way the paper's tables do (3 decimals).
pub fn fmt_s(v: f64) -> String {
    format!("{v:.3}")
}

/// Format bytes human-readably.
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{:.2} {}", v, UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_metrics_take_max_clock_and_sum_bytes() {
        let mut s1 = CommStats::default();
        s1.bytes_sent = 100;
        s1.compute_time = 2.0;
        let mut s2 = CommStats::default();
        s2.bytes_sent = 50;
        s2.inter_node_bytes = 10;
        s2.comm_time = 1.5;
        let m = RunMetrics::from_ranks(&[(3.0, s1), (4.0, s2)], 0.1);
        assert_eq!(m.virtual_time, 4.0);
        assert_eq!(m.total_bytes, 150);
        assert_eq!(m.inter_node_bytes, 10);
        assert_eq!(m.compute_time, 2.0);
        assert_eq!(m.comm_time, 1.5);
    }

    #[test]
    fn markdown_table_is_aligned() {
        let mut t = Table::new(&["# GPUs", "Avg step time (s)"]);
        t.row(&["8".into(), "0.341".into()]);
        t.row(&["64".into(), "1.560".into()]);
        let md = t.to_markdown();
        let lines: Vec<&str> = md.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("# GPUs"));
        assert!(lines[1].starts_with("|--"));
        // all lines same width
        assert_eq!(lines[0].len(), lines[2].len());
        assert_eq!(lines[0].len(), lines[1].len());
    }

    #[test]
    fn csv_output() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }

    #[test]
    fn pack_bytes_counter_accumulates() {
        // Global counter shared with concurrent tests: assert the floor.
        let before = pack_bytes();
        add_pack_bytes(1234);
        assert!(pack_bytes() - before >= 1234);
    }

    #[test]
    fn byte_formatting() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
