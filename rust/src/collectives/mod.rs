//! Collective communication over rank groups.
//!
//! These are *real* message-passing implementations — ring all-gather, ring
//! reduce-scatter, binomial-tree broadcast/reduce — not analytic stand-ins.
//! They run on the [`crate::comm`] transport, so every call both moves the
//! actual shard data (materialized mode) and advances the virtual clocks by
//! the α-β cost of exactly the hops the algorithm performs (both modes).
//!
//! ## Zero-copy, allocation-free hot path
//!
//! The Arc-backed tensor storage plus the per-endpoint recycling pool
//! ([`crate::comm::pool`]) make the ring algorithms allocation-free in the
//! steady state, matching how NCCL-class implementations move buffers:
//!
//! * every `send` enqueues a buffer *handle* — no payload copy, ever;
//! * ring all-gather forwards the received chunk by handle: a chunk that
//!   originated at rank `k` travels all `g−1` hops as refcount bumps on
//!   rank `k`'s original buffer (pinned by `ring_all_gather_forwards_by_handle`);
//! * ring reduce-scatter materializes its accumulator **from the pool**
//!   (step 0 writes `incoming + contribution` into a recycled buffer) and
//!   hands it to the next rank with [`Endpoint::send_owned`], so from step
//!   1 on the receiver holds the only reference and folds in place;
//! * all-reduce chunks its input with zero-copy flat views (`split_flat`)
//!   whenever `numel % g == 0` (padded chunks in the misaligned case come
//!   from the pool), and assembles its output by writing each ring chunk
//!   straight into a pooled output buffer as it arrives;
//! * the binomial-tree `reduce` materializes its accumulator from the pool
//!   on the first fold (pure leaves never touch the pool, and the caller's
//!   tensor is never copy-on-written), and the large-payload `broadcast_bw`
//!   / `reduce_bw` assemble their full outputs straight into pooled buffers
//!   (`broadcast_bw` through the same `all_gather_into` engine the
//!   all-reduce uses) — so the tree/bw paths recycle exactly like the ring
//!   steady state, pinned by the per-endpoint pool-counter tests below.
//!
//! The remaining data movement is the mathematically required work: one
//! accumulator fill per reduce-scatter and one contiguous output assembly
//! per all-gather-shaped result — and after one warmup iteration both run
//! in recycled buffers: a steady-state all-reduce performs **zero** f32
//! buffer allocations and **zero** copy-on-write clones per rank per call.
//! `CommStats::{pool_hits, pool_misses}` pin this per endpoint (exact, test
//! below); the global counters in [`crate::metrics`] and the microbench pin
//! it process-wide. (Small control allocations — shape vectors, the
//! per-call chunk-handle list — are O(g) pointers and not tracked.)
//!
//! Cost shapes (group size `g`, payload `n` bytes, uniform link):
//! * ring all-gather / reduce-scatter: `(g−1)·α + (g−1)/g · n_total/β`
//! * all-reduce (RS + AG):             `2·((g−1)·α + (g−1)/g · n/β)`
//! * binomial broadcast / reduce:      `⌈log₂ g⌉ · (α + n/β)`
//!
//! The paper's Algorithms 1–8 are built from these plus local matmuls.
//!
//! Every function takes the *ordered* group (as produced by
//! [`crate::topology`]) and requires `group[my_pos] == ep.rank()`. Groups of
//! size 1 are no-ops that return immediately — important because the 3-D
//! algorithms degenerate gracefully at `p = 1`.

use crate::comm::Endpoint;
use crate::tensor::Tensor;

/// Split `t`'s flattened data into `g` equal chunks of `ceil(n/g)`
/// elements, zero-padding the tail when `n % g != 0`. The aligned case
/// (`n % g == 0`) produces zero-copy views of `t`'s buffer; the misaligned
/// case materializes padded chunks in recycled pool buffers; phantom input
/// produces phantom chunks.
///
/// These chunk boundaries are *the* deterministic partition map of the
/// crate: [`all_reduce`] is exactly `flat_chunks` → [`reduce_scatter`] →
/// [`all_gather_into`], so any caller that partitions a tensor with
/// `flat_chunks(_, t, g)` and reduce-scatters the result obtains — bitwise —
/// the `k`-th slice of the corresponding all-reduce. The ZeRO-style
/// sharded-optimizer path in `parallel::hybrid` (see
/// `Hybrid::with_zero_stage`) relies on this equality for its headline
/// ZeRO-on ≡ ZeRO-off numerics pin.
pub fn flat_chunks(ep: &mut Endpoint, t: &Tensor, g: usize) -> Vec<Tensor> {
    let n = t.numel();
    let chunk = n.div_ceil(g);
    if t.is_phantom() {
        return (0..g).map(|_| Tensor::phantom(&[chunk])).collect();
    }
    if n % g == 0 {
        return t.split_flat(g);
    }
    (0..g)
        .map(|k| {
            let lo = k * chunk;
            let hi = ((k + 1) * chunk).min(n);
            let copied = hi.saturating_sub(lo);
            let mut c = ep.pooled_tensor(&[chunk]);
            let cd = c.data_mut();
            if copied > 0 {
                cd[..copied].copy_from_slice(&t.data()[lo..hi]);
            }
            cd[copied..].fill(0.0);
            c
        })
        .collect()
}

/// Clamp-and-copy `parts` (group order, possibly zero-padded) into `out`
/// (`n` valid elements): the shared assembly loop of [`assemble_chunks`]
/// and [`assemble_chunks_pooled`], kept in one place so the fresh and
/// pooled paths cannot diverge.
fn assemble_into(out: &mut [f32], parts: &[Tensor], n: usize) {
    let mut off = 0usize;
    for p in parts {
        let d = p.data();
        let take = d.len().min(n - off);
        out[off..off + take].copy_from_slice(&d[..take]);
        off += take;
    }
    // Hard assert (release builds included): short input must crash at the
    // fault site, not propagate a silently zero-padded tail.
    assert_eq!(off, n, "assembled {off} of {n} elements");
}

/// Reassemble a tensor of shape `shape` (numel `n`) from `g` gathered
/// chunks (group order, possibly zero-padded): one contiguous output
/// allocation, one pass.
fn assemble_chunks(parts: &[Tensor], shape: &[usize], n: usize) -> Tensor {
    if parts.iter().any(|p| p.is_phantom()) {
        return Tensor::phantom(shape);
    }
    let mut flat = vec![0.0f32; n];
    assemble_into(&mut flat, parts, n);
    Tensor::from_vec(shape, flat)
}

fn my_pos_checked(ep: &Endpoint, group: &[usize]) -> usize {
    let pos = group
        .iter()
        .position(|&r| r == ep.rank())
        .unwrap_or_else(|| panic!("rank {} is not in group {:?}", ep.rank(), group));
    pos
}

/// One ring-gather traversal — the shared engine beneath [`all_gather`]
/// (chunk-collecting visitor) and [`all_gather_into`] (slot-writing
/// visitor), so the clock/ledger charges of the phantom and materialized
/// all-reduce paths cannot drift apart.
///
/// At step s this rank forwards the chunk that originated at
/// `(pos - s) mod g`. Forwarding is by handle: `incoming` is visited AND
/// re-sent as the next hop's payload, both refcount bumps on the
/// originator's buffer — no chunk is ever deep-copied on the ring. Each
/// step's duration is floored at the ring's bottleneck link (the
/// pipelined-wavefront bound; see `Endpoint::ring_worst_hop`). `visit` is
/// called exactly once per origin (own contribution included), in arrival
/// order.
fn ring_gather(
    ep: &mut Endpoint,
    group: &[usize],
    mine: Tensor,
    mut visit: impl FnMut(usize, &Tensor),
) {
    let g = group.len();
    let pos = my_pos_checked(ep, group);
    visit(pos, &mine);
    if g == 1 {
        return;
    }
    let tag = ep.next_collective_tag(group);
    let next = group[(pos + 1) % g];
    let prev = group[(pos + g - 1) % g];
    let worst = ep.ring_worst_hop(group, mine.nominal_bytes());
    let mut outgoing = mine;
    for s in 0..g - 1 {
        let start = ep.clock;
        ep.send_owned(next, (s as u64) << 48 | tag, outgoing);
        let incoming = ep.recv(prev, (s as u64) << 48 | tag);
        ep.apply_step_floor(start, worst);
        let origin = (pos + g - 1 - s) % g;
        visit(origin, &incoming);
        outgoing = incoming;
    }
    // The final `outgoing` handle drops here; whichever rank drops a
    // chunk's last handle sends a pooled buffer home to its origin pool.
}

/// Ring all-gather: every rank contributes `mine`; returns all `g`
/// contributions in group order (position `k` of the result came from
/// `group[k]`). Contributions may differ in shape across ranks. Every
/// part is a zero-copy handle on its originator's buffer.
pub fn all_gather(ep: &mut Endpoint, group: &[usize], mine: &Tensor) -> Vec<Tensor> {
    let mut parts: Vec<Option<Tensor>> = vec![None; group.len()];
    ring_gather(ep, group, mine.clone(), |origin, chunk| {
        parts[origin] = Some(chunk.clone());
    });
    parts.into_iter().map(|p| p.unwrap()).collect()
}

/// Ring reduce-scatter: `contrib[k]` is this rank's addend destined for
/// `group[k]`; returns the fully reduced chunk owned by this rank
/// (`Σ_ranks contrib[my_pos]`). All ranks must pass shape-consistent chunks.
pub fn reduce_scatter(ep: &mut Endpoint, group: &[usize], contrib: Vec<Tensor>) -> Tensor {
    let g = group.len();
    assert_eq!(contrib.len(), g, "reduce_scatter needs one chunk per group member");
    let pos = my_pos_checked(ep, group);
    if g == 1 {
        return contrib.into_iter().next().unwrap();
    }
    let tag = ep.next_collective_tag(group);
    let next = group[(pos + 1) % g];
    let prev = group[(pos + g - 1) % g];
    let chunks = contrib;
    // Standard ring: at step s, send the partial for destination
    // (pos − s − 1) mod g to `next`; receive the partial for
    // (pos − s − 2) mod g from `prev` and fold in our own contribution.
    // After g−1 steps the chunk for `pos` is complete here (derivation:
    // the partial received at the final step has passed through every other
    // rank exactly once).
    //
    // Allocation discipline: the step-0 fold writes `incoming + ours` into
    // a buffer from this endpoint's recycling pool — the accumulator
    // materialization is mathematically required, the *allocation* is not,
    // and after warmup the pool serves it without touching the heap. The
    // accumulator is handed to the next rank with `send_owned`, so from
    // step 1 on the received partial is the *sole* reference to its buffer
    // and `add_assign` folds in place (no copy-on-write anywhere). When the
    // finished chunk's last handle drops — possibly ranks away — the buffer
    // migrates back to the pool it came from.
    let worst = ep.ring_worst_hop(group, chunks[0].nominal_bytes());
    let mut acc: Option<Tensor> = None;
    for s in 0..g - 1 {
        let send_dst = (pos + g - s - 1) % g; // destination index of outgoing partial
        let outgoing = if s == 0 {
            chunks[send_dst].clone()
        } else {
            acc.take().unwrap()
        };
        let start = ep.clock;
        ep.send_owned(next, (s as u64) << 48 | tag, outgoing);
        let incoming = ep.recv(prev, (s as u64) << 48 | tag);
        ep.apply_step_floor(start, worst);
        let dst = (pos + 2 * g - s - 2) % g;
        let folded = if s == 0 {
            fold_into_pooled(ep, &incoming, &chunks[dst])
        } else {
            let mut f = incoming;
            f.add_assign(&chunks[dst]);
            f
        };
        // Charge the elementwise add (one pass over the chunk).
        ep.charge_memop(folded.nominal_bytes() as f64);
        acc = Some(folded);
    }
    acc.unwrap()
}

/// `a + b` into a pooled scratch tensor — the reduce-scatter step-0
/// accumulator materialization, recycled instead of freshly allocated.
/// Phantom in → phantom out (the pool is never touched in phantom mode).
fn fold_into_pooled(ep: &mut Endpoint, a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape(), b.shape(), "fold shape mismatch: {:?} vs {:?}", a.shape(), b.shape());
    if a.is_phantom() || b.is_phantom() {
        return Tensor::phantom(a.shape());
    }
    let mut out = ep.pooled_tensor(a.shape());
    let od = out.data_mut();
    for ((o, &x), &y) in od.iter_mut().zip(a.data()).zip(b.data()) {
        *o = x + y;
    }
    out
}

/// All-reduce = ring reduce-scatter + ring all-gather on flat chunks of the
/// tensor (chunks padded up to a multiple of `g` elements when misaligned;
/// the aligned case chunks with zero-copy views). The gather phase writes
/// every chunk straight into a pooled output buffer as it crosses this rank
/// ([`all_gather_into`]), so the steady state touches the heap zero times:
/// the only buffers in play are the recycled accumulator, the recycled
/// output, and (misaligned case) recycled padded chunks.
pub fn all_reduce(ep: &mut Endpoint, group: &[usize], t: &Tensor) -> Tensor {
    let g = group.len();
    if g == 1 {
        return t.clone();
    }
    let contrib = flat_chunks(ep, t, g);
    let mine = reduce_scatter(ep, group, contrib);
    if mine.is_phantom() {
        // Phantom mode: drive the ring for identical clock/ledger charges,
        // then return a shape-only result (no buffers exist to assemble).
        let parts = all_gather(ep, group, &mine);
        return assemble_chunks(&parts, t.shape(), t.numel());
    }
    let mut out = ep.pooled_tensor(t.shape());
    all_gather_into(ep, group, mine, out.data_mut());
    out
}

/// Ring all-gather of same-size chunks, written straight into `out` in
/// group order (chunk from `group[k]` lands at offset `k * chunk`, the tail
/// truncated to `out.len()` for padded chunks). Same [`ring_gather`] engine
/// as [`all_gather`] — the per-chunk copy into its output slot is the
/// mathematically required assembly work. Used by [`all_reduce`] so the
/// output can live in a recycled pool buffer instead of a fresh
/// concatenation, and by the ZeRO weight path in `train` to gather each
/// replica's updated `flat_chunks` partition back into the full parameter
/// buffer after a partitioned optimizer step.
pub fn all_gather_into(ep: &mut Endpoint, group: &[usize], mine: Tensor, out: &mut [f32]) {
    let chunk = mine.numel();
    ring_gather(ep, group, mine, |origin, t| {
        let lo = (origin * chunk).min(out.len());
        let hi = ((origin + 1) * chunk).min(out.len());
        out[lo..hi].copy_from_slice(&t.data()[..hi - lo]);
    });
}

/// Binomial-tree broadcast from `group[root_pos]`. The root passes
/// `Some(tensor)`; everyone else passes `None` and gets the tensor back.
pub fn broadcast(
    ep: &mut Endpoint,
    group: &[usize],
    root_pos: usize,
    t: Option<Tensor>,
) -> Tensor {
    let g = group.len();
    let pos = my_pos_checked(ep, group);
    if g == 1 {
        return t.expect("root must supply the tensor");
    }
    let tag = ep.next_collective_tag(group);
    // Rotate so the root is virtual position 0.
    let vpos = (pos + g - root_pos) % g;
    let mut have: Option<Tensor> = if vpos == 0 {
        Some(t.expect("root must supply the tensor"))
    } else {
        assert!(t.is_none(), "non-root rank must pass None to broadcast");
        None
    };
    // Round r: ranks with vpos < 2^r that own the data send to vpos + 2^r.
    let mut span = 1usize;
    while span < g {
        if vpos < span {
            let peer = vpos + span;
            if peer < g {
                let dst = group[(peer + root_pos) % g];
                ep.send(dst, tag, have.as_ref().unwrap());
            }
        } else if vpos < 2 * span && have.is_none() {
            let peer = vpos - span;
            let src = group[(peer + root_pos) % g];
            have = Some(ep.recv(src, tag));
        }
        span *= 2;
    }
    have.unwrap()
}

/// Binomial-tree reduce to `group[root_pos]`: returns `Some(sum)` at the
/// root, `None` elsewhere.
pub fn reduce(
    ep: &mut Endpoint,
    group: &[usize],
    root_pos: usize,
    t: &Tensor,
) -> Option<Tensor> {
    let g = group.len();
    let pos = my_pos_checked(ep, group);
    if g == 1 {
        return Some(t.clone());
    }
    let tag = ep.next_collective_tag(group);
    let vpos = (pos + g - root_pos) % g;
    // Bottom-up binomial tree: at round `step` the active ranks are the
    // multiples of `step`; those at odd multiples send their partial to
    // `vpos − step` (an even multiple, still active this round) and leave.
    // Nobody ever sends to a rank that has already left the collective —
    // the property that makes this safe against endpoint teardown races.
    //
    // Allocation discipline (mirrors the ring reduce-scatter): the first
    // fold writes `t + incoming` into a recycled pool buffer; later folds
    // add into that sole-owner buffer in place. A pure leaf sends `t`
    // itself (a handle) and never touches the pool, and `t` is never
    // copy-on-written. The accumulator is handed up with `send_owned`, so
    // the parent's drop sends it home to this rank's pool.
    let mut acc: Option<Tensor> = None;
    let mut step = 1usize;
    while step < g {
        if vpos % (2 * step) == step {
            let peer = vpos - step;
            let dst = group[(peer + root_pos) % g];
            match acc {
                Some(a) => ep.send_owned(dst, tag, a),
                None => ep.send(dst, tag, t),
            }
            return None; // partial handed up the tree; done
        }
        // vpos % (2*step) == 0: receive from vpos + step if it exists.
        let peer = vpos + step;
        if peer < g {
            let src = group[(peer + root_pos) % g];
            let incoming = ep.recv(src, tag);
            match acc {
                Some(ref mut a) => a.add_assign(&incoming),
                None => acc = Some(fold_into_pooled(ep, t, &incoming)),
            }
            ep.charge_memop(t.nominal_bytes() as f64);
        }
        step *= 2;
    }
    Some(acc.unwrap_or_else(|| t.clone()))
}

/// Bandwidth-optimal broadcast for large payloads of a shape every rank
/// already knows (SUMMA panels, bias chunks): scatter-then-all-gather, the
/// NCCL large-message algorithm. Cost ≈ `2·(g−1)/g · n/β` instead of the
/// binomial tree's `⌈log₂g⌉ · n/β`. The root's egress serialization during
/// the scatter phase is charged to its virtual clock.
pub fn broadcast_bw(
    ep: &mut Endpoint,
    group: &[usize],
    root_pos: usize,
    t: Option<Tensor>,
    shape: &[usize],
) -> Tensor {
    let g = group.len();
    let pos = my_pos_checked(ep, group);
    if g == 1 {
        return t.expect("root must supply the tensor");
    }
    let n: usize = shape.iter().product();
    let chunk = n.div_ceil(g);
    let tag = ep.next_collective_tag(group);
    // Scatter phase: root splits into g padded chunks and sends each member
    // its chunk (egress serialized on the root's clock).
    let mine = if pos == root_pos {
        let t = t.expect("root must supply the tensor");
        assert_eq!(t.shape(), shape, "broadcast_bw shape mismatch");
        // Zero-copy chunk views in the aligned case; the sends below are
        // handle handoffs either way.
        let chunks = flat_chunks(ep, &t, g);
        for (k, &dst) in group.iter().enumerate() {
            if k != root_pos {
                // Egress serialization: the k-th chunk leaves after k−1
                // previous ones.
                let cost = ep.net().hop_cost(ep.rank(), dst, chunk * 4)
                    - ep.net().hop_cost(ep.rank(), dst, 0);
                ep.clock += cost.max(0.0);
                ep.send(dst, tag, &chunks[k]);
            }
        }
        chunks[root_pos].clone()
    } else {
        assert!(t.is_none(), "non-root must pass None to broadcast_bw");
        ep.recv(group[root_pos], tag)
    };
    // All-gather phase reassembles the full payload everywhere, written
    // straight into a pooled output buffer (the phantom path drives the
    // chunk-collecting ring instead for identical clock/ledger charges —
    // there are no buffers to assemble).
    if mine.is_phantom() {
        let parts = all_gather(ep, group, &mine);
        return assemble_chunks(&parts, shape, n);
    }
    let mut out = ep.pooled_tensor(shape);
    all_gather_into(ep, group, mine, out.data_mut());
    out
}

/// Bandwidth-optimal reduce for large payloads: ring reduce-scatter then a
/// chunk gather to the root (cost ≈ `2·n/β` vs the tree's `log₂g·n/β`).
pub fn reduce_bw(
    ep: &mut Endpoint,
    group: &[usize],
    root_pos: usize,
    t: &Tensor,
) -> Option<Tensor> {
    let g = group.len();
    let pos = my_pos_checked(ep, group);
    if g == 1 {
        return Some(t.clone());
    }
    let contrib = flat_chunks(ep, t, g);
    let mine = reduce_scatter(ep, group, contrib);
    gather_tensor(ep, group, root_pos, &mine, t.shape())
}

/// Gather equal flat chunks (one per rank, zero-padded tails allowed) to
/// the root and reassemble them into a tensor of `shape` — the
/// pooled-assembly form of [`gather`]: the root's output comes from its
/// recycling pool (`assemble_chunks_pooled`), so a steady-state caller
/// allocates nothing. Returns `Some(tensor)` at the root, `None`
/// elsewhere. Used by [`reduce_bw`]'s root assembly and as the control-path
/// gather for checkpoint-style global reassembly.
pub fn gather_tensor(
    ep: &mut Endpoint,
    group: &[usize],
    root_pos: usize,
    mine: &Tensor,
    shape: &[usize],
) -> Option<Tensor> {
    let n: usize = shape.iter().product();
    let parts = gather(ep, group, root_pos, mine)?;
    Some(assemble_chunks_pooled(ep, &parts, shape, n))
}

/// Scatter one tensor from the root as `g` equal flat chunks
/// (`ceil(n/g)` elements each, zero-padded tail) — the pooled form of
/// [`scatter`]: the root chunks through `flat_chunks`, so aligned
/// payloads ship zero-copy views and misaligned payloads ship recycled
/// pool buffers; receivers get handles. Every rank returns its chunk
/// (the root keeps `chunks[root_pos]` without sending it anywhere).
pub fn scatter_tensor(
    ep: &mut Endpoint,
    group: &[usize],
    root_pos: usize,
    t: Option<&Tensor>,
    shape: &[usize],
) -> Tensor {
    let g = group.len();
    // Chunking is the only scatter_tensor-specific work; the collective
    // protocol itself is [`scatter`]'s, so the two cannot drift.
    let parts = t.map(|t| {
        assert_eq!(t.shape(), shape, "scatter_tensor shape mismatch");
        flat_chunks(ep, t, g)
    });
    scatter(ep, group, root_pos, parts)
}

/// Reassemble like [`assemble_chunks`], but into a recycled pool buffer —
/// the root-side assembly of [`reduce_bw`]. Phantom parts produce a phantom
/// result without touching the pool.
fn assemble_chunks_pooled(
    ep: &mut Endpoint,
    parts: &[Tensor],
    shape: &[usize],
    n: usize,
) -> Tensor {
    if parts.iter().any(|p| p.is_phantom()) {
        return Tensor::phantom(shape);
    }
    let mut out = ep.pooled_tensor(shape);
    assemble_into(out.data_mut(), parts, n);
    out
}

/// Gather all contributions to `group[root_pos]` (returns `Some(parts)` in
/// group order at the root, `None` elsewhere). Linear algorithm — gather is
/// only used on control paths (global assembly for checkpoints/tests), never
/// in the training step.
pub fn gather(
    ep: &mut Endpoint,
    group: &[usize],
    root_pos: usize,
    mine: &Tensor,
) -> Option<Vec<Tensor>> {
    let g = group.len();
    let pos = my_pos_checked(ep, group);
    if g == 1 {
        return Some(vec![mine.clone()]);
    }
    let tag = ep.next_collective_tag(group);
    if pos == root_pos {
        let mut parts: Vec<Option<Tensor>> = vec![None; g];
        parts[pos] = Some(mine.clone());
        for (k, &src) in group.iter().enumerate() {
            if k != root_pos {
                parts[k] = Some(ep.recv(src, tag));
            }
        }
        Some(parts.into_iter().map(|p| p.unwrap()).collect())
    } else {
        ep.send(group[root_pos], tag, mine);
        None
    }
}

/// Scatter `parts` (present at the root only, group order) so member `k`
/// receives `parts[k]`. Control-path counterpart of `gather`.
pub fn scatter(
    ep: &mut Endpoint,
    group: &[usize],
    root_pos: usize,
    parts: Option<Vec<Tensor>>,
) -> Tensor {
    let g = group.len();
    let pos = my_pos_checked(ep, group);
    if g == 1 {
        return parts.expect("root must supply parts").into_iter().next().unwrap();
    }
    let tag = ep.next_collective_tag(group);
    if pos == root_pos {
        let parts = parts.expect("root must supply parts");
        assert_eq!(parts.len(), g);
        for (k, &dst) in group.iter().enumerate() {
            if k != root_pos {
                ep.send(dst, tag, &parts[k]);
            }
        }
        parts[root_pos].clone()
    } else {
        assert!(parts.is_none());
        ep.recv(group[root_pos], tag)
    }
}

// --- non-blocking collectives (compute/comm overlap) ---------------------
//
// The `iall_*` forms run the *identical* synchronous algorithm — same
// reduction order, same participant set, same per-collective tag sequence,
// so results and the traffic ledger are bit-for-bit those of the blocking
// calls — inside an [`Endpoint::defer`] window: the clock cost rides the
// endpoint's comm timeline and the returned [`PendingColl`] joins it.
// With `CUBIC_OVERLAP=0` they degenerate to the blocking schedule exactly.

/// Completion handle for a non-blocking collective. Owns the result (the
/// collective's pooled output buffer travels with the handle); `wait`
/// joins the clock ticket and releases it. Dropping a handle without
/// waiting leaves the ticket for [`Endpoint::join_all`] at the step
/// boundary — the value is still valid, only the clock join is pending.
#[must_use = "wait() joins the comm-timeline ticket (or let join_all retire it)"]
pub struct PendingColl<T> {
    value: T,
    ticket: Option<u64>,
}

impl<T> PendingColl<T> {
    /// Join the collective on the compute timeline and take the result:
    /// `clock = max(clock, finish)`, with the stall split into exposed vs
    /// overlapped comm (see the `comm` module docs).
    pub fn wait(self, ep: &mut Endpoint) -> T {
        if let Some(id) = self.ticket {
            ep.join_ticket(id);
        }
        self.value
    }

    /// Take the result *without* joining the clock ticket — the issue-site
    /// pattern: values are needed by the next layer's bookkeeping while
    /// the virtual transfer keeps riding the comm timeline until
    /// [`Endpoint::drain_ready`] / [`Endpoint::join_all`].
    pub fn into_inner(self) -> T {
        self.value
    }

    /// True when a comm-timeline ticket is in flight (overlap on).
    pub fn is_deferred(&self) -> bool {
        self.ticket.is_some()
    }
}

impl Endpoint {
    /// Non-blocking [`all_reduce`] over `group`.
    pub fn iall_reduce(&mut self, group: &[usize], t: &Tensor) -> PendingColl<Tensor> {
        let (value, ticket) = self.defer(|ep| all_reduce(ep, group, t));
        PendingColl { value, ticket }
    }

    /// Non-blocking [`reduce_scatter`] over `group`.
    pub fn ireduce_scatter(
        &mut self,
        group: &[usize],
        contrib: Vec<Tensor>,
    ) -> PendingColl<Tensor> {
        let (value, ticket) = self.defer(|ep| reduce_scatter(ep, group, contrib));
        PendingColl { value, ticket }
    }

    /// Non-blocking [`all_gather`] over `group`.
    pub fn iall_gather(&mut self, group: &[usize], mine: &Tensor) -> PendingColl<Vec<Tensor>> {
        let (value, ticket) = self.defer(|ep| all_gather(ep, group, mine));
        PendingColl { value, ticket }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::NetModel;
    use crate::spmd::run_spmd;

    #[test]
    fn all_gather_collects_in_group_order() {
        let out = run_spmd(4, NetModel::zero(), |rank, ep| {
            let mine = Tensor::from_vec(&[1], vec![rank as f32]);
            let parts = all_gather(ep, &[0, 1, 2, 3], &mine);
            parts.iter().map(|p| p.data()[0]).collect::<Vec<_>>()
        });
        for r in out {
            assert_eq!(r, vec![0.0, 1.0, 2.0, 3.0]);
        }
    }

    #[test]
    fn all_gather_on_subgroup() {
        let out = run_spmd(4, NetModel::zero(), |rank, ep| {
            // Two disjoint groups {0,2} and {1,3} run concurrently.
            let group = if rank % 2 == 0 { vec![0, 2] } else { vec![1, 3] };
            let mine = Tensor::from_vec(&[1], vec![rank as f32 * 10.0]);
            let parts = all_gather(ep, &group, &mine);
            parts.iter().map(|p| p.data()[0]).collect::<Vec<_>>()
        });
        assert_eq!(out[0], vec![0.0, 20.0]);
        assert_eq!(out[2], vec![0.0, 20.0]);
        assert_eq!(out[1], vec![10.0, 30.0]);
        assert_eq!(out[3], vec![10.0, 30.0]);
    }

    #[test]
    fn reduce_scatter_sums_per_destination() {
        let out = run_spmd(3, NetModel::zero(), |rank, ep| {
            // contrib[k] = rank + k*100 — destination k should end with
            // sum_r (r + k*100) = 3 + 300k... wait: 0+1+2 = 3.
            let contrib = (0..3)
                .map(|k| Tensor::from_vec(&[2], vec![(rank + k * 100) as f32; 2]))
                .collect();
            let got = reduce_scatter(ep, &[0, 1, 2], contrib);
            got.data()[0]
        });
        assert_eq!(out[0], 3.0); // 0+1+2
        assert_eq!(out[1], 303.0); // 100*3 + 3
        assert_eq!(out[2], 603.0);
    }

    #[test]
    fn all_reduce_matches_local_sum() {
        for n in [1usize, 2, 3, 5] {
            let out = run_spmd(n, NetModel::zero(), move |rank, ep| {
                let group: Vec<usize> = (0..ep.world_size()).collect();
                // numel = 7, deliberately not divisible by most group sizes.
                let t = Tensor::from_vec(&[7], (0..7).map(|i| (rank * 7 + i) as f32).collect());
                all_reduce(ep, &group, &t)
            });
            let expected: Vec<f32> = (0..7)
                .map(|i| (0..n).map(|r| (r * 7 + i) as f32).sum())
                .collect();
            for r in &out {
                assert_eq!(r.data(), &expected[..], "world size {n}");
            }
        }
    }

    #[test]
    fn broadcast_from_each_root() {
        for root in 0..4 {
            let out = run_spmd(4, NetModel::zero(), move |rank, ep| {
                let t = (rank == root).then(|| Tensor::from_vec(&[3], vec![root as f32; 3]));
                broadcast(ep, &[0, 1, 2, 3], root, t)
            });
            for r in out {
                assert_eq!(r.data(), &[root as f32; 3]);
            }
        }
    }

    #[test]
    fn reduce_to_root_sums() {
        for root in 0..3 {
            let out = run_spmd(3, NetModel::zero(), move |rank, ep| {
                let t = Tensor::from_vec(&[2], vec![rank as f32 + 1.0; 2]);
                reduce(ep, &[0, 1, 2], root, &t)
            });
            for (rank, r) in out.iter().enumerate() {
                if rank == root {
                    assert_eq!(r.as_ref().unwrap().data(), &[6.0, 6.0]);
                } else {
                    assert!(r.is_none());
                }
            }
        }
    }

    #[test]
    fn gather_scatter_round_trip() {
        let out = run_spmd(3, NetModel::zero(), |rank, ep| {
            let mine = Tensor::from_vec(&[1], vec![rank as f32]);
            let gathered = gather(ep, &[0, 1, 2], 1, &mine);
            // Root re-scatters reversed.
            let parts = gathered.map(|mut g| {
                g.reverse();
                g
            });
            let back = scatter(ep, &[0, 1, 2], 1, parts);
            back.data()[0]
        });
        assert_eq!(out, vec![2.0, 1.0, 0.0]);
    }

    #[test]
    fn ring_all_gather_forwards_by_handle() {
        // Zero-copy pin: at every rank, part k of the gathered result must
        // share storage with the tensor rank k originally contributed —
        // i.e. the chunk traveled the whole ring as a refcount bump, never
        // as a deep copy.
        let out = run_spmd(4, NetModel::zero(), |rank, ep| {
            let mine = Tensor::full(&[32], rank as f32);
            let parts = all_gather(ep, &[0, 1, 2, 3], &mine);
            (mine, parts)
        });
        for (rank, (_, parts)) in out.iter().enumerate() {
            for (k, part) in parts.iter().enumerate() {
                assert!(
                    part.shares_storage(&out[k].0),
                    "rank {rank}: part {k} was deep-copied on the ring"
                );
            }
        }
    }

    #[test]
    fn steady_state_all_reduce_is_allocation_free_after_warmup() {
        // The zero-allocation pin of ROADMAP item 2: after one warmup
        // iteration, every scratch buffer an all-reduce needs (the
        // reduce-scatter accumulator, the all-gather output assembly) is
        // served by the endpoint's recycling pool — `pool_misses` stops
        // growing. The counters are per-endpoint, so this is exact even
        // with other tests running concurrently in the process. Buffers
        // migrate home across rank threads asynchronously, so each
        // iteration ends with a (real, not virtual) barrier: by the time
        // every rank passes it, every handle from the previous call has
        // dropped and every buffer is back in its origin pool.
        let world = 4usize;
        let elems = 64usize;
        let iters = 6u64;
        let out = run_spmd(world, NetModel::zero(), move |rank, ep| {
            let group: Vec<usize> = (0..world).collect();
            let t = Tensor::full(&[elems], (rank + 1) as f32);
            // Warmup: allocates the accumulator + output buffers once.
            let r = all_reduce(ep, &group, &t);
            assert_eq!(r.data()[0], (1 + 2 + 3 + 4) as f32);
            drop(r);
            ep.barrier_wait();
            let (h0, m0) = (ep.stats.pool_hits, ep.stats.pool_misses);
            for _ in 0..iters {
                let r = all_reduce(ep, &group, &t);
                assert_eq!(r.data()[0], (1 + 2 + 3 + 4) as f32);
                drop(r);
                ep.barrier_wait();
            }
            (ep.stats.pool_hits - h0, ep.stats.pool_misses - m0, rank)
        });
        for (hits, misses, rank) in out {
            assert_eq!(misses, 0, "rank {rank}: steady state must not allocate");
            // Two pool requests per call: accumulator + output assembly.
            assert_eq!(hits, 2 * iters, "rank {rank}: every request must hit the pool");
        }
    }

    #[test]
    fn misaligned_all_reduce_also_reaches_pool_steady_state() {
        // n % g != 0: the g padded chunks are pooled too (g + 2 requests
        // per call), and the steady state is still allocation-free.
        let world = 3usize;
        let elems = 7usize;
        let iters = 5u64;
        let out = run_spmd(world, NetModel::zero(), move |rank, ep| {
            let group: Vec<usize> = (0..world).collect();
            let t = Tensor::from_vec(&[elems], vec![(rank + 1) as f32; elems]);
            let r = all_reduce(ep, &group, &t);
            assert_eq!(r.data()[0], 6.0);
            drop(r);
            ep.barrier_wait();
            let m0 = ep.stats.pool_misses;
            for _ in 0..iters {
                let r = all_reduce(ep, &group, &t);
                assert_eq!(r.data(), &[6.0; 7][..]);
                drop(r);
                ep.barrier_wait();
            }
            ep.stats.pool_misses - m0
        });
        for (rank, misses) in out.iter().enumerate() {
            assert_eq!(*misses, 0, "rank {rank}: padded chunks must recycle");
        }
    }

    #[test]
    fn all_reduce_send_path_and_aligned_chunking_never_clone() {
        // Aligned chunking is zero-copy views and the whole collective no
        // longer copy-on-writes at all (the accumulator fill is an explicit
        // write into a pooled buffer). The global bytes-cloned counter is
        // shared with concurrent tests, so the exact-zero equality is
        // pinned by the microbench (own process); here we pin the
        // structural facts that imply it.
        let t = Tensor::full(&[64], 3.0);
        let chunks = t.split_flat(4);
        for c in &chunks {
            assert!(c.shares_storage(&t), "aligned chunks must be views");
        }
    }

    #[test]
    fn tree_reduce_steady_state_is_allocation_free_after_warmup() {
        // ROADMAP item 4, part 1: the binomial-tree reduce accumulator
        // comes from the pool. g = 4, root 0: vpos 0 folds twice (one pool
        // request, then in place), vpos 2 folds once (one request), vpos 1
        // and 3 are pure leaves (zero requests) — so after warmup the hits
        // grow by exactly {1, 0, 1, 0} per call and misses stay flat.
        let g = 4usize;
        let iters = 5u64;
        let out = run_spmd(g, NetModel::zero(), move |rank, ep| {
            let group: Vec<usize> = (0..g).collect();
            let t = Tensor::from_vec(&[16], vec![(rank + 1) as f32; 16]);
            let r = reduce(ep, &group, 0, &t);
            if rank == 0 {
                assert_eq!(r.as_ref().unwrap().data()[0], 10.0);
            } else {
                assert!(r.is_none());
            }
            drop(r);
            ep.barrier_wait();
            let (h0, m0) = (ep.stats.pool_hits, ep.stats.pool_misses);
            for _ in 0..iters {
                let r = reduce(ep, &group, 0, &t);
                if rank == 0 {
                    assert_eq!(r.as_ref().unwrap().data()[0], 10.0);
                }
                drop(r);
                ep.barrier_wait();
            }
            (ep.stats.pool_hits - h0, ep.stats.pool_misses - m0)
        });
        for (rank, (hits, misses)) in out.iter().enumerate() {
            assert_eq!(*misses, 0, "rank {rank}: tree reduce must recycle after warmup");
            let expect = if rank % 2 == 0 { iters } else { 0 };
            assert_eq!(*hits, expect, "rank {rank}: folding ranks hit the pool once per call");
        }
    }

    #[test]
    fn broadcast_bw_steady_state_recycles_the_assembly() {
        // ROADMAP item 4, part 2: broadcast_bw assembles into a pooled
        // output. Aligned payload → the root's chunks are zero-copy views,
        // so the output assembly is the only pool request: exactly one hit
        // per rank per call after warmup, zero misses.
        let g = 4usize;
        let n = 64usize;
        let root = 1usize;
        let iters = 5u64;
        let out = run_spmd(g, NetModel::zero(), move |rank, ep| {
            let group: Vec<usize> = (0..g).collect();
            let t = Tensor::from_vec(&[n], (0..n).map(|i| i as f32).collect());
            let run_one = |ep: &mut crate::comm::Endpoint| {
                let arg = (rank == root).then(|| t.clone());
                let r = broadcast_bw(ep, &group, root, arg, &[n]);
                assert_eq!(r.data()[5], 5.0);
                drop(r);
                ep.barrier_wait();
            };
            run_one(ep); // warmup allocates the assembly buffer once
            let (h0, m0) = (ep.stats.pool_hits, ep.stats.pool_misses);
            for _ in 0..iters {
                run_one(ep);
            }
            (ep.stats.pool_hits - h0, ep.stats.pool_misses - m0)
        });
        for (rank, (hits, misses)) in out.iter().enumerate() {
            assert_eq!(*misses, 0, "rank {rank}: broadcast_bw must recycle after warmup");
            assert_eq!(*hits, iters, "rank {rank}: one pooled assembly per call");
        }
    }

    #[test]
    fn misaligned_broadcast_bw_also_reaches_pool_steady_state() {
        // n % g != 0: the root's padded chunks are pooled too; the steady
        // state must still be allocation-free everywhere.
        let g = 3usize;
        let n = 7usize;
        let iters = 5u64;
        let out = run_spmd(g, NetModel::zero(), move |rank, ep| {
            let group: Vec<usize> = (0..g).collect();
            let t = Tensor::from_vec(&[n], vec![2.5; n]);
            let run_one = |ep: &mut crate::comm::Endpoint| {
                let arg = (rank == 0).then(|| t.clone());
                let r = broadcast_bw(ep, &group, 0, arg, &[n]);
                assert_eq!(r.data(), &[2.5; 7][..]);
                drop(r);
                ep.barrier_wait();
            };
            run_one(ep);
            let m0 = ep.stats.pool_misses;
            for _ in 0..iters {
                run_one(ep);
            }
            ep.stats.pool_misses - m0
        });
        for (rank, misses) in out.iter().enumerate() {
            assert_eq!(*misses, 0, "rank {rank}: padded bw chunks must recycle");
        }
    }

    #[test]
    fn reduce_bw_steady_state_recycles_accumulator_and_assembly() {
        // ROADMAP item 4, part 3: reduce_bw = ring reduce-scatter (pooled
        // accumulator on every rank) + root-side pooled assembly. Aligned
        // payload: exactly one hit per rank per call, two at the root.
        let g = 4usize;
        let n = 64usize;
        let root = 2usize;
        let iters = 5u64;
        let out = run_spmd(g, NetModel::zero(), move |rank, ep| {
            let group: Vec<usize> = (0..g).collect();
            let t = Tensor::from_vec(&[n], vec![(rank + 1) as f32; n]);
            let run_one = |ep: &mut crate::comm::Endpoint| {
                let r = reduce_bw(ep, &group, root, &t);
                if rank == root {
                    assert_eq!(r.as_ref().unwrap().data()[0], 10.0);
                } else {
                    assert!(r.is_none());
                }
                drop(r);
                ep.barrier_wait();
            };
            run_one(ep);
            let (h0, m0) = (ep.stats.pool_hits, ep.stats.pool_misses);
            for _ in 0..iters {
                run_one(ep);
            }
            (ep.stats.pool_hits - h0, ep.stats.pool_misses - m0)
        });
        for (rank, (hits, misses)) in out.iter().enumerate() {
            assert_eq!(*misses, 0, "rank {rank}: reduce_bw must recycle after warmup");
            let expect = if rank == root { 2 * iters } else { iters };
            assert_eq!(*hits, expect, "rank {rank}: accumulator (+ root assembly) per call");
        }
    }

    #[test]
    fn gather_tensor_matches_gather_and_recycles_root_assembly() {
        // ROADMAP pool follow-on: gather now assembles through
        // assemble_chunks_pooled. Correctness: the root's assembled tensor
        // equals the concatenation of everyone's chunks. Steady state: after
        // one warmup, the root takes exactly one pooled buffer per call
        // (the assembly) and misses zero; non-roots never touch the pool.
        let g = 4usize;
        let chunk = 16usize;
        let root = 1usize;
        let iters = 5u64;
        let out = run_spmd(g, NetModel::zero(), move |rank, ep| {
            let group: Vec<usize> = (0..g).collect();
            let mine = Tensor::full(&[chunk], rank as f32);
            let shape = [g * chunk];
            let run_one = |ep: &mut crate::comm::Endpoint| {
                let r = gather_tensor(ep, &group, root, &mine, &shape);
                if rank == root {
                    let r = r.as_ref().unwrap();
                    for k in 0..g {
                        assert_eq!(r.data()[k * chunk], k as f32, "chunk {k} misplaced");
                    }
                } else {
                    assert!(r.is_none());
                }
                drop(r);
                ep.barrier_wait();
            };
            run_one(ep); // warmup allocates the root assembly once
            let (h0, m0) = (ep.stats.pool_hits, ep.stats.pool_misses);
            for _ in 0..iters {
                run_one(ep);
            }
            (ep.stats.pool_hits - h0, ep.stats.pool_misses - m0)
        });
        for (rank, (hits, misses)) in out.iter().enumerate() {
            assert_eq!(*misses, 0, "rank {rank}: gather_tensor must recycle after warmup");
            let expect = if rank == root { iters } else { 0 };
            assert_eq!(*hits, expect, "rank {rank}: one pooled assembly per call at the root");
        }
    }

    #[test]
    fn scatter_tensor_round_trips_and_misaligned_chunks_recycle() {
        // Aligned: every rank's chunk is a zero-copy view of the root's
        // payload (no pool traffic at all). Misaligned: the root's padded
        // chunks come from its pool — zero misses after warmup.
        let g = 3usize;
        let iters = 5u64;
        let out = run_spmd(g, NetModel::zero(), move |rank, ep| {
            let group: Vec<usize> = (0..g).collect();
            // Aligned round trip (n = 12, chunk 4).
            let t = (rank == 0)
                .then(|| Tensor::from_vec(&[12], (0..12).map(|i| i as f32).collect()));
            let chunk = scatter_tensor(ep, &group, 0, t.as_ref(), &[12]);
            assert_eq!(chunk.numel(), 4);
            assert_eq!(chunk.data()[0], (rank * 4) as f32);
            drop(chunk);
            ep.barrier_wait();
            // Misaligned steady state (n = 7, chunk 3, padded).
            let t7 = (rank == 0).then(|| Tensor::from_vec(&[7], vec![2.5; 7]));
            let run_one = |ep: &mut crate::comm::Endpoint| {
                let c = scatter_tensor(ep, &group, 0, t7.as_ref(), &[7]);
                assert_eq!(c.numel(), 3);
                if rank < 2 {
                    assert_eq!(c.data()[0], 2.5);
                } else {
                    // Last chunk: one valid element + two pad zeros.
                    assert_eq!(c.data(), &[2.5, 0.0, 0.0]);
                }
                drop(c);
                ep.barrier_wait();
            };
            run_one(ep); // warmup allocates the root's padded chunks once
            let m0 = ep.stats.pool_misses;
            for _ in 0..iters {
                run_one(ep);
            }
            ep.stats.pool_misses - m0
        });
        for (rank, misses) in out.iter().enumerate() {
            assert_eq!(*misses, 0, "rank {rank}: padded scatter chunks must recycle");
        }
    }

    #[test]
    fn phantom_all_reduce_keeps_shape_and_charges_bytes() {
        let out = run_spmd(4, NetModel::flat(1e-6, 1e9, f64::INFINITY), |_, ep| {
            let group: Vec<usize> = (0..4).collect();
            let t = Tensor::phantom(&[256, 256]);
            let r = all_reduce(ep, &group, &t);
            (r.is_phantom(), r.shape().to_vec(), ep.clock, ep.stats.bytes_sent)
        });
        for (ph, shape, clock, bytes) in out {
            assert!(ph);
            assert_eq!(shape, vec![256, 256]);
            // Ring all-reduce sends 2*(g-1) chunks of n/g bytes each.
            let n = 256 * 256 * 4u64;
            assert_eq!(bytes, 2 * 3 * (n / 4));
            // Virtual clock advanced: 6 hops of (alpha + chunk/beta).
            let chunk = (n / 4) as f64;
            let expect = 6.0 * (1e-6 + chunk / 1e9);
            assert!((clock - expect).abs() < expect * 0.01, "clock {clock} vs {expect}");
        }
    }

    #[test]
    fn clocks_converge_after_all_reduce() {
        // Ranks start with wildly different clocks; after an all-reduce the
        // slowest participant dominates everyone (within one ring traversal).
        let out = run_spmd(4, NetModel::flat(1e-6, 1e12, f64::INFINITY), |rank, ep| {
            ep.clock = rank as f64; // rank 3 is 3 virtual seconds behind
            let t = Tensor::zeros(&[64]);
            let _ = all_reduce(ep, &(0..4).collect::<Vec<_>>(), &t);
            ep.clock
        });
        for c in out {
            assert!(c >= 3.0, "clock {c} should be dominated by slowest rank");
        }
    }

    #[test]
    fn singleton_groups_are_noops() {
        let out = run_spmd(1, NetModel::zero(), |_, ep| {
            let t = Tensor::from_vec(&[2], vec![1.0, 2.0]);
            let ag = all_gather(ep, &[0], &t);
            let rs = reduce_scatter(ep, &[0], vec![t.clone()]);
            let ar = all_reduce(ep, &[0], &t);
            let bc = broadcast(ep, &[0], 0, Some(t.clone()));
            (ag.len(), rs, ar, bc, ep.stats.messages_sent)
        });
        let (n, rs, ar, bc, sent) = &out[0];
        assert_eq!(*n, 1);
        assert_eq!(rs.data(), &[1.0, 2.0]);
        assert_eq!(ar.data(), &[1.0, 2.0]);
        assert_eq!(bc.data(), &[1.0, 2.0]);
        assert_eq!(*sent, 0);
    }

    #[test]
    fn iall_reduce_is_bitwise_identical_to_blocking_in_both_modes() {
        for overlap in [false, true] {
            let mut net = NetModel::zero();
            net.overlap = overlap;
            let out = run_spmd(3, net, move |rank, ep| {
                let group = vec![0, 1, 2];
                let t = Tensor::from_vec(
                    &[7],
                    (0..7).map(|i| ((rank * 7 + i) as f32).sin()).collect(),
                );
                let sync = all_reduce(ep, &group, &t);
                let pend = ep.iall_reduce(&group, &t).wait(ep);
                (sync, pend)
            });
            for (sync, pend) in out {
                assert_eq!(
                    sync.data(),
                    pend.data(),
                    "overlap={overlap}: deferred schedule must be bit-identical"
                );
            }
        }
    }

    #[test]
    fn multiple_pending_collectives_ride_in_flight() {
        let mut net = NetModel::flat(0.0, 1e9, 1e12);
        net.overlap = true;
        let out = run_spmd(2, net, |rank, ep| {
            let group = vec![0, 1];
            let a = Tensor::from_vec(&[4], vec![rank as f32 + 1.0; 4]);
            let b = Tensor::from_vec(&[4], vec![(rank as f32 + 1.0) * 10.0; 4]);
            let pa = ep.iall_reduce(&group, &a);
            let pb = ep.iall_reduce(&group, &b);
            assert!(pa.is_deferred() && pb.is_deferred());
            assert_eq!(ep.pending_colls(), 2, "both handles must be in flight");
            let ra = pa.wait(ep);
            let rb = pb.wait(ep);
            assert_eq!(ep.pending_colls(), 0);
            (ra, rb)
        });
        for (ra, rb) in out {
            assert_eq!(ra.data(), &[3.0; 4]);
            assert_eq!(rb.data(), &[30.0; 4]);
        }
    }

    #[test]
    fn ireduce_scatter_and_iall_gather_match_blocking() {
        let mut net = NetModel::zero();
        net.overlap = true;
        let out = run_spmd(3, net, |rank, ep| {
            let group = vec![0, 1, 2];
            let contrib: Vec<Tensor> = (0..3)
                .map(|k| Tensor::from_vec(&[2], vec![(rank + k * 100) as f32; 2]))
                .collect();
            let mine = ep.ireduce_scatter(&group, contrib).wait(ep);
            let parts = ep.iall_gather(&group, &mine).wait(ep);
            parts.iter().map(|p| p.data()[0]).collect::<Vec<_>>()
        });
        for r in out {
            assert_eq!(r, vec![3.0, 303.0, 603.0]);
        }
    }

    #[test]
    fn pending_all_reduce_steady_state_recycles() {
        // In-flight collective buffers must keep hitting the pool: after
        // one warmup call, 10 deferred all-reduces = 20 pool hits (the RS
        // accumulator + the AG assembly per call) and zero allocations.
        let mut net = NetModel::flat(0.0, 1e9, 1e12);
        net.overlap = true;
        let iters = 10u64;
        let out = run_spmd(2, net, move |_, ep| {
            let group = vec![0, 1];
            let t = Tensor::from_vec(&[64], vec![1.0; 64]);
            let _ = ep.iall_reduce(&group, &t).wait(ep); // warmup
            let (h0, m0) = (ep.stats.pool_hits, ep.stats.pool_misses);
            for _ in 0..iters {
                let _r = ep.iall_reduce(&group, &t).wait(ep);
            }
            ep.join_all();
            (ep.stats.pool_hits - h0, ep.stats.pool_misses - m0)
        });
        for (hits, misses) in out {
            assert_eq!(misses, 0, "pending-collective steady state must not allocate");
            assert_eq!(hits, 2 * iters, "two pooled buffers per aligned all-reduce");
        }
    }
}

#[cfg(test)]
mod reduce_tree_tests {
    use super::*;
    use crate::comm::NetModel;
    use crate::spmd::run_spmd;

    #[test]
    fn reduce_correct_for_all_group_sizes_and_roots() {
        // The g >= 4 regression: the old top-down tree silently dropped
        // contributions from ranks like vpos 3 (g=4) and stranded their
        // messages at exited peers. Sweep sizes incl. non-powers-of-two.
        for g in 2..=9usize {
            for root in [0, g / 2, g - 1] {
                let out = run_spmd(g, NetModel::zero(), move |rank, ep| {
                    let group: Vec<usize> = (0..g).collect();
                    let t = Tensor::from_vec(&[2], vec![(rank + 1) as f32; 2]);
                    reduce(ep, &group, root, &t)
                });
                let expect = (g * (g + 1) / 2) as f32;
                for (rank, r) in out.iter().enumerate() {
                    if rank == root {
                        let v = r.as_ref().expect("root must get the sum");
                        assert_eq!(v.data(), &[expect, expect], "g={g} root={root}");
                    } else {
                        assert!(r.is_none(), "g={g} root={root} rank={rank}");
                    }
                }
            }
        }
    }

    #[test]
    fn reduce_leaves_no_stranded_messages() {
        // After a reduce, a barrier + fresh collective must see clean
        // mailboxes: run many reduces back-to-back on the same group and
        // verify each result (a stranded message would corrupt none — tags
        // differ — but this exercises the stash hygiene end to end).
        let g = 8usize;
        let out = run_spmd(g, NetModel::zero(), move |rank, ep| {
            let group: Vec<usize> = (0..g).collect();
            let mut results = Vec::new();
            for round in 0..20u32 {
                let t = Tensor::from_vec(&[1], vec![(rank as u32 * 100 + round) as f32]);
                if let Some(sum) = reduce(ep, &group, (round as usize) % g, &t) {
                    results.push((round, sum.data()[0]));
                }
            }
            results
        });
        for per_rank in out {
            for (round, got) in per_rank {
                let expect: f32 = (0..g).map(|r| (r as u32 * 100 + round) as f32).sum();
                assert_eq!(got, expect, "round {round}");
            }
        }
    }
}
