//! Collective communication over rank groups.
//!
//! These are *real* message-passing implementations — ring all-gather, ring
//! reduce-scatter, binomial-tree broadcast/reduce — not analytic stand-ins.
//! They run on the [`crate::comm`] transport, so every call both moves the
//! actual shard data (materialized mode) and advances the virtual clocks by
//! the α-β cost of exactly the hops the algorithm performs (both modes).
//!
//! Cost shapes (group size `g`, payload `n` bytes, uniform link):
//! * ring all-gather / reduce-scatter: `(g−1)·α + (g−1)/g · n_total/β`
//! * all-reduce (RS + AG):             `2·((g−1)·α + (g−1)/g · n/β)`
//! * binomial broadcast / reduce:      `⌈log₂ g⌉ · (α + n/β)`
//!
//! The paper's Algorithms 1–8 are built from these plus local matmuls.
//!
//! Every function takes the *ordered* group (as produced by
//! [`crate::topology`]) and requires `group[my_pos] == ep.rank()`. Groups of
//! size 1 are no-ops that return immediately — important because the 3-D
//! algorithms degenerate gracefully at `p = 1`.

use crate::comm::Endpoint;
use crate::tensor::Tensor;

fn my_pos_checked(ep: &Endpoint, group: &[usize]) -> usize {
    let pos = group
        .iter()
        .position(|&r| r == ep.rank())
        .unwrap_or_else(|| panic!("rank {} is not in group {:?}", ep.rank(), group));
    pos
}

/// Ring all-gather: every rank contributes `mine`; returns all `g`
/// contributions in group order (position `k` of the result came from
/// `group[k]`). Contributions may differ in shape across ranks.
pub fn all_gather(ep: &mut Endpoint, group: &[usize], mine: &Tensor) -> Vec<Tensor> {
    let g = group.len();
    let pos = my_pos_checked(ep, group);
    if g == 1 {
        return vec![mine.clone()];
    }
    let tag = ep.next_collective_tag(group);
    let next = group[(pos + 1) % g];
    let prev = group[(pos + g - 1) % g];
    let mut parts: Vec<Option<Tensor>> = vec![None; g];
    parts[pos] = Some(mine.clone());
    // At step s we forward the chunk that originated at (pos - s) mod g.
    // Each step's duration is floored at the ring's bottleneck link (the
    // pipelined-wavefront bound; see Endpoint::ring_worst_hop).
    let worst = ep.ring_worst_hop(group, mine.nominal_bytes());
    let mut outgoing = mine.clone();
    for s in 0..g - 1 {
        let start = ep.clock;
        ep.send(next, (s as u64) << 48 | tag, &outgoing);
        let incoming = ep.recv(prev, (s as u64) << 48 | tag);
        ep.apply_step_floor(start, worst);
        let origin = (pos + g - 1 - s) % g;
        parts[origin] = Some(incoming.clone());
        outgoing = incoming;
    }
    parts.into_iter().map(|p| p.unwrap()).collect()
}

/// Ring reduce-scatter: `contrib[k]` is this rank's addend destined for
/// `group[k]`; returns the fully reduced chunk owned by this rank
/// (`Σ_ranks contrib[my_pos]`). All ranks must pass shape-consistent chunks.
pub fn reduce_scatter(ep: &mut Endpoint, group: &[usize], contrib: Vec<Tensor>) -> Tensor {
    let g = group.len();
    assert_eq!(contrib.len(), g, "reduce_scatter needs one chunk per group member");
    let pos = my_pos_checked(ep, group);
    if g == 1 {
        return contrib.into_iter().next().unwrap();
    }
    let tag = ep.next_collective_tag(group);
    let next = group[(pos + 1) % g];
    let prev = group[(pos + g - 1) % g];
    let chunks = contrib;
    // Standard ring: at step s, send the partial for destination
    // (pos − s − 1) mod g to `next`; receive the partial for
    // (pos − s − 2) mod g from `prev` and fold in our own contribution.
    // After g−1 steps the chunk for `pos` is complete here (derivation:
    // the partial received at the final step has passed through every other
    // rank exactly once).
    let worst = ep.ring_worst_hop(group, chunks[0].nominal_bytes());
    let mut acc: Option<Tensor> = None;
    for s in 0..g - 1 {
        let send_dst = (pos + g - s - 1) % g; // destination index of outgoing partial
        let outgoing = if s == 0 {
            chunks[send_dst].clone()
        } else {
            acc.take().unwrap()
        };
        let start = ep.clock;
        ep.send(next, (s as u64) << 48 | tag, &outgoing);
        let incoming = ep.recv(prev, (s as u64) << 48 | tag);
        ep.apply_step_floor(start, worst);
        let dst = (pos + 2 * g - s - 2) % g;
        let mut folded = incoming;
        folded.add_assign(&chunks[dst]);
        // Charge the elementwise add (one pass over the chunk).
        ep.charge_memop(folded.nominal_bytes() as f64);
        acc = Some(folded);
    }
    acc.unwrap()
}

/// All-reduce = ring reduce-scatter + ring all-gather on row-chunks of the
/// flattened tensor (chunks padded up to a multiple of `g` elements).
pub fn all_reduce(ep: &mut Endpoint, group: &[usize], t: &Tensor) -> Tensor {
    let g = group.len();
    if g == 1 {
        return t.clone();
    }
    let n = t.numel();
    let chunk = n.div_ceil(g);
    let padded = chunk * g;
    // Split (with zero padding) into g flat chunks.
    let contrib: Vec<Tensor> = if let Some(d) = t.try_data() {
        (0..g)
            .map(|k| {
                let lo = k * chunk;
                let hi = ((k + 1) * chunk).min(n);
                let mut v = vec![0.0f32; chunk];
                if lo < n {
                    v[..hi - lo].copy_from_slice(&d[lo..hi]);
                }
                Tensor::from_vec(&[chunk], v)
            })
            .collect()
    } else {
        (0..g).map(|_| Tensor::phantom(&[chunk])).collect()
    };
    let mine = reduce_scatter(ep, group, contrib);
    let parts = all_gather(ep, group, &mine);
    if parts.iter().any(|p| p.is_phantom()) {
        return Tensor::phantom(t.shape());
    }
    let mut flat = Vec::with_capacity(padded);
    for p in &parts {
        flat.extend_from_slice(p.data());
    }
    flat.truncate(n);
    Tensor::from_vec(t.shape(), flat)
}

/// Binomial-tree broadcast from `group[root_pos]`. The root passes
/// `Some(tensor)`; everyone else passes `None` and gets the tensor back.
pub fn broadcast(
    ep: &mut Endpoint,
    group: &[usize],
    root_pos: usize,
    t: Option<Tensor>,
) -> Tensor {
    let g = group.len();
    let pos = my_pos_checked(ep, group);
    if g == 1 {
        return t.expect("root must supply the tensor");
    }
    let tag = ep.next_collective_tag(group);
    // Rotate so the root is virtual position 0.
    let vpos = (pos + g - root_pos) % g;
    let mut have: Option<Tensor> = if vpos == 0 {
        Some(t.expect("root must supply the tensor"))
    } else {
        assert!(t.is_none(), "non-root rank must pass None to broadcast");
        None
    };
    // Round r: ranks with vpos < 2^r that own the data send to vpos + 2^r.
    let mut span = 1usize;
    while span < g {
        if vpos < span {
            let peer = vpos + span;
            if peer < g {
                let dst = group[(peer + root_pos) % g];
                ep.send(dst, tag, have.as_ref().unwrap());
            }
        } else if vpos < 2 * span && have.is_none() {
            let peer = vpos - span;
            let src = group[(peer + root_pos) % g];
            have = Some(ep.recv(src, tag));
        }
        span *= 2;
    }
    have.unwrap()
}

/// Binomial-tree reduce to `group[root_pos]`: returns `Some(sum)` at the
/// root, `None` elsewhere.
pub fn reduce(
    ep: &mut Endpoint,
    group: &[usize],
    root_pos: usize,
    t: &Tensor,
) -> Option<Tensor> {
    let g = group.len();
    let pos = my_pos_checked(ep, group);
    if g == 1 {
        return Some(t.clone());
    }
    let tag = ep.next_collective_tag(group);
    let vpos = (pos + g - root_pos) % g;
    let mut acc = t.clone();
    // Bottom-up binomial tree: at round `step` the active ranks are the
    // multiples of `step`; those at odd multiples send their partial to
    // `vpos − step` (an even multiple, still active this round) and leave.
    // Nobody ever sends to a rank that has already left the collective —
    // the property that makes this safe against endpoint teardown races.
    let mut step = 1usize;
    while step < g {
        if vpos % (2 * step) == step {
            let peer = vpos - step;
            let dst = group[(peer + root_pos) % g];
            ep.send(dst, tag, &acc);
            return None; // partial handed up the tree; done
        }
        // vpos % (2*step) == 0: receive from vpos + step if it exists.
        let peer = vpos + step;
        if peer < g {
            let src = group[(peer + root_pos) % g];
            let incoming = ep.recv(src, tag);
            acc.add_assign(&incoming);
            ep.charge_memop(acc.nominal_bytes() as f64);
        }
        step *= 2;
    }
    Some(acc)
}

/// Bandwidth-optimal broadcast for large payloads of a shape every rank
/// already knows (SUMMA panels, bias chunks): scatter-then-all-gather, the
/// NCCL large-message algorithm. Cost ≈ `2·(g−1)/g · n/β` instead of the
/// binomial tree's `⌈log₂g⌉ · n/β`. The root's egress serialization during
/// the scatter phase is charged to its virtual clock.
pub fn broadcast_bw(
    ep: &mut Endpoint,
    group: &[usize],
    root_pos: usize,
    t: Option<Tensor>,
    shape: &[usize],
) -> Tensor {
    let g = group.len();
    let pos = my_pos_checked(ep, group);
    if g == 1 {
        return t.expect("root must supply the tensor");
    }
    let n: usize = shape.iter().product();
    let chunk = n.div_ceil(g);
    let tag = ep.next_collective_tag(group);
    // Scatter phase: root splits into g padded chunks and sends each member
    // its chunk (egress serialized on the root's clock).
    let mine = if pos == root_pos {
        let t = t.expect("root must supply the tensor");
        assert_eq!(t.shape(), shape, "broadcast_bw shape mismatch");
        let chunks: Vec<Tensor> = match t.try_data() {
            Some(d) => (0..g)
                .map(|k| {
                    let lo = k * chunk;
                    let hi = ((k + 1) * chunk).min(n);
                    let mut v = vec![0.0f32; chunk];
                    if lo < n {
                        v[..hi - lo].copy_from_slice(&d[lo..hi]);
                    }
                    Tensor::from_vec(&[chunk], v)
                })
                .collect(),
            None => (0..g).map(|_| Tensor::phantom(&[chunk])).collect(),
        };
        for (k, &dst) in group.iter().enumerate() {
            if k != root_pos {
                // Egress serialization: the k-th chunk leaves after k−1
                // previous ones.
                let cost = ep.net().hop_cost(ep.rank(), dst, chunk * 4)
                    - ep.net().hop_cost(ep.rank(), dst, 0);
                ep.clock += cost.max(0.0);
                ep.send(dst, tag, &chunks[k]);
            }
        }
        chunks[root_pos].clone()
    } else {
        assert!(t.is_none(), "non-root must pass None to broadcast_bw");
        ep.recv(group[root_pos], tag)
    };
    // All-gather phase reassembles the full payload everywhere.
    let parts = all_gather(ep, group, &mine);
    if parts.iter().any(|p| p.is_phantom()) {
        return Tensor::phantom(shape);
    }
    let mut flat = Vec::with_capacity(chunk * g);
    for p in &parts {
        flat.extend_from_slice(p.data());
    }
    flat.truncate(n);
    Tensor::from_vec(shape, flat)
}

/// Bandwidth-optimal reduce for large payloads: ring reduce-scatter then a
/// chunk gather to the root (cost ≈ `2·n/β` vs the tree's `log₂g·n/β`).
pub fn reduce_bw(
    ep: &mut Endpoint,
    group: &[usize],
    root_pos: usize,
    t: &Tensor,
) -> Option<Tensor> {
    let g = group.len();
    let pos = my_pos_checked(ep, group);
    if g == 1 {
        return Some(t.clone());
    }
    let n = t.numel();
    let chunk = n.div_ceil(g);
    let contrib: Vec<Tensor> = match t.try_data() {
        Some(d) => (0..g)
            .map(|k| {
                let lo = k * chunk;
                let hi = ((k + 1) * chunk).min(n);
                let mut v = vec![0.0f32; chunk];
                if lo < n {
                    v[..hi - lo].copy_from_slice(&d[lo..hi]);
                }
                Tensor::from_vec(&[chunk], v)
            })
            .collect(),
        None => (0..g).map(|_| Tensor::phantom(&[chunk])).collect(),
    };
    let mine = reduce_scatter(ep, group, contrib);
    let parts = gather(ep, group, root_pos, &mine)?;
    if parts.iter().any(|p| p.is_phantom()) {
        return Some(Tensor::phantom(t.shape()));
    }
    let mut flat = Vec::with_capacity(chunk * g);
    for p in &parts {
        flat.extend_from_slice(p.data());
    }
    flat.truncate(n);
    Some(Tensor::from_vec(t.shape(), flat))
}

/// Gather all contributions to `group[root_pos]` (returns `Some(parts)` in
/// group order at the root, `None` elsewhere). Linear algorithm — gather is
/// only used on control paths (global assembly for checkpoints/tests), never
/// in the training step.
pub fn gather(
    ep: &mut Endpoint,
    group: &[usize],
    root_pos: usize,
    mine: &Tensor,
) -> Option<Vec<Tensor>> {
    let g = group.len();
    let pos = my_pos_checked(ep, group);
    if g == 1 {
        return Some(vec![mine.clone()]);
    }
    let tag = ep.next_collective_tag(group);
    if pos == root_pos {
        let mut parts: Vec<Option<Tensor>> = vec![None; g];
        parts[pos] = Some(mine.clone());
        for (k, &src) in group.iter().enumerate() {
            if k != root_pos {
                parts[k] = Some(ep.recv(src, tag));
            }
        }
        Some(parts.into_iter().map(|p| p.unwrap()).collect())
    } else {
        ep.send(group[root_pos], tag, mine);
        None
    }
}

/// Scatter `parts` (present at the root only, group order) so member `k`
/// receives `parts[k]`. Control-path counterpart of `gather`.
pub fn scatter(
    ep: &mut Endpoint,
    group: &[usize],
    root_pos: usize,
    parts: Option<Vec<Tensor>>,
) -> Tensor {
    let g = group.len();
    let pos = my_pos_checked(ep, group);
    if g == 1 {
        return parts.expect("root must supply parts").into_iter().next().unwrap();
    }
    let tag = ep.next_collective_tag(group);
    if pos == root_pos {
        let parts = parts.expect("root must supply parts");
        assert_eq!(parts.len(), g);
        for (k, &dst) in group.iter().enumerate() {
            if k != root_pos {
                ep.send(dst, tag, &parts[k]);
            }
        }
        parts[root_pos].clone()
    } else {
        assert!(parts.is_none());
        ep.recv(group[root_pos], tag)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::NetModel;
    use crate::spmd::run_spmd;

    #[test]
    fn all_gather_collects_in_group_order() {
        let out = run_spmd(4, NetModel::zero(), |rank, ep| {
            let mine = Tensor::from_vec(&[1], vec![rank as f32]);
            let parts = all_gather(ep, &[0, 1, 2, 3], &mine);
            parts.iter().map(|p| p.data()[0]).collect::<Vec<_>>()
        });
        for r in out {
            assert_eq!(r, vec![0.0, 1.0, 2.0, 3.0]);
        }
    }

    #[test]
    fn all_gather_on_subgroup() {
        let out = run_spmd(4, NetModel::zero(), |rank, ep| {
            // Two disjoint groups {0,2} and {1,3} run concurrently.
            let group = if rank % 2 == 0 { vec![0, 2] } else { vec![1, 3] };
            let mine = Tensor::from_vec(&[1], vec![rank as f32 * 10.0]);
            let parts = all_gather(ep, &group, &mine);
            parts.iter().map(|p| p.data()[0]).collect::<Vec<_>>()
        });
        assert_eq!(out[0], vec![0.0, 20.0]);
        assert_eq!(out[2], vec![0.0, 20.0]);
        assert_eq!(out[1], vec![10.0, 30.0]);
        assert_eq!(out[3], vec![10.0, 30.0]);
    }

    #[test]
    fn reduce_scatter_sums_per_destination() {
        let out = run_spmd(3, NetModel::zero(), |rank, ep| {
            // contrib[k] = rank + k*100 — destination k should end with
            // sum_r (r + k*100) = 3 + 300k... wait: 0+1+2 = 3.
            let contrib = (0..3)
                .map(|k| Tensor::from_vec(&[2], vec![(rank + k * 100) as f32; 2]))
                .collect();
            let got = reduce_scatter(ep, &[0, 1, 2], contrib);
            got.data()[0]
        });
        assert_eq!(out[0], 3.0); // 0+1+2
        assert_eq!(out[1], 303.0); // 100*3 + 3
        assert_eq!(out[2], 603.0);
    }

    #[test]
    fn all_reduce_matches_local_sum() {
        for n in [1usize, 2, 3, 5] {
            let out = run_spmd(n, NetModel::zero(), move |rank, ep| {
                let group: Vec<usize> = (0..ep.world_size()).collect();
                // numel = 7, deliberately not divisible by most group sizes.
                let t = Tensor::from_vec(&[7], (0..7).map(|i| (rank * 7 + i) as f32).collect());
                all_reduce(ep, &group, &t)
            });
            let expected: Vec<f32> = (0..7)
                .map(|i| (0..n).map(|r| (r * 7 + i) as f32).sum())
                .collect();
            for r in &out {
                assert_eq!(r.data(), &expected[..], "world size {n}");
            }
        }
    }

    #[test]
    fn broadcast_from_each_root() {
        for root in 0..4 {
            let out = run_spmd(4, NetModel::zero(), move |rank, ep| {
                let t = (rank == root).then(|| Tensor::from_vec(&[3], vec![root as f32; 3]));
                broadcast(ep, &[0, 1, 2, 3], root, t)
            });
            for r in out {
                assert_eq!(r.data(), &[root as f32; 3]);
            }
        }
    }

    #[test]
    fn reduce_to_root_sums() {
        for root in 0..3 {
            let out = run_spmd(3, NetModel::zero(), move |rank, ep| {
                let t = Tensor::from_vec(&[2], vec![rank as f32 + 1.0; 2]);
                reduce(ep, &[0, 1, 2], root, &t)
            });
            for (rank, r) in out.iter().enumerate() {
                if rank == root {
                    assert_eq!(r.as_ref().unwrap().data(), &[6.0, 6.0]);
                } else {
                    assert!(r.is_none());
                }
            }
        }
    }

    #[test]
    fn gather_scatter_round_trip() {
        let out = run_spmd(3, NetModel::zero(), |rank, ep| {
            let mine = Tensor::from_vec(&[1], vec![rank as f32]);
            let gathered = gather(ep, &[0, 1, 2], 1, &mine);
            // Root re-scatters reversed.
            let parts = gathered.map(|mut g| {
                g.reverse();
                g
            });
            let back = scatter(ep, &[0, 1, 2], 1, parts);
            back.data()[0]
        });
        assert_eq!(out, vec![2.0, 1.0, 0.0]);
    }

    #[test]
    fn phantom_all_reduce_keeps_shape_and_charges_bytes() {
        let out = run_spmd(4, NetModel::flat(1e-6, 1e9, f64::INFINITY), |_, ep| {
            let group: Vec<usize> = (0..4).collect();
            let t = Tensor::phantom(&[256, 256]);
            let r = all_reduce(ep, &group, &t);
            (r.is_phantom(), r.shape().to_vec(), ep.clock, ep.stats.bytes_sent)
        });
        for (ph, shape, clock, bytes) in out {
            assert!(ph);
            assert_eq!(shape, vec![256, 256]);
            // Ring all-reduce sends 2*(g-1) chunks of n/g bytes each.
            let n = 256 * 256 * 4u64;
            assert_eq!(bytes, 2 * 3 * (n / 4));
            // Virtual clock advanced: 6 hops of (alpha + chunk/beta).
            let chunk = (n / 4) as f64;
            let expect = 6.0 * (1e-6 + chunk / 1e9);
            assert!((clock - expect).abs() < expect * 0.01, "clock {clock} vs {expect}");
        }
    }

    #[test]
    fn clocks_converge_after_all_reduce() {
        // Ranks start with wildly different clocks; after an all-reduce the
        // slowest participant dominates everyone (within one ring traversal).
        let out = run_spmd(4, NetModel::flat(1e-6, 1e12, f64::INFINITY), |rank, ep| {
            ep.clock = rank as f64; // rank 3 is 3 virtual seconds behind
            let t = Tensor::zeros(&[64]);
            let _ = all_reduce(ep, &(0..4).collect::<Vec<_>>(), &t);
            ep.clock
        });
        for c in out {
            assert!(c >= 3.0, "clock {c} should be dominated by slowest rank");
        }
    }

    #[test]
    fn singleton_groups_are_noops() {
        let out = run_spmd(1, NetModel::zero(), |_, ep| {
            let t = Tensor::from_vec(&[2], vec![1.0, 2.0]);
            let ag = all_gather(ep, &[0], &t);
            let rs = reduce_scatter(ep, &[0], vec![t.clone()]);
            let ar = all_reduce(ep, &[0], &t);
            let bc = broadcast(ep, &[0], 0, Some(t.clone()));
            (ag.len(), rs, ar, bc, ep.stats.messages_sent)
        });
        let (n, rs, ar, bc, sent) = &out[0];
        assert_eq!(*n, 1);
        assert_eq!(rs.data(), &[1.0, 2.0]);
        assert_eq!(ar.data(), &[1.0, 2.0]);
        assert_eq!(bc.data(), &[1.0, 2.0]);
        assert_eq!(*sent, 0);
    }
}

#[cfg(test)]
mod reduce_tree_tests {
    use super::*;
    use crate::comm::NetModel;
    use crate::spmd::run_spmd;

    #[test]
    fn reduce_correct_for_all_group_sizes_and_roots() {
        // The g >= 4 regression: the old top-down tree silently dropped
        // contributions from ranks like vpos 3 (g=4) and stranded their
        // messages at exited peers. Sweep sizes incl. non-powers-of-two.
        for g in 2..=9usize {
            for root in [0, g / 2, g - 1] {
                let out = run_spmd(g, NetModel::zero(), move |rank, ep| {
                    let group: Vec<usize> = (0..g).collect();
                    let t = Tensor::from_vec(&[2], vec![(rank + 1) as f32; 2]);
                    reduce(ep, &group, root, &t)
                });
                let expect = (g * (g + 1) / 2) as f32;
                for (rank, r) in out.iter().enumerate() {
                    if rank == root {
                        let v = r.as_ref().expect("root must get the sum");
                        assert_eq!(v.data(), &[expect, expect], "g={g} root={root}");
                    } else {
                        assert!(r.is_none(), "g={g} root={root} rank={rank}");
                    }
                }
            }
        }
    }

    #[test]
    fn reduce_leaves_no_stranded_messages() {
        // After a reduce, a barrier + fresh collective must see clean
        // mailboxes: run many reduces back-to-back on the same group and
        // verify each result (a stranded message would corrupt none — tags
        // differ — but this exercises the stash hygiene end to end).
        let g = 8usize;
        let out = run_spmd(g, NetModel::zero(), move |rank, ep| {
            let group: Vec<usize> = (0..g).collect();
            let mut results = Vec::new();
            for round in 0..20u32 {
                let t = Tensor::from_vec(&[1], vec![(rank as u32 * 100 + round) as f32]);
                if let Some(sum) = reduce(ep, &group, (round as usize) % g, &t) {
                    results.push((round, sum.data()[0]));
                }
            }
            results
        });
        for per_rank in out {
            for (round, got) in per_rank {
                let expect: f32 = (0..g).map(|r| (r as u32 * 100 + round) as f32).sum();
                assert_eq!(got, expect, "round {round}");
            }
        }
    }
}
