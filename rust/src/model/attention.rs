//! Rank-local multi-head causal attention.
//!
//! Thanks to the head-major QKV weight layout and sequence-aligned row
//! sharding (see `model/mod.rs`), every parallelism hands each rank complete
//! heads over complete sequences, so attention itself never communicates —
//! matching the paper's treatment of attention as "activation operations
//! [that] can be independently executed in parallel" (§3.1).
//!
//! Input: the local QKV shard `(rows, 3·hl·hd)` in head-major triple order;
//! `rows` is a multiple of `seq` and each `seq` row block is one sequence.
//! Output: `(rows, hl·hd)` head-major.

use crate::comm::Endpoint;
use crate::ops;
use crate::tensor::Tensor;

/// Saved state for backward: per (sequence, head) softmax probabilities,
/// plus the qkv input it was computed from.
pub struct AttnCache {
    pub qkv: Tensor,
    /// `probs[chunk * heads + head]`, each `(seq, seq)`.
    pub probs: Vec<Tensor>,
    pub heads: usize,
    pub head_dim: usize,
    pub seq: usize,
}

fn charge_mm(ep: &mut Endpoint, m: usize, n: usize, k: usize) {
    ep.charge_flops(2.0 * m as f64 * n as f64 * k as f64);
}

/// Per-layer KV cache for autoregressive decode: one append-only `(max_seq,
/// head_dim)` K and V tensor per (local slot, local head), plus a per-slot
/// fill length. This is the *only* state inference retains between tokens —
/// no probs, no qkv stash, no backward plumbing (`AttnCache` stays a
/// training-side type; see the serve-parity steady-state memory test).
///
/// Sharding falls out of the training layout: heads here are the rank's
/// *local* heads (already validated by `ShardSpec::head_divisor`) and slots
/// are the rank's local activation rows, so the cache is sharded exactly
/// like the QKV activation it is harvested from.
pub struct DecodeKv {
    /// `k[slot * heads + head]` and likewise `v`, each `(max_seq, head_dim)`.
    pub k: Vec<Tensor>,
    pub v: Vec<Tensor>,
    /// Tokens currently resident per local slot (`≤ max_seq`).
    pub len: Vec<usize>,
    pub slots: usize,
    pub heads: usize,
    pub head_dim: usize,
    pub max_seq: usize,
}

impl DecodeKv {
    /// Allocate an empty cache. `phantom` skips backing storage but keeps
    /// the same length bookkeeping, so phantom decode charges use real
    /// per-slot positions.
    pub fn new(slots: usize, heads: usize, head_dim: usize, max_seq: usize, phantom: bool) -> Self {
        let n = slots * heads;
        let mk = || {
            if phantom {
                Tensor::phantom(&[max_seq, head_dim])
            } else {
                Tensor::zeros(&[max_seq, head_dim])
            }
        };
        DecodeKv {
            k: (0..n).map(|_| mk()).collect(),
            v: (0..n).map(|_| mk()).collect(),
            len: vec![0; slots],
            slots,
            heads,
            head_dim,
            max_seq,
        }
    }

    /// Copy prefill K/V rows out of a forward QKV activation. `qkv` is the
    /// local head-major shard `(slots · pad, 3·heads·head_dim)`; slot `s`
    /// occupies rows `s·pad .. s·pad+pad` of which the first `lens[s]` are
    /// real prompt tokens (the rest is ragged-batch padding, never cached).
    pub fn harvest(&mut self, qkv: &Tensor, pad: usize, lens: &[usize]) {
        assert_eq!(lens.len(), self.slots);
        if qkv.is_phantom() {
            self.len.copy_from_slice(lens);
            return;
        }
        let hd = self.head_dim;
        for s in 0..self.slots {
            let l = lens[s];
            assert!(l >= 1 && l <= pad && l <= self.max_seq, "prompt len {l} out of range");
            for g in 0..self.heads {
                let base = g * 3 * hd;
                let idx = s * self.heads + g;
                self.k[idx].set_block(0, 0, &qkv.block(s * pad, base + hd, l, hd));
                self.v[idx].set_block(0, 0, &qkv.block(s * pad, base + 2 * hd, l, hd));
            }
            self.len[s] = l;
        }
    }

    /// Free a finished slot mid-flight: the rows stay allocated (steady
    /// state — no churn), the length resets so the next admitted sequence
    /// starts fresh at row 0.
    pub fn retire(&mut self, slot: usize) {
        self.len[slot] = 0;
    }

    /// Resident cache bytes on this rank for this layer (full `max_seq`
    /// extent — the allocation, not the fill). Pinned against
    /// `costmodel::kv_cache_bytes_for` in its tests.
    pub fn nominal_bytes(&self) -> u64 {
        2 * (self.slots * self.heads) as u64 * (self.max_seq * self.head_dim) as u64 * 4
    }
}

/// One decode step over the KV cache: `qkv` holds exactly one new token per
/// local slot (`(slots, 3·heads·head_dim)`, same head-major layout as
/// training). For each (slot, head) the new K/V row is appended *first* so
/// the query attends to itself, then scores span the `len+1` resident rows —
/// no causal mask needed, the cache prefix *is* the causal set. Bitwise
/// equal to the corresponding row of a full-sequence `fwd` (pinned in
/// tests/serve_parity.rs): same kernel, same score prefix, and the masked
/// tail of the full forward softmaxes to exact `0.0` contributions.
pub fn decode_fwd(
    ep: &mut Endpoint,
    qkv: &Tensor,
    heads: usize,
    head_dim: usize,
    kv: &mut DecodeKv,
) -> Tensor {
    let (rows, cols) = qkv.dims2();
    assert_eq!(rows, kv.slots, "decode rows {rows} != kv slots {}", kv.slots);
    assert_eq!(heads, kv.heads);
    assert_eq!(head_dim, kv.head_dim);
    if qkv.is_phantom() {
        // Same charges as the real loop below, from the real per-slot fill.
        for s in 0..rows {
            let l = (kv.len[s] + 1) as f64;
            let (h, hd) = (heads as f64, head_dim as f64);
            ep.charge_flops(2.0 * (2.0 * l * hd) * h);
            ep.charge_memop(3.0 * (4.0 * l) * h);
            kv.len[s] += 1;
        }
        return Tensor::phantom(&[rows, heads * head_dim]);
    }
    assert_eq!(cols, 3 * heads * head_dim, "qkv cols {cols} != 3·{heads}·{head_dim}");
    let scale = 1.0 / (head_dim as f32).sqrt();
    let mut out = Tensor::zeros(&[rows, heads * head_dim]);
    for s in 0..rows {
        let pos = kv.len[s];
        assert!(pos < kv.max_seq, "KV overflow: slot {s} at {pos} of {}", kv.max_seq);
        for g in 0..heads {
            let base = g * 3 * head_dim;
            let q = qkv.block(s, base, 1, head_dim);
            let idx = s * heads + g;
            kv.k[idx].set_block(pos, 0, &qkv.block(s, base + head_dim, 1, head_dim));
            kv.v[idx].set_block(pos, 0, &qkv.block(s, base + 2 * head_dim, 1, head_dim));
            // Views drop before the next append, so set_block stays in place.
            let kview = kv.k[idx].block(0, 0, pos + 1, head_dim);
            let vview = kv.v[idx].block(0, 0, pos + 1, head_dim);
            charge_mm(ep, 1, pos + 1, head_dim);
            let scores = q.matmul_nt(&kview).scale(scale);
            ep.charge_memop(3.0 * scores.nominal_bytes() as f64);
            let p = ops::softmax_rows(&scores);
            charge_mm(ep, 1, head_dim, pos + 1);
            let o = p.matmul(&vview);
            out.set_block(s, g * head_dim, &o);
        }
        kv.len[s] += 1;
    }
    out
}

/// Analytic cost of this rank's attention shard, charged in phantom mode.
/// Work is derived from the *shard width* (`qkv_cols/3` = local heads ×
/// head_dim, fractional heads allowed — the paper's own Table configs split
/// heads/sequences unevenly), so the charge is exact for any sharding:
/// score + context matmuls are `2·rows·seq·(cols/3)` flops each; the
/// mask/softmax pass is ~3 touches of the per-head `(rows, seq)` scores.
fn charge_phantom(ep: &mut Endpoint, rows: usize, qkv_cols: usize, hd: usize, seq: usize, backward: bool) {
    let heads_f = qkv_cols as f64 / (3.0 * hd as f64);
    let mm_flops = 2.0 * rows as f64 * seq as f64 * hd as f64 * heads_f;
    let score_bytes = 4.0 * rows as f64 * seq as f64 * heads_f;
    if backward {
        // dV, dP, dQ, dK: four matmuls of the same shape class.
        ep.charge_flops(4.0 * mm_flops);
        ep.charge_memop(6.0 * score_bytes);
    } else {
        ep.charge_flops(2.0 * mm_flops);
        ep.charge_memop(3.0 * score_bytes);
    }
}

/// Forward. `heads` is the number of *local* heads; `seq` the sequence
/// length; `head_dim` the per-head width.
pub fn fwd(
    ep: &mut Endpoint,
    qkv: &Tensor,
    heads: usize,
    head_dim: usize,
    seq: usize,
) -> (Tensor, AttnCache) {
    let (rows, cols) = qkv.dims2();
    if qkv.is_phantom() {
        // Timing-only path: charge the attention cost analytically. This
        // also covers paper-scale bench configs where a rank's row block is
        // a *fraction* of a sequence (the paper splits the sequence axis
        // too and leaves score distribution unspecified — see DESIGN.md);
        // the per-rank score work is (rows·seq) regardless of alignment.
        charge_phantom(ep, rows, cols, head_dim, seq, /*backward=*/ false);
        return (
            Tensor::phantom(&[rows, cols / 3]),
            AttnCache { qkv: qkv.clone(), probs: Vec::new(), heads, head_dim, seq },
        );
    }
    assert_eq!(cols, 3 * heads * head_dim, "qkv cols {cols} != 3·{heads}·{head_dim}");
    assert_eq!(rows % seq, 0, "rows {rows} not a multiple of seq {seq}");
    let chunks = rows / seq;
    let scale = 1.0 / (head_dim as f32).sqrt();
    let mut out = Tensor::zeros(&[rows, heads * head_dim]);
    let mut probs = Vec::with_capacity(chunks * heads);
    for c in 0..chunks {
        for g in 0..heads {
            let base = g * 3 * head_dim;
            let q = qkv.block(c * seq, base, seq, head_dim);
            let k = qkv.block(c * seq, base + head_dim, seq, head_dim);
            let v = qkv.block(c * seq, base + 2 * head_dim, seq, head_dim);
            charge_mm(ep, seq, seq, head_dim);
            let scores = q.matmul_nt(&k).scale(scale);
            let masked = ops::causal_mask(&scores, seq);
            ep.charge_memop(3.0 * masked.nominal_bytes() as f64);
            let p = ops::softmax_rows(&masked);
            charge_mm(ep, seq, head_dim, seq);
            let o = p.matmul(&v);
            out.set_block(c * seq, g * head_dim, &o);
            probs.push(p);
        }
    }
    (
        out,
        AttnCache { qkv: qkv.clone(), probs, heads, head_dim, seq },
    )
}

/// Backward: upstream `dout` is `(rows, hl·hd)`; returns `d_qkv` with the
/// same layout as the forward input.
pub fn bwd(ep: &mut Endpoint, dout: &Tensor, cache: &AttnCache) -> Tensor {
    let (rows, _) = dout.dims2();
    let (heads, hd, seq) = (cache.heads, cache.head_dim, cache.seq);
    if dout.is_phantom() || cache.qkv.is_phantom() {
        let qkv_cols = cache.qkv.dims2().1;
        charge_phantom(ep, rows, qkv_cols, hd, seq, /*backward=*/ true);
        return Tensor::phantom(cache.qkv.shape());
    }
    let chunks = rows / seq;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut dqkv = Tensor::zeros(cache.qkv.shape());
    for c in 0..chunks {
        for g in 0..heads {
            let base = g * 3 * hd;
            let q = cache.qkv.block(c * seq, base, seq, hd);
            let k = cache.qkv.block(c * seq, base + hd, seq, hd);
            let v = cache.qkv.block(c * seq, base + 2 * hd, seq, hd);
            let p = &cache.probs[c * heads + g];
            let doh = dout.block(c * seq, g * hd, seq, hd);
            // dV = Pᵀ · dO
            charge_mm(ep, seq, hd, seq);
            let dv = p.matmul_tn(&doh);
            // dP = dO · Vᵀ ; dS = softmax_bwd(dP) ⊙ mask ; scaled
            charge_mm(ep, seq, seq, hd);
            let dp = doh.matmul_nt(&v);
            ep.charge_memop(3.0 * dp.nominal_bytes() as f64);
            let ds = ops::causal_mask_backward(&ops::softmax_rows_backward(&dp, p), seq)
                .scale(scale);
            // dQ = dS · K ; dK = dSᵀ · Q
            charge_mm(ep, seq, hd, seq);
            let dq = ds.matmul(&k);
            charge_mm(ep, seq, hd, seq);
            let dk = ds.matmul_tn(&q);
            dqkv.set_block(c * seq, base, &dq);
            dqkv.set_block(c * seq, base + hd, &dk);
            dqkv.set_block(c * seq, base + 2 * hd, &dv);
        }
    }
    dqkv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::NetModel;
    use crate::rng::Xoshiro256;
    use crate::spmd::run_spmd;

    fn randt(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        Tensor::randn(shape, 1.0, &mut rng)
    }

    /// Dense single-head reference.
    fn ref_single_head(q: &Tensor, k: &Tensor, v: &Tensor) -> Tensor {
        let (s, d) = q.dims2();
        let scores = q.matmul_nt(k).scale(1.0 / (d as f32).sqrt());
        let masked = ops::causal_mask(&scores, s);
        ops::softmax_rows(&masked).matmul(v)
    }

    fn with_ep<T: Send + 'static>(f: impl Fn(&mut Endpoint) -> T + Send + Sync + 'static) -> T {
        run_spmd(1, NetModel::zero(), move |_, ep| f(ep)).pop().unwrap()
    }

    #[test]
    fn forward_matches_reference_per_head() {
        let (heads, hd, seq, chunks) = (3usize, 4usize, 8usize, 2usize);
        let qkv = randt(&[chunks * seq, 3 * heads * hd], 1);
        let out = with_ep(move |ep| fwd(ep, &qkv, heads, hd, seq).0);
        let qkv = randt(&[chunks * seq, 3 * heads * hd], 1);
        for c in 0..chunks {
            for g in 0..heads {
                let base = g * 3 * hd;
                let q = qkv.block(c * seq, base, seq, hd);
                let k = qkv.block(c * seq, base + hd, seq, hd);
                let v = qkv.block(c * seq, base + 2 * hd, seq, hd);
                let want = ref_single_head(&q, &k, &v);
                let got = out.block(c * seq, g * hd, seq, hd);
                assert!(got.max_abs_diff(&want) < 1e-5, "chunk {c} head {g}");
            }
        }
    }

    #[test]
    fn backward_matches_numeric_gradient() {
        let (heads, hd, seq) = (2usize, 3usize, 4usize);
        let qkv0 = randt(&[seq, 3 * heads * hd], 2);
        let dout0 = randt(&[seq, heads * hd], 3);

        let qkv = qkv0.clone();
        let dout = dout0.clone();
        let dqkv = with_ep(move |ep| {
            let (_, cache) = fwd(ep, &qkv, heads, hd, seq);
            bwd(ep, &dout, &cache)
        });

        let h = 1e-2f32;
        for idx in [0usize, 17, 40, qkv0.numel() - 1] {
            let mut xp = qkv0.clone();
            xp.data_mut()[idx] += h;
            let mut xm = qkv0.clone();
            xm.data_mut()[idx] -= h;
            let dout = dout0.clone();
            let fp = with_ep(move |ep| fwd(ep, &xp, heads, hd, seq).0);
            let fm = with_ep(move |ep| fwd(ep, &xm, heads, hd, seq).0);
            let num = fp.sub(&fm).scale(1.0 / (2.0 * h)).mul(&dout).sum();
            let ana = dqkv.data()[idx];
            assert!(
                (num - ana).abs() < 3e-2 * (1.0 + ana.abs()),
                "idx {idx}: numeric {num} vs analytic {ana}"
            );
        }
    }

    #[test]
    fn causality_holds_across_chunks() {
        // Changing the last token of chunk 0 must not affect chunk 1 at all,
        // nor earlier rows of chunk 0.
        let (heads, hd, seq) = (1usize, 4usize, 4usize);
        let qkv0 = randt(&[2 * seq, 3 * hd], 4);
        let mut qkv1 = qkv0.clone();
        for c in 0..3 * hd {
            let idx = (seq - 1) * 3 * hd + c;
            qkv1.data_mut()[idx] += 5.0;
        }
        let a = with_ep(move |ep| fwd(ep, &qkv0, heads, hd, seq).0);
        let b = with_ep(move |ep| fwd(ep, &qkv1, heads, hd, seq).0);
        // rows 0..seq-1 of chunk 0 unchanged
        assert!(a.block(0, 0, seq - 1, hd).max_abs_diff(&b.block(0, 0, seq - 1, hd)) < 1e-6);
        // chunk 1 untouched entirely
        assert!(a.block(seq, 0, seq, hd).max_abs_diff(&b.block(seq, 0, seq, hd)) < 1e-6);
        // last row of chunk 0 did change
        assert!(a.block(seq - 1, 0, 1, hd).max_abs_diff(&b.block(seq - 1, 0, 1, hd)) > 1e-3);
    }

    #[test]
    fn decode_rows_match_full_forward_rows_bitwise() {
        // Harvest a 3-token prompt from a full forward's QKV, then decode
        // tokens 3..seq one at a time feeding the same QKV rows; every
        // decoded row must equal the full forward's row *bitwise*.
        let (heads, hd, seq, prompt) = (2usize, 4usize, 8usize, 3usize);
        let qkv = randt(&[seq, 3 * heads * hd], 7);
        let (full, rows) = with_ep(move |ep| {
            let full = fwd(ep, &qkv, heads, hd, seq).0;
            let mut kv = DecodeKv::new(1, heads, hd, seq, false);
            kv.harvest(&qkv, seq, &[prompt]);
            let mut rows = Vec::new();
            for t in prompt..seq {
                let step = qkv.block(t, 0, 1, 3 * heads * hd);
                rows.push(decode_fwd(ep, &step, heads, hd, &mut kv));
            }
            (full, rows)
        });
        for (i, r) in rows.iter().enumerate() {
            let want = full.block(prompt + i, 0, 1, heads * hd);
            assert_eq!(r.data(), want.data(), "decode row {} differs", prompt + i);
        }
    }

    #[test]
    fn retired_slot_restarts_fresh() {
        let (heads, hd, seq) = (1usize, 4usize, 6usize);
        let qkv = randt(&[1, 3 * heads * hd], 8);
        let (a, b) = with_ep(move |ep| {
            let mut kv = DecodeKv::new(1, heads, hd, seq, false);
            let a = decode_fwd(ep, &qkv, heads, hd, &mut kv);
            decode_fwd(ep, &qkv, heads, hd, &mut kv);
            kv.retire(0);
            assert_eq!(kv.len[0], 0);
            let b = decode_fwd(ep, &qkv, heads, hd, &mut kv);
            (a, b)
        });
        assert_eq!(a.data(), b.data(), "slot reuse after retire is not fresh");
    }

    #[test]
    fn phantom_flows_and_charges() {
        let (heads, hd, seq) = (2usize, 4usize, 8usize);
        let (is_ph, clock) = run_spmd(1, NetModel::longhorn_v100(), move |_, ep| {
            let qkv = Tensor::phantom(&[seq, 3 * heads * hd]);
            let (o, cache) = fwd(ep, &qkv, heads, hd, seq);
            let d = bwd(ep, &Tensor::phantom(&[seq, heads * hd]), &cache);
            (o.is_phantom() && d.is_phantom(), ep.clock)
        })
        .pop()
        .unwrap();
        assert!(is_ph);
        assert!(clock > 0.0);
    }
}
