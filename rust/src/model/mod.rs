//! The Transformer model, parameterized by parallelism.
//!
//! One set of *global* parameters (deterministically initialized from a
//! seed) can be sharded onto any of the four execution modes — `Seq`
//! (dense single device), `1-D` (Megatron), `2-D` (Optimus/SUMMA) and the
//! paper's `3-D` — and every mode computes the *same function* to float
//! tolerance, which is what the cross-parallelism parity tests in
//! `rust/tests/` pin down.
//!
//! ## Weight conventions
//!
//! * `w_qkv` is `(h, 3h)` in **head-major triple** order: columns
//!   `[g·3·hd, (g+1)·3·hd)` hold `[Wq_g | Wk_g | Wv_g]` for head `g`. This
//!   makes any column-sharding of the QKV projection hand each rank a set
//!   of *complete heads*, so attention is always rank-local (the
//!   Colossal-AI trick; the paper is silent on attention-score
//!   distribution — see DESIGN.md).
//! * Activations are `(batch·seq, hidden)` row-major, batch-major rows
//!   (row = `b·seq + s`), so row-range shards hold complete sequences
//!   whenever `batch` divides by the row-chunk count (`config::validate`).
//!
//! ## Direction bookkeeping (3-D)
//!
//! Every block starts with the canonical direction triple `d0`; its two
//! linear layers per branch swap `d0 ↔ d1 = d0.swapped()` and swap back, so
//! blocks stack with a constant layout (§3.2 of the paper). The bias of a
//! linear layer lives on the diagonal of the *output* directions.

pub mod attention;
pub mod oned;
pub mod seq;
pub mod threed;
pub mod twod;

use crate::comm::Endpoint;
use crate::config::ModelConfig;
use crate::dist::{DiagVec3D, Dirs, Layout1D, Layout2D, Layout3D};
use crate::parallel::{oned::Ctx1D, threed::Ctx3D, twod::Ctx2D};
use crate::rng::Xoshiro256;
use crate::tensor::Tensor;
use crate::topology::{Cube, Mesh, Parallelism};

/// One transformer block's tensors — used both for parameters and for
/// gradients (same shapes, same ownership pattern). Matrix entries are
/// always present (every rank owns a shard); vector entries are `Some` only
/// on owning ranks (3-D: direction diagonal; 2-D: mesh row 0; 1-D/Seq: all).
#[derive(Clone, Debug)]
pub struct BlockTensors {
    pub ln1_g: Option<Tensor>,
    pub ln1_b: Option<Tensor>,
    pub w_qkv: Tensor,
    pub b_qkv: Option<Tensor>,
    pub w_proj: Tensor,
    pub b_proj: Option<Tensor>,
    pub ln2_g: Option<Tensor>,
    pub ln2_b: Option<Tensor>,
    pub w_fc1: Tensor,
    pub b_fc1: Option<Tensor>,
    pub w_fc2: Tensor,
    pub b_fc2: Option<Tensor>,
}

impl BlockTensors {
    /// Parameter/gradient pairs for the optimizer, in a stable order.
    pub fn pairs_mut<'a>(
        &'a mut self,
        g: &'a BlockTensors,
    ) -> Vec<(&'a mut Tensor, &'a Tensor)> {
        let mut out: Vec<(&mut Tensor, &Tensor)> = vec![
            (&mut self.w_qkv, &g.w_qkv),
            (&mut self.w_proj, &g.w_proj),
            (&mut self.w_fc1, &g.w_fc1),
            (&mut self.w_fc2, &g.w_fc2),
        ];
        let vecs: [(&mut Option<Tensor>, &Option<Tensor>); 8] = [
            (&mut self.ln1_g, &g.ln1_g),
            (&mut self.ln1_b, &g.ln1_b),
            (&mut self.b_qkv, &g.b_qkv),
            (&mut self.b_proj, &g.b_proj),
            (&mut self.ln2_g, &g.ln2_g),
            (&mut self.ln2_b, &g.ln2_b),
            (&mut self.b_fc1, &g.b_fc1),
            (&mut self.b_fc2, &g.b_fc2),
        ];
        for (p, gr) in vecs {
            match (p.as_mut(), gr.as_ref()) {
                (Some(p), Some(gr)) => out.push((p, gr)),
                (None, None) => {}
                _ => panic!("param/grad ownership mismatch"),
            }
        }
        out
    }

    /// Total elements this rank stores for the block (memory accounting).
    pub fn numel(&self) -> usize {
        let v = |t: &Option<Tensor>| t.as_ref().map_or(0, |t| t.numel());
        self.w_qkv.numel()
            + self.w_proj.numel()
            + self.w_fc1.numel()
            + self.w_fc2.numel()
            + v(&self.ln1_g)
            + v(&self.ln1_b)
            + v(&self.b_qkv)
            + v(&self.b_proj)
            + v(&self.ln2_g)
            + v(&self.ln2_b)
            + v(&self.b_fc1)
            + v(&self.b_fc2)
    }
}

/// Dense (global, unsharded) block parameters — the init source and the
/// test-time ground truth.
#[derive(Clone, Debug)]
pub struct DenseBlock {
    pub ln1_g: Tensor,
    pub ln1_b: Tensor,
    pub w_qkv: Tensor,
    pub b_qkv: Tensor,
    pub w_proj: Tensor,
    pub b_proj: Tensor,
    pub ln2_g: Tensor,
    pub ln2_b: Tensor,
    pub w_fc1: Tensor,
    pub b_fc1: Tensor,
    pub w_fc2: Tensor,
    pub b_fc2: Tensor,
}

impl DenseBlock {
    /// GPT-2-style init: N(0, 0.02) weights, residual-path projections
    /// scaled down by √(2·layers), unit γ, zero biases.
    pub fn init(cfg: &ModelConfig, rng: &mut Xoshiro256) -> DenseBlock {
        let h = cfg.hidden;
        let f = cfg.ffn;
        let std = 0.02f32;
        let res_std = std / ((2 * cfg.layers) as f32).sqrt();
        DenseBlock {
            ln1_g: Tensor::ones(&[h]),
            ln1_b: Tensor::zeros(&[h]),
            w_qkv: Tensor::randn(&[h, 3 * h], std, rng),
            b_qkv: Tensor::zeros(&[3 * h]),
            w_proj: Tensor::randn(&[h, h], res_std, rng),
            b_proj: Tensor::zeros(&[h]),
            ln2_g: Tensor::ones(&[h]),
            ln2_b: Tensor::zeros(&[h]),
            w_fc1: Tensor::randn(&[h, f], std, rng),
            b_fc1: Tensor::zeros(&[f]),
            w_fc2: Tensor::randn(&[f, h], res_std, rng),
            b_fc2: Tensor::zeros(&[h]),
        }
    }

    /// As `BlockTensors` with everything owned (the Seq sharding).
    pub fn to_seq(&self) -> BlockTensors {
        BlockTensors {
            ln1_g: Some(self.ln1_g.clone()),
            ln1_b: Some(self.ln1_b.clone()),
            w_qkv: self.w_qkv.clone(),
            b_qkv: Some(self.b_qkv.clone()),
            w_proj: self.w_proj.clone(),
            b_proj: Some(self.b_proj.clone()),
            ln2_g: Some(self.ln2_g.clone()),
            ln2_b: Some(self.ln2_b.clone()),
            w_fc1: self.w_fc1.clone(),
            b_fc1: Some(self.b_fc1.clone()),
            w_fc2: self.w_fc2.clone(),
            b_fc2: Some(self.b_fc2.clone()),
        }
    }

    /// 1-D Megatron sharding for `rank` of `world`.
    pub fn to_oned(&self, world: usize, rank: usize) -> BlockTensors {
        let col = Layout1D::ColShard;
        let row = Layout1D::RowShard;
        let vec_shard = |v: &Tensor| {
            let n = v.numel();
            col.shard_of(world, rank, &v.reshape(&[1, n]))
                .into_reshape(&[n / world])
        };
        BlockTensors {
            ln1_g: Some(self.ln1_g.clone()),
            ln1_b: Some(self.ln1_b.clone()),
            w_qkv: col.shard_of(world, rank, &self.w_qkv),
            b_qkv: Some(vec_shard(&self.b_qkv)),
            w_proj: row.shard_of(world, rank, &self.w_proj),
            b_proj: Some(self.b_proj.clone()),
            ln2_g: Some(self.ln2_g.clone()),
            ln2_b: Some(self.ln2_b.clone()),
            w_fc1: col.shard_of(world, rank, &self.w_fc1),
            b_fc1: Some(vec_shard(&self.b_fc1)),
            w_fc2: row.shard_of(world, rank, &self.w_fc2),
            b_fc2: Some(self.b_fc2.clone()),
        }
    }

    /// 2-D SUMMA sharding: matrices in `(·/q, ·/q)` blocks, vectors as
    /// column chunks on mesh row 0.
    pub fn to_twod(&self, mesh: &Mesh, rank: usize) -> BlockTensors {
        let (row, col) = mesh.coord_of(rank);
        let q = mesh.edge();
        let vec_chunk = |v: &Tensor| -> Option<Tensor> {
            (row == 0).then(|| {
                let n = v.numel();
                v.reshape(&[1, n])
                    .block(0, col * (n / q), 1, n / q)
                    .into_reshape(&[n / q])
            })
        };
        BlockTensors {
            ln1_g: vec_chunk(&self.ln1_g),
            ln1_b: vec_chunk(&self.ln1_b),
            w_qkv: Layout2D::shard_of(mesh, rank, &self.w_qkv),
            b_qkv: vec_chunk(&self.b_qkv),
            w_proj: Layout2D::shard_of(mesh, rank, &self.w_proj),
            b_proj: vec_chunk(&self.b_proj),
            ln2_g: vec_chunk(&self.ln2_g),
            ln2_b: vec_chunk(&self.ln2_b),
            w_fc1: Layout2D::shard_of(mesh, rank, &self.w_fc1),
            b_fc1: vec_chunk(&self.b_fc1),
            w_fc2: Layout2D::shard_of(mesh, rank, &self.w_fc2),
            b_fc2: vec_chunk(&self.b_fc2),
        }
    }

    /// 3-D sharding under block-entry directions `d0` (paper §3.1.1/Fig. 5):
    /// weights in `Layout3D::weight` of their layer's directions, vectors on
    /// the diagonal of their layer's *output* directions.
    pub fn to_threed(&self, cube: &Cube, rank: usize, d0: Dirs) -> BlockTensors {
        let d1 = d0.swapped();
        let coord = cube.coord_of(rank);
        let wl0 = Layout3D::weight(d0);
        let wl1 = Layout3D::weight(d1);
        let diag0 = DiagVec3D::for_dirs(d0);
        let diag1 = DiagVec3D::for_dirs(d1);
        BlockTensors {
            ln1_g: diag0.shard_of(cube, coord, &self.ln1_g),
            ln1_b: diag0.shard_of(cube, coord, &self.ln1_b),
            w_qkv: wl0.shard_of(cube, coord, &self.w_qkv),
            b_qkv: diag1.shard_of(cube, coord, &self.b_qkv),
            w_proj: wl1.shard_of(cube, coord, &self.w_proj),
            b_proj: diag0.shard_of(cube, coord, &self.b_proj),
            ln2_g: diag0.shard_of(cube, coord, &self.ln2_g),
            ln2_b: diag0.shard_of(cube, coord, &self.ln2_b),
            w_fc1: wl0.shard_of(cube, coord, &self.w_fc1),
            b_fc1: diag1.shard_of(cube, coord, &self.b_fc1),
            w_fc2: wl1.shard_of(cube, coord, &self.w_fc2),
            b_fc2: diag0.shard_of(cube, coord, &self.b_fc2),
        }
    }
}

/// Deterministic global parameters for the whole core (all blocks).
pub fn init_dense_blocks(cfg: &ModelConfig, seed: u64) -> Vec<DenseBlock> {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    (0..cfg.layers).map(|_| DenseBlock::init(cfg, &mut rng)).collect()
}

/// Per-rank execution environment: which parallelism, with its topology
/// context. The 3-D variant carries the block-entry directions.
pub enum ParEnv {
    Seq,
    OneD(Ctx1D),
    TwoD(Ctx2D),
    ThreeD(Ctx3D, Dirs),
}

impl ParEnv {
    pub fn new(par: Parallelism, edge: usize, rank: usize) -> ParEnv {
        match par {
            Parallelism::Seq => ParEnv::Seq,
            Parallelism::OneD => ParEnv::OneD(Ctx1D::new(edge, rank)),
            Parallelism::TwoD => ParEnv::TwoD(Ctx2D::new(Mesh::new(edge), rank)),
            Parallelism::ThreeD => {
                ParEnv::ThreeD(Ctx3D::new(Cube::new(edge), rank), Dirs::canonical())
            }
        }
    }

    pub fn kind(&self) -> Parallelism {
        match self {
            ParEnv::Seq => Parallelism::Seq,
            ParEnv::OneD(_) => Parallelism::OneD,
            ParEnv::TwoD(_) => Parallelism::TwoD,
            ParEnv::ThreeD(..) => Parallelism::ThreeD,
        }
    }

    /// Shard the global dense blocks for this rank.
    pub fn shard_blocks(&self, dense: &[DenseBlock], rank: usize) -> Vec<BlockTensors> {
        dense
            .iter()
            .map(|b| match self {
                ParEnv::Seq => b.to_seq(),
                ParEnv::OneD(ctx) => b.to_oned(ctx.world(), rank),
                ParEnv::TwoD(ctx) => b.to_twod(&ctx.mesh, rank),
                ParEnv::ThreeD(ctx, d0) => b.to_threed(&ctx.cube, rank, *d0),
            })
            .collect()
    }

    /// This rank's shard of a global `(rows, hidden)` activation.
    pub fn scatter_activation(&self, global: &Tensor, rank: usize) -> Tensor {
        match self {
            ParEnv::Seq | ParEnv::OneD(_) => global.clone(),
            ParEnv::TwoD(ctx) => Layout2D::shard_of(&ctx.mesh, rank, global),
            ParEnv::ThreeD(ctx, d0) => {
                Layout3D::input(*d0).shard_of(&ctx.cube, ctx.cube.coord_of(rank), global)
            }
        }
    }

    /// Reassemble the global activation on every rank (one all-gather over
    /// the world; only used at the model boundary — embedding/head — which
    /// the paper excludes from the parallelized region).
    pub fn gather_activation(
        &self,
        ep: &mut Endpoint,
        local: &Tensor,
        rows: usize,
        cols: usize,
    ) -> Tensor {
        match self {
            ParEnv::Seq | ParEnv::OneD(_) => local.clone(),
            ParEnv::TwoD(ctx) => {
                let world: Vec<usize> = (0..ctx.mesh.size()).collect();
                let parts = crate::collectives::all_gather(ep, &world, local);
                Layout2D::gather(&ctx.mesh, &parts, rows, cols)
            }
            ParEnv::ThreeD(ctx, d0) => {
                let world: Vec<usize> = (0..ctx.cube.size()).collect();
                let parts = crate::collectives::all_gather(ep, &world, local);
                Layout3D::input(*d0).gather(&ctx.cube, &parts, rows, cols)
            }
        }
    }

    /// Number of attention heads this rank computes locally.
    pub fn local_heads(&self, cfg: &ModelConfig) -> usize {
        match self {
            ParEnv::Seq => cfg.heads,
            ParEnv::OneD(ctx) => cfg.heads / ctx.world(),
            ParEnv::TwoD(ctx) => cfg.heads / ctx.q(),
            ParEnv::ThreeD(ctx, _) => cfg.heads / ctx.p(),
        }
    }
}

/// Shape-only (phantom) block parameters for this rank — the timing path
/// used by the benchmark harness at paper scale, where materializing
/// hidden-8192 weights would be pointless. Shapes and vector ownership are
/// identical to the materialized sharding.
pub fn phantom_block(env: &ParEnv, cfg: &ModelConfig, rank: usize) -> BlockTensors {
    let h = cfg.hidden;
    let f = cfg.ffn;
    // (w_qkv, b_qkv, w_proj, b_proj, w_fc1, b_fc1, w_fc2, b_fc2, ln owner?)
    match env {
        ParEnv::Seq => BlockTensors {
            ln1_g: Some(Tensor::phantom(&[h])),
            ln1_b: Some(Tensor::phantom(&[h])),
            w_qkv: Tensor::phantom(&[h, 3 * h]),
            b_qkv: Some(Tensor::phantom(&[3 * h])),
            w_proj: Tensor::phantom(&[h, h]),
            b_proj: Some(Tensor::phantom(&[h])),
            ln2_g: Some(Tensor::phantom(&[h])),
            ln2_b: Some(Tensor::phantom(&[h])),
            w_fc1: Tensor::phantom(&[h, f]),
            b_fc1: Some(Tensor::phantom(&[f])),
            w_fc2: Tensor::phantom(&[f, h]),
            b_fc2: Some(Tensor::phantom(&[h])),
        },
        ParEnv::OneD(ctx) => {
            let w = ctx.world();
            BlockTensors {
                ln1_g: Some(Tensor::phantom(&[h])),
                ln1_b: Some(Tensor::phantom(&[h])),
                w_qkv: Tensor::phantom(&[h, 3 * h / w]),
                b_qkv: Some(Tensor::phantom(&[3 * h / w])),
                w_proj: Tensor::phantom(&[h / w, h]),
                b_proj: Some(Tensor::phantom(&[h])),
                ln2_g: Some(Tensor::phantom(&[h])),
                ln2_b: Some(Tensor::phantom(&[h])),
                w_fc1: Tensor::phantom(&[h, f / w]),
                b_fc1: Some(Tensor::phantom(&[f / w])),
                w_fc2: Tensor::phantom(&[f / w, h]),
                b_fc2: Some(Tensor::phantom(&[h])),
            }
        }
        ParEnv::TwoD(ctx) => {
            let q = ctx.q();
            let own = ctx.row == 0;
            let vec = |n: usize| own.then(|| Tensor::phantom(&[n / q]));
            BlockTensors {
                ln1_g: vec(h),
                ln1_b: vec(h),
                w_qkv: Tensor::phantom(&[h / q, 3 * h / q]),
                b_qkv: vec(3 * h),
                w_proj: Tensor::phantom(&[h / q, h / q]),
                b_proj: vec(h),
                ln2_g: vec(h),
                ln2_b: vec(h),
                w_fc1: Tensor::phantom(&[h / q, f / q]),
                b_fc1: vec(3 * h).map(|_| Tensor::phantom(&[f / q])),
                w_fc2: Tensor::phantom(&[f / q, h / q]),
                b_fc2: vec(h),
            }
        }
        ParEnv::ThreeD(ctx, d0) => {
            let p = ctx.p();
            let d1 = d0.swapped();
            let coord = ctx.cube.coord_of(rank);
            let diag0 = DiagVec3D::for_dirs(*d0);
            let diag1 = DiagVec3D::for_dirs(d1);
            let vec = |diag: &DiagVec3D, n: usize| {
                diag.owns(coord).then(|| Tensor::phantom(&[n / (p * p)]))
            };
            let wshape = |dirs: Dirs, rows: usize, cols: usize| {
                let (r, c) = Layout3D::weight(dirs).shard_shape(p, rows, cols);
                Tensor::phantom(&[r, c])
            };
            BlockTensors {
                ln1_g: vec(&diag0, h),
                ln1_b: vec(&diag0, h),
                w_qkv: wshape(*d0, h, 3 * h),
                b_qkv: vec(&diag1, 3 * h),
                w_proj: wshape(d1, h, h),
                b_proj: vec(&diag0, h),
                ln2_g: vec(&diag0, h),
                ln2_b: vec(&diag0, h),
                w_fc1: wshape(*d0, h, f),
                b_fc1: vec(&diag1, f),
                w_fc2: wshape(d1, f, h),
                b_fc2: vec(&diag0, h),
            }
        }
    }
}

/// Shape of this rank's activation shard for a global `(rows, hidden)`.
pub fn local_activation_shape(env: &ParEnv, rows: usize, hidden: usize) -> (usize, usize) {
    match env {
        ParEnv::Seq | ParEnv::OneD(_) => (rows, hidden),
        ParEnv::TwoD(ctx) => (rows / ctx.q(), hidden / ctx.q()),
        ParEnv::ThreeD(ctx, _) => {
            let p = ctx.p();
            (rows / (p * p), hidden / p)
        }
    }
}

/// Per-block forward cache (local shards only).
pub struct BlockCache {
    pub x: Tensor,
    pub xhat1: Tensor,
    pub istd1: Tensor,
    pub ln1: Tensor,
    pub attn: attention::AttnCache,
    pub attn_out: Tensor,
    pub xa: Tensor,
    pub xhat2: Tensor,
    pub istd2: Tensor,
    pub ln2: Tensor,
    pub fc1_pre: Tensor,
    pub fc1_act: Tensor,
}

/// Dispatch: one transformer block forward on this rank's shard.
pub fn block_fwd(
    ep: &mut Endpoint,
    env: &ParEnv,
    p: &BlockTensors,
    x: &Tensor,
    cfg: &ModelConfig,
) -> (Tensor, BlockCache) {
    match env {
        ParEnv::Seq => seq::block_fwd(ep, p, x, cfg),
        ParEnv::OneD(ctx) => oned::block_fwd(ep, ctx, p, x, cfg),
        ParEnv::TwoD(ctx) => twod::block_fwd(ep, ctx, p, x, cfg),
        ParEnv::ThreeD(ctx, d0) => threed::block_fwd(ep, ctx, p, x, cfg, *d0),
    }
}

/// Dispatch: block backward; returns `(dx, grads)`.
pub fn block_bwd(
    ep: &mut Endpoint,
    env: &ParEnv,
    p: &BlockTensors,
    cache: &BlockCache,
    dy: &Tensor,
    cfg: &ModelConfig,
) -> (Tensor, BlockTensors) {
    match env {
        ParEnv::Seq => seq::block_bwd(ep, p, cache, dy, cfg),
        ParEnv::OneD(ctx) => oned::block_bwd(ep, ctx, p, cache, dy, cfg),
        ParEnv::TwoD(ctx) => twod::block_bwd(ep, ctx, p, cache, dy, cfg),
        ParEnv::ThreeD(ctx, d0) => threed::block_bwd(ep, ctx, p, cache, dy, cfg, *d0),
    }
}

/// Full core forward: all blocks in sequence.
pub fn core_fwd(
    ep: &mut Endpoint,
    env: &ParEnv,
    blocks: &[BlockTensors],
    x: &Tensor,
    cfg: &ModelConfig,
) -> (Tensor, Vec<BlockCache>) {
    let mut cur = x.clone();
    let mut caches = Vec::with_capacity(blocks.len());
    for p in blocks {
        let (y, cache) = block_fwd(ep, env, p, &cur, cfg);
        caches.push(cache);
        cur = y;
    }
    (cur, caches)
}

/// Full core backward: returns `(dx, per-block grads)`.
pub fn core_bwd(
    ep: &mut Endpoint,
    env: &ParEnv,
    blocks: &[BlockTensors],
    caches: &[BlockCache],
    dy: &Tensor,
    cfg: &ModelConfig,
) -> (Tensor, Vec<BlockTensors>) {
    assert_eq!(blocks.len(), caches.len());
    let mut grads = Vec::with_capacity(blocks.len());
    let mut cur = dy.clone();
    for (p, cache) in blocks.iter().zip(caches.iter()).rev() {
        let (dx, g) = block_bwd(ep, env, p, cache, &cur, cfg);
        grads.push(g);
        cur = dx;
    }
    grads.reverse();
    (cur, grads)
}

/// Local layernorm forward used by the Seq/1-D paths (rows fully local).
/// Returns `(y, xhat, inv_std)`.
pub fn local_layernorm(
    x: &Tensor,
    gamma: &Tensor,
    beta: &Tensor,
    eps: f32,
) -> (Tensor, Tensor, Tensor) {
    let (rows, cols) = x.dims2();
    if x.is_phantom() {
        return (
            Tensor::phantom(x.shape()),
            Tensor::phantom(x.shape()),
            Tensor::phantom(&[rows]),
        );
    }
    let mut xh = x.clone();
    let mut istd = vec![0.0f32; rows];
    {
        let xd = xh.data_mut();
        for r in 0..rows {
            let row = &mut xd[r * cols..(r + 1) * cols];
            let mean = row.iter().sum::<f32>() / cols as f32;
            let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / cols as f32;
            let inv = 1.0 / (var + eps).sqrt();
            istd[r] = inv;
            for v in row.iter_mut() {
                *v = (*v - mean) * inv;
            }
        }
    }
    let y = xh.mul_row_vector(gamma).add_row_vector(beta);
    (y, xh, Tensor::from_vec(&[rows], istd))
}

/// Local layernorm backward: `(dx, dγ, dβ)`.
pub fn local_layernorm_backward(
    dy: &Tensor,
    xhat: &Tensor,
    inv_std: &Tensor,
    gamma: &Tensor,
) -> (Tensor, Tensor, Tensor) {
    let (rows, cols) = dy.dims2();
    if dy.is_phantom() || xhat.is_phantom() {
        return (
            Tensor::phantom(dy.shape()),
            Tensor::phantom(gamma.shape()),
            Tensor::phantom(gamma.shape()),
        );
    }
    let dgamma = dy.mul(xhat).sum_rows();
    let dbeta = dy.sum_rows();
    let g = dy.mul_row_vector(gamma);
    let gd = g.data();
    let xd = xhat.data();
    let istd = inv_std.data();
    let n = cols as f32;
    let mut out = vec![0.0f32; rows * cols];
    for r in 0..rows {
        let mut sum_g = 0.0f32;
        let mut sum_gx = 0.0f32;
        for c in 0..cols {
            let idx = r * cols + c;
            sum_g += gd[idx];
            sum_gx += gd[idx] * xd[idx];
        }
        let c0 = istd[r] / n;
        for c in 0..cols {
            let idx = r * cols + c;
            out[idx] = c0 * (n * gd[idx] - sum_g - xd[idx] * sum_gx);
        }
    }
    (Tensor::from_vec(dy.shape(), out), dgamma, dbeta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Axis;

    fn cfg() -> ModelConfig {
        ModelConfig::tiny()
    }

    #[test]
    fn dense_init_is_deterministic() {
        let a = init_dense_blocks(&cfg(), 7);
        let b = init_dense_blocks(&cfg(), 7);
        assert_eq!(a.len(), 2);
        assert!(a[0].w_qkv.max_abs_diff(&b[0].w_qkv) == 0.0);
        assert!(a[1].w_fc2.max_abs_diff(&b[1].w_fc2) == 0.0);
        let c = init_dense_blocks(&cfg(), 8);
        assert!(a[0].w_qkv.max_abs_diff(&c[0].w_qkv) > 0.0);
    }

    #[test]
    fn sharding_partitions_weights_exactly_3d() {
        let cfg = cfg();
        let dense = DenseBlock::init(&cfg, &mut Xoshiro256::seed_from_u64(1));
        let cube = Cube::new(2);
        let d0 = Dirs::canonical();
        let mut total_w_qkv = 0;
        let mut vec_owners = 0;
        for r in 0..8 {
            let s = dense.to_threed(&cube, r, d0);
            total_w_qkv += s.w_qkv.numel();
            if s.b_qkv.is_some() {
                vec_owners += 1;
            }
            // Perfect balance: every rank stores exactly 1/P of each matrix.
            assert_eq!(s.w_qkv.numel(), cfg.hidden * 3 * cfg.hidden / 8);
        }
        assert_eq!(total_w_qkv, cfg.hidden * 3 * cfg.hidden);
        assert_eq!(vec_owners, 4); // p² diagonal owners
    }

    #[test]
    fn threed_gather_back_reconstructs_dense() {
        let cfg = cfg();
        let dense = DenseBlock::init(&cfg, &mut Xoshiro256::seed_from_u64(2));
        let cube = Cube::new(2);
        let d0 = Dirs::canonical();
        let shards: Vec<BlockTensors> =
            (0..8).map(|r| dense.to_threed(&cube, r, d0)).collect();
        let w_shards: Vec<Tensor> = shards.iter().map(|s| s.w_qkv.clone()).collect();
        let w = Layout3D::weight(d0).gather(&cube, &w_shards, cfg.hidden, 3 * cfg.hidden);
        assert_eq!(w, dense.w_qkv);
        // fc2 uses the swapped directions.
        let w2_shards: Vec<Tensor> = shards.iter().map(|s| s.w_fc2.clone()).collect();
        let w2 = Layout3D::weight(d0.swapped()).gather(&cube, &w2_shards, cfg.ffn, cfg.hidden);
        assert_eq!(w2, dense.w_fc2);
    }

    #[test]
    fn pairs_mut_yields_all_owned_params() {
        let cfg = cfg();
        let dense = DenseBlock::init(&cfg, &mut Xoshiro256::seed_from_u64(3));
        let mut p = dense.to_seq();
        let g = dense.to_seq();
        assert_eq!(p.pairs_mut(&g).len(), 12);
        let cube = Cube::new(2);
        let mut p3 = dense.to_threed(&cube, 0, Dirs::canonical());
        let g3 = dense.to_threed(&cube, 0, Dirs::canonical());
        // rank 0 = coord (0,0,0): on every diagonal → owns all 8 vectors.
        assert_eq!(p3.pairs_mut(&g3).len(), 12);
        let mut p3b = dense.to_threed(&cube, 1, Dirs::canonical());
        let g3b = dense.to_threed(&cube, 1, Dirs::canonical());
        // rank 1 = coord (0,0,1): j≠l and l≠j diagonals differ per dirs.
        assert!(p3b.pairs_mut(&g3b).len() < 12);
    }

    #[test]
    fn local_layernorm_normalizes_and_backward_checks() {
        let mut rng = Xoshiro256::seed_from_u64(4);
        let x = Tensor::randn(&[6, 16], 1.0, &mut rng);
        let gamma = Tensor::ones(&[16]);
        let beta = Tensor::zeros(&[16]);
        let (y, xhat, istd) = local_layernorm(&x, &gamma, &beta, 1e-5);
        for r in 0..6 {
            let mean: f32 = (0..16).map(|c| y.at2(r, c)).sum::<f32>() / 16.0;
            assert!(mean.abs() < 1e-5);
        }
        // Finite-difference dx check.
        let dy = Tensor::randn(&[6, 16], 1.0, &mut rng);
        let (dx, _, _) = local_layernorm_backward(&dy, &xhat, &istd, &gamma);
        let h = 1e-2f32;
        for idx in [0usize, 40, 95] {
            let mut xp = x.clone();
            xp.data_mut()[idx] += h;
            let mut xm = x.clone();
            xm.data_mut()[idx] -= h;
            let fp = local_layernorm(&xp, &gamma, &beta, 1e-5).0;
            let fm = local_layernorm(&xm, &gamma, &beta, 1e-5).0;
            let num = fp.sub(&fm).scale(1.0 / (2.0 * h)).mul(&dy).sum();
            let ana = dx.data()[idx];
            assert!((num - ana).abs() < 2e-2 * (1.0 + ana.abs()), "{num} vs {ana}");
        }
    }

    #[test]
    fn par_env_constructors() {
        let e = ParEnv::new(Parallelism::ThreeD, 2, 5);
        assert_eq!(e.kind(), Parallelism::ThreeD);
        assert_eq!(e.local_heads(&cfg()), 2);
        if let ParEnv::ThreeD(ctx, d0) = e {
            assert_eq!(ctx.coord, Cube::new(2).coord_of(5));
            assert_eq!(d0.a, Axis::Y);
        } else {
            panic!()
        }
        assert_eq!(ParEnv::new(Parallelism::OneD, 4, 1).local_heads(&cfg()), 1);
    }
}
