//! The Transformer model, parameterized by parallelism — *once*.
//!
//! One set of *global* parameters (deterministically initialized from a
//! seed) can be sharded onto any of the seven execution modes — `Seq`
//! (dense single device), `1-D` (Megatron), `2-D` (Optimus/SUMMA), the
//! paper's `3-D`, the Tesseract-style `2.5-D`, the hybrid data×tensor
//! mesh, and the pipeline wrapper — and every mode computes the *same
//! function* to float tolerance, which is what the cross-parallelism
//! parity tests in `rust/tests/` pin down.
//!
//! Since the `ParallelOps` redesign there is exactly **one** transformer
//! block ([`block::block_fwd`] / [`block::block_bwd`]), written against
//! [`crate::parallel::ParallelOps`]; the per-parallelism differences live
//! entirely in the trait implementations and in the layout algebra
//! ([`crate::dist::ShardSpec`]). [`ParEnv`] is the thin boxed dispatcher
//! that selects an implementation per rank, and [`DenseBlock::shard`] cuts
//! the global parameters for any spec — there are no per-dimension model
//! files or `to_*` converter families anymore.
//!
//! ## Weight conventions
//!
//! * `w_qkv` is `(h, 3h)` in **head-major triple** order: columns
//!   `[g·3·hd, (g+1)·3·hd)` hold `[Wq_g | Wk_g | Wv_g]` for head `g`. This
//!   makes any column-sharding of the QKV projection hand each rank a set
//!   of *complete heads*, so attention is always rank-local (the
//!   Colossal-AI trick; the paper is silent on attention-score
//!   distribution — see DESIGN.md).
//! * Activations are `(batch·seq, hidden)` row-major, batch-major rows
//!   (row = `b·seq + s`), so row-range shards hold complete sequences
//!   whenever `batch` divides by the row-chunk count (`config::validate`).
//!
//! ## Direction bookkeeping (3-D)
//!
//! Every block starts with the canonical direction triple `d0`; its two
//! linear layers per branch swap `d0 ↔ d1 = d0.swapped()` and swap back, so
//! blocks stack with a constant layout (§3.2 of the paper). In the unified
//! API this is the [`crate::dist::Stage`] of each weight: `Expand` runs
//! under `d0`, `Reduce` under `d1`, and biases live on the diagonal of the
//! *output* directions ([`crate::dist::VecRole`]).

pub mod attention;
pub mod block;

pub use block::{
    block_bwd, block_bwd_dx, block_fwd, block_wgrad, core_bwd, core_fwd, BlockBwdStash, WgradActs,
};

use crate::comm::Endpoint;
use crate::config::ModelConfig;
use crate::dist::{ShardSpec, Stage, VecRole};
use crate::parallel::{ops_for, ParallelOps};
use crate::rng::Xoshiro256;
use crate::tensor::Tensor;
use crate::topology::Parallelism;

/// One transformer block's tensors — used both for parameters and for
/// gradients (same shapes, same ownership pattern). Matrix entries are
/// always present (every rank owns a shard); vector entries are `Some` only
/// on owning ranks (3-D: direction diagonal; 2-D: mesh row 0; 1-D/Seq: all;
/// 1-D expand biases: every rank owns a column chunk).
#[derive(Clone, Debug)]
pub struct BlockTensors {
    pub ln1_g: Option<Tensor>,
    pub ln1_b: Option<Tensor>,
    pub w_qkv: Tensor,
    pub b_qkv: Option<Tensor>,
    pub w_proj: Tensor,
    pub b_proj: Option<Tensor>,
    pub ln2_g: Option<Tensor>,
    pub ln2_b: Option<Tensor>,
    pub w_fc1: Tensor,
    pub b_fc1: Option<Tensor>,
    pub w_fc2: Tensor,
    pub b_fc2: Option<Tensor>,
}

impl BlockTensors {
    /// Parameter/gradient pairs for the optimizer, in a stable order.
    pub fn pairs_mut<'a>(
        &'a mut self,
        g: &'a BlockTensors,
    ) -> Vec<(&'a mut Tensor, &'a Tensor)> {
        let mut out: Vec<(&mut Tensor, &Tensor)> = vec![
            (&mut self.w_qkv, &g.w_qkv),
            (&mut self.w_proj, &g.w_proj),
            (&mut self.w_fc1, &g.w_fc1),
            (&mut self.w_fc2, &g.w_fc2),
        ];
        let vecs: [(&mut Option<Tensor>, &Option<Tensor>); 8] = [
            (&mut self.ln1_g, &g.ln1_g),
            (&mut self.ln1_b, &g.ln1_b),
            (&mut self.b_qkv, &g.b_qkv),
            (&mut self.b_proj, &g.b_proj),
            (&mut self.ln2_g, &g.ln2_g),
            (&mut self.ln2_b, &g.ln2_b),
            (&mut self.b_fc1, &g.b_fc1),
            (&mut self.b_fc2, &g.b_fc2),
        ];
        for (p, gr) in vecs {
            match (p.as_mut(), gr.as_ref()) {
                (Some(p), Some(gr)) => out.push((p, gr)),
                (None, None) => {}
                _ => panic!("param/grad ownership mismatch"),
            }
        }
        out
    }

    /// Per-parameter element counts in [`BlockTensors::pairs_mut`] order
    /// (weights first, then present vectors) — the `local_param_numels`
    /// input of the `costmodel` optimizer-memory forms, so `plan` and the
    /// benches size ZeRO partitions from the same enumeration the trainer
    /// optimizes over.
    pub fn param_numels(&self) -> Vec<u64> {
        let mut out = vec![
            self.w_qkv.numel() as u64,
            self.w_proj.numel() as u64,
            self.w_fc1.numel() as u64,
            self.w_fc2.numel() as u64,
        ];
        for t in [
            &self.ln1_g, &self.ln1_b, &self.b_qkv, &self.b_proj, &self.ln2_g, &self.ln2_b,
            &self.b_fc1, &self.b_fc2,
        ]
        .into_iter()
        .flatten()
        {
            out.push(t.numel() as u64);
        }
        out
    }

    /// Total elements this rank stores for the block (memory accounting).
    pub fn numel(&self) -> usize {
        let v = |t: &Option<Tensor>| t.as_ref().map_or(0, |t| t.numel());
        self.w_qkv.numel()
            + self.w_proj.numel()
            + self.w_fc1.numel()
            + self.w_fc2.numel()
            + v(&self.ln1_g)
            + v(&self.ln1_b)
            + v(&self.b_qkv)
            + v(&self.b_proj)
            + v(&self.ln2_g)
            + v(&self.ln2_b)
            + v(&self.b_fc1)
            + v(&self.b_fc2)
    }
}

/// Dense (global, unsharded) block parameters — the init source and the
/// test-time ground truth.
#[derive(Clone, Debug)]
pub struct DenseBlock {
    pub ln1_g: Tensor,
    pub ln1_b: Tensor,
    pub w_qkv: Tensor,
    pub b_qkv: Tensor,
    pub w_proj: Tensor,
    pub b_proj: Tensor,
    pub ln2_g: Tensor,
    pub ln2_b: Tensor,
    pub w_fc1: Tensor,
    pub b_fc1: Tensor,
    pub w_fc2: Tensor,
    pub b_fc2: Tensor,
}

impl DenseBlock {
    /// GPT-2-style init: N(0, 0.02) weights, residual-path projections
    /// scaled down by √(2·layers), unit γ, zero biases.
    pub fn init(cfg: &ModelConfig, rng: &mut Xoshiro256) -> DenseBlock {
        let h = cfg.hidden;
        let f = cfg.ffn;
        let std = 0.02f32;
        let res_std = std / ((2 * cfg.layers) as f32).sqrt();
        DenseBlock {
            ln1_g: Tensor::ones(&[h]),
            ln1_b: Tensor::zeros(&[h]),
            w_qkv: Tensor::randn(&[h, 3 * h], std, rng),
            b_qkv: Tensor::zeros(&[3 * h]),
            w_proj: Tensor::randn(&[h, h], res_std, rng),
            b_proj: Tensor::zeros(&[h]),
            ln2_g: Tensor::ones(&[h]),
            ln2_b: Tensor::zeros(&[h]),
            w_fc1: Tensor::randn(&[h, f], std, rng),
            b_fc1: Tensor::zeros(&[f]),
            w_fc2: Tensor::randn(&[f, h], res_std, rng),
            b_fc2: Tensor::zeros(&[h]),
        }
    }

    /// Shape-only (phantom) global parameters — the timing path at paper
    /// scale. Sharding a phantom block through [`DenseBlock::shard`] yields
    /// phantom shards with exactly the shapes and vector ownership of the
    /// materialized sharding, because both flow through the same
    /// [`ShardSpec`] algebra (there is no separate hand-maintained phantom
    /// shape table to drift).
    pub fn phantom(cfg: &ModelConfig) -> DenseBlock {
        let h = cfg.hidden;
        let f = cfg.ffn;
        DenseBlock {
            ln1_g: Tensor::phantom(&[h]),
            ln1_b: Tensor::phantom(&[h]),
            w_qkv: Tensor::phantom(&[h, 3 * h]),
            b_qkv: Tensor::phantom(&[3 * h]),
            w_proj: Tensor::phantom(&[h, h]),
            b_proj: Tensor::phantom(&[h]),
            ln2_g: Tensor::phantom(&[h]),
            ln2_b: Tensor::phantom(&[h]),
            w_fc1: Tensor::phantom(&[h, f]),
            b_fc1: Tensor::phantom(&[f]),
            w_fc2: Tensor::phantom(&[f, h]),
            b_fc2: Tensor::phantom(&[h]),
        }
    }

    /// Cut this rank's shards under any layout — the single replacement for
    /// the old `to_seq`/`to_oned`/`to_twod`/`to_threed` family. Weight
    /// placement is keyed by the layer's [`Stage`], vector placement by its
    /// [`VecRole`]; the spec does the rest.
    pub fn shard(&self, spec: &ShardSpec) -> BlockTensors {
        BlockTensors {
            ln1_g: spec.shard_vector(VecRole::Norm, &self.ln1_g),
            ln1_b: spec.shard_vector(VecRole::Norm, &self.ln1_b),
            w_qkv: spec.shard_weight(Stage::Expand, &self.w_qkv),
            b_qkv: spec.shard_vector(VecRole::ExpandBias, &self.b_qkv),
            w_proj: spec.shard_weight(Stage::Reduce, &self.w_proj),
            b_proj: spec.shard_vector(VecRole::ReduceBias, &self.b_proj),
            ln2_g: spec.shard_vector(VecRole::Norm, &self.ln2_g),
            ln2_b: spec.shard_vector(VecRole::Norm, &self.ln2_b),
            w_fc1: spec.shard_weight(Stage::Expand, &self.w_fc1),
            b_fc1: spec.shard_vector(VecRole::ExpandBias, &self.b_fc1),
            w_fc2: spec.shard_weight(Stage::Reduce, &self.w_fc2),
            b_fc2: spec.shard_vector(VecRole::ReduceBias, &self.b_fc2),
        }
    }
}

/// Deterministic global parameters for the whole core (all blocks).
pub fn init_dense_blocks(cfg: &ModelConfig, seed: u64) -> Vec<DenseBlock> {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    (0..cfg.layers).map(|_| DenseBlock::init(cfg, &mut rng)).collect()
}

/// Per-rank execution environment: the boxed [`ParallelOps`] dispatcher.
/// Construction picks the implementation (`Seq`/`Ctx1D`/`Ctx2D`/`Ctx3D`)
/// once; everything downstream — the generic block, the trainer, the
/// engine, the benches — drives the trait object and cannot tell the
/// parallelisms apart.
pub struct ParEnv {
    ops: Box<dyn ParallelOps>,
}

impl ParEnv {
    pub fn new(par: Parallelism, edge: usize, rank: usize) -> ParEnv {
        ParEnv { ops: ops_for(par, edge, rank) }
    }

    /// The dense single-device environment.
    pub fn seq() -> ParEnv {
        ParEnv::new(Parallelism::Seq, 1, 0)
    }

    /// Wrap a custom [`ParallelOps`] implementation (new parallelisms plug
    /// in here without touching the dispatcher).
    pub fn from_ops(ops: Box<dyn ParallelOps>) -> ParEnv {
        ParEnv { ops }
    }

    /// The trait object the generic block drives.
    pub fn ops(&self) -> &dyn ParallelOps {
        &*self.ops
    }

    pub fn spec(&self) -> &ShardSpec {
        self.ops.spec()
    }

    pub fn kind(&self) -> Parallelism {
        self.ops.kind()
    }

    /// Number of attention heads this rank computes locally.
    pub fn local_heads(&self, cfg: &ModelConfig) -> usize {
        self.ops.local_heads(cfg)
    }

    /// Shape of this rank's activation shard for a global `(rows, hidden)`.
    pub fn activation_shape(&self, rows: usize, hidden: usize) -> (usize, usize) {
        self.ops.activation_shape(rows, hidden)
    }

    /// Shard the global dense blocks for this rank.
    pub fn shard_blocks(&self, dense: &[DenseBlock]) -> Vec<BlockTensors> {
        dense.iter().map(|b| self.ops.shard_block(b)).collect()
    }

    /// Shape-only block shards for the timing path.
    pub fn phantom_block(&self, cfg: &ModelConfig) -> BlockTensors {
        self.ops.phantom_block(cfg)
    }

    /// This rank's shard of a global `(rows, hidden)` activation (written
    /// into a recycled pool buffer on sharding meshes).
    pub fn scatter_activation(&self, ep: &mut Endpoint, global: &Tensor) -> Tensor {
        self.ops.scatter_activation(ep, global)
    }

    /// Reassemble the global activation on every rank (one all-gather over
    /// the world; only used at the model boundary — embedding/head — which
    /// the paper excludes from the parallelized region).
    pub fn gather_activation(
        &self,
        ep: &mut Endpoint,
        local: &Tensor,
        rows: usize,
        cols: usize,
    ) -> Tensor {
        self.ops.gather_activation(ep, local, rows, cols)
    }
}

/// Per-block forward cache (local shards only).
pub struct BlockCache {
    pub x: Tensor,
    pub xhat1: Tensor,
    pub istd1: Tensor,
    pub ln1: Tensor,
    pub attn: attention::AttnCache,
    pub attn_out: Tensor,
    pub xa: Tensor,
    pub xhat2: Tensor,
    pub istd2: Tensor,
    pub ln2: Tensor,
    pub fc1_pre: Tensor,
    pub fc1_act: Tensor,
}

/// Local layernorm forward used by the Seq/1-D paths (rows fully local)
/// and by the replicated head in [`crate::train`].
/// Returns `(y, xhat, inv_std)`.
pub fn local_layernorm(
    x: &Tensor,
    gamma: &Tensor,
    beta: &Tensor,
    eps: f32,
) -> (Tensor, Tensor, Tensor) {
    let (rows, cols) = x.dims2();
    if x.is_phantom() {
        return (
            Tensor::phantom(x.shape()),
            Tensor::phantom(x.shape()),
            Tensor::phantom(&[rows]),
        );
    }
    let mut xh = x.clone();
    let mut istd = vec![0.0f32; rows];
    {
        let xd = xh.data_mut();
        for r in 0..rows {
            let row = &mut xd[r * cols..(r + 1) * cols];
            let mean = row.iter().sum::<f32>() / cols as f32;
            let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / cols as f32;
            let inv = 1.0 / (var + eps).sqrt();
            istd[r] = inv;
            for v in row.iter_mut() {
                *v = (*v - mean) * inv;
            }
        }
    }
    let y = xh.mul_row_vector(gamma).add_row_vector(beta);
    (y, xh, Tensor::from_vec(&[rows], istd))
}

/// The `dx` half of [`local_layernorm_backward`] on its own — the
/// micro-batch pipelining path computes input gradients per micro-batch
/// but parameter gradients once on the concatenated rows, so the two
/// halves must be callable separately. The float operations here are a
/// verbatim copy of the `dx` part of the joint routine (per-row
/// accumulation order included), which is what keeps a pipelined backward
/// bit-identical to the unpipelined one on replicated meshes.
pub fn local_layernorm_backward_dx(
    dy: &Tensor,
    xhat: &Tensor,
    inv_std: &Tensor,
    gamma: &Tensor,
) -> Tensor {
    let (rows, cols) = dy.dims2();
    if dy.is_phantom() || xhat.is_phantom() {
        return Tensor::phantom(dy.shape());
    }
    let g = dy.mul_row_vector(gamma);
    let gd = g.data();
    let xd = xhat.data();
    let istd = inv_std.data();
    let n = cols as f32;
    let mut out = vec![0.0f32; rows * cols];
    for r in 0..rows {
        let mut sum_g = 0.0f32;
        let mut sum_gx = 0.0f32;
        for c in 0..cols {
            let idx = r * cols + c;
            sum_g += gd[idx];
            sum_gx += gd[idx] * xd[idx];
        }
        let c0 = istd[r] / n;
        for c in 0..cols {
            let idx = r * cols + c;
            out[idx] = c0 * (n * gd[idx] - sum_g - xd[idx] * sum_gx);
        }
    }
    Tensor::from_vec(dy.shape(), out)
}

/// Local layernorm backward: `(dx, dγ, dβ)`.
pub fn local_layernorm_backward(
    dy: &Tensor,
    xhat: &Tensor,
    inv_std: &Tensor,
    gamma: &Tensor,
) -> (Tensor, Tensor, Tensor) {
    let (rows, cols) = dy.dims2();
    if dy.is_phantom() || xhat.is_phantom() {
        return (
            Tensor::phantom(dy.shape()),
            Tensor::phantom(gamma.shape()),
            Tensor::phantom(gamma.shape()),
        );
    }
    let dgamma = dy.mul(xhat).sum_rows();
    let dbeta = dy.sum_rows();
    let g = dy.mul_row_vector(gamma);
    let gd = g.data();
    let xd = xhat.data();
    let istd = inv_std.data();
    let n = cols as f32;
    let mut out = vec![0.0f32; rows * cols];
    for r in 0..rows {
        let mut sum_g = 0.0f32;
        let mut sum_gx = 0.0f32;
        for c in 0..cols {
            let idx = r * cols + c;
            sum_g += gd[idx];
            sum_gx += gd[idx] * xd[idx];
        }
        let c0 = istd[r] / n;
        for c in 0..cols {
            let idx = r * cols + c;
            out[idx] = c0 * (n * gd[idx] - sum_g - xd[idx] * sum_gx);
        }
    }
    (Tensor::from_vec(dy.shape(), out), dgamma, dbeta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::{Dirs, Layout3D, MeshSpec};
    use crate::topology::{Axis, Cube};

    fn cfg() -> ModelConfig {
        ModelConfig::tiny()
    }

    #[test]
    fn dense_init_is_deterministic() {
        let a = init_dense_blocks(&cfg(), 7);
        let b = init_dense_blocks(&cfg(), 7);
        assert_eq!(a.len(), 2);
        assert!(a[0].w_qkv.max_abs_diff(&b[0].w_qkv) == 0.0);
        assert!(a[1].w_fc2.max_abs_diff(&b[1].w_fc2) == 0.0);
        let c = init_dense_blocks(&cfg(), 8);
        assert!(a[0].w_qkv.max_abs_diff(&c[0].w_qkv) > 0.0);
    }

    #[test]
    fn sharding_partitions_weights_exactly_3d() {
        let cfg = cfg();
        let dense = DenseBlock::init(&cfg, &mut Xoshiro256::seed_from_u64(1));
        let mut total_w_qkv = 0;
        let mut vec_owners = 0;
        for r in 0..8 {
            let s = dense.shard(&ShardSpec::threed(2, r));
            total_w_qkv += s.w_qkv.numel();
            if s.b_qkv.is_some() {
                vec_owners += 1;
            }
            // Perfect balance: every rank stores exactly 1/P of each matrix.
            assert_eq!(s.w_qkv.numel(), cfg.hidden * 3 * cfg.hidden / 8);
        }
        assert_eq!(total_w_qkv, cfg.hidden * 3 * cfg.hidden);
        assert_eq!(vec_owners, 4); // p² diagonal owners
    }

    #[test]
    fn threed_gather_back_reconstructs_dense() {
        let cfg = cfg();
        let dense = DenseBlock::init(&cfg, &mut Xoshiro256::seed_from_u64(2));
        let spec0 = ShardSpec::threed(2, 0);
        let shards: Vec<BlockTensors> =
            (0..8).map(|r| dense.shard(&ShardSpec::threed(2, r))).collect();
        let w_shards: Vec<Tensor> = shards.iter().map(|s| s.w_qkv.clone()).collect();
        let w = spec0.assemble_weight(Stage::Expand, &w_shards, cfg.hidden, 3 * cfg.hidden);
        assert_eq!(w, dense.w_qkv);
        // fc2 uses the swapped directions (the Reduce stage).
        let w2_shards: Vec<Tensor> = shards.iter().map(|s| s.w_fc2.clone()).collect();
        let w2 = spec0.assemble_weight(Stage::Reduce, &w2_shards, cfg.ffn, cfg.hidden);
        assert_eq!(w2, dense.w_fc2);
        // And the spec agrees with the raw Layout3D algebra.
        let cube = Cube::new(2);
        let w_direct =
            Layout3D::weight(Dirs::canonical()).gather(&cube, &w_shards, cfg.hidden, 3 * cfg.hidden);
        assert_eq!(w_direct, dense.w_qkv);
    }

    #[test]
    fn pairs_mut_yields_all_owned_params() {
        let cfg = cfg();
        let dense = DenseBlock::init(&cfg, &mut Xoshiro256::seed_from_u64(3));
        let mut p = dense.shard(&ShardSpec::seq());
        let g = dense.shard(&ShardSpec::seq());
        assert_eq!(p.pairs_mut(&g).len(), 12);
        let mut p3 = dense.shard(&ShardSpec::threed(2, 0));
        let g3 = dense.shard(&ShardSpec::threed(2, 0));
        // rank 0 = coord (0,0,0): on every diagonal → owns all 8 vectors.
        assert_eq!(p3.pairs_mut(&g3).len(), 12);
        let mut p3b = dense.shard(&ShardSpec::threed(2, 1));
        let g3b = dense.shard(&ShardSpec::threed(2, 1));
        // rank 1 = coord (0,0,1): j≠l and l≠j diagonals differ per dirs.
        assert!(p3b.pairs_mut(&g3b).len() < 12);
    }

    #[test]
    fn local_layernorm_normalizes_and_backward_checks() {
        let mut rng = Xoshiro256::seed_from_u64(4);
        let x = Tensor::randn(&[6, 16], 1.0, &mut rng);
        let gamma = Tensor::ones(&[16]);
        let beta = Tensor::zeros(&[16]);
        let (y, xhat, istd) = local_layernorm(&x, &gamma, &beta, 1e-5);
        for r in 0..6 {
            let mean: f32 = (0..16).map(|c| y.at2(r, c)).sum::<f32>() / 16.0;
            assert!(mean.abs() < 1e-5);
        }
        // Finite-difference dx check.
        let dy = Tensor::randn(&[6, 16], 1.0, &mut rng);
        let (dx, _, _) = local_layernorm_backward(&dy, &xhat, &istd, &gamma);
        let h = 1e-2f32;
        for idx in [0usize, 40, 95] {
            let mut xp = x.clone();
            xp.data_mut()[idx] += h;
            let mut xm = x.clone();
            xm.data_mut()[idx] -= h;
            let fp = local_layernorm(&xp, &gamma, &beta, 1e-5).0;
            let fm = local_layernorm(&xm, &gamma, &beta, 1e-5).0;
            let num = fp.sub(&fm).scale(1.0 / (2.0 * h)).mul(&dy).sum();
            let ana = dx.data()[idx];
            assert!((num - ana).abs() < 2e-2 * (1.0 + ana.abs()), "{num} vs {ana}");
        }
    }

    #[test]
    fn par_env_constructors_dispatch_by_kind() {
        let e = ParEnv::new(Parallelism::ThreeD, 2, 5);
        assert_eq!(e.kind(), Parallelism::ThreeD);
        assert_eq!(e.local_heads(&cfg()), 2);
        let MeshSpec::Cube(cube, d0) = &e.spec().mesh else {
            panic!("3-D env must carry a cube spec");
        };
        assert_eq!(cube.edge(), 2);
        assert_eq!(e.spec().rank, 5);
        assert_eq!(d0.a, Axis::Y);
        assert_eq!(ParEnv::new(Parallelism::OneD, 4, 1).local_heads(&cfg()), 1);
        assert_eq!(ParEnv::seq().kind(), Parallelism::Seq);
    }

    #[test]
    fn phantom_blocks_match_materialized_shard_shapes_everywhere() {
        // The phantom timing path and the materialized path share one
        // sharding routine; pin that the shapes and the vector-ownership
        // pattern agree for every parallelism and every rank.
        let cfg = cfg();
        let dense = DenseBlock::init(&cfg, &mut Xoshiro256::seed_from_u64(9));
        for (par, edge) in [
            (Parallelism::Seq, 1usize),
            (Parallelism::OneD, 4),
            (Parallelism::TwoD, 2),
            (Parallelism::ThreeD, 2),
            (Parallelism::TwoFiveD { depth: 2 }, 2),
            (
                Parallelism::Hybrid {
                    replicas: 2,
                    inner: crate::topology::HybridInner::OneD,
                },
                2,
            ),
        ] {
            let world = par.world_size(edge);
            for rank in 0..world {
                let env = ParEnv::new(par, edge, rank);
                let ph = env.phantom_block(&cfg);
                let real = dense.shard(env.spec());
                assert_eq!(ph.w_qkv.shape(), real.w_qkv.shape(), "{par:?} r{rank}");
                assert_eq!(ph.w_fc2.shape(), real.w_fc2.shape(), "{par:?} r{rank}");
                assert!(ph.w_qkv.is_phantom());
                let vecs = [
                    (&ph.ln1_g, &real.ln1_g),
                    (&ph.b_qkv, &real.b_qkv),
                    (&ph.b_proj, &real.b_proj),
                    (&ph.b_fc1, &real.b_fc1),
                ];
                for (p, r) in vecs {
                    assert_eq!(p.is_some(), r.is_some(), "{par:?} r{rank} ownership");
                    if let (Some(p), Some(r)) = (p.as_ref(), r.as_ref()) {
                        assert_eq!(p.shape(), r.shape(), "{par:?} r{rank}");
                    }
                }
                assert_eq!(ph.numel(), real.numel(), "{par:?} r{rank}");
            }
        }
    }
}
