//! 3-D transformer block — the paper's §3.2, built from Algorithms 1–8.
//!
//! Directions: the block receives its input in `Layout3D::input(d0)`. Each
//! linear layer (Algorithm 1 + 7) flips the activation directions
//! `d0 ↔ d1 = d0.swapped()`; with exactly two linears per residual branch,
//! both branch outputs land back in `d0`, so the residual adds are local
//! and blocks stack with a constant layout — the paper's "we only need to
//! exchange the input and output direction after the first linear layer of
//! both Self-Attention and MLP blocks".
//!
//! Biases live on the diagonal of their layer's *output* directions
//! (Figure 5); layernorm γ/β on the diagonal of `d0`.

use super::{attention, BlockCache, BlockTensors};
use crate::comm::Endpoint;
use crate::config::ModelConfig;
use crate::dist::Dirs;
use crate::ops;
use crate::parallel::threed::{
    add_vec_backward, layernorm, layernorm_backward, mm_nn, mm_nn_backward, vec_op, Ctx3D,
};
use crate::tensor::Tensor;

pub fn block_fwd(
    ep: &mut Endpoint,
    ctx: &Ctx3D,
    p: &BlockTensors,
    x: &Tensor,
    cfg: &ModelConfig,
    d0: Dirs,
) -> (Tensor, BlockCache) {
    let d1 = d0.swapped();
    let hd = cfg.hidden / cfg.heads;
    let local_heads = cfg.heads / ctx.p();

    // LN1 (γ/β diagonal vectors under d0).
    let (ln1, xhat1, istd1) = layernorm(
        ep, ctx, x, p.ln1_g.as_ref(), p.ln1_b.as_ref(), d0, cfg.eps, cfg.hidden,
    );

    // QKV linear: Algorithm 1 under d0, bias via Algorithm 7 under d1.
    let qkv_mm = mm_nn(ep, ctx, &ln1, &p.w_qkv, d0);
    let qkv = vec_op(ep, ctx, &qkv_mm, p.b_qkv.as_ref(), d1, false);

    // Attention: rank-local (complete heads × complete sequences).
    let (attn_out, attn) = attention::fwd(ep, &qkv, local_heads, hd, cfg.seq);

    // Projection: Algorithm 1 under d1 → back to d0.
    let proj_mm = mm_nn(ep, ctx, &attn_out, &p.w_proj, d1);
    let proj = vec_op(ep, ctx, &proj_mm, p.b_proj.as_ref(), d0, false);
    let xa = x.add(&proj);
    ep.charge_memop(2.0 * x.nominal_bytes() as f64);

    // LN2.
    let (ln2, xhat2, istd2) = layernorm(
        ep, ctx, &xa, p.ln2_g.as_ref(), p.ln2_b.as_ref(), d0, cfg.eps, cfg.hidden,
    );

    // MLP: fc1 under d0, gelu local, fc2 under d1 → back to d0.
    let fc1_mm = mm_nn(ep, ctx, &ln2, &p.w_fc1, d0);
    let fc1_pre = vec_op(ep, ctx, &fc1_mm, p.b_fc1.as_ref(), d1, false);
    let fc1_act = ops::gelu(&fc1_pre);
    ep.charge_memop(2.0 * fc1_pre.nominal_bytes() as f64);

    let fc2_mm = mm_nn(ep, ctx, &fc1_act, &p.w_fc2, d1);
    let fc2 = vec_op(ep, ctx, &fc2_mm, p.b_fc2.as_ref(), d0, false);
    let y = xa.add(&fc2);
    ep.charge_memop(2.0 * x.nominal_bytes() as f64);

    (
        y,
        BlockCache {
            x: x.clone(),
            xhat1,
            istd1,
            ln1,
            attn,
            attn_out,
            xa,
            xhat2,
            istd2,
            ln2,
            fc1_pre,
            fc1_act,
        },
    )
}

pub fn block_bwd(
    ep: &mut Endpoint,
    ctx: &Ctx3D,
    p: &BlockTensors,
    cache: &BlockCache,
    dy: &Tensor,
    cfg: &ModelConfig,
    d0: Dirs,
) -> (Tensor, BlockTensors) {
    let d1 = d0.swapped();

    // fc2 bias (Algorithm 8 under d0) then matmul backward (Algorithm 2
    // under d1: fc2 ran with dirs d1).
    let (d_fc2mm, db_fc2) = add_vec_backward(ep, ctx, dy, d0);
    let (d_fc1act, dw_fc2) =
        mm_nn_backward(ep, ctx, &d_fc2mm, &cache.fc1_act, &p.w_fc2, d1);

    let d_fc1pre = ops::gelu_backward(&d_fc1act, &cache.fc1_pre);
    ep.charge_memop(3.0 * d_fc1act.nominal_bytes() as f64);

    let (d_fc1mm, db_fc1) = add_vec_backward(ep, ctx, &d_fc1pre, d1);
    let (d_ln2, dw_fc1) = mm_nn_backward(ep, ctx, &d_fc1mm, &cache.ln2, &p.w_fc1, d0);

    let (d_xa_ln, dg2, db2) = layernorm_backward(
        ep, ctx, &d_ln2, &cache.xhat2, &cache.istd2, p.ln2_g.as_ref(), d0, cfg.hidden,
    );
    let dxa = dy.add(&d_xa_ln);
    ep.charge_memop(2.0 * dy.nominal_bytes() as f64);

    // Attention branch.
    let (d_projmm, db_proj) = add_vec_backward(ep, ctx, &dxa, d0);
    let (d_attn, dw_proj) =
        mm_nn_backward(ep, ctx, &d_projmm, &cache.attn_out, &p.w_proj, d1);

    let d_qkv = attention::bwd(ep, &d_attn, &cache.attn);

    let (d_qkvmm, db_qkv) = add_vec_backward(ep, ctx, &d_qkv, d1);
    let (d_ln1, dw_qkv) = mm_nn_backward(ep, ctx, &d_qkvmm, &cache.ln1, &p.w_qkv, d0);

    let (dx_ln, dg1, db1) = layernorm_backward(
        ep, ctx, &d_ln1, &cache.xhat1, &cache.istd1, p.ln1_g.as_ref(), d0, cfg.hidden,
    );
    let dx = dxa.add(&dx_ln);
    ep.charge_memop(2.0 * dy.nominal_bytes() as f64);

    (
        dx,
        BlockTensors {
            ln1_g: dg1,
            ln1_b: db1,
            w_qkv: dw_qkv,
            b_qkv: db_qkv,
            w_proj: dw_proj,
            b_proj: db_proj,
            ln2_g: dg2,
            ln2_b: db2,
            w_fc1: dw_fc1,
            b_fc1: db_fc1,
            w_fc2: dw_fc2,
            b_fc2: db_fc2,
        },
    )
}
