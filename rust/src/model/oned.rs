//! 1-D (Megatron) transformer block: replicated activations, column- then
//! row-parallel linears, two all-reduces per block in each direction.

use super::{attention, local_layernorm, local_layernorm_backward, BlockCache, BlockTensors};
use crate::comm::Endpoint;
use crate::config::ModelConfig;
use crate::ops;
use crate::parallel::oned::{
    col_linear_bwd, col_linear_fwd, row_linear_bwd, row_linear_fwd, Ctx1D,
};
use crate::tensor::Tensor;

fn req<'a>(t: &'a Option<Tensor>, name: &str) -> &'a Tensor {
    t.as_ref().unwrap_or_else(|| panic!("1-D block missing vector param {name}"))
}

pub fn block_fwd(
    ep: &mut Endpoint,
    ctx: &Ctx1D,
    p: &BlockTensors,
    x: &Tensor,
    cfg: &ModelConfig,
) -> (Tensor, BlockCache) {
    let hd = cfg.hidden / cfg.heads;
    let local_heads = cfg.heads / ctx.world();
    let (ln1, xhat1, istd1) =
        local_layernorm(x, req(&p.ln1_g, "ln1_g"), req(&p.ln1_b, "ln1_b"), cfg.eps);
    ep.charge_memop(4.0 * x.nominal_bytes() as f64);

    // Column-parallel QKV: local output = this rank's heads.
    let qkv = col_linear_fwd(ep, ctx, &ln1, &p.w_qkv, Some(req(&p.b_qkv, "b_qkv")));
    let (attn_out, attn) = attention::fwd(ep, &qkv, local_heads, hd, cfg.seq);

    // Row-parallel projection: one all-reduce, replicated output.
    let proj = row_linear_fwd(ep, ctx, &attn_out, &p.w_proj, Some(req(&p.b_proj, "b_proj")));
    let xa = x.add(&proj);
    ep.charge_memop(2.0 * x.nominal_bytes() as f64);

    let (ln2, xhat2, istd2) =
        local_layernorm(&xa, req(&p.ln2_g, "ln2_g"), req(&p.ln2_b, "ln2_b"), cfg.eps);
    ep.charge_memop(4.0 * x.nominal_bytes() as f64);

    let fc1_pre = col_linear_fwd(ep, ctx, &ln2, &p.w_fc1, Some(req(&p.b_fc1, "b_fc1")));
    let fc1_act = ops::gelu(&fc1_pre);
    ep.charge_memop(2.0 * fc1_pre.nominal_bytes() as f64);

    let fc2 = row_linear_fwd(ep, ctx, &fc1_act, &p.w_fc2, Some(req(&p.b_fc2, "b_fc2")));
    let y = xa.add(&fc2);
    ep.charge_memop(2.0 * x.nominal_bytes() as f64);

    (
        y,
        BlockCache {
            x: x.clone(),
            xhat1,
            istd1,
            ln1,
            attn,
            attn_out,
            xa,
            xhat2,
            istd2,
            ln2,
            fc1_pre,
            fc1_act,
        },
    )
}

pub fn block_bwd(
    ep: &mut Endpoint,
    ctx: &Ctx1D,
    p: &BlockTensors,
    cache: &BlockCache,
    dy: &Tensor,
    _cfg: &ModelConfig,
) -> (Tensor, BlockTensors) {
    // fc2 (row-parallel) backward: dy replicated.
    let (d_fc1act, dw_fc2, db_fc2) = row_linear_bwd(ep, ctx, dy, &cache.fc1_act, &p.w_fc2);
    let d_fc1pre = ops::gelu_backward(&d_fc1act, &cache.fc1_pre);
    ep.charge_memop(3.0 * d_fc1act.nominal_bytes() as f64);
    // fc1 (column-parallel) backward: all-reduces d_ln2.
    let (d_ln2, dw_fc1, db_fc1) = col_linear_bwd(ep, ctx, &d_fc1pre, &cache.ln2, &p.w_fc1);

    let (d_xa_ln, dg2, db2) =
        local_layernorm_backward(&d_ln2, &cache.xhat2, &cache.istd2, req(&p.ln2_g, "ln2_g"));
    ep.charge_memop(6.0 * dy.nominal_bytes() as f64);
    let dxa = dy.add(&d_xa_ln);

    let (d_attn, dw_proj, db_proj) = row_linear_bwd(ep, ctx, &dxa, &cache.attn_out, &p.w_proj);
    let d_qkv = attention::bwd(ep, &d_attn, &cache.attn);
    let (d_ln1, dw_qkv, db_qkv) = col_linear_bwd(ep, ctx, &d_qkv, &cache.ln1, &p.w_qkv);

    let (dx_ln, dg1, db1) =
        local_layernorm_backward(&d_ln1, &cache.xhat1, &cache.istd1, req(&p.ln1_g, "ln1_g"));
    ep.charge_memop(6.0 * dy.nominal_bytes() as f64);
    let dx = dxa.add(&dx_ln);

    (
        dx,
        BlockTensors {
            ln1_g: Some(dg1),
            ln1_b: Some(db1),
            w_qkv: dw_qkv,
            b_qkv: Some(db_qkv),
            w_proj: dw_proj,
            b_proj: Some(db_proj),
            ln2_g: Some(dg2),
            ln2_b: Some(db2),
            w_fc1: dw_fc1,
            b_fc1: Some(db_fc1),
            w_fc2: dw_fc2,
            b_fc2: Some(db_fc2),
        },
    )
}
