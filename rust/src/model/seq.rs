//! Dense single-device transformer block (the `Seq` reference everything
//! else is verified against).

use super::{attention, local_layernorm, local_layernorm_backward, BlockCache, BlockTensors};
use crate::comm::Endpoint;
use crate::config::ModelConfig;
use crate::ops;
use crate::tensor::Tensor;

fn charge_mm(ep: &mut Endpoint, m: usize, n: usize, k: usize) {
    ep.charge_flops(2.0 * m as f64 * n as f64 * k as f64);
}

fn req<'a>(t: &'a Option<Tensor>, name: &str) -> &'a Tensor {
    t.as_ref().unwrap_or_else(|| panic!("Seq block missing vector param {name}"))
}

/// Forward: pre-LN block `y = x + proj(attn(ln1 x)) + fc2(gelu(fc1(ln2 ·)))`.
pub fn block_fwd(
    ep: &mut Endpoint,
    p: &BlockTensors,
    x: &Tensor,
    cfg: &ModelConfig,
) -> (Tensor, BlockCache) {
    let (rows, h) = x.dims2();
    let hd = cfg.hidden / cfg.heads;
    let (ln1, xhat1, istd1) =
        local_layernorm(x, req(&p.ln1_g, "ln1_g"), req(&p.ln1_b, "ln1_b"), cfg.eps);
    ep.charge_memop(4.0 * x.nominal_bytes() as f64);

    charge_mm(ep, rows, 3 * h, h);
    let qkv = ln1.matmul(&p.w_qkv).add_row_vector(req(&p.b_qkv, "b_qkv"));
    let (attn_out, attn) = attention::fwd(ep, &qkv, cfg.heads, hd, cfg.seq);

    charge_mm(ep, rows, h, h);
    let proj = attn_out
        .matmul(&p.w_proj)
        .add_row_vector(req(&p.b_proj, "b_proj"));
    let xa = x.add(&proj);
    ep.charge_memop(2.0 * x.nominal_bytes() as f64);

    let (ln2, xhat2, istd2) =
        local_layernorm(&xa, req(&p.ln2_g, "ln2_g"), req(&p.ln2_b, "ln2_b"), cfg.eps);
    ep.charge_memop(4.0 * x.nominal_bytes() as f64);

    charge_mm(ep, rows, cfg.ffn, h);
    let fc1_pre = ln2.matmul(&p.w_fc1).add_row_vector(req(&p.b_fc1, "b_fc1"));
    let fc1_act = ops::gelu(&fc1_pre);
    ep.charge_memop(2.0 * fc1_pre.nominal_bytes() as f64);

    charge_mm(ep, rows, h, cfg.ffn);
    let fc2 = fc1_act
        .matmul(&p.w_fc2)
        .add_row_vector(req(&p.b_fc2, "b_fc2"));
    let y = xa.add(&fc2);
    ep.charge_memop(2.0 * x.nominal_bytes() as f64);

    (
        y,
        BlockCache {
            x: x.clone(),
            xhat1,
            istd1,
            ln1,
            attn,
            attn_out,
            xa,
            xhat2,
            istd2,
            ln2,
            fc1_pre,
            fc1_act,
        },
    )
}

/// Backward; returns `(dx, grads)`.
pub fn block_bwd(
    ep: &mut Endpoint,
    p: &BlockTensors,
    cache: &BlockCache,
    dy: &Tensor,
    cfg: &ModelConfig,
) -> (Tensor, BlockTensors) {
    let (rows, h) = dy.dims2();
    let f = cfg.ffn;

    // y = xa + fc2(gelu(fc1(ln2(xa)))): both residual branches get dy.
    let db_fc2 = dy.sum_rows();
    charge_mm(ep, rows, f, h);
    let d_fc1act = dy.matmul_nt(&p.w_fc2);
    charge_mm(ep, f, h, rows);
    let dw_fc2 = cache.fc1_act.matmul_tn(dy);

    let d_fc1pre = ops::gelu_backward(&d_fc1act, &cache.fc1_pre);
    ep.charge_memop(3.0 * d_fc1act.nominal_bytes() as f64);
    let db_fc1 = d_fc1pre.sum_rows();
    charge_mm(ep, rows, h, f);
    let d_ln2 = d_fc1pre.matmul_nt(&p.w_fc1);
    charge_mm(ep, h, f, rows);
    let dw_fc1 = cache.ln2.matmul_tn(&d_fc1pre);

    let (d_xa_ln, dg2, db2) =
        local_layernorm_backward(&d_ln2, &cache.xhat2, &cache.istd2, req(&p.ln2_g, "ln2_g"));
    ep.charge_memop(6.0 * dy.nominal_bytes() as f64);
    let dxa = dy.add(&d_xa_ln);

    // xa = x + proj(attn): both branches get dxa.
    let db_proj = dxa.sum_rows();
    charge_mm(ep, rows, h, h);
    let d_attn = dxa.matmul_nt(&p.w_proj);
    charge_mm(ep, h, h, rows);
    let dw_proj = cache.attn_out.matmul_tn(&dxa);

    let d_qkv = attention::bwd(ep, &d_attn, &cache.attn);
    let db_qkv = d_qkv.sum_rows();
    charge_mm(ep, rows, h, 3 * h);
    let d_ln1 = d_qkv.matmul_nt(&p.w_qkv);
    charge_mm(ep, h, 3 * h, rows);
    let dw_qkv = cache.ln1.matmul_tn(&d_qkv);

    let (dx_ln, dg1, db1) =
        local_layernorm_backward(&d_ln1, &cache.xhat1, &cache.istd1, req(&p.ln1_g, "ln1_g"));
    ep.charge_memop(6.0 * dy.nominal_bytes() as f64);
    let dx = dxa.add(&dx_ln);

    (
        dx,
        BlockTensors {
            ln1_g: Some(dg1),
            ln1_b: Some(db1),
            w_qkv: dw_qkv,
            b_qkv: Some(db_qkv),
            w_proj: dw_proj,
            b_proj: Some(db_proj),
            ln2_g: Some(dg2),
            ln2_b: Some(db2),
            w_fc1: dw_fc1,
            b_fc1: Some(db_fc1),
            w_fc2: dw_fc2,
            b_fc2: Some(db_fc2),
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::NetModel;
    use crate::model::{init_dense_blocks, DenseBlock};
    use crate::rng::Xoshiro256;
    use crate::spmd::run_spmd;

    fn tiny() -> ModelConfig {
        ModelConfig::tiny()
    }

    fn randt(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        Tensor::randn(shape, 1.0, &mut rng)
    }

    #[test]
    fn forward_shapes_and_determinism() {
        let cfg = tiny();
        let dense = init_dense_blocks(&cfg, 1);
        let x = randt(&[cfg.batch * cfg.seq, cfg.hidden], 2);
        let x2 = x.clone();
        let p = dense[0].to_seq();
        let p2 = p.clone();
        let y1 = run_spmd(1, NetModel::zero(), move |_, ep| block_fwd(ep, &p, &x, &tiny()).0)
            .pop()
            .unwrap();
        let y2 = run_spmd(1, NetModel::zero(), move |_, ep| block_fwd(ep, &p2, &x2, &tiny()).0)
            .pop()
            .unwrap();
        assert_eq!(y1.shape(), &[cfg.batch * cfg.seq, cfg.hidden]);
        assert_eq!(y1, y2);
    }

    #[test]
    fn backward_input_gradient_matches_numeric() {
        let mut cfg = tiny();
        cfg.seq = 4;
        cfg.batch = 1;
        cfg.hidden = 16;
        cfg.ffn = 32;
        cfg.heads = 2;
        cfg.layers = 1;
        let dense = DenseBlock::init(&cfg, &mut Xoshiro256::seed_from_u64(3));
        let x0 = randt(&[cfg.seq, cfg.hidden], 4);
        let dy0 = randt(&[cfg.seq, cfg.hidden], 5);

        let run_f = |xin: Tensor| -> Tensor {
            let p = dense.to_seq();
            let cfg = cfg.clone();
            run_spmd(1, NetModel::zero(), move |_, ep| block_fwd(ep, &p, &xin, &cfg).0)
                .pop()
                .unwrap()
        };
        let p = dense.to_seq();
        let cfgc = cfg.clone();
        let x = x0.clone();
        let dy = dy0.clone();
        let dx = run_spmd(1, NetModel::zero(), move |_, ep| {
            let (_, cache) = block_fwd(ep, &p, &x, &cfgc);
            block_bwd(ep, &p, &cache, &dy, &cfgc).0
        })
        .pop()
        .unwrap();

        let h = 5e-3f32;
        for idx in [0usize, 33, 63] {
            let mut xp = x0.clone();
            xp.data_mut()[idx] += h;
            let mut xm = x0.clone();
            xm.data_mut()[idx] -= h;
            let num = run_f(xp).sub(&run_f(xm)).scale(1.0 / (2.0 * h)).mul(&dy0).sum();
            let ana = dx.data()[idx];
            assert!(
                (num - ana).abs() < 5e-2 * (1.0 + ana.abs()),
                "idx {idx}: numeric {num} vs analytic {ana}"
            );
        }
    }

    #[test]
    fn backward_weight_gradient_matches_numeric() {
        let mut cfg = tiny();
        cfg.seq = 4;
        cfg.batch = 1;
        cfg.hidden = 8;
        cfg.ffn = 16;
        cfg.heads = 2;
        cfg.layers = 1;
        let dense = DenseBlock::init(&cfg, &mut Xoshiro256::seed_from_u64(6));
        let x0 = randt(&[cfg.seq, cfg.hidden], 7);
        let dy0 = randt(&[cfg.seq, cfg.hidden], 8);

        let p0 = dense.to_seq();
        let cfgc = cfg.clone();
        let x = x0.clone();
        let dy = dy0.clone();
        let grads = run_spmd(1, NetModel::zero(), move |_, ep| {
            let (_, cache) = block_fwd(ep, &p0, &x, &cfgc);
            block_bwd(ep, &p0, &cache, &dy, &cfgc).1
        })
        .pop()
        .unwrap();

        // Perturb w_fc1[idx] and check dL = <grad, dW> numerically.
        let h = 5e-3f32;
        for idx in [0usize, 50, 127] {
            let run_with = |delta: f32| -> Tensor {
                let mut d2 = dense.clone();
                d2.w_fc1.data_mut()[idx] += delta;
                let p = d2.to_seq();
                let x = x0.clone();
                let cfg = cfg.clone();
                run_spmd(1, NetModel::zero(), move |_, ep| block_fwd(ep, &p, &x, &cfg).0)
                    .pop()
                    .unwrap()
            };
            let num = run_with(h).sub(&run_with(-h)).scale(1.0 / (2.0 * h)).mul(&dy0).sum();
            let ana = grads.w_fc1.data()[idx];
            assert!(
                (num - ana).abs() < 5e-2 * (1.0 + ana.abs()),
                "w_fc1[{idx}]: numeric {num} vs analytic {ana}"
            );
        }
    }
}
