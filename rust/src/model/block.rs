//! The one transformer block — generic over parallelism.
//!
//! Pre-LN block `y = xa + fc2(gelu(fc1(ln2 xa)))`, `xa = x + proj(attn(ln1
//! x))`, written once against [`ParallelOps`]. Which collectives move the
//! shards — none (Seq), Megatron all-reduces (1-D), SUMMA broadcasts
//! (2-D), or the paper's gather/reduce-scatter lines (3-D) — is entirely
//! the trait implementation's business; this file only sequences the
//! layers and charges the env-independent memory passes (residual adds,
//! gelu). Layer staging is the [`Stage`] pairing: each residual branch is
//! one `Expand` then one `Reduce` linear, which returns the activation to
//! the block-entry layout so blocks stack under every parallelism.
//!
//! Attention is always rank-local (complete heads × complete sequences per
//! shard — see the weight conventions in [`crate::model`]), so it is the
//! same code for all four kinds too.

use super::{attention, BlockCache, BlockTensors};
use crate::comm::Endpoint;
use crate::config::ModelConfig;
use crate::dist::Stage;
use crate::ops::{gelu, gelu_backward};
use crate::parallel::ParallelOps;
use crate::tensor::Tensor;

/// One transformer block forward on this rank's shard.
pub fn block_fwd(
    ep: &mut Endpoint,
    ops: &dyn ParallelOps,
    p: &BlockTensors,
    x: &Tensor,
    cfg: &ModelConfig,
) -> (Tensor, BlockCache) {
    let hd = cfg.hidden / cfg.heads;
    let local_heads = ops.local_heads(cfg);

    let (ln1, xhat1, istd1) =
        ops.layernorm(ep, x, p.ln1_g.as_ref(), p.ln1_b.as_ref(), cfg.eps, cfg.hidden);

    // Attention branch: Expand (QKV) → rank-local attention → Reduce (proj).
    let qkv = ops.linear_fwd(ep, &ln1, &p.w_qkv, p.b_qkv.as_ref(), Stage::Expand);
    let (attn_out, attn) = attention::fwd(ep, &qkv, local_heads, hd, cfg.seq);
    let proj = ops.linear_fwd(ep, &attn_out, &p.w_proj, p.b_proj.as_ref(), Stage::Reduce);
    let xa = x.add(&proj);
    ep.charge_memop(2.0 * x.nominal_bytes() as f64);

    let (ln2, xhat2, istd2) =
        ops.layernorm(ep, &xa, p.ln2_g.as_ref(), p.ln2_b.as_ref(), cfg.eps, cfg.hidden);

    // MLP branch: Expand (fc1) → local gelu → Reduce (fc2).
    let fc1_pre = ops.linear_fwd(ep, &ln2, &p.w_fc1, p.b_fc1.as_ref(), Stage::Expand);
    let fc1_act = gelu(&fc1_pre);
    ep.charge_memop(2.0 * fc1_pre.nominal_bytes() as f64);
    let fc2 = ops.linear_fwd(ep, &fc1_act, &p.w_fc2, p.b_fc2.as_ref(), Stage::Reduce);
    let y = xa.add(&fc2);
    ep.charge_memop(2.0 * x.nominal_bytes() as f64);

    (
        y,
        BlockCache {
            x: x.clone(),
            xhat1,
            istd1,
            ln1,
            attn,
            attn_out,
            xa,
            xhat2,
            istd2,
            ln2,
            fc1_pre,
            fc1_act,
        },
    )
}

/// Block backward; returns `(dx, grads)`. Vector gradients come back with
/// exactly the ownership pattern of the parameters (`Option` per rank), so
/// the optimizer pairing is parallelism-agnostic too.
pub fn block_bwd(
    ep: &mut Endpoint,
    ops: &dyn ParallelOps,
    p: &BlockTensors,
    cache: &BlockCache,
    dy: &Tensor,
    cfg: &ModelConfig,
) -> (Tensor, BlockTensors) {
    // y = xa + fc2(gelu(fc1(ln2(xa)))): both residual branches get dy.
    let (d_fc1act, dw_fc2, db_fc2) =
        ops.linear_bwd(ep, dy, &cache.fc1_act, &p.w_fc2, Stage::Reduce);
    let d_fc1pre = gelu_backward(&d_fc1act, &cache.fc1_pre);
    ep.charge_memop(3.0 * d_fc1act.nominal_bytes() as f64);
    let (d_ln2, dw_fc1, db_fc1) =
        ops.linear_bwd(ep, &d_fc1pre, &cache.ln2, &p.w_fc1, Stage::Expand);

    let (d_xa_ln, dg2, db2) = ops.layernorm_backward(
        ep, &d_ln2, &cache.xhat2, &cache.istd2, p.ln2_g.as_ref(), cfg.hidden,
    );
    let dxa = dy.add(&d_xa_ln);
    ep.charge_memop(2.0 * dy.nominal_bytes() as f64);

    // xa = x + proj(attn): both branches get dxa.
    let (d_attn, dw_proj, db_proj) =
        ops.linear_bwd(ep, &dxa, &cache.attn_out, &p.w_proj, Stage::Reduce);
    let d_qkv = attention::bwd(ep, &d_attn, &cache.attn);
    let (d_ln1, dw_qkv, db_qkv) = ops.linear_bwd(ep, &d_qkv, &cache.ln1, &p.w_qkv, Stage::Expand);

    let (dx_ln, dg1, db1) = ops.layernorm_backward(
        ep, &d_ln1, &cache.xhat1, &cache.istd1, p.ln1_g.as_ref(), cfg.hidden,
    );
    let dx = dxa.add(&dx_ln);
    ep.charge_memop(2.0 * dy.nominal_bytes() as f64);

    (
        dx,
        BlockTensors {
            ln1_g: dg1,
            ln1_b: db1,
            w_qkv: dw_qkv,
            b_qkv: db_qkv,
            w_proj: dw_proj,
            b_proj: db_proj,
            ln2_g: dg2,
            ln2_b: db2,
            w_fc1: dw_fc1,
            b_fc1: db_fc1,
            w_fc2: dw_fc2,
            b_fc2: db_fc2,
        },
    )
}

/// Full core forward: all blocks in sequence.
pub fn core_fwd(
    ep: &mut Endpoint,
    ops: &dyn ParallelOps,
    blocks: &[BlockTensors],
    x: &Tensor,
    cfg: &ModelConfig,
) -> (Tensor, Vec<BlockCache>) {
    let mut cur = x.clone();
    let mut caches = Vec::with_capacity(blocks.len());
    for p in blocks {
        let (y, cache) = block_fwd(ep, ops, p, &cur, cfg);
        caches.push(cache);
        cur = y;
    }
    (cur, caches)
}

/// Full core backward: returns `(dx, per-block grads)`.
///
/// Weight-grad syncs issued by layer `L` (the hybrid replica all-reduces —
/// deferred collectives on the comm timeline) overlap layer `L−1`'s GEMMs:
/// after each block the finished tickets are retired with
/// [`Endpoint::drain_ready`] (pure bookkeeping, zero compute-clock cost),
/// and whatever is still in flight after the last block is the caller's to
/// join — [`crate::train`] and [`crate::engine`] call
/// [`Endpoint::join_all`] at the optimizer boundary.
pub fn core_bwd(
    ep: &mut Endpoint,
    ops: &dyn ParallelOps,
    blocks: &[BlockTensors],
    caches: &[BlockCache],
    dy: &Tensor,
    cfg: &ModelConfig,
) -> (Tensor, Vec<BlockTensors>) {
    assert_eq!(blocks.len(), caches.len());
    let mut grads = Vec::with_capacity(blocks.len());
    let mut cur = dy.clone();
    for (p, cache) in blocks.iter().zip(caches.iter()).rev() {
        let (dx, g) = block_bwd(ep, ops, p, cache, &cur, cfg);
        grads.push(g);
        cur = dx;
        ep.drain_ready();
    }
    grads.reverse();
    (cur, grads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::NetModel;
    use crate::dist::ShardSpec;
    use crate::model::{init_dense_blocks, DenseBlock};
    use crate::parallel::seq::Seq;
    use crate::rng::Xoshiro256;
    use crate::spmd::run_spmd;

    fn tiny() -> ModelConfig {
        ModelConfig::tiny()
    }

    fn randt(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        Tensor::randn(shape, 1.0, &mut rng)
    }

    #[test]
    fn forward_shapes_and_determinism() {
        let cfg = tiny();
        let dense = init_dense_blocks(&cfg, 1);
        let x = randt(&[cfg.batch * cfg.seq, cfg.hidden], 2);
        let x2 = x.clone();
        let p = dense[0].shard(&ShardSpec::seq());
        let p2 = p.clone();
        let y1 = run_spmd(1, NetModel::zero(), move |_, ep| {
            block_fwd(ep, &Seq::new(), &p, &x, &tiny()).0
        })
        .pop()
        .unwrap();
        let y2 = run_spmd(1, NetModel::zero(), move |_, ep| {
            block_fwd(ep, &Seq::new(), &p2, &x2, &tiny()).0
        })
        .pop()
        .unwrap();
        assert_eq!(y1.shape(), &[cfg.batch * cfg.seq, cfg.hidden]);
        assert_eq!(y1, y2);
    }

    #[test]
    fn backward_input_gradient_matches_numeric() {
        let mut cfg = tiny();
        cfg.seq = 4;
        cfg.batch = 1;
        cfg.hidden = 16;
        cfg.ffn = 32;
        cfg.heads = 2;
        cfg.layers = 1;
        let dense = DenseBlock::init(&cfg, &mut Xoshiro256::seed_from_u64(3));
        let x0 = randt(&[cfg.seq, cfg.hidden], 4);
        let dy0 = randt(&[cfg.seq, cfg.hidden], 5);

        let run_f = |xin: Tensor| -> Tensor {
            let p = dense.shard(&ShardSpec::seq());
            let cfg = cfg.clone();
            run_spmd(1, NetModel::zero(), move |_, ep| {
                block_fwd(ep, &Seq::new(), &p, &xin, &cfg).0
            })
            .pop()
            .unwrap()
        };
        let p = dense.shard(&ShardSpec::seq());
        let cfgc = cfg.clone();
        let x = x0.clone();
        let dy = dy0.clone();
        let dx = run_spmd(1, NetModel::zero(), move |_, ep| {
            let ops = Seq::new();
            let (_, cache) = block_fwd(ep, &ops, &p, &x, &cfgc);
            block_bwd(ep, &ops, &p, &cache, &dy, &cfgc).0
        })
        .pop()
        .unwrap();

        let h = 5e-3f32;
        for idx in [0usize, 33, 63] {
            let mut xp = x0.clone();
            xp.data_mut()[idx] += h;
            let mut xm = x0.clone();
            xm.data_mut()[idx] -= h;
            let num = run_f(xp).sub(&run_f(xm)).scale(1.0 / (2.0 * h)).mul(&dy0).sum();
            let ana = dx.data()[idx];
            assert!(
                (num - ana).abs() < 5e-2 * (1.0 + ana.abs()),
                "idx {idx}: numeric {num} vs analytic {ana}"
            );
        }
    }

    #[test]
    fn backward_weight_gradient_matches_numeric() {
        let mut cfg = tiny();
        cfg.seq = 4;
        cfg.batch = 1;
        cfg.hidden = 8;
        cfg.ffn = 16;
        cfg.heads = 2;
        cfg.layers = 1;
        let dense = DenseBlock::init(&cfg, &mut Xoshiro256::seed_from_u64(6));
        let x0 = randt(&[cfg.seq, cfg.hidden], 7);
        let dy0 = randt(&[cfg.seq, cfg.hidden], 8);

        let p0 = dense.shard(&ShardSpec::seq());
        let cfgc = cfg.clone();
        let x = x0.clone();
        let dy = dy0.clone();
        let grads = run_spmd(1, NetModel::zero(), move |_, ep| {
            let ops = Seq::new();
            let (_, cache) = block_fwd(ep, &ops, &p0, &x, &cfgc);
            block_bwd(ep, &ops, &p0, &cache, &dy, &cfgc).1
        })
        .pop()
        .unwrap();

        // Perturb w_fc1[idx] and check dL = <grad, dW> numerically.
        let h = 5e-3f32;
        for idx in [0usize, 50, 127] {
            let run_with = |delta: f32| -> Tensor {
                let mut d2 = dense.clone();
                d2.w_fc1.data_mut()[idx] += delta;
                let p = d2.shard(&ShardSpec::seq());
                let x = x0.clone();
                let cfg = cfg.clone();
                run_spmd(1, NetModel::zero(), move |_, ep| {
                    block_fwd(ep, &Seq::new(), &p, &x, &cfg).0
                })
                .pop()
                .unwrap()
            };
            let num = run_with(h).sub(&run_with(-h)).scale(1.0 / (2.0 * h)).mul(&dy0).sum();
            let ana = grads.w_fc1.data()[idx];
            assert!(
                (num - ana).abs() < 5e-2 * (1.0 + ana.abs()),
                "w_fc1[{idx}]: numeric {num} vs analytic {ana}"
            );
        }
    }
}
