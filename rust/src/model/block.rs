//! The one transformer block — generic over parallelism.
//!
//! Pre-LN block `y = xa + fc2(gelu(fc1(ln2 xa)))`, `xa = x + proj(attn(ln1
//! x))`, written once against [`ParallelOps`]. Which collectives move the
//! shards — none (Seq), Megatron all-reduces (1-D), SUMMA broadcasts
//! (2-D), or the paper's gather/reduce-scatter lines (3-D) — is entirely
//! the trait implementation's business; this file only sequences the
//! layers and charges the env-independent memory passes (residual adds,
//! gelu). Layer staging is the [`Stage`] pairing: each residual branch is
//! one `Expand` then one `Reduce` linear, which returns the activation to
//! the block-entry layout so blocks stack under every parallelism.
//!
//! Attention is always rank-local (complete heads × complete sequences per
//! shard — see the weight conventions in [`crate::model`]), so it is the
//! same code for all four kinds too.

use super::{attention, BlockCache, BlockTensors};
use crate::comm::Endpoint;
use crate::config::ModelConfig;
use crate::dist::Stage;
use crate::ops::{gelu, gelu_backward};
use crate::parallel::ParallelOps;
use crate::tensor::Tensor;

/// One transformer block forward on this rank's shard.
///
/// Generic over `O: ParallelOps + ?Sized` (rather than taking `&dyn`
/// directly) so the trait's provided `serve_*` methods can pass `self`
/// through — `dyn ParallelOps` still satisfies the bound, so `&dyn` callers
/// are unchanged.
pub fn block_fwd<O: ParallelOps + ?Sized>(
    ep: &mut Endpoint,
    ops: &O,
    p: &BlockTensors,
    x: &Tensor,
    cfg: &ModelConfig,
) -> (Tensor, BlockCache) {
    let hd = cfg.hidden / cfg.heads;
    let local_heads = ops.local_heads(cfg);

    let (ln1, xhat1, istd1) =
        ops.layernorm(ep, x, p.ln1_g.as_ref(), p.ln1_b.as_ref(), cfg.eps, cfg.hidden);

    // Attention branch: Expand (QKV) → rank-local attention → Reduce (proj).
    let qkv = ops.linear_fwd(ep, &ln1, &p.w_qkv, p.b_qkv.as_ref(), Stage::Expand);
    let (attn_out, attn) = attention::fwd(ep, &qkv, local_heads, hd, cfg.seq);
    let proj = ops.linear_fwd(ep, &attn_out, &p.w_proj, p.b_proj.as_ref(), Stage::Reduce);
    let xa = x.add(&proj);
    ep.charge_memop(2.0 * x.nominal_bytes() as f64);

    let (ln2, xhat2, istd2) =
        ops.layernorm(ep, &xa, p.ln2_g.as_ref(), p.ln2_b.as_ref(), cfg.eps, cfg.hidden);

    // MLP branch: Expand (fc1) → local gelu → Reduce (fc2).
    let fc1_pre = ops.linear_fwd(ep, &ln2, &p.w_fc1, p.b_fc1.as_ref(), Stage::Expand);
    let fc1_act = gelu(&fc1_pre);
    ep.charge_memop(2.0 * fc1_pre.nominal_bytes() as f64);
    let fc2 = ops.linear_fwd(ep, &fc1_act, &p.w_fc2, p.b_fc2.as_ref(), Stage::Reduce);
    let y = xa.add(&fc2);
    ep.charge_memop(2.0 * x.nominal_bytes() as f64);

    (
        y,
        BlockCache {
            x: x.clone(),
            xhat1,
            istd1,
            ln1,
            attn,
            attn_out,
            xa,
            xhat2,
            istd2,
            ln2,
            fc1_pre,
            fc1_act,
        },
    )
}

/// Prefill: one block forward over the padded prompt batch, harvesting the
/// K/V rows into `kv` and **dropping every backward stash**. The forward is
/// [`block_fwd`] verbatim (`cfg.seq` must equal the padded prompt length),
/// so prefill activations are bitwise identical to training forward — the
/// serve-parity pin rests on that. Ragged prompts: slot `s` holds `lens[s]`
/// real tokens; padded rows are computed (causality keeps them out of every
/// real row) but never cached.
pub fn prefill_block_fwd<O: ParallelOps + ?Sized>(
    ep: &mut Endpoint,
    ops: &O,
    p: &BlockTensors,
    x: &Tensor,
    cfg: &ModelConfig,
    kv: &mut attention::DecodeKv,
    lens: &[usize],
) -> Tensor {
    let (y, cache) = block_fwd(ep, ops, p, x, cfg);
    kv.harvest(&cache.attn.qkv, cfg.seq, lens);
    // `cache` (probs, qkv, layernorm stats, activations) drops here:
    // inference retains only the KV rows just harvested.
    y
}

/// One decode step through a block: one new token per local slot
/// (`x: (slots_local, hidden_local)` in block-entry layout). Mirrors
/// [`block_fwd`]'s float-op and charge sequence exactly — same layernorms,
/// same `Expand`/`Reduce` linear pairing, same residual/gelu memops — with
/// [`attention::decode_fwd`] over the KV cache in place of the full
/// attention, and **no cache retained** beyond the appended K/V rows.
pub fn decode_block_fwd<O: ParallelOps + ?Sized>(
    ep: &mut Endpoint,
    ops: &O,
    p: &BlockTensors,
    x: &Tensor,
    cfg: &ModelConfig,
    kv: &mut attention::DecodeKv,
) -> Tensor {
    let hd = cfg.hidden / cfg.heads;
    let local_heads = ops.local_heads(cfg);

    let (ln1, _xhat1, _istd1) =
        ops.layernorm(ep, x, p.ln1_g.as_ref(), p.ln1_b.as_ref(), cfg.eps, cfg.hidden);

    let qkv = ops.linear_fwd(ep, &ln1, &p.w_qkv, p.b_qkv.as_ref(), Stage::Expand);
    let attn_out = attention::decode_fwd(ep, &qkv, local_heads, hd, kv);
    let proj = ops.linear_fwd(ep, &attn_out, &p.w_proj, p.b_proj.as_ref(), Stage::Reduce);
    let xa = x.add(&proj);
    ep.charge_memop(2.0 * x.nominal_bytes() as f64);

    let (ln2, _xhat2, _istd2) =
        ops.layernorm(ep, &xa, p.ln2_g.as_ref(), p.ln2_b.as_ref(), cfg.eps, cfg.hidden);

    let fc1_pre = ops.linear_fwd(ep, &ln2, &p.w_fc1, p.b_fc1.as_ref(), Stage::Expand);
    let fc1_act = gelu(&fc1_pre);
    ep.charge_memop(2.0 * fc1_pre.nominal_bytes() as f64);
    let fc2 = ops.linear_fwd(ep, &fc1_act, &p.w_fc2, p.b_fc2.as_ref(), Stage::Reduce);
    let y = xa.add(&fc2);
    ep.charge_memop(2.0 * x.nominal_bytes() as f64);
    y
}

/// Block backward; returns `(dx, grads)`. Vector gradients come back with
/// exactly the ownership pattern of the parameters (`Option` per rank), so
/// the optimizer pairing is parallelism-agnostic too.
pub fn block_bwd(
    ep: &mut Endpoint,
    ops: &dyn ParallelOps,
    p: &BlockTensors,
    cache: &BlockCache,
    dy: &Tensor,
    cfg: &ModelConfig,
) -> (Tensor, BlockTensors) {
    // y = xa + fc2(gelu(fc1(ln2(xa)))): both residual branches get dy.
    let (d_fc1act, dw_fc2, db_fc2) =
        ops.linear_bwd(ep, dy, &cache.fc1_act, &p.w_fc2, Stage::Reduce);
    let d_fc1pre = gelu_backward(&d_fc1act, &cache.fc1_pre);
    ep.charge_memop(3.0 * d_fc1act.nominal_bytes() as f64);
    let (d_ln2, dw_fc1, db_fc1) =
        ops.linear_bwd(ep, &d_fc1pre, &cache.ln2, &p.w_fc1, Stage::Expand);

    let (d_xa_ln, dg2, db2) = ops.layernorm_backward(
        ep, &d_ln2, &cache.xhat2, &cache.istd2, p.ln2_g.as_ref(), cfg.hidden,
    );
    let dxa = dy.add(&d_xa_ln);
    ep.charge_memop(2.0 * dy.nominal_bytes() as f64);

    // xa = x + proj(attn): both branches get dxa.
    let (d_attn, dw_proj, db_proj) =
        ops.linear_bwd(ep, &dxa, &cache.attn_out, &p.w_proj, Stage::Reduce);
    let d_qkv = attention::bwd(ep, &d_attn, &cache.attn);
    let (d_ln1, dw_qkv, db_qkv) = ops.linear_bwd(ep, &d_qkv, &cache.ln1, &p.w_qkv, Stage::Expand);

    let (dx_ln, dg1, db1) = ops.layernorm_backward(
        ep, &d_ln1, &cache.xhat1, &cache.istd1, p.ln1_g.as_ref(), cfg.hidden,
    );
    let dx = dxa.add(&dx_ln);
    ep.charge_memop(2.0 * dy.nominal_bytes() as f64);

    (
        dx,
        BlockTensors {
            ln1_g: dg1,
            ln1_b: db1,
            w_qkv: dw_qkv,
            b_qkv: db_qkv,
            w_proj: dw_proj,
            b_proj: db_proj,
            ln2_g: dg2,
            ln2_b: db2,
            w_fc1: dw_fc1,
            b_fc1: db_fc1,
            w_fc2: dw_fc2,
            b_fc2: db_fc2,
        },
    )
}

/// Per-layer gradients stashed by [`block_bwd_dx`] for a later
/// [`block_wgrad`] — every upstream gradient that feeds a weight, bias, or
/// layernorm-parameter gradient. In the 1F1B schedule one of these is kept
/// per (layer, micro-batch); the weight-grad flush concatenates them over
/// micro-batches so the GEMMs see full-batch rows.
pub struct BlockBwdStash {
    /// Block output gradient (`dy` of fc2's `Reduce` linear).
    pub dy: Tensor,
    /// Gelu-adjusted gradient (`dy` of fc1's `Expand` linear).
    pub d_fc1pre: Tensor,
    /// Gradient into ln2's output (feeds `dγ₂`/`dβ₂`).
    pub d_ln2: Tensor,
    /// Residual-joined gradient at `xa` (`dy` of proj's `Reduce` linear).
    pub dxa: Tensor,
    /// Gradient into the QKV projection (`dy` of qkv's `Expand` linear).
    pub d_qkv: Tensor,
    /// Gradient into ln1's output (feeds `dγ₁`/`dβ₁`).
    pub d_ln1: Tensor,
}

/// The forward activations [`block_wgrad`] multiplies against — the same
/// fields a [`BlockCache`] holds, minus everything only the `dx` pass
/// needs. Built by the caller from (possibly concatenated) caches.
pub struct WgradActs {
    pub ln1: Tensor,
    pub xhat1: Tensor,
    pub attn_out: Tensor,
    pub ln2: Tensor,
    pub xhat2: Tensor,
    pub fc1_act: Tensor,
}

impl WgradActs {
    /// The wgrad view of a single forward cache.
    pub fn from_cache(c: &BlockCache) -> WgradActs {
        WgradActs {
            ln1: c.ln1.clone(),
            xhat1: c.xhat1.clone(),
            attn_out: c.attn_out.clone(),
            ln2: c.ln2.clone(),
            xhat2: c.xhat2.clone(),
            fc1_act: c.fc1_act.clone(),
        }
    }

    /// Row-concatenate the wgrad views of several caches (micro-batch
    /// order). Feeding the concatenation to [`block_wgrad`] makes the
    /// weight-grad GEMMs bit-identical to an unpipelined full-batch
    /// backward, because GEMM rows accumulate independently.
    pub fn concat(caches: &[&BlockCache]) -> WgradActs {
        fn cat(parts: Vec<Tensor>) -> Tensor {
            Tensor::concat_rows(&parts)
        }
        WgradActs {
            ln1: cat(caches.iter().map(|c| c.ln1.clone()).collect()),
            xhat1: cat(caches.iter().map(|c| c.xhat1.clone()).collect()),
            attn_out: cat(caches.iter().map(|c| c.attn_out.clone()).collect()),
            ln2: cat(caches.iter().map(|c| c.ln2.clone()).collect()),
            xhat2: cat(caches.iter().map(|c| c.xhat2.clone()).collect()),
            fc1_act: cat(caches.iter().map(|c| c.fc1_act.clone()).collect()),
        }
    }
}

impl BlockBwdStash {
    /// Row-concatenate several stashes (micro-batch order) — the gradient
    /// side of [`WgradActs::concat`].
    pub fn concat(stashes: &[BlockBwdStash]) -> BlockBwdStash {
        fn cat(parts: Vec<Tensor>) -> Tensor {
            Tensor::concat_rows(&parts)
        }
        BlockBwdStash {
            dy: cat(stashes.iter().map(|s| s.dy.clone()).collect()),
            d_fc1pre: cat(stashes.iter().map(|s| s.d_fc1pre.clone()).collect()),
            d_ln2: cat(stashes.iter().map(|s| s.d_ln2.clone()).collect()),
            dxa: cat(stashes.iter().map(|s| s.dxa.clone()).collect()),
            d_qkv: cat(stashes.iter().map(|s| s.d_qkv.clone()).collect()),
            d_ln1: cat(stashes.iter().map(|s| s.d_ln1.clone()).collect()),
        }
    }
}

/// The input-gradient half of [`block_bwd`]: the same `dx` cascade with the
/// same float operations and memory charges, but no weight-gradient GEMMs —
/// those run later from the returned stash via [`block_wgrad`]. This is the
/// per-micro-batch backward of the pipeline schedule: `dx` must flow to the
/// previous stage immediately, weight grads can wait for the flush.
pub fn block_bwd_dx(
    ep: &mut Endpoint,
    ops: &dyn ParallelOps,
    p: &BlockTensors,
    cache: &BlockCache,
    dy: &Tensor,
    cfg: &ModelConfig,
) -> (Tensor, BlockBwdStash) {
    // y = xa + fc2(gelu(fc1(ln2(xa)))): both residual branches get dy.
    let d_fc1act = ops.linear_bwd_dx(ep, dy, &p.w_fc2, Stage::Reduce);
    let d_fc1pre = gelu_backward(&d_fc1act, &cache.fc1_pre);
    ep.charge_memop(3.0 * d_fc1act.nominal_bytes() as f64);
    let d_ln2 = ops.linear_bwd_dx(ep, &d_fc1pre, &p.w_fc1, Stage::Expand);

    let d_xa_ln = ops.layernorm_backward_dx(
        ep, &d_ln2, &cache.xhat2, &cache.istd2, p.ln2_g.as_ref(), cfg.hidden,
    );
    let dxa = dy.add(&d_xa_ln);
    ep.charge_memop(2.0 * dy.nominal_bytes() as f64);

    // xa = x + proj(attn): both branches get dxa.
    let d_attn = ops.linear_bwd_dx(ep, &dxa, &p.w_proj, Stage::Reduce);
    let d_qkv = attention::bwd(ep, &d_attn, &cache.attn);
    let d_ln1 = ops.linear_bwd_dx(ep, &d_qkv, &p.w_qkv, Stage::Expand);

    let dx_ln = ops.layernorm_backward_dx(
        ep, &d_ln1, &cache.xhat1, &cache.istd1, p.ln1_g.as_ref(), cfg.hidden,
    );
    let dx = dxa.add(&dx_ln);
    ep.charge_memop(2.0 * dy.nominal_bytes() as f64);

    (
        dx,
        BlockBwdStash {
            dy: dy.clone(),
            d_fc1pre,
            d_ln2,
            dxa,
            d_qkv,
            d_ln1,
        },
    )
}

/// The weight-gradient half of [`block_bwd`], run from a stash (possibly
/// micro-batch-concatenated) and the matching forward activations. The
/// pairings and their order mirror `block_bwd` exactly: fc2, fc1, ln2,
/// proj, qkv, ln1. Because every gradient here is a row-wise GEMM, a
/// column sum, or an `xhat`-weighted column sum, running it once on
/// concatenated micro-batch rows is bit-identical to the unpipelined
/// full-batch backward.
pub fn block_wgrad(
    ep: &mut Endpoint,
    ops: &dyn ParallelOps,
    stash: &BlockBwdStash,
    acts: &WgradActs,
) -> BlockTensors {
    let (dw_fc2, db_fc2) = ops.linear_bwd_dw(ep, &stash.dy, &acts.fc1_act, Stage::Reduce);
    let (dw_fc1, db_fc1) = ops.linear_bwd_dw(ep, &stash.d_fc1pre, &acts.ln2, Stage::Expand);
    let (dg2, db2) = ops.layernorm_param_grads(ep, &stash.d_ln2, &acts.xhat2);
    let (dw_proj, db_proj) = ops.linear_bwd_dw(ep, &stash.dxa, &acts.attn_out, Stage::Reduce);
    let (dw_qkv, db_qkv) = ops.linear_bwd_dw(ep, &stash.d_qkv, &acts.ln1, Stage::Expand);
    let (dg1, db1) = ops.layernorm_param_grads(ep, &stash.d_ln1, &acts.xhat1);
    BlockTensors {
        ln1_g: dg1,
        ln1_b: db1,
        w_qkv: dw_qkv,
        b_qkv: db_qkv,
        w_proj: dw_proj,
        b_proj: db_proj,
        ln2_g: dg2,
        ln2_b: db2,
        w_fc1: dw_fc1,
        b_fc1: db_fc1,
        w_fc2: dw_fc2,
        b_fc2: db_fc2,
    }
}

/// Full core forward: all blocks in sequence.
pub fn core_fwd(
    ep: &mut Endpoint,
    ops: &dyn ParallelOps,
    blocks: &[BlockTensors],
    x: &Tensor,
    cfg: &ModelConfig,
) -> (Tensor, Vec<BlockCache>) {
    let mut cur = x.clone();
    let mut caches = Vec::with_capacity(blocks.len());
    for p in blocks {
        let (y, cache) = block_fwd(ep, ops, p, &cur, cfg);
        caches.push(cache);
        cur = y;
    }
    (cur, caches)
}

/// Full core backward: returns `(dx, per-block grads)`.
///
/// Weight-grad syncs issued by layer `L` (the hybrid replica all-reduces —
/// deferred collectives on the comm timeline) overlap layer `L−1`'s GEMMs:
/// after each block the finished tickets are retired with
/// [`Endpoint::drain_ready`] (pure bookkeeping, zero compute-clock cost),
/// and whatever is still in flight after the last block is the caller's to
/// join — [`crate::train`] and [`crate::engine`] call
/// [`Endpoint::join_all`] at the optimizer boundary.
pub fn core_bwd(
    ep: &mut Endpoint,
    ops: &dyn ParallelOps,
    blocks: &[BlockTensors],
    caches: &[BlockCache],
    dy: &Tensor,
    cfg: &ModelConfig,
) -> (Tensor, Vec<BlockTensors>) {
    assert_eq!(blocks.len(), caches.len());
    let mut grads = Vec::with_capacity(blocks.len());
    let mut cur = dy.clone();
    for (p, cache) in blocks.iter().zip(caches.iter()).rev() {
        let (dx, g) = block_bwd(ep, ops, p, cache, &cur, cfg);
        grads.push(g);
        cur = dx;
        ep.drain_ready();
    }
    grads.reverse();
    (cur, grads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::NetModel;
    use crate::dist::ShardSpec;
    use crate::model::{init_dense_blocks, DenseBlock};
    use crate::parallel::seq::Seq;
    use crate::rng::Xoshiro256;
    use crate::spmd::run_spmd;

    fn tiny() -> ModelConfig {
        ModelConfig::tiny()
    }

    fn randt(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        Tensor::randn(shape, 1.0, &mut rng)
    }

    #[test]
    fn forward_shapes_and_determinism() {
        let cfg = tiny();
        let dense = init_dense_blocks(&cfg, 1);
        let x = randt(&[cfg.batch * cfg.seq, cfg.hidden], 2);
        let x2 = x.clone();
        let p = dense[0].shard(&ShardSpec::seq());
        let p2 = p.clone();
        let y1 = run_spmd(1, NetModel::zero(), move |_, ep| {
            block_fwd(ep, &Seq::new(), &p, &x, &tiny()).0
        })
        .pop()
        .unwrap();
        let y2 = run_spmd(1, NetModel::zero(), move |_, ep| {
            block_fwd(ep, &Seq::new(), &p2, &x2, &tiny()).0
        })
        .pop()
        .unwrap();
        assert_eq!(y1.shape(), &[cfg.batch * cfg.seq, cfg.hidden]);
        assert_eq!(y1, y2);
    }

    #[test]
    fn backward_input_gradient_matches_numeric() {
        let mut cfg = tiny();
        cfg.seq = 4;
        cfg.batch = 1;
        cfg.hidden = 16;
        cfg.ffn = 32;
        cfg.heads = 2;
        cfg.layers = 1;
        let dense = DenseBlock::init(&cfg, &mut Xoshiro256::seed_from_u64(3));
        let x0 = randt(&[cfg.seq, cfg.hidden], 4);
        let dy0 = randt(&[cfg.seq, cfg.hidden], 5);

        let run_f = |xin: Tensor| -> Tensor {
            let p = dense.shard(&ShardSpec::seq());
            let cfg = cfg.clone();
            run_spmd(1, NetModel::zero(), move |_, ep| {
                block_fwd(ep, &Seq::new(), &p, &xin, &cfg).0
            })
            .pop()
            .unwrap()
        };
        let p = dense.shard(&ShardSpec::seq());
        let cfgc = cfg.clone();
        let x = x0.clone();
        let dy = dy0.clone();
        let dx = run_spmd(1, NetModel::zero(), move |_, ep| {
            let ops = Seq::new();
            let (_, cache) = block_fwd(ep, &ops, &p, &x, &cfgc);
            block_bwd(ep, &ops, &p, &cache, &dy, &cfgc).0
        })
        .pop()
        .unwrap();

        let h = 5e-3f32;
        for idx in [0usize, 33, 63] {
            let mut xp = x0.clone();
            xp.data_mut()[idx] += h;
            let mut xm = x0.clone();
            xm.data_mut()[idx] -= h;
            let num = run_f(xp).sub(&run_f(xm)).scale(1.0 / (2.0 * h)).mul(&dy0).sum();
            let ana = dx.data()[idx];
            assert!(
                (num - ana).abs() < 5e-2 * (1.0 + ana.abs()),
                "idx {idx}: numeric {num} vs analytic {ana}"
            );
        }
    }

    fn assert_grads_eq(a: &BlockTensors, b: &BlockTensors) {
        assert_eq!(a.w_qkv, b.w_qkv, "w_qkv");
        assert_eq!(a.b_qkv, b.b_qkv, "b_qkv");
        assert_eq!(a.w_proj, b.w_proj, "w_proj");
        assert_eq!(a.b_proj, b.b_proj, "b_proj");
        assert_eq!(a.w_fc1, b.w_fc1, "w_fc1");
        assert_eq!(a.b_fc1, b.b_fc1, "b_fc1");
        assert_eq!(a.w_fc2, b.w_fc2, "w_fc2");
        assert_eq!(a.b_fc2, b.b_fc2, "b_fc2");
        assert_eq!(a.ln1_g, b.ln1_g, "ln1_g");
        assert_eq!(a.ln1_b, b.ln1_b, "ln1_b");
        assert_eq!(a.ln2_g, b.ln2_g, "ln2_g");
        assert_eq!(a.ln2_b, b.ln2_b, "ln2_b");
    }

    #[test]
    fn split_backward_matches_joint_bitwise() {
        // block_bwd_dx + block_wgrad on the same cache must reproduce
        // block_bwd bit-for-bit — the split is a reordering, not a
        // reformulation.
        let cfg = tiny();
        let dense = init_dense_blocks(&cfg, 9);
        let x = randt(&[cfg.batch * cfg.seq, cfg.hidden], 10);
        let dy = randt(&[cfg.batch * cfg.seq, cfg.hidden], 11);
        let p = dense[0].shard(&ShardSpec::seq());
        let (p2, x2, dy2, cfg2) = (p.clone(), x.clone(), dy.clone(), cfg.clone());
        let (dx_joint, g_joint) = run_spmd(1, NetModel::zero(), move |_, ep| {
            let ops = Seq::new();
            let (_, cache) = block_fwd(ep, &ops, &p2, &x2, &cfg2);
            block_bwd(ep, &ops, &p2, &cache, &dy2, &cfg2)
        })
        .pop()
        .unwrap();
        let cfg2 = cfg.clone();
        let (dx_split, g_split) = run_spmd(1, NetModel::zero(), move |_, ep| {
            let ops = Seq::new();
            let (_, cache) = block_fwd(ep, &ops, &p, &x, &cfg2);
            let (dx, stash) = block_bwd_dx(ep, &ops, &p, &cache, &dy, &cfg2);
            let g = block_wgrad(ep, &ops, &stash, &WgradActs::from_cache(&cache));
            (dx, g)
        })
        .pop()
        .unwrap();
        assert_eq!(dx_joint, dx_split, "dx");
        assert_grads_eq(&g_joint, &g_split);
    }

    #[test]
    fn microbatched_wgrad_matches_full_batch_bitwise() {
        // Forward/backward-dx each micro-batch separately, then one wgrad
        // on the concatenated stashes/activations: weight grads must equal
        // the unpipelined full-batch backward bit-for-bit (rows of a GEMM
        // accumulate independently; column sums are per-column).
        let mut cfg = tiny();
        cfg.batch = 2; // two micro-batches of one sequence each
        let dense = init_dense_blocks(&cfg, 12);
        let rows = cfg.batch * cfg.seq;
        let x = randt(&[rows, cfg.hidden], 13);
        let dy = randt(&[rows, cfg.hidden], 14);
        let p = dense[0].shard(&ShardSpec::seq());
        let (p2, x2, dy2, cfg2) = (p.clone(), x.clone(), dy.clone(), cfg.clone());
        let g_full = run_spmd(1, NetModel::zero(), move |_, ep| {
            let ops = Seq::new();
            let (_, cache) = block_fwd(ep, &ops, &p2, &x2, &cfg2);
            block_bwd(ep, &ops, &p2, &cache, &dy2, &cfg2).1
        })
        .pop()
        .unwrap();
        let g_mb = run_spmd(1, NetModel::zero(), move |_, ep| {
            let ops = Seq::new();
            let half = rows / 2;
            let mut caches = Vec::new();
            let mut stashes = Vec::new();
            let mut mb_cfg = cfg.clone();
            mb_cfg.batch = 1;
            for u in 0..2 {
                let xu = x.block(u * half, 0, half, cfg.hidden).compact();
                let dyu = dy.block(u * half, 0, half, cfg.hidden).compact();
                let (_, cache) = block_fwd(ep, &ops, &p, &xu, &mb_cfg);
                let (_, stash) = block_bwd_dx(ep, &ops, &p, &cache, &dyu, &mb_cfg);
                caches.push(cache);
                stashes.push(stash);
            }
            let acts = WgradActs::concat(&caches.iter().collect::<Vec<_>>());
            let stash = BlockBwdStash::concat(&stashes);
            block_wgrad(ep, &ops, &stash, &acts)
        })
        .pop()
        .unwrap();
        assert_grads_eq(&g_full, &g_mb);
    }

    #[test]
    fn backward_weight_gradient_matches_numeric() {
        let mut cfg = tiny();
        cfg.seq = 4;
        cfg.batch = 1;
        cfg.hidden = 8;
        cfg.ffn = 16;
        cfg.heads = 2;
        cfg.layers = 1;
        let dense = DenseBlock::init(&cfg, &mut Xoshiro256::seed_from_u64(6));
        let x0 = randt(&[cfg.seq, cfg.hidden], 7);
        let dy0 = randt(&[cfg.seq, cfg.hidden], 8);

        let p0 = dense.shard(&ShardSpec::seq());
        let cfgc = cfg.clone();
        let x = x0.clone();
        let dy = dy0.clone();
        let grads = run_spmd(1, NetModel::zero(), move |_, ep| {
            let ops = Seq::new();
            let (_, cache) = block_fwd(ep, &ops, &p0, &x, &cfgc);
            block_bwd(ep, &ops, &p0, &cache, &dy, &cfgc).1
        })
        .pop()
        .unwrap();

        // Perturb w_fc1[idx] and check dL = <grad, dW> numerically.
        let h = 5e-3f32;
        for idx in [0usize, 50, 127] {
            let run_with = |delta: f32| -> Tensor {
                let mut d2 = dense.clone();
                d2.w_fc1.data_mut()[idx] += delta;
                let p = d2.shard(&ShardSpec::seq());
                let x = x0.clone();
                let cfg = cfg.clone();
                run_spmd(1, NetModel::zero(), move |_, ep| {
                    block_fwd(ep, &Seq::new(), &p, &x, &cfg).0
                })
                .pop()
                .unwrap()
            };
            let num = run_with(h).sub(&run_with(-h)).scale(1.0 / (2.0 * h)).mul(&dy0).sum();
            let ana = grads.w_fc1.data()[idx];
            assert!(
                (num - ana).abs() < 5e-2 * (1.0 + ana.abs()),
                "w_fc1[{idx}]: numeric {num} vs analytic {ana}"
            );
        }
    }
}
