//! 2-D (Optimus/SUMMA) transformer block: everything block-distributed on
//! the `q × q` mesh; linears run as SUMMA, layernorm all-reduces row stats
//! across mesh rows, attention is rank-local (complete heads × complete
//! sequences per block).

use super::{attention, BlockCache, BlockTensors};
use crate::comm::Endpoint;
use crate::config::ModelConfig;
use crate::ops;
use crate::parallel::twod::{layernorm, layernorm_backward, linear_bwd, linear_fwd, Ctx2D};
use crate::tensor::Tensor;

pub fn block_fwd(
    ep: &mut Endpoint,
    ctx: &Ctx2D,
    p: &BlockTensors,
    x: &Tensor,
    cfg: &ModelConfig,
) -> (Tensor, BlockCache) {
    let hd = cfg.hidden / cfg.heads;
    let local_heads = cfg.heads / ctx.q();
    let (ln1, xhat1, istd1) = layernorm(
        ep, ctx, x, p.ln1_g.as_ref(), p.ln1_b.as_ref(), cfg.eps, cfg.hidden,
    );

    let qkv = linear_fwd(ep, ctx, &ln1, &p.w_qkv, p.b_qkv.as_ref(), true);
    let (attn_out, attn) = attention::fwd(ep, &qkv, local_heads, hd, cfg.seq);

    let proj = linear_fwd(ep, ctx, &attn_out, &p.w_proj, p.b_proj.as_ref(), true);
    let xa = x.add(&proj);
    ep.charge_memop(2.0 * x.nominal_bytes() as f64);

    let (ln2, xhat2, istd2) = layernorm(
        ep, ctx, &xa, p.ln2_g.as_ref(), p.ln2_b.as_ref(), cfg.eps, cfg.hidden,
    );

    let fc1_pre = linear_fwd(ep, ctx, &ln2, &p.w_fc1, p.b_fc1.as_ref(), true);
    let fc1_act = ops::gelu(&fc1_pre);
    ep.charge_memop(2.0 * fc1_pre.nominal_bytes() as f64);

    let fc2 = linear_fwd(ep, ctx, &fc1_act, &p.w_fc2, p.b_fc2.as_ref(), true);
    let y = xa.add(&fc2);
    ep.charge_memop(2.0 * x.nominal_bytes() as f64);

    (
        y,
        BlockCache {
            x: x.clone(),
            xhat1,
            istd1,
            ln1,
            attn,
            attn_out,
            xa,
            xhat2,
            istd2,
            ln2,
            fc1_pre,
            fc1_act,
        },
    )
}

pub fn block_bwd(
    ep: &mut Endpoint,
    ctx: &Ctx2D,
    p: &BlockTensors,
    cache: &BlockCache,
    dy: &Tensor,
    cfg: &ModelConfig,
) -> (Tensor, BlockTensors) {
    let (d_fc1act, dw_fc2, db_fc2) = linear_bwd(ep, ctx, dy, &cache.fc1_act, &p.w_fc2);
    let d_fc1pre = ops::gelu_backward(&d_fc1act, &cache.fc1_pre);
    ep.charge_memop(3.0 * d_fc1act.nominal_bytes() as f64);
    let (d_ln2, dw_fc1, db_fc1) = linear_bwd(ep, ctx, &d_fc1pre, &cache.ln2, &p.w_fc1);

    let (d_xa_ln, dg2, db2) = layernorm_backward(
        ep, ctx, &d_ln2, &cache.xhat2, &cache.istd2, p.ln2_g.as_ref(), cfg.eps, cfg.hidden,
    );
    let dxa = dy.add(&d_xa_ln);
    ep.charge_memop(2.0 * dy.nominal_bytes() as f64);

    let (d_attn, dw_proj, db_proj) = linear_bwd(ep, ctx, &dxa, &cache.attn_out, &p.w_proj);
    let d_qkv = attention::bwd(ep, &d_attn, &cache.attn);
    let (d_ln1, dw_qkv, db_qkv) = linear_bwd(ep, ctx, &d_qkv, &cache.ln1, &p.w_qkv);

    let (dx_ln, dg1, db1) = layernorm_backward(
        ep, ctx, &d_ln1, &cache.xhat1, &cache.istd1, p.ln1_g.as_ref(), cfg.eps, cfg.hidden,
    );
    let dx = dxa.add(&dx_ln);
    ep.charge_memop(2.0 * dy.nominal_bytes() as f64);

    (
        dx,
        BlockTensors {
            ln1_g: dg1,
            ln1_b: db1,
            w_qkv: dw_qkv,
            b_qkv: db_qkv,
            w_proj: dw_proj,
            b_proj: db_proj,
            ln2_g: dg2,
            ln2_b: db2,
            w_fc1: dw_fc1,
            b_fc1: db_fc1,
            w_fc2: dw_fc2,
            b_fc2: db_fc2,
        },
    )
}
