//! Per-endpoint recycling buffer pool.
//!
//! The collectives in [`crate::collectives`] need exactly two scratch
//! buffers per steady-state all-reduce on each rank: the reduce-scatter
//! accumulator (one chunk) and the all-gather output assembly (the full
//! payload). Before PR 2 both were fresh heap allocations per call; this
//! pool recycles them, so after one warmup iteration the hot loop performs
//! **zero** f32-buffer allocations (asserted per-endpoint by the
//! collectives tests and exactly, process-wide, by the microbench).
//!
//! Mechanics: [`BufferPool::take`] hands out a `Vec<f32>` from the shared
//! [`FreeList`], best-fit by capacity (smallest buffer that holds the
//! request, so a chunk-sized request cannot poach the full-payload buffer
//! and force it to reallocate). Tensors built over pooled buffers
//! ([`Tensor::from_pooled`]) push the buffer back onto the free list when
//! their *last* handle drops — which for ring collectives is routinely on a
//! different rank's thread, hence the `Arc<Mutex<..>>` free list rather
//! than a thread-local. A `Weak` back-reference keeps a retired endpoint
//! from leaking buffers: reclaim is a no-op once the pool is gone.
//!
//! Scope of the zero-allocation claim: the pool tracks the f32 *data*
//! buffers (the ones proportional to payload size). Small control
//! allocations — shape `Vec<usize>`s, the per-call chunk-handle vector —
//! are O(group size) pointers and are not routed through the pool.

use crate::tensor::{FreeList, Tensor};
use std::sync::{Arc, Mutex};

/// A recycling pool of f32 buffers, owned by one [`super::Endpoint`].
pub struct BufferPool {
    free: FreeList,
}

/// What a [`BufferPool::take`] had to do to satisfy the request — the
/// endpoint turns this into `CommStats::pool_hits` / `pool_misses` and the
/// global counters in [`crate::metrics`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Takeout {
    /// Served from the free list: no heap allocation happened.
    Recycled,
    /// Free list had no buffer of sufficient capacity: fresh allocation.
    Allocated,
}

impl Default for BufferPool {
    fn default() -> Self {
        Self::new()
    }
}

impl BufferPool {
    pub fn new() -> Self {
        BufferPool { free: Arc::new(Mutex::new(Vec::new())) }
    }

    /// The shared free list pooled tensors return their buffers to.
    pub fn free_list(&self) -> &FreeList {
        &self.free
    }

    /// Buffers currently parked in the free list (diagnostics/tests).
    pub fn idle(&self) -> usize {
        self.free.lock().map(|q| q.len()).unwrap_or(0)
    }

    /// A buffer of exactly `n` elements. Best-fit from the free list when
    /// possible (`Takeout::Recycled`), freshly allocated otherwise.
    /// Recycled contents are unspecified beyond length `n` being zeroed on
    /// *growth* only — callers must overwrite every element they read.
    pub fn take(&self, n: usize) -> (Vec<f32>, Takeout) {
        let mut free = self.free.lock().expect("buffer pool poisoned");
        let mut best: Option<(usize, usize)> = None; // (index, capacity)
        for (i, b) in free.iter().enumerate() {
            let cap = b.capacity();
            let better = match best {
                None => cap >= n,
                Some((_, c)) => cap >= n && cap < c,
            };
            if better {
                best = Some((i, cap));
            }
        }
        match best {
            Some((i, _)) => {
                let mut v = free.swap_remove(i);
                // Within capacity: resize never reallocates here.
                v.resize(n, 0.0);
                (v, Takeout::Recycled)
            }
            None => (vec![0.0; n], Takeout::Allocated),
        }
    }

    /// Build a pooled tensor of `shape` over a [`BufferPool::take`] buffer.
    /// The buffer comes home to this pool on final drop.
    pub fn tensor(&self, shape: &[usize]) -> (Tensor, Takeout) {
        let n: usize = shape.iter().product();
        let (buf, how) = self.take(n);
        (Tensor::from_pooled(shape, buf, &self.free), how)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_allocates_then_recycles() {
        let pool = BufferPool::new();
        let (t, how) = pool.tensor(&[16]);
        assert_eq!(how, Takeout::Allocated);
        drop(t);
        assert_eq!(pool.idle(), 1);
        let (t2, how2) = pool.tensor(&[16]);
        assert_eq!(how2, Takeout::Recycled, "round trip must hit the pool");
        assert_eq!(t2.numel(), 16);
        assert_eq!(pool.idle(), 0);
    }

    #[test]
    fn best_fit_leaves_the_big_buffer_for_the_big_request() {
        let pool = BufferPool::new();
        let (small, _) = pool.tensor(&[8]);
        let (big, _) = pool.tensor(&[64]);
        drop(small);
        drop(big);
        assert_eq!(pool.idle(), 2);
        // A small request must take the 8-capacity buffer, not the 64.
        let (s, how) = pool.take(8);
        assert_eq!(how, Takeout::Recycled);
        assert!(s.capacity() < 64, "best fit must not poach the large buffer");
        let (b, how) = pool.take(64);
        assert_eq!(how, Takeout::Recycled);
        assert_eq!(b.len(), 64);
    }

    #[test]
    fn shrinking_reuse_keeps_capacity_for_later_growth() {
        let pool = BufferPool::new();
        let (t, _) = pool.tensor(&[64]);
        drop(t);
        let (small, how) = pool.take(8);
        assert_eq!(how, Takeout::Recycled);
        assert_eq!(small.len(), 8);
        assert!(small.capacity() >= 64, "capacity must survive shrink");
    }
}
