//! Per-endpoint recycling buffer pool.
//!
//! The collectives in [`crate::collectives`] need exactly two scratch
//! buffers per steady-state all-reduce on each rank: the reduce-scatter
//! accumulator (one chunk) and the all-gather output assembly (the full
//! payload). Before PR 2 both were fresh heap allocations per call; this
//! pool recycles them, so after one warmup iteration the hot loop performs
//! **zero** f32-buffer allocations (asserted per-endpoint by the
//! collectives tests and exactly, process-wide, by the microbench).
//!
//! Mechanics: [`BufferPool::take`] hands out a `Vec<f32>` best-fit by
//! capacity (smallest buffer that holds the request, so a chunk-sized
//! request cannot poach the full-payload buffer and force it to
//! reallocate). Returned buffers land on the shared [`FreeList`] (a flat
//! `Vec` — the type tensors reclaim to from any thread); `take` drains
//! that list into a private capacity-ordered index (`BTreeMap<capacity,
//! bucket>`) and answers best-fit queries from the index in O(log m)
//! instead of rescanning the whole free list per request — with many
//! collectives in flight the old linear scan rescanned every parked
//! buffer on every take. Tensors built over pooled buffers
//! ([`Tensor::from_pooled`]) push the buffer back onto the free list when
//! their *last* handle drops — which for ring collectives is routinely on a
//! different rank's thread, hence the `Arc<Mutex<..>>` free list rather
//! than a thread-local. A `Weak` back-reference keeps a retired endpoint
//! from leaking buffers: reclaim is a no-op once the pool is gone.
//!
//! Scope of the zero-allocation claim: the pool tracks the f32 *data*
//! buffers (the ones proportional to payload size). Small control
//! allocations — shape `Vec<usize>`s, the per-call chunk-handle vector —
//! are O(group size) pointers and are not routed through the pool.

use crate::tensor::{FreeList, Tensor};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// A recycling pool of f32 buffers, owned by one [`super::Endpoint`].
pub struct BufferPool {
    free: FreeList,
    /// Capacity-ordered view of the parked buffers, fed by draining
    /// `free`. Behind a (private, uncontended) mutex only because
    /// `take(&self)` works through the shared-endpoint borrow.
    index: Mutex<Index>,
}

/// Capacity-ordered buckets + a running count (for [`BufferPool::idle`]).
#[derive(Default)]
struct Index {
    by_cap: BTreeMap<usize, Vec<Vec<f32>>>,
    count: usize,
}

/// What a [`BufferPool::take`] had to do to satisfy the request — the
/// endpoint turns this into `CommStats::pool_hits` / `pool_misses` and the
/// global counters in [`crate::metrics`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Takeout {
    /// Served from the free list: no heap allocation happened.
    Recycled,
    /// Free list had no buffer of sufficient capacity: fresh allocation.
    Allocated,
}

impl Default for BufferPool {
    fn default() -> Self {
        Self::new()
    }
}

impl BufferPool {
    pub fn new() -> Self {
        BufferPool {
            free: Arc::new(Mutex::new(Vec::new())),
            index: Mutex::new(Index::default()),
        }
    }

    /// The shared free list pooled tensors return their buffers to.
    pub fn free_list(&self) -> &FreeList {
        &self.free
    }

    /// Buffers currently parked in the pool (diagnostics/tests): freshly
    /// returned ones still on the free list plus the indexed ones.
    pub fn idle(&self) -> usize {
        let returned = self.free.lock().map(|q| q.len()).unwrap_or(0);
        returned + self.index.lock().map(|ix| ix.count).unwrap_or(0)
    }

    /// A buffer of exactly `n` elements. Best-fit from the pool when
    /// possible (`Takeout::Recycled`), freshly allocated otherwise.
    /// Recycled contents are unspecified beyond length `n` being zeroed on
    /// *growth* only — callers must overwrite every element they read.
    pub fn take(&self, n: usize) -> (Vec<f32>, Takeout) {
        let mut ix = self.index.lock().expect("buffer pool poisoned");
        // Drain freshly returned buffers into the capacity index: O(1)
        // amortized per buffer lifecycle, so a take never rescans buffers
        // parked by earlier iterations.
        {
            let mut free = self.free.lock().expect("buffer pool poisoned");
            for b in free.drain(..) {
                ix.by_cap.entry(b.capacity()).or_default().push(b);
                ix.count += 1;
            }
        }
        // Best fit = smallest capacity >= n: one ordered-map seek.
        let cap = ix.by_cap.range(n..).next().map(|(&c, _)| c);
        match cap {
            Some(c) => {
                let bucket = ix.by_cap.get_mut(&c).expect("bucket vanished");
                let mut v = bucket.pop().expect("empty bucket in index");
                if bucket.is_empty() {
                    ix.by_cap.remove(&c);
                }
                ix.count -= 1;
                // Within capacity: resize never reallocates here.
                v.resize(n, 0.0);
                (v, Takeout::Recycled)
            }
            None => (vec![0.0; n], Takeout::Allocated),
        }
    }

    /// Build a pooled tensor of `shape` over a [`BufferPool::take`] buffer.
    /// The buffer comes home to this pool on final drop.
    pub fn tensor(&self, shape: &[usize]) -> (Tensor, Takeout) {
        let n: usize = shape.iter().product();
        let (buf, how) = self.take(n);
        (Tensor::from_pooled(shape, buf, &self.free), how)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_allocates_then_recycles() {
        let pool = BufferPool::new();
        let (t, how) = pool.tensor(&[16]);
        assert_eq!(how, Takeout::Allocated);
        drop(t);
        assert_eq!(pool.idle(), 1);
        let (t2, how2) = pool.tensor(&[16]);
        assert_eq!(how2, Takeout::Recycled, "round trip must hit the pool");
        assert_eq!(t2.numel(), 16);
        assert_eq!(pool.idle(), 0);
    }

    #[test]
    fn best_fit_leaves_the_big_buffer_for_the_big_request() {
        let pool = BufferPool::new();
        let (small, _) = pool.tensor(&[8]);
        let (big, _) = pool.tensor(&[64]);
        drop(small);
        drop(big);
        assert_eq!(pool.idle(), 2);
        // A small request must take the 8-capacity buffer, not the 64.
        let (s, how) = pool.take(8);
        assert_eq!(how, Takeout::Recycled);
        assert!(s.capacity() < 64, "best fit must not poach the large buffer");
        let (b, how) = pool.take(64);
        assert_eq!(how, Takeout::Recycled);
        assert_eq!(b.len(), 64);
    }

    #[test]
    fn capacity_index_serves_many_in_flight_sizes_best_fit() {
        // The many-in-flight-collectives shape: dozens of parked buffers
        // of distinct sizes. Every request must still recycle the exact
        // best-fit capacity (now via one ordered-map seek, not a scan).
        let pool = BufferPool::new();
        let handles: Vec<_> = (1..=32).map(|i| pool.tensor(&[i * 8]).0).collect();
        drop(handles);
        assert_eq!(pool.idle(), 32);
        for i in (1..=32).rev() {
            let (b, how) = pool.take(i * 8);
            assert_eq!(how, Takeout::Recycled);
            assert_eq!(b.capacity(), i * 8, "best fit must pick the exact size");
        }
        assert_eq!(pool.idle(), 0);
    }

    #[test]
    fn shrinking_reuse_keeps_capacity_for_later_growth() {
        let pool = BufferPool::new();
        let (t, _) = pool.tensor(&[64]);
        drop(t);
        let (small, how) = pool.take(8);
        assert_eq!(how, Takeout::Recycled);
        assert_eq!(small.len(), 8);
        assert!(small.capacity() >= 64, "capacity must survive shrink");
    }
}
