//! Deterministic fault injection for the virtual-clock transport.
//!
//! A [`FaultPlan`] installed on a [`crate::comm::World`] injects three
//! failure classes into every endpoint it hands out, all derived from a
//! seed so the same plan replays the same faults:
//!
//! * **Message drops** — each `(src, dst, tag)` delivery is preceded by a
//!   deterministic number of dropped attempts (a stateless hash over
//!   `(seed, generation, src, dst, tag, attempt)` thresholded against
//!   `drop_p`). The receiver pays one exponentially backed-off retry
//!   interval of *virtual* time per dropped attempt; `max_retries`
//!   consecutive drops surface as [`CommError::Timeout`].
//! * **Link delay (stragglers)** — extra per-hop latency on selected
//!   `(src, dst)` links, charged on the virtual clock exactly like
//!   `hop_cost`, so a slow rank shows up in the step-time ledger instead
//!   of being invisible.
//! * **Rank crashes** — a rank scheduled to crash at step `S` completes
//!   steps `< S`, broadcasts an obituary, and aborts. Crashes fire only in
//!   generation 0 (the first life of the world); recovered generations
//!   replay clean, which is what makes the recovery determinism pin
//!   testable.
//!
//! Faults are *clock-and-control-plane only*: payload data is never
//! corrupted, so any run that survives injection is bit-identical in its
//! numerics to the fault-free run — only clocks and the retry/timeout
//! counters differ. A world with no plan installed takes the exact legacy
//! code path (clock included).
//!
//! The `generation` salt exists so a deterministic plan cannot re-fail a
//! recovered run forever: after a restart the supervisor bumps the
//! generation, which reshuffles the drop pattern while staying fully
//! reproducible.

use std::fmt;

/// Reserved tag for death announcements. Obituaries bypass fault
/// injection, carry no payload bytes, and are processed by the receive
/// loop on arrival (never stashed).
pub const OBITUARY_TAG: u64 = u64::MAX;

/// Typed communication failure surfaced by the fallible receive path.
#[derive(Clone, Debug, PartialEq)]
pub enum CommError {
    /// The peer we are waiting on announced its death (crash or abort).
    PeerDead { rank: usize, peer: usize, tag: u64 },
    /// Delivery of `(src, tag)` exhausted its retry budget, or the
    /// wall-clock hang watchdog fired. `pending` lists the `(src, tag)`
    /// keys parked in the stash at the time — the mismatched-tag deadlock
    /// diagnosis.
    Timeout {
        rank: usize,
        src: usize,
        tag: u64,
        attempts: u32,
        pending: Vec<(usize, u64)>,
    },
    /// This rank was scheduled to crash at `step` by the fault plan.
    Crashed { rank: usize, step: usize },
    /// A checkpoint save failed with an IO error. The trainer state is
    /// still valid (the temp-file-then-rename protocol published nothing),
    /// so the supervisor can retry or re-point the directory.
    Checkpoint { rank: usize, msg: String },
}

impl fmt::Display for CommError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CommError::PeerDead { rank, peer, tag } => {
                write!(f, "rank {rank}: peer {peer} died while waiting for tag {tag:#x}")
            }
            CommError::Timeout { rank, src, tag, attempts, pending } => {
                write!(
                    f,
                    "rank {rank}: recv (src {src}, tag {tag:#x}) timed out after {attempts} \
                     attempts; pending stash tags: {pending:?}"
                )
            }
            CommError::Crashed { rank, step } => {
                write!(f, "rank {rank}: injected crash at step {step}")
            }
            CommError::Checkpoint { rank, msg } => {
                write!(f, "rank {rank}: checkpoint save failed: {msg}")
            }
        }
    }
}

impl std::error::Error for CommError {}

/// Panic payload used to unwind a rank out of a collective on an
/// unrecoverable comm error — the NCCL async-error/abort pattern: the
/// erroring endpoint broadcasts its obituary, then aborts the rank;
/// [`catch_comm`] at the step boundary downcasts the unwind back into a
/// typed per-rank `Result`.
pub struct CommAbort(pub CommError);

/// Run `f`, converting a [`CommAbort`] unwind into `Err(CommError)`.
/// Any other panic is resumed untouched, so real assertion failures still
/// surface as test failures. This is the fallible entry point for the
/// whole blocking comm API: wrap a collective (or a full training step)
/// and a dead peer becomes a clean per-rank error instead of a hang.
pub fn catch_comm<R>(f: impl FnOnce() -> R) -> Result<R, CommError> {
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(f)) {
        Ok(r) => Ok(r),
        Err(payload) => match payload.downcast::<CommAbort>() {
            Ok(abort) => Err(abort.0),
            Err(other) => std::panic::resume_unwind(other),
        },
    }
}

/// Install (once per process) a panic hook that silences [`CommAbort`]
/// unwinds — they are control flow, not failures — while chaining every
/// other panic to the previous hook.
pub fn install_quiet_hook() {
    static ONCE: std::sync::Once = std::sync::Once::new();
    ONCE.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if info.payload().downcast_ref::<CommAbort>().is_none() {
                prev(info);
            }
        }));
    });
}

/// Extra latency on a link; `None` endpoints match any rank.
#[derive(Clone, Debug, PartialEq)]
pub struct LinkDelay {
    pub src: Option<usize>,
    pub dst: Option<usize>,
    /// Extra virtual seconds added to every hop on the matching link.
    pub extra: f64,
}

/// Seeded, deterministic fault schedule for one world.
#[derive(Clone, Debug)]
pub struct FaultPlan {
    pub seed: u64,
    /// Recovery generation this plan instance drives (0 = first life).
    /// Salted into the drop hash so restarts reshuffle the drop pattern.
    pub generation: u64,
    /// Per-attempt drop probability in `[0, 1]`.
    pub drop_p: f64,
    /// Consecutive dropped attempts before a delivery gives up.
    pub max_retries: u32,
    /// Virtual seconds charged for the first retry interval; attempt `i`
    /// waits `retry_timeout · 2^i` (bounded exponential backoff).
    pub retry_timeout: f64,
    /// `(rank, step)` crash schedule; fires in generation 0 only.
    pub crashes: Vec<(usize, usize)>,
    /// Straggler links.
    pub delays: Vec<LinkDelay>,
    /// Supervisor bound on restart generations before giving up.
    pub max_recoveries: usize,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 0,
            generation: 0,
            drop_p: 0.0,
            max_retries: 4,
            retry_timeout: 1e-3,
            crashes: Vec::new(),
            delays: Vec::new(),
            max_recoveries: 3,
        }
    }
}

/// splitmix64 finalizer — the avalanche stage used throughout the crate's
/// seeding paths; good enough to decorrelate adjacent tags.
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl FaultPlan {
    /// The same plan re-keyed for recovery generation `g`.
    pub fn with_generation(mut self, g: u64) -> FaultPlan {
        self.generation = g;
        self
    }

    /// Does `rank` crash at the top of `step` under this plan? Crashes are
    /// one-shot: generation 0 only.
    pub fn crashes_at(&self, rank: usize, step: usize) -> bool {
        self.generation == 0 && self.crashes.iter().any(|&(r, s)| r == rank && s == step)
    }

    /// Uniform-in-`[0,1)` hash of one delivery attempt.
    fn attempt_unit(&self, src: usize, dst: usize, tag: u64, attempt: u32) -> f64 {
        let h = mix64(
            self.seed
                ^ mix64(self.generation)
                ^ mix64((src as u64) << 32 | dst as u64)
                ^ mix64(tag)
                ^ mix64(0xA77E0 + attempt as u64),
        );
        // 53 high bits → exact double in [0, 1).
        (h >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Number of consecutive dropped attempts preceding the delivery of
    /// `(src → dst, tag)`; saturates at `max_retries` (= delivery failed).
    pub fn drops_for(&self, src: usize, dst: usize, tag: u64) -> u32 {
        if self.drop_p <= 0.0 {
            return 0;
        }
        let mut n = 0;
        while n < self.max_retries && self.attempt_unit(src, dst, tag, n) < self.drop_p {
            n += 1;
        }
        n
    }

    /// Total virtual-clock stall for `drops` backed-off retry intervals:
    /// `retry_timeout · (2^drops − 1)`.
    pub fn retry_stall(&self, drops: u32) -> f64 {
        self.retry_timeout * ((1u64 << drops.min(62)) - 1) as f64
    }

    /// Extra straggler latency on the `src → dst` link.
    pub fn link_delay(&self, src: usize, dst: usize) -> f64 {
        self.delays
            .iter()
            .filter(|d| d.src.is_none_or(|s| s == src) && d.dst.is_none_or(|t| t == dst))
            .map(|d| d.extra)
            .sum()
    }

    /// Any fault configured at all? (An inactive plan is not installed, so
    /// the fault-free path stays on the legacy code path bit-for-bit.)
    pub fn is_active(&self) -> bool {
        self.drop_p > 0.0 || !self.crashes.is_empty() || !self.delays.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drops_are_deterministic_and_generation_salted() {
        let plan = FaultPlan { seed: 7, drop_p: 0.5, ..Default::default() };
        let a = plan.drops_for(0, 1, 123);
        let b = plan.drops_for(0, 1, 123);
        assert_eq!(a, b, "same plan must replay the same drops");
        // Across many tags both outcomes occur at p = 0.5.
        let hits: u32 = (0..200).map(|t| plan.drops_for(0, 1, t).min(1)).sum();
        assert!(hits > 50 && hits < 150, "drop rate implausible: {hits}/200");
        // A new generation reshuffles the pattern (some tag must differ).
        let g1 = plan.clone().with_generation(1);
        assert!(
            (0..200).any(|t| plan.drops_for(0, 1, t) != g1.drops_for(0, 1, t)),
            "generation salt must change the drop pattern"
        );
    }

    #[test]
    fn drop_p_one_exhausts_retries() {
        let plan = FaultPlan { drop_p: 1.0, max_retries: 3, ..Default::default() };
        assert_eq!(plan.drops_for(4, 2, 99), 3);
        assert!((plan.retry_stall(3) - plan.retry_timeout * 7.0).abs() < 1e-15);
        let clean = FaultPlan::default();
        assert_eq!(clean.drops_for(4, 2, 99), 0);
        assert_eq!(clean.retry_stall(0), 0.0);
    }

    #[test]
    fn crashes_fire_in_generation_zero_only() {
        let plan = FaultPlan { crashes: vec![(2, 5)], ..Default::default() };
        assert!(plan.crashes_at(2, 5));
        assert!(!plan.crashes_at(2, 4));
        assert!(!plan.crashes_at(1, 5));
        assert!(!plan.clone().with_generation(1).crashes_at(2, 5));
    }

    #[test]
    fn link_delays_match_wildcards() {
        let plan = FaultPlan {
            delays: vec![
                LinkDelay { src: Some(0), dst: None, extra: 1e-3 },
                LinkDelay { src: Some(0), dst: Some(2), extra: 5e-3 },
            ],
            ..Default::default()
        };
        assert!((plan.link_delay(0, 1) - 1e-3).abs() < 1e-15);
        assert!((plan.link_delay(0, 2) - 6e-3).abs() < 1e-15);
        assert_eq!(plan.link_delay(1, 0), 0.0);
    }

    #[test]
    fn catch_comm_converts_aborts_and_passes_values() {
        assert_eq!(catch_comm(|| 42).unwrap(), 42);
        let err = catch_comm(|| -> u32 {
            std::panic::panic_any(CommAbort(CommError::Crashed { rank: 3, step: 1 }))
        })
        .unwrap_err();
        assert_eq!(err, CommError::Crashed { rank: 3, step: 1 });
    }
}
