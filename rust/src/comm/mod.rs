//! In-process communication substrate (the NCCL replacement).
//!
//! Every "device" in `cubic` is a worker thread holding an [`Endpoint`]:
//! an mpsc mailbox, a clone of every other rank's sender, a **virtual
//! clock**, and a traffic ledger. Messages carry the sender's clock; a
//! receive advances the receiver's clock to
//! `max(own, sender_at_send + hop_cost)`, where `hop_cost = α + bytes/β`
//! comes from the hierarchical [`NetModel`] (NVLink-class links inside a
//! node, InfiniBand across nodes — matching the paper's TACC Longhorn
//! testbed with 4 GPUs per node).
//!
//! This is how `cubic` reproduces 64-GPU timing on a 1-core host: the
//! collective algorithms in [`crate::collectives`] are *real* message-passing
//! implementations (ring all-gather, ring reduce-scatter, binomial-tree
//! broadcast), and the virtual time of the full schedule emerges from clock
//! piggybacking — the same way a discrete-event simulator would compute it,
//! but on the actual production code path. See DESIGN.md §1 and §5.
//!
//! ## Overlap & the virtual clock
//!
//! Real runtimes hide gradient synchronization behind the next layer's
//! compute; a single per-rank clock cannot express that, so each endpoint
//! carries **two timelines**:
//!
//! * `clock` — the *compute* timeline: GEMMs, memops, and any collective
//!   the caller runs synchronously.
//! * `comm_clock` — the *communication* timeline: one virtual NIC/stream
//!   per rank, so deferred collectives serialize against each other but
//!   run concurrently with compute.
//!
//! A deferred collective ([`Endpoint::defer`], or the `iall_*` wrappers in
//! [`crate::collectives`]) executes its data movement **at issue time** —
//! reduction order and participant sets are exactly those of the
//! synchronous schedule, so results are bit-identical by construction —
//! but its *clock cost* is moved onto the comm timeline: the compute clock
//! is rewound to the issue point, the collective occupies
//! `[max(comm_clock, issue), …)` on the comm timeline, and a
//! [`CommTicket`] records its finish time. Joining a ticket
//! ([`Endpoint::drain_ready`] / [`Endpoint::join_all`] /
//! `PendingColl::wait`) advances `clock = max(clock, finish)`; only the
//! stall actually suffered at the join is **exposed** communication, the
//! rest was hidden behind compute. [`CommStats`] splits `comm_time` into
//! `exposed_comm_time` + `overlapped_comm_time` (an exact partition:
//! `exposed + overlapped == comm_time` always).
//!
//! The `CUBIC_OVERLAP={0,1}` environment knob (default `1`; also a config
//! key and `--overlap` CLI option, env wins) selects between the
//! overlapped and fully serialized schedules; with overlap off, `defer`
//! degenerates to running the collective inline and every ticket is a
//! no-op, reproducing the pre-overlap clock exactly.
//!
//! ## Failure model & recovery
//!
//! A seeded [`fault::FaultPlan`] installed via [`World::install_faults`]
//! turns the transport fallible. The fault taxonomy (all deterministic in
//! the seed, so the whole matrix is CI-able):
//!
//! * **Drops / retries** — each delivery of `(src → dst, tag)` is preceded
//!   by a hash-determined number of dropped attempts; the receiver pays
//!   exponentially backed-off retry intervals of *virtual* time
//!   (`retry_timeout · (2ⁿ − 1)`), counted exactly in
//!   [`CommStats::retries`] / [`CommStats::fault_stall_time`]. Exhausting
//!   `max_retries` surfaces [`fault::CommError::Timeout`].
//! * **Stragglers** — per-link extra latency charged on the virtual clock
//!   exactly like `hop_cost`, so slow links show up in the step ledger.
//! * **Crashes** — [`Endpoint::maybe_crash`] fires at the top of a
//!   scheduled step (generation 0 only): the rank broadcasts an
//!   **obituary** (reserved tag `u64::MAX`, sent raw — no stats, no
//!   injection) and unwinds with a [`fault::CommAbort`] payload.
//!
//! Error propagation is the NCCL async-error/abort pattern rather than
//! `Result`-plumbing through every collective: [`Endpoint::try_recv`] is
//! the fallible primitive; the infallible [`Endpoint::recv`] wraps it and,
//! on error, broadcasts this rank's own obituary (so blocked peers cascade
//! instead of deadlocking) and aborts the rank. [`fault::catch_comm`] at a
//! step boundary downcasts the unwind back into a typed per-rank
//! `Result<_, CommError>`. Obituaries are processed inside the receive
//! loop (never stashed); because the mpsc channel is FIFO per sender, data
//! a peer sent *before* dying is still drained first, and only then does
//! the receiver see [`fault::CommError::PeerDead`]. A wall-clock watchdog
//! (`CUBIC_HANG_TIMEOUT`, default 60 s) backstops genuine deadlocks: the
//! timeout error lists the expected `(src, tag)` and every key parked in
//! the stash, turning a frozen CI leg into a diagnosable failure.
//!
//! Recovery (driven by `engine::run_training_supervised`): on a detected
//! rank failure every rank's outcome is collected at the step boundary;
//! survivors either keep their in-memory state, restore from the last
//! crash-consistent checkpoint, or — on `Hybrid(r, inner)` meshes with a
//! healthy counterpart replica — **adopt** weights/optimizer state donated
//! over the comm layer by the surviving replica (no disk round-trip).
//! Exception: with `zero_stage ≥ 1` the survivor holds only its own `1/r`
//! moment partition — the dead rank's partition died with it — so the
//! engine skips donation and takes the checkpoint path instead.
//! Faults never touch payload bytes, so a recovered run is bit-identical
//! to the fault-free run; with no plan installed every path below is the
//! exact legacy code path, clock included. ROADMAP item 4's real
//! transport inherits this whole layer: the typed errors, the
//! obituary/abort protocol, and the retry/backoff envelope are the wire
//! contract, with only the drop *source* changing from a seeded hash to
//! the network.

use crate::tensor::Tensor;
use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Barrier};
use std::time::Duration;

pub mod fault;
pub mod pool;

use fault::{CommAbort, CommError, FaultPlan, OBITUARY_TAG};
use pool::{BufferPool, Takeout};

/// Hierarchical α-β network + device compute model.
///
/// Defaults are calibrated to the paper's testbed (TACC Longhorn):
/// V100 GPUs, NVLink2 inside a 4-GPU node, EDR InfiniBand (100 Gb/s)
/// across nodes. See `costmodel::calibration` for how κ was fitted.
#[derive(Clone, Debug)]
pub struct NetModel {
    /// Per-message latency within a node (s).
    pub alpha_intra: f64,
    /// Bandwidth within a node (bytes/s). NVLink2 ~ 150 GB/s effective.
    pub beta_intra: f64,
    /// Per-message latency across nodes (s).
    pub alpha_inter: f64,
    /// Bandwidth across nodes (bytes/s). EDR IB ~ 12.5 GB/s, de-rated.
    pub beta_inter: f64,
    /// Ranks packed per node (Longhorn: 4 V100 per node).
    pub ranks_per_node: usize,
    /// Fixed per-collective launch overhead (framework + kernel launch;
    /// ~tens of µs for 2021 PyTorch/NCCL). Charged once per collective.
    pub coll_overhead: f64,
    /// Effective device matmul throughput (flop/s) = κ · peak.
    pub flops_rate: f64,
    /// Effective device memory bandwidth (bytes/s) for elementwise ops.
    pub mem_bw: f64,
    /// Model compute/comm overlap for deferred collectives (the
    /// two-timeline scheme — see the module docs). Constructors default
    /// this from `CUBIC_OVERLAP` (unset ⇒ on); tests pin a schedule by
    /// setting the field directly.
    pub overlap: bool,
}

/// `CUBIC_OVERLAP` parsed: `Some(false)` for `0/false/off`, `Some(true)`
/// for `1/true/on`, `None` when unset (or unparseable, with a warning).
pub fn overlap_env() -> Option<bool> {
    match std::env::var("CUBIC_OVERLAP") {
        Ok(v) => match v.trim() {
            "0" | "false" | "off" => Some(false),
            "1" | "true" | "on" => Some(true),
            other => {
                eprintln!("CUBIC_OVERLAP={other:?} invalid (want 0 or 1); ignoring");
                None
            }
        },
        Err(_) => None,
    }
}

impl NetModel {
    /// Paper-testbed calibration (V100 + NVLink2 + EDR IB).
    pub fn longhorn_v100() -> Self {
        NetModel {
            alpha_intra: 6.0e-6,
            beta_intra: 130.0e9,
            alpha_inter: 18.0e-6,
            beta_inter: 10.0e9,
            ranks_per_node: 4,
            coll_overhead: 60.0e-6,
            // κ ≈ 0.30 of 31.4 TF/s fp32-with-tensor-core-accumulate mix the
            // paper's PyTorch fp32 path achieves; fitted in costmodel tests.
            flops_rate: 9.5e12,
            mem_bw: 750.0e9,
            overlap: overlap_env().unwrap_or(true),
        }
    }

    /// A uniform (flat) network — useful for unit tests where hierarchy
    /// effects would obscure the algebra.
    pub fn flat(alpha: f64, beta: f64, flops_rate: f64) -> Self {
        NetModel {
            alpha_intra: alpha,
            beta_intra: beta,
            alpha_inter: alpha,
            beta_inter: beta,
            ranks_per_node: usize::MAX,
            coll_overhead: 0.0,
            flops_rate,
            mem_bw: f64::INFINITY,
            overlap: overlap_env().unwrap_or(true),
        }
    }

    /// Set the overlap knob from config/CLI; the `CUBIC_OVERLAP`
    /// environment variable wins over the requested value (mirrors the
    /// `CUBIC_THREADS` precedence).
    pub fn set_overlap(&mut self, requested: bool) {
        self.overlap = overlap_env().unwrap_or(requested);
    }

    /// Zero-cost model: virtual clocks never advance. Used by correctness
    /// tests that only care about numerics.
    pub fn zero() -> Self {
        Self::flat(0.0, f64::INFINITY, f64::INFINITY)
    }

    pub fn node_of(&self, rank: usize) -> usize {
        if self.ranks_per_node == usize::MAX {
            0
        } else {
            rank / self.ranks_per_node
        }
    }

    /// Time for one point-to-point hop of `bytes` from `src` to `dst`.
    pub fn hop_cost(&self, src: usize, dst: usize, bytes: usize) -> f64 {
        if src == dst {
            return 0.0;
        }
        if self.node_of(src) == self.node_of(dst) {
            self.alpha_intra + bytes as f64 / self.beta_intra
        } else {
            self.alpha_inter + bytes as f64 / self.beta_inter
        }
    }

    /// Time to execute `flops` floating point operations on one device.
    pub fn compute_cost(&self, flops: f64) -> f64 {
        if self.flops_rate.is_infinite() {
            0.0
        } else {
            flops / self.flops_rate
        }
    }

    /// Time for a memory-bound elementwise pass over `bytes`.
    pub fn memop_cost(&self, bytes: f64) -> f64 {
        if self.mem_bw.is_infinite() {
            0.0
        } else {
            bytes / self.mem_bw
        }
    }
}

/// A tagged message between ranks. The payload is a [`Tensor`] so phantom
/// shards flow through the transport exactly like materialized ones (the
/// ledger charges `nominal_bytes` either way). With the Arc-backed tensor
/// storage the payload is a *handle* — enqueueing a message never copies
/// the f32 buffer, in either mode.
struct Message {
    src: usize,
    tag: u64,
    /// Sender's virtual clock at the moment of send.
    clock: f64,
    payload: Tensor,
}

/// Per-endpoint traffic statistics; merged across ranks by the engine.
#[derive(Clone, Debug, Default)]
pub struct CommStats {
    pub messages_sent: u64,
    pub bytes_sent: u64,
    /// Bytes that crossed a node boundary (the expensive kind).
    pub inter_node_bytes: u64,
    /// Virtual seconds spent waiting on communication (recv-side).
    pub comm_time: f64,
    /// The part of `comm_time` the compute timeline actually stalled on:
    /// synchronous collectives in full, plus the join-point stall of
    /// deferred ones. Invariant: `exposed + overlapped == comm_time`.
    pub exposed_comm_time: f64,
    /// The part of `comm_time` hidden behind compute by deferred
    /// collectives (shifted out of `exposed_comm_time` at the join).
    pub overlapped_comm_time: f64,
    /// Virtual seconds spent in local compute charges.
    pub compute_time: f64,
    /// Scratch-buffer requests served by the recycling pool (no heap
    /// allocation). Per-endpoint, so tests can assert exact values even
    /// when other worlds run concurrently in the process.
    pub pool_hits: u64,
    /// Scratch-buffer requests that had to heap-allocate. In the collective
    /// steady state this stops growing after the first iteration — the
    /// zero-allocation pin of the hot path.
    pub pool_misses: u64,
    /// Dropped delivery attempts this endpoint retried through (fault
    /// injection). Exact and deterministic in the plan seed.
    pub retries: u64,
    /// Receives that gave up: retry budget exhausted or the wall-clock
    /// hang watchdog fired.
    pub timeouts: u64,
    /// Virtual seconds of retry/backoff stall charged by fault injection
    /// (a sub-account of `comm_time`).
    pub fault_stall_time: f64,
}

impl CommStats {
    pub fn merge(&mut self, other: &CommStats) {
        self.messages_sent += other.messages_sent;
        self.bytes_sent += other.bytes_sent;
        self.inter_node_bytes += other.inter_node_bytes;
        self.comm_time = self.comm_time.max(other.comm_time);
        self.exposed_comm_time = self.exposed_comm_time.max(other.exposed_comm_time);
        self.overlapped_comm_time = self.overlapped_comm_time.max(other.overlapped_comm_time);
        self.compute_time = self.compute_time.max(other.compute_time);
        self.pool_hits += other.pool_hits;
        self.pool_misses += other.pool_misses;
        self.retries += other.retries;
        self.timeouts += other.timeouts;
        self.fault_stall_time = self.fault_stall_time.max(other.fault_stall_time);
    }
}

/// Global monotonically increasing id so distinct [`World`]s never share
/// tags even if a test reuses ranks.
static WORLD_ID: AtomicU64 = AtomicU64::new(1);

/// Factory for a fully connected group of [`Endpoint`]s.
pub struct World {
    senders: Vec<Sender<Message>>,
    receivers: Vec<Option<Receiver<Message>>>,
    net: Arc<NetModel>,
    barrier: Arc<Barrier>,
    world_id: u64,
    faults: Option<Arc<FaultPlan>>,
}

impl World {
    pub fn new(size: usize, net: NetModel) -> Self {
        let mut senders = Vec::with_capacity(size);
        let mut receivers = Vec::with_capacity(size);
        for _ in 0..size {
            let (tx, rx) = channel();
            senders.push(tx);
            receivers.push(Some(rx));
        }
        World {
            senders,
            receivers,
            net: Arc::new(net),
            barrier: Arc::new(Barrier::new(size)),
            world_id: WORLD_ID.fetch_add(1, Ordering::Relaxed),
            faults: None,
        }
    }

    /// Install a fault plan on every endpoint this world hands out, and
    /// silence the [`CommAbort`] control-flow unwinds it will cause. With
    /// no plan installed the transport is the exact legacy code path.
    pub fn install_faults(&mut self, plan: FaultPlan) {
        fault::install_quiet_hook();
        self.faults = Some(Arc::new(plan));
    }

    pub fn size(&self) -> usize {
        self.senders.len()
    }

    /// Take the endpoint for `rank`. Each rank may be taken exactly once;
    /// the endpoint is then moved into its worker thread.
    pub fn endpoint(&mut self, rank: usize) -> Endpoint {
        let rx = self.receivers[rank]
            .take()
            .expect("endpoint already taken for this rank");
        Endpoint {
            rank,
            rx,
            tx: self.senders.clone(),
            net: self.net.clone(),
            barrier: self.barrier.clone(),
            clock: 0.0,
            comm_clock: 0.0,
            stats: CommStats::default(),
            stash: HashMap::new(),
            group_seqs: HashMap::new(),
            world_id: self.world_id,
            pool: BufferPool::new(),
            deferred: VecDeque::new(),
            next_ticket: 0,
            in_defer: false,
            faults: self.faults.clone(),
            dead_peers: HashSet::new(),
            hang_timeout: hang_timeout_env(),
            obituary_sent: false,
        }
    }

    /// Take all endpoints at once (rank order).
    pub fn endpoints(mut self) -> Vec<Endpoint> {
        (0..self.size()).map(|r| self.endpoint(r)).collect()
    }
}

/// An in-flight deferred collective on the comm timeline: when it finishes
/// there and how much `comm_time` it charged at issue. Clock-only — the
/// data already moved at issue time (see the module docs).
#[derive(Clone, Copy, Debug)]
pub struct CommTicket {
    /// Monotonic per-endpoint id; `PendingColl::wait` joins by id.
    id: u64,
    /// Completion time on the comm timeline. Monotone across the queue
    /// (the comm timeline serializes), so draining is O(1) amortized.
    finish: f64,
    /// `comm_time` charged while the collective ran; the join splits this
    /// into exposed (stalled-on) and overlapped (hidden) parts.
    comm_elapsed: f64,
}

/// One rank's view of the world: mailbox, peers, virtual clock, ledger.
pub struct Endpoint {
    rank: usize,
    rx: Receiver<Message>,
    tx: Vec<Sender<Message>>,
    net: Arc<NetModel>,
    barrier: Arc<Barrier>,
    /// Virtual time (seconds) at this rank — the *compute* timeline.
    pub clock: f64,
    /// The *communication* timeline: deferred collectives serialize here
    /// (one virtual NIC/stream per rank) while `clock` keeps computing.
    pub comm_clock: f64,
    pub stats: CommStats,
    /// Out-of-order arrivals parked until someone asks for them. Per-key
    /// FIFO: `VecDeque` so draining is O(1) per message even under heavy
    /// reordering (a `Vec` + `remove(0)` degrades to O(n²)).
    stash: HashMap<(usize, u64), VecDeque<Message>>,
    /// Per-*group* collective sequence numbers, keyed by a hash of the
    /// ordered group membership (see `next_collective_tag`).
    group_seqs: HashMap<u64, u64>,
    world_id: u64,
    /// Recycling pool for collective scratch buffers (reduce-scatter
    /// accumulators, all-gather output assemblies, padded chunks). See
    /// [`pool::BufferPool`].
    pool: BufferPool,
    /// In-flight deferred collectives, FIFO by comm-timeline finish time.
    deferred: VecDeque<CommTicket>,
    /// Next [`CommTicket::id`].
    next_ticket: u64,
    /// Re-entrancy guard: a collective issued *inside* a deferred window
    /// runs inline on that window (no nested ticket).
    in_defer: bool,
    /// Installed fault plan; `None` keeps every path on the legacy code.
    faults: Option<Arc<FaultPlan>>,
    /// Ranks whose obituary this endpoint has seen.
    dead_peers: HashSet<usize>,
    /// Wall-clock watchdog for a blocking receive (`CUBIC_HANG_TIMEOUT`).
    hang_timeout: Duration,
    /// This rank already broadcast its own obituary (idempotence guard).
    obituary_sent: bool,
}

/// `CUBIC_HANG_TIMEOUT` (seconds, f64) — wall-clock watchdog on blocking
/// receives; defaults to 60 s, generous enough that it only fires on a
/// genuine deadlock or dead peer.
fn hang_timeout_env() -> Duration {
    std::env::var("CUBIC_HANG_TIMEOUT")
        .ok()
        .and_then(|v| v.trim().parse::<f64>().ok())
        .filter(|s| *s > 0.0)
        .map(Duration::from_secs_f64)
        .unwrap_or(Duration::from_secs(60))
}

impl Endpoint {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn world_size(&self) -> usize {
        self.tx.len()
    }

    pub fn net(&self) -> &NetModel {
        &self.net
    }

    /// Fresh tag for the next collective this rank runs on `group`.
    ///
    /// Tags are sequenced **per group**, not per rank: all members of a
    /// group execute the same program order of collectives *on that group*
    /// (SPMD), so their per-group counters — and therefore the tags — always
    /// agree, even when other groups this rank belongs to have run a
    /// different number of collectives (e.g. the diagonal-only
    /// reduce-scatter of Algorithm 8). Messages from a neighbour that has
    /// raced ahead are disambiguated by tag and stashed.
    ///
    /// Layout: `[group-hash:28][seq:20]` in the low 48 bits; ring/tree
    /// algorithms may use bits 48+ for step indices.
    pub fn next_collective_tag(&mut self, group: &[usize]) -> u64 {
        // Per-collective launch overhead (see NetModel::coll_overhead).
        let oh = self.net.coll_overhead;
        if oh > 0.0 {
            self.clock += oh;
            self.stats.comm_time += oh;
            self.stats.exposed_comm_time += oh;
        }
        // FNV-1a over the ordered membership, world id mixed in.
        let mut h: u64 = 0xcbf29ce484222325 ^ self.world_id;
        for &r in group {
            h ^= r as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        h ^= group.len() as u64;
        h = h.wrapping_mul(0x100000001b3);
        let key = h;
        let seq = self.group_seqs.entry(key).or_insert(0);
        *seq += 1;
        ((h & 0x0FFF_FFF0_0000_0000) >> 16) | (*seq & 0xFFFFF)
    }

    /// Send `t` to `dst` with `tag`, charging the ledger. Zero-copy: the
    /// payload clone is an `Arc` refcount bump (materialized) or shape-only
    /// (phantom) — the f32 buffer is never duplicated on the send path.
    pub fn send(&mut self, dst: usize, tag: u64, t: &Tensor) {
        self.send_owned(dst, tag, t.clone());
    }

    /// Like [`Endpoint::send`] but consumes the tensor, so the sender
    /// relinquishes its buffer handle at send time. Ring algorithms use
    /// this for forwarded chunks: the receiver then holds the only
    /// reference and can fold into the buffer in place, keeping the
    /// steady-state collective hot path free of copy-on-write.
    pub fn send_owned(&mut self, dst: usize, tag: u64, t: Tensor) {
        let bytes = t.nominal_bytes();
        self.stats.messages_sent += 1;
        self.stats.bytes_sent += bytes as u64;
        if self.net.node_of(self.rank) != self.net.node_of(dst) {
            self.stats.inter_node_bytes += bytes as u64;
        }
        let msg = Message {
            src: self.rank,
            tag,
            clock: self.clock,
            payload: t,
        };
        // A send can only fail if the peer's receiver was dropped. Under a
        // fault plan that is an expected rank death: drop the message
        // silently — the obituary (or the next receive involving that
        // peer) surfaces the failure where it can be handled. Without a
        // plan it means a worker panicked; keep the loud legacy behavior.
        if self.tx[dst].send(msg).is_err() && self.faults.is_none() {
            panic!("rank {} cannot reach rank {dst} (worker died)", self.rank);
        }
    }

    /// Blocking receive of the message `(src, tag)`; other arrivals are
    /// stashed. Advances the virtual clock by the α-β hop cost. On a comm
    /// failure (dead peer, exhausted retries, watchdog) this broadcasts
    /// the rank's own obituary and unwinds with [`CommAbort`] — see
    /// [`fault::catch_comm`] for the fallible boundary; use
    /// [`Endpoint::try_recv`] for a local `Result`.
    pub fn recv(&mut self, src: usize, tag: u64) -> Tensor {
        match self.try_recv(src, tag) {
            Ok(t) => t,
            Err(e) => self.abort(e),
        }
    }

    /// Fallible receive: the primitive behind [`Endpoint::recv`]. Applies
    /// the installed fault plan (drop/retry stalls, straggler delay,
    /// obituary handling) and returns a typed [`CommError`] instead of
    /// unwinding.
    pub fn try_recv(&mut self, src: usize, tag: u64) -> Result<Tensor, CommError> {
        // Injected drops: the delivery is dropped `drops` times before one
        // attempt gets through; the receiver pays one backed-off retry
        // interval of virtual time per drop (the sender sent exactly once
        // — drops are a clock-and-counter fiction, never a data change).
        let mut stall = 0.0;
        if let Some(plan) = self.faults.clone() {
            let drops = plan.drops_for(src, self.rank, tag);
            if drops > 0 {
                stall = plan.retry_stall(drops);
                self.stats.retries += drops as u64;
                self.stats.fault_stall_time += stall;
                if drops >= plan.max_retries {
                    // Gave up before any attempt landed: charge the full
                    // backoff wait, then surface the failure.
                    self.stats.timeouts += 1;
                    self.stats.comm_time += stall;
                    self.stats.exposed_comm_time += stall;
                    self.clock += stall;
                    return Err(CommError::Timeout {
                        rank: self.rank,
                        src,
                        tag,
                        attempts: drops,
                        pending: self.pending_tags(),
                    });
                }
            }
        }
        let msg = loop {
            if let Some(q) = self.stash.get_mut(&(src, tag)) {
                if let Some(m) = q.pop_front() {
                    if q.is_empty() {
                        self.stash.remove(&(src, tag));
                    }
                    break m;
                }
            }
            // The mpsc channel is FIFO per sender, so anything `src` sent
            // before dying has already been drained into the stash (or
            // matched) by the time its obituary is seen — pre-death data
            // is never lost to this check.
            if self.dead_peers.contains(&src) {
                return Err(CommError::PeerDead { rank: self.rank, peer: src, tag });
            }
            match self.rx.recv_timeout(self.hang_timeout) {
                Ok(m) if m.tag == OBITUARY_TAG => {
                    self.dead_peers.insert(m.src);
                }
                Ok(m) if m.src == src && m.tag == tag => break m,
                Ok(m) => {
                    self.stash.entry((m.src, m.tag)).or_default().push_back(m);
                }
                Err(RecvTimeoutError::Timeout) => {
                    // The silent-hang diagnostic: name what we were
                    // waiting for and everything parked in the stash, so a
                    // mismatched-tag deadlock reads off the error.
                    self.stats.timeouts += 1;
                    return Err(CommError::Timeout {
                        rank: self.rank,
                        src,
                        tag,
                        attempts: 0,
                        pending: self.pending_tags(),
                    });
                }
                Err(RecvTimeoutError::Disconnected) => {
                    if self.faults.is_some() {
                        return Err(CommError::PeerDead { rank: self.rank, peer: src, tag });
                    }
                    panic!("transport closed while waiting for message");
                }
            }
        };
        let bytes = msg.payload.nominal_bytes();
        let mut hop = self.net.hop_cost(src, self.rank, bytes);
        if let Some(plan) = &self.faults {
            hop += plan.link_delay(src, self.rank);
        }
        let arrive = msg.clock + hop + stall;
        if arrive > self.clock {
            self.stats.comm_time += arrive - self.clock;
            self.stats.exposed_comm_time += arrive - self.clock;
            self.clock = arrive;
        }
        Ok(msg.payload)
    }

    /// `(src, tag)` keys currently parked in the stash, sorted (timeout
    /// diagnostics).
    fn pending_tags(&self) -> Vec<(usize, u64)> {
        let mut v: Vec<(usize, u64)> = self.stash.keys().copied().collect();
        v.sort_unstable();
        v
    }

    /// Broadcast this rank's obituary, then unwind out of the current
    /// collective with a [`CommAbort`] payload. The obituary-first order
    /// is what makes failure *cascade* instead of deadlock: every peer
    /// blocked on this rank (directly or transitively) sees the death and
    /// aborts in turn, so all survivors reach the step boundary.
    pub fn abort(&mut self, err: CommError) -> ! {
        self.announce_death();
        std::panic::panic_any(CommAbort(err))
    }

    /// Send the reserved obituary tag to every peer, bypassing stats and
    /// fault injection. Idempotent; delivery failures (peer already gone)
    /// are ignored.
    pub fn announce_death(&mut self) {
        if self.obituary_sent {
            return;
        }
        self.obituary_sent = true;
        for dst in 0..self.tx.len() {
            if dst == self.rank {
                continue;
            }
            let _ = self.tx[dst].send(Message {
                src: self.rank,
                tag: OBITUARY_TAG,
                clock: self.clock,
                payload: Tensor::phantom(&[0]),
            });
        }
    }

    /// Abort this rank if the installed fault plan schedules a crash at
    /// `step`. Call at the top of each training step, *inside* the
    /// step-boundary `catch_comm`/`catch_unwind`.
    pub fn maybe_crash(&mut self, step: usize) {
        if let Some(plan) = self.faults.clone() {
            if plan.crashes_at(self.rank, step) {
                self.abort(CommError::Crashed { rank: self.rank, step });
            }
        }
    }

    /// Has `peer`'s obituary been seen by this endpoint?
    pub fn peer_is_dead(&self, peer: usize) -> bool {
        self.dead_peers.contains(&peer)
    }

    /// The installed fault plan, if any.
    pub fn fault_plan(&self) -> Option<&FaultPlan> {
        self.faults.as_deref()
    }

    /// Override the wall-clock hang watchdog (tests use a short timeout
    /// instead of racing on the `CUBIC_HANG_TIMEOUT` env var).
    pub fn set_hang_timeout(&mut self, d: Duration) {
        self.hang_timeout = d;
    }

    /// Worst (slowest) link cost of one ring step over `group` for a
    /// payload of `bytes` — the wavefront bound of a pipelined ring on a
    /// hierarchical network (every chunk crosses every link, so sustained
    /// ring throughput is set by the bottleneck link, exactly as in NCCL).
    pub fn ring_worst_hop(&self, group: &[usize], bytes: usize) -> f64 {
        let g = group.len();
        (0..g)
            .map(|i| self.net.hop_cost(group[i], group[(i + 1) % g], bytes))
            .fold(0.0, f64::max)
    }

    /// Clamp the clock to at least `start + floor_cost` — used by ring
    /// algorithms to enforce the bottleneck-link wavefront per step.
    pub fn apply_step_floor(&mut self, start: f64, floor_cost: f64) {
        let floor = start + floor_cost;
        if floor > self.clock {
            self.stats.comm_time += floor - self.clock;
            self.stats.exposed_comm_time += floor - self.clock;
            self.clock = floor;
        }
    }

    // --- deferred collectives (compute/comm overlap) ------------------

    /// Run `f` (a collective) as a *deferred* operation: the data moves
    /// now — bit-identical to the synchronous schedule — but the clock
    /// cost lands on the comm timeline instead of stalling compute. The
    /// issue-time charges keep `comm_time` and `exposed_comm_time` in
    /// sync; the join reclassifies the hidden part as overlapped.
    ///
    /// Returns `f`'s result plus the [`CommTicket`] id when a ticket was
    /// queued (`None` with overlap off, or inside another deferred
    /// window, where `f` just runs inline). Callers either hold the id in
    /// a `PendingColl` and join it explicitly, or rely on
    /// [`Endpoint::drain_ready`] / [`Endpoint::join_all`].
    pub fn defer<R>(&mut self, f: impl FnOnce(&mut Endpoint) -> R) -> (R, Option<u64>) {
        if !self.net.overlap || self.in_defer {
            return (f(self), None);
        }
        self.in_defer = true;
        let t0 = self.clock;
        let comm_t0 = self.stats.comm_time;
        let out = f(self);
        self.in_defer = false;
        let dur = self.clock - t0;
        let comm_elapsed = self.stats.comm_time - comm_t0;
        // Rewind the compute timeline to the issue point; the collective
        // occupies [max(comm_clock, issue), +dur) on the comm timeline.
        self.clock = t0;
        let start = if self.comm_clock > t0 { self.comm_clock } else { t0 };
        let finish = start + dur;
        self.comm_clock = finish;
        let id = self.next_ticket;
        self.next_ticket += 1;
        self.deferred.push_back(CommTicket { id, finish, comm_elapsed });
        (out, Some(id))
    }

    /// Join the oldest in-flight ticket: advance `clock` to its finish and
    /// split its `comm_time` into the stall actually suffered here
    /// (exposed) and the part hidden behind compute (overlapped). Stall in
    /// excess of the ticket's comm charge is in-collective compute,
    /// already in `compute_time`.
    fn join_front(&mut self) {
        let Some(t) = self.deferred.pop_front() else { return };
        let stall = (t.finish - self.clock).max(0.0);
        let overlapped = t.comm_elapsed - stall.min(t.comm_elapsed);
        self.stats.exposed_comm_time -= overlapped;
        self.stats.overlapped_comm_time += overlapped;
        if t.finish > self.clock {
            self.clock = t.finish;
        }
    }

    /// Retire every in-flight ticket that has already finished on the comm
    /// timeline — zero compute-clock cost, pure bookkeeping. Called
    /// between backward layers so the queue stays shallow. O(1) amortized:
    /// finish times are monotone, so this stops at the first unfinished
    /// ticket.
    pub fn drain_ready(&mut self) {
        while self.deferred.front().is_some_and(|t| t.finish <= self.clock) {
            self.join_front();
        }
    }

    /// Join *all* in-flight tickets (the optimizer boundary): the compute
    /// clock waits for the comm timeline to drain.
    pub fn join_all(&mut self) {
        while !self.deferred.is_empty() {
            self.join_front();
        }
    }

    /// Join tickets up to and including `id` (FIFO — earlier tickets
    /// finish earlier on the serialized comm timeline).
    pub fn join_ticket(&mut self, id: u64) {
        while self.deferred.front().is_some_and(|t| t.id <= id) {
            self.join_front();
        }
    }

    /// In-flight deferred collectives (diagnostics/tests).
    pub fn pending_colls(&self) -> usize {
        self.deferred.len()
    }

    /// Charge local matmul/elementwise compute time to the virtual clock.
    pub fn charge_flops(&mut self, flops: f64) {
        let t = self.net.compute_cost(flops);
        self.clock += t;
        self.stats.compute_time += t;
    }

    /// Charge a memory-bound pass over `bytes` to the virtual clock.
    pub fn charge_memop(&mut self, bytes: f64) {
        let t = self.net.memop_cost(bytes);
        self.clock += t;
        self.stats.compute_time += t;
    }

    /// Real (thread) barrier across the whole world. Does not touch virtual
    /// clocks — use a collective for that.
    pub fn barrier_wait(&self) {
        self.barrier.wait();
    }

    /// Scratch tensor of `shape` from this endpoint's recycling pool.
    /// Contents are unspecified (recycled) — the caller must overwrite every
    /// element it reads. The buffer returns to this pool when the last
    /// handle drops, wherever that happens; after warmup the collective hot
    /// path is served entirely from recycled buffers (see
    /// `CommStats::pool_misses` and the counters in [`crate::metrics`]).
    pub fn pooled_tensor(&mut self, shape: &[usize]) -> Tensor {
        let (t, how) = self.pool.tensor(shape);
        match how {
            Takeout::Recycled => {
                self.stats.pool_hits += 1;
                crate::metrics::add_pool_hit();
            }
            Takeout::Allocated => {
                self.stats.pool_misses += 1;
                crate::metrics::add_pool_alloc();
            }
        }
        t
    }

    /// Buffers currently idle in this endpoint's pool (diagnostics).
    pub fn pool_idle(&self) -> usize {
        self.pool.idle()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn p2p_send_recv_carries_data_and_clock() {
        let mut world = World::new(2, NetModel::flat(1e-6, 1e9, f64::INFINITY));
        let mut e0 = world.endpoint(0);
        let mut e1 = world.endpoint(1);
        let h = thread::spawn(move || {
            e0.clock = 5.0;
            let t = Tensor::from_vec(&[2], vec![1.0, 2.0]);
            e0.send(1, 7, &t);
            e0.stats.clone()
        });
        let got = e1.recv(0, 7);
        assert_eq!(got.data(), &[1.0, 2.0]);
        // clock = sender(5.0) + alpha(1e-6) + 8 bytes / 1e9
        assert!((e1.clock - (5.0 + 1e-6 + 8.0 / 1e9)).abs() < 1e-12);
        let s = h.join().unwrap();
        assert_eq!(s.messages_sent, 1);
        assert_eq!(s.bytes_sent, 8);
    }

    #[test]
    fn out_of_order_messages_are_stashed() {
        let mut world = World::new(2, NetModel::zero());
        let mut e0 = world.endpoint(0);
        let mut e1 = world.endpoint(1);
        let h = thread::spawn(move || {
            e0.send(1, 100, &Tensor::from_vec(&[1], vec![1.0]));
            e0.send(1, 101, &Tensor::from_vec(&[1], vec![2.0]));
            e0.send(1, 102, &Tensor::from_vec(&[1], vec![3.0]));
        });
        // Receive in reverse order.
        assert_eq!(e1.recv(0, 102).data(), &[3.0]);
        assert_eq!(e1.recv(0, 101).data(), &[2.0]);
        assert_eq!(e1.recv(0, 100).data(), &[1.0]);
        h.join().unwrap();
    }

    #[test]
    fn send_is_zero_copy() {
        // The received tensor must share storage with the sender's original
        // buffer — the transport moves handles, not data.
        let mut world = World::new(2, NetModel::zero());
        let mut e0 = world.endpoint(0);
        let mut e1 = world.endpoint(1);
        let original = Tensor::from_vec(&[64], (0..64).map(|i| i as f32).collect());
        let keep = original.clone();
        let h = thread::spawn(move || {
            e0.send(1, 5, &original);
        });
        let got = e1.recv(0, 5);
        h.join().unwrap();
        assert!(got.shares_storage(&keep), "payload must be a buffer handle");
        assert_eq!(got.data(), keep.data());
    }

    #[test]
    fn heavy_reordering_drains_stash_fifo() {
        // Many same-tag messages received after an unrelated tag: FIFO
        // order per (src, tag) must hold (VecDeque stash).
        let mut world = World::new(2, NetModel::zero());
        let mut e0 = world.endpoint(0);
        let mut e1 = world.endpoint(1);
        let n = 200u64;
        let h = thread::spawn(move || {
            for i in 0..n {
                e0.send(1, 7, &Tensor::from_vec(&[1], vec![i as f32]));
            }
            e0.send(1, 8, &Tensor::from_vec(&[1], vec![-1.0]));
        });
        // Pull the late tag first, stashing all n tag-7 messages.
        assert_eq!(e1.recv(0, 8).data(), &[-1.0]);
        for i in 0..n {
            assert_eq!(e1.recv(0, 7).data(), &[i as f32], "message {i} out of order");
        }
        h.join().unwrap();
    }

    #[test]
    fn inter_node_traffic_is_accounted() {
        let mut net = NetModel::flat(0.0, 1e9, f64::INFINITY);
        net.ranks_per_node = 2; // ranks {0,1} node 0, {2,3} node 1
        let mut world = World::new(4, net);
        let mut e0 = world.endpoint(0);
        let mut e2 = world.endpoint(2);
        let h = thread::spawn(move || {
            e0.send(2, 1, &Tensor::zeros(&[4]));
            e0.stats.clone()
        });
        let _ = e2.recv(0, 1);
        let s = h.join().unwrap();
        assert_eq!(s.inter_node_bytes, 16);
    }

    #[test]
    fn hop_cost_hierarchy() {
        let mut net = NetModel::longhorn_v100();
        net.ranks_per_node = 4;
        let intra = net.hop_cost(0, 1, 1 << 20);
        let inter = net.hop_cost(0, 4, 1 << 20);
        assert!(inter > intra * 5.0, "inter {inter} should dwarf intra {intra}");
        assert_eq!(net.hop_cost(3, 3, 1 << 20), 0.0);
    }

    #[test]
    fn phantom_payloads_charge_nominal_bytes() {
        let mut world = World::new(2, NetModel::flat(0.0, 1e6, f64::INFINITY));
        let mut e0 = world.endpoint(0);
        let mut e1 = world.endpoint(1);
        let h = thread::spawn(move || {
            e0.send(1, 9, &Tensor::phantom(&[1000]));
            e0.stats.clone()
        });
        let got = e1.recv(0, 9);
        assert!(got.is_phantom());
        // 4000 bytes at 1e6 B/s = 4ms of virtual time.
        assert!((e1.clock - 4e-3).abs() < 1e-9);
        assert_eq!(h.join().unwrap().bytes_sent, 4000);
    }

    #[test]
    fn charge_flops_advances_clock() {
        let mut world = World::new(1, NetModel::flat(0.0, 1e9, 1e12));
        let mut e = world.endpoint(0);
        e.charge_flops(2e12);
        assert!((e.clock - 2.0).abs() < 1e-12);
        assert!((e.stats.compute_time - 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "already taken")]
    fn endpoint_cannot_be_taken_twice() {
        let mut world = World::new(2, NetModel::zero());
        let _a = world.endpoint(0);
        let _b = world.endpoint(0);
    }

    /// 1000-elem tensor = 4000 bytes at 1e9 B/s: 4 µs per hop.
    fn overlap_pair(overlap: bool) -> (Endpoint, Endpoint) {
        let mut net = NetModel::flat(0.0, 1e9, 1e12);
        net.overlap = overlap;
        let mut world = World::new(2, net);
        (world.endpoint(0), world.endpoint(1))
    }

    #[test]
    fn deferred_recv_hides_comm_behind_compute() {
        let (mut e0, mut e1) = overlap_pair(true);
        let h = thread::spawn(move || {
            e0.send(1, 1, &Tensor::phantom(&[1000]));
        });
        let (_t, ticket) = e1.defer(|ep| ep.recv(0, 1));
        assert!(ticket.is_some());
        // Compute clock rewound to the issue point; comm timeline holds
        // the 4 µs transfer.
        assert_eq!(e1.clock, 0.0);
        assert!((e1.comm_clock - 4e-6).abs() < 1e-15);
        assert_eq!(e1.pending_colls(), 1);
        e1.charge_flops(10e6); // 10 µs of compute at 1e12 flop/s
        e1.drain_ready();
        assert_eq!(e1.pending_colls(), 0);
        // Fully hidden: no stall, all 4 µs reclassified as overlapped.
        assert!((e1.clock - 10e-6).abs() < 1e-15);
        assert!((e1.stats.comm_time - 4e-6).abs() < 1e-15);
        assert!((e1.stats.overlapped_comm_time - 4e-6).abs() < 1e-15);
        assert!(e1.stats.exposed_comm_time.abs() < 1e-15);
        h.join().unwrap();
    }

    #[test]
    fn deferred_recv_exposes_stall_when_nothing_hides_it() {
        let (mut e0, mut e1) = overlap_pair(true);
        let h = thread::spawn(move || {
            e0.send(1, 1, &Tensor::phantom(&[1000]));
        });
        let (_t, _) = e1.defer(|ep| ep.recv(0, 1));
        e1.join_all(); // no compute issued: the full 4 µs is exposed
        assert!((e1.clock - 4e-6).abs() < 1e-15);
        assert!((e1.stats.exposed_comm_time - 4e-6).abs() < 1e-15);
        assert!(e1.stats.overlapped_comm_time.abs() < 1e-15);
        h.join().unwrap();
    }

    #[test]
    fn comm_timeline_serializes_in_flight_tickets() {
        let (mut e0, mut e1) = overlap_pair(true);
        let h = thread::spawn(move || {
            e0.send(1, 1, &Tensor::phantom(&[1000]));
            e0.send(1, 2, &Tensor::phantom(&[1000]));
        });
        let (_a, _) = e1.defer(|ep| ep.recv(0, 1));
        let (_b, _) = e1.defer(|ep| ep.recv(0, 2));
        // Both issued at t=0; the comm timeline runs them back to back:
        // finishes at 4 µs and 8 µs.
        assert!((e1.comm_clock - 8e-6).abs() < 1e-15);
        e1.charge_flops(5e6); // 5 µs of compute
        e1.join_all();
        // Ticket 1 (finish 4 µs < clock 5 µs) fully overlapped; ticket 2
        // stalls 3 µs: exposed 3 µs, overlapped 1 µs; clock = 8 µs.
        assert!((e1.clock - 8e-6).abs() < 1e-14);
        assert!((e1.stats.comm_time - 8e-6).abs() < 1e-14);
        assert!((e1.stats.exposed_comm_time - 3e-6).abs() < 1e-14);
        assert!((e1.stats.overlapped_comm_time - 5e-6).abs() < 1e-14);
        let s = &e1.stats;
        assert!(
            (s.exposed_comm_time + s.overlapped_comm_time - s.comm_time).abs() < 1e-14,
            "exposed + overlapped must partition comm_time"
        );
        h.join().unwrap();
    }

    #[test]
    fn overlap_off_runs_inline_with_no_tickets() {
        let (mut e0, mut e1) = overlap_pair(false);
        let h = thread::spawn(move || {
            e0.send(1, 1, &Tensor::phantom(&[1000]));
        });
        let (_t, ticket) = e1.defer(|ep| ep.recv(0, 1));
        assert!(ticket.is_none());
        assert_eq!(e1.pending_colls(), 0);
        // Serialized: the clock advanced inline and all comm is exposed.
        assert!((e1.clock - 4e-6).abs() < 1e-15);
        assert!((e1.stats.exposed_comm_time - 4e-6).abs() < 1e-15);
        assert!(e1.stats.overlapped_comm_time.abs() < 1e-15);
        e1.join_all(); // no-op
        assert!((e1.clock - 4e-6).abs() < 1e-15);
        h.join().unwrap();
    }

    #[test]
    fn nested_defer_runs_inline_on_the_outer_window() {
        let (mut e0, mut e1) = overlap_pair(true);
        let h = thread::spawn(move || {
            e0.send(1, 1, &Tensor::phantom(&[1000]));
            e0.send(1, 2, &Tensor::phantom(&[1000]));
        });
        let ((_a, inner_ticket), outer_ticket) = e1.defer(|ep| {
            let _x = ep.recv(0, 1);
            ep.defer(|ep2| ep2.recv(0, 2))
        });
        assert!(outer_ticket.is_some());
        assert!(inner_ticket.is_none(), "nested window must not double-book");
        assert_eq!(e1.pending_colls(), 1);
        e1.join_all();
        assert!((e1.clock - 8e-6).abs() < 1e-14);
        h.join().unwrap();
    }

    #[test]
    fn join_ticket_drains_the_fifo_prefix() {
        let (mut e0, mut e1) = overlap_pair(true);
        let h = thread::spawn(move || {
            for tag in 1..=3u64 {
                e0.send(1, tag, &Tensor::phantom(&[1000]));
            }
        });
        let (_a, t1) = e1.defer(|ep| ep.recv(0, 1));
        let (_b, t2) = e1.defer(|ep| ep.recv(0, 2));
        let (_c, _t3) = e1.defer(|ep| ep.recv(0, 3));
        e1.join_ticket(t2.unwrap());
        assert_eq!(e1.pending_colls(), 1);
        assert!((e1.clock - 8e-6).abs() < 1e-14);
        e1.join_ticket(t1.unwrap()); // already joined: no-op
        assert_eq!(e1.pending_colls(), 1);
        e1.join_all();
        assert!((e1.clock - 12e-6).abs() < 1e-14);
        h.join().unwrap();
    }

    // --- fault injection ----------------------------------------------

    #[test]
    fn dead_peer_drains_predeath_data_then_errors() {
        let mut world = World::new(2, NetModel::zero());
        world.install_faults(FaultPlan::default());
        let mut e0 = world.endpoint(0);
        let mut e1 = world.endpoint(1);
        let h = thread::spawn(move || {
            e0.send(1, 1, &Tensor::from_vec(&[1], vec![42.0]));
            e0.announce_death();
        });
        h.join().unwrap();
        // FIFO per sender: the pre-death payload arrives before the
        // obituary and must still be delivered.
        assert_eq!(e1.recv(0, 1).data(), &[42.0]);
        let err = e1.try_recv(0, 2).unwrap_err();
        assert_eq!(err, CommError::PeerDead { rank: 1, peer: 0, tag: 2 });
        assert!(e1.peer_is_dead(0));
        // recv() on the same condition aborts with a catchable payload.
        let caught = fault::catch_comm(|| e1.recv(0, 3)).unwrap_err();
        assert!(matches!(caught, CommError::PeerDead { peer: 0, .. }));
    }

    #[test]
    fn exhausted_retries_surface_exact_counters() {
        let mut world = World::new(2, NetModel::zero());
        world.install_faults(FaultPlan {
            drop_p: 1.0,
            max_retries: 3,
            retry_timeout: 1e-3,
            ..Default::default()
        });
        let mut e0 = world.endpoint(0);
        let mut e1 = world.endpoint(1);
        e0.send(1, 5, &Tensor::from_vec(&[1], vec![1.0]));
        let err = e1.try_recv(0, 5).unwrap_err();
        match err {
            CommError::Timeout { rank, src, tag, attempts, .. } => {
                assert_eq!((rank, src, tag, attempts), (1, 0, 5, 3));
            }
            other => panic!("expected Timeout, got {other:?}"),
        }
        assert_eq!(e1.stats.retries, 3);
        assert_eq!(e1.stats.timeouts, 1);
        // Backoff: 1 + 2 + 4 intervals of 1 ms.
        assert!((e1.stats.fault_stall_time - 7e-3).abs() < 1e-12);
        assert!((e1.clock - 7e-3).abs() < 1e-12);
        assert!((e1.stats.exposed_comm_time - 7e-3).abs() < 1e-12);
    }

    #[test]
    fn partial_drops_stall_then_deliver() {
        let plan = FaultPlan { seed: 11, drop_p: 0.6, max_retries: 8, ..Default::default() };
        // Find a tag that drops at least once but still delivers.
        let tag = (0..1000u64)
            .find(|&t| {
                let d = plan.drops_for(0, 1, t);
                d > 0 && d < plan.max_retries
            })
            .expect("some tag must partially drop at p=0.6");
        let drops = plan.drops_for(0, 1, tag);
        let stall = plan.retry_stall(drops);
        let mut world = World::new(2, NetModel::zero());
        world.install_faults(plan);
        let mut e0 = world.endpoint(0);
        let mut e1 = world.endpoint(1);
        e0.send(1, tag, &Tensor::from_vec(&[1], vec![3.0]));
        assert_eq!(e1.recv(0, tag).data(), &[3.0]);
        assert_eq!(e1.stats.retries, drops as u64);
        assert_eq!(e1.stats.timeouts, 0);
        assert!((e1.clock - stall).abs() < 1e-12, "retry stall must reach the clock");
        assert!((e1.stats.fault_stall_time - stall).abs() < 1e-12);
    }

    #[test]
    fn hang_watchdog_names_expected_and_pending_tags() {
        let mut world = World::new(2, NetModel::zero());
        let mut e0 = world.endpoint(0);
        let mut e1 = world.endpoint(1);
        e1.set_hang_timeout(Duration::from_millis(50));
        e0.send(1, 9, &Tensor::from_vec(&[1], vec![1.0]));
        // Waiting on the wrong tag: the watchdog fires and the error
        // carries both the expectation and the stash contents.
        let err = e1.try_recv(0, 7).unwrap_err();
        match err {
            CommError::Timeout { rank, src, tag, attempts, pending } => {
                assert_eq!((rank, src, tag, attempts), (1, 0, 7, 0));
                assert_eq!(pending, vec![(0, 9)]);
            }
            other => panic!("expected Timeout, got {other:?}"),
        }
        assert_eq!(e1.stats.timeouts, 1);
        // The stashed message is still deliverable afterwards.
        assert_eq!(e1.recv(0, 9).data(), &[1.0]);
    }

    #[test]
    fn straggler_delay_rides_the_virtual_clock() {
        let mut world = World::new(2, NetModel::zero());
        world.install_faults(FaultPlan {
            delays: vec![fault::LinkDelay { src: Some(0), dst: Some(1), extra: 2e-3 }],
            ..Default::default()
        });
        let mut e0 = world.endpoint(0);
        let mut e1 = world.endpoint(1);
        e0.send(1, 1, &Tensor::from_vec(&[1], vec![1.0]));
        let _ = e1.recv(0, 1);
        assert!((e1.clock - 2e-3).abs() < 1e-12);
        // Reverse direction is unaffected: e0's clock only piggybacks off
        // the sender's clock (2 ms), with no extra link delay added.
        e1.send(0, 2, &Tensor::from_vec(&[1], vec![1.0]));
        let _ = e0.recv(1, 2);
        assert!((e0.clock - 2e-3).abs() < 1e-12);
        assert_eq!(e0.stats.retries, 0);
    }

    #[test]
    fn maybe_crash_fires_only_at_the_scheduled_step() {
        let mut world = World::new(2, NetModel::zero());
        world.install_faults(FaultPlan { crashes: vec![(0, 2)], ..Default::default() });
        let mut e0 = world.endpoint(0);
        let mut e1 = world.endpoint(1);
        e0.maybe_crash(0);
        e0.maybe_crash(1); // no-ops
        let err = fault::catch_comm(|| e0.maybe_crash(2)).unwrap_err();
        assert_eq!(err, CommError::Crashed { rank: 0, step: 2 });
        // The obituary went out before the unwind.
        assert!(e1.try_recv(0, 1).is_err());
        assert!(e1.peer_is_dead(0));
        // The unaffected rank never crashes.
        e1.maybe_crash(2);
    }
}
