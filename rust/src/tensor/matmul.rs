//! Blocked matrix-multiplication kernels.
//!
//! Three forms, matching the paper's §3.1.2 (Eq. 3–5): `C = AB`, `C = ABᵀ`,
//! `C = AᵀB`. These are the per-device compute of the whole framework — the
//! role cuBLAS plays on the authors' V100s and the Pallas L1 kernel plays on
//! TPU — so they are written as cache-blocked loops with an `ikj` inner order
//! (stream through contiguous rows of B and C) and a per-call flop counter
//! feeding the metrics layer.
//!
//! Phantom inputs short-circuit to a phantom output of the correct shape;
//! shape *checking* still happens first, so the simulated benches exercise
//! the same contract the numeric path does.

use super::Tensor;
use std::sync::atomic::{AtomicU64, Ordering};

/// Global flop counter (2·M·N·K per matmul). The metrics layer reads and
/// resets this around timed regions; relaxed ordering is fine for a counter.
static FLOPS: AtomicU64 = AtomicU64::new(0);

pub fn flops_executed() -> u64 {
    FLOPS.load(Ordering::Relaxed)
}

pub fn reset_flops() {
    FLOPS.store(0, Ordering::Relaxed);
}

fn count(m: usize, n: usize, k: usize) {
    FLOPS.fetch_add(2 * (m as u64) * (n as u64) * (k as u64), Ordering::Relaxed);
}

/// Cache block edge (elements). 64×64 f32 tiles = 16 KiB per operand tile,
/// comfortably inside L1+L2 on any x86 host; chosen by the §Perf sweep in
/// EXPERIMENTS.md.
const BLOCK: usize = 64;

/// `C = A · B` for A:(m,k), B:(k,n).
pub fn matmul_nn(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, ka) = a.dims2();
    let (kb, n) = b.dims2();
    assert_eq!(ka, kb, "matmul_nn: inner dims {ka} vs {kb} (A {:?}, B {:?})", a.shape(), b.shape());
    let (Some(ad), Some(bd)) = (a.try_data(), b.try_data()) else {
        return Tensor::phantom(&[m, n]);
    };
    count(m, n, ka);
    let k = ka;
    let mut c = vec![0.0f32; m * n];
    // Blocked ikj: for each (i-block, k-block) pair, stream across full rows
    // of B and C. The innermost loop is a contiguous axpy over n columns,
    // which the compiler auto-vectorizes.
    for ib in (0..m).step_by(BLOCK) {
        let ie = (ib + BLOCK).min(m);
        for kb_ in (0..k).step_by(BLOCK) {
            let ke = (kb_ + BLOCK).min(k);
            for i in ib..ie {
                let arow = &ad[i * k..(i + 1) * k];
                let crow = &mut c[i * n..(i + 1) * n];
                for kk in kb_..ke {
                    let aik = arow[kk];
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = &bd[kk * n..(kk + 1) * n];
                    for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                        *cv += aik * bv;
                    }
                }
            }
        }
    }
    Tensor::from_vec(&[m, n], c)
}

/// `C = A · Bᵀ` for A:(m,k), B:(n,k).
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, ka) = a.dims2();
    let (n, kb) = b.dims2();
    assert_eq!(ka, kb, "matmul_nt: inner dims {ka} vs {kb} (A {:?}, B {:?})", a.shape(), b.shape());
    let (Some(ad), Some(bd)) = (a.try_data(), b.try_data()) else {
        return Tensor::phantom(&[m, n]);
    };
    count(m, n, ka);
    let k = ka;
    let mut c = vec![0.0f32; m * n];
    // Both A and B rows are contiguous here, so a dot-product kernel is the
    // natural fit; block over (i, j) to keep B rows resident. The dot is
    // split across 4 independent accumulators to break the serial FP add
    // dependency chain (§Perf: 2.85 → ~9 GF/s on the 256³ microbench).
    for ib in (0..m).step_by(BLOCK) {
        let ie = (ib + BLOCK).min(m);
        for jb in (0..n).step_by(BLOCK) {
            let je = (jb + BLOCK).min(n);
            for i in ib..ie {
                let arow = &ad[i * k..(i + 1) * k];
                for j in jb..je {
                    let brow = &bd[j * k..(j + 1) * k];
                    let chunks = k / 4;
                    let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
                    for t in 0..chunks {
                        let base = t * 4;
                        a0 += arow[base] * brow[base];
                        a1 += arow[base + 1] * brow[base + 1];
                        a2 += arow[base + 2] * brow[base + 2];
                        a3 += arow[base + 3] * brow[base + 3];
                    }
                    let mut acc = (a0 + a1) + (a2 + a3);
                    for t in chunks * 4..k {
                        acc += arow[t] * brow[t];
                    }
                    c[i * n + j] = acc;
                }
            }
        }
    }
    Tensor::from_vec(&[m, n], c)
}

/// `C = Aᵀ · B` for A:(k,m), B:(k,n).
pub fn matmul_tn(a: &Tensor, b: &Tensor) -> Tensor {
    let (ka, m) = a.dims2();
    let (kb, n) = b.dims2();
    assert_eq!(ka, kb, "matmul_tn: inner dims {ka} vs {kb} (A {:?}, B {:?})", a.shape(), b.shape());
    let (Some(ad), Some(bd)) = (a.try_data(), b.try_data()) else {
        return Tensor::phantom(&[m, n]);
    };
    count(m, n, ka);
    let k = ka;
    let mut c = vec![0.0f32; m * n];
    // k is the outer loop: for each row of A (length m) and row of B
    // (length n), rank-1 update of C. Row accesses are all contiguous.
    for kb_ in (0..k).step_by(BLOCK) {
        let ke = (kb_ + BLOCK).min(k);
        for kk in kb_..ke {
            let arow = &ad[kk * m..(kk + 1) * m];
            let brow = &bd[kk * n..(kk + 1) * n];
            for i in 0..m {
                let aki = arow[i];
                if aki == 0.0 {
                    continue;
                }
                let crow = &mut c[i * n..(i + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                    *cv += aki * bv;
                }
            }
        }
    }
    Tensor::from_vec(&[m, n], c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    /// Naive triple loop oracle.
    fn naive_nn(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = a.dims2();
        let (_, n) = b.dims2();
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for kk in 0..k {
                    acc += a.at2(i, kk) * b.at2(kk, j);
                }
                c[i * n + j] = acc;
            }
        }
        Tensor::from_vec(&[m, n], c)
    }

    fn randt(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        Tensor::randn(shape, 1.0, &mut rng)
    }

    #[test]
    fn nn_matches_naive_various_shapes() {
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (64, 64, 64), (65, 33, 129), (128, 1, 17)] {
            let a = randt(&[m, k], 1 + m as u64);
            let b = randt(&[k, n], 2 + n as u64);
            let c = matmul_nn(&a, &b);
            let r = naive_nn(&a, &b);
            assert!(c.max_abs_diff(&r) < 1e-3, "shape ({m},{k},{n})");
        }
    }

    #[test]
    fn nt_equals_nn_with_transpose() {
        for &(m, k, n) in &[(4, 6, 5), (65, 64, 63), (17, 129, 31)] {
            let a = randt(&[m, k], 10);
            let b = randt(&[n, k], 11);
            let c = matmul_nt(&a, &b);
            let r = matmul_nn(&a, &b.transpose());
            assert!(c.max_abs_diff(&r) < 1e-3, "shape ({m},{k},{n})");
        }
    }

    #[test]
    fn tn_equals_nn_with_transpose() {
        for &(m, k, n) in &[(4, 6, 5), (65, 64, 63), (31, 129, 17)] {
            let a = randt(&[k, m], 20);
            let b = randt(&[k, n], 21);
            let c = matmul_tn(&a, &b);
            let r = matmul_nn(&a.transpose(), &b);
            assert!(c.max_abs_diff(&r) < 1e-3, "shape ({m},{k},{n})");
        }
    }

    #[test]
    fn identity_is_neutral() {
        let a = randt(&[8, 8], 30);
        let mut eye = Tensor::zeros(&[8, 8]);
        for i in 0..8 {
            eye.data_mut()[i * 8 + i] = 1.0;
        }
        assert!(a.matmul(&eye).max_abs_diff(&a) < 1e-6);
        assert!(eye.matmul(&a).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn phantom_inputs_give_phantom_output() {
        let a = Tensor::phantom(&[4, 6]);
        let b = randt(&[6, 5], 1);
        let c = matmul_nn(&a, &b);
        assert!(c.is_phantom());
        assert_eq!(c.shape(), &[4, 5]);
        let c2 = matmul_nt(&Tensor::phantom(&[4, 6]), &Tensor::phantom(&[5, 6]));
        assert_eq!(c2.shape(), &[4, 5]);
        let c3 = matmul_tn(&Tensor::phantom(&[6, 4]), &Tensor::phantom(&[6, 5]));
        assert_eq!(c3.shape(), &[4, 5]);
    }

    #[test]
    #[should_panic(expected = "inner dims")]
    fn shape_mismatch_panics_even_for_phantom() {
        let a = Tensor::phantom(&[4, 6]);
        let b = Tensor::phantom(&[7, 5]);
        let _ = matmul_nn(&a, &b);
    }

    #[test]
    fn flop_counter_counts() {
        reset_flops();
        let a = randt(&[8, 16], 40);
        let b = randt(&[16, 4], 41);
        let _ = matmul_nn(&a, &b);
        assert_eq!(flops_executed(), 2 * 8 * 16 * 4);
    }
}
