//! Matrix multiplication in the three forms the paper uses.
//!
//! Three forms, matching the paper's §3.1.2 (Eq. 3–5): `C = AB`, `C = ABᵀ`,
//! `C = AᵀB`. These are the per-device compute of the whole framework — the
//! role cuBLAS plays on the authors' V100s and the Pallas L1 kernel plays on
//! TPU — so since PR 2 all three forms drive the explicit-SIMD microkernel
//! subsystem in [`super::kernel`]:
//!
//! * an 8×8 register-blocked microkernel (AVX2+FMA on x86-64, NEON on
//!   aarch64, portable scalar fallback) selected **once at startup** by
//!   runtime CPU-feature detection — see `kernel::selected`;
//! * operands packed into microkernel-aligned micro-panels per cache block
//!   (`KC`/`NC` blocking, `MR`-row strips — the strips are also the unit of
//!   multi-core work sharing since PR 3), so nn / nt / tn differ only in
//!   pack strides: the inner loop never sees a transpose;
//! * edge tiles (m, n remainders) computed against zero-padded panels and
//!   written back through a masked copy — every (m, n, k) ≥ 1 is legal and
//!   verified bit-for-bit against a reference kernel by
//!   `tests/kernel_parity.rs`.
//!
//! This module keeps the *accounting contract* around the kernels: the
//! global flop counter (2·M·N·K per call, read by the metrics layer) and
//! the phantom short-circuit — phantom inputs return a phantom output of
//! the correct shape *after* shape checking, so the simulated benches
//! exercise the same contract the numeric path does. Since the PR-3
//! multi-core driver, the counter is fed by the *driver* (`kernel`):
//! each participating thread tallies the tiles it computed and the merged
//! total — exactly 2·M·N·K — lands here once per call, so concurrent
//! threaded gemms report the same flops the serial driver would
//! (`tests/kernel_threads.rs` pins the exactness). Phantom matmuls still
//! never touch the counter.
//!
//! Measured throughput lives in `BENCH_PR2.json` (per-kernel GF/s on the
//! 256³ microbench plus the scalar-vs-SIMD ratio); design details and the
//! dispatch policy are documented in [`super::kernel`].

use super::kernel;
use super::Tensor;
use std::sync::atomic::{AtomicU64, Ordering};

/// Global flop counter (2·M·N·K per matmul), advanced by the kernel driver
/// with each call's merged per-thread tally (atomic add, so concurrent
/// gemms — whether from SPMD rank threads or the gemm pool — never lose
/// counts). The metrics layer reads and resets this around timed regions;
/// relaxed ordering is fine for a counter. The companion bytes-cloned
/// counter lives in [`crate::metrics`].
static FLOPS: AtomicU64 = AtomicU64::new(0);

pub fn flops_executed() -> u64 {
    FLOPS.load(Ordering::Relaxed)
}

pub fn reset_flops() {
    FLOPS.store(0, Ordering::Relaxed);
}

/// Credit one gemm's merged flop tally (called by `kernel::gemm_strided_t`
/// after the per-thread counters are joined).
pub(crate) fn add_flops(flops: u64) {
    FLOPS.fetch_add(flops, Ordering::Relaxed);
}

/// `C = A · B` for A:(m,k), B:(k,n): both operands row-major, unit column
/// stride on each — pack strides `(k, 1)` / `(n, 1)`.
pub fn matmul_nn(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, ka) = a.dims2();
    let (kb, n) = b.dims2();
    assert_eq!(ka, kb, "matmul_nn: inner dims {ka} vs {kb} (A {:?}, B {:?})", a.shape(), b.shape());
    let (Some(ad), Some(bd)) = (a.try_data(), b.try_data()) else {
        return Tensor::phantom(&[m, n]);
    };
    let mut c = vec![0.0f32; m * n];
    kernel::gemm_strided(kernel::selected(), m, n, ka, ad, ka, 1, bd, n, 1, &mut c);
    Tensor::from_vec(&[m, n], c)
}

/// `C = A · Bᵀ` for A:(m,k), B:(n,k): the logical `(k,n)` right operand is
/// B read through swapped strides `(1, k)` — the B pack walks B's rows as
/// columns, and the microkernel never sees the transpose.
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, ka) = a.dims2();
    let (n, kb) = b.dims2();
    assert_eq!(ka, kb, "matmul_nt: inner dims {ka} vs {kb} (A {:?}, B {:?})", a.shape(), b.shape());
    let (Some(ad), Some(bd)) = (a.try_data(), b.try_data()) else {
        return Tensor::phantom(&[m, n]);
    };
    let mut c = vec![0.0f32; m * n];
    kernel::gemm_strided(kernel::selected(), m, n, ka, ad, ka, 1, bd, 1, ka, &mut c);
    Tensor::from_vec(&[m, n], c)
}

/// `C = Aᵀ · B` for A:(k,m), B:(k,n): the logical `(m,k)` left operand is
/// A read through swapped strides `(1, m)`.
pub fn matmul_tn(a: &Tensor, b: &Tensor) -> Tensor {
    let (ka, m) = a.dims2();
    let (kb, n) = b.dims2();
    assert_eq!(ka, kb, "matmul_tn: inner dims {ka} vs {kb} (A {:?}, B {:?})", a.shape(), b.shape());
    let (Some(ad), Some(bd)) = (a.try_data(), b.try_data()) else {
        return Tensor::phantom(&[m, n]);
    };
    let mut c = vec![0.0f32; m * n];
    kernel::gemm_strided(kernel::selected(), m, n, ka, ad, 1, m, bd, n, 1, &mut c);
    Tensor::from_vec(&[m, n], c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    /// Naive triple loop oracle.
    fn naive_nn(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = a.dims2();
        let (_, n) = b.dims2();
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for kk in 0..k {
                    acc += a.at2(i, kk) * b.at2(kk, j);
                }
                c[i * n + j] = acc;
            }
        }
        Tensor::from_vec(&[m, n], c)
    }

    fn randt(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        Tensor::randn(shape, 1.0, &mut rng)
    }

    #[test]
    fn nn_matches_naive_various_shapes() {
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (64, 64, 64), (65, 33, 129), (128, 1, 17)] {
            let a = randt(&[m, k], 1 + m as u64);
            let b = randt(&[k, n], 2 + n as u64);
            let c = matmul_nn(&a, &b);
            let r = naive_nn(&a, &b);
            assert!(c.max_abs_diff(&r) < 1e-3, "shape ({m},{k},{n})");
        }
    }

    #[test]
    fn nt_equals_nn_with_transpose() {
        for &(m, k, n) in &[(4, 6, 5), (65, 64, 63), (17, 129, 31)] {
            let a = randt(&[m, k], 10);
            let b = randt(&[n, k], 11);
            let c = matmul_nt(&a, &b);
            let r = matmul_nn(&a, &b.transpose());
            assert!(c.max_abs_diff(&r) < 1e-3, "shape ({m},{k},{n})");
        }
    }

    #[test]
    fn nt_tail_only_small_k() {
        // k < 8: shallower than one full microkernel depth step group —
        // exercises short packed panels (the exhaustive 1..=17 sweep lives
        // in tests/kernel_parity.rs).
        for k in 1..8usize {
            let (m, n) = (5, 6);
            let a = randt(&[m, k], 100 + k as u64);
            let b = randt(&[n, k], 200 + k as u64);
            let c = matmul_nt(&a, &b);
            let r = matmul_nn(&a, &b.transpose());
            assert!(c.max_abs_diff(&r) < 1e-4, "tail-only k={k}");
        }
    }

    #[test]
    fn nt_unroll_boundary_ks() {
        // k straddling multiples of the 8-wide microkernel tile: both full
        // and remainder panels contribute.
        for k in [8usize, 9, 15, 16, 17, 24] {
            let (m, n) = (3, 4);
            let a = randt(&[m, k], 300 + k as u64);
            let b = randt(&[n, k], 400 + k as u64);
            let c = matmul_nt(&a, &b);
            let r = matmul_nn(&a, &b.transpose());
            assert!(c.max_abs_diff(&r) < 1e-3, "boundary k={k}");
        }
    }

    #[test]
    fn tn_equals_nn_with_transpose() {
        for &(m, k, n) in &[(4, 6, 5), (65, 64, 63), (31, 129, 17), (7, 3, 9), (5, 2, 4)] {
            let a = randt(&[k, m], 20);
            let b = randt(&[k, n], 21);
            let c = matmul_tn(&a, &b);
            let r = matmul_nn(&a.transpose(), &b);
            assert!(c.max_abs_diff(&r) < 1e-3, "shape ({m},{k},{n})");
        }
    }

    #[test]
    fn identity_is_neutral() {
        let a = randt(&[8, 8], 30);
        let mut eye = Tensor::zeros(&[8, 8]);
        for i in 0..8 {
            eye.data_mut()[i * 8 + i] = 1.0;
        }
        assert!(a.matmul(&eye).max_abs_diff(&a) < 1e-6);
        assert!(eye.matmul(&a).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn kernels_accept_zero_copy_views() {
        // Operands that are views into a larger buffer (nonzero offset)
        // must compute identically to fresh copies.
        let big = randt(&[8, 6], 40);
        let a_view = big.block(2, 0, 3, 6); // zero-copy row range
        assert!(a_view.shares_storage(&big));
        let a_copy = Tensor::from_vec(&[3, 6], a_view.data().to_vec());
        let b = randt(&[6, 4], 41);
        assert_eq!(matmul_nn(&a_view, &b), matmul_nn(&a_copy, &b));
        let bt = randt(&[4, 6], 42);
        assert_eq!(matmul_nt(&a_view, &bt), matmul_nt(&a_copy, &bt));
    }

    #[test]
    fn phantom_inputs_give_phantom_output() {
        let a = Tensor::phantom(&[4, 6]);
        let b = randt(&[6, 5], 1);
        let c = matmul_nn(&a, &b);
        assert!(c.is_phantom());
        assert_eq!(c.shape(), &[4, 5]);
        let c2 = matmul_nt(&Tensor::phantom(&[4, 6]), &Tensor::phantom(&[5, 6]));
        assert_eq!(c2.shape(), &[4, 5]);
        let c3 = matmul_tn(&Tensor::phantom(&[6, 4]), &Tensor::phantom(&[6, 5]));
        assert_eq!(c3.shape(), &[4, 5]);
    }

    #[test]
    #[should_panic(expected = "inner dims")]
    fn shape_mismatch_panics_even_for_phantom() {
        let a = Tensor::phantom(&[4, 6]);
        let b = Tensor::phantom(&[7, 5]);
        let _ = matmul_nn(&a, &b);
    }

    #[test]
    fn concurrent_matmuls_never_lose_flop_counts() {
        // Rank-style threads all matmul'ing at once: each call's merged
        // per-thread tally lands atomically, so the delta is at least the
        // sum of the four exact totals (other tests in this process can
        // only add more, never subtract). The shape is large enough to
        // engage the threaded driver; contention for the gemm pool makes
        // some callers take the serial fallback — both paths must count
        // identically. The bit-level exactness (each call returning
        // precisely 2mnk) is pinned in tests/kernel_threads.rs where the
        // per-call tallies are observable.
        let before = flops_executed();
        let handles: Vec<_> = (0..4u64)
            .map(|t| {
                std::thread::spawn(move || {
                    // 2·128³ ≈ 4.2M flops — above kernel::threads::
                    // PAR_MIN_FLOPS, so the auto path genuinely goes
                    // through the pool (not the serial short-circuit).
                    let a = randt(&[128, 128], 50 + t);
                    let b = randt(&[128, 128], 60 + t);
                    let _ = matmul_nn(&a, &b);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert!(flops_executed() - before >= 4 * 2 * 128 * 128 * 128);
    }

    #[test]
    fn flop_counter_counts() {
        // Other tests run concurrently in this process, so assert on the
        // delta as a lower bound rather than an absolute value.
        let before = flops_executed();
        let a = randt(&[8, 16], 40);
        let b = randt(&[16, 4], 41);
        let _ = matmul_nn(&a, &b);
        assert!(flops_executed() - before >= 2 * 8 * 16 * 4);
    }
}
