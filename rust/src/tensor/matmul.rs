//! Blocked matrix-multiplication kernels.
//!
//! Three forms, matching the paper's §3.1.2 (Eq. 3–5): `C = AB`, `C = ABᵀ`,
//! `C = AᵀB`. These are the per-device compute of the whole framework — the
//! role cuBLAS plays on the authors' V100s and the Pallas L1 kernel plays on
//! TPU — so they are written as cache-blocked loops with packed B-panels and
//! multi-accumulator inner kernels, plus a per-call flop counter feeding the
//! metrics layer.
//!
//! Kernel structure (§Perf of EXPERIMENTS.md):
//! * `matmul_nn` packs each `(k-block × j-block)` panel of B into a
//!   contiguous scratch tile (one pack amortized over all `m` rows) and
//!   applies 4 rank-1 updates per pass over the C row segment — 4× fewer
//!   C-row traversals than the scalar `ikj` loop.
//! * `matmul_nt` is a dot-product kernel over two contiguous rows; the dot
//!   runs on 8 independent accumulators to break the serial FP-add
//!   dependency chain (the k<8 remainder takes a scalar tail, exercised by
//!   the tail-only tests below).
//! * `matmul_tn` streams 4 rank-1 updates per C row pass with contiguous
//!   row access on A, B and C.
//!
//! Phantom inputs short-circuit to a phantom output of the correct shape;
//! shape *checking* still happens first, so the simulated benches exercise
//! the same contract the numeric path does.

use super::Tensor;
use std::sync::atomic::{AtomicU64, Ordering};

/// Global flop counter (2·M·N·K per matmul). The metrics layer reads and
/// resets this around timed regions; relaxed ordering is fine for a counter.
/// The companion bytes-cloned counter lives in [`crate::metrics`].
static FLOPS: AtomicU64 = AtomicU64::new(0);

pub fn flops_executed() -> u64 {
    FLOPS.load(Ordering::Relaxed)
}

pub fn reset_flops() {
    FLOPS.store(0, Ordering::Relaxed);
}

fn count(m: usize, n: usize, k: usize) {
    FLOPS.fetch_add(2 * (m as u64) * (n as u64) * (k as u64), Ordering::Relaxed);
}

/// Cache block edge (elements). 64×64 f32 tiles = 16 KiB per operand tile,
/// comfortably inside L1+L2 on any x86 host; chosen by the §Perf sweep in
/// EXPERIMENTS.md.
const BLOCK: usize = 64;

/// `C = A · B` for A:(m,k), B:(k,n).
///
/// For each `(k-block, j-block)` pair the B panel is packed into a
/// contiguous scratch tile, then every row of A streams through it with a
/// 4-wide rank-1-update kernel: `c[j] += a0·b0[j] + a1·b1[j] + a2·b2[j] +
/// a3·b3[j]`. The pack cost is `O(k·n)` total and is repaid `m/BLOCK`
/// times over.
pub fn matmul_nn(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, ka) = a.dims2();
    let (kb, n) = b.dims2();
    assert_eq!(ka, kb, "matmul_nn: inner dims {ka} vs {kb} (A {:?}, B {:?})", a.shape(), b.shape());
    let (Some(ad), Some(bd)) = (a.try_data(), b.try_data()) else {
        return Tensor::phantom(&[m, n]);
    };
    count(m, n, ka);
    let k = ka;
    let mut c = vec![0.0f32; m * n];
    let mut bpack = vec![0.0f32; BLOCK * BLOCK];
    for jb in (0..n).step_by(BLOCK) {
        let je = (jb + BLOCK).min(n);
        let jw = je - jb;
        for kb_ in (0..k).step_by(BLOCK) {
            let ke = (kb_ + BLOCK).min(k);
            let kw = ke - kb_;
            // Pack B[kb_..ke, jb..je] rows contiguously.
            for kk in 0..kw {
                let src = (kb_ + kk) * n + jb;
                bpack[kk * jw..(kk + 1) * jw].copy_from_slice(&bd[src..src + jw]);
            }
            for i in 0..m {
                let arow = &ad[i * k + kb_..i * k + ke];
                let crow = &mut c[i * n + jb..i * n + je];
                let k4 = kw - kw % 4;
                let mut kk = 0;
                while kk < k4 {
                    let a0 = arow[kk];
                    let a1 = arow[kk + 1];
                    let a2 = arow[kk + 2];
                    let a3 = arow[kk + 3];
                    let b0 = &bpack[kk * jw..kk * jw + jw];
                    let b1 = &bpack[(kk + 1) * jw..(kk + 1) * jw + jw];
                    let b2 = &bpack[(kk + 2) * jw..(kk + 2) * jw + jw];
                    let b3 = &bpack[(kk + 3) * jw..(kk + 3) * jw + jw];
                    for j in 0..jw {
                        crow[j] += a0 * b0[j] + a1 * b1[j] + a2 * b2[j] + a3 * b3[j];
                    }
                    kk += 4;
                }
                while kk < kw {
                    let aik = arow[kk];
                    let brow = &bpack[kk * jw..kk * jw + jw];
                    if aik != 0.0 {
                        for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                            *cv += aik * bv;
                        }
                    }
                    kk += 1;
                }
            }
        }
    }
    Tensor::from_vec(&[m, n], c)
}

/// `C = A · Bᵀ` for A:(m,k), B:(n,k).
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, ka) = a.dims2();
    let (n, kb) = b.dims2();
    assert_eq!(ka, kb, "matmul_nt: inner dims {ka} vs {kb} (A {:?}, B {:?})", a.shape(), b.shape());
    let (Some(ad), Some(bd)) = (a.try_data(), b.try_data()) else {
        return Tensor::phantom(&[m, n]);
    };
    count(m, n, ka);
    let k = ka;
    let mut c = vec![0.0f32; m * n];
    // Both A and B rows are contiguous here, so a dot-product kernel is the
    // natural fit; block over (i, j) to keep B rows resident. The dot is
    // split across 8 independent accumulators to break the serial FP add
    // dependency chain (§Perf: 2.85 → ~9 GF/s with 4 accumulators on the
    // 256³ microbench; 8 keeps the FMA ports saturated on wider cores).
    for ib in (0..m).step_by(BLOCK) {
        let ie = (ib + BLOCK).min(m);
        for jb in (0..n).step_by(BLOCK) {
            let je = (jb + BLOCK).min(n);
            for i in ib..ie {
                let arow = &ad[i * k..(i + 1) * k];
                for j in jb..je {
                    let brow = &bd[j * k..(j + 1) * k];
                    let chunks = k / 8;
                    let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
                    let (mut a4, mut a5, mut a6, mut a7) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
                    for t in 0..chunks {
                        let base = t * 8;
                        a0 += arow[base] * brow[base];
                        a1 += arow[base + 1] * brow[base + 1];
                        a2 += arow[base + 2] * brow[base + 2];
                        a3 += arow[base + 3] * brow[base + 3];
                        a4 += arow[base + 4] * brow[base + 4];
                        a5 += arow[base + 5] * brow[base + 5];
                        a6 += arow[base + 6] * brow[base + 6];
                        a7 += arow[base + 7] * brow[base + 7];
                    }
                    let mut acc = ((a0 + a1) + (a2 + a3)) + ((a4 + a5) + (a6 + a7));
                    for t in chunks * 8..k {
                        acc += arow[t] * brow[t];
                    }
                    c[i * n + j] = acc;
                }
            }
        }
    }
    Tensor::from_vec(&[m, n], c)
}

/// `C = Aᵀ · B` for A:(k,m), B:(k,n).
pub fn matmul_tn(a: &Tensor, b: &Tensor) -> Tensor {
    let (ka, m) = a.dims2();
    let (kb, n) = b.dims2();
    assert_eq!(ka, kb, "matmul_tn: inner dims {ka} vs {kb} (A {:?}, B {:?})", a.shape(), b.shape());
    let (Some(ad), Some(bd)) = (a.try_data(), b.try_data()) else {
        return Tensor::phantom(&[m, n]);
    };
    count(m, n, ka);
    let k = ka;
    let mut c = vec![0.0f32; m * n];
    // k is the outer loop: for each row of A (length m) and row of B
    // (length n), rank-1 update of C. Row accesses are all contiguous; four
    // k-rows are fused per C pass to quarter the C traffic.
    for kb_ in (0..k).step_by(BLOCK) {
        let ke = (kb_ + BLOCK).min(k);
        let kw = ke - kb_;
        let k4 = kw - kw % 4;
        let mut kk = 0;
        while kk < k4 {
            let a0 = &ad[(kb_ + kk) * m..(kb_ + kk + 1) * m];
            let a1 = &ad[(kb_ + kk + 1) * m..(kb_ + kk + 2) * m];
            let a2 = &ad[(kb_ + kk + 2) * m..(kb_ + kk + 3) * m];
            let a3 = &ad[(kb_ + kk + 3) * m..(kb_ + kk + 4) * m];
            let b0 = &bd[(kb_ + kk) * n..(kb_ + kk + 1) * n];
            let b1 = &bd[(kb_ + kk + 1) * n..(kb_ + kk + 2) * n];
            let b2 = &bd[(kb_ + kk + 2) * n..(kb_ + kk + 3) * n];
            let b3 = &bd[(kb_ + kk + 3) * n..(kb_ + kk + 4) * n];
            for i in 0..m {
                let (x0, x1, x2, x3) = (a0[i], a1[i], a2[i], a3[i]);
                let crow = &mut c[i * n..(i + 1) * n];
                for j in 0..n {
                    crow[j] += x0 * b0[j] + x1 * b1[j] + x2 * b2[j] + x3 * b3[j];
                }
            }
            kk += 4;
        }
        while kk < kw {
            let arow = &ad[(kb_ + kk) * m..(kb_ + kk + 1) * m];
            let brow = &bd[(kb_ + kk) * n..(kb_ + kk + 1) * n];
            for i in 0..m {
                let aki = arow[i];
                if aki == 0.0 {
                    continue;
                }
                let crow = &mut c[i * n..(i + 1) * n];
                for (cv, &bv) in crow.iter_mut().zip(brow.iter()) {
                    *cv += aki * bv;
                }
            }
            kk += 1;
        }
    }
    Tensor::from_vec(&[m, n], c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    /// Naive triple loop oracle.
    fn naive_nn(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = a.dims2();
        let (_, n) = b.dims2();
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for kk in 0..k {
                    acc += a.at2(i, kk) * b.at2(kk, j);
                }
                c[i * n + j] = acc;
            }
        }
        Tensor::from_vec(&[m, n], c)
    }

    fn randt(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        Tensor::randn(shape, 1.0, &mut rng)
    }

    #[test]
    fn nn_matches_naive_various_shapes() {
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (64, 64, 64), (65, 33, 129), (128, 1, 17)] {
            let a = randt(&[m, k], 1 + m as u64);
            let b = randt(&[k, n], 2 + n as u64);
            let c = matmul_nn(&a, &b);
            let r = naive_nn(&a, &b);
            assert!(c.max_abs_diff(&r) < 1e-3, "shape ({m},{k},{n})");
        }
    }

    #[test]
    fn nt_equals_nn_with_transpose() {
        for &(m, k, n) in &[(4, 6, 5), (65, 64, 63), (17, 129, 31)] {
            let a = randt(&[m, k], 10);
            let b = randt(&[n, k], 11);
            let c = matmul_nt(&a, &b);
            let r = matmul_nn(&a, &b.transpose());
            assert!(c.max_abs_diff(&r) < 1e-3, "shape ({m},{k},{n})");
        }
    }

    #[test]
    fn nt_tail_only_small_k() {
        // k < 8 exercises only the scalar remainder of the 8-accumulator
        // dot kernel (the tail path the unrolled loop never touches).
        for k in 1..8usize {
            let (m, n) = (5, 6);
            let a = randt(&[m, k], 100 + k as u64);
            let b = randt(&[n, k], 200 + k as u64);
            let c = matmul_nt(&a, &b);
            let r = matmul_nn(&a, &b.transpose());
            assert!(c.max_abs_diff(&r) < 1e-4, "tail-only k={k}");
        }
    }

    #[test]
    fn nt_unroll_boundary_ks() {
        // k straddling multiples of the 8-wide unroll: both the unrolled
        // body and the remainder contribute.
        for k in [8usize, 9, 15, 16, 17, 24] {
            let (m, n) = (3, 4);
            let a = randt(&[m, k], 300 + k as u64);
            let b = randt(&[n, k], 400 + k as u64);
            let c = matmul_nt(&a, &b);
            let r = matmul_nn(&a, &b.transpose());
            assert!(c.max_abs_diff(&r) < 1e-3, "boundary k={k}");
        }
    }

    #[test]
    fn tn_equals_nn_with_transpose() {
        for &(m, k, n) in &[(4, 6, 5), (65, 64, 63), (31, 129, 17), (7, 3, 9), (5, 2, 4)] {
            let a = randt(&[k, m], 20);
            let b = randt(&[k, n], 21);
            let c = matmul_tn(&a, &b);
            let r = matmul_nn(&a.transpose(), &b);
            assert!(c.max_abs_diff(&r) < 1e-3, "shape ({m},{k},{n})");
        }
    }

    #[test]
    fn identity_is_neutral() {
        let a = randt(&[8, 8], 30);
        let mut eye = Tensor::zeros(&[8, 8]);
        for i in 0..8 {
            eye.data_mut()[i * 8 + i] = 1.0;
        }
        assert!(a.matmul(&eye).max_abs_diff(&a) < 1e-6);
        assert!(eye.matmul(&a).max_abs_diff(&a) < 1e-6);
    }

    #[test]
    fn kernels_accept_zero_copy_views() {
        // Operands that are views into a larger buffer (nonzero offset)
        // must compute identically to fresh copies.
        let big = randt(&[8, 6], 40);
        let a_view = big.block(2, 0, 3, 6); // zero-copy row range
        assert!(a_view.shares_storage(&big));
        let a_copy = Tensor::from_vec(&[3, 6], a_view.data().to_vec());
        let b = randt(&[6, 4], 41);
        assert_eq!(matmul_nn(&a_view, &b), matmul_nn(&a_copy, &b));
        let bt = randt(&[4, 6], 42);
        assert_eq!(matmul_nt(&a_view, &bt), matmul_nt(&a_copy, &bt));
    }

    #[test]
    fn phantom_inputs_give_phantom_output() {
        let a = Tensor::phantom(&[4, 6]);
        let b = randt(&[6, 5], 1);
        let c = matmul_nn(&a, &b);
        assert!(c.is_phantom());
        assert_eq!(c.shape(), &[4, 5]);
        let c2 = matmul_nt(&Tensor::phantom(&[4, 6]), &Tensor::phantom(&[5, 6]));
        assert_eq!(c2.shape(), &[4, 5]);
        let c3 = matmul_tn(&Tensor::phantom(&[6, 4]), &Tensor::phantom(&[6, 5]));
        assert_eq!(c3.shape(), &[4, 5]);
    }

    #[test]
    #[should_panic(expected = "inner dims")]
    fn shape_mismatch_panics_even_for_phantom() {
        let a = Tensor::phantom(&[4, 6]);
        let b = Tensor::phantom(&[7, 5]);
        let _ = matmul_nn(&a, &b);
    }

    #[test]
    fn flop_counter_counts() {
        // Other tests run concurrently in this process, so assert on the
        // delta as a lower bound rather than an absolute value.
        let before = flops_executed();
        let a = randt(&[8, 16], 40);
        let b = randt(&[16, 4], 41);
        let _ = matmul_nn(&a, &b);
        assert!(flops_executed() - before >= 2 * 8 * 16 * 4);
    }
}
