//! AVX2+FMA microkernel (x86-64).
//!
//! The 8×8 C tile is eight `__m256` accumulators — one YMM register per
//! C-tile row, all eight columns per register. Per depth step: one 256-bit
//! load of the packed B row, eight scalar broadcasts of the packed A column,
//! eight `vfmaddps`. With 8 accumulators + 1 B vector + 1 broadcast register
//! the kernel fits comfortably in the 16 YMM architectural registers, and
//! the 8 independent FMA chains keep both FMA ports saturated (the
//! dependency distance per accumulator is the full loop iteration).
//!
//! Expected upper bound: 2 FMA issues/cycle × 8 lanes × 2 flops ≈ 32
//! flops/cycle/core; packing overhead and edge tiles land the 256³
//! microbench typically at 35–60 GF/s on 2020s desktop parts, vs ~5–10 GF/s
//! for the scalar path (measured numbers in `BENCH_PR2.json`).
//!
//! Only compiled on `x86_64` with the `simd` feature; only *dispatched*
//! when `is_x86_feature_detected!("avx2") && ("fma")` at startup.

use super::{MR, NR};
use core::arch::x86_64::{
    __m256, _mm256_add_ps, _mm256_fmadd_ps, _mm256_loadu_ps, _mm256_set1_ps, _mm256_setzero_ps,
    _mm256_storeu_ps,
};

/// `C[8×8] += Apanel(kc×8) · Bpanel(kc×8)`; see [`super::MicroKernel`].
///
/// # Safety
/// As [`super::MicroKernel`], plus the host CPU must support AVX2 and FMA
/// (guaranteed when this kernel is obtained from [`super::available`]).
#[target_feature(enable = "avx2,fma")]
pub unsafe fn microkernel(kc: usize, a: *const f32, b: *const f32, c: *mut f32, ldc: usize) {
    const { assert!(NR == 8, "one __m256 per C-tile row") };
    let mut acc: [__m256; MR] = [_mm256_setzero_ps(); MR];
    for kk in 0..kc {
        let bv = _mm256_loadu_ps(b.add(kk * NR));
        let ap = a.add(kk * MR);
        for (r, accr) in acc.iter_mut().enumerate() {
            // vbroadcastss from the packed A panel, then one fused
            // multiply-add into this row's accumulator register.
            *accr = _mm256_fmadd_ps(_mm256_set1_ps(*ap.add(r)), bv, *accr);
        }
    }
    for (r, &accr) in acc.iter().enumerate() {
        let cp = c.add(r * ldc);
        _mm256_storeu_ps(cp, _mm256_add_ps(_mm256_loadu_ps(cp), accr));
    }
}
