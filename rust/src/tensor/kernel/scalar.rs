//! Portable scalar microkernel — the fallback on hosts without AVX2/NEON
//! and the only kernel when the `simd` cargo feature is disabled.
//!
//! Same register-blocking shape as the SIMD variants (an MR×NR accumulator
//! tile, k-sequential per-element chains) but with plain `a * b + acc`
//! arithmetic: no fused rounding, so an autovectorizing compiler is free to
//! keep it fast on any ISA, and its results are bit-identical to a naive
//! same-order unfused triple loop (pinned by `kernel::tests`).

use super::{MR, NR};

/// `C[MR×NR] += Apanel(kc×MR) · Bpanel(kc×NR)`; see [`super::MicroKernel`]
/// for the full safety contract.
///
/// # Safety
/// `a`/`b` must point to `kc*MR` / `kc*NR` readable f32s; `c` must be an
/// MR×NR writable window at row stride `ldc`.
pub unsafe fn microkernel(kc: usize, a: *const f32, b: *const f32, c: *mut f32, ldc: usize) {
    let mut acc = [[0.0f32; NR]; MR];
    for kk in 0..kc {
        let ap = a.add(kk * MR);
        let bp = b.add(kk * NR);
        for (r, row) in acc.iter_mut().enumerate() {
            let av = *ap.add(r);
            for (j, cell) in row.iter_mut().enumerate() {
                *cell += av * *bp.add(j);
            }
        }
    }
    for (r, row) in acc.iter().enumerate() {
        let cp = c.add(r * ldc);
        for (j, &cell) in row.iter().enumerate() {
            *cp.add(j) += cell;
        }
    }
}
