//! NEON microkernel (aarch64).
//!
//! The 8×8 C tile is sixteen `float32x4_t` accumulators (two 128-bit
//! registers per C-tile row). Per depth step: two 128-bit loads of the
//! packed B row, eight scalar broadcasts of the packed A column, sixteen
//! `fmla` (vfmaq_f32, fused). aarch64 has 32 architectural vector
//! registers, so 16 accumulators + 2 B vectors + a broadcast register leave
//! ample headroom; accumulation order per output element is identical to
//! the AVX2 and reference kernels (k-sequential fused chain), so parity is
//! bit-for-bit.
//!
//! Only compiled on `aarch64` with the `simd` feature; dispatched when
//! `is_aarch64_feature_detected!("neon")` (always true on aarch64 in
//! practice — NEON is mandatory in ARMv8-A — but checked anyway).

use super::{MR, NR};
use core::arch::aarch64::{
    float32x4_t, vaddq_f32, vdupq_n_f32, vfmaq_f32, vld1q_f32, vst1q_f32,
};

/// `C[8×8] += Apanel(kc×8) · Bpanel(kc×8)`; see [`super::MicroKernel`].
///
/// # Safety
/// As [`super::MicroKernel`], plus the host CPU must support NEON
/// (guaranteed when this kernel is obtained from [`super::available`]).
#[target_feature(enable = "neon")]
pub unsafe fn microkernel(kc: usize, a: *const f32, b: *const f32, c: *mut f32, ldc: usize) {
    const { assert!(NR == 8, "two float32x4 per C-tile row") };
    let zero = vdupq_n_f32(0.0);
    let mut acc: [[float32x4_t; 2]; MR] = [[zero; 2]; MR];
    for kk in 0..kc {
        let bp = b.add(kk * NR);
        let b0 = vld1q_f32(bp);
        let b1 = vld1q_f32(bp.add(4));
        let ap = a.add(kk * MR);
        for (r, accr) in acc.iter_mut().enumerate() {
            let av = vdupq_n_f32(*ap.add(r));
            accr[0] = vfmaq_f32(accr[0], av, b0);
            accr[1] = vfmaq_f32(accr[1], av, b1);
        }
    }
    for (r, accr) in acc.iter().enumerate() {
        let cp = c.add(r * ldc);
        vst1q_f32(cp, vaddq_f32(vld1q_f32(cp), accr[0]));
        vst1q_f32(cp.add(4), vaddq_f32(vld1q_f32(cp.add(4)), accr[1]));
    }
}
