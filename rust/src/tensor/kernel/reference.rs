//! Fused-rounding reference microkernel — the parity oracle.
//!
//! Identical blocking and accumulation order to the SIMD kernels, with
//! `f32::mul_add` (round-once fused multiply-add) as the arithmetic
//! primitive. A hardware FMA instruction and `mul_add`'s software fallback
//! are both correctly rounded, so for any `k <= KC` (single k-block: one
//! accumulation chain per output element) this kernel's results are
//! **bit-identical** to the AVX2 and NEON kernels on every input — the
//! property the parity suite (`tests/kernel_parity.rs`) asserts. Not listed
//! in [`super::available`]: without hardware FMA codegen the software
//! `fmaf` path is orders of magnitude slower than [`super::scalar`].

use super::{MR, NR};

/// `C[MR×NR] += Apanel(kc×MR) · Bpanel(kc×NR)` with fused rounding; see
/// [`super::MicroKernel`] for the full safety contract.
///
/// # Safety
/// `a`/`b` must point to `kc*MR` / `kc*NR` readable f32s; `c` must be an
/// MR×NR writable window at row stride `ldc`.
pub unsafe fn microkernel(kc: usize, a: *const f32, b: *const f32, c: *mut f32, ldc: usize) {
    let mut acc = [[0.0f32; NR]; MR];
    for kk in 0..kc {
        let ap = a.add(kk * MR);
        let bp = b.add(kk * NR);
        for (r, row) in acc.iter_mut().enumerate() {
            let av = *ap.add(r);
            for (j, cell) in row.iter_mut().enumerate() {
                *cell = av.mul_add(*bp.add(j), *cell);
            }
        }
    }
    for (r, row) in acc.iter().enumerate() {
        let cp = c.add(r * ldc);
        for (j, &cell) in row.iter().enumerate() {
            *cp.add(j) += cell;
        }
    }
}
