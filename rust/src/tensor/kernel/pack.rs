//! Operand packing into microkernel-aligned micro-panels.
//!
//! The microkernel consumes both operands as `k`-major panels: for each
//! depth step `kk` there are [`MR`](super::MR) consecutive A values (one per
//! C-tile row) and [`NR`](super::NR) consecutive B values (one per C-tile
//! column). Packing happens once per cache block and is amortized over every
//! microkernel invocation that reuses the panel (`~MC/MR` times for B panels,
//! `~NC/NR` times for A panels), which is what lets the inner loop run at
//! register speed on strided source forms (nt reads B column-major, tn reads
//! A column-major — after packing the microkernel cannot tell the difference).
//!
//! Panels at the m/n edges are zero-padded to full MR/NR width. Zero lanes
//! flow through the multiply-accumulate as exact zeros, and the driver's
//! edge write-back discards them, so padding never contaminates results.

use super::{MR, NR};

/// Pack the A micro-panel for C-tile rows `i0..i0+mr_eff` over depth
/// `k0..k0+kc` into `dst` (layout: `kc` groups of `MR` floats), reading the
/// logical operand through strides: `A'[i][kk] = a[i*ars + kk*aks]`.
/// Rows `mr_eff..MR` are zero-filled.
#[allow(clippy::too_many_arguments)]
pub fn pack_a(
    a: &[f32],
    ars: usize,
    aks: usize,
    i0: usize,
    mr_eff: usize,
    k0: usize,
    kc: usize,
    dst: &mut [f32],
) {
    debug_assert!(mr_eff >= 1 && mr_eff <= MR);
    debug_assert!(dst.len() >= kc * MR);
    for (kk, d) in dst.chunks_exact_mut(MR).take(kc).enumerate() {
        let kbase = (k0 + kk) * aks;
        for (r, dr) in d.iter_mut().enumerate() {
            *dr = if r < mr_eff { a[(i0 + r) * ars + kbase] } else { 0.0 };
        }
    }
}

/// Pack the B micro-panel for C-tile columns `j0..j0+nr_eff` over depth
/// `k0..k0+kc` into `dst` (layout: `kc` groups of `NR` floats), reading the
/// logical operand through strides: `B'[kk][j] = b[kk*brs + j*bcs]`.
/// Columns `nr_eff..NR` are zero-filled.
#[allow(clippy::too_many_arguments)]
pub fn pack_b(
    b: &[f32],
    brs: usize,
    bcs: usize,
    k0: usize,
    kc: usize,
    j0: usize,
    nr_eff: usize,
    dst: &mut [f32],
) {
    debug_assert!(nr_eff >= 1 && nr_eff <= NR);
    debug_assert!(dst.len() >= kc * NR);
    if bcs == 1 && nr_eff == NR {
        // Contiguous full-width rows (the nn/tn B form away from the right
        // edge): straight memcpy per depth step.
        for (kk, d) in dst.chunks_exact_mut(NR).take(kc).enumerate() {
            let src = (k0 + kk) * brs + j0;
            d.copy_from_slice(&b[src..src + NR]);
        }
        return;
    }
    for (kk, d) in dst.chunks_exact_mut(NR).take(kc).enumerate() {
        let kbase = (k0 + kk) * brs;
        for (j, dj) in d.iter_mut().enumerate() {
            *dj = if j < nr_eff { b[kbase + (j0 + j) * bcs] } else { 0.0 };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_a_pads_and_orders() {
        // A (3x4) row-major, pack rows 1..3 (mr_eff=2), k 1..4 (kc=3).
        let a: Vec<f32> = (0..12).map(|x| x as f32).collect();
        let mut dst = vec![-1.0f32; 3 * MR];
        pack_a(&a, 4, 1, 1, 2, 1, 3, &mut dst);
        for kk in 0..3 {
            let d = &dst[kk * MR..(kk + 1) * MR];
            assert_eq!(d[0], a[4 + 1 + kk], "row 1, k {kk}");
            assert_eq!(d[1], a[8 + 1 + kk], "row 2, k {kk}");
            assert!(d[2..].iter().all(|&x| x == 0.0), "padding must be zero");
        }
    }

    #[test]
    fn pack_b_strided_matches_contiguous() {
        // B (4x6) row-major vs its transpose read back through strides.
        let b: Vec<f32> = (0..24).map(|x| (x * 7 % 13) as f32).collect();
        let mut bt = vec![0.0f32; 24];
        for kk in 0..4 {
            for j in 0..6 {
                bt[j * 4 + kk] = b[kk * 6 + j];
            }
        }
        let mut d1 = vec![0.0f32; 4 * NR];
        let mut d2 = vec![0.0f32; 4 * NR];
        pack_b(&b, 6, 1, 0, 4, 0, 6, &mut d1);
        pack_b(&bt, 1, 4, 0, 4, 0, 6, &mut d2);
        assert_eq!(d1, d2);
        assert!(d1[6..NR].iter().all(|&x| x == 0.0));
    }
}
