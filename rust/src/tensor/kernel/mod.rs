//! Explicit-SIMD matmul microkernel subsystem.
//!
//! This is the per-device compute engine beneath every matmul form in
//! [`crate::tensor::matmul`] — the role a hand-tuned cuBLAS SGEMM inner
//! kernel plays on the paper's V100s. The design is the classic GEBP
//! (GotoBLAS/BLIS) decomposition:
//!
//! * **Register-blocked microkernel** — an [`MR`]×[`NR`] (8×8) tile of C is
//!   held entirely in registers while streaming through a shared `k` panel:
//!   per `k` step, one NR-wide vector load of B, MR scalar broadcasts of A,
//!   and MR fused multiply-adds. Implemented three times with identical
//!   accumulation order:
//!   - [`avx2`]: `std::arch` x86-64 AVX2+FMA (`__m256`, `_mm256_fmadd_ps`),
//!   - [`neon`]: `std::arch` aarch64 NEON (`float32x4_t` ×2, `vfmaq_f32`),
//!   - [`scalar`]: portable fallback (plain mul+add, autovectorizable),
//!   plus a [`reference`] kernel (`f32::mul_add`, same order) whose results
//!   are bit-identical to the FMA kernels — the oracle of the parity suite.
//! * **Packing** ([`pack`]) — operand panels are repacked into
//!   microkernel-aligned layout (`kc`-major, MR/NR-wide, zero-padded at the
//!   edges) so the inner loop issues only contiguous loads regardless of the
//!   source form (nn / nt / tn are just different pack strides).
//! * **Cache blocking** — [`gemm_strided`] tiles the operation `NC`×`KC`
//!   so the packed B block lives in L2/L3; within a block, `MR`-row strips
//!   of C each pack their A micro-panel (hot in L1) and sweep the
//!   microkernel across the B panels; partial edge tiles compute into a
//!   zero-padded register tile and write back only the valid window. The
//!   strips are also the unit of multi-core work sharing — see
//!   *Threading model & determinism* below.
//! * **Runtime dispatch** — the best kernel is selected once per process
//!   (`is_x86_feature_detected!` / `is_aarch64_feature_detected!`) into a
//!   [`Kernel`] table entry; [`selected`] caches the choice in a `OnceLock`.
//!   `CUBIC_KERNEL=scalar|avx2|neon` overrides the choice (benchmarking and
//!   fallback-path testing), and building with `--no-default-features`
//!   (disabling the `simd` cargo feature) compiles the scalar path only.
//!
//! Edge handling contract: every (m, n, k) is legal, including 1. Remainder
//! tiles in m and n are computed through the same packed microkernel against
//! zero-padded panels, so for `k <= KC` every output element is one
//! k-sequential accumulation chain — which is what makes the parity suite's
//! bit-for-bit comparison against [`reference`] meaningful.
//!
//! # Threading model & determinism
//!
//! Since PR 3 the cache-block driver is multi-core: [`gemm_strided`] runs on
//! a small persistent worker pool ([`threads`]), sharding each
//! `(stripe, pc)` phase across participants — the n axis is cut into
//! [`JC_STRIPE`]-wide stripes of `NC` blocks, the stripe's packed-B panels
//! are built cooperatively (atomic claims over `NR`-wide panels, then a
//! barrier makes them read-only), and `(NC-block, MR-strip)` *tiles* of C
//! are claimed with a second atomic counter, each computed from the
//! claimant's *own* thread-local A-panel scratch. Tile (not just
//! row-strip) claims are what keep wide-n/short-m gemms on every core.
//! The thread count is selected **once at startup** (`CUBIC_THREADS=`
//! override → config/CLI request → available parallelism);
//! [`gemm_strided_t`] drives an explicit count for tests and benches.
//!
//! **Determinism:** every C element belongs to exactly one tile per phase,
//! a tile has exactly one writer, packed panel contents are identical to
//! the serial driver's, and the `pc` (k-block) accumulation loop stays
//! outside the parallel region, separated by barriers (stripes partition
//! the columns, so they never reorder an element's contributions) — so
//! each element sees the same floating-point op sequence in the same order
//! regardless of thread count. Output is **bit-exact for every thread
//! count** (pinned by `tests/kernel_threads.rs` across
//! `CUBIC_THREADS ∈ {1, 2, 3, 4, 8}`), which is also what makes the
//! pool-busy serial fallback safe: a caller that cannot get the pool runs
//! the identical loop on its own core and produces identical bits.
//!
//! **Accounting:** participants keep local flop / packed-byte tallies,
//! merged into the job once on completion; the driver adds the merged
//! totals to the global counters (the flop counter in
//! [`crate::tensor::matmul`], pack bytes in [`crate::metrics`]). The merged
//! flop total equals the serial `2·m·n·k` exactly — concurrent gemms never
//! under- or over-count.

pub mod pack;
pub mod reference;
pub mod scalar;
pub mod threads;

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub mod avx2;
#[cfg(all(feature = "simd", target_arch = "aarch64"))]
pub mod neon;

use std::sync::OnceLock;

/// Microkernel tile height (rows of C held in registers).
pub const MR: usize = 8;
/// Microkernel tile width (columns of C held in registers).
pub const NR: usize = 8;

/// Cache-block depth (k). Also the upper bound on `k` for which the whole
/// accumulation is a single per-element chain (the parity suite relies on
/// this when comparing kernels bit-for-bit).
pub const KC: usize = 256;
/// Historical cache-block height (m). The strip-based driver shards m at
/// [`MR`] granularity instead (each strip's A micro-panel lives in L1);
/// kept as the documented L2 sizing target for A-panel working sets.
pub const MC: usize = 128;
/// Cache-block width (n): columns of B packed per outer block.
pub const NC: usize = 256;

/// Columns of B packed per *parallel stripe* — the width of the shared
/// packed-B buffer the threaded driver claims work from. A stripe holds
/// `JC_STRIPE / NC` cache blocks, so wide-n/short-m gemms expose
/// `(m/MR) · (stripe_cols/NC)` parallel tiles per k-phase instead of the
/// old per-`NC`-block `m/MR`. Bounded so the shared buffer stays ≤
/// `KC · JC_STRIPE` floats (4 MiB) regardless of n.
pub const JC_STRIPE: usize = NC * 16;

/// A packed-panel microkernel:
/// `C[MR×NR] += Apanel(kc×MR) · Bpanel(kc×NR)`, with C at row stride `ldc`.
///
/// # Safety
/// * `a` must point to `kc * MR` readable f32s (k-major MR-wide panels);
/// * `b` must point to `kc * NR` readable f32s (k-major NR-wide panels);
/// * `c` must point to an MR×NR writable window at row stride `ldc`
///   (`c[r*ldc + j]` valid for r < MR, j < NR);
/// * for the SIMD variants, the corresponding CPU feature must be present
///   (guaranteed by [`available`], which only lists detected kernels).
pub type MicroKernel =
    unsafe fn(kc: usize, a: *const f32, b: *const f32, c: *mut f32, ldc: usize);

/// One dispatch-table entry: a named microkernel variant.
#[derive(Clone, Copy)]
pub struct Kernel {
    pub name: &'static str,
    pub mk: MicroKernel,
}

impl std::fmt::Debug for Kernel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Kernel({})", self.name)
    }
}

fn detect() -> Vec<Kernel> {
    let mut v = vec![Kernel { name: "scalar", mk: scalar::microkernel }];
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
        v.push(Kernel { name: "avx2+fma", mk: avx2::microkernel });
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    if std::arch::is_aarch64_feature_detected!("neon") {
        v.push(Kernel { name: "neon", mk: neon::microkernel });
    }
    v
}

/// All kernels usable on this host, scalar first, best last. Stable for the
/// process lifetime; the parity suite and the microbench iterate over this.
pub fn available() -> &'static [Kernel] {
    static KERNELS: OnceLock<Vec<Kernel>> = OnceLock::new();
    KERNELS.get_or_init(detect)
}

/// The kernel every matmul call dispatches through: the most capable
/// detected variant, unless `CUBIC_KERNEL=<name>` pins one explicitly.
/// Selected once per process.
pub fn selected() -> Kernel {
    static SELECTED: OnceLock<Kernel> = OnceLock::new();
    *SELECTED.get_or_init(|| {
        let avail = available();
        if let Ok(want) = std::env::var("CUBIC_KERNEL") {
            if let Some(k) = avail.iter().find(|k| k.name.starts_with(&want)) {
                return *k;
            }
            eprintln!(
                "CUBIC_KERNEL={want} not available (have: {:?}); using default",
                avail.iter().map(|k| k.name).collect::<Vec<_>>()
            );
        }
        *avail.last().expect("scalar kernel is always available")
    })
}

/// Name of the dispatched kernel (for reports and bench JSON).
pub fn selected_name() -> &'static str {
    selected().name
}

/// The fused-rounding oracle kernel (not in [`available`]: it is built for
/// bit-exactness against the FMA kernels, not speed).
pub fn reference_kernel() -> Kernel {
    Kernel { name: "reference-fma", mk: reference::microkernel }
}

/// `C += A' · B'` where the logical operands are addressed through strides:
/// `A'[i][kk] = a[i*ars + kk*aks]` (m×k) and `B'[kk][j] = b[kk*brs + j*bcs]`
/// (k×n). C is row-major m×n. The three matmul forms are:
///
/// | form | A strides (ars, aks) | B strides (brs, bcs) |
/// |------|----------------------|----------------------|
/// | nn   | `(k, 1)`             | `(n, 1)`             |
/// | nt   | `(k, 1)`             | `(1, k)`             |
/// | tn   | `(1, m)`             | `(n, 1)`             |
///
/// Accumulating (`+=`) rather than overwriting keeps k-blocking trivial;
/// callers that want `C = A·B` pass a zeroed `c`.
///
/// Runs on the startup-selected thread count
/// ([`threads::selected_threads`]) when the matmul is large enough to
/// amortize the per-block barriers ([`threads::PAR_MIN_FLOPS`]); smaller
/// calls, `CUBIC_THREADS=1`, and pool-busy contention all take the
/// bit-identical serial loop (see the module docs on determinism).
#[allow(clippy::too_many_arguments)]
pub fn gemm_strided(
    kern: Kernel,
    m: usize,
    n: usize,
    kdim: usize,
    a: &[f32],
    ars: usize,
    aks: usize,
    b: &[f32],
    brs: usize,
    bcs: usize,
    c: &mut [f32],
) {
    let flops = 2 * (m as u64) * (n as u64) * (kdim as u64);
    let t = if flops >= threads::PAR_MIN_FLOPS { threads::selected_threads() } else { 1 };
    gemm_strided_t(kern, t, m, n, kdim, a, ars, aks, b, brs, bcs, c);
}

/// [`gemm_strided`] with an explicit thread count (no size threshold:
/// `threads` participants are used whenever `threads > 1` and the pool is
/// free, clamped only by the number of `MR`-row strips). Returns the flops
/// this call executed, merged from the per-thread tallies — exactly
/// `2·m·n·k`, which the concurrency battery asserts. The global flop /
/// pack-byte counters are also advanced by the same amounts.
#[allow(clippy::too_many_arguments)]
pub fn gemm_strided_t(
    kern: Kernel,
    threads: usize,
    m: usize,
    n: usize,
    kdim: usize,
    a: &[f32],
    ars: usize,
    aks: usize,
    b: &[f32],
    brs: usize,
    bcs: usize,
    c: &mut [f32],
) -> u64 {
    assert_eq!(c.len(), m * n, "gemm_strided: C buffer is {} elems, need {}", c.len(), m * n);
    if m == 0 || n == 0 || kdim == 0 {
        return 0;
    }
    let (flops, pack_bytes) =
        threads::execute(kern, m, n, kdim, a, ars, aks, b, brs, bcs, c, threads);
    debug_assert_eq!(
        flops,
        2 * (m as u64) * (n as u64) * (kdim as u64),
        "merged per-thread flop tallies must equal the serial total"
    );
    super::matmul::add_flops(flops);
    crate::metrics::add_pack_bytes(pack_bytes);
    flops
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Unfused same-order oracle: per output element, one k-sequential
    /// chain of `acc + a*b` — the exact op sequence of the scalar kernel.
    fn naive_unfused(
        m: usize,
        n: usize,
        kdim: usize,
        a: &[f32],
        ars: usize,
        aks: usize,
        b: &[f32],
        brs: usize,
        bcs: usize,
    ) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for kk in 0..kdim {
                    acc += a[i * ars + kk * aks] * b[kk * brs + j * bcs];
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    fn fill(seed: u64, n: usize) -> Vec<f32> {
        let mut rng = crate::rng::Xoshiro256::seed_from_u64(seed);
        let mut v = vec![0.0f32; n];
        rng.fill_normal(&mut v, 1.0);
        v
    }

    #[test]
    fn scalar_kernel_is_bit_exact_vs_unfused_naive() {
        for &(m, n, k) in &[(1, 1, 1), (3, 5, 7), (8, 8, 8), (9, 17, 13), (64, 64, 64), (65, 9, 33)]
        {
            let a = fill(1, m * k);
            let b = fill(2, k * n);
            let mut c = vec![0.0f32; m * n];
            let kern = Kernel { name: "scalar", mk: scalar::microkernel };
            gemm_strided(kern, m, n, k, &a, k, 1, &b, n, 1, &mut c);
            let r = naive_unfused(m, n, k, &a, k, 1, &b, n, 1);
            assert_eq!(c, r, "({m},{n},{k})");
        }
    }

    #[test]
    fn strided_forms_agree_with_explicit_transposes() {
        let (m, n, k) = (11, 13, 17);
        let kern = *available().last().unwrap();
        let a = fill(3, m * k); // row-major (m,k)
        let b = fill(4, k * n); // row-major (k,n)
        let mut c_nn = vec![0.0f32; m * n];
        gemm_strided(kern, m, n, k, &a, k, 1, &b, n, 1, &mut c_nn);
        // nt: hand B as its (n,k) transpose with swapped strides.
        let mut bt = vec![0.0f32; n * k];
        for kk in 0..k {
            for j in 0..n {
                bt[j * k + kk] = b[kk * n + j];
            }
        }
        let mut c_nt = vec![0.0f32; m * n];
        gemm_strided(kern, m, n, k, &a, k, 1, &bt, 1, k, &mut c_nt);
        assert_eq!(c_nn, c_nt);
        // tn: hand A as its (k,m) transpose with swapped strides.
        let mut at = vec![0.0f32; k * m];
        for i in 0..m {
            for kk in 0..k {
                at[kk * m + i] = a[i * k + kk];
            }
        }
        let mut c_tn = vec![0.0f32; m * n];
        gemm_strided(kern, m, n, k, &at, 1, m, &b, n, 1, &mut c_tn);
        assert_eq!(c_nn, c_tn);
    }

    #[test]
    fn multi_kblock_accumulation_is_numerically_sound() {
        // k > KC exercises the C += per-k-block accumulation path.
        let (m, n, k) = (5, 6, 2 * KC + 37);
        let a = fill(5, m * k);
        let b = fill(6, k * n);
        for kern in available() {
            let mut c = vec![0.0f32; m * n];
            gemm_strided(*kern, m, n, k, &a, k, 1, &b, n, 1, &mut c);
            // f64 oracle.
            for i in 0..m {
                for j in 0..n {
                    let want: f64 =
                        (0..k).map(|kk| a[i * k + kk] as f64 * b[kk * n + j] as f64).sum();
                    let got = c[i * n + j] as f64;
                    assert!(
                        (got - want).abs() <= 1e-3 * want.abs().max(1.0),
                        "{}: ({i},{j}) got {got} want {want}",
                        kern.name
                    );
                }
            }
        }
    }

    #[test]
    fn explicit_thread_counts_match_serial_bitwise_and_count_exact_flops() {
        // Edge tiles in m and n plus k > KC (multi-k-block accumulation):
        // the geometry where a threading bug would first break bit-parity.
        let (m, n, k) = (65, 33, 2 * KC + 7);
        let a = fill(11, m * k);
        let b = fill(12, k * n);
        let kern = *available().last().unwrap();
        let mut base = vec![0.0f32; m * n];
        let f1 = gemm_strided_t(kern, 1, m, n, k, &a, k, 1, &b, n, 1, &mut base);
        assert_eq!(f1, 2 * (m * n * k) as u64, "serial tally must equal 2mnk");
        for t in [2usize, 3, 8] {
            let mut c = vec![0.0f32; m * n];
            let ft = gemm_strided_t(kern, t, m, n, k, &a, k, 1, &b, n, 1, &mut c);
            assert_eq!(ft, f1, "thread count {t} must merge to the serial flop total");
            assert_eq!(c, base, "thread count {t} must be bit-exact vs serial");
        }
    }

    #[test]
    fn dispatch_always_has_scalar_and_selected_is_available() {
        let avail = available();
        assert_eq!(avail[0].name, "scalar");
        let sel = selected_name();
        assert!(avail.iter().any(|k| k.name == sel), "selected {sel} not in table");
    }
}
