//! Multi-core GEMM driver: a small persistent worker pool plus the
//! SPMD-style cache-block loop every participant (the calling thread and
//! `t − 1` pool workers) executes cooperatively.
//!
//! ## Work decomposition
//!
//! The n axis is cut into [`JC_STRIPE`]-wide *stripes* (16 `NC` cache
//! blocks; the last stripe may be ragged). For each `(stripe, pc)` phase of
//! the [`super::gemm_strided`] loop nest:
//!
//! 1. **Shared B pack** — the packed-B panels of the whole stripe are built
//!    once into a buffer shared by all participants; its `NR`-wide
//!    micro-panels are claimed with an atomic counter, so packing itself is
//!    parallel and every panel is written by exactly one thread. After a
//!    barrier the stripe is read-only for the rest of the phase.
//! 2. **Tile claims** — participants claim disjoint `(NC-block, MR-strip)`
//!    tiles of C with a second atomic counter (work stealing degenerates to
//!    an atomic fetch-add: idle threads keep claiming until the counter
//!    runs out, so load imbalance self-corrects without deques). A claimant
//!    packs its own A micro-panel into *its* thread-local scratch and
//!    sweeps the microkernel across its block's B panels. Claiming tiles —
//!    not just row strips — is what keeps wide-n/short-m gemms parallel:
//!    an 8-row, 4096-column gemm exposes 16 tiles per phase where the old
//!    per-`NC`-block strip claims exposed one.
//! 3. **Barrier + reset** — one barrier ends the phase (the shared packed-B
//!    stripe may be overwritten next), the barrier leader resets both claim
//!    counters, and a second barrier publishes the reset.
//!
//! ## Determinism (bit-exact for every thread count)
//!
//! Each output element belongs to exactly one tile per phase, and a tile is
//! computed by exactly one thread from packed panels whose contents are
//! identical to the serial driver's (same `pack_a` / `pack_b` calls, same
//! zero padding). The `pc` (k-block) loop is *outside* the parallel claims
//! and separated by barriers, so every element receives its `C +=` k-block
//! contributions in the same ascending-`pc` order as the serial driver —
//! and stripes partition the columns, so striping never reorders any
//! element's contributions either. Threads therefore only change *which
//! core* computes a tile and *when* — never the per-element floating-point
//! op sequence — and the output is bit-identical for every thread count,
//! including 1. The parity battery in `tests/kernel_threads.rs` pins this
//! across `CUBIC_THREADS ∈ {1, 2, 3, 4, 8}`, including wide-n/short-m
//! shapes and n spanning multiple stripes.
//!
//! ## Accounting
//!
//! Every participant keeps *local* flop and packed-byte tallies and merges
//! them into the job's atomics once, on completion; the driver then adds the
//! merged totals to the global counters (`tensor::matmul` flops,
//! `metrics::pack_bytes`). The merged flop total is exactly `2·m·n·k` — the
//! serial number — which `tests/kernel_threads.rs` asserts under concurrent
//! callers.
//!
//! ## Pool lifecycle
//!
//! Workers are spawned lazily on demand, parked on per-worker mailbox
//! condvars between jobs, and live for the process lifetime. Concurrent
//! callers *split* the pool instead of racing for it: each job leases its
//! fair share of the worker budget (`MAX_THREADS − 1` divided by the jobs
//! in flight), spawning new workers as needed up to the budget, so two
//! ranks' matmuls run threaded side by side where the old single-job gate
//! forced one of them serial. A caller whose lease comes back empty
//! (budget exhausted) runs the identical loop serially, which is safe
//! *because* of the bit-exactness guarantee — participant count never
//! changes the per-element floating-point op sequence. Thread count is
//! selected once at startup: `CUBIC_THREADS=` overrides, then the
//! config/CLI request ([`request_threads`]), then
//! `std::thread::available_parallelism()`.

use super::{pack, Kernel, JC_STRIPE, KC, MR, NC, NR};
use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Barrier, Condvar, Mutex, OnceLock};

/// Hard cap on pool size (defends against absurd `CUBIC_THREADS` values).
pub const MAX_THREADS: usize = 64;

/// Below this many flops (`2·m·n·k`) the auto path stays serial: the
/// per-block barriers (~µs) would dominate the compute of small matmuls.
/// Explicit [`super::gemm_strided_t`] calls bypass this (tests need to
/// drive small shapes threaded).
pub const PAR_MIN_FLOPS: u64 = 2 * 96 * 96 * 96;

static REQUESTED: AtomicUsize = AtomicUsize::new(0);

/// Request a thread count from config/CLI (0 = auto). Must run before the
/// first matmul: [`selected_threads`] latches on first use and ignores
/// later requests. `CUBIC_THREADS=` takes precedence over this.
pub fn request_threads(n: usize) {
    REQUESTED.store(n, Ordering::Relaxed);
}

/// The driver-wide thread count, selected once per process:
/// `CUBIC_THREADS=` override, else the [`request_threads`] value, else
/// `available_parallelism()`. Always in `1..=MAX_THREADS`.
pub fn selected_threads() -> usize {
    static SELECTED: OnceLock<usize> = OnceLock::new();
    *SELECTED.get_or_init(|| {
        let mut n = 0usize;
        if let Ok(v) = std::env::var("CUBIC_THREADS") {
            match v.trim().parse::<usize>() {
                Ok(t) if t >= 1 => n = t,
                _ => eprintln!("CUBIC_THREADS={v:?} invalid (want >= 1); using default"),
            }
        }
        if n == 0 {
            n = REQUESTED.load(Ordering::Relaxed);
        }
        if n == 0 {
            n = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        }
        n.clamp(1, MAX_THREADS)
    })
}

/// Jobs the pool actually ran multi-threaded (observability; the parity
/// battery asserts this grows so thread coverage cannot silently vanish).
static THREADED_JOBS: AtomicU64 = AtomicU64::new(0);
/// Parallel-eligible calls whose worker lease came back empty (the fair
/// share of the worker budget rounded to zero under heavy job concurrency).
/// Correctness is unaffected — the serial loop is bit-identical — this only
/// tracks lost parallelism.
static SERIAL_FALLBACKS: AtomicU64 = AtomicU64::new(0);

pub fn threaded_jobs() -> u64 {
    THREADED_JOBS.load(Ordering::Relaxed)
}

pub fn serial_fallbacks() -> u64 {
    SERIAL_FALLBACKS.load(Ordering::Relaxed)
}

thread_local! {
    /// Per-thread A-panel packing scratch (each participant packs the
    /// strips it claims into its own panel — no sharing, no locks).
    static A_SCRATCH: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
    /// Per-thread B-block scratch. Only the *calling* thread's buffer is
    /// used per job (resized up front, then shared read-mostly via raw
    /// pointer); workers never touch their own B scratch.
    static B_SCRATCH: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// Everything one gemm job shares between participants. Lives on the
/// calling thread's stack for the duration of the job; workers receive it
/// as a type-erased pointer and must not touch it after their final
/// decrement (the caller blocks until all participants check out, then the
/// frame dies).
pub(super) struct GemmCtx {
    kern: Kernel,
    m: usize,
    n: usize,
    kdim: usize,
    a: *const f32,
    alen: usize,
    ars: usize,
    aks: usize,
    b: *const f32,
    blen: usize,
    brs: usize,
    bcs: usize,
    c: *mut f32,
    /// Shared packed-B stripe, capacity `>= min(KC,k) * min(JC_STRIPE, n_pad)`.
    bp: *mut f32,
    participants: usize,
    barrier: Barrier,
    panel_next: AtomicUsize,
    strip_next: AtomicUsize,
    flops: AtomicU64,
    pack_bytes: AtomicU64,
}

// SAFETY: the raw pointers reference buffers that outlive the job (the
// caller blocks in `ThreadPool::run_leased` until every participant has
// finished),
// and all concurrent access is to disjoint regions (disjoint B panels while
// packing, disjoint C row strips while computing) or read-only (a, b, and
// the packed B block after its barrier). The sync primitives are Sync.
unsafe impl Sync for GemmCtx {}

impl GemmCtx {
    #[allow(clippy::too_many_arguments)]
    fn new(
        kern: Kernel,
        m: usize,
        n: usize,
        kdim: usize,
        a: &[f32],
        ars: usize,
        aks: usize,
        b: &[f32],
        brs: usize,
        bcs: usize,
        c: *mut f32,
        bp: *mut f32,
        participants: usize,
    ) -> GemmCtx {
        GemmCtx {
            kern,
            m,
            n,
            kdim,
            a: a.as_ptr(),
            alen: a.len(),
            ars,
            aks,
            b: b.as_ptr(),
            blen: b.len(),
            brs,
            bcs,
            c,
            bp,
            participants,
            barrier: Barrier::new(participants),
            panel_next: AtomicUsize::new(0),
            strip_next: AtomicUsize::new(0),
            flops: AtomicU64::new(0),
            pack_bytes: AtomicU64::new(0),
        }
    }

    /// Phase barrier; a no-op for the single-participant (serial) path.
    fn sync(&self) {
        if self.participants > 1 {
            self.barrier.wait();
        }
    }

    /// Phase barrier that elects one participant (serial path: the caller).
    fn sync_leader(&self) -> bool {
        if self.participants > 1 {
            self.barrier.wait().is_leader()
        } else {
            true
        }
    }

    fn totals(&self) -> (u64, u64) {
        (self.flops.load(Ordering::Relaxed), self.pack_bytes.load(Ordering::Relaxed))
    }
}

/// The SPMD participant body: the full `(stripe, pc)` phase loop with
/// cooperative B packing and `(NC-block, MR-strip)` tile claims. Every
/// participant — pool workers and the caller alike — runs exactly this.
fn run_participant(ctx: &GemmCtx, _me: usize) {
    let (m, n, kdim) = (ctx.m, ctx.n, ctx.kdim);
    let kern = ctx.kern;
    // SAFETY: a/b were live slices when the job was published and the
    // publisher blocks until all participants finish (see GemmCtx).
    let a = unsafe { std::slice::from_raw_parts(ctx.a, ctx.alen) };
    let b = unsafe { std::slice::from_raw_parts(ctx.b, ctx.blen) };
    let nstrips = m.div_ceil(MR);
    let mut local_flops = 0u64;
    let mut local_pack = 0u64;
    A_SCRATCH.with(|s| {
        let ap_buf = &mut *s.borrow_mut();
        for jcs in (0..n).step_by(JC_STRIPE) {
            let ncs = (jcs + JC_STRIPE).min(n) - jcs; // stripe width
            let npanels = ncs.div_ceil(NR);
            let njb = ncs.div_ceil(NC); // NC cache blocks in the stripe
            for pc in (0..kdim).step_by(KC) {
                let kc = (pc + KC).min(kdim) - pc;
                // Phase 1: cooperatively pack the stripe's B panels. Claims
                // are disjoint panels, so each region has one writer.
                loop {
                    let pi = ctx.panel_next.fetch_add(1, Ordering::Relaxed);
                    if pi >= npanels {
                        break;
                    }
                    let jr = pi * NR;
                    let nr_eff = NR.min(ncs - jr);
                    // SAFETY: panel `pi` occupies bp[pi*kc*NR .. (pi+1)*kc*NR],
                    // within the buffer (resized to >= kc * npanels*NR by the
                    // caller before publishing); no other participant holds
                    // this panel index.
                    let dst = unsafe {
                        std::slice::from_raw_parts_mut(ctx.bp.add(pi * kc * NR), kc * NR)
                    };
                    pack::pack_b(b, ctx.brs, ctx.bcs, pc, kc, jcs + jr, nr_eff, dst);
                    local_pack += (kc * NR * std::mem::size_of::<f32>()) as u64;
                }
                ctx.sync(); // the stripe is fully packed before anyone reads it
                // Phase 2: claim disjoint (NC-block, MR-strip) tiles of C.
                // Consecutive claims walk strips within one block first, so
                // a thread keeps reusing the same hot B panels.
                let ntiles = njb * nstrips;
                loop {
                    let t = ctx.strip_next.fetch_add(1, Ordering::Relaxed);
                    if t >= ntiles {
                        break;
                    }
                    let jb = t / nstrips;
                    let strip = t % nstrips;
                    let jc = jb * NC; // stripe-relative block start
                    let nc = (jc + NC).min(ncs) - jc;
                    let ir = strip * MR;
                    let mr_eff = MR.min(m - ir);
                    ap_buf.resize(kc * MR, 0.0);
                    pack::pack_a(a, ctx.ars, ctx.aks, ir, mr_eff, pc, kc, ap_buf);
                    local_pack += (kc * MR * std::mem::size_of::<f32>()) as u64;
                    let apan = ap_buf.as_ptr();
                    // NC % NR == 0, so block panel indices are contiguous.
                    let p0 = jc / NR;
                    for pi in p0..p0 + nc.div_ceil(NR) {
                        let jr = pi * NR;
                        let nr_eff = NR.min(ncs - jr);
                        let bpan = unsafe { ctx.bp.add(pi * kc * NR) } as *const f32;
                        let (row, col) = (ir, jcs + jr);
                        if mr_eff == MR && nr_eff == NR {
                            // SAFETY: panels hold kc*MR / kc*NR packed f32s
                            // (fully written above; the barrier published
                            // the B panels); the full-tile condition
                            // guarantees the MR×NR window at c[row*n + col]
                            // with ldc = n is in bounds and owned by this
                            // tile; `kern` came from `available`, so its
                            // ISA features are present.
                            unsafe {
                                (kern.mk)(kc, apan, bpan, ctx.c.add(row * n + col), n);
                            }
                        } else {
                            // Edge tile: compute the full padded tile into
                            // scratch, write back only the valid window.
                            // Zero-padded panel lanes contribute exact zeros.
                            let mut tile = [0.0f32; MR * NR];
                            // SAFETY: as above; `tile` is an MR×NR window
                            // with ldc = NR.
                            unsafe {
                                (kern.mk)(kc, apan, bpan, tile.as_mut_ptr(), NR);
                            }
                            for (r, trow) in tile.chunks_exact(NR).take(mr_eff).enumerate() {
                                // SAFETY: rows row..row+mr_eff, cols
                                // col..col+nr_eff are in bounds and owned by
                                // this tile.
                                let cp = unsafe { ctx.c.add((row + r) * n + col) };
                                for (j, &tv) in trow.iter().take(nr_eff).enumerate() {
                                    unsafe { *cp.add(j) += tv };
                                }
                            }
                        }
                        local_flops += 2 * (mr_eff * nr_eff * kc) as u64;
                    }
                }
                // Phase 3: all tiles of this (stripe, pc) phase are written
                // (the B buffer may be overwritten next phase); the leader
                // resets the claim counters and a second barrier publishes
                // that.
                if ctx.sync_leader() {
                    ctx.panel_next.store(0, Ordering::Relaxed);
                    ctx.strip_next.store(0, Ordering::Relaxed);
                }
                ctx.sync();
            }
        }
    });
    // Merge this participant's tallies exactly once, on completion.
    ctx.flops.fetch_add(local_flops, Ordering::Relaxed);
    ctx.pack_bytes.fetch_add(local_pack, Ordering::Relaxed);
}

/// A published job: type-erased participant entry point + context pointer.
#[derive(Clone, Copy)]
struct Job {
    run: unsafe fn(*const (), usize),
    ctx: *const (),
}

unsafe fn run_erased(ctx: *const (), me: usize) {
    run_participant(&*(ctx as *const GemmCtx), me);
}

/// Per-job completion latch, living on the publishing caller's stack: the
/// caller blocks until every leased worker has decremented it, so the
/// `GemmCtx` frame (and this latch) outlive all worker access.
struct JobDone {
    remaining: Mutex<usize>,
    cv: Condvar,
}

impl JobDone {
    fn signal(&self) {
        let mut g = self.remaining.lock().expect("gemm pool poisoned");
        *g -= 1;
        if *g == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut g = self.remaining.lock().expect("gemm pool poisoned");
        while *g > 0 {
            g = self.cv.wait(g).expect("gemm pool poisoned");
        }
    }
}

/// One leased worker's marching orders: the job plus *this worker's*
/// participant index and the publisher's completion latch.
#[derive(Clone, Copy)]
struct Assignment {
    job: Job,
    me: usize,
    done: *const JobDone,
}

// SAFETY: `job.ctx` points at a `GemmCtx` (Sync, see above) and `done` at a
// `JobDone`, both on the publisher's stack; the publisher blocks in
// `run_leased` until every assignee has signalled `done`, so neither is
// freed while a worker can still reach it.
unsafe impl Send for Assignment {}

/// A worker's parking spot: at most one assignment in flight (workers only
/// return to the free list after finishing, so a parked mailbox is empty).
#[derive(Default)]
struct Mailbox {
    slot: Mutex<Option<Assignment>>,
    bell: Condvar,
}

/// State shared between the pool handle and its workers.
struct PoolShared {
    /// Indices of workers that are parked and leasable.
    free: Mutex<Vec<usize>>,
    /// One mailbox per spawned worker, indexed by worker id.
    mailboxes: Mutex<Vec<Arc<Mailbox>>>,
}

/// The process-wide persistent gemm pool (never torn down; idle workers
/// park on their mailbox condvars and cost nothing). Concurrent jobs each
/// lease a fair share of the worker budget — see the module docs.
pub(super) struct ThreadPool {
    shared: Arc<PoolShared>,
    /// Jobs currently holding (or acquiring) a lease; the fair-share
    /// denominator.
    active_jobs: AtomicUsize,
}

fn worker_loop(shared: Arc<PoolShared>, mailbox: Arc<Mailbox>, idx: usize) {
    loop {
        let a = {
            let mut g = mailbox.slot.lock().expect("gemm pool poisoned");
            loop {
                if let Some(a) = g.take() {
                    break a;
                }
                g = mailbox.bell.wait(g).expect("gemm pool poisoned");
            }
        };
        // SAFETY: the publisher keeps ctx + done alive until this worker
        // signals `done` below.
        //
        // A panic must not unwind out of a pooled job: the barrier and
        // latch bookkeeping would wedge every other participant in a
        // silent hang. Abort instead — loud, with the panic message already
        // printed by the default hook.
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| unsafe {
            (a.job.run)(a.job.ctx, a.me)
        }));
        if result.is_err() {
            eprintln!("gemm pool worker {idx} panicked mid-job; aborting");
            std::process::abort();
        }
        // Back on the market before signalling, so a caller woken by the
        // latch already sees this worker leasable.
        shared.free.lock().expect("gemm pool poisoned").push(idx);
        // SAFETY: `done` is still alive — the publisher cannot return from
        // `run_leased` until this signal lands.
        unsafe { (*a.done).signal() };
    }
}

impl ThreadPool {
    fn new() -> ThreadPool {
        ThreadPool {
            shared: Arc::new(PoolShared {
                free: Mutex::new(Vec::new()),
                mailboxes: Mutex::new(Vec::new()),
            }),
            active_jobs: AtomicUsize::new(0),
        }
    }

    /// Lease up to `desired` helper workers for one job, capped at the
    /// job's fair share of the worker budget (`MAX_THREADS − 1` divided by
    /// the jobs in flight) and spawning new workers up to the budget when
    /// the free list runs short. Registers the job in `active_jobs` even
    /// when the lease is empty — every `lease` must be paired with a
    /// [`Self::finish_job`].
    fn lease(&self, desired: usize) -> Vec<usize> {
        let active = self.active_jobs.fetch_add(1, Ordering::SeqCst) + 1;
        let budget = MAX_THREADS - 1;
        let take = desired.min(budget / active);
        if take == 0 {
            return Vec::new();
        }
        let mut free = self.shared.free.lock().expect("gemm pool poisoned");
        if free.len() < take {
            self.spawn_workers(take - free.len(), &mut free);
        }
        let n = take.min(free.len());
        let at = free.len() - n;
        free.split_off(at)
    }

    /// Unregister a job from the fair-share denominator (pairs with
    /// [`Self::lease`]; call after the job — threaded or fallen-back —
    /// is done).
    fn finish_job(&self) {
        self.active_jobs.fetch_sub(1, Ordering::SeqCst);
    }

    /// Spawn up to `need` new workers (bounded by the worker budget) and
    /// add them to the free list the caller holds locked.
    fn spawn_workers(&self, need: usize, free: &mut Vec<usize>) {
        let mut reg = self.shared.mailboxes.lock().expect("gemm pool poisoned");
        for _ in 0..need {
            if reg.len() >= MAX_THREADS - 1 {
                break;
            }
            let idx = reg.len();
            let mailbox = Arc::new(Mailbox::default());
            reg.push(mailbox.clone());
            let shared = self.shared.clone();
            std::thread::Builder::new()
                .name(format!("cubic-gemm-{idx}"))
                .spawn(move || worker_loop(shared, mailbox, idx))
                .expect("cannot spawn gemm worker");
            free.push(idx);
        }
    }

    /// Run `ctx` on the leased workers plus the calling thread; requires
    /// `ctx.participants == lease.len() + 1` (caller = participant 0).
    /// Blocks until every participant has finished.
    fn run_leased(&self, ctx: &GemmCtx, lease: &[usize]) {
        debug_assert_eq!(ctx.participants, lease.len() + 1);
        let done = JobDone { remaining: Mutex::new(lease.len()), cv: Condvar::new() };
        let job = Job { run: run_erased, ctx: ctx as *const GemmCtx as *const () };
        for (i, &w) in lease.iter().enumerate() {
            let mailbox =
                self.shared.mailboxes.lock().expect("gemm pool poisoned")[w].clone();
            let mut g = mailbox.slot.lock().expect("gemm pool poisoned");
            *g = Some(Assignment { job, me: i + 1, done: &done });
            mailbox.bell.notify_one();
        }
        // Same panic policy as the workers (see worker_loop): unwinding out
        // of a pooled job while workers hold barrier/ctx references would
        // deadlock them against a dead stack frame. Abort loudly instead.
        // The serial path (no pool) propagates panics normally.
        let caller = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_participant(ctx, 0);
        }));
        if caller.is_err() {
            eprintln!("gemm pool caller panicked mid-job; aborting");
            std::process::abort();
        }
        done.wait();
    }
}

fn pool() -> &'static ThreadPool {
    static POOL: OnceLock<ThreadPool> = OnceLock::new();
    POOL.get_or_init(ThreadPool::new)
}

/// Drive one strided gemm with up to `threads` participants (clamped to the
/// strip count and the job's fair share of the worker pool), falling back
/// to the bit-identical serial loop when `threads <= 1` or the lease comes
/// back empty. Returns the merged per-thread `(flops, packed_bytes)`
/// tallies.
#[allow(clippy::too_many_arguments)]
pub(super) fn execute(
    kern: Kernel,
    m: usize,
    n: usize,
    kdim: usize,
    a: &[f32],
    ars: usize,
    aks: usize,
    b: &[f32],
    brs: usize,
    bcs: usize,
    c: &mut [f32],
    threads: usize,
) -> (u64, u64) {
    // Participants are capped by the tiles one phase can expose: row strips
    // × NC blocks, with the block count bounded by a stripe's width
    // (wide-n/short-m gemms get their parallelism from the block axis —
    // the ROADMAP follow-on).
    let want = threads
        .clamp(1, MAX_THREADS)
        .min(m.div_ceil(MR) * n.div_ceil(NC).min(JC_STRIPE / NC));
    B_SCRATCH.with(|s| {
        let bp_buf = &mut *s.borrow_mut();
        // One resize covers every (stripe, pc) phase of this job; the
        // thread-local keeps its capacity, so steady state allocates 0.
        let max_kc = KC.min(kdim);
        let max_stripe_pad = JC_STRIPE.min(n.div_ceil(NR) * NR);
        bp_buf.resize(max_kc * max_stripe_pad, 0.0);
        let cp = c.as_mut_ptr();
        let bpp = bp_buf.as_mut_ptr();
        if want > 1 {
            let p = pool();
            let lease = p.lease(want - 1);
            if lease.is_empty() {
                SERIAL_FALLBACKS.fetch_add(1, Ordering::Relaxed);
            } else {
                // The lease may be smaller than asked (fair share under
                // concurrent jobs) — any participant count is bit-exact.
                let ctx = GemmCtx::new(
                    kern, m, n, kdim, a, ars, aks, b, brs, bcs, cp, bpp,
                    lease.len() + 1,
                );
                p.run_leased(&ctx, &lease);
                p.finish_job();
                THREADED_JOBS.fetch_add(1, Ordering::Relaxed);
                return ctx.totals();
            }
            p.finish_job();
        }
        let ctx = GemmCtx::new(kern, m, n, kdim, a, ars, aks, b, brs, bcs, cp, bpp, 1);
        run_participant(&ctx, 0);
        ctx.totals()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn selected_threads_is_in_range() {
        let t = selected_threads();
        assert!((1..=MAX_THREADS).contains(&t), "selected {t}");
    }

    #[test]
    fn request_after_selection_is_ignored() {
        let before = selected_threads();
        request_threads(MAX_THREADS + 100);
        assert_eq!(selected_threads(), before, "selection must latch once");
    }

    #[test]
    fn saturated_pool_leases_nothing_and_falls_back() {
        // Inflate the job counter past the worker budget so the fair share
        // rounds to zero: the lease must come back empty (the serial-
        // fallback path) without spawning or blocking. Concurrent gemms in
        // this process may transiently fall back serial during this window,
        // which is bit-exact by construction.
        let p = pool();
        p.active_jobs.fetch_add(MAX_THREADS, Ordering::SeqCst);
        let lease = p.lease(4);
        assert!(lease.is_empty(), "fair share at saturation must be zero");
        p.finish_job();
        p.active_jobs.fetch_sub(MAX_THREADS, Ordering::SeqCst);
    }

    #[test]
    fn leased_workers_run_the_job_and_return_to_the_pool() {
        let p = pool();
        let lease = p.lease(2);
        if lease.is_empty() {
            // A concurrent saturation test can empty the fair share.
            p.finish_job();
            return;
        }
        let a = vec![1.0f32; 8 * 8];
        let b = vec![1.0f32; 8 * 8];
        let mut c = vec![0.0f32; 8 * 8];
        let mut bp = vec![0.0f32; KC * JC_STRIPE];
        let ctx = GemmCtx::new(
            crate::tensor::kernel::selected(),
            8,
            8,
            8,
            &a,
            8,
            1,
            &b,
            8,
            1,
            c.as_mut_ptr(),
            bp.as_mut_ptr(),
            lease.len() + 1,
        );
        p.run_leased(&ctx, &lease);
        p.finish_job();
        // 8×8 all-ones product: every element is exactly 8.
        assert!(c.iter().all(|&v| v == 8.0), "{c:?}");
        assert_eq!(ctx.totals().0, 2 * 8 * 8 * 8, "exact serial flop total");
        // The leased workers must come back on the market (bounded retry —
        // they re-register just before signalling completion, and other
        // tests may lease them in between).
        for _ in 0..1000 {
            let free = p.shared.free.lock().unwrap().len();
            if free > 0 {
                return;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        panic!("leased workers never returned to the free list");
    }
}
