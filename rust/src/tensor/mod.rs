//! Dense tensor substrate with zero-copy `Arc`-backed storage.
//!
//! The offline crate set has no `ndarray` or BLAS, so `cubic` carries its own
//! dense f32 tensor with the handful of operations a Transformer needs:
//! blocked matrix multiplication in all three forms the paper uses
//! (`C = AB`, `C = ABᵀ`, `C = AᵀB`), transpose, elementwise arithmetic,
//! reductions, and block slicing (the primitive behind every shard layout in
//! [`crate::dist`]).
//!
//! ## Storage model: shared buffer + copy-on-write
//!
//! A materialized [`Tensor`] is a *window* `(offset, numel)` into a
//! reference-counted `Arc<Vec<f32>>` buffer. `Clone` is a refcount bump —
//! no data moves — which is what makes the transport ([`crate::comm`]) and
//! the ring collectives ([`crate::collectives`]) allocation-free on their
//! hot paths: a message payload, a forwarded ring chunk, or a cached
//! activation is just another handle on the same buffer.
//!
//! Mutation goes through copy-on-write: [`Tensor::data_mut`] (and everything
//! built on it — `set_block`, `add_assign`, `axpy`) first checks whether the
//! buffer is uniquely owned. If it is, mutation happens in place; if it is
//! shared, the window is copied into a fresh buffer *once* and the copy is
//! charged to the global bytes-cloned counter in [`crate::metrics`] — the
//! observability hook the microbench and the zero-copy tests use. A cloned
//! tensor therefore behaves exactly like a deep copy (mutating one sibling
//! never alters another) while costing nothing until someone writes.
//!
//! Contiguous sub-windows are free: [`Tensor::block`] returns a zero-copy
//! view for full-width row ranges (and single rows), so `split_rows` — the
//! chunking primitive under reduce-scatter — never copies.
//!
//! Buffers can additionally be *pooled*: [`Tensor::from_pooled`] ties the
//! storage to a [`FreeList`] so the buffer returns there when the last
//! handle drops (possibly on another rank's thread) instead of being freed.
//! This is the mechanism behind the per-endpoint recycling pool
//! (`comm::pool`) that makes the collective steady state allocation-free —
//! see [`crate::collectives`].
//!
//! ## Dual-mode tensors
//!
//! A [`Tensor`] is either *materialized* (carries a buffer window) or
//! *phantom* (shape only). Every operation flows through the same code path
//! in both modes: phantom inputs produce phantom outputs with the correct
//! shape. This is the mechanism that lets the benchmark harness drive the
//! exact 1-D/2-D/3-D schedules at paper scale (hidden 8192, batch 384 —
//! ~10¹⁵ flops) while charging only virtual time, and lets the test suite
//! verify the *same* code path numerically at small scale. See DESIGN.md §2.

use crate::rng::Xoshiro256;
use std::fmt;
use std::ops::Deref;
use std::sync::{Arc, Mutex, Weak};

pub mod kernel;
pub mod matmul;

pub use matmul::{flops_executed as matmul_flops, reset_flops as reset_flop_counter};

/// Shared free list a pooled buffer returns to when its last handle drops:
/// the storage side of the per-endpoint recycling pool in
/// [`crate::comm::pool`]. Kept as a plain `Mutex<Vec<Vec<f32>>>` so the
/// reclaim in [`Storage::drop`] works from whichever worker thread happens
/// to drop the final handle (ring collectives routinely retire a buffer on
/// a different rank than the one that allocated it).
pub type FreeList = Arc<Mutex<Vec<Vec<f32>>>>;

/// Upper bound on buffers parked in one free list; beyond this, retiring
/// buffers are simply freed (defends against pathological churn pinning
/// unbounded memory).
const MAX_POOLED: usize = 32;

/// The refcounted storage behind a materialized tensor: the f32 buffer plus
/// an optional way home. Plain storage (`reclaim: None`) frees normally;
/// pooled storage (built by [`Tensor::from_pooled`]) pushes its buffer back
/// onto the owning endpoint's free list on final drop, making the buffer
/// reusable without a fresh heap allocation.
struct Storage {
    data: Vec<f32>,
    reclaim: Option<Weak<Mutex<Vec<Vec<f32>>>>>,
}

impl Storage {
    fn plain(data: Vec<f32>) -> Self {
        Storage { data, reclaim: None }
    }
}

impl Deref for Storage {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        &self.data
    }
}

impl Drop for Storage {
    fn drop(&mut self) {
        if let Some(w) = self.reclaim.take() {
            if let Some(free) = w.upgrade() {
                // Never panic in drop: a poisoned free list (some rank
                // panicked mid-collective) still accepts the buffer.
                let mut q = match free.lock() {
                    Ok(g) => g,
                    Err(p) => p.into_inner(),
                };
                if q.len() < MAX_POOLED {
                    q.push(std::mem::take(&mut self.data));
                }
            }
        }
    }
}

/// Shared storage: one refcounted buffer, potentially windowed by several
/// tensors (clones, `block` views, `split_rows` chunks).
type Buf = Arc<Storage>;

/// Row-major dense f32 tensor (a window into shared storage) or shape-only
/// placeholder (phantom).
#[derive(Clone)]
pub struct Tensor {
    shape: Vec<usize>,
    /// Element offset of this tensor's window within `data`.
    off: usize,
    data: Option<Buf>,
}

impl PartialEq for Tensor {
    fn eq(&self, other: &Self) -> bool {
        self.shape == other.shape
            && match (self.try_data(), other.try_data()) {
                (Some(a), Some(b)) => a == b,
                (None, None) => true,
                _ => false,
            }
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.try_data() {
            Some(d) if d.len() <= 16 => {
                write!(f, "Tensor{:?} {:?}", self.shape, d)
            }
            Some(_) => write!(f, "Tensor{:?} (materialized)", self.shape),
            None => write!(f, "Tensor{:?} (phantom)", self.shape),
        }
    }
}

impl Tensor {
    // ------------------------------------------------------------------
    // Constructors
    // ------------------------------------------------------------------

    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Self { shape: shape.to_vec(), off: 0, data: Some(Arc::new(Storage::plain(vec![0.0; n]))) }
    }

    pub fn ones(shape: &[usize]) -> Self {
        Self::full(shape, 1.0)
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        let n: usize = shape.iter().product();
        Self { shape: shape.to_vec(), off: 0, data: Some(Arc::new(Storage::plain(vec![v; n]))) }
    }

    /// Shape-only tensor: flows through every op without computing data.
    pub fn phantom(shape: &[usize]) -> Self {
        Self { shape: shape.to_vec(), off: 0, data: None }
    }

    /// Take ownership of `data` (moved into the shared buffer, no copy).
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "shape {:?} does not match data len {}", shape, data.len());
        Self { shape: shape.to_vec(), off: 0, data: Some(Arc::new(Storage::plain(data))) }
    }

    /// Like [`Tensor::from_vec`], but the buffer returns to `home` (an
    /// endpoint's recycling free list) when the last handle drops instead
    /// of being freed — the constructor behind
    /// `comm::Endpoint::pooled_tensor`. The reclaim reference is weak: if
    /// the owning pool is gone by then, the buffer frees normally.
    pub fn from_pooled(shape: &[usize], data: Vec<f32>, home: &FreeList) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "shape {:?} does not match data len {}", shape, data.len());
        let storage = Storage { data, reclaim: Some(Arc::downgrade(home)) };
        Self { shape: shape.to_vec(), off: 0, data: Some(Arc::new(storage)) }
    }

    /// N(0, std) initialized tensor (deterministic given the rng state).
    pub fn randn(shape: &[usize], std: f32, rng: &mut Xoshiro256) -> Self {
        let n: usize = shape.iter().product();
        let mut data = vec![0.0f32; n];
        rng.fill_normal(&mut data, std);
        Self::from_vec(shape, data)
    }

    /// U(lo, hi) initialized tensor.
    pub fn rand_uniform(shape: &[usize], lo: f32, hi: f32, rng: &mut Xoshiro256) -> Self {
        let n: usize = shape.iter().product();
        let mut data = vec![0.0f32; n];
        rng.fill_uniform(&mut data, lo, hi);
        Self::from_vec(shape, data)
    }

    // ------------------------------------------------------------------
    // Storage internals (copy-on-write + views)
    // ------------------------------------------------------------------

    /// Zero-copy window `[lo, lo + len)` of this tensor's flat data with the
    /// given shape. Phantom in → phantom out.
    fn view_flat(&self, lo: usize, len: usize, shape: &[usize]) -> Tensor {
        debug_assert_eq!(len, shape.iter().product::<usize>());
        debug_assert!(lo + len <= self.numel(), "view [{lo}, {lo}+{len}) out of window");
        match &self.data {
            Some(buf) => Tensor { shape: shape.to_vec(), off: self.off + lo, data: Some(buf.clone()) },
            None => Tensor::phantom(shape),
        }
    }

    /// Ensure this tensor is the sole owner of its buffer, copying the
    /// window out (and charging the bytes-cloned counter) if it is shared.
    fn make_unique(&mut self) {
        let n = self.numel();
        let off = self.off;
        let Some(buf) = self.data.as_mut() else {
            panic!("tensor is phantom; no data");
        };
        if Arc::get_mut(buf).is_none() {
            let copied: Vec<f32> = buf[off..off + n].to_vec();
            crate::metrics::add_bytes_cloned((n * std::mem::size_of::<f32>()) as u64);
            *buf = Arc::new(Storage::plain(copied));
            self.off = 0;
        }
    }

    /// Do these tensors share one underlying buffer? (Diagnostic for the
    /// zero-copy tests; `true` after `clone`/`block`-view until one side
    /// triggers copy-on-write.)
    pub fn shares_storage(&self, other: &Tensor) -> bool {
        match (&self.data, &other.data) {
            (Some(a), Some(b)) => Arc::ptr_eq(a, b),
            _ => false,
        }
    }

    /// Shrink to a private, minimal buffer: if this tensor is a view into a
    /// larger or shared buffer, copy just its window out. Views keep their
    /// *entire* parent allocation alive, so long-lived tensors built from
    /// slices of a big source (model shards cut from a global matrix) should
    /// be compacted — otherwise every rank pins the full global buffer until
    /// first mutation. Deliberate extraction, not a redundant copy: NOT
    /// charged to the bytes-cloned counter.
    pub fn compact(mut self) -> Tensor {
        let needs = match &self.data {
            Some(buf) => {
                self.off != 0 || buf.len() != self.numel() || Arc::strong_count(buf) > 1
            }
            None => false,
        };
        if needs {
            let copied = self.data().to_vec();
            self.data = Some(Arc::new(Storage::plain(copied)));
            self.off = 0;
        }
        self
    }

    // ------------------------------------------------------------------
    // Accessors
    // ------------------------------------------------------------------

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_phantom(&self) -> bool {
        self.data.is_none()
    }

    /// Bytes this tensor would occupy materialized (used by the memory
    /// accountant regardless of mode).
    pub fn nominal_bytes(&self) -> usize {
        self.numel() * std::mem::size_of::<f32>()
    }

    pub fn data(&self) -> &[f32] {
        let buf = self.data.as_ref().expect("tensor is phantom; no data");
        &buf[self.off..self.off + self.numel()]
    }

    /// Mutable access; copy-on-write if the buffer is shared.
    pub fn data_mut(&mut self) -> &mut [f32] {
        self.make_unique();
        let n = self.numel();
        let off = self.off;
        let buf = self.data.as_mut().expect("tensor is phantom; no data");
        let v = Arc::get_mut(buf).expect("buffer unique after make_unique");
        &mut v.data[off..off + n]
    }

    pub fn try_data(&self) -> Option<&[f32]> {
        self.data
            .as_ref()
            .map(|buf| &buf[self.off..self.off + self.numel()])
    }

    /// 2-D dimensions helper; panics if not rank 2.
    pub fn dims2(&self) -> (usize, usize) {
        assert_eq!(self.shape.len(), 2, "expected rank-2 tensor, got {:?}", self.shape);
        (self.shape[0], self.shape[1])
    }

    pub fn at2(&self, r: usize, c: usize) -> f32 {
        let (_, cols) = self.dims2();
        self.data()[r * cols + c]
    }

    // ------------------------------------------------------------------
    // Shape manipulation
    // ------------------------------------------------------------------

    /// Zero-copy reshape (shares the buffer window).
    pub fn reshape(&self, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.numel(), "reshape {:?} -> {:?} changes numel", self.shape, shape);
        Tensor { shape: shape.to_vec(), off: self.off, data: self.data.clone() }
    }

    pub fn into_reshape(mut self, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.numel(), "reshape {:?} -> {:?} changes numel", self.shape, shape);
        self.shape = shape.to_vec();
        self
    }

    /// 2-D transpose.
    pub fn transpose(&self) -> Tensor {
        let (r, c) = self.dims2();
        let Some(src) = self.try_data() else {
            return Tensor::phantom(&[c, r]);
        };
        let mut out = vec![0.0f32; r * c];
        // Blocked transpose for cache friendliness.
        const B: usize = 32;
        for ib in (0..r).step_by(B) {
            for jb in (0..c).step_by(B) {
                for i in ib..(ib + B).min(r) {
                    for j in jb..(jb + B).min(c) {
                        out[j * r + i] = src[i * c + j];
                    }
                }
            }
        }
        Tensor::from_vec(&[c, r], out)
    }

    // ------------------------------------------------------------------
    // Block slicing / assembly — the primitive behind all shard layouts
    // ------------------------------------------------------------------

    /// Extract the sub-block `[r0..r0+rows, c0..c0+cols]` of a rank-2
    /// tensor. Full-width row ranges and single rows are contiguous in the
    /// row-major buffer, so those come back as zero-copy views; interior
    /// blocks are extracted with one copy.
    pub fn block(&self, r0: usize, c0: usize, rows: usize, cols: usize) -> Tensor {
        let (r, c) = self.dims2();
        assert!(r0 + rows <= r && c0 + cols <= c,
            "block [{r0}+{rows}, {c0}+{cols}] out of bounds for {:?}", self.shape);
        if self.is_phantom() {
            return Tensor::phantom(&[rows, cols]);
        }
        if (c0 == 0 && cols == c) || rows == 1 {
            return self.view_flat(r0 * c + c0, rows * cols, &[rows, cols]);
        }
        let src = self.data();
        let mut out = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            let off = (r0 + i) * c + c0;
            out.extend_from_slice(&src[off..off + cols]);
        }
        Tensor::from_vec(&[rows, cols], out)
    }

    /// Copy the sub-block `[r0..r0+rows, c0..c0+cols]` into a
    /// caller-supplied `(rows, cols)` tensor — the pooled-buffer
    /// counterpart of [`Tensor::block`] + [`Tensor::compact`], used by
    /// the activation scatter so a recycled buffer can receive the window
    /// without a fresh allocation. Phantom source leaves `out` untouched.
    pub fn block_into(&self, r0: usize, c0: usize, rows: usize, cols: usize, out: &mut Tensor) {
        let (r, c) = self.dims2();
        assert!(r0 + rows <= r && c0 + cols <= c,
            "block_into [{r0}+{rows}, {c0}+{cols}] out of bounds for {:?}", self.shape);
        assert_eq!(out.shape(), &[rows, cols], "block_into output shape mismatch");
        if self.is_phantom() {
            return;
        }
        let dst = out.data_mut();
        let src = self.data();
        for i in 0..rows {
            let soff = (r0 + i) * c + c0;
            dst[i * cols..(i + 1) * cols].copy_from_slice(&src[soff..soff + cols]);
        }
    }

    /// Write `src` into the sub-block at `[r0, c0]` of a rank-2 tensor.
    /// Copy-on-write: if `src` is a view of this tensor's own buffer, the
    /// un-share happens first, so `src` is read as a consistent snapshot.
    pub fn set_block(&mut self, r0: usize, c0: usize, src: &Tensor) {
        let (r, c) = self.dims2();
        let (rows, cols) = src.dims2();
        assert!(r0 + rows <= r && c0 + cols <= c,
            "set_block [{r0}+{rows}, {c0}+{cols}] out of bounds for {r}x{c}");
        if self.is_phantom() || src.is_phantom() {
            return;
        }
        let dst = self.data_mut();
        let sdata = src.data();
        for i in 0..rows {
            let doff = (r0 + i) * c + c0;
            let soff = i * cols;
            dst[doff..doff + cols].copy_from_slice(&sdata[soff..soff + cols]);
        }
    }

    /// Concatenate rank-2 tensors along rows (axis 0).
    pub fn concat_rows(parts: &[Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let cols = parts[0].dims2().1;
        let rows: usize = parts.iter().map(|p| {
            assert_eq!(p.dims2().1, cols, "concat_rows: column mismatch");
            p.dims2().0
        }).sum();
        if parts.iter().any(|p| p.is_phantom()) {
            return Tensor::phantom(&[rows, cols]);
        }
        let mut data = Vec::with_capacity(rows * cols);
        for p in parts {
            data.extend_from_slice(p.data());
        }
        Tensor::from_vec(&[rows, cols], data)
    }

    /// Concatenate rank-2 tensors along columns (axis 1).
    pub fn concat_cols(parts: &[Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let rows = parts[0].dims2().0;
        let cols: usize = parts.iter().map(|p| {
            assert_eq!(p.dims2().0, rows, "concat_cols: row mismatch");
            p.dims2().1
        }).sum();
        if parts.iter().any(|p| p.is_phantom()) {
            return Tensor::phantom(&[rows, cols]);
        }
        let mut data = vec![0.0f32; rows * cols];
        let mut c0 = 0;
        for p in parts {
            let (_, pc) = p.dims2();
            let pd = p.data();
            for i in 0..rows {
                data[i * cols + c0..i * cols + c0 + pc]
                    .copy_from_slice(&pd[i * pc..(i + 1) * pc]);
            }
            c0 += pc;
        }
        Tensor::from_vec(&[rows, cols], data)
    }

    /// Split a rank-2 tensor into `n` equal row chunks — zero-copy views.
    pub fn split_rows(&self, n: usize) -> Vec<Tensor> {
        let (r, c) = self.dims2();
        assert_eq!(r % n, 0, "split_rows: {r} rows not divisible by {n}");
        let chunk = r / n;
        (0..n).map(|i| self.block(i * chunk, 0, chunk, c)).collect()
    }

    /// Split a rank-2 tensor into `n` equal column chunks.
    pub fn split_cols(&self, n: usize) -> Vec<Tensor> {
        let (r, c) = self.dims2();
        assert_eq!(c % n, 0, "split_cols: {c} cols not divisible by {n}");
        let chunk = c / n;
        (0..n).map(|j| self.block(0, j * chunk, r, chunk)).collect()
    }

    /// Split the *flattened* tensor into `n` equal chunks — zero-copy views
    /// (the chunking primitive under ring all-reduce). Requires
    /// `numel % n == 0`.
    pub fn split_flat(&self, n: usize) -> Vec<Tensor> {
        let total = self.numel();
        assert_eq!(total % n, 0, "split_flat: {total} elems not divisible by {n}");
        let chunk = total / n;
        (0..n)
            .map(|k| self.view_flat(k * chunk, chunk, &[chunk]))
            .collect()
    }

    // ------------------------------------------------------------------
    // Elementwise arithmetic
    // ------------------------------------------------------------------

    fn zip_with(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
        assert_eq!(self.shape, other.shape,
            "elementwise shape mismatch: {:?} vs {:?}", self.shape, other.shape);
        match (self.try_data(), other.try_data()) {
            (Some(a), Some(b)) => {
                let data = a.iter().zip(b.iter()).map(|(&x, &y)| f(x, y)).collect();
                Tensor::from_vec(&self.shape, data)
            }
            _ => Tensor::phantom(&self.shape),
        }
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        self.zip_with(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        self.zip_with(other, |a, b| a - b)
    }

    pub fn mul(&self, other: &Tensor) -> Tensor {
        self.zip_with(other, |a, b| a * b)
    }

    /// In-place accumulate: `self += other` (copy-on-write if shared).
    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape,
            "add_assign shape mismatch: {:?} vs {:?}", self.shape, other.shape);
        if self.is_phantom() || other.is_phantom() {
            self.data = None;
            self.off = 0;
            return;
        }
        let o = other.data();
        for (a, &b) in self.data_mut().iter_mut().zip(o.iter()) {
            *a += b;
        }
    }

    /// In-place axpy: `self += alpha * other` (copy-on-write if shared).
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        if self.is_phantom() || other.is_phantom() {
            self.data = None;
            self.off = 0;
            return;
        }
        let o = other.data();
        for (a, &b) in self.data_mut().iter_mut().zip(o.iter()) {
            *a += alpha * b;
        }
    }

    pub fn scale(&self, s: f32) -> Tensor {
        self.map(|x| x * s)
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        match self.try_data() {
            Some(d) => Tensor::from_vec(&self.shape, d.iter().map(|&x| f(x)).collect()),
            None => Tensor::phantom(&self.shape),
        }
    }

    /// Add a row vector (len == cols) to every row of a rank-2 tensor.
    pub fn add_row_vector(&self, v: &Tensor) -> Tensor {
        let (r, c) = self.dims2();
        assert_eq!(v.numel(), c, "row vector len {} != cols {c}", v.numel());
        match (self.try_data(), v.try_data()) {
            (Some(a), Some(b)) => {
                let mut out = Vec::with_capacity(r * c);
                for i in 0..r {
                    for j in 0..c {
                        out.push(a[i * c + j] + b[j]);
                    }
                }
                Tensor::from_vec(&self.shape, out)
            }
            _ => Tensor::phantom(&self.shape),
        }
    }

    /// Multiply every row of a rank-2 tensor by a row vector (len == cols).
    pub fn mul_row_vector(&self, v: &Tensor) -> Tensor {
        let (r, c) = self.dims2();
        assert_eq!(v.numel(), c, "row vector len {} != cols {c}", v.numel());
        match (self.try_data(), v.try_data()) {
            (Some(a), Some(b)) => {
                let mut out = Vec::with_capacity(r * c);
                for i in 0..r {
                    for j in 0..c {
                        out.push(a[i * c + j] * b[j]);
                    }
                }
                Tensor::from_vec(&self.shape, out)
            }
            _ => Tensor::phantom(&self.shape),
        }
    }

    // ------------------------------------------------------------------
    // Reductions
    // ------------------------------------------------------------------

    pub fn sum(&self) -> f32 {
        self.data().iter().sum()
    }

    /// Sum over rows producing a row vector of length `cols`.
    pub fn sum_rows(&self) -> Tensor {
        let (r, c) = self.dims2();
        let Some(d) = self.try_data() else {
            return Tensor::phantom(&[c]);
        };
        let mut out = vec![0.0f32; c];
        for i in 0..r {
            for j in 0..c {
                out[j] += d[i * c + j];
            }
        }
        Tensor::from_vec(&[c], out)
    }

    /// Sum over columns producing a column vector of length `rows`.
    pub fn sum_cols(&self) -> Tensor {
        let (r, c) = self.dims2();
        let Some(d) = self.try_data() else {
            return Tensor::phantom(&[r]);
        };
        let mut out = vec![0.0f32; r];
        for i in 0..r {
            let row = &d[i * c..(i + 1) * c];
            out[i] = row.iter().sum();
        }
        Tensor::from_vec(&[r], out)
    }

    /// Max |a - b| over all elements; used pervasively by tests.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape,
            "max_abs_diff shape mismatch: {:?} vs {:?}", self.shape, other.shape);
        self.data()
            .iter()
            .zip(other.data().iter())
            .map(|(&a, &b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Relative L2 error ‖a−b‖ / (‖b‖ + eps).
    pub fn rel_l2_error(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (&a, &b) in self.data().iter().zip(other.data().iter()) {
            num += ((a - b) as f64).powi(2);
            den += (b as f64).powi(2);
        }
        (num.sqrt() / (den.sqrt() + 1e-12)) as f32
    }

    pub fn frob_norm(&self) -> f32 {
        (self.data().iter().map(|&x| (x as f64).powi(2)).sum::<f64>()).sqrt() as f32
    }

    // ------------------------------------------------------------------
    // Matmul — delegates to the blocked kernels in `matmul`
    // ------------------------------------------------------------------

    /// `C = self · other` — (m,k)·(k,n) -> (m,n).
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        matmul::matmul_nn(self, other)
    }

    /// `C = self · otherᵀ` — (m,k)·(n,k)ᵀ -> (m,n).
    pub fn matmul_nt(&self, other: &Tensor) -> Tensor {
        matmul::matmul_nt(self, other)
    }

    /// `C = selfᵀ · other` — (k,m)ᵀ·(k,n) -> (m,n).
    pub fn matmul_tn(&self, other: &Tensor) -> Tensor {
        matmul::matmul_tn(self, other)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t2(rows: usize, cols: usize, f: impl Fn(usize, usize) -> f32) -> Tensor {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Tensor::from_vec(&[rows, cols], data)
    }

    #[test]
    fn construct_and_shape() {
        let t = Tensor::zeros(&[3, 4]);
        assert_eq!(t.shape(), &[3, 4]);
        assert_eq!(t.numel(), 12);
        assert!(!t.is_phantom());
        let p = Tensor::phantom(&[3, 4]);
        assert!(p.is_phantom());
        assert_eq!(p.nominal_bytes(), 48);
    }

    #[test]
    fn transpose_round_trip() {
        let t = t2(5, 7, |i, j| (i * 10 + j) as f32);
        let tt = t.transpose().transpose();
        assert_eq!(t, tt);
        assert_eq!(t.transpose().at2(3, 4), t.at2(4, 3));
    }

    #[test]
    fn transpose_phantom_keeps_shape() {
        let p = Tensor::phantom(&[5, 7]);
        let pt = p.transpose();
        assert!(pt.is_phantom());
        assert_eq!(pt.shape(), &[7, 5]);
    }

    #[test]
    fn block_and_set_block_round_trip() {
        let t = t2(6, 8, |i, j| (i * 8 + j) as f32);
        let b = t.block(2, 3, 3, 4);
        assert_eq!(b.shape(), &[3, 4]);
        assert_eq!(b.at2(0, 0), t.at2(2, 3));
        assert_eq!(b.at2(2, 3), t.at2(4, 6));
        let mut z = Tensor::zeros(&[6, 8]);
        z.set_block(2, 3, &b);
        assert_eq!(z.at2(3, 4), t.at2(3, 4));
        assert_eq!(z.at2(0, 0), 0.0);
    }

    #[test]
    fn split_concat_rows_round_trip() {
        let t = t2(6, 4, |i, j| (i + j) as f32 * 0.5);
        let parts = t.split_rows(3);
        assert_eq!(parts.len(), 3);
        assert_eq!(Tensor::concat_rows(&parts), t);
    }

    #[test]
    fn split_concat_cols_round_trip() {
        let t = t2(4, 6, |i, j| (i * 6 + j) as f32);
        let parts = t.split_cols(2);
        assert_eq!(parts[1].at2(0, 0), 3.0);
        assert_eq!(Tensor::concat_cols(&parts), t);
    }

    #[test]
    fn elementwise_ops() {
        let a = t2(2, 3, |i, j| (i + j) as f32);
        let b = t2(2, 3, |_, _| 2.0);
        assert_eq!(a.add(&b).at2(1, 2), 5.0);
        assert_eq!(a.sub(&b).at2(0, 0), -2.0);
        assert_eq!(a.mul(&b).at2(1, 1), 4.0);
        assert_eq!(a.scale(3.0).at2(1, 2), 9.0);
        let mut c = a.clone();
        c.add_assign(&b);
        assert_eq!(c.at2(0, 1), 3.0);
        c.axpy(-1.0, &b);
        assert_eq!(c, a);
    }

    #[test]
    fn phantom_propagates_through_elementwise() {
        let a = Tensor::phantom(&[2, 3]);
        let b = Tensor::ones(&[2, 3]);
        assert!(a.add(&b).is_phantom());
        assert!(b.mul(&a).is_phantom());
        let mut c = Tensor::ones(&[2, 3]);
        c.add_assign(&a);
        assert!(c.is_phantom());
    }

    #[test]
    fn row_vector_ops() {
        let a = t2(2, 3, |i, j| (i * 3 + j) as f32);
        let v = Tensor::from_vec(&[3], vec![10.0, 20.0, 30.0]);
        let s = a.add_row_vector(&v);
        assert_eq!(s.at2(0, 0), 10.0);
        assert_eq!(s.at2(1, 2), 35.0);
        let m = a.mul_row_vector(&v);
        assert_eq!(m.at2(1, 1), 80.0);
    }

    #[test]
    fn reductions() {
        let a = t2(2, 3, |i, j| (i * 3 + j) as f32); // 0..5
        assert_eq!(a.sum(), 15.0);
        assert_eq!(a.sum_rows().data(), &[3.0, 5.0, 7.0]);
        assert_eq!(a.sum_cols().data(), &[3.0, 12.0]);
    }

    #[test]
    fn error_metrics() {
        let a = t2(2, 2, |i, j| (i + j) as f32);
        let mut b = a.clone();
        b.data_mut()[3] += 0.5;
        assert!((a.max_abs_diff(&b) - 0.5).abs() < 1e-6);
        assert!(a.rel_l2_error(&a) < 1e-9);
    }

    #[test]
    fn randn_is_deterministic_per_seed() {
        let mut r1 = Xoshiro256::seed_from_u64(11);
        let mut r2 = Xoshiro256::seed_from_u64(11);
        let a = Tensor::randn(&[4, 4], 0.02, &mut r1);
        let b = Tensor::randn(&[4, 4], 0.02, &mut r2);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "phantom")]
    fn phantom_data_access_panics() {
        let p = Tensor::phantom(&[2, 2]);
        let _ = p.data();
    }

    #[test]
    fn reshape_checks_numel() {
        let t = Tensor::zeros(&[2, 6]);
        let r = t.reshape(&[3, 4]);
        assert_eq!(r.shape(), &[3, 4]);
    }

    #[test]
    #[should_panic(expected = "changes numel")]
    fn bad_reshape_panics() {
        let t = Tensor::zeros(&[2, 6]);
        let _ = t.reshape(&[3, 5]);
    }

    // ------------------------------------------------------------------
    // Copy-on-write / zero-copy storage semantics
    // ------------------------------------------------------------------

    #[test]
    fn clone_shares_storage_until_mutation() {
        let a = t2(4, 4, |i, j| (i * 4 + j) as f32);
        let mut b = a.clone();
        assert!(a.shares_storage(&b), "clone must be zero-copy");
        b.data_mut()[0] = 99.0;
        assert!(!a.shares_storage(&b), "mutation must un-share");
        assert_eq!(a.at2(0, 0), 0.0, "sibling must be unaffected");
        assert_eq!(b.at2(0, 0), 99.0);
    }

    #[test]
    fn mutating_the_original_leaves_clones_intact() {
        let mut a = t2(3, 3, |i, j| (i + j) as f32);
        let b = a.clone();
        a.data_mut()[4] = -7.0;
        assert_eq!(b.at2(1, 1), 2.0, "clone must keep the old value");
        assert_eq!(a.at2(1, 1), -7.0);
    }

    #[test]
    fn set_block_on_clone_does_not_alter_sibling() {
        let a = t2(4, 4, |i, j| (i * 4 + j) as f32);
        let mut b = a.clone();
        let patch = Tensor::full(&[2, 2], -1.0);
        b.set_block(1, 1, &patch);
        assert_eq!(a.at2(1, 1), 5.0, "sibling must keep original data");
        assert_eq!(b.at2(1, 1), -1.0);
        assert_eq!(b.at2(0, 0), a.at2(0, 0), "untouched region matches");
    }

    #[test]
    fn add_assign_and_axpy_on_clone_do_not_alias() {
        let a = t2(2, 3, |i, j| (i * 3 + j) as f32);
        let mut b = a.clone();
        b.add_assign(&Tensor::ones(&[2, 3]));
        assert_eq!(a.at2(0, 0), 0.0);
        assert_eq!(b.at2(0, 0), 1.0);
        let mut c = a.clone();
        c.axpy(2.0, &Tensor::ones(&[2, 3]));
        assert_eq!(a.at2(1, 2), 5.0);
        assert_eq!(c.at2(1, 2), 7.0);
    }

    #[test]
    fn row_blocks_are_zero_copy_views() {
        let t = t2(6, 4, |i, j| (i * 4 + j) as f32);
        let parts = t.split_rows(3);
        for p in &parts {
            assert!(p.shares_storage(&t), "row chunks must be views");
        }
        // Single rows and flat chunks too.
        assert!(t.block(2, 1, 1, 3).shares_storage(&t));
        for ch in t.split_flat(4) {
            assert!(ch.shares_storage(&t));
        }
        // Interior (strided) blocks must copy.
        assert!(!t.block(0, 1, 2, 2).shares_storage(&t));
    }

    #[test]
    fn mutating_a_view_preserves_the_parent() {
        let t = t2(4, 4, |i, j| (i * 4 + j) as f32);
        let mut row = t.block(2, 0, 1, 4);
        row.data_mut()[0] = 1000.0;
        assert!(!row.shares_storage(&t));
        assert_eq!(t.at2(2, 0), 8.0, "parent unchanged after view CoW");
        assert_eq!(row.at2(0, 0), 1000.0);
    }

    #[test]
    fn set_block_from_aliasing_view_snapshots_source() {
        // Copy row 1 over row 0 where the source is a live view of self.
        let mut t = t2(3, 4, |i, j| (i * 4 + j) as f32);
        let row1 = t.block(1, 0, 1, 4);
        assert!(row1.shares_storage(&t));
        t.set_block(0, 0, &row1);
        for j in 0..4 {
            assert_eq!(t.at2(0, j), (4 + j) as f32, "row 0 = old row 1");
            assert_eq!(t.at2(1, j), (4 + j) as f32, "row 1 unchanged");
        }
    }

    #[test]
    fn view_equality_matches_by_value() {
        let t = t2(4, 2, |i, j| (i * 2 + j) as f32);
        let view = t.block(1, 0, 2, 2);
        let copy = Tensor::from_vec(&[2, 2], vec![2.0, 3.0, 4.0, 5.0]);
        assert_eq!(view, copy);
        assert!(!view.shares_storage(&copy));
    }

    #[test]
    fn pooled_storage_returns_to_free_list_on_final_drop() {
        let free: FreeList = Arc::new(Mutex::new(Vec::new()));
        let t = Tensor::from_pooled(&[4], vec![1.0; 4], &free);
        let u = t.clone();
        assert!(t.shares_storage(&u));
        drop(t);
        assert_eq!(free.lock().unwrap().len(), 0, "a live handle must pin the buffer");
        drop(u);
        assert_eq!(free.lock().unwrap().len(), 1, "final drop must return the buffer");
        assert_eq!(free.lock().unwrap()[0], vec![1.0; 4]);
    }

    #[test]
    fn cow_on_shared_pooled_tensor_detaches_and_still_reclaims() {
        let free: FreeList = Arc::new(Mutex::new(Vec::new()));
        let t = Tensor::from_pooled(&[3], vec![7.0; 3], &free);
        let mut u = t.clone();
        u.data_mut()[0] = 1.0; // CoW: u detaches onto plain storage
        assert!(!t.shares_storage(&u));
        assert_eq!(t.data(), &[7.0; 3], "original pooled data intact");
        drop(u); // plain storage: freed, NOT pooled
        assert_eq!(free.lock().unwrap().len(), 0);
        drop(t);
        assert_eq!(free.lock().unwrap().len(), 1, "pooled original comes home once");
    }

    #[test]
    fn pooled_reclaim_is_a_noop_when_the_pool_is_gone() {
        let free: FreeList = Arc::new(Mutex::new(Vec::new()));
        let t = Tensor::from_pooled(&[2], vec![0.5; 2], &free);
        drop(free); // endpoint torn down before its in-flight buffers
        drop(t); // must not panic; buffer simply frees
    }

    #[test]
    fn cow_charges_the_bytes_cloned_counter() {
        let a = Tensor::full(&[64], 1.0);
        let mut b = a.clone();
        let before = crate::metrics::bytes_cloned();
        b.data_mut()[0] = 2.0; // CoW: 64 floats copied
        let after = crate::metrics::bytes_cloned();
        // Other tests may run concurrently, so only a lower bound is exact.
        assert!(after >= before + 64 * 4, "CoW must charge the counter");
        // A second mutation is in place: no further charge from this tensor.
        let mid = crate::metrics::bytes_cloned();
        b.data_mut()[1] = 3.0;
        let _ = mid;
    }
}
