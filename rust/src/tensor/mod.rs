//! Dense tensor substrate.
//!
//! The offline crate set has no `ndarray` or BLAS, so `cubic` carries its own
//! dense f32 tensor with the handful of operations a Transformer needs:
//! blocked matrix multiplication in all three forms the paper uses
//! (`C = AB`, `C = ABᵀ`, `C = AᵀB`), transpose, elementwise arithmetic,
//! reductions, and block slicing (the primitive behind every shard layout in
//! [`crate::dist`]).
//!
//! ## Dual-mode tensors
//!
//! A [`Tensor`] is either *materialized* (carries a `Vec<f32>`) or *phantom*
//! (shape only). Every operation flows through the same code path in both
//! modes: phantom inputs produce phantom outputs with the correct shape.
//! This is the mechanism that lets the benchmark harness drive the exact
//! 1-D/2-D/3-D schedules at paper scale (hidden 8192, batch 384 — ~10¹⁵
//! flops) while charging only virtual time, and lets the test suite verify
//! the *same* code path numerically at small scale. See DESIGN.md §2.

use crate::rng::Xoshiro256;
use std::fmt;

pub mod matmul;

pub use matmul::{flops_executed as matmul_flops, reset_flops as reset_flop_counter};

/// Row-major dense f32 tensor (materialized) or shape-only placeholder
/// (phantom).
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Option<Vec<f32>>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match &self.data {
            Some(d) if d.len() <= 16 => {
                write!(f, "Tensor{:?} {:?}", self.shape, d)
            }
            Some(_) => write!(f, "Tensor{:?} (materialized)", self.shape),
            None => write!(f, "Tensor{:?} (phantom)", self.shape),
        }
    }
}

impl Tensor {
    // ------------------------------------------------------------------
    // Constructors
    // ------------------------------------------------------------------

    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Self { shape: shape.to_vec(), data: Some(vec![0.0; n]) }
    }

    pub fn ones(shape: &[usize]) -> Self {
        Self::full(shape, 1.0)
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        let n: usize = shape.iter().product();
        Self { shape: shape.to_vec(), data: Some(vec![v; n]) }
    }

    /// Shape-only tensor: flows through every op without computing data.
    pub fn phantom(shape: &[usize]) -> Self {
        Self { shape: shape.to_vec(), data: None }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "shape {:?} does not match data len {}", shape, data.len());
        Self { shape: shape.to_vec(), data: Some(data) }
    }

    /// N(0, std) initialized tensor (deterministic given the rng state).
    pub fn randn(shape: &[usize], std: f32, rng: &mut Xoshiro256) -> Self {
        let n: usize = shape.iter().product();
        let mut data = vec![0.0f32; n];
        rng.fill_normal(&mut data, std);
        Self { shape: shape.to_vec(), data: Some(data) }
    }

    /// U(lo, hi) initialized tensor.
    pub fn rand_uniform(shape: &[usize], lo: f32, hi: f32, rng: &mut Xoshiro256) -> Self {
        let n: usize = shape.iter().product();
        let mut data = vec![0.0f32; n];
        rng.fill_uniform(&mut data, lo, hi);
        Self { shape: shape.to_vec(), data: Some(data) }
    }

    // ------------------------------------------------------------------
    // Accessors
    // ------------------------------------------------------------------

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_phantom(&self) -> bool {
        self.data.is_none()
    }

    /// Bytes this tensor would occupy materialized (used by the memory
    /// accountant regardless of mode).
    pub fn nominal_bytes(&self) -> usize {
        self.numel() * std::mem::size_of::<f32>()
    }

    pub fn data(&self) -> &[f32] {
        self.data.as_deref().expect("tensor is phantom; no data")
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        self.data.as_deref_mut().expect("tensor is phantom; no data")
    }

    pub fn try_data(&self) -> Option<&[f32]> {
        self.data.as_deref()
    }

    /// 2-D dimensions helper; panics if not rank 2.
    pub fn dims2(&self) -> (usize, usize) {
        assert_eq!(self.shape.len(), 2, "expected rank-2 tensor, got {:?}", self.shape);
        (self.shape[0], self.shape[1])
    }

    pub fn at2(&self, r: usize, c: usize) -> f32 {
        let (_, cols) = self.dims2();
        self.data()[r * cols + c]
    }

    // ------------------------------------------------------------------
    // Shape manipulation
    // ------------------------------------------------------------------

    pub fn reshape(&self, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.numel(), "reshape {:?} -> {:?} changes numel", self.shape, shape);
        Tensor { shape: shape.to_vec(), data: self.data.clone() }
    }

    pub fn into_reshape(mut self, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.numel(), "reshape {:?} -> {:?} changes numel", self.shape, shape);
        self.shape = shape.to_vec();
        self
    }

    /// 2-D transpose.
    pub fn transpose(&self) -> Tensor {
        let (r, c) = self.dims2();
        let Some(src) = self.try_data() else {
            return Tensor::phantom(&[c, r]);
        };
        let mut out = vec![0.0f32; r * c];
        // Blocked transpose for cache friendliness.
        const B: usize = 32;
        for ib in (0..r).step_by(B) {
            for jb in (0..c).step_by(B) {
                for i in ib..(ib + B).min(r) {
                    for j in jb..(jb + B).min(c) {
                        out[j * r + i] = src[i * c + j];
                    }
                }
            }
        }
        Tensor::from_vec(&[c, r], out)
    }

    // ------------------------------------------------------------------
    // Block slicing / assembly — the primitive behind all shard layouts
    // ------------------------------------------------------------------

    /// Extract the sub-block `[r0..r0+rows, c0..c0+cols]` of a rank-2 tensor.
    pub fn block(&self, r0: usize, c0: usize, rows: usize, cols: usize) -> Tensor {
        let (r, c) = self.dims2();
        assert!(r0 + rows <= r && c0 + cols <= c,
            "block [{r0}+{rows}, {c0}+{cols}] out of bounds for {:?}", self.shape);
        let Some(src) = self.try_data() else {
            return Tensor::phantom(&[rows, cols]);
        };
        let mut out = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            let off = (r0 + i) * c + c0;
            out.extend_from_slice(&src[off..off + cols]);
        }
        Tensor::from_vec(&[rows, cols], out)
    }

    /// Write `src` into the sub-block at `[r0, c0]` of a rank-2 tensor.
    pub fn set_block(&mut self, r0: usize, c0: usize, src: &Tensor) {
        let (r, c) = self.dims2();
        let (rows, cols) = src.dims2();
        assert!(r0 + rows <= r && c0 + cols <= c,
            "set_block [{r0}+{rows}, {c0}+{cols}] out of bounds for {r}x{c}");
        if self.is_phantom() || src.is_phantom() {
            return;
        }
        let sdata = src.data().to_vec();
        let dst = self.data_mut();
        for i in 0..rows {
            let doff = (r0 + i) * c + c0;
            let soff = i * cols;
            dst[doff..doff + cols].copy_from_slice(&sdata[soff..soff + cols]);
        }
    }

    /// Concatenate rank-2 tensors along rows (axis 0).
    pub fn concat_rows(parts: &[Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let cols = parts[0].dims2().1;
        let rows: usize = parts.iter().map(|p| {
            assert_eq!(p.dims2().1, cols, "concat_rows: column mismatch");
            p.dims2().0
        }).sum();
        if parts.iter().any(|p| p.is_phantom()) {
            return Tensor::phantom(&[rows, cols]);
        }
        let mut data = Vec::with_capacity(rows * cols);
        for p in parts {
            data.extend_from_slice(p.data());
        }
        Tensor::from_vec(&[rows, cols], data)
    }

    /// Concatenate rank-2 tensors along columns (axis 1).
    pub fn concat_cols(parts: &[Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let rows = parts[0].dims2().0;
        let cols: usize = parts.iter().map(|p| {
            assert_eq!(p.dims2().0, rows, "concat_cols: row mismatch");
            p.dims2().1
        }).sum();
        if parts.iter().any(|p| p.is_phantom()) {
            return Tensor::phantom(&[rows, cols]);
        }
        let mut data = vec![0.0f32; rows * cols];
        let mut c0 = 0;
        for p in parts {
            let (_, pc) = p.dims2();
            let pd = p.data();
            for i in 0..rows {
                data[i * cols + c0..i * cols + c0 + pc]
                    .copy_from_slice(&pd[i * pc..(i + 1) * pc]);
            }
            c0 += pc;
        }
        Tensor::from_vec(&[rows, cols], data)
    }

    /// Split a rank-2 tensor into `n` equal row chunks.
    pub fn split_rows(&self, n: usize) -> Vec<Tensor> {
        let (r, c) = self.dims2();
        assert_eq!(r % n, 0, "split_rows: {r} rows not divisible by {n}");
        let chunk = r / n;
        (0..n).map(|i| self.block(i * chunk, 0, chunk, c)).collect()
    }

    /// Split a rank-2 tensor into `n` equal column chunks.
    pub fn split_cols(&self, n: usize) -> Vec<Tensor> {
        let (r, c) = self.dims2();
        assert_eq!(c % n, 0, "split_cols: {c} cols not divisible by {n}");
        let chunk = c / n;
        (0..n).map(|j| self.block(0, j * chunk, r, chunk)).collect()
    }

    // ------------------------------------------------------------------
    // Elementwise arithmetic
    // ------------------------------------------------------------------

    fn zip_with(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
        assert_eq!(self.shape, other.shape,
            "elementwise shape mismatch: {:?} vs {:?}", self.shape, other.shape);
        match (self.try_data(), other.try_data()) {
            (Some(a), Some(b)) => {
                let data = a.iter().zip(b.iter()).map(|(&x, &y)| f(x, y)).collect();
                Tensor::from_vec(&self.shape, data)
            }
            _ => Tensor::phantom(&self.shape),
        }
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        self.zip_with(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        self.zip_with(other, |a, b| a - b)
    }

    pub fn mul(&self, other: &Tensor) -> Tensor {
        self.zip_with(other, |a, b| a * b)
    }

    /// In-place accumulate: `self += other`.
    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape,
            "add_assign shape mismatch: {:?} vs {:?}", self.shape, other.shape);
        if self.is_phantom() || other.is_phantom() {
            self.data = None;
            return;
        }
        let o = other.data();
        for (a, &b) in self.data_mut().iter_mut().zip(o.iter()) {
            *a += b;
        }
    }

    /// In-place axpy: `self += alpha * other`.
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        if self.is_phantom() || other.is_phantom() {
            self.data = None;
            return;
        }
        let o = other.data();
        for (a, &b) in self.data_mut().iter_mut().zip(o.iter()) {
            *a += alpha * b;
        }
    }

    pub fn scale(&self, s: f32) -> Tensor {
        self.map(|x| x * s)
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        match self.try_data() {
            Some(d) => Tensor::from_vec(&self.shape, d.iter().map(|&x| f(x)).collect()),
            None => Tensor::phantom(&self.shape),
        }
    }

    /// Add a row vector (len == cols) to every row of a rank-2 tensor.
    pub fn add_row_vector(&self, v: &Tensor) -> Tensor {
        let (r, c) = self.dims2();
        assert_eq!(v.numel(), c, "row vector len {} != cols {c}", v.numel());
        match (self.try_data(), v.try_data()) {
            (Some(a), Some(b)) => {
                let mut out = Vec::with_capacity(r * c);
                for i in 0..r {
                    for j in 0..c {
                        out.push(a[i * c + j] + b[j]);
                    }
                }
                Tensor::from_vec(&self.shape, out)
            }
            _ => Tensor::phantom(&self.shape),
        }
    }

    /// Multiply every row of a rank-2 tensor by a row vector (len == cols).
    pub fn mul_row_vector(&self, v: &Tensor) -> Tensor {
        let (r, c) = self.dims2();
        assert_eq!(v.numel(), c, "row vector len {} != cols {c}", v.numel());
        match (self.try_data(), v.try_data()) {
            (Some(a), Some(b)) => {
                let mut out = Vec::with_capacity(r * c);
                for i in 0..r {
                    for j in 0..c {
                        out.push(a[i * c + j] * b[j]);
                    }
                }
                Tensor::from_vec(&self.shape, out)
            }
            _ => Tensor::phantom(&self.shape),
        }
    }

    // ------------------------------------------------------------------
    // Reductions
    // ------------------------------------------------------------------

    pub fn sum(&self) -> f32 {
        self.data().iter().sum()
    }

    /// Sum over rows producing a row vector of length `cols`.
    pub fn sum_rows(&self) -> Tensor {
        let (r, c) = self.dims2();
        let Some(d) = self.try_data() else {
            return Tensor::phantom(&[c]);
        };
        let mut out = vec![0.0f32; c];
        for i in 0..r {
            for j in 0..c {
                out[j] += d[i * c + j];
            }
        }
        Tensor::from_vec(&[c], out)
    }

    /// Sum over columns producing a column vector of length `rows`.
    pub fn sum_cols(&self) -> Tensor {
        let (r, c) = self.dims2();
        let Some(d) = self.try_data() else {
            return Tensor::phantom(&[r]);
        };
        let mut out = vec![0.0f32; r];
        for i in 0..r {
            let row = &d[i * c..(i + 1) * c];
            out[i] = row.iter().sum();
        }
        Tensor::from_vec(&[r], out)
    }

    /// Max |a - b| over all elements; used pervasively by tests.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape,
            "max_abs_diff shape mismatch: {:?} vs {:?}", self.shape, other.shape);
        self.data()
            .iter()
            .zip(other.data().iter())
            .map(|(&a, &b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Relative L2 error ‖a−b‖ / (‖b‖ + eps).
    pub fn rel_l2_error(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for (&a, &b) in self.data().iter().zip(other.data().iter()) {
            num += ((a - b) as f64).powi(2);
            den += (b as f64).powi(2);
        }
        (num.sqrt() / (den.sqrt() + 1e-12)) as f32
    }

    pub fn frob_norm(&self) -> f32 {
        (self.data().iter().map(|&x| (x as f64).powi(2)).sum::<f64>()).sqrt() as f32
    }

    // ------------------------------------------------------------------
    // Matmul — delegates to the blocked kernels in `matmul`
    // ------------------------------------------------------------------

    /// `C = self · other` — (m,k)·(k,n) -> (m,n).
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        matmul::matmul_nn(self, other)
    }

    /// `C = self · otherᵀ` — (m,k)·(n,k)ᵀ -> (m,n).
    pub fn matmul_nt(&self, other: &Tensor) -> Tensor {
        matmul::matmul_nt(self, other)
    }

    /// `C = selfᵀ · other` — (k,m)ᵀ·(k,n) -> (m,n).
    pub fn matmul_tn(&self, other: &Tensor) -> Tensor {
        matmul::matmul_tn(self, other)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t2(rows: usize, cols: usize, f: impl Fn(usize, usize) -> f32) -> Tensor {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Tensor::from_vec(&[rows, cols], data)
    }

    #[test]
    fn construct_and_shape() {
        let t = Tensor::zeros(&[3, 4]);
        assert_eq!(t.shape(), &[3, 4]);
        assert_eq!(t.numel(), 12);
        assert!(!t.is_phantom());
        let p = Tensor::phantom(&[3, 4]);
        assert!(p.is_phantom());
        assert_eq!(p.nominal_bytes(), 48);
    }

    #[test]
    fn transpose_round_trip() {
        let t = t2(5, 7, |i, j| (i * 10 + j) as f32);
        let tt = t.transpose().transpose();
        assert_eq!(t, tt);
        assert_eq!(t.transpose().at2(3, 4), t.at2(4, 3));
    }

    #[test]
    fn transpose_phantom_keeps_shape() {
        let p = Tensor::phantom(&[5, 7]);
        let pt = p.transpose();
        assert!(pt.is_phantom());
        assert_eq!(pt.shape(), &[7, 5]);
    }

    #[test]
    fn block_and_set_block_round_trip() {
        let t = t2(6, 8, |i, j| (i * 8 + j) as f32);
        let b = t.block(2, 3, 3, 4);
        assert_eq!(b.shape(), &[3, 4]);
        assert_eq!(b.at2(0, 0), t.at2(2, 3));
        assert_eq!(b.at2(2, 3), t.at2(4, 6));
        let mut z = Tensor::zeros(&[6, 8]);
        z.set_block(2, 3, &b);
        assert_eq!(z.at2(3, 4), t.at2(3, 4));
        assert_eq!(z.at2(0, 0), 0.0);
    }

    #[test]
    fn split_concat_rows_round_trip() {
        let t = t2(6, 4, |i, j| (i + j) as f32 * 0.5);
        let parts = t.split_rows(3);
        assert_eq!(parts.len(), 3);
        assert_eq!(Tensor::concat_rows(&parts), t);
    }

    #[test]
    fn split_concat_cols_round_trip() {
        let t = t2(4, 6, |i, j| (i * 6 + j) as f32);
        let parts = t.split_cols(2);
        assert_eq!(parts[1].at2(0, 0), 3.0);
        assert_eq!(Tensor::concat_cols(&parts), t);
    }

    #[test]
    fn elementwise_ops() {
        let a = t2(2, 3, |i, j| (i + j) as f32);
        let b = t2(2, 3, |_, _| 2.0);
        assert_eq!(a.add(&b).at2(1, 2), 5.0);
        assert_eq!(a.sub(&b).at2(0, 0), -2.0);
        assert_eq!(a.mul(&b).at2(1, 1), 4.0);
        assert_eq!(a.scale(3.0).at2(1, 2), 9.0);
        let mut c = a.clone();
        c.add_assign(&b);
        assert_eq!(c.at2(0, 1), 3.0);
        c.axpy(-1.0, &b);
        assert_eq!(c, a);
    }

    #[test]
    fn phantom_propagates_through_elementwise() {
        let a = Tensor::phantom(&[2, 3]);
        let b = Tensor::ones(&[2, 3]);
        assert!(a.add(&b).is_phantom());
        assert!(b.mul(&a).is_phantom());
        let mut c = Tensor::ones(&[2, 3]);
        c.add_assign(&a);
        assert!(c.is_phantom());
    }

    #[test]
    fn row_vector_ops() {
        let a = t2(2, 3, |i, j| (i * 3 + j) as f32);
        let v = Tensor::from_vec(&[3], vec![10.0, 20.0, 30.0]);
        let s = a.add_row_vector(&v);
        assert_eq!(s.at2(0, 0), 10.0);
        assert_eq!(s.at2(1, 2), 35.0);
        let m = a.mul_row_vector(&v);
        assert_eq!(m.at2(1, 1), 80.0);
    }

    #[test]
    fn reductions() {
        let a = t2(2, 3, |i, j| (i * 3 + j) as f32); // 0..5
        assert_eq!(a.sum(), 15.0);
        assert_eq!(a.sum_rows().data(), &[3.0, 5.0, 7.0]);
        assert_eq!(a.sum_cols().data(), &[3.0, 12.0]);
    }

    #[test]
    fn error_metrics() {
        let a = t2(2, 2, |i, j| (i + j) as f32);
        let mut b = a.clone();
        b.data_mut()[3] += 0.5;
        assert!((a.max_abs_diff(&b) - 0.5).abs() < 1e-6);
        assert!(a.rel_l2_error(&a) < 1e-9);
    }

    #[test]
    fn randn_is_deterministic_per_seed() {
        let mut r1 = Xoshiro256::seed_from_u64(11);
        let mut r2 = Xoshiro256::seed_from_u64(11);
        let a = Tensor::randn(&[4, 4], 0.02, &mut r1);
        let b = Tensor::randn(&[4, 4], 0.02, &mut r2);
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "phantom")]
    fn phantom_data_access_panics() {
        let p = Tensor::phantom(&[2, 2]);
        let _ = p.data();
    }

    #[test]
    fn reshape_checks_numel() {
        let t = Tensor::zeros(&[2, 6]);
        let r = t.reshape(&[3, 4]);
        assert_eq!(r.shape(), &[3, 4]);
    }

    #[test]
    #[should_panic(expected = "changes numel")]
    fn bad_reshape_panics() {
        let t = Tensor::zeros(&[2, 6]);
        let _ = t.reshape(&[3, 5]);
    }
}
