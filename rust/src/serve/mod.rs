//! Inference serving: KV-cached autoregressive decode + continuous
//! batching over every mesh kind.
//!
//! # Serving model
//!
//! **Prefill/decode split.** A request's prompt is processed in one
//! *prefill* pass — [`crate::model::block::prefill_block_fwd`] per layer,
//! which is the training forward verbatim with the backward stash dropped
//! and the per-layer K/V rows harvested into a
//! [`crate::model::attention::DecodeKv`]. Generation then proceeds one
//! *decode* step at a time: one new token per batch slot,
//! [`crate::model::block::decode_block_fwd`] mirroring the block's exact
//! float-op sequence on single-row-per-slot tensors, attention scoring
//! against the appended KV prefix. Decode output rows are the next step's
//! input rows — the autoregressive feedback never leaves the sharded
//! domain (the crate models the paper's parallelized core, embedding and
//! head excluded, so "token identity" is the block-entry hidden state).
//!
//! **KV sharding per leaf.** The cache inherits the training layout with
//! zero new placement rules: heads split by `ShardSpec::head_divisor`
//! (already validated), slots split exactly like activation rows, layers
//! split by pipeline stage. A rank caches precisely the (slot, head) pairs
//! whose QKV shard it already computes, so decode attention stays
//! rank-local on every mesh — the collectives are the same linear-layer
//! collectives as training, at one row per slot.
//!
//! **Scheduler admission policy.** The continuous-batching scheduler
//! ([`simulate`]) is deterministic and step-structured, consuming
//! virtual-clock step costs measured by [`measure_serve`]:
//! at each step boundary, arrivals up to `now` join the queue; then
//! *if a slot is free and a request is waiting*, one prefill step admits
//! as many waiters as fit; *else if any slot is active*, one decode step
//! advances every active slot by one token, retiring finished sequences
//! mid-flight (their slots are reusable at the very next boundary);
//! *else* the clock jumps to the next arrival. Open-loop synthetic
//! traffic: seeded exponential inter-arrivals, seeded ragged
//! prompt/generation lengths. The SPMD engine always computes the full
//! slot grid (fixed collective shapes — the steady-state zero-allocation
//! property depends on it), so the measured decode-step cost is an
//! occupancy-independent ceiling; the simulator tracks which of those
//! slot-rows carry live requests.
//!
//! **Phantom projection.** Everything above runs in phantom mode — the
//! same charges, no floats — so `cubic serve --phantom --world 64`
//! projects tokens/sec/rank and p50/p99 latency per mesh kind on a
//! laptop. Costmodel cross-checks: `costmodel::decode_step_comm_bytes_per_rank`
//! and `costmodel::kv_cache_bytes_per_rank` are pinned against this
//! engine's ledger and cache in their tests.
//!
//! **Follow-ons** (recorded in ROADMAP): paged KV (page-granular cache
//! blocks so `max_seq` stops over-reserving), speculative decode (draft
//! model over the same `ParallelOps`), per-slot ragged prefill
//! (admission-sized prefill instead of the full-grid step), and
//! measured-cost admission control in the scheduler.

use crate::comm::NetModel;
use crate::config::{ModelConfig, ServeConfig};
use crate::model::attention::DecodeKv;
use crate::model::{init_dense_blocks, BlockTensors};
use crate::parallel::{ops_for, pipeline::Pipeline, ParallelOps};
use crate::rng::Xoshiro256;
use crate::spmd::run_spmd_with_stats;
use crate::tensor::Tensor;
use crate::topology::Parallelism;

/// Build this rank's ops + layer slice of sharded (or phantom) blocks.
fn build_rank(
    par: Parallelism,
    edge: usize,
    rank: usize,
    cfg: &ModelConfig,
    seed: u64,
    phantom: bool,
) -> (Box<dyn ParallelOps>, Vec<BlockTensors>) {
    let (ops, range): (Box<dyn ParallelOps>, std::ops::Range<usize>) = match par {
        Parallelism::Pipeline { stages, micro_batches, inner } => {
            let p = Pipeline::for_kind(stages, micro_batches, inner, edge, rank);
            let r = p.layer_range(cfg.layers);
            (Box::new(p), r)
        }
        _ => (ops_for(par, edge, rank), 0..cfg.layers),
    };
    let blocks: Vec<BlockTensors> = if phantom {
        range.map(|_| ops.phantom_block(cfg)).collect()
    } else {
        let dense = init_dense_blocks(cfg, seed);
        dense[range].iter().map(|b| ops.shard_block(b)).collect()
    };
    (ops, blocks)
}

/// One empty [`DecodeKv`] per local layer, sized from the rank's spec:
/// local slots from the activation row split of the `(slots, hidden)`
/// decode grid, local heads from the head divisor.
pub fn build_kv(
    ops: &dyn ParallelOps,
    layers_local: usize,
    cfg: &ModelConfig,
    slots: usize,
    max_seq: usize,
    phantom: bool,
) -> Vec<DecodeKv> {
    let (slots_loc, _) = ops.activation_shape(slots, cfg.hidden);
    let heads_loc = ops.local_heads(cfg);
    let hd = cfg.hidden / cfg.heads;
    (0..layers_local)
        .map(|_| DecodeKv::new(slots_loc, heads_loc, hd, max_seq, phantom))
        .collect()
}

/// Extract the per-slot feedback rows from a prefill output shard: slot
/// `s`'s last prompt position (`lens[s] - 1`) within its padded window.
/// `y` must hold `slots_loc` whole padded slot windows (the serve
/// divisibility conditions in `ModelConfig::validate_serve` guarantee the
/// row split lands on slot boundaries).
pub fn feedback_rows(y: &Tensor, slots_loc: usize, pad: usize, lens: &[usize]) -> Tensor {
    let (rows, cols) = y.dims2();
    assert_eq!(rows, slots_loc * pad, "prefill shard is not whole padded slots");
    assert_eq!(lens.len(), slots_loc);
    if y.is_phantom() {
        return Tensor::phantom(&[slots_loc, cols]);
    }
    let parts: Vec<Tensor> =
        (0..slots_loc).map(|s| y.block(s * pad + lens[s] - 1, 0, 1, cols)).collect();
    Tensor::concat_rows(&parts)
}

/// Virtual-clock serve measurement: one full-grid prefill at the padded
/// prompt length, then `gen_len` full-grid decode steps with the output
/// rows fed back as the next input.
#[derive(Clone, Debug)]
pub struct ServeMeasurement {
    /// Max-over-ranks prefill time (s, virtual clock).
    pub prefill_s: f64,
    /// Per-decode-step durations (s), max over ranks per step; the step at
    /// index `i` runs with `prompt_len + i` tokens resident per slot.
    pub decode_step_s: Vec<f64>,
    /// Max-over-ranks total decode time (s).
    pub decode_total_s: f64,
    /// `slots · gen_len / decode_total_s / world`.
    pub tokens_per_sec_per_rank: f64,
    /// Mean bytes sent per rank over the whole run.
    pub bytes_sent_per_rank: u64,
}

/// Run the serve schedule under the SPMD engine (`phantom = true` charges
/// the clock without floats — any world size; `phantom = false` computes
/// real numerics). Deterministic: same inputs → bitwise-same measurement.
pub fn measure_serve(
    cfg: &ModelConfig,
    serve: &ServeConfig,
    par: Parallelism,
    edge: usize,
    net: NetModel,
    phantom: bool,
    seed: u64,
) -> ServeMeasurement {
    let world = par.world_size(edge);
    let (cfgc, sv) = (cfg.clone(), serve.clone());
    let results = run_spmd_with_stats(world, net, move |rank, ep| {
        let (ops, blocks) = build_rank(par, edge, rank, &cfgc, seed, phantom);
        let ops = ops.as_ref();
        let pad = sv.prompt_len;
        let mut kv = build_kv(ops, blocks.len(), &cfgc, sv.slots, sv.max_seq, phantom);
        let slots_loc = kv.first().map_or(0, |k| k.slots);
        let lens = vec![pad; slots_loc];
        let cfg_pre = ModelConfig { seq: pad, batch: sv.slots, ..cfgc.clone() };
        let x = if phantom {
            let (r, c) = ops.activation_shape(sv.slots * pad, cfgc.hidden);
            Tensor::phantom(&[r, c])
        } else {
            let gx = Tensor::randn(
                &[sv.slots * pad, cfgc.hidden],
                0.5,
                &mut Xoshiro256::seed_from_u64(seed ^ 0x5e),
            );
            ops.scatter_activation(ep, &gx)
        };
        let y = ops.serve_prefill(ep, &blocks, &x, &cfg_pre, &lens, &mut kv);
        let t_prefill = ep.clock;
        let mut xd = feedback_rows(&y, slots_loc, pad, &lens);
        let mut clocks = Vec::with_capacity(sv.gen_len);
        for _ in 0..sv.gen_len {
            xd = ops.serve_decode(ep, &blocks, &xd, &cfgc, &mut kv);
            clocks.push(ep.clock);
        }
        (t_prefill, clocks)
    });
    let prefill_s = results.iter().map(|((t, _), _, _)| *t).fold(0.0, f64::max);
    let gen = serve.gen_len;
    let mut decode_step_s = vec![0.0f64; gen];
    let mut decode_total_s = 0.0f64;
    let mut bytes = 0u64;
    for ((t_pre, clocks), _, stats) in &results {
        let mut prev = *t_pre;
        for (i, &c) in clocks.iter().enumerate() {
            decode_step_s[i] = decode_step_s[i].max(c - prev);
            prev = c;
        }
        if let Some(&last) = clocks.last() {
            decode_total_s = decode_total_s.max(last - t_pre);
        }
        bytes += stats.bytes_sent;
    }
    let tokens = (serve.slots * gen) as f64;
    let tokens_per_sec_per_rank = if decode_total_s > 0.0 {
        tokens / decode_total_s / world as f64
    } else {
        f64::INFINITY
    };
    ServeMeasurement {
        prefill_s,
        decode_step_s,
        decode_total_s,
        tokens_per_sec_per_rank,
        bytes_sent_per_rank: bytes / world as u64,
    }
}

/// One synthetic request's lifecycle through the scheduler.
#[derive(Clone, Debug)]
pub struct SimRequest {
    pub id: usize,
    /// Open-loop arrival time (s).
    pub arrival: f64,
    /// Seeded ragged lengths: prompt tokens and tokens to generate.
    pub prompt: usize,
    pub gen: usize,
    /// Step-boundary times: admitted (prefill ran), first token decoded,
    /// last token decoded.
    pub admit: f64,
    pub first_token: f64,
    pub finish: f64,
}

impl SimRequest {
    /// End-to-end latency (arrival → last token).
    pub fn latency(&self) -> f64 {
        self.finish - self.arrival
    }

    /// One deterministic trace line (CI diffs two same-seed runs).
    pub fn trace_line(&self) -> String {
        format!(
            "req {:>3}: prompt {:>3} gen {:>3} arrive {:.6} admit {:.6} first {:.6} finish {:.6}",
            self.id, self.prompt, self.gen, self.arrival, self.admit, self.first_token, self.finish
        )
    }
}

/// Scheduler outcome over one seeded traffic trace.
#[derive(Clone, Debug)]
pub struct SimReport {
    pub requests: Vec<SimRequest>,
    /// End-to-end latency percentiles (s).
    pub p50: f64,
    pub p99: f64,
    pub mean: f64,
    /// Time of the last finish (s).
    pub makespan: f64,
    /// Decoded tokens actually generated (sum of `gen`).
    pub tokens: u64,
    pub prefill_steps: u64,
    pub decode_steps: u64,
    /// High-water mark of concurrently active slots.
    pub max_concurrent: usize,
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx]
}

/// Deterministic continuous-batching simulation (admission policy in the
/// module docs). `prefill_cost` and `decode_cost` come from
/// [`measure_serve`]; a decode step is charged at the cost index of the
/// deepest active slot (attention cost grows with resident tokens), capped
/// at the last measured step.
pub fn simulate(serve: &ServeConfig, prefill_cost: f64, decode_cost: &[f64]) -> SimReport {
    assert!(serve.slots >= 1 && serve.requests >= 1 && serve.arrival_rate > 0.0);
    assert!(!decode_cost.is_empty());
    let mut rng = Xoshiro256::seed_from_u64(serve.seed);
    let mut reqs: Vec<SimRequest> = Vec::with_capacity(serve.requests);
    let mut t = 0.0f64;
    for id in 0..serve.requests {
        // Exponential inter-arrivals at the open-loop rate; ragged lengths
        // uniform in [1, prompt_len] / [1, gen_len].
        t += -(1.0 - rng.next_f64()).ln() / serve.arrival_rate;
        let prompt = 1 + rng.next_below(serve.prompt_len as u64) as usize;
        let gen = 1 + rng.next_below(serve.gen_len as u64) as usize;
        reqs.push(SimRequest {
            id,
            arrival: t,
            prompt,
            gen,
            admit: 0.0,
            first_token: 0.0,
            finish: 0.0,
        });
    }

    // (request index, tokens generated so far) per occupied slot.
    let mut active: Vec<(usize, usize)> = Vec::new();
    let mut queue: std::collections::VecDeque<usize> = std::collections::VecDeque::new();
    let mut next_arrival = 0usize;
    let mut now = 0.0f64;
    let (mut prefill_steps, mut decode_steps, mut tokens) = (0u64, 0u64, 0u64);
    let mut max_concurrent = 0usize;
    let mut done = 0usize;
    while done < serve.requests {
        while next_arrival < reqs.len() && reqs[next_arrival].arrival <= now {
            queue.push_back(next_arrival);
            next_arrival += 1;
        }
        if active.is_empty() && queue.is_empty() {
            // Idle: jump to the next arrival.
            now = reqs[next_arrival].arrival;
            continue;
        }
        if !queue.is_empty() && active.len() < serve.slots {
            // Prefill step: admit every waiter that fits; admission
            // completes at the step boundary.
            let mut admitted = Vec::new();
            while active.len() < serve.slots {
                let Some(i) = queue.pop_front() else { break };
                active.push((i, 0));
                admitted.push(i);
            }
            now += prefill_cost;
            prefill_steps += 1;
            max_concurrent = max_concurrent.max(active.len());
            for i in admitted {
                reqs[i].admit = now;
            }
            continue;
        }
        // Decode step: every active slot emits one token; retire finished
        // sequences mid-flight (their slots free up this boundary).
        let depth = active.iter().map(|&(_, g)| g).max().unwrap_or(0);
        now += decode_cost[depth.min(decode_cost.len() - 1)];
        decode_steps += 1;
        let mut still = Vec::with_capacity(active.len());
        for (i, g) in active {
            let g = g + 1;
            tokens += 1;
            if g == 1 {
                reqs[i].first_token = now;
            }
            if g == reqs[i].gen {
                reqs[i].finish = now;
                done += 1;
            } else {
                still.push((i, g));
            }
        }
        active = still;
    }

    let mut lats: Vec<f64> = reqs.iter().map(|r| r.latency()).collect();
    lats.sort_by(|a, b| a.total_cmp(b));
    let mean = lats.iter().sum::<f64>() / lats.len() as f64;
    let makespan = reqs.iter().map(|r| r.finish).fold(0.0, f64::max);
    SimReport {
        p50: percentile(&lats, 0.50),
        p99: percentile(&lats, 0.99),
        mean,
        makespan,
        tokens,
        prefill_steps,
        decode_steps,
        max_concurrent,
        requests: reqs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(slots: usize, requests: usize, rate: f64, seed: u64) -> ServeConfig {
        ServeConfig {
            slots,
            max_seq: 32,
            prompt_len: 8,
            gen_len: 8,
            requests,
            arrival_rate: rate,
            seed,
        }
    }

    #[test]
    fn simulation_is_deterministic_for_a_seed() {
        let cost = vec![2e-3; 8];
        let a = simulate(&sv(4, 64, 40.0, 9), 1e-2, &cost);
        let b = simulate(&sv(4, 64, 40.0, 9), 1e-2, &cost);
        let ta: Vec<String> = a.requests.iter().map(|r| r.trace_line()).collect();
        let tb: Vec<String> = b.requests.iter().map(|r| r.trace_line()).collect();
        assert_eq!(ta, tb, "same seed must reproduce the trace bitwise");
        assert_eq!(a.p50, b.p50);
        assert_eq!(a.p99, b.p99);
        let c = simulate(&sv(4, 64, 40.0, 10), 1e-2, &cost);
        let tc: Vec<String> = c.requests.iter().map(|r| r.trace_line()).collect();
        assert_ne!(ta, tc, "a different seed must change the trace");
    }

    #[test]
    fn every_request_completes_in_order_of_physics() {
        let r = simulate(&sv(4, 100, 25.0, 3), 5e-3, &[1e-3; 8]);
        assert_eq!(r.requests.len(), 100);
        for q in &r.requests {
            assert!(q.admit >= q.arrival, "admitted before arrival: {}", q.trace_line());
            assert!(q.first_token > q.admit, "token before admission: {}", q.trace_line());
            assert!(q.finish >= q.first_token, "finish before first token: {}", q.trace_line());
        }
        assert_eq!(r.tokens, r.requests.iter().map(|q| q.gen as u64).sum::<u64>());
        assert!(r.max_concurrent <= 4);
    }

    #[test]
    fn retirement_reuses_slots_mid_flight() {
        // One slot, many requests: every later request can only run because
        // earlier ones retired mid-flight and freed the slot.
        let r = simulate(&sv(1, 10, 1000.0, 5), 1e-3, &[1e-3; 8]);
        assert_eq!(r.max_concurrent, 1);
        assert_eq!(r.requests.iter().filter(|q| q.finish > 0.0).count(), 10);
        // With effectively simultaneous arrivals the queue drains strictly
        // in order: each finish frees the slot for the next admission.
        for w in r.requests.windows(2) {
            assert!(w[1].admit >= w[0].finish - 1e-12, "slot reused before free");
        }
    }

    #[test]
    fn saturation_raises_tail_latency() {
        let cost = vec![2e-3; 8];
        let light = simulate(&sv(4, 64, 5.0, 7), 1e-2, &cost);
        let heavy = simulate(&sv(4, 64, 500.0, 7), 1e-2, &cost);
        assert!(
            heavy.p99 > light.p99,
            "saturated p99 {} must exceed light-load p99 {}",
            heavy.p99,
            light.p99
        );
    }

    #[test]
    fn phantom_measurement_is_deterministic_and_positive() {
        let cfg = ModelConfig::tiny();
        let serve = sv(4, 8, 10.0, 1);
        let m1 = measure_serve(
            &cfg,
            &serve,
            Parallelism::OneD,
            4,
            NetModel::longhorn_v100(),
            true,
            1,
        );
        let m2 = measure_serve(
            &cfg,
            &serve,
            Parallelism::OneD,
            4,
            NetModel::longhorn_v100(),
            true,
            1,
        );
        assert!(m1.prefill_s > 0.0 && m1.decode_total_s > 0.0);
        assert_eq!(m1.prefill_s, m2.prefill_s, "phantom clock must be deterministic");
        assert_eq!(m1.decode_step_s, m2.decode_step_s);
        assert!(m1.tokens_per_sec_per_rank.is_finite() && m1.tokens_per_sec_per_rank > 0.0);
        // Later decode steps attend over longer KV prefixes: monotonically
        // non-decreasing per-step cost.
        for w in m1.decode_step_s.windows(2) {
            assert!(w[1] >= w[0] - 1e-12, "decode step cost decreased: {:?}", m1.decode_step_s);
        }
    }
}
