//! # cubic — 3-D tensor-parallel distributed training
//!
//! `cubic` is a production-shaped reproduction of *"Maximizing Parallelism in
//! Distributed Training for Huge Neural Networks"* (Bian, Xu, Wang, You,
//! 2021): load-balanced 3-D intra-layer tensor parallelism for Transformer
//! models, implemented alongside the 1-D (Megatron [17]) and 2-D
//! (Optimus/SUMMA [21]) baselines the paper compares against.
//!
//! The stack has three layers (see `ARCHITECTURE.md` for the full map):
//!
//! * **L3 (this crate)** — the coordinator: process topology, collective
//!   communication, the 1-D/2-D/3-D parallel linear algebra (the paper's
//!   Algorithms 1–8), the Transformer model, optimizer, trainer, cluster
//!   engine, cost model, and benchmark harness.
//! * **L2 (python/compile/model.py)** — per-shard JAX programs, AOT-lowered
//!   once to HLO text in `artifacts/`.
//! * **L1 (python/compile/kernels/)** — Pallas kernels the L2 programs call.
//!
//! Python never runs at train time: the [`runtime`] module loads the AOT
//! artifacts through the PJRT C API and executes them from Rust.
//!
//! The whole-repo architecture book — the layer map, all seven parallelism
//! kinds with their per-rank memory and communication formulas in one
//! table, the bitwise-determinism contract, and the "adding a parallelism"
//! walkthrough — is `ARCHITECTURE.md` at the repository root. Start there;
//! the module docs below are the per-subsystem deep dives it links to.

pub mod bench;
pub mod cli;
pub mod collectives;
pub mod comm;
pub mod config;
#[deny(missing_docs)]
pub mod costmodel;
#[deny(missing_docs)]
pub mod dist;
pub mod engine;
pub mod metrics;
pub mod model;
pub mod ops;
#[deny(missing_docs)]
pub mod optim;
#[deny(missing_docs)]
pub mod parallel;
pub mod rng;
pub mod runtime;
pub mod serve;
pub mod spmd;
pub mod tensor;
pub mod topology;
pub mod train;

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::comm::{Endpoint, NetModel};
    // config types are re-exported once the config module lands
    pub use crate::rng::Xoshiro256;
    pub use crate::tensor::Tensor;
    pub use crate::topology::{Axis, Coord, Cube, Line, Mesh, Parallelism};
}
