//! PJRT runtime: load and execute the AOT artifacts from Rust.
//!
//! This is the boundary where the L1/L2 Python work re-enters the system —
//! as **HLO text**, never as a Python process. `make artifacts` runs
//! `python/compile/aot.py` once; afterwards the Rust binary is
//! self-contained: [`Runtime`] parses `artifacts/manifest.tsv`, compiles
//! each program on the PJRT CPU client on first use (cached thereafter),
//! and executes it with `Tensor` inputs.
//!
//! ## Threading
//!
//! The `xla` crate's client types are single-threaded; `cubic`'s workers
//! are many. A dedicated **service thread** owns the `PjRtClient` and all
//! compiled executables; worker threads talk to it through a channel via
//! the cloneable [`RuntimeHandle`]. (On this 1-core container the
//! serialization is also the honest performance model — one accelerator
//! services one op at a time.)

use crate::tensor::Tensor;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Sender};

/// Offline stand-in for the `xla` PJRT bindings. This container has no
/// XLA/PJRT shared library in its crate set, so the runtime compiles
/// against this stub: the types mirror the real `xla` crate's surface, but
/// client construction fails with a descriptive error which the service
/// thread then returns for every execute request (callers fall back to the
/// native kernels; the artifact integration tests skip when no bundle is
/// present). Swapping in the real bindings is this module plus one Cargo
/// dependency.
mod xla {
    const UNAVAILABLE: &str =
        "XLA/PJRT bindings are not built into this binary (offline crate set); \
         AOT artifacts cannot be executed — use the native kernels";

    pub struct PjRtClient;
    pub struct PjRtLoadedExecutable;
    pub struct PjRtBuffer;
    pub struct HloModuleProto;
    pub struct XlaComputation;
    pub struct Literal;

    impl PjRtClient {
        pub fn cpu() -> Result<PjRtClient, String> {
            Err(UNAVAILABLE.to_string())
        }

        pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable, String> {
            Err(UNAVAILABLE.to_string())
        }
    }

    impl HloModuleProto {
        pub fn from_text_file(_path: &str) -> Result<HloModuleProto, String> {
            Err(UNAVAILABLE.to_string())
        }
    }

    impl XlaComputation {
        pub fn from_proto(_p: &HloModuleProto) -> XlaComputation {
            XlaComputation
        }
    }

    impl Literal {
        pub fn vec1(_data: &[f32]) -> Literal {
            Literal
        }

        pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, String> {
            Err(UNAVAILABLE.to_string())
        }

        pub fn to_tuple1(&self) -> Result<Literal, String> {
            Err(UNAVAILABLE.to_string())
        }

        pub fn to_vec<T>(&self) -> Result<Vec<T>, String> {
            Err(UNAVAILABLE.to_string())
        }
    }

    impl PjRtLoadedExecutable {
        pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, String> {
            Err(UNAVAILABLE.to_string())
        }
    }

    impl PjRtBuffer {
        pub fn to_literal_sync(&self) -> Result<Literal, String> {
            Err(UNAVAILABLE.to_string())
        }
    }
}

/// One artifact as described by `manifest.tsv`:
/// `name \t file \t in_shapes \t out_shape` with shapes like `64x64,64x256`.
#[derive(Clone, Debug, PartialEq)]
pub struct ManifestEntry {
    pub name: String,
    pub file: String,
    pub in_shapes: Vec<Vec<usize>>,
    pub out_shape: Vec<usize>,
}

fn parse_shape(s: &str) -> Result<Vec<usize>> {
    s.split('x')
        .map(|d| d.parse::<usize>().map_err(|e| anyhow!("bad dim {d:?}: {e}")))
        .collect()
}

/// Parsed manifest (name → entry).
#[derive(Debug, Default)]
pub struct Manifest {
    entries: HashMap<String, ManifestEntry>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut entries = HashMap::new();
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 4 {
                bail!("manifest line {}: expected 4 tab-separated columns", i + 1);
            }
            let in_shapes = cols[2]
                .split(',')
                .map(parse_shape)
                .collect::<Result<Vec<_>>>()
                .with_context(|| format!("manifest line {}", i + 1))?;
            let entry = ManifestEntry {
                name: cols[0].to_string(),
                file: cols[1].to_string(),
                in_shapes,
                out_shape: parse_shape(cols[3])?,
            };
            if entries.insert(entry.name.clone(), entry).is_some() {
                bail!("duplicate manifest entry {:?}", cols[0]);
            }
        }
        Ok(Manifest { entries })
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, name: &str) -> Option<&ManifestEntry> {
        self.entries.get(name)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.entries.keys().cloned().collect();
        v.sort();
        v
    }

    /// Artifact name for a local matmul of the given form and shape, if the
    /// AOT bundle includes it (`mm_nn_MxKxN` naming from aot.py).
    pub fn matmul_name(&self, form: &str, m: usize, k: usize, n: usize) -> Option<String> {
        let name = format!("mm_{form}_{m}x{k}x{n}");
        self.entries.contains_key(&name).then_some(name)
    }
}

enum Request {
    Execute {
        name: String,
        inputs: Vec<Tensor>,
        reply: Sender<Result<Tensor>>,
    },
    Shutdown,
}

/// Cloneable handle for submitting execution requests from any thread.
#[derive(Clone)]
pub struct RuntimeHandle {
    tx: Sender<Request>,
}

impl RuntimeHandle {
    /// Execute artifact `name` with `inputs`; blocks until the result is
    /// ready. Inputs must be materialized rank-1/2 f32 tensors matching the
    /// manifest shapes.
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Tensor> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::Execute {
                name: name.to_string(),
                inputs: inputs.to_vec(),
                reply,
            })
            .map_err(|_| anyhow!("runtime service thread is gone"))?;
        rx.recv().map_err(|_| anyhow!("runtime dropped the request"))?
    }
}

/// The artifact runtime: manifest + service thread owning the PJRT client.
pub struct Runtime {
    pub manifest: Manifest,
    handle: RuntimeHandle,
    join: Option<std::thread::JoinHandle<()>>,
}

impl Runtime {
    /// Load a runtime for an artifacts directory produced by `make
    /// artifacts`. Compiles lazily: each program is compiled on first
    /// execute and cached.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let entries: HashMap<String, ManifestEntry> = manifest
            .names()
            .into_iter()
            .map(|n| (n.clone(), manifest.get(&n).unwrap().clone()))
            .collect();
        let (tx, rx) = channel::<Request>();
        let join = std::thread::Builder::new()
            .name("cubic-pjrt".into())
            .spawn(move || service_thread(dir, entries, rx))
            .context("spawning PJRT service thread")?;
        Ok(Runtime {
            manifest,
            handle: RuntimeHandle { tx },
            join: Some(join),
        })
    }

    pub fn handle(&self) -> RuntimeHandle {
        self.handle.clone()
    }
}

impl Drop for Runtime {
    fn drop(&mut self) {
        let _ = self.handle.tx.send(Request::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

/// The service thread: owns the client, compiles + caches executables,
/// answers execute requests until shutdown.
fn service_thread(
    dir: PathBuf,
    entries: HashMap<String, ManifestEntry>,
    rx: std::sync::mpsc::Receiver<Request>,
) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            // Fail every request with the construction error.
            while let Ok(req) = rx.recv() {
                if let Request::Execute { reply, .. } = req {
                    let _ = reply.send(Err(anyhow!("PJRT client failed to start: {e}")));
                }
            }
            return;
        }
    };
    let mut cache: HashMap<String, xla::PjRtLoadedExecutable> = HashMap::new();
    while let Ok(req) = rx.recv() {
        match req {
            Request::Shutdown => break,
            Request::Execute { name, inputs, reply } => {
                let result = execute_one(&client, &dir, &entries, &mut cache, &name, &inputs);
                let _ = reply.send(result);
            }
        }
    }
}

fn execute_one(
    client: &xla::PjRtClient,
    dir: &Path,
    entries: &HashMap<String, ManifestEntry>,
    cache: &mut HashMap<String, xla::PjRtLoadedExecutable>,
    name: &str,
    inputs: &[Tensor],
) -> Result<Tensor> {
    let entry = entries
        .get(name)
        .ok_or_else(|| anyhow!("unknown artifact {name:?}"))?;
    if inputs.len() != entry.in_shapes.len() {
        bail!(
            "{name}: expected {} inputs, got {}",
            entry.in_shapes.len(),
            inputs.len()
        );
    }
    for (i, (t, want)) in inputs.iter().zip(entry.in_shapes.iter()).enumerate() {
        if t.shape() != &want[..] {
            bail!("{name}: input {i} shape {:?} != manifest {:?}", t.shape(), want);
        }
        if t.is_phantom() {
            bail!("{name}: input {i} is phantom; PJRT needs materialized data");
        }
    }
    if !cache.contains_key(name) {
        let path = dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("loading {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e}"))?;
        cache.insert(name.to_string(), exe);
    }
    let exe = cache.get(name).unwrap();
    let literals: Vec<xla::Literal> = inputs
        .iter()
        .map(|t| {
            let flat = xla::Literal::vec1(t.data());
            let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
            flat.reshape(&dims).map_err(|e| anyhow!("reshaping input: {e}"))
        })
        .collect::<Result<Vec<_>>>()?;
    let result = exe
        .execute::<xla::Literal>(&literals)
        .map_err(|e| anyhow!("executing {name}: {e}"))?[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("fetching result: {e}"))?;
    // aot.py lowers with return_tuple=True → 1-tuple.
    let out = result
        .to_tuple1()
        .map_err(|e| anyhow!("untupling result: {e}"))?;
    let values = out
        .to_vec::<f32>()
        .map_err(|e| anyhow!("reading result: {e}"))?;
    Ok(Tensor::from_vec(&entry.out_shape, values))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses_and_indexes() {
        let text = "mm_nn_4x8x2\tmm_nn_4x8x2.hlo.txt\t4x8,8x2\t4x2\n\
                    gelu_4x8\tgelu_4x8.hlo.txt\t4x8\t4x8\n";
        let m = Manifest::parse(text).unwrap();
        assert_eq!(m.len(), 2);
        let e = m.get("mm_nn_4x8x2").unwrap();
        assert_eq!(e.in_shapes, vec![vec![4, 8], vec![8, 2]]);
        assert_eq!(e.out_shape, vec![4, 2]);
        assert_eq!(m.matmul_name("nn", 4, 8, 2), Some("mm_nn_4x8x2".into()));
        assert_eq!(m.matmul_name("nn", 4, 8, 3), None);
        assert_eq!(m.names()[0], "gelu_4x8");
    }

    #[test]
    fn manifest_rejects_malformed() {
        assert!(Manifest::parse("too\tfew\tcolumns\n").is_err());
        assert!(Manifest::parse("a\tb\t4xZ\t4\n").is_err());
        let dup = "a\tf\t1\t1\na\tf\t1\t1\n";
        assert!(Manifest::parse(dup).is_err());
    }

    // Execution against real artifacts is covered by rust/tests/
    // runtime_artifacts.rs (requires `make artifacts` first).
}
