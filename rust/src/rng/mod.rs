//! Pseudo-random number generation substrate.
//!
//! The offline crate set has no `rand`, so `cubic` carries its own small,
//! well-tested PRNG stack: [`SplitMix64`] for seeding and [`Xoshiro256`]
//! (xoshiro256**) as the workhorse generator, plus normal / uniform sampling
//! helpers used for parameter initialization and synthetic data.
//!
//! Determinism is a hard requirement everywhere in `cubic`: every distributed
//! test initializes both the dense reference and the sharded replicas from the
//! same seed, so this module is part of the correctness story, not just a
//! convenience.

/// SplitMix64: used to expand a single `u64` seed into a full generator state.
///
/// Reference: Steele, Lea, Flood — "Fast splittable pseudorandom number
/// generators" (OOPSLA 2014). This is the standard seeding routine recommended
/// by the xoshiro authors.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast, high-quality 64-bit PRNG (Blackman & Vigna).
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 as recommended by the xoshiro reference code.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform integer in [0, n) via Lemire's rejection-free-ish method
    /// (simple modulo with 64-bit state is fine for our non-crypto uses,
    /// but we debias properly anyway).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // 128-bit multiply method (Lemire 2018), unbiased enough for n << 2^64.
        let m = (self.next_u64() as u128).wrapping_mul(n as u128);
        (m >> 64) as u64
    }

    /// Standard normal sample via Box–Muller (we only need throughput for
    /// init-time weight sampling, and Box–Muller is branch-free and exact).
    pub fn normal(&mut self) -> f32 {
        // Guard against log(0).
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        (r * theta.cos()) as f32
    }

    /// N(mean, std) sample.
    pub fn normal_scaled(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// Fill a slice with N(0, std) values.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal() * std;
        }
    }

    /// Fill a slice with U(lo, hi) values.
    pub fn fill_uniform(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for v in out.iter_mut() {
            *v = self.uniform(lo, hi);
        }
    }

    /// Derive an independent stream for worker `rank` (used so every rank can
    /// draw its own dropout masks etc. without cross-rank correlation).
    pub fn split(&self, rank: u64) -> Xoshiro256 {
        let mut sm = SplitMix64::new(self.s[0] ^ self.s[3].rotate_left(17) ^ rank.wrapping_mul(0x9E3779B97F4A7C15));
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Xoshiro256 { s }
    }
}

/// Sample from a Zipf(s) distribution over [0, n) — used by the synthetic
/// corpus generator to get a realistic token frequency profile.
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for v in cdf.iter_mut() {
            *v /= total;
        }
        Self { cdf }
    }

    pub fn sample(&self, rng: &mut Xoshiro256) -> usize {
        let u = rng.next_f64();
        // Binary search the CDF.
        match self
            .cdf
            .binary_search_by(|probe| probe.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // Reference values for seed 1234567 from the public-domain C code.
        let mut sm = SplitMix64::new(0);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Known first output for seed 0.
        assert_eq!(a, 0xE220A8397B1DCDAF);
    }

    #[test]
    fn xoshiro_is_deterministic() {
        let mut a = Xoshiro256::seed_from_u64(42);
        let mut b = Xoshiro256::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn xoshiro_different_seeds_diverge() {
        let mut a = Xoshiro256::seed_from_u64(1);
        let mut b = Xoshiro256::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_range() {
        let mut rng = Xoshiro256::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
            let y = rng.uniform(-3.0, 5.0);
            assert!((-3.0..5.0).contains(&y));
        }
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut rng = Xoshiro256::seed_from_u64(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let k = rng.next_below(10) as usize;
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Xoshiro256::seed_from_u64(2024);
        let n = 200_000;
        let mut sum = 0.0f64;
        let mut sumsq = 0.0f64;
        for _ in 0..n {
            let x = rng.normal() as f64;
            sum += x;
            sumsq += x * x;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn split_streams_are_independent() {
        let base = Xoshiro256::seed_from_u64(5);
        let mut r0 = base.split(0);
        let mut r1 = base.split(1);
        let equal = (0..64).filter(|_| r0.next_u64() == r1.next_u64()).count();
        assert_eq!(equal, 0);
    }

    #[test]
    fn zipf_is_skewed_and_in_range() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let z = Zipf::new(100, 1.1);
        let mut counts = vec![0usize; 100];
        for _ in 0..20_000 {
            let k = z.sample(&mut rng);
            assert!(k < 100);
            counts[k] += 1;
        }
        // Head should dominate the tail.
        assert!(counts[0] > counts[50] * 5);
    }
}
