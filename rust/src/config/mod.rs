//! Configuration system: typed configs + a minimal TOML-subset parser.
//!
//! The offline crate set has no `serde`/`toml`, so `cubic` ships its own
//! small parser covering the subset real configs need: `[section]` headers,
//! `key = value` with integers, floats, booleans and quoted strings, `#`
//! comments. See `examples/configs/*.toml` for the on-disk format.

use crate::topology::Parallelism;
use std::fmt;

pub mod toml;

/// Transformer model hyper-parameters.
///
/// Divisibility requirements (asserted by `validate`): attention stays
/// node-local in every parallelism iff `batch % p² == 0` and
/// `heads % p == 0` for 3-D (resp. `q`/`P` for 2-D/1-D) — the same
/// constraints Colossal-AI's 3-D layers impose.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub vocab: usize,
    pub hidden: usize,
    /// MLP inner width (the paper uses 4·hidden).
    pub ffn: usize,
    pub heads: usize,
    pub layers: usize,
    pub seq: usize,
    pub batch: usize,
    pub eps: f32,
}

impl ModelConfig {
    /// Tiny config used by unit/integration tests and the quickstart
    /// example. Kept in sync with `CONFIGS["tiny"]` in python/compile/aot.py.
    pub fn tiny() -> Self {
        ModelConfig {
            vocab: 64,
            hidden: 64,
            ffn: 256,
            heads: 4,
            layers: 2,
            seq: 16,
            batch: 4,
            eps: 1e-5,
        }
    }

    /// The e2e char-LM training config (python CONFIGS["charlm"]).
    pub fn charlm() -> Self {
        ModelConfig {
            vocab: 96,
            hidden: 128,
            ffn: 512,
            heads: 4,
            layers: 4,
            seq: 32,
            batch: 8,
            eps: 1e-5,
        }
    }

    /// ~100M-parameter configuration (GPT-2-small-ish) used by the e2e
    /// example's `--model large` composition check.
    pub fn large100m() -> Self {
        ModelConfig {
            vocab: 50304,
            hidden: 768,
            ffn: 3072,
            heads: 12,
            layers: 12,
            seq: 256,
            batch: 4,
            eps: 1e-5,
        }
    }

    /// Paper Table 1/2 shape (hidden/batch vary per row; seq fixed at 512).
    pub fn paper(hidden: usize, batch: usize) -> Self {
        ModelConfig {
            vocab: 51200,
            hidden,
            ffn: 4 * hidden,
            heads: hidden / 64, // 64-dim heads, Megatron convention
            layers: 1,          // tables report per-layer-stack time; see benches
            seq: 512,
            batch,
            eps: 1e-5,
        }
    }

    /// Total parameter count of the transformer core (blocks only).
    pub fn core_params(&self) -> usize {
        let h = self.hidden;
        let f = self.ffn;
        // per block: 2 LN (2h each) + qkv (3h² + 3h) + proj (h² + h)
        //          + fc1 (h·f + f) + fc2 (f·h + h)
        self.layers * (4 * h + 3 * h * h + 3 * h + h * h + h + h * f + f + f * h + h)
    }

    /// Total parameters including embedding, position table and LM head.
    pub fn total_params(&self) -> usize {
        self.core_params()
            + self.vocab * self.hidden // embedding
            + self.seq * self.hidden // positions
            + self.vocab * self.hidden // head
    }

    /// Check divisibility constraints for running under `par` at `edge`.
    ///
    /// The attention-head constraint is derived from the layout algebra
    /// (`ShardSpec::head_divisor`) for every kind, so `validate` and the
    /// runtime head split cannot drift: a mesh whose column split does not
    /// divide `heads` is a plan-level error here instead of a silent
    /// truncation at shard time (`ShardSpec::local_heads` additionally
    /// panics on the same condition as defense in depth).
    pub fn validate(&self, par: Parallelism, edge: usize) -> Result<(), String> {
        // Degenerate mesh parameters are config errors, not internal
        // asserts (the ShardSpec constructors below would panic on them).
        match par {
            Parallelism::TwoFiveD { depth } if depth == 0 => {
                return Err("2.5-D depth must be >= 1".into());
            }
            Parallelism::Hybrid { replicas, .. } if replicas == 0 => {
                return Err("hybrid replicas must be >= 1".into());
            }
            Parallelism::Hybrid {
                inner: crate::topology::HybridInner::TwoFiveD { depth },
                ..
            } if depth == 0 => {
                return Err("2.5-D depth must be >= 1".into());
            }
            Parallelism::Pipeline { stages, micro_batches, inner } => {
                if stages == 0 {
                    return Err("pipeline stages must be >= 1".into());
                }
                if micro_batches == 0 {
                    return Err("pipeline micro_batches must be >= 1".into());
                }
                match inner {
                    crate::topology::PipelineInner::TwoFiveD { depth } if depth == 0 => {
                        return Err("2.5-D depth must be >= 1".into());
                    }
                    crate::topology::PipelineInner::Hybrid { replicas, inner: hi } => {
                        if replicas == 0 {
                            return Err("hybrid replicas must be >= 1".into());
                        }
                        if let crate::topology::HybridInner::TwoFiveD { depth } = hi {
                            if depth == 0 {
                                return Err("2.5-D depth must be >= 1".into());
                            }
                        }
                    }
                    _ => {}
                }
            }
            _ => {}
        }
        let div = crate::dist::ShardSpec::for_parallelism(par, edge, 0).head_divisor();
        if self.heads % div != 0 {
            return Err(format!(
                "heads {} not divisible by head divisor {div} of the {} mesh ({})",
                self.heads,
                par.name(),
                par.mesh_desc(edge),
            ));
        }
        let p = edge;
        match par {
            Parallelism::Seq => Ok(()),
            Parallelism::OneD => {
                if self.ffn % p != 0 || self.hidden % p != 0 {
                    return Err(format!("hidden/ffn must divide P {}", p));
                }
                Ok(())
            }
            Parallelism::TwoD => {
                if self.batch % p != 0 {
                    return Err(format!("batch {} % q {} != 0", self.batch, p));
                }
                if self.hidden % (p * p) != 0 || self.ffn % (p * p) != 0 {
                    return Err(format!("hidden/ffn must divide q² = {}", p * p));
                }
                Ok(())
            }
            Parallelism::ThreeD => {
                if self.batch % (p * p) != 0 {
                    return Err(format!("batch {} % p² {} != 0", self.batch, p * p));
                }
                if self.hidden % (p * p) != 0 || self.ffn % (p * p) != 0 {
                    return Err(format!("hidden/ffn must divide p² = {}", p * p));
                }
                Ok(())
            }
            Parallelism::TwoFiveD { depth } => {
                let d = depth;
                if self.batch % p != 0 {
                    return Err(format!("batch {} % p {} != 0", self.batch, p));
                }
                if self.hidden % (p * p) != 0 || self.ffn % (p * p) != 0 {
                    return Err(format!("hidden/ffn must divide p² = {}", p * p));
                }
                if self.hidden % (d * p) != 0 || self.ffn % (d * p) != 0 {
                    return Err(format!("hidden/ffn must divide depth·p = {}", d * p));
                }
                Ok(())
            }
            Parallelism::Hybrid { replicas, inner } => {
                if self.batch % replicas != 0 {
                    return Err(format!("batch {} % replicas {} != 0", self.batch, replicas));
                }
                // Each replica runs the inner mesh on batch/replicas.
                let per_replica = ModelConfig { batch: self.batch / replicas, ..self.clone() };
                per_replica
                    .validate(inner.as_parallelism(), edge)
                    .map_err(|e| format!("inner {}: {e}", inner.as_parallelism().name()))
            }
            Parallelism::Pipeline { stages, micro_batches, inner } => {
                if self.layers % stages != 0 {
                    return Err(format!(
                        "layers {} % stages {} != 0 (stages own contiguous layer slices)",
                        self.layers, stages
                    ));
                }
                if self.batch % micro_batches != 0 {
                    return Err(format!(
                        "batch {} % micro_batches {} != 0 (micro-batches must hold whole \
                         sequences for bitwise parity)",
                        self.batch, micro_batches
                    ));
                }
                // Every stage group runs the inner mesh on one micro-batch
                // at a time.
                let per_mb =
                    ModelConfig { batch: self.batch / micro_batches, ..self.clone() };
                per_mb
                    .validate(inner.as_parallelism(), edge)
                    .map_err(|e| format!("inner {}: {e}", inner.as_parallelism().name()))
            }
        }
    }

    /// Check a serving configuration against this model on `(par, edge)`.
    ///
    /// Beyond the head-divisor rule (the KV cache splits heads exactly like
    /// training), serving adds two families of constraints:
    ///
    /// * **KV-cache shape**: `prompt_len + gen_len ≤ max_seq` — a sequence
    ///   must fit the per-slot cache rows it reserves.
    /// * **Slot divisibility**: the decode activation has `slots` rows, so
    ///   every mesh's row split (and, for bitwise decode-vs-prefill parity,
    ///   every reduction's chunking) must land on slot boundaries. Ring
    ///   reductions over groups of ≤ 2 ranks are order-free (`a + b` is
    ///   IEEE-commutative), so those groups impose no chunk-alignment
    ///   condition; larger groups require slot-aligned chunks. For `Hybrid`
    ///   this includes the replica batch split (`slots % replicas`, then
    ///   the inner conditions at `slots / replicas`); for `Pipeline` the
    ///   whole slot batch relays through each stage, so the inner
    ///   conditions apply at the full `slots` (decode is not
    ///   micro-batched) plus `layers % stages`.
    pub fn validate_serve(
        &self,
        par: Parallelism,
        edge: usize,
        serve: &ServeConfig,
    ) -> Result<(), String> {
        if serve.slots == 0 {
            return Err("serve slots must be >= 1".into());
        }
        if serve.max_seq == 0 {
            return Err("serve max_seq must be >= 1".into());
        }
        if serve.prompt_len == 0 || serve.gen_len == 0 {
            return Err("serve prompt_len and gen_len must be >= 1".into());
        }
        if serve.prompt_len + serve.gen_len > serve.max_seq {
            return Err(format!(
                "prompt_len {} + gen_len {} exceeds max_seq {} (KV-cache rows per slot)",
                serve.prompt_len, serve.gen_len, serve.max_seq
            ));
        }
        let div = crate::dist::ShardSpec::for_parallelism(par, edge, 0).head_divisor();
        if self.heads % div != 0 {
            return Err(format!(
                "heads {} not divisible by head divisor {div} of the {} mesh ({})",
                self.heads,
                par.name(),
                par.mesh_desc(edge),
            ));
        }
        self.validate_serve_mesh(par, edge, serve.slots)
    }

    /// The recursive per-kind half of [`ModelConfig::validate_serve`]:
    /// weight divisibility (same as training) + decode-slot alignment.
    fn validate_serve_mesh(
        &self,
        par: Parallelism,
        edge: usize,
        slots: usize,
    ) -> Result<(), String> {
        let p = edge;
        match par {
            Parallelism::Seq => Ok(()),
            Parallelism::OneD => {
                if self.ffn % p != 0 || self.hidden % p != 0 {
                    return Err(format!("hidden/ffn must divide P {p}"));
                }
                if slots % p != 0 {
                    return Err(format!(
                        "serve slots {slots} % P {p} != 0 (1-D all-reduce chunks must land \
                         on slot boundaries for decode parity)"
                    ));
                }
                Ok(())
            }
            Parallelism::TwoD => {
                if self.hidden % (p * p) != 0 || self.ffn % (p * p) != 0 {
                    return Err(format!("hidden/ffn must divide q² = {}", p * p));
                }
                if slots % p != 0 {
                    return Err(format!("serve slots {slots} % q {p} != 0 (row split)"));
                }
                if p > 2 && slots % (p * p) != 0 {
                    return Err(format!(
                        "serve slots {slots} % q² {} != 0 (layernorm-stat reduction over \
                         q > 2 ranks needs slot-aligned chunks)",
                        p * p
                    ));
                }
                Ok(())
            }
            Parallelism::ThreeD => {
                if self.hidden % (p * p) != 0 || self.ffn % (p * p) != 0 {
                    return Err(format!("hidden/ffn must divide p² = {}", p * p));
                }
                if slots % (p * p) != 0 {
                    return Err(format!(
                        "serve slots {slots} % p² {} != 0 (reduce-scatter row chunks)",
                        p * p
                    ));
                }
                if p > 2 && slots % (p * p * p) != 0 {
                    return Err(format!(
                        "serve slots {slots} % p³ {} != 0 (line reductions over p > 2 \
                         ranks need slot-aligned chunks)",
                        p * p * p
                    ));
                }
                Ok(())
            }
            Parallelism::TwoFiveD { depth } => {
                let d = depth;
                if self.hidden % (p * p) != 0 || self.ffn % (p * p) != 0 {
                    return Err(format!("hidden/ffn must divide p² = {}", p * p));
                }
                if self.hidden % (d * p) != 0 || self.ffn % (d * p) != 0 {
                    return Err(format!("hidden/ffn must divide depth·p = {}", d * p));
                }
                if slots % p != 0 {
                    return Err(format!("serve slots {slots} % p {p} != 0 (row split)"));
                }
                if p > 2 && slots % (p * p) != 0 {
                    return Err(format!(
                        "serve slots {slots} % p² {} != 0 (grid reductions over p > 2 \
                         ranks need slot-aligned chunks)",
                        p * p
                    ));
                }
                if d > 2 && (slots / p) % d != 0 {
                    return Err(format!(
                        "serve slots/p {} % depth {d} != 0 (depth all-reduce over d > 2 \
                         ranks needs slot-aligned chunks)",
                        slots / p
                    ));
                }
                Ok(())
            }
            Parallelism::Hybrid { replicas, inner } => {
                if slots % replicas != 0 {
                    return Err(format!(
                        "serve slots {slots} % replicas {replicas} != 0 (batch admission \
                         must split across replicas)"
                    ));
                }
                self.validate_serve_mesh(inner.as_parallelism(), edge, slots / replicas)
                    .map_err(|e| format!("inner {}: {e}", inner.as_parallelism().name()))
            }
            Parallelism::Pipeline { stages, inner, .. } => {
                if self.layers % stages != 0 {
                    return Err(format!(
                        "layers {} % stages {} != 0 (stages own contiguous layer slices)",
                        self.layers, stages
                    ));
                }
                // The whole slot batch relays through every stage — decode
                // is not micro-batched — so inner conditions see all slots.
                self.validate_serve_mesh(inner.as_parallelism(), edge, slots)
                    .map_err(|e| format!("inner {}: {e}", inner.as_parallelism().name()))
            }
        }
    }
}

/// Inference-serving parameters: batch-slot grid, KV-cache extent, and the
/// synthetic open-loop traffic the scheduler simulates.
#[derive(Clone, Debug, PartialEq)]
pub struct ServeConfig {
    /// Concurrent batch slots (the decode grid's row count).
    pub slots: usize,
    /// KV rows reserved per slot — the hard per-sequence length cap.
    pub max_seq: usize,
    /// Padded prefill length; synthetic prompts draw from `[1, prompt_len]`.
    pub prompt_len: usize,
    /// Decode steps measured; synthetic generations draw from `[1, gen_len]`.
    pub gen_len: usize,
    /// Synthetic requests per simulated trace.
    pub requests: usize,
    /// Open-loop arrival rate (req/s of virtual time); 0 = auto-sweep
    /// around the measured per-mesh service rate.
    pub arrival_rate: f64,
    /// Traffic seed (arrivals + ragged lengths).
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            slots: 4,
            max_seq: 64,
            prompt_len: 16,
            gen_len: 16,
            requests: 64,
            arrival_rate: 0.0,
            seed: 9,
        }
    }
}

/// Training loop hyper-parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr: f32,
    /// Linear warmup steps before cosine decay.
    pub warmup: usize,
    pub seed: u64,
    pub optimizer: OptimizerKind,
    pub adam_beta1: f32,
    pub adam_beta2: f32,
    pub weight_decay: f32,
    pub grad_clip: f32,
    pub log_every: usize,
    /// Write a full checkpoint (shards + optimizer state) every this many
    /// completed steps when training under supervision with a save dir.
    /// 0 disables periodic checkpoints (final-state save only).
    pub ckpt_every: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptimizerKind {
    Sgd,
    Adam,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 100,
            lr: 3e-3,
            warmup: 20,
            seed: 42,
            optimizer: OptimizerKind::Adam,
            adam_beta1: 0.9,
            adam_beta2: 0.999,
            weight_decay: 0.0,
            grad_clip: 1.0,
            log_every: 10,
            ckpt_every: 0,
        }
    }
}

/// Fault-injection configuration (see `comm::fault`). Inactive by default;
/// activated by the `[faults]` TOML section, the `--fault-seed`/`--crash-at`
/// CLI flags, or the `CUBIC_FAULTS=` env spec — the env override wins over
/// both, mirroring `CUBIC_THREADS`/`CUBIC_OVERLAP` (the CLI applies it last
/// via [`FaultConfig::apply_env`]).
#[derive(Clone, Debug, PartialEq)]
pub struct FaultConfig {
    /// Seed for the deterministic drop/straggler coins.
    pub seed: u64,
    /// Per-delivery-attempt message drop probability.
    pub drop_p: f64,
    /// Consecutive dropped attempts tolerated before a receive times out.
    pub max_retries: u32,
    /// Base virtual-seconds retry backoff (doubles per attempt).
    pub timeout: f64,
    /// Kill `(rank, step)`: the rank crashes entering that step (first
    /// generation only — restarts don't re-crash).
    pub crash: Option<(usize, usize)>,
    /// Straggler link `(src, dst, extra_seconds)`; `None` endpoints are
    /// wildcards (`*` in the env spec, `-1` in TOML).
    pub delay: Option<(Option<usize>, Option<usize>, f64)>,
    /// Restart generations the supervision loop may spend before giving up.
    pub max_recoveries: usize,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig {
            seed: 0,
            drop_p: 0.0,
            max_retries: 4,
            timeout: 1e-3,
            crash: None,
            delay: None,
            max_recoveries: 3,
        }
    }
}

impl FaultConfig {
    /// Whether any fault is actually injected (the engine only installs a
    /// plan — and pays the supervision machinery — when this is true).
    pub fn is_active(&self) -> bool {
        self.drop_p > 0.0 || self.crash.is_some() || self.delay.is_some()
    }

    /// Lower to the comm layer's [`crate::comm::fault::FaultPlan`].
    pub fn to_plan(&self) -> crate::comm::fault::FaultPlan {
        use crate::comm::fault::{FaultPlan, LinkDelay};
        FaultPlan {
            seed: self.seed,
            drop_p: self.drop_p,
            max_retries: self.max_retries,
            retry_timeout: self.timeout,
            crashes: self.crash.into_iter().collect(),
            delays: self
                .delay
                .into_iter()
                .map(|(src, dst, extra)| LinkDelay { src, dst, extra })
                .collect(),
            max_recoveries: self.max_recoveries,
            ..FaultPlan::default()
        }
    }

    /// Parse a `CUBIC_FAULTS` spec like
    /// `seed=7,drop_p=0.01,crash=1@3,delay=0->1:0.002,timeout=0.001,max_retries=4,max_recoveries=2`
    /// into this config (entries override fields in place).
    pub fn parse_spec(&mut self, spec: &str) -> Result<(), String> {
        let side = |t: &str| -> Result<Option<usize>, String> {
            if t == "*" {
                Ok(None)
            } else {
                t.parse().map(Some).map_err(|_| format!("bad rank {t:?} in CUBIC_FAULTS"))
            }
        };
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, val) = part
                .split_once('=')
                .ok_or_else(|| format!("bad CUBIC_FAULTS entry {part:?} (want key=value)"))?;
            let bad = |what: &str| format!("bad CUBIC_FAULTS {what} {val:?}");
            match key.trim() {
                "seed" => self.seed = val.parse().map_err(|_| bad("seed"))?,
                "drop_p" => self.drop_p = val.parse().map_err(|_| bad("drop_p"))?,
                "max_retries" => self.max_retries = val.parse().map_err(|_| bad("max_retries"))?,
                "timeout" => self.timeout = val.parse().map_err(|_| bad("timeout"))?,
                "max_recoveries" => {
                    self.max_recoveries = val.parse().map_err(|_| bad("max_recoveries"))?
                }
                "crash" => {
                    let (r, s) = val.split_once('@').ok_or_else(|| bad("crash (want R@S)"))?;
                    self.crash = Some((
                        r.parse().map_err(|_| bad("crash rank"))?,
                        s.parse().map_err(|_| bad("crash step"))?,
                    ));
                }
                "delay" => {
                    let (link, secs) =
                        val.rsplit_once(':').ok_or_else(|| bad("delay (want SRC->DST:SECS)"))?;
                    let (src, dst) =
                        link.split_once("->").ok_or_else(|| bad("delay (want SRC->DST:SECS)"))?;
                    self.delay = Some((
                        side(src)?,
                        side(dst)?,
                        secs.parse().map_err(|_| bad("delay seconds"))?,
                    ));
                }
                other => return Err(format!("unknown CUBIC_FAULTS key {other:?}")),
            }
        }
        Ok(())
    }

    /// Apply the `CUBIC_FAULTS=` env override, if set (env wins; called by
    /// the CLI after flags and TOML are folded in).
    pub fn apply_env(&mut self) -> Result<(), String> {
        match std::env::var("CUBIC_FAULTS") {
            Ok(spec) if !spec.trim().is_empty() => self.parse_spec(&spec),
            _ => Ok(()),
        }
    }
}

/// Top-level config: model + parallelism + training + runtime.
#[derive(Clone, Debug)]
pub struct CubicConfig {
    pub model: ModelConfig,
    pub train: TrainConfig,
    pub parallelism: Parallelism,
    pub edge: usize,
    /// ZeRO optimizer-state sharding stage on the hybrid replica axis
    /// (0 = off, the replicated default). Stages 1 and 2 share one
    /// execution path here — reduce-scattered gradients, `1/r`-partitioned
    /// Adam moments, post-step weight all-gather — and are bit-identical to
    /// stage 0; they differ only in the cost model's gradient-residency
    /// accounting. Requires `Parallelism::Hybrid` when non-zero.
    pub zero_stage: usize,
    /// Artifacts directory for the PJRT runtime (empty = native only).
    pub artifacts_dir: String,
    /// Cores for the multi-threaded gemm driver (0 = auto: available
    /// parallelism). Applied via `kernel::threads::request_threads` before
    /// the first matmul; the `CUBIC_THREADS=` env override wins over this.
    pub threads: usize,
    /// Overlap deferred collectives with compute on the virtual clock (the
    /// two-timeline scheme — see `comm` module docs). Applied via
    /// `NetModel::set_overlap`; the `CUBIC_OVERLAP=` env override wins over
    /// this, mirroring `CUBIC_THREADS`. Numerics are bit-identical either
    /// way — the knob only changes the timing model.
    pub overlap: bool,
    /// Deterministic fault injection + recovery budget (inactive default).
    pub faults: FaultConfig,
    /// Inference-serving parameters (`cubic serve`; see the `serve` module).
    pub serve: ServeConfig,
}

impl Default for CubicConfig {
    fn default() -> Self {
        CubicConfig {
            model: ModelConfig::tiny(),
            train: TrainConfig::default(),
            parallelism: Parallelism::ThreeD,
            edge: 2,
            zero_stage: 0,
            artifacts_dir: String::new(),
            threads: 0,
            overlap: true,
            faults: FaultConfig::default(),
            serve: ServeConfig::default(),
        }
    }
}

#[derive(Debug)]
pub struct ConfigError(pub String);

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config error: {}", self.0)
    }
}

impl std::error::Error for ConfigError {}

impl CubicConfig {
    /// Load from a TOML-subset file (see module docs / examples/configs).
    pub fn from_file(path: &str) -> Result<Self, ConfigError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| ConfigError(format!("cannot read {path}: {e}")))?;
        Self::from_toml(&text)
    }

    pub fn from_toml(text: &str) -> Result<Self, ConfigError> {
        let doc = toml::parse(text).map_err(ConfigError)?;
        let mut cfg = CubicConfig::default();

        if let Some(preset) = doc.get_str("model", "preset") {
            cfg.model = match preset.as_str() {
                "tiny" => ModelConfig::tiny(),
                "charlm" => ModelConfig::charlm(),
                "large100m" => ModelConfig::large100m(),
                other => return Err(ConfigError(format!("unknown model preset {other:?}"))),
            };
        }
        macro_rules! set_usize {
            ($section:literal, $key:literal, $field:expr) => {
                if let Some(v) = doc.get_int($section, $key) {
                    $field = v as usize;
                }
            };
        }
        set_usize!("model", "vocab", cfg.model.vocab);
        set_usize!("model", "hidden", cfg.model.hidden);
        set_usize!("model", "ffn", cfg.model.ffn);
        set_usize!("model", "heads", cfg.model.heads);
        set_usize!("model", "layers", cfg.model.layers);
        set_usize!("model", "seq", cfg.model.seq);
        set_usize!("model", "batch", cfg.model.batch);

        if let Some(p) = doc.get_str("parallel", "kind") {
            cfg.parallelism = Parallelism::parse(&p)
                .ok_or_else(|| ConfigError(format!("unknown parallelism {p:?}")))?;
        }
        set_usize!("parallel", "edge", cfg.edge);
        if let Some(d) = doc.get_int("parallel", "depth") {
            // Range-check before the cast: a negative TOML value must be a
            // config error, not a usize wraparound.
            let d = usize::try_from(d).map_err(|_| ConfigError(format!("depth {d} < 1")))?;
            cfg.parallelism.set_depth(d).map_err(ConfigError)?;
        }
        if let Some(r) = doc.get_int("parallel", "replicas") {
            let r =
                usize::try_from(r).map_err(|_| ConfigError(format!("replicas {r} < 1")))?;
            cfg.parallelism.set_replicas(r).map_err(ConfigError)?;
        }
        if let Some(s) = doc.get_int("parallel", "stages") {
            let s = usize::try_from(s).map_err(|_| ConfigError(format!("stages {s} < 1")))?;
            cfg.parallelism.set_stages(s).map_err(ConfigError)?;
        }
        if let Some(m) = doc.get_int("parallel", "micro_batches") {
            let m = usize::try_from(m)
                .map_err(|_| ConfigError(format!("micro_batches {m} < 1")))?;
            cfg.parallelism.set_micro_batches(m).map_err(ConfigError)?;
        }
        if let Some(z) = doc.get_int("parallel", "zero_stage") {
            let z = usize::try_from(z)
                .map_err(|_| ConfigError(format!("zero_stage {z} < 0")))?;
            cfg.zero_stage = z;
        }

        set_usize!("train", "steps", cfg.train.steps);
        set_usize!("train", "warmup", cfg.train.warmup);
        set_usize!("train", "log_every", cfg.train.log_every);
        set_usize!("train", "ckpt_every", cfg.train.ckpt_every);
        if let Some(v) = doc.get_float("train", "lr") {
            cfg.train.lr = v as f32;
        }
        if let Some(v) = doc.get_float("train", "grad_clip") {
            cfg.train.grad_clip = v as f32;
        }
        if let Some(v) = doc.get_float("train", "weight_decay") {
            cfg.train.weight_decay = v as f32;
        }
        if let Some(v) = doc.get_int("train", "seed") {
            cfg.train.seed = v as u64;
        }
        if let Some(o) = doc.get_str("train", "optimizer") {
            cfg.train.optimizer = match o.as_str() {
                "sgd" => OptimizerKind::Sgd,
                "adam" => OptimizerKind::Adam,
                other => return Err(ConfigError(format!("unknown optimizer {other:?}"))),
            };
        }
        if let Some(d) = doc.get_str("runtime", "artifacts_dir") {
            cfg.artifacts_dir = d;
        }
        set_usize!("runtime", "threads", cfg.threads);
        if let Some(v) = doc.get_bool("runtime", "overlap") {
            cfg.overlap = v;
        }

        if let Some(v) = doc.get_int("faults", "seed") {
            cfg.faults.seed = v as u64;
        }
        if let Some(v) = doc.get_float("faults", "drop_p") {
            if !(0.0..=1.0).contains(&v) {
                return Err(ConfigError(format!("drop_p {v} not in [0, 1]")));
            }
            cfg.faults.drop_p = v;
        }
        if let Some(v) = doc.get_int("faults", "max_retries") {
            cfg.faults.max_retries = u32::try_from(v)
                .map_err(|_| ConfigError(format!("max_retries {v} < 0")))?;
        }
        if let Some(v) = doc.get_float("faults", "timeout") {
            cfg.faults.timeout = v;
        }
        set_usize!("faults", "max_recoveries", cfg.faults.max_recoveries);
        match (doc.get_int("faults", "crash_rank"), doc.get_int("faults", "crash_step")) {
            (Some(r), Some(s)) => {
                let r = usize::try_from(r)
                    .map_err(|_| ConfigError(format!("crash_rank {r} < 0")))?;
                let s = usize::try_from(s)
                    .map_err(|_| ConfigError(format!("crash_step {s} < 0")))?;
                cfg.faults.crash = Some((r, s));
            }
            (None, None) => {}
            _ => {
                return Err(ConfigError(
                    "crash_rank and crash_step must be given together".into(),
                ));
            }
        }
        if let Some(extra) = doc.get_float("faults", "delay_s") {
            // -1 (or absent) endpoint = wildcard, matching any rank.
            let side = |v: Option<i64>| v.and_then(|v| usize::try_from(v).ok());
            cfg.faults.delay = Some((
                side(doc.get_int("faults", "delay_src")),
                side(doc.get_int("faults", "delay_dst")),
                extra,
            ));
        }

        set_usize!("serve", "slots", cfg.serve.slots);
        set_usize!("serve", "max_seq", cfg.serve.max_seq);
        set_usize!("serve", "prompt_len", cfg.serve.prompt_len);
        set_usize!("serve", "gen_len", cfg.serve.gen_len);
        set_usize!("serve", "requests", cfg.serve.requests);
        if let Some(v) = doc.get_float("serve", "arrival_rate") {
            if v < 0.0 {
                return Err(ConfigError(format!("arrival_rate {v} < 0")));
            }
            cfg.serve.arrival_rate = v;
        }
        if let Some(v) = doc.get_int("serve", "seed") {
            cfg.serve.seed = v as u64;
        }
        cfg.model
            .validate(cfg.parallelism, cfg.edge)
            .map_err(ConfigError)?;
        cfg.validate_zero().map_err(ConfigError)?;
        Ok(cfg)
    }

    /// Validate the ZeRO knob against the parallelism: stages above 2 are
    /// not implemented (stage 3 parameter sharding is a recorded follow-on),
    /// and a non-zero stage needs a replica axis to partition over — i.e.
    /// top-level [`Parallelism::Hybrid`]. Pipeline-wrapped hybrids are
    /// rejected for now (the stage-local replica groups would each need
    /// their own partition map).
    pub fn validate_zero(&self) -> Result<(), String> {
        if self.zero_stage == 0 {
            return Ok(());
        }
        if self.zero_stage > 2 {
            return Err(format!(
                "zero_stage {} unsupported (stages 0-2; stage 3 parameter sharding is a follow-on)",
                self.zero_stage
            ));
        }
        match self.parallelism {
            Parallelism::Hybrid { .. } => Ok(()),
            p => Err(format!(
                "zero_stage {} requires hybrid parallelism (got {})",
                self.zero_stage,
                p.name()
            )),
        }
    }
}

/// One-line human description for log headers.
pub fn describe(cfg: &CubicConfig) -> String {
    format!(
        "{} x{} ({} ranks), hidden={} layers={} seq={} batch={} (~{:.1}M params)",
        cfg.parallelism.name(),
        cfg.edge,
        cfg.parallelism.world_size(cfg.edge),
        cfg.model.hidden,
        cfg.model.layers,
        cfg.model.seq,
        cfg.model.batch,
        cfg.model.total_params() as f64 / 1e6,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::topology::HybridInner;

    #[test]
    fn presets_validate_under_their_parallelisms() {
        assert!(ModelConfig::tiny().validate(Parallelism::ThreeD, 2).is_ok());
        assert!(ModelConfig::tiny().validate(Parallelism::TwoD, 2).is_ok());
        assert!(ModelConfig::tiny().validate(Parallelism::OneD, 4).is_ok());
        assert!(ModelConfig::charlm().validate(Parallelism::ThreeD, 2).is_ok());
        assert!(ModelConfig::large100m().validate(Parallelism::ThreeD, 2).is_ok());
        assert!(ModelConfig::tiny().validate(Parallelism::TwoFiveD { depth: 2 }, 2).is_ok());
        assert!(ModelConfig::tiny()
            .validate(Parallelism::Hybrid { replicas: 2, inner: HybridInner::OneD }, 2)
            .is_ok());
        assert!(ModelConfig::charlm()
            .validate(Parallelism::Hybrid { replicas: 2, inner: HybridInner::TwoD }, 2)
            .is_ok());
    }

    #[test]
    fn invalid_divisibility_is_rejected() {
        let mut m = ModelConfig::tiny();
        m.batch = 3; // not divisible by p² = 4
        assert!(m.validate(Parallelism::ThreeD, 2).is_err());
        m.batch = 4;
        m.heads = 3;
        assert!(m.validate(Parallelism::ThreeD, 2).is_err());
    }

    #[test]
    fn head_divisor_errors_are_plan_level_not_truncation() {
        // The satellite fix: a mesh whose column split does not divide
        // `heads` must be rejected by validate (which the `plan` command
        // runs) — previously `local_heads` silently truncated.
        let mut m = ModelConfig::tiny(); // 4 heads
        // 2.5-D at p=2, depth=4 splits heads 8 ways: 4 % 8 != 0.
        let err = m.validate(Parallelism::TwoFiveD { depth: 4 }, 2).unwrap_err();
        assert!(err.contains("head divisor"), "{err}");
        // Hybrid inherits the inner divisor: 2 × 1-D(8) splits heads 8 ways.
        let err = m
            .validate(Parallelism::Hybrid { replicas: 2, inner: HybridInner::OneD }, 8)
            .unwrap_err();
        assert!(err.contains("head divisor"), "{err}");
        // Replicas must divide the batch.
        m.batch = 3;
        assert!(m
            .validate(Parallelism::Hybrid { replicas: 2, inner: HybridInner::OneD }, 2)
            .is_err());
    }

    #[test]
    fn hybrid_validates_inner_on_per_replica_batch() {
        // 2-D inner needs batch % q per *replica*: total batch 4 over 2
        // replicas leaves 2 per replica, which q=2 accepts.
        let m = ModelConfig::tiny();
        assert!(m
            .validate(Parallelism::Hybrid { replicas: 2, inner: HybridInner::TwoD }, 2)
            .is_ok());
        // 4 replicas leave batch 1 per replica: 1 % 2 != 0 → rejected.
        let err = m
            .validate(Parallelism::Hybrid { replicas: 4, inner: HybridInner::TwoD }, 2)
            .unwrap_err();
        assert!(err.contains("inner 2d"), "{err}");
    }

    #[test]
    fn param_counts_are_sane() {
        let m = ModelConfig::large100m();
        let total = m.total_params();
        // GPT-2-small ballpark with vocab 50k and untied head.
        assert!(total > 80_000_000 && total < 200_000_000, "{total}");
    }

    #[test]
    fn full_toml_round_trip() {
        let text = r#"
# cubic run config
[model]
preset = "tiny"
layers = 3

[parallel]
kind = "3d"
edge = 2

[train]
steps = 50
lr = 0.001
optimizer = "sgd"
seed = 7

[runtime]
artifacts_dir = "artifacts"
threads = 4
overlap = false
"#;
        let cfg = CubicConfig::from_toml(text).unwrap();
        assert_eq!(cfg.threads, 4);
        assert_eq!(CubicConfig::default().threads, 0, "default must be auto");
        assert!(!cfg.overlap, "[runtime] overlap = false must parse");
        assert!(CubicConfig::default().overlap, "overlap defaults on");
        assert_eq!(cfg.model.layers, 3);
        assert_eq!(cfg.model.hidden, ModelConfig::tiny().hidden);
        assert_eq!(cfg.parallelism, Parallelism::ThreeD);
        assert_eq!(cfg.edge, 2);
        assert_eq!(cfg.train.steps, 50);
        assert_eq!(cfg.train.optimizer, OptimizerKind::Sgd);
        assert!((cfg.train.lr - 0.001).abs() < 1e-9);
        assert_eq!(cfg.train.seed, 7);
        assert_eq!(cfg.artifacts_dir, "artifacts");
        assert_eq!(cfg.faults, FaultConfig::default(), "no [faults] section → inactive");
        assert!(!cfg.faults.is_active());
    }

    #[test]
    fn faults_toml_round_trip() {
        let text = r#"
[train]
ckpt_every = 2

[faults]
seed = 7
drop_p = 0.01
max_retries = 5
timeout = 0.002
crash_rank = 1
crash_step = 3
delay_src = 0
delay_dst = -1
delay_s = 0.004
max_recoveries = 2
"#;
        let cfg = CubicConfig::from_toml(text).unwrap();
        assert_eq!(cfg.train.ckpt_every, 2);
        let f = &cfg.faults;
        assert!(f.is_active());
        assert_eq!(f.seed, 7);
        assert!((f.drop_p - 0.01).abs() < 1e-12);
        assert_eq!(f.max_retries, 5);
        assert!((f.timeout - 0.002).abs() < 1e-12);
        assert_eq!(f.crash, Some((1, 3)));
        assert_eq!(f.delay, Some((Some(0), None, 0.004)));
        assert_eq!(f.max_recoveries, 2);
        // Lowered plan carries everything through.
        let plan = f.to_plan();
        assert_eq!(plan.seed, 7);
        assert_eq!(plan.generation, 0);
        assert_eq!(plan.crashes, vec![(1, 3)]);
        assert_eq!(plan.delays.len(), 1);
        assert_eq!(plan.delays[0].src, Some(0));
        assert_eq!(plan.delays[0].dst, None);
        assert_eq!(plan.max_recoveries, 2);
        // Half-specified crash coordinates are a config error.
        let bad = "[faults]\ncrash_rank = 1";
        assert!(CubicConfig::from_toml(bad).is_err());
        assert!(CubicConfig::from_toml("[faults]\ndrop_p = 1.5").is_err());
    }

    #[test]
    fn cubic_faults_spec_parses_and_overrides() {
        let mut f = FaultConfig::default();
        f.parse_spec("seed=7,drop_p=0.01,crash=1@3,delay=0->1:0.002,max_recoveries=2")
            .unwrap();
        assert_eq!(f.seed, 7);
        assert!((f.drop_p - 0.01).abs() < 1e-12);
        assert_eq!(f.crash, Some((1, 3)));
        assert_eq!(f.delay, Some((Some(0), Some(1), 0.002)));
        assert_eq!(f.max_recoveries, 2);
        // Wildcards and in-place overrides.
        f.parse_spec("delay=*->2:0.5,timeout=0.01,max_retries=9").unwrap();
        assert_eq!(f.delay, Some((None, Some(2), 0.5)));
        assert!((f.timeout - 0.01).abs() < 1e-12);
        assert_eq!(f.max_retries, 9);
        assert_eq!(f.crash, Some((1, 3)), "untouched keys survive");
        // Malformed entries are loud errors, not silent defaults.
        assert!(f.parse_spec("crash=5").is_err());
        assert!(f.parse_spec("delay=0:0.1").is_err());
        assert!(f.parse_spec("nope=1").is_err());
        assert!(f.parse_spec("drop_p").is_err());
    }

    #[test]
    fn invalid_configs_are_rejected() {
        assert!(CubicConfig::from_toml("[parallel]\nkind = \"9d\"").is_err());
        assert!(CubicConfig::from_toml("[model]\npreset = \"nope\"").is_err());
        // tiny batch=4 cannot run 3-D at edge 4 (needs batch % 16 == 0).
        let bad = "[model]\npreset = \"tiny\"\n[parallel]\nkind = \"3d\"\nedge = 4";
        assert!(CubicConfig::from_toml(bad).is_err());
        // depth/replicas keys only apply to their kinds.
        assert!(CubicConfig::from_toml("[parallel]\nkind = \"3d\"\ndepth = 2").is_err());
        assert!(CubicConfig::from_toml("[parallel]\nkind = \"2.5d\"\nreplicas = 2").is_err());
    }

    #[test]
    fn two_five_d_and_hybrid_toml_round_trip() {
        let cfg = CubicConfig::from_toml(
            "[parallel]\nkind = \"2.5d\"\nedge = 2\ndepth = 2",
        )
        .unwrap();
        assert_eq!(cfg.parallelism, Parallelism::TwoFiveD { depth: 2 });
        assert_eq!(cfg.parallelism.world_size(cfg.edge), 8);
        let cfg = CubicConfig::from_toml(
            "[parallel]\nkind = \"hybrid2d\"\nedge = 2\nreplicas = 2\n[model]\npreset = \"charlm\"",
        )
        .unwrap();
        assert_eq!(
            cfg.parallelism,
            Parallelism::Hybrid { replicas: 2, inner: crate::topology::HybridInner::TwoD }
        );
        assert_eq!(cfg.parallelism.world_size(cfg.edge), 8);
        // depth reaches a hybrid2.5d inner too (charlm: heads 4 % (2·2)=0,
        // batch 8 → 4 per replica, hidden 128 / ffn 512 divide d·p and p²).
        let cfg = CubicConfig::from_toml(
            "[parallel]\nkind = \"hybrid2.5d\"\nedge = 2\ndepth = 2\nreplicas = 2\n\
             [model]\npreset = \"charlm\"",
        )
        .unwrap();
        assert_eq!(
            cfg.parallelism,
            Parallelism::Hybrid {
                replicas: 2,
                inner: crate::topology::HybridInner::TwoFiveD { depth: 2 },
            }
        );
        assert_eq!(cfg.parallelism.world_size(cfg.edge), 16);
        // Degenerate parameters are config errors, not panics.
        assert!(ModelConfig::tiny().validate(Parallelism::TwoFiveD { depth: 0 }, 2).is_err());
    }

    #[test]
    fn zero_stage_toml_round_trip_and_validation() {
        // Round-trip: [parallel] zero_stage reaches the config on a hybrid.
        let cfg = CubicConfig::from_toml(
            "[parallel]\nkind = \"hybrid2d\"\nedge = 2\nreplicas = 2\nzero_stage = 1\n\
             [model]\npreset = \"charlm\"",
        )
        .unwrap();
        assert_eq!(cfg.zero_stage, 1);
        let cfg = CubicConfig::from_toml(
            "[parallel]\nkind = \"hybrid1d\"\nedge = 2\nreplicas = 2\nzero_stage = 2",
        )
        .unwrap();
        assert_eq!(cfg.zero_stage, 2);
        // Absent key = stage 0 (replicated default).
        assert_eq!(CubicConfig::from_toml("[parallel]\nkind = \"3d\"").unwrap().zero_stage, 0);
        // Rejections: non-hybrid parallelism, unimplemented stage 3,
        // negative values — config errors, not panics or wraparounds.
        assert!(CubicConfig::from_toml("[parallel]\nkind = \"3d\"\nzero_stage = 1").is_err());
        assert!(CubicConfig::from_toml(
            "[parallel]\nkind = \"hybrid1d\"\nedge = 2\nreplicas = 2\nzero_stage = 3"
        )
        .is_err());
        assert!(CubicConfig::from_toml(
            "[parallel]\nkind = \"hybrid1d\"\nedge = 2\nreplicas = 2\nzero_stage = -1"
        )
        .is_err());
        // Pipeline-wrapped hybrids are not partitionable yet (follow-on).
        assert!(CubicConfig::from_toml(
            "[parallel]\nkind = \"pipeline\"\nedge = 2\nstages = 2\nmicro_batches = 2\n\
             zero_stage = 1"
        )
        .is_err());
    }

    #[test]
    fn pipeline_toml_round_trip() {
        let cfg = CubicConfig::from_toml(
            "[parallel]\nkind = \"pipeline\"\nedge = 2\nstages = 2\nmicro_batches = 4",
        )
        .unwrap();
        assert_eq!(
            cfg.parallelism,
            Parallelism::Pipeline {
                stages: 2,
                micro_batches: 4,
                inner: crate::topology::PipelineInner::OneD,
            }
        );
        assert_eq!(cfg.parallelism.world_size(cfg.edge), 4);
        // stages/micro_batches only apply to pipeline kinds.
        assert!(CubicConfig::from_toml("[parallel]\nkind = \"3d\"\nstages = 2").is_err());
        assert!(CubicConfig::from_toml("[parallel]\nkind = \"1d\"\nmicro_batches = 2").is_err());
        // depth reaches a pipelined 2.5-D inner (charlm divisibility).
        let cfg = CubicConfig::from_toml(
            "[parallel]\nkind = \"pipeline2.5d\"\nedge = 2\ndepth = 2\nstages = 2\n\
             micro_batches = 2\n[model]\npreset = \"charlm\"",
        )
        .unwrap();
        assert_eq!(
            cfg.parallelism,
            Parallelism::Pipeline {
                stages: 2,
                micro_batches: 2,
                inner: crate::topology::PipelineInner::TwoFiveD { depth: 2 },
            }
        );
        assert_eq!(cfg.parallelism.world_size(cfg.edge), 16);
    }

    #[test]
    fn pipeline_divisibility_is_validated() {
        let pp = |stages, micro_batches| Parallelism::Pipeline {
            stages,
            micro_batches,
            inner: crate::topology::PipelineInner::OneD,
        };
        // tiny: layers=2, batch=4.
        assert!(ModelConfig::tiny().validate(pp(2, 4), 2).is_ok());
        assert!(ModelConfig::tiny().validate(pp(2, 1), 2).is_ok());
        // layers % stages != 0: stages own contiguous slices.
        let err = ModelConfig::tiny().validate(pp(3, 1), 2).unwrap_err();
        assert!(err.contains("stages"), "{err}");
        // batch % micro_batches != 0: micro-batches hold whole sequences.
        let err = ModelConfig::tiny().validate(pp(2, 3), 2).unwrap_err();
        assert!(err.contains("micro_batches"), "{err}");
        // Inner-mesh constraints still apply at the per-micro-batch batch:
        // 2-D inner at q=2 needs (batch / m) % q == 0 — m=4 leaves 1 row.
        let pp2d = Parallelism::Pipeline {
            stages: 2,
            micro_batches: 4,
            inner: crate::topology::PipelineInner::TwoD,
        };
        let err = ModelConfig::tiny().validate(pp2d, 2).unwrap_err();
        assert!(err.contains("inner"), "{err}");
        // Degenerate parameters are config errors, not panics.
        assert!(ModelConfig::tiny().validate(pp(0, 1), 2).is_err());
        assert!(ModelConfig::tiny().validate(pp(1, 0), 2).is_err());
    }

    #[test]
    fn serve_config_validates_slot_alignment_per_kind() {
        let m = ModelConfig::tiny(); // hidden 64, ffn 256, heads 4, layers 2
        let sv = |slots: usize| ServeConfig { slots, ..ServeConfig::default() };
        // Positive: the tiny model serves on all seven kinds at slots = 4.
        let envs: [(Parallelism, usize); 7] = [
            (Parallelism::Seq, 1),
            (Parallelism::OneD, 4),
            (Parallelism::TwoD, 2),
            (Parallelism::ThreeD, 2),
            (Parallelism::TwoFiveD { depth: 2 }, 2),
            (Parallelism::Hybrid { replicas: 2, inner: HybridInner::OneD }, 2),
            (
                Parallelism::Pipeline {
                    stages: 2,
                    micro_batches: 4,
                    inner: crate::topology::PipelineInner::OneD,
                },
                2,
            ),
        ];
        for (par, edge) in envs {
            m.validate_serve(par, edge, &sv(4))
                .unwrap_or_else(|e| panic!("{}: {e}", par.name()));
        }
        // 1-D: decode rows must land on all-reduce chunk boundaries.
        let err = m.validate_serve(Parallelism::OneD, 4, &sv(3)).unwrap_err();
        assert!(err.contains("slots"), "{err}");
        // 2-D at q = 4: q | slots alone is not enough — reductions over
        // q > 2 ranks additionally need q² | slots for chunk alignment.
        let mut wide = ModelConfig::tiny();
        wide.hidden = 256;
        wide.ffn = 1024;
        wide.heads = 16;
        let err = wide.validate_serve(Parallelism::TwoD, 4, &sv(4)).unwrap_err();
        assert!(err.contains("q²"), "{err}");
        assert!(wide.validate_serve(Parallelism::TwoD, 4, &sv(16)).is_ok());
        // 3-D: reduce-scatter splits decode rows p² ways.
        let err = m.validate_serve(Parallelism::ThreeD, 2, &sv(2)).unwrap_err();
        assert!(err.contains("p²"), "{err}");
        // Hybrid: batch admission must split across replicas.
        let err = m
            .validate_serve(
                Parallelism::Hybrid { replicas: 2, inner: HybridInner::OneD },
                2,
                &sv(3),
            )
            .unwrap_err();
        assert!(err.contains("replicas"), "{err}");
        // Pipeline applies inner conditions at the FULL slot batch (decode
        // is not micro-batched): 1-D inner at p = 4 rejects slots = 2 even
        // though micro_batches would have split the training batch.
        let pp1d = Parallelism::Pipeline {
            stages: 2,
            micro_batches: 4,
            inner: crate::topology::PipelineInner::OneD,
        };
        let err = m.validate_serve(pp1d, 4, &sv(2)).unwrap_err();
        assert!(err.contains("inner 1d"), "{err}");
    }

    #[test]
    fn serve_config_rejects_kv_overflow_and_degenerate_shapes() {
        let m = ModelConfig::tiny();
        // A sequence must fit its per-slot KV rows.
        let sv = ServeConfig { prompt_len: 40, gen_len: 32, ..ServeConfig::default() };
        let err = m.validate_serve(Parallelism::Seq, 1, &sv).unwrap_err();
        assert!(err.contains("max_seq"), "{err}");
        let err = m
            .validate_serve(Parallelism::Seq, 1, &ServeConfig { slots: 0, ..Default::default() })
            .unwrap_err();
        assert!(err.contains("slots"), "{err}");
        assert!(m
            .validate_serve(Parallelism::Seq, 1, &ServeConfig { max_seq: 0, ..Default::default() })
            .is_err());
        assert!(m
            .validate_serve(Parallelism::Seq, 1, &ServeConfig { gen_len: 0, ..Default::default() })
            .is_err());
        // The KV cache splits heads exactly like training: 2.5-D at p = 2,
        // depth = 4 splits heads 8 ways, which 4 heads cannot satisfy.
        let err = m
            .validate_serve(Parallelism::TwoFiveD { depth: 4 }, 2, &ServeConfig::default())
            .unwrap_err();
        assert!(err.contains("head divisor"), "{err}");
    }

    #[test]
    fn serve_toml_round_trip() {
        let text = r#"
[serve]
slots = 8
max_seq = 128
prompt_len = 32
gen_len = 16
requests = 100
arrival_rate = 2.5
seed = 42
"#;
        let cfg = CubicConfig::from_toml(text).unwrap();
        assert_eq!(
            cfg.serve,
            ServeConfig {
                slots: 8,
                max_seq: 128,
                prompt_len: 32,
                gen_len: 16,
                requests: 100,
                arrival_rate: 2.5,
                seed: 42,
            }
        );
        assert!(CubicConfig::from_toml("[serve]\narrival_rate = -1.0").is_err());
        assert_eq!(
            CubicConfig::default().serve,
            ServeConfig::default(),
            "no [serve] section → defaults"
        );
    }
}
