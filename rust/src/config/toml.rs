//! Minimal TOML-subset parser (no external crates offline).
//!
//! Supported: `[section]` headers, `key = value` pairs with integer, float,
//! boolean and double-quoted string values, `#` comments (full-line and
//! trailing), blank lines. Unsupported TOML (arrays, tables-in-tables,
//! multi-line strings) is rejected with an error rather than misparsed.

use std::collections::HashMap;

/// Parsed value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
}

/// A parsed document: `(section, key) -> value`. Keys before any section
/// header live in section `""`.
#[derive(Debug, Default)]
pub struct Doc {
    values: HashMap<(String, String), Value>,
}

impl Doc {
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.values.get(&(section.to_string(), key.to_string()))
    }

    pub fn get_int(&self, section: &str, key: &str) -> Option<i64> {
        match self.get(section, key) {
            Some(Value::Int(v)) => Some(*v),
            _ => None,
        }
    }

    pub fn get_float(&self, section: &str, key: &str) -> Option<f64> {
        match self.get(section, key) {
            Some(Value::Float(v)) => Some(*v),
            Some(Value::Int(v)) => Some(*v as f64),
            _ => None,
        }
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        match self.get(section, key) {
            Some(Value::Bool(v)) => Some(*v),
            _ => None,
        }
    }

    pub fn get_str(&self, section: &str, key: &str) -> Option<String> {
        match self.get(section, key) {
            Some(Value::Str(v)) => Some(v.clone()),
            _ => None,
        }
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }
}

/// Strip a trailing comment that is not inside a quoted string.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(raw: &str, lineno: usize) -> Result<Value, String> {
    let raw = raw.trim();
    if raw.is_empty() {
        return Err(format!("line {lineno}: missing value"));
    }
    if raw.starts_with('"') {
        if raw.len() < 2 || !raw.ends_with('"') {
            return Err(format!("line {lineno}: unterminated string"));
        }
        let inner = &raw[1..raw.len() - 1];
        if inner.contains('"') {
            return Err(format!("line {lineno}: embedded quote in string"));
        }
        return Ok(Value::Str(inner.to_string()));
    }
    match raw {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if raw.starts_with('[') {
        return Err(format!("line {lineno}: arrays are not supported"));
    }
    // Integers (allow underscores like TOML).
    let cleaned: String = raw.chars().filter(|&c| c != '_').collect();
    if let Ok(v) = cleaned.parse::<i64>() {
        return Ok(Value::Int(v));
    }
    if let Ok(v) = cleaned.parse::<f64>() {
        return Ok(Value::Float(v));
    }
    Err(format!("line {lineno}: cannot parse value {raw:?}"))
}

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> Result<Doc, String> {
    let mut doc = Doc::default();
    let mut section = String::new();
    for (idx, raw_line) in text.lines().enumerate() {
        let lineno = idx + 1;
        let line = strip_comment(raw_line).trim();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            if !line.ends_with(']') {
                return Err(format!("line {lineno}: malformed section header"));
            }
            let name = line[1..line.len() - 1].trim();
            if name.is_empty() || name.contains('[') || name.contains('.') {
                return Err(format!("line {lineno}: unsupported section {name:?}"));
            }
            section = name.to_string();
            continue;
        }
        let Some(eq) = line.find('=') else {
            return Err(format!("line {lineno}: expected `key = value`"));
        };
        let key = line[..eq].trim();
        if key.is_empty() || key.contains(' ') {
            return Err(format!("line {lineno}: bad key {key:?}"));
        }
        let value = parse_value(&line[eq + 1..], lineno)?;
        let k = (section.clone(), key.to_string());
        if doc.values.insert(k, value).is_some() {
            return Err(format!("line {lineno}: duplicate key {key:?} in [{section}]"));
        }
    }
    Ok(doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_all_value_types() {
        let doc = parse(
            "a = 1\nb = 2.5\nc = true\nd = \"hi\"\n[s]\ne = -3\nf = 1_000\n",
        )
        .unwrap();
        assert_eq!(doc.get_int("", "a"), Some(1));
        assert_eq!(doc.get_float("", "b"), Some(2.5));
        assert_eq!(doc.get_bool("", "c"), Some(true));
        assert_eq!(doc.get_str("", "d"), Some("hi".into()));
        assert_eq!(doc.get_int("s", "e"), Some(-3));
        assert_eq!(doc.get_int("s", "f"), Some(1000));
        assert_eq!(doc.len(), 6);
    }

    #[test]
    fn comments_and_blanks_are_ignored() {
        let doc = parse("# header\n\na = 1  # trailing\nb = \"x # not a comment\"\n").unwrap();
        assert_eq!(doc.get_int("", "a"), Some(1));
        assert_eq!(doc.get_str("", "b"), Some("x # not a comment".into()));
    }

    #[test]
    fn int_float_coercion_only_one_way() {
        let doc = parse("a = 2\n").unwrap();
        assert_eq!(doc.get_float("", "a"), Some(2.0)); // int readable as float
        assert_eq!(doc.get_int("", "a"), Some(2));
        let doc = parse("a = 2.5\n").unwrap();
        assert_eq!(doc.get_int("", "a"), None); // float not readable as int
    }

    #[test]
    fn errors_are_reported_with_line_numbers() {
        assert!(parse("a =\n").unwrap_err().contains("line 1"));
        assert!(parse("x\n").unwrap_err().contains("key = value"));
        assert!(parse("[bad\n").unwrap_err().contains("section"));
        assert!(parse("a = [1, 2]\n").unwrap_err().contains("arrays"));
        assert!(parse("a = \"unterminated\n").unwrap_err().contains("string"));
        assert!(parse("a = 1\na = 2\n").unwrap_err().contains("duplicate"));
        assert!(parse("a = zzz\n").unwrap_err().contains("cannot parse"));
    }

    #[test]
    fn sections_scope_keys() {
        let doc = parse("[x]\nk = 1\n[y]\nk = 2\n").unwrap();
        assert_eq!(doc.get_int("x", "k"), Some(1));
        assert_eq!(doc.get_int("y", "k"), Some(2));
        assert_eq!(doc.get_int("", "k"), None);
    }
}
