//! Local (per-shard) activation operations and their VJPs.
//!
//! These run shard-wise on every rank with no communication — the paper's
//! observation that "activation operations can be independently executed in
//! parallel" (§3.1) is what makes the balanced 3-D storage pay off: because
//! every rank holds exactly `1/P` of each activation, the elementwise work
//! is also perfectly balanced.
//!
//! All functions propagate phantom tensors (shape-only) untouched so the
//! paper-scale benches flow through the identical code path.

use crate::tensor::Tensor;

/// Tanh-approximation GeLU (the BERT/Megatron variant):
/// `gelu(x) = 0.5·x·(1 + tanh(√(2/π)·(x + 0.044715·x³)))`.
pub fn gelu(x: &Tensor) -> Tensor {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    x.map(|v| 0.5 * v * (1.0 + (C * (v + 0.044715 * v * v * v)).tanh()))
}

/// VJP of [`gelu`]: `dx = dy · gelu'(x)`.
pub fn gelu_backward(dy: &Tensor, x: &Tensor) -> Tensor {
    const C: f32 = 0.797_884_6;
    let dgelu = x.map(|v| {
        let inner = C * (v + 0.044715 * v * v * v);
        let t = inner.tanh();
        let sech2 = 1.0 - t * t;
        0.5 * (1.0 + t) + 0.5 * v * sech2 * C * (1.0 + 3.0 * 0.044715 * v * v)
    });
    dy.mul(&dgelu)
}

/// Row-wise softmax of a rank-2 tensor (numerically stabilized).
pub fn softmax_rows(x: &Tensor) -> Tensor {
    let (r, c) = x.dims2();
    let Some(d) = x.try_data() else {
        return Tensor::phantom(x.shape());
    };
    let mut out = vec![0.0f32; r * c];
    for i in 0..r {
        let row = &d[i * c..(i + 1) * c];
        let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut sum = 0.0f32;
        for j in 0..c {
            let e = (row[j] - m).exp();
            out[i * c + j] = e;
            sum += e;
        }
        let inv = 1.0 / sum;
        for j in 0..c {
            out[i * c + j] *= inv;
        }
    }
    Tensor::from_vec(x.shape(), out)
}

/// VJP of row-softmax: `dx_i = s_i ⊙ (dy_i − ⟨dy_i, s_i⟩)` per row, where
/// `s` is the saved softmax output.
pub fn softmax_rows_backward(dy: &Tensor, s: &Tensor) -> Tensor {
    assert_eq!(dy.shape(), s.shape());
    let (r, c) = dy.dims2();
    let (Some(dyd), Some(sd)) = (dy.try_data(), s.try_data()) else {
        return Tensor::phantom(dy.shape());
    };
    let mut out = vec![0.0f32; r * c];
    for i in 0..r {
        let dyr = &dyd[i * c..(i + 1) * c];
        let sr = &sd[i * c..(i + 1) * c];
        let dot: f32 = dyr.iter().zip(sr.iter()).map(|(&a, &b)| a * b).sum();
        for j in 0..c {
            out[i * c + j] = sr[j] * (dyr[j] - dot);
        }
    }
    Tensor::from_vec(dy.shape(), out)
}

/// Causal (lower-triangular) mask applied in-place semantics: entries with
/// `col > row (mod seq)` set to −1e9 before softmax. `x` is `(rows, seq)`
/// where each chunk of `seq` rows is one attention matrix.
pub fn causal_mask(x: &Tensor, seq: usize) -> Tensor {
    let (r, c) = x.dims2();
    assert_eq!(c, seq, "mask expects (…, seq) scores");
    let Some(d) = x.try_data() else {
        return Tensor::phantom(x.shape());
    };
    let mut out = d.to_vec();
    for i in 0..r {
        let q_pos = i % seq;
        for j in (q_pos + 1)..seq {
            out[i * c + j] = -1e9;
        }
    }
    Tensor::from_vec(x.shape(), out)
}

/// Zero out gradient entries that were masked in the forward pass.
pub fn causal_mask_backward(dy: &Tensor, seq: usize) -> Tensor {
    let (r, c) = dy.dims2();
    assert_eq!(c, seq);
    let Some(d) = dy.try_data() else {
        return Tensor::phantom(dy.shape());
    };
    let mut out = d.to_vec();
    for i in 0..r {
        let q_pos = i % seq;
        for j in (q_pos + 1)..seq {
            out[i * c + j] = 0.0;
        }
    }
    Tensor::from_vec(dy.shape(), out)
}

/// Fused softmax-cross-entropy over logit rows. `targets[i]` is the class
/// index for row `i`. Returns `(mean_loss, dlogits)` — the backward is fused
/// because `dlogits = (softmax − onehot)/rows` falls out of the forward.
pub fn cross_entropy(logits: &Tensor, targets: &[usize]) -> (f32, Tensor) {
    let (r, c) = logits.dims2();
    assert_eq!(r, targets.len());
    if logits.is_phantom() {
        return (0.0, Tensor::phantom(logits.shape()));
    }
    let probs = softmax_rows(logits);
    let pd = probs.data();
    let mut loss = 0.0f64;
    let mut grad = pd.to_vec();
    for i in 0..r {
        let t = targets[i];
        assert!(t < c, "target {t} out of range for {c} classes");
        loss += -(pd[i * c + t].max(1e-12) as f64).ln();
        grad[i * c + t] -= 1.0;
    }
    let scale = 1.0 / r as f32;
    for g in grad.iter_mut() {
        *g *= scale;
    }
    ((loss / r as f64) as f32, Tensor::from_vec(logits.shape(), grad))
}

/// Deterministic dropout (seeded per rank/step by the caller). Returns
/// `(y, mask)`; backward is `dy ⊙ mask`.
pub fn dropout(x: &Tensor, rate: f32, rng: &mut crate::rng::Xoshiro256) -> (Tensor, Tensor) {
    assert!((0.0..1.0).contains(&rate));
    if rate == 0.0 {
        return (x.clone(), Tensor::ones(x.shape()));
    }
    let keep = 1.0 - rate;
    let scale = 1.0 / keep;
    let mask_data: Vec<f32> = (0..x.numel())
        .map(|_| if rng.next_f32() < keep { scale } else { 0.0 })
        .collect();
    let mask = Tensor::from_vec(x.shape(), mask_data);
    if x.is_phantom() {
        return (Tensor::phantom(x.shape()), mask);
    }
    (x.mul(&mask), mask)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn randt(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        Tensor::randn(shape, 1.0, &mut rng)
    }

    /// Generic finite-difference VJP check: ⟨num_grad, dy⟩ vs analytic.
    fn check_grad(
        f: impl Fn(&Tensor) -> Tensor,
        grad: impl Fn(&Tensor, &Tensor) -> Tensor,
        x: &Tensor,
        dy: &Tensor,
        tol: f32,
    ) {
        let analytic = grad(dy, x);
        let h = 1e-2f32;
        for idx in [0usize, x.numel() / 2, x.numel() - 1] {
            let mut xp = x.clone();
            xp.data_mut()[idx] += h;
            let mut xm = x.clone();
            xm.data_mut()[idx] -= h;
            let num = f(&xp).sub(&f(&xm)).scale(1.0 / (2.0 * h)).mul(dy).sum();
            let ana = analytic.data()[idx];
            assert!(
                (num - ana).abs() < tol * (1.0 + ana.abs()),
                "idx {idx}: numeric {num} vs analytic {ana}"
            );
        }
    }

    #[test]
    fn gelu_known_values() {
        let x = Tensor::from_vec(&[3], vec![-1.0, 0.0, 1.0]);
        let y = gelu(&x);
        assert!((y.data()[1]).abs() < 1e-7);
        assert!((y.data()[2] - 0.8412).abs() < 1e-3);
        assert!((y.data()[0] + 0.1588).abs() < 1e-3);
    }

    #[test]
    fn gelu_gradient_matches_numeric() {
        let x = randt(&[4, 5], 1);
        let dy = randt(&[4, 5], 2);
        check_grad(gelu, gelu_backward, &x, &dy, 1e-2);
    }

    #[test]
    fn softmax_rows_sum_to_one_and_shift_invariant() {
        let x = randt(&[5, 7], 3);
        let s = softmax_rows(&x);
        for i in 0..5 {
            let sum: f32 = (0..7).map(|j| s.at2(i, j)).sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
        let shifted = softmax_rows(&x.map(|v| v + 100.0));
        assert!(s.max_abs_diff(&shifted) < 1e-5);
    }

    #[test]
    fn softmax_gradient_matches_numeric() {
        let x = randt(&[3, 6], 4);
        let dy = randt(&[3, 6], 5);
        let s = softmax_rows(&x);
        let analytic = softmax_rows_backward(&dy, &s);
        let h = 1e-2f32;
        for idx in [0usize, 7, 17] {
            let mut xp = x.clone();
            xp.data_mut()[idx] += h;
            let mut xm = x.clone();
            xm.data_mut()[idx] -= h;
            let num = softmax_rows(&xp)
                .sub(&softmax_rows(&xm))
                .scale(1.0 / (2.0 * h))
                .mul(&dy)
                .sum();
            let ana = analytic.data()[idx];
            assert!((num - ana).abs() < 1e-2 * (1.0 + ana.abs()));
        }
    }

    #[test]
    fn causal_mask_zeroes_upper_triangle() {
        let seq = 4;
        let x = Tensor::ones(&[8, seq]); // two 4x4 attention matrices
        let m = causal_mask(&x, seq);
        for block in 0..2 {
            for i in 0..seq {
                for j in 0..seq {
                    let v = m.at2(block * seq + i, j);
                    if j > i {
                        assert!(v < -1e8);
                    } else {
                        assert_eq!(v, 1.0);
                    }
                }
            }
        }
        // backward zeroes the same entries
        let g = causal_mask_backward(&Tensor::ones(&[8, seq]), seq);
        assert_eq!(g.at2(0, 3), 0.0);
        assert_eq!(g.at2(3, 3), 1.0);
    }

    #[test]
    fn cross_entropy_perfect_prediction_is_low_loss() {
        // Huge logit on the target class → loss ≈ 0, grads ≈ 0.
        let mut logits = Tensor::zeros(&[2, 4]);
        logits.data_mut()[0 * 4 + 1] = 50.0;
        logits.data_mut()[1 * 4 + 3] = 50.0;
        let (loss, grad) = cross_entropy(&logits, &[1, 3]);
        assert!(loss < 1e-4);
        assert!(grad.frob_norm() < 1e-4);
    }

    #[test]
    fn cross_entropy_uniform_is_log_c() {
        let logits = Tensor::zeros(&[3, 8]);
        let (loss, _) = cross_entropy(&logits, &[0, 1, 2]);
        assert!((loss - (8f32).ln()).abs() < 1e-5);
    }

    #[test]
    fn cross_entropy_gradient_matches_numeric() {
        let x = randt(&[3, 5], 6);
        let targets = vec![2usize, 0, 4];
        let (_, grad) = cross_entropy(&x, &targets);
        let h = 1e-2f32;
        for idx in [0usize, 7, 14] {
            let mut xp = x.clone();
            xp.data_mut()[idx] += h;
            let mut xm = x.clone();
            xm.data_mut()[idx] -= h;
            let (lp, _) = cross_entropy(&xp, &targets);
            let (lm, _) = cross_entropy(&xm, &targets);
            let num = (lp - lm) / (2.0 * h);
            let ana = grad.data()[idx];
            assert!((num - ana).abs() < 1e-3, "idx {idx}: {num} vs {ana}");
        }
    }

    #[test]
    fn dropout_scales_and_masks() {
        let mut rng = Xoshiro256::seed_from_u64(7);
        let x = Tensor::ones(&[100, 10]);
        let (y, mask) = dropout(&x, 0.3, &mut rng);
        let kept = y.data().iter().filter(|&&v| v > 0.0).count();
        // ~70% kept, scaled by 1/0.7.
        assert!((kept as f32 / 1000.0 - 0.7).abs() < 0.05);
        for &v in y.data() {
            assert!(v == 0.0 || (v - 1.0 / 0.7).abs() < 1e-6);
        }
        // backward is mask multiplication: dy ⊙ mask recovers y for dy = x.
        assert_eq!(x.mul(&mask), y);
    }

    #[test]
    fn phantom_propagation() {
        let p = Tensor::phantom(&[3, 4]);
        assert!(gelu(&p).is_phantom());
        assert!(softmax_rows(&p).is_phantom());
        assert!(causal_mask(&p, 4).is_phantom());
        let (l, g) = cross_entropy(&p, &[0, 1, 2]);
        assert_eq!(l, 0.0);
        assert!(g.is_phantom());
    }
}
