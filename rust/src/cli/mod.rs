//! Minimal command-line argument parser (no `clap` offline): positional
//! subcommand + `--key value` / `--flag` options, with typed accessors and
//! unknown-option detection.

use std::collections::HashMap;

/// Parsed arguments: one optional subcommand + options.
#[derive(Debug, Default)]
pub struct Args {
    pub command: Option<String>,
    options: HashMap<String, String>,
    flags: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                out.command = Some(it.next().unwrap());
            }
        }
        while let Some(a) = it.next() {
            let Some(key) = a.strip_prefix("--") else {
                return Err(format!("unexpected positional argument {a:?}"));
            };
            if key.is_empty() {
                return Err("empty option name".into());
            }
            // --key=value or --key value or bare flag
            if let Some((k, v)) = key.split_once('=') {
                out.options.insert(k.to_string(), v.to_string());
            } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                out.options.insert(key.to_string(), it.next().unwrap());
            } else {
                out.flags.push(key.to_string());
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<String> {
        self.consumed.borrow_mut().push(key.to_string());
        self.options.get(key).cloned()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{key} {v:?}: {e}")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| format!("--{key} {v:?}: {e}")),
        }
    }

    pub fn flag(&self, key: &str) -> bool {
        self.consumed.borrow_mut().push(key.to_string());
        self.flags.iter().any(|f| f == key)
    }

    /// Options/flags that were never queried — typo detection.
    pub fn unknown(&self) -> Vec<String> {
        let seen = self.consumed.borrow();
        self.options
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !seen.contains(k))
            .cloned()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["train", "--steps", "50", "--lr=0.01", "--verbose"]);
        assert_eq!(a.command.as_deref(), Some("train"));
        assert_eq!(a.get_usize("steps", 0).unwrap(), 50);
        assert!((a.get_f64("lr", 0.0).unwrap() - 0.01).abs() < 1e-12);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert!(a.unknown().is_empty());
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["bench"]);
        assert_eq!(a.get_usize("steps", 7).unwrap(), 7);
    }

    #[test]
    fn unknown_options_are_reported() {
        let a = parse(&["train", "--stepz", "50"]);
        let _ = a.get_usize("steps", 0);
        assert_eq!(a.unknown(), vec!["stepz".to_string()]);
    }

    #[test]
    fn bad_values_error() {
        let a = parse(&["x", "--steps", "abc"]);
        assert!(a.get_usize("steps", 0).is_err());
    }

    #[test]
    fn positional_after_flags_rejected() {
        assert!(Args::parse(["--a".to_string(), "--b".to_string(), "oops".to_string()].into_iter()).is_ok());
        assert!(Args::parse(["cmd".to_string(), "stray".to_string()].into_iter()).is_err());
    }
}
