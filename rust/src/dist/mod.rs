//! Shard layouts: how global matrices and vectors map onto the 1-D line,
//! 2-D mesh and 3-D cube topologies.
//!
//! This module is pure data-placement algebra — no communication. Each
//! layout knows, for every rank, which sub-block of a global tensor that
//! rank owns, and provides `scatter` (global → per-rank shards), `gather`
//! (per-rank shards → global) and `shard_of` (one rank's shard). The
//! distributed algorithms in [`crate::parallel`] are written against these
//! layouts, and the property tests in `rust/tests/property.rs` pin down
//! that every layout tiles the global matrix exactly (no gaps, no
//! overlaps) and that `gather ∘ scatter = id`.
//!
//! With the Arc-backed tensor storage, shard extraction cuts a view of the
//! source and then *compacts* it (`Tensor::compact`): shards are long-lived
//! model state, and a zero-copy view would pin the full global allocation
//! on every rank. Zero-copy views are reserved for the transient chunking
//! on the collective hot path (`Tensor::block`/`split_rows`/`split_flat`).
//!
//! ## The 3-D layouts (paper §3.1.1, Figure 5)
//!
//! A `p³` cube has coordinates `(i, j, l)` along axes `X`, `Y`, `Z`
//! ([`crate::topology::Axis`]). A direction triple [`Dirs`] `{a, b, c}`
//! assigns the three axes roles per operation: operand `A` is gathered
//! along `a`, operand `B` along `b`, and the output partial is
//! reduce-scattered along `c`. The canonical triple is `{a: Y, b: X,
//! c: Z}` — inputs travel along y, weights along x, outputs reduce along
//! z, exactly the paper's Figure 1 annotation.
//!
//! Every matrix layout splits rows and columns by cube axes via [`Split`]:
//! `One(axis)` splits a dimension `p` ways indexed by that axis'
//! coordinate; `Two(outer, inner)` splits it `p²` ways indexed by
//! `coord(outer)·p + coord(inner)`. The three operand layouts of
//! Algorithm 1 (`C = A·B`) are:
//!
//! | layout                | global | rows split        | cols split        |
//! |-----------------------|--------|-------------------|-------------------|
//! | [`Layout3D::input`]   | (M, N) | `Two(b, a)` (p²)  | `One(c)` (p)      |
//! | [`Layout3D::weight`]  | (N, K) | `One(c)` (p)      | `Two(a, b)` (p²)  |
//! | [`Layout3D::output`]  | (M, K) | `Two(b, c)` (p²)  | `One(a)` (p)      |
//!
//! so every rank stores exactly `1/p³` of each matrix — the paper's
//! perfect load balance. Gathering `input` along `a` merges the inner row
//! split into an `(M/p, N/p)` block; ditto `weight` along `b`; the local
//! product is reduce-scattered along `c`, splitting rows, which lands the
//! result exactly in the `output` layout. Note `output(d) = input(d.swapped())`:
//! chaining two linear layers with swapped direction triples keeps the
//! activation layout invariant (§3.2).
//!
//! Vectors (biases, layernorm γ/β) use [`DiagVec3D`]: the length-`n`
//! vector is split into `p²` chunks owned by the ranks on the diagonal
//! `coord(a) == coord(c)`, with chunk `coord(c)·(n/p) + coord(b)·(n/p²)`.
//! That placement makes Algorithm 7's broadcast (along `a`, rooted at the
//! diagonal) + all-gather (along `b`) deliver exactly the column-block
//! slice each activation shard needs.

use crate::tensor::Tensor;
use crate::topology::{Axis, Coord, Cube, Mesh};

// ---------------------------------------------------------------------
// Direction triples
// ---------------------------------------------------------------------

/// The three cube axes in their per-operation roles: gather `A` along `a`,
/// gather `B` along `b`, reduce-scatter the output along `c`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Dirs {
    pub a: Axis,
    pub b: Axis,
    pub c: Axis,
}

impl Dirs {
    /// The paper's Figure 1 assignment: inputs along y, weights along x,
    /// outputs along z.
    pub fn canonical() -> Dirs {
        Dirs { a: Axis::Y, b: Axis::X, c: Axis::Z }
    }

    /// Swap the input and output directions (`a ↔ c`), keeping `b`. The
    /// §3.2 stacking trick: `output(d) == input(d.swapped())`, so two
    /// chained linears under `d` then `d.swapped()` return the activation
    /// to its original layout.
    pub fn swapped(&self) -> Dirs {
        Dirs { a: self.c, b: self.b, c: self.a }
    }

    /// Panic unless the three directions are distinct axes.
    pub fn assert_distinct(&self) {
        assert!(
            self.a != self.b && self.b != self.c && self.a != self.c,
            "direction triple {:?} must use three distinct axes",
            self
        );
    }
}

// ---------------------------------------------------------------------
// Splits and the 3-D matrix layouts
// ---------------------------------------------------------------------

/// How one dimension of a matrix is split across cube axes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    /// `p` blocks indexed by the coordinate on the given axis.
    One(Axis),
    /// `p²` blocks indexed by `coord(outer)·p + coord(inner)`. Ring
    /// collectives along `inner` merge/scatter adjacent blocks.
    Two(Axis, Axis),
}

impl Split {
    fn factor(&self, p: usize) -> usize {
        match self {
            Split::One(_) => p,
            Split::Two(_, _) => p * p,
        }
    }

    fn index(&self, p: usize, c: Coord) -> usize {
        match self {
            Split::One(ax) => c.axis(*ax),
            Split::Two(outer, inner) => c.axis(*outer) * p + c.axis(*inner),
        }
    }
}

/// A rank-2 tensor distribution over the `p³` cube: independent row and
/// column splits. See the module docs for the three standard layouts; the
/// transposed-form operand layouts live in
/// `crate::parallel::threed::Layout3DExt`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Layout3D {
    pub row: Split,
    pub col: Split,
}

impl Layout3D {
    /// Layout of `A` in `C = A·B` (global `(M, N)`).
    pub fn input(d: Dirs) -> Layout3D {
        Layout3D { row: Split::Two(d.b, d.a), col: Split::One(d.c) }
    }

    /// Layout of `B` in `C = A·B` (global `(N, K)`).
    pub fn weight(d: Dirs) -> Layout3D {
        Layout3D { row: Split::One(d.c), col: Split::Two(d.a, d.b) }
    }

    /// Layout of `C` in `C = A·B` (global `(M, K)`). Equals
    /// `input(d.swapped())`.
    pub fn output(d: Dirs) -> Layout3D {
        Layout3D { row: Split::Two(d.b, d.c), col: Split::One(d.a) }
    }

    /// Per-rank shard shape for a global `(rows, cols)` matrix.
    pub fn shard_shape(&self, p: usize, rows: usize, cols: usize) -> (usize, usize) {
        let rf = self.row.factor(p);
        let cf = self.col.factor(p);
        assert_eq!(rows % rf, 0, "rows {rows} not divisible by split factor {rf}");
        assert_eq!(cols % cf, 0, "cols {cols} not divisible by split factor {cf}");
        (rows / rf, cols / cf)
    }

    /// Per-rank shard bytes (f32) for a global `(rows, cols)` matrix —
    /// always `rows·cols·4 / p³` for the standard layouts.
    pub fn bytes_per_rank(&self, p: usize, rows: usize, cols: usize) -> usize {
        let (r, c) = self.shard_shape(p, rows, cols);
        r * c * std::mem::size_of::<f32>()
    }

    /// `(r0, c0, shard_rows, shard_cols)` of the block owned by `coord`.
    pub fn shard_bounds(
        &self,
        cube: &Cube,
        coord: Coord,
        rows: usize,
        cols: usize,
    ) -> (usize, usize, usize, usize) {
        let p = cube.edge();
        let (sr, sc) = self.shard_shape(p, rows, cols);
        let r0 = self.row.index(p, coord) * sr;
        let c0 = self.col.index(p, coord) * sc;
        (r0, c0, sr, sc)
    }

    /// Extract the shard owned by `coord` (phantom in → phantom out).
    /// Shards are *compacted* — they own a private minimal buffer — because
    /// they are long-lived (model state); a zero-copy view here would pin
    /// the full global matrix allocation on every rank. Transient chunking
    /// on the collective hot path uses `Tensor::block`/`split_rows` views
    /// directly.
    pub fn shard_of(&self, cube: &Cube, coord: Coord, t: &Tensor) -> Tensor {
        let (rows, cols) = t.dims2();
        let (r0, c0, sr, sc) = self.shard_bounds(cube, coord, rows, cols);
        t.block(r0, c0, sr, sc).compact()
    }

    /// All shards in rank order.
    pub fn scatter(&self, cube: &Cube, t: &Tensor) -> Vec<Tensor> {
        (0..cube.size())
            .map(|r| self.shard_of(cube, cube.coord_of(r), t))
            .collect()
    }

    /// Reassemble the global `(rows, cols)` matrix from shards in rank
    /// order. Any phantom shard makes the result phantom.
    pub fn gather(&self, cube: &Cube, shards: &[Tensor], rows: usize, cols: usize) -> Tensor {
        assert_eq!(shards.len(), cube.size(), "need one shard per rank");
        if shards.iter().any(|s| s.is_phantom()) {
            return Tensor::phantom(&[rows, cols]);
        }
        let mut out = Tensor::zeros(&[rows, cols]);
        for (rank, shard) in shards.iter().enumerate() {
            let coord = cube.coord_of(rank);
            let (r0, c0, sr, sc) = self.shard_bounds(cube, coord, rows, cols);
            assert_eq!(
                shard.shape(),
                &[sr, sc],
                "rank {rank} shard shape mismatch for layout {:?}",
                self
            );
            out.set_block(r0, c0, shard);
        }
        out
    }
}

// ---------------------------------------------------------------------
// Diagonal vectors (Algorithms 7/8 storage)
// ---------------------------------------------------------------------

/// Diagonal storage for a length-`n` vector under directions `d`: ranks
/// with `coord(a) == coord(c)` each own the `n/p²` chunk at offset
/// `coord(c)·(n/p) + coord(b)·(n/p²)`; everyone else owns nothing.
#[derive(Clone, Copy, Debug)]
pub struct DiagVec3D {
    pub dirs: Dirs,
}

impl DiagVec3D {
    pub fn for_dirs(dirs: Dirs) -> DiagVec3D {
        DiagVec3D { dirs }
    }

    /// Does `coord` own a chunk (is it on the `a == c` diagonal)?
    pub fn owns(&self, c: Coord) -> bool {
        c.axis(self.dirs.a) == c.axis(self.dirs.c)
    }

    fn chunk_range(&self, p: usize, n: usize, c: Coord) -> (usize, usize) {
        assert_eq!(n % (p * p), 0, "vector len {n} not divisible by p² = {}", p * p);
        let chunk = n / (p * p);
        let off = c.axis(self.dirs.c) * (n / p) + c.axis(self.dirs.b) * chunk;
        (off, chunk)
    }

    /// This coord's chunk, or `None` off the diagonal.
    pub fn shard_of(&self, cube: &Cube, coord: Coord, v: &Tensor) -> Option<Tensor> {
        if !self.owns(coord) {
            return None;
        }
        let p = cube.edge();
        let n = v.numel();
        let (off, chunk) = self.chunk_range(p, n, coord);
        if v.is_phantom() {
            return Some(Tensor::phantom(&[chunk]));
        }
        Some(
            v.reshape(&[1, n])
                .block(0, off, 1, chunk)
                .into_reshape(&[chunk])
                .compact(),
        )
    }

    /// Per-rank chunks in rank order (`None` off the diagonal).
    pub fn scatter(&self, cube: &Cube, v: &Tensor) -> Vec<Option<Tensor>> {
        (0..cube.size())
            .map(|r| self.shard_of(cube, cube.coord_of(r), v))
            .collect()
    }

    /// Reassemble the global vector from per-rank chunks.
    pub fn gather(&self, cube: &Cube, shards: &[Option<Tensor>], n: usize) -> Tensor {
        assert_eq!(shards.len(), cube.size(), "need one entry per rank");
        let p = cube.edge();
        let mut out = vec![0.0f32; n];
        let mut covered = 0usize;
        for (rank, s) in shards.iter().enumerate() {
            let coord = cube.coord_of(rank);
            match s {
                Some(t) => {
                    assert!(self.owns(coord), "rank {rank} is off-diagonal but has a chunk");
                    let (off, chunk) = self.chunk_range(p, n, coord);
                    assert_eq!(t.numel(), chunk, "rank {rank} chunk length mismatch");
                    out[off..off + chunk].copy_from_slice(t.data());
                    covered += chunk;
                }
                None => {
                    assert!(!self.owns(coord), "rank {rank} is on-diagonal but has no chunk");
                }
            }
        }
        assert_eq!(covered, n, "diagonal chunks do not cover the vector");
        Tensor::from_vec(&[n], out)
    }
}

// ---------------------------------------------------------------------
// 1-D (Megatron) and 2-D (SUMMA) layouts
// ---------------------------------------------------------------------

/// Megatron weight sharding along one dimension of a rank-2 tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Layout1D {
    /// Split columns `P` ways (column-parallel linear weights).
    ColShard,
    /// Split rows `P` ways (row-parallel linear weights).
    RowShard,
}

impl Layout1D {
    /// The shard owned by `rank` of `world` (compacted — see
    /// [`Layout3D::shard_of`] for why shards own their buffers).
    pub fn shard_of(&self, world: usize, rank: usize, t: &Tensor) -> Tensor {
        let (r, c) = t.dims2();
        match self {
            Layout1D::ColShard => {
                assert_eq!(c % world, 0, "cols {c} not divisible by world {world}");
                t.block(0, rank * (c / world), r, c / world)
            }
            Layout1D::RowShard => {
                assert_eq!(r % world, 0, "rows {r} not divisible by world {world}");
                t.block(rank * (r / world), 0, r / world, c)
            }
        }
        .compact()
    }

    /// All shards in rank order.
    pub fn scatter(&self, world: usize, t: &Tensor) -> Vec<Tensor> {
        (0..world).map(|rank| self.shard_of(world, rank, t)).collect()
    }

    /// Reassemble from shards in rank order.
    pub fn gather(&self, parts: &[Tensor]) -> Tensor {
        match self {
            Layout1D::ColShard => Tensor::concat_cols(parts),
            Layout1D::RowShard => Tensor::concat_rows(parts),
        }
    }
}

/// Optimus/SUMMA block distribution: rank `(i, j)` of the `q × q` mesh
/// owns block `(i, j)` of every `(R/q, C/q)` blocking.
#[derive(Clone, Copy, Debug)]
pub struct Layout2D;

impl Layout2D {
    /// The `(R/q, C/q)` block owned by `rank` (compacted — see
    /// [`Layout3D::shard_of`]).
    pub fn shard_of(mesh: &Mesh, rank: usize, t: &Tensor) -> Tensor {
        let q = mesh.edge();
        let (r, c) = t.dims2();
        assert_eq!(r % q, 0, "rows {r} not divisible by mesh edge {q}");
        assert_eq!(c % q, 0, "cols {c} not divisible by mesh edge {q}");
        let (row, col) = mesh.coord_of(rank);
        t.block(row * (r / q), col * (c / q), r / q, c / q).compact()
    }

    /// All blocks in rank order.
    pub fn scatter(mesh: &Mesh, t: &Tensor) -> Vec<Tensor> {
        (0..mesh.size()).map(|rank| Self::shard_of(mesh, rank, t)).collect()
    }

    /// Reassemble the global `(rows, cols)` matrix from blocks in rank
    /// order. Any phantom block makes the result phantom.
    pub fn gather(mesh: &Mesh, parts: &[Tensor], rows: usize, cols: usize) -> Tensor {
        assert_eq!(parts.len(), mesh.size(), "need one block per rank");
        if parts.iter().any(|p| p.is_phantom()) {
            return Tensor::phantom(&[rows, cols]);
        }
        let q = mesh.edge();
        assert_eq!(rows % q, 0);
        assert_eq!(cols % q, 0);
        let (br, bc) = (rows / q, cols / q);
        let mut out = Tensor::zeros(&[rows, cols]);
        for (rank, part) in parts.iter().enumerate() {
            let (row, col) = mesh.coord_of(rank);
            assert_eq!(part.shape(), &[br, bc], "rank {rank} block shape mismatch");
            out.set_block(row * br, col * bc, part);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn randt(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        Tensor::randn(shape, 1.0, &mut rng)
    }

    #[test]
    fn canonical_dirs_match_paper_roles() {
        let d = Dirs::canonical();
        assert_eq!(d.a, Axis::Y);
        assert_eq!(d.b, Axis::X);
        assert_eq!(d.c, Axis::Z);
        d.assert_distinct();
        let s = d.swapped();
        assert_eq!(s, Dirs { a: Axis::Z, b: Axis::X, c: Axis::Y });
        assert_eq!(s.swapped(), d);
    }

    #[test]
    fn output_equals_swapped_input() {
        let d = Dirs::canonical();
        assert_eq!(Layout3D::output(d), Layout3D::input(d.swapped()));
    }

    #[test]
    fn layout3d_shard_shapes_are_balanced() {
        let d = Dirs::canonical();
        for p in [1usize, 2, 3] {
            let (rows, cols) = (p * p * 3, p * p * 5);
            for layout in [Layout3D::input(d), Layout3D::weight(d), Layout3D::output(d)] {
                let (r, c) = layout.shard_shape(p, rows, cols);
                assert_eq!(r * c * p * p * p, rows * cols, "p={p} layout {layout:?}");
                assert_eq!(layout.bytes_per_rank(p, rows, cols), r * c * 4);
            }
        }
    }

    #[test]
    fn layout3d_scatter_gather_round_trip() {
        let d = Dirs::canonical();
        let cube = Cube::new(2);
        let t = randt(&[8, 12], 1);
        for layout in [Layout3D::input(d), Layout3D::weight(d), Layout3D::output(d)] {
            let shards = layout.scatter(&cube, &t);
            assert_eq!(shards.len(), 8);
            assert_eq!(layout.gather(&cube, &shards, 8, 12), t);
        }
    }

    #[test]
    fn layout3d_phantom_flows() {
        let d = Dirs::canonical();
        let cube = Cube::new(2);
        let t = Tensor::phantom(&[8, 12]);
        let shards = Layout3D::input(d).scatter(&cube, &t);
        assert!(shards.iter().all(|s| s.is_phantom()));
        assert!(Layout3D::input(d).gather(&cube, &shards, 8, 12).is_phantom());
    }

    #[test]
    fn diag_vec_round_trip_and_ownership() {
        let cube = Cube::new(2);
        for d in [Dirs::canonical(), Dirs::canonical().swapped()] {
            let spec = DiagVec3D::for_dirs(d);
            let v = randt(&[12], 2);
            let shards = spec.scatter(&cube, &v);
            let owners = shards.iter().filter(|s| s.is_some()).count();
            assert_eq!(owners, 4, "p² diagonal owners");
            for (rank, s) in shards.iter().enumerate() {
                assert_eq!(s.is_some(), spec.owns(cube.coord_of(rank)));
                if let Some(t) = s {
                    assert_eq!(t.numel(), 12 / 4);
                }
            }
            assert_eq!(spec.gather(&cube, &shards, 12), v);
        }
    }

    #[test]
    fn layout1d_round_trips_both_ways() {
        let t = randt(&[6, 8], 3);
        for layout in [Layout1D::ColShard, Layout1D::RowShard] {
            let parts = layout.scatter(2, &t);
            assert_eq!(parts.len(), 2);
            assert_eq!(layout.gather(&parts), t);
            assert_eq!(parts[1], layout.shard_of(2, 1, &t));
        }
    }

    #[test]
    fn layout2d_round_trip() {
        let mesh = Mesh::new(2);
        let t = randt(&[8, 6], 4);
        let parts = Layout2D::scatter(&mesh, &t);
        assert_eq!(parts.len(), 4);
        assert_eq!(parts[3], t.block(4, 3, 4, 3));
        assert_eq!(Layout2D::gather(&mesh, &parts, 8, 6), t);
    }
}
