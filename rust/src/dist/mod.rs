//! Shard layouts: how global matrices and vectors map onto the 1-D line,
//! 2-D mesh and 3-D cube topologies.
//!
//! This module is pure data-placement algebra — no communication. Each
//! layout knows, for every rank, which sub-block of a global tensor that
//! rank owns, and provides `scatter` (global → per-rank shards), `gather`
//! (per-rank shards → global) and `shard_of` (one rank's shard). The
//! distributed algorithms in [`crate::parallel`] are written against these
//! layouts, and the property tests in `rust/tests/property.rs` pin down
//! that every layout tiles the global matrix exactly (no gaps, no
//! overlaps) and that `gather ∘ scatter = id`.
//!
//! With the Arc-backed tensor storage, shard extraction cuts a view of the
//! source and then *compacts* it (`Tensor::compact`): shards are long-lived
//! model state, and a zero-copy view would pin the full global allocation
//! on every rank. Zero-copy views are reserved for the transient chunking
//! on the collective hot path (`Tensor::block`/`split_rows`/`split_flat`).
//!
//! ## The 3-D layouts (paper §3.1.1, Figure 5)
//!
//! A `p³` cube has coordinates `(i, j, l)` along axes `X`, `Y`, `Z`
//! ([`crate::topology::Axis`]). A direction triple [`Dirs`] `{a, b, c}`
//! assigns the three axes roles per operation: operand `A` is gathered
//! along `a`, operand `B` along `b`, and the output partial is
//! reduce-scattered along `c`. The canonical triple is `{a: Y, b: X,
//! c: Z}` — inputs travel along y, weights along x, outputs reduce along
//! z, exactly the paper's Figure 1 annotation.
//!
//! Every matrix layout splits rows and columns by cube axes via [`Split`]:
//! `One(axis)` splits a dimension `p` ways indexed by that axis'
//! coordinate; `Two(outer, inner)` splits it `p²` ways indexed by
//! `coord(outer)·p + coord(inner)`. The three operand layouts of
//! Algorithm 1 (`C = A·B`) are:
//!
//! | layout                | global | rows split        | cols split        |
//! |-----------------------|--------|-------------------|-------------------|
//! | [`Layout3D::input`]   | (M, N) | `Two(b, a)` (p²)  | `One(c)` (p)      |
//! | [`Layout3D::weight`]  | (N, K) | `One(c)` (p)      | `Two(a, b)` (p²)  |
//! | [`Layout3D::output`]  | (M, K) | `Two(b, c)` (p²)  | `One(a)` (p)      |
//!
//! so every rank stores exactly `1/p³` of each matrix — the paper's
//! perfect load balance. Gathering `input` along `a` merges the inner row
//! split into an `(M/p, N/p)` block; ditto `weight` along `b`; the local
//! product is reduce-scattered along `c`, splitting rows, which lands the
//! result exactly in the `output` layout. Note `output(d) = input(d.swapped())`:
//! chaining two linear layers with swapped direction triples keeps the
//! activation layout invariant (§3.2).
//!
//! Vectors (biases, layernorm γ/β) use [`DiagVec3D`]: the length-`n`
//! vector is split into `p²` chunks owned by the ranks on the diagonal
//! `coord(a) == coord(c)`, with chunk `coord(c)·(n/p) + coord(b)·(n/p²)`.
//! That placement makes Algorithm 7's broadcast (along `a`, rooted at the
//! diagonal) + all-gather (along `b`) deliver exactly the column-block
//! slice each activation shard needs.
//!
//! ## The unified layout algebra: [`ShardSpec`] and [`DistTensor`]
//!
//! The per-dimension layouts above are *points*; [`ShardSpec`] is the
//! spectrum. One spec = one device mesh shape ([`MeshSpec`]: a point, a
//! `P`-line, a `q × q` grid, a `p³` cube with block-entry directions, a
//! `p × p × d` 2.5-D Tesseract, or a hybrid of `r` data-parallel replicas
//! around any of those — see the [`MeshSpec`] docs for the 2.5-D
//! memory/communication trade-off table) plus this rank's position, and it
//! answers every placement question the model has — which shard of a weight this rank owns
//! ([`ShardSpec::shard_weight`], keyed by the layer's [`Stage`]), which
//! chunk of a bias/γ/β vector ([`ShardSpec::shard_vector`], keyed by
//! [`VecRole`]), which window of a global activation
//! ([`ShardSpec::shard_activation`]), and how to reassemble any of them
//! from all ranks' shards (`assemble_*`). [`DistTensor`] pairs one rank's
//! local shard with its spec so shards can travel with their layout.
//!
//! Everything here stays pure placement algebra: no communication. The
//! communicating counterparts live behind
//! [`crate::parallel::ParallelOps`], which is written *against* this
//! module — a new parallelism is a new `MeshSpec` arm plus a new
//! `ParallelOps` impl, never a new copy of the model.

use crate::tensor::Tensor;
use crate::topology::{Axis, Coord, Cube, HybridInner, Mesh, Parallelism, PipelineInner};

// ---------------------------------------------------------------------
// Direction triples
// ---------------------------------------------------------------------

/// The three cube axes in their per-operation roles: gather `A` along `a`,
/// gather `B` along `b`, reduce-scatter the output along `c`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Dirs {
    /// Axis the `A` operand is gathered along.
    pub a: Axis,
    /// Axis the `B` operand is gathered along.
    pub b: Axis,
    /// Axis the output partials are reduce-scattered along.
    pub c: Axis,
}

impl Dirs {
    /// The paper's Figure 1 assignment: inputs along y, weights along x,
    /// outputs along z.
    pub fn canonical() -> Dirs {
        Dirs { a: Axis::Y, b: Axis::X, c: Axis::Z }
    }

    /// Swap the input and output directions (`a ↔ c`), keeping `b`. The
    /// §3.2 stacking trick: `output(d) == input(d.swapped())`, so two
    /// chained linears under `d` then `d.swapped()` return the activation
    /// to its original layout.
    pub fn swapped(&self) -> Dirs {
        Dirs { a: self.c, b: self.b, c: self.a }
    }

    /// Panic unless the three directions are distinct axes.
    pub fn assert_distinct(&self) {
        assert!(
            self.a != self.b && self.b != self.c && self.a != self.c,
            "direction triple {:?} must use three distinct axes",
            self
        );
    }
}

// ---------------------------------------------------------------------
// Splits and the 3-D matrix layouts
// ---------------------------------------------------------------------

/// How one dimension of a matrix is split across cube axes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    /// `p` blocks indexed by the coordinate on the given axis.
    One(Axis),
    /// `p²` blocks indexed by `coord(outer)·p + coord(inner)`. Ring
    /// collectives along `inner` merge/scatter adjacent blocks.
    Two(Axis, Axis),
}

impl Split {
    fn factor(&self, p: usize) -> usize {
        match self {
            Split::One(_) => p,
            Split::Two(_, _) => p * p,
        }
    }

    fn index(&self, p: usize, c: Coord) -> usize {
        match self {
            Split::One(ax) => c.axis(*ax),
            Split::Two(outer, inner) => c.axis(*outer) * p + c.axis(*inner),
        }
    }
}

/// A rank-2 tensor distribution over the `p³` cube: independent row and
/// column splits. See the module docs for the three standard layouts; the
/// transposed-form operand layouts live in
/// `crate::parallel::threed::Layout3DExt`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Layout3D {
    /// How the row dimension is split across cube axes.
    pub row: Split,
    /// How the column dimension is split across cube axes.
    pub col: Split,
}

impl Layout3D {
    /// Layout of `A` in `C = A·B` (global `(M, N)`).
    pub fn input(d: Dirs) -> Layout3D {
        Layout3D { row: Split::Two(d.b, d.a), col: Split::One(d.c) }
    }

    /// Layout of `B` in `C = A·B` (global `(N, K)`).
    pub fn weight(d: Dirs) -> Layout3D {
        Layout3D { row: Split::One(d.c), col: Split::Two(d.a, d.b) }
    }

    /// Layout of `C` in `C = A·B` (global `(M, K)`). Equals
    /// `input(d.swapped())`.
    pub fn output(d: Dirs) -> Layout3D {
        Layout3D { row: Split::Two(d.b, d.c), col: Split::One(d.a) }
    }

    /// Per-rank shard shape for a global `(rows, cols)` matrix.
    pub fn shard_shape(&self, p: usize, rows: usize, cols: usize) -> (usize, usize) {
        let rf = self.row.factor(p);
        let cf = self.col.factor(p);
        assert_eq!(rows % rf, 0, "rows {rows} not divisible by split factor {rf}");
        assert_eq!(cols % cf, 0, "cols {cols} not divisible by split factor {cf}");
        (rows / rf, cols / cf)
    }

    /// Per-rank shard bytes (f32) for a global `(rows, cols)` matrix —
    /// always `rows·cols·4 / p³` for the standard layouts.
    pub fn bytes_per_rank(&self, p: usize, rows: usize, cols: usize) -> usize {
        let (r, c) = self.shard_shape(p, rows, cols);
        r * c * std::mem::size_of::<f32>()
    }

    /// `(r0, c0, shard_rows, shard_cols)` of the block owned by `coord`.
    pub fn shard_bounds(
        &self,
        cube: &Cube,
        coord: Coord,
        rows: usize,
        cols: usize,
    ) -> (usize, usize, usize, usize) {
        let p = cube.edge();
        let (sr, sc) = self.shard_shape(p, rows, cols);
        let r0 = self.row.index(p, coord) * sr;
        let c0 = self.col.index(p, coord) * sc;
        (r0, c0, sr, sc)
    }

    /// Extract the shard owned by `coord` (phantom in → phantom out).
    /// Shards are *compacted* — they own a private minimal buffer — because
    /// they are long-lived (model state); a zero-copy view here would pin
    /// the full global matrix allocation on every rank. Transient chunking
    /// on the collective hot path uses `Tensor::block`/`split_rows` views
    /// directly.
    pub fn shard_of(&self, cube: &Cube, coord: Coord, t: &Tensor) -> Tensor {
        let (rows, cols) = t.dims2();
        let (r0, c0, sr, sc) = self.shard_bounds(cube, coord, rows, cols);
        t.block(r0, c0, sr, sc).compact()
    }

    /// All shards in rank order.
    pub fn scatter(&self, cube: &Cube, t: &Tensor) -> Vec<Tensor> {
        (0..cube.size())
            .map(|r| self.shard_of(cube, cube.coord_of(r), t))
            .collect()
    }

    /// Reassemble the global `(rows, cols)` matrix from shards in rank
    /// order. Any phantom shard makes the result phantom.
    pub fn gather(&self, cube: &Cube, shards: &[Tensor], rows: usize, cols: usize) -> Tensor {
        if shards.iter().any(|s| s.is_phantom()) {
            assert_eq!(shards.len(), cube.size(), "need one shard per rank");
            return Tensor::phantom(&[rows, cols]);
        }
        let mut out = Tensor::zeros(&[rows, cols]);
        self.gather_into(cube, shards, rows, cols, &mut out);
        out
    }

    /// [`Layout3D::gather`] into a caller-supplied `(rows, cols)` buffer —
    /// the hook that lets hot-loop assembly (activation gathers) reuse a
    /// recycled pool buffer instead of a fresh allocation. All shards must
    /// be materialized.
    pub fn gather_into(
        &self,
        cube: &Cube,
        shards: &[Tensor],
        rows: usize,
        cols: usize,
        out: &mut Tensor,
    ) {
        assert_eq!(shards.len(), cube.size(), "need one shard per rank");
        assert_eq!(out.shape(), &[rows, cols], "gather_into output shape mismatch");
        for (rank, shard) in shards.iter().enumerate() {
            let coord = cube.coord_of(rank);
            let (r0, c0, sr, sc) = self.shard_bounds(cube, coord, rows, cols);
            assert_eq!(
                shard.shape(),
                &[sr, sc],
                "rank {rank} shard shape mismatch for layout {:?}",
                self
            );
            out.set_block(r0, c0, shard);
        }
    }
}

// ---------------------------------------------------------------------
// Diagonal vectors (Algorithms 7/8 storage)
// ---------------------------------------------------------------------

/// Diagonal storage for a length-`n` vector under directions `d`: ranks
/// with `coord(a) == coord(c)` each own the `n/p²` chunk at offset
/// `coord(c)·(n/p) + coord(b)·(n/p²)`; everyone else owns nothing.
#[derive(Clone, Copy, Debug)]
pub struct DiagVec3D {
    /// The direction triple whose `a == c` diagonal owns the chunks.
    pub dirs: Dirs,
}

impl DiagVec3D {
    /// Diagonal storage under the given direction triple.
    pub fn for_dirs(dirs: Dirs) -> DiagVec3D {
        DiagVec3D { dirs }
    }

    /// Does `coord` own a chunk (is it on the `a == c` diagonal)?
    pub fn owns(&self, c: Coord) -> bool {
        c.axis(self.dirs.a) == c.axis(self.dirs.c)
    }

    fn chunk_range(&self, p: usize, n: usize, c: Coord) -> (usize, usize) {
        assert_eq!(n % (p * p), 0, "vector len {n} not divisible by p² = {}", p * p);
        let chunk = n / (p * p);
        let off = c.axis(self.dirs.c) * (n / p) + c.axis(self.dirs.b) * chunk;
        (off, chunk)
    }

    /// This coord's chunk, or `None` off the diagonal.
    pub fn shard_of(&self, cube: &Cube, coord: Coord, v: &Tensor) -> Option<Tensor> {
        if !self.owns(coord) {
            return None;
        }
        let p = cube.edge();
        let n = v.numel();
        let (off, chunk) = self.chunk_range(p, n, coord);
        if v.is_phantom() {
            return Some(Tensor::phantom(&[chunk]));
        }
        Some(
            v.reshape(&[1, n])
                .block(0, off, 1, chunk)
                .into_reshape(&[chunk])
                .compact(),
        )
    }

    /// Per-rank chunks in rank order (`None` off the diagonal).
    pub fn scatter(&self, cube: &Cube, v: &Tensor) -> Vec<Option<Tensor>> {
        (0..cube.size())
            .map(|r| self.shard_of(cube, cube.coord_of(r), v))
            .collect()
    }

    /// Reassemble the global vector from per-rank chunks.
    pub fn gather(&self, cube: &Cube, shards: &[Option<Tensor>], n: usize) -> Tensor {
        assert_eq!(shards.len(), cube.size(), "need one entry per rank");
        let p = cube.edge();
        let mut out = vec![0.0f32; n];
        let mut covered = 0usize;
        for (rank, s) in shards.iter().enumerate() {
            let coord = cube.coord_of(rank);
            match s {
                Some(t) => {
                    assert!(self.owns(coord), "rank {rank} is off-diagonal but has a chunk");
                    let (off, chunk) = self.chunk_range(p, n, coord);
                    assert_eq!(t.numel(), chunk, "rank {rank} chunk length mismatch");
                    out[off..off + chunk].copy_from_slice(t.data());
                    covered += chunk;
                }
                None => {
                    assert!(!self.owns(coord), "rank {rank} is on-diagonal but has no chunk");
                }
            }
        }
        assert_eq!(covered, n, "diagonal chunks do not cover the vector");
        Tensor::from_vec(&[n], out)
    }
}

// ---------------------------------------------------------------------
// 1-D (Megatron) and 2-D (SUMMA) layouts
// ---------------------------------------------------------------------

/// Megatron weight sharding along one dimension of a rank-2 tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Layout1D {
    /// Split columns `P` ways (column-parallel linear weights).
    ColShard,
    /// Split rows `P` ways (row-parallel linear weights).
    RowShard,
}

impl Layout1D {
    /// The shard owned by `rank` of `world` (compacted — see
    /// [`Layout3D::shard_of`] for why shards own their buffers).
    pub fn shard_of(&self, world: usize, rank: usize, t: &Tensor) -> Tensor {
        let (r, c) = t.dims2();
        match self {
            Layout1D::ColShard => {
                assert_eq!(c % world, 0, "cols {c} not divisible by world {world}");
                t.block(0, rank * (c / world), r, c / world)
            }
            Layout1D::RowShard => {
                assert_eq!(r % world, 0, "rows {r} not divisible by world {world}");
                t.block(rank * (r / world), 0, r / world, c)
            }
        }
        .compact()
    }

    /// All shards in rank order.
    pub fn scatter(&self, world: usize, t: &Tensor) -> Vec<Tensor> {
        (0..world).map(|rank| self.shard_of(world, rank, t)).collect()
    }

    /// Reassemble from shards in rank order.
    pub fn gather(&self, parts: &[Tensor]) -> Tensor {
        match self {
            Layout1D::ColShard => Tensor::concat_cols(parts),
            Layout1D::RowShard => Tensor::concat_rows(parts),
        }
    }
}

/// Optimus/SUMMA block distribution: rank `(i, j)` of the `q × q` mesh
/// owns block `(i, j)` of every `(R/q, C/q)` blocking.
#[derive(Clone, Copy, Debug)]
pub struct Layout2D;

impl Layout2D {
    /// The `(R/q, C/q)` block owned by `rank` (compacted — see
    /// [`Layout3D::shard_of`]).
    pub fn shard_of(mesh: &Mesh, rank: usize, t: &Tensor) -> Tensor {
        let q = mesh.edge();
        let (r, c) = t.dims2();
        assert_eq!(r % q, 0, "rows {r} not divisible by mesh edge {q}");
        assert_eq!(c % q, 0, "cols {c} not divisible by mesh edge {q}");
        let (row, col) = mesh.coord_of(rank);
        t.block(row * (r / q), col * (c / q), r / q, c / q).compact()
    }

    /// All blocks in rank order.
    pub fn scatter(mesh: &Mesh, t: &Tensor) -> Vec<Tensor> {
        (0..mesh.size()).map(|rank| Self::shard_of(mesh, rank, t)).collect()
    }

    /// Reassemble the global `(rows, cols)` matrix from blocks in rank
    /// order. Any phantom block makes the result phantom.
    pub fn gather(mesh: &Mesh, parts: &[Tensor], rows: usize, cols: usize) -> Tensor {
        if parts.iter().any(|p| p.is_phantom()) {
            assert_eq!(parts.len(), mesh.size(), "need one block per rank");
            return Tensor::phantom(&[rows, cols]);
        }
        let mut out = Tensor::zeros(&[rows, cols]);
        Self::gather_into(mesh, parts, rows, cols, &mut out);
        out
    }

    /// [`Layout2D::gather`] into a caller-supplied `(rows, cols)` buffer
    /// (see [`Layout3D::gather_into`]). All blocks must be materialized.
    pub fn gather_into(
        mesh: &Mesh,
        parts: &[Tensor],
        rows: usize,
        cols: usize,
        out: &mut Tensor,
    ) {
        assert_eq!(parts.len(), mesh.size(), "need one block per rank");
        assert_eq!(out.shape(), &[rows, cols], "gather_into output shape mismatch");
        let q = mesh.edge();
        assert_eq!(rows % q, 0);
        assert_eq!(cols % q, 0);
        let (br, bc) = (rows / q, cols / q);
        for (rank, part) in parts.iter().enumerate() {
            let (row, col) = mesh.coord_of(rank);
            assert_eq!(part.shape(), &[br, bc], "rank {rank} block shape mismatch");
            out.set_block(row * br, col * bc, part);
        }
    }
}

// ---------------------------------------------------------------------
// The unified layout algebra: ShardSpec + DistTensor
// ---------------------------------------------------------------------

/// Which linear of a residual branch a weight belongs to. The transformer
/// block has exactly two linears per branch (QKV→proj, fc1→fc2); every
/// parallelism exploits that pairing:
///
/// * 1-D: `Expand` weights are column-sharded (no forward comm), `Reduce`
///   weights row-sharded (one all-reduce) — the Megatron pattern;
/// * 3-D: `Expand` runs under the block-entry directions `d0`, `Reduce`
///   under `d0.swapped()`, returning the activation to its entry layout
///   (§3.2's direction flip);
/// * Seq and 2-D treat both stages identically.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stage {
    /// First linear of a branch (hidden → wider): `w_qkv`, `w_fc1`.
    Expand,
    /// Second linear of a branch (back to hidden): `w_proj`, `w_fc2`.
    Reduce,
}

/// Which kind of per-column vector a parameter is — determines its owner
/// set and chunking under each mesh.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum VecRole {
    /// Bias of an `Expand` linear (`b_qkv`, `b_fc1`): lives where that
    /// layer's *output* lives (1-D: column chunks; 3-D: diagonal of
    /// `d0.swapped()`).
    ExpandBias,
    /// Bias of a `Reduce` linear (`b_proj`, `b_fc2`): output is in the
    /// block-entry layout (1-D: replicated; 3-D: diagonal of `d0`).
    ReduceBias,
    /// Layernorm γ/β: applied to block-entry-layout activations (same
    /// placement as `ReduceBias`; 2-D keeps all vectors on mesh row 0).
    Norm,
}

/// The device-mesh shape of one parallelism point. `Cube` carries the
/// block-entry direction triple `d0`.
///
/// ## The 2.5-D Tesseract mesh (`Tess`)
///
/// A `p × p × d` mesh: `d` depth layers, each holding a SUMMA `p × p`
/// grid. Activations are block-distributed over the grid and **replicated
/// across depth layers**; weights shard **across** depth — each layer owns
/// `1/d` of a stage's weight (the `Expand` weight column-slabbed, the
/// `Reduce` weight row-slabbed, Megatron-style along the depth axis) and
/// 2-D blocks it over its grid. One depth all-reduce closes each residual
/// branch forward (after the `Reduce` linear) and one backward (after the
/// `Expand` input gradient).
///
/// Memory/communication trade-off at equal world size `P` (per rank, one
/// `M×N` activation and an `N×K` weight; the §Comparison axis between
/// Optimus and the paper's 3-D):
///
/// | mesh               | weight mem | activation mem | matmul comm volume  |
/// |--------------------|------------|----------------|---------------------|
/// | 2-D (`q²=P`)       | `NK/P`     | `MN/P`         | `O(1/√P)` broadcasts |
/// | 2.5-D (`p²d=P`)    | `NK/P`     | `MN/p²` (d×)   | `O(1/(p√d))` + depth all-reduce `O(MK/p²)` |
/// | 3-D (`p³=P`)       | `NK/P`     | `MN/P`         | `O(P^{-2/3})`       |
///
/// Growing `d` at fixed `P` shrinks the SUMMA grid (fewer, larger panel
/// broadcasts and cheaper weight-side traffic) at the cost of `d`-fold
/// activation replication — exactly Tesseract's knob between 2-D (`d = 1`)
/// and activation-light 3-D.
///
/// ## The hybrid data×tensor mesh (`Hybrid`)
///
/// `r` data-parallel replicas around *any* inner tensor mesh. Batch rows
/// split across replicas (each replica computes `1/r` of the batch on its
/// own copy of the weights, sharded by the inner mesh); weight and vector
/// gradients are all-reduced over the replica groups at the block-backward
/// weight-grad boundary, so replicas stay bit-consistent. This is the
/// Megatron-LM-style outer data-parallel group (Narayanan et al.) as one
/// more leaf of the same spectrum.
#[derive(Clone, Debug)]
pub enum MeshSpec {
    /// Single device (the dense `Seq` reference).
    Point,
    /// `P`-rank line (1-D Megatron).
    Line(usize),
    /// `q × q` mesh (2-D Optimus/SUMMA).
    Grid(Mesh),
    /// `p³` cube with block-entry directions (the paper's 3-D).
    Cube(Cube, Dirs),
    /// `p × p × d` Tesseract: `d` depth layers of SUMMA `p × p` grids
    /// (2.5-D). Rank layout: `rank = layer·p² + grid_rank` (grid row-major).
    Tess(Mesh, usize),
    /// `r` data-parallel replicas around an inner tensor mesh. Rank layout:
    /// `rank = replica·inner_world + inner_rank`. The inner mesh must be a
    /// tensor mesh (`Line`/`Grid`/`Cube`/`Tess`) — no nesting, no `Point`.
    Hybrid(usize, Box<MeshSpec>),
    /// `stages` pipeline stage groups around an inner mesh, streaming
    /// `micro_batches` micro-batches: `Pipeline(stages, micro_batches,
    /// inner)`. Rank layout: `rank = stage·inner_world + inner_rank`. The
    /// layer partition lives *above* the spec (each stage group runs its
    /// contiguous layer slice on an identical inner layout), so every
    /// placement question delegates to the inner mesh at `rank %
    /// inner_world` — activations replicate across stage groups exactly as
    /// weights replicate across hybrid replicas. The inner mesh may be any
    /// tensor mesh or a `Hybrid` (PP × DP × TP), but not `Point` and not
    /// another pipeline.
    Pipeline(usize, usize, Box<MeshSpec>),
}

impl MeshSpec {
    /// Total ranks of this mesh.
    pub fn world(&self) -> usize {
        match self {
            MeshSpec::Point => 1,
            MeshSpec::Line(p) => *p,
            MeshSpec::Grid(mesh) => mesh.size(),
            MeshSpec::Cube(cube, _) => cube.size(),
            MeshSpec::Tess(mesh, d) => mesh.size() * d,
            MeshSpec::Hybrid(r, inner) => r * inner.world(),
            MeshSpec::Pipeline(s, _, inner) => s * inner.world(),
        }
    }
}

/// The inner mesh of a hybrid decomposition for a given edge parameter
/// (shared with [`ShardSpec::for_parallelism`] so the two cannot drift).
pub fn mesh_for_inner(inner: HybridInner, edge: usize) -> MeshSpec {
    match inner {
        HybridInner::OneD => MeshSpec::Line(edge),
        HybridInner::TwoD => MeshSpec::Grid(Mesh::new(edge)),
        HybridInner::ThreeD => MeshSpec::Cube(Cube::new(edge), Dirs::canonical()),
        HybridInner::TwoFiveD { depth } => MeshSpec::Tess(Mesh::new(edge), depth),
    }
}

/// The per-stage inner mesh of a pipeline decomposition for a given edge
/// parameter (shared with [`ShardSpec::for_parallelism`]).
pub fn mesh_for_pipeline_inner(inner: PipelineInner, edge: usize) -> MeshSpec {
    match inner {
        PipelineInner::OneD => MeshSpec::Line(edge),
        PipelineInner::TwoD => MeshSpec::Grid(Mesh::new(edge)),
        PipelineInner::ThreeD => MeshSpec::Cube(Cube::new(edge), Dirs::canonical()),
        PipelineInner::TwoFiveD { depth } => MeshSpec::Tess(Mesh::new(edge), depth),
        PipelineInner::Hybrid { replicas, inner } => {
            MeshSpec::Hybrid(replicas, Box::new(mesh_for_inner(inner, edge)))
        }
    }
}

/// One rank's complete layout knowledge: the mesh and its position on it.
/// This is the generalization of `Layout1D/2D/3D/DiagVec3D` the model is
/// written against — every shard/assemble question for weights, vectors
/// and activations is answered here, so adding a parallelism never forks
/// the model code.
#[derive(Clone, Debug)]
pub struct ShardSpec {
    /// The mesh every rank of this parallelism agrees on.
    pub mesh: MeshSpec,
    /// This rank's flat index on the mesh.
    pub rank: usize,
}

impl ShardSpec {
    /// The dense single-device spec (the parity reference).
    pub fn seq() -> ShardSpec {
        ShardSpec { mesh: MeshSpec::Point, rank: 0 }
    }

    /// 1-D (Megatron) spec over a `world`-rank line.
    pub fn oned(world: usize, rank: usize) -> ShardSpec {
        assert!(rank < world);
        ShardSpec { mesh: MeshSpec::Line(world), rank }
    }

    /// 2-D (SUMMA) spec on a `q × q` grid.
    pub fn twod(q: usize, rank: usize) -> ShardSpec {
        let mesh = Mesh::new(q);
        assert!(rank < mesh.size());
        ShardSpec { mesh: MeshSpec::Grid(mesh), rank }
    }

    /// 3-D spec under the canonical block-entry directions.
    pub fn threed(p: usize, rank: usize) -> ShardSpec {
        Self::threed_with_dirs(p, rank, Dirs::canonical())
    }

    /// 3-D spec on a `p³` cube with explicit block-entry directions.
    pub fn threed_with_dirs(p: usize, rank: usize, d0: Dirs) -> ShardSpec {
        d0.assert_distinct();
        let cube = Cube::new(p);
        assert!(rank < cube.size());
        ShardSpec { mesh: MeshSpec::Cube(cube, d0), rank }
    }

    /// 2.5-D Tesseract spec: `d` depth layers of `p × p` SUMMA grids.
    pub fn twofived(p: usize, d: usize, rank: usize) -> ShardSpec {
        assert!(p >= 1 && d >= 1, "2.5-D mesh needs p >= 1 and depth >= 1");
        let mesh = Mesh::new(p);
        assert!(rank < mesh.size() * d);
        ShardSpec { mesh: MeshSpec::Tess(mesh, d), rank }
    }

    /// Hybrid spec: `replicas` data-parallel copies of `inner` (which must
    /// be a tensor mesh — `Line`/`Grid`/`Cube`/`Tess`).
    pub fn hybrid(replicas: usize, inner: MeshSpec, rank: usize) -> ShardSpec {
        assert!(replicas >= 1, "hybrid needs at least one replica");
        assert!(
            !matches!(inner, MeshSpec::Point | MeshSpec::Hybrid(..)),
            "hybrid inner must be a tensor mesh (no Point, no nesting)"
        );
        assert!(rank < replicas * inner.world());
        ShardSpec { mesh: MeshSpec::Hybrid(replicas, Box::new(inner)), rank }
    }

    /// Pipeline spec: `stages` stage groups around `inner` (any tensor
    /// mesh or a `Hybrid` — no `Point`, no nested pipeline), streaming
    /// `micro_batches` micro-batches per step.
    pub fn pipeline(
        stages: usize,
        micro_batches: usize,
        inner: MeshSpec,
        rank: usize,
    ) -> ShardSpec {
        assert!(stages >= 1, "pipeline needs at least one stage");
        assert!(micro_batches >= 1, "pipeline needs at least one micro-batch");
        assert!(
            !matches!(inner, MeshSpec::Point | MeshSpec::Pipeline(..)),
            "pipeline inner must be a tensor mesh or Hybrid (no Point, no nesting)"
        );
        assert!(rank < stages * inner.world());
        ShardSpec { mesh: MeshSpec::Pipeline(stages, micro_batches, Box::new(inner)), rank }
    }

    /// Spec for `rank` of the given parallelism/edge (the constructor the
    /// dispatcher uses).
    pub fn for_parallelism(par: Parallelism, edge: usize, rank: usize) -> ShardSpec {
        match par {
            Parallelism::Seq => Self::seq(),
            Parallelism::OneD => Self::oned(edge, rank),
            Parallelism::TwoD => Self::twod(edge, rank),
            Parallelism::ThreeD => Self::threed(edge, rank),
            Parallelism::TwoFiveD { depth } => Self::twofived(edge, depth, rank),
            Parallelism::Hybrid { replicas, inner } => {
                Self::hybrid(replicas, mesh_for_inner(inner, edge), rank)
            }
            Parallelism::Pipeline { stages, micro_batches, inner } => {
                Self::pipeline(stages, micro_batches, mesh_for_pipeline_inner(inner, edge), rank)
            }
        }
    }

    /// The [`Parallelism`] kind this spec describes (inverse of
    /// [`ShardSpec::for_parallelism`]).
    pub fn kind(&self) -> Parallelism {
        match &self.mesh {
            MeshSpec::Point => Parallelism::Seq,
            MeshSpec::Line(_) => Parallelism::OneD,
            MeshSpec::Grid(_) => Parallelism::TwoD,
            MeshSpec::Cube(..) => Parallelism::ThreeD,
            MeshSpec::Tess(_, d) => Parallelism::TwoFiveD { depth: *d },
            MeshSpec::Hybrid(r, inner) => {
                let inner = match inner.as_ref() {
                    MeshSpec::Line(_) => HybridInner::OneD,
                    MeshSpec::Grid(_) => HybridInner::TwoD,
                    MeshSpec::Cube(..) => HybridInner::ThreeD,
                    MeshSpec::Tess(_, d) => HybridInner::TwoFiveD { depth: *d },
                    MeshSpec::Point | MeshSpec::Hybrid(..) | MeshSpec::Pipeline(..) => {
                        unreachable!("constructor rejects Point/Hybrid/Pipeline inners")
                    }
                };
                Parallelism::Hybrid { replicas: *r, inner }
            }
            MeshSpec::Pipeline(s, m, inner) => {
                let inner = match inner.as_ref() {
                    MeshSpec::Line(_) => PipelineInner::OneD,
                    MeshSpec::Grid(_) => PipelineInner::TwoD,
                    MeshSpec::Cube(..) => PipelineInner::ThreeD,
                    MeshSpec::Tess(_, d) => PipelineInner::TwoFiveD { depth: *d },
                    MeshSpec::Hybrid(r, hinner) => PipelineInner::Hybrid {
                        replicas: *r,
                        inner: match hinner.as_ref() {
                            MeshSpec::Line(_) => HybridInner::OneD,
                            MeshSpec::Grid(_) => HybridInner::TwoD,
                            MeshSpec::Cube(..) => HybridInner::ThreeD,
                            MeshSpec::Tess(_, d) => HybridInner::TwoFiveD { depth: *d },
                            MeshSpec::Point | MeshSpec::Hybrid(..) | MeshSpec::Pipeline(..) => {
                                unreachable!("constructor rejects Point/Hybrid/Pipeline inners")
                            }
                        },
                    },
                    MeshSpec::Point | MeshSpec::Pipeline(..) => {
                        unreachable!("constructor rejects Point/Pipeline inners")
                    }
                };
                Parallelism::Pipeline { stages: *s, micro_batches: *m, inner }
            }
        }
    }

    /// Total ranks on the mesh.
    pub fn world(&self) -> usize {
        self.mesh.world()
    }

    /// `(layer, grid_row, grid_col)` of this rank on a Tess mesh.
    fn tess_coords(&self) -> (usize, usize, usize) {
        let MeshSpec::Tess(mesh, _) = &self.mesh else {
            panic!("tess_coords on a non-Tess mesh");
        };
        let (row, col) = mesh.coord_of(self.rank % mesh.size());
        (self.rank / mesh.size(), row, col)
    }

    /// `(replica, inner spec)` of this rank on a hybrid mesh.
    fn hybrid_parts(&self) -> (usize, ShardSpec) {
        let MeshSpec::Hybrid(_, inner) = &self.mesh else {
            panic!("hybrid_parts on a non-hybrid mesh");
        };
        let iw = inner.world();
        (self.rank / iw, ShardSpec { mesh: inner.as_ref().clone(), rank: self.rank % iw })
    }

    /// `(stage, inner spec)` of this rank on a pipeline mesh. The layer
    /// partition lives above the spec, so every placement question
    /// delegates to the inner spec — stage groups are layout-identical.
    fn pipeline_parts(&self) -> (usize, ShardSpec) {
        let MeshSpec::Pipeline(_, _, inner) = &self.mesh else {
            panic!("pipeline_parts on a non-pipeline mesh");
        };
        let iw = inner.world();
        (self.rank / iw, ShardSpec { mesh: inner.as_ref().clone(), rank: self.rank % iw })
    }

    /// How the mesh divides attention heads: the column-split factor of an
    /// `Expand` weight (1-D: `P`; 2-D/3-D: the edge; 2.5-D: `depth·p` —
    /// depth slabs of grid-blocked columns; hybrid: the inner divisor).
    pub fn head_divisor(&self) -> usize {
        match &self.mesh {
            MeshSpec::Point => 1,
            MeshSpec::Line(p) => *p,
            MeshSpec::Grid(mesh) => mesh.edge(),
            MeshSpec::Cube(cube, _) => cube.edge(),
            MeshSpec::Tess(mesh, d) => mesh.edge() * d,
            MeshSpec::Hybrid(_, _) => self.hybrid_parts().1.head_divisor(),
            // Stages split layers, never heads.
            MeshSpec::Pipeline(..) => self.pipeline_parts().1.head_divisor(),
        }
    }

    /// Attention heads one rank computes locally: `heads / head_divisor()`.
    /// Panics when the mesh does not divide `heads` — silently truncating
    /// here would drop heads; `ModelConfig::validate` reports the same
    /// condition as a plan-level error before any rank gets this far.
    pub fn local_heads(&self, heads: usize) -> usize {
        let div = self.head_divisor();
        assert_eq!(
            heads % div,
            0,
            "heads {heads} not divisible by head divisor {div} of {:?}",
            self.kind()
        );
        heads / div
    }

    /// How many full copies of a weight the whole mesh stores (1 for every
    /// pure tensor mesh; `r` per hybrid level — data-parallel replicas each
    /// hold a complete inner-sharded copy). The cross-parallelism tests use
    /// this to assert exact tiling in the presence of replication.
    pub fn weight_replicas(&self) -> usize {
        match &self.mesh {
            MeshSpec::Hybrid(r, _) => r * self.hybrid_parts().1.weight_replicas(),
            // Dist-level view: the layer partition lives above the spec, so
            // sharding one tensor across the whole pipeline mesh lands one
            // inner-sharded copy per stage group. (Per-layer, the engine
            // materializes it only on the owning stage — the parity tests
            // restrict to that stage group's ranks.)
            MeshSpec::Pipeline(s, _, _) => s * self.pipeline_parts().1.weight_replicas(),
            _ => 1,
        }
    }

    /// Does this mesh shard activations? (`false` = replicated: Seq, 1-D.
    /// Tess shards over its grids; hybrid always shards batch rows.)
    pub fn shards_activation(&self) -> bool {
        match &self.mesh {
            MeshSpec::Grid(_) | MeshSpec::Cube(..) | MeshSpec::Tess(..) | MeshSpec::Hybrid(..) => {
                true
            }
            // Stage groups replicate the activation layout; whether it is
            // sharded within a group is the inner mesh's call.
            MeshSpec::Pipeline(..) => self.pipeline_parts().1.shards_activation(),
            MeshSpec::Point | MeshSpec::Line(_) => false,
        }
    }

    /// Shape of this rank's shard of a global `(rows, cols)` activation.
    pub fn activation_shape(&self, rows: usize, cols: usize) -> (usize, usize) {
        match &self.mesh {
            MeshSpec::Point | MeshSpec::Line(_) => (rows, cols),
            MeshSpec::Grid(mesh) => {
                let q = mesh.edge();
                (rows / q, cols / q)
            }
            MeshSpec::Cube(cube, _) => {
                let p = cube.edge();
                (rows / (p * p), cols / p)
            }
            // Depth layers replicate the grid-blocked activation.
            MeshSpec::Tess(mesh, _) => {
                let p = mesh.edge();
                (rows / p, cols / p)
            }
            // Replicas split batch rows; the inner mesh shards the rest.
            MeshSpec::Hybrid(r, _) => {
                let (_, inner) = self.hybrid_parts();
                inner.activation_shape(rows / r, cols)
            }
            // Every stage group sees the full (micro-)batch under the
            // inner layout.
            MeshSpec::Pipeline(..) => self.pipeline_parts().1.activation_shape(rows, cols),
        }
    }

    /// Activation rows this rank holds out of `rows` global batch rows —
    /// the row half of [`ShardSpec::activation_shape`], independent of the
    /// column count. Serving sizes per-rank KV-cache pools from this (the
    /// decode grid has one row per batch slot), and the cost model uses it
    /// for KV-bytes-per-rank forms.
    pub fn activation_rows(&self, rows: usize) -> usize {
        // Column width never affects row sharding; 1 is a unit width.
        self.activation_shape(rows, 1).0
    }

    /// `(r0, c0, shard_rows, shard_cols)` of this rank's activation window
    /// in the global `(rows, cols)` matrix. Panics for replicated meshes
    /// (there is no window — the whole matrix is local).
    pub fn activation_bounds(&self, rows: usize, cols: usize) -> (usize, usize, usize, usize) {
        match &self.mesh {
            MeshSpec::Point | MeshSpec::Line(_) => {
                panic!("replicated activations have no shard window")
            }
            MeshSpec::Grid(mesh) => {
                let q = mesh.edge();
                assert_eq!(rows % q, 0);
                assert_eq!(cols % q, 0);
                let (row, col) = mesh.coord_of(self.rank);
                let (sr, sc) = (rows / q, cols / q);
                (row * sr, col * sc, sr, sc)
            }
            MeshSpec::Cube(cube, d0) => Layout3D::input(*d0).shard_bounds(
                cube,
                cube.coord_of(self.rank),
                rows,
                cols,
            ),
            MeshSpec::Tess(mesh, _) => {
                let p = mesh.edge();
                assert_eq!(rows % p, 0);
                assert_eq!(cols % p, 0);
                let (_, row, col) = self.tess_coords();
                let (sr, sc) = (rows / p, cols / p);
                (row * sr, col * sc, sr, sc)
            }
            MeshSpec::Hybrid(r, _) => {
                assert_eq!(rows % r, 0, "rows {rows} not divisible by replicas {r}");
                let (replica, inner) = self.hybrid_parts();
                let slab = rows / r;
                let (r0, c0, sr, sc) = if inner.shards_activation() {
                    inner.activation_bounds(slab, cols)
                } else {
                    (0, 0, slab, cols)
                };
                (replica * slab + r0, c0, sr, sc)
            }
            MeshSpec::Pipeline(..) => self.pipeline_parts().1.activation_bounds(rows, cols),
        }
    }

    /// This rank's shard of a global activation (compacted; replicated
    /// meshes return a handle on the global).
    pub fn shard_activation(&self, global: &Tensor) -> Tensor {
        if !self.shards_activation() {
            return global.clone();
        }
        let (rows, cols) = global.dims2();
        let (r0, c0, sr, sc) = self.activation_bounds(rows, cols);
        global.block(r0, c0, sr, sc).compact()
    }

    /// Reassemble the global `(rows, cols)` activation from all ranks'
    /// shards in rank order (replicated meshes: the shards *are* the
    /// global — returns shard 0; Tess uses depth layer 0's grid; hybrid
    /// stacks the replicas' row slabs).
    pub fn assemble_activation(&self, parts: &[Tensor], rows: usize, cols: usize) -> Tensor {
        match &self.mesh {
            MeshSpec::Point | MeshSpec::Line(_) => parts[0].clone(),
            MeshSpec::Grid(mesh) => Layout2D::gather(mesh, parts, rows, cols),
            MeshSpec::Cube(cube, d0) => {
                Layout3D::input(*d0).gather(cube, parts, rows, cols)
            }
            MeshSpec::Tess(mesh, d) => {
                assert_eq!(parts.len(), mesh.size() * d, "need one shard per rank");
                Layout2D::gather(mesh, &parts[..mesh.size()], rows, cols)
            }
            MeshSpec::Hybrid(r, inner) => {
                let iw = inner.world();
                assert_eq!(parts.len(), r * iw, "need one shard per rank");
                assert_eq!(rows % r, 0);
                let slab = rows / r;
                let inner0 = ShardSpec { mesh: inner.as_ref().clone(), rank: 0 };
                let slabs: Vec<Tensor> = (0..*r)
                    .map(|k| {
                        inner0.assemble_activation(&parts[k * iw..(k + 1) * iw], slab, cols)
                    })
                    .collect();
                Tensor::concat_rows(&slabs)
            }
            // Stage groups are activation-layout replicas: stage 0's group
            // carries a full copy.
            MeshSpec::Pipeline(s, _, inner) => {
                let iw = inner.world();
                assert_eq!(parts.len(), s * iw, "need one shard per rank");
                let inner0 = ShardSpec { mesh: inner.as_ref().clone(), rank: 0 };
                inner0.assemble_activation(&parts[..iw], rows, cols)
            }
        }
    }

    /// [`ShardSpec::assemble_activation`] into a caller-supplied buffer —
    /// the pooled-assembly hook of the activation gather. Sharding meshes
    /// only; all parts must be materialized.
    pub fn assemble_activation_into(
        &self,
        parts: &[Tensor],
        rows: usize,
        cols: usize,
        out: &mut Tensor,
    ) {
        match &self.mesh {
            MeshSpec::Point | MeshSpec::Line(_) => {
                panic!("replicated activations need no assembly")
            }
            MeshSpec::Grid(mesh) => Layout2D::gather_into(mesh, parts, rows, cols, out),
            MeshSpec::Cube(cube, d0) => {
                Layout3D::input(*d0).gather_into(cube, parts, rows, cols, out)
            }
            MeshSpec::Tess(mesh, d) => {
                assert_eq!(parts.len(), mesh.size() * d, "need one shard per rank");
                Layout2D::gather_into(mesh, &parts[..mesh.size()], rows, cols, out)
            }
            MeshSpec::Hybrid(r, inner) => {
                let iw = inner.world();
                assert_eq!(parts.len(), r * iw, "need one shard per rank");
                assert_eq!(out.shape(), &[rows, cols], "gather_into output shape mismatch");
                assert_eq!(rows % r, 0);
                let slab = rows / r;
                // Write every shard straight into its window of `out` —
                // no intermediate slab assembly, keeping this per-step
                // gather path allocation-free like the other arms. One
                // stack-only inner-spec clone; replicated inners only need
                // their first rank's (identical) slab.
                let mut ispec = ShardSpec { mesh: inner.as_ref().clone(), rank: 0 };
                let inner_shards = ispec.shards_activation();
                for (rank, part) in parts.iter().enumerate() {
                    if !inner_shards && rank % iw != 0 {
                        continue;
                    }
                    let replica = rank / iw;
                    let (r0, c0, sr, sc) = if inner_shards {
                        ispec.rank = rank % iw;
                        ispec.activation_bounds(slab, cols)
                    } else {
                        (0, 0, slab, cols)
                    };
                    assert_eq!(part.shape(), &[sr, sc], "rank {rank} shard shape mismatch");
                    out.set_block(replica * slab + r0, c0, part);
                }
            }
            MeshSpec::Pipeline(s, _, inner) => {
                let iw = inner.world();
                assert_eq!(parts.len(), s * iw, "need one shard per rank");
                let inner0 = ShardSpec { mesh: inner.as_ref().clone(), rank: 0 };
                inner0.assemble_activation_into(&parts[..iw], rows, cols, out);
            }
        }
    }

    /// The 3-D direction triple a `stage` weight runs under (`None` off
    /// the cube).
    pub fn stage_dirs(&self, stage: Stage) -> Option<Dirs> {
        match &self.mesh {
            MeshSpec::Cube(_, d0) => Some(match stage {
                Stage::Expand => *d0,
                Stage::Reduce => d0.swapped(),
            }),
            _ => None,
        }
    }

    /// `(r0, c0, shard_rows, shard_cols)` of the `stage`-weight block a
    /// Tess rank owns in the global `(rows, cols)` weight: the `Expand`
    /// weight is column-slabbed across depth layers (each layer owns
    /// `cols/d` columns, Megatron column-parallel along depth), the
    /// `Reduce` weight row-slabbed (`rows/d` rows); the layer's slab is
    /// 2-D blocked over its grid.
    fn tess_weight_bounds(
        &self,
        stage: Stage,
        rows: usize,
        cols: usize,
    ) -> (usize, usize, usize, usize) {
        let MeshSpec::Tess(mesh, d) = &self.mesh else {
            panic!("tess_weight_bounds on a non-Tess mesh");
        };
        let p = mesh.edge();
        let (layer, row, col) = self.tess_coords();
        match stage {
            Stage::Expand => {
                assert_eq!(rows % p, 0, "weight rows {rows} not divisible by p {p}");
                assert_eq!(cols % (d * p), 0, "weight cols {cols} not divisible by d·p");
                let (sr, sc) = (rows / p, cols / (d * p));
                (row * sr, layer * (cols / d) + col * sc, sr, sc)
            }
            Stage::Reduce => {
                assert_eq!(rows % (d * p), 0, "weight rows {rows} not divisible by d·p");
                assert_eq!(cols % p, 0, "weight cols {cols} not divisible by p {p}");
                let (sr, sc) = (rows / (d * p), cols / p);
                (layer * (rows / d) + row * sr, col * sc, sr, sc)
            }
        }
    }

    /// This rank's shard of a global `stage` weight.
    pub fn shard_weight(&self, stage: Stage, w: &Tensor) -> Tensor {
        match &self.mesh {
            MeshSpec::Point => w.clone(),
            MeshSpec::Line(p) => match stage {
                Stage::Expand => Layout1D::ColShard.shard_of(*p, self.rank, w),
                Stage::Reduce => Layout1D::RowShard.shard_of(*p, self.rank, w),
            },
            MeshSpec::Grid(mesh) => Layout2D::shard_of(mesh, self.rank, w),
            MeshSpec::Cube(cube, _) => {
                let dirs = self.stage_dirs(stage).unwrap();
                Layout3D::weight(dirs).shard_of(cube, cube.coord_of(self.rank), w)
            }
            MeshSpec::Tess(..) => {
                let (rows, cols) = w.dims2();
                let (r0, c0, sr, sc) = self.tess_weight_bounds(stage, rows, cols);
                w.block(r0, c0, sr, sc).compact()
            }
            // Every replica holds a full inner-sharded copy.
            MeshSpec::Hybrid(..) => self.hybrid_parts().1.shard_weight(stage, w),
            // Per stage-local layer, the stage group shards exactly like
            // its inner mesh (which layers exist here is decided above).
            MeshSpec::Pipeline(..) => self.pipeline_parts().1.shard_weight(stage, w),
        }
    }

    /// Reassemble a global `(rows, cols)` `stage` weight from all ranks'
    /// shards in rank order (hybrid meshes reassemble from replica 0 — the
    /// other replicas hold identical copies).
    pub fn assemble_weight(
        &self,
        stage: Stage,
        parts: &[Tensor],
        rows: usize,
        cols: usize,
    ) -> Tensor {
        match &self.mesh {
            MeshSpec::Point => parts[0].clone(),
            MeshSpec::Line(_) => match stage {
                Stage::Expand => Layout1D::ColShard.gather(parts),
                Stage::Reduce => Layout1D::RowShard.gather(parts),
            },
            MeshSpec::Grid(mesh) => Layout2D::gather(mesh, parts, rows, cols),
            MeshSpec::Cube(cube, _) => {
                let dirs = self.stage_dirs(stage).unwrap();
                Layout3D::weight(dirs).gather(cube, parts, rows, cols)
            }
            MeshSpec::Tess(mesh, d) => {
                let world = mesh.size() * d;
                assert_eq!(parts.len(), world, "need one shard per rank");
                if parts.iter().any(|s| s.is_phantom()) {
                    return Tensor::phantom(&[rows, cols]);
                }
                let mut out = Tensor::zeros(&[rows, cols]);
                for (rank, shard) in parts.iter().enumerate() {
                    let spec = ShardSpec { mesh: self.mesh.clone(), rank };
                    let (r0, c0, sr, sc) = spec.tess_weight_bounds(stage, rows, cols);
                    assert_eq!(shard.shape(), &[sr, sc], "rank {rank} shard shape mismatch");
                    out.set_block(r0, c0, shard);
                }
                out
            }
            MeshSpec::Hybrid(r, inner) => {
                let iw = inner.world();
                assert_eq!(parts.len(), r * iw, "need one shard per rank");
                let inner0 = ShardSpec { mesh: inner.as_ref().clone(), rank: 0 };
                inner0.assemble_weight(stage, &parts[..iw], rows, cols)
            }
            // One stage group's shards reassemble the weight; callers pass
            // the owning stage's group (or any group, at the dist level).
            MeshSpec::Pipeline(s, _, inner) => {
                let iw = inner.world();
                assert_eq!(parts.len(), s * iw, "need one shard per rank");
                let inner0 = ShardSpec { mesh: inner.as_ref().clone(), rank: 0 };
                inner0.assemble_weight(stage, &parts[..iw], rows, cols)
            }
        }
    }

    /// Does this rank own a chunk of a `role` vector?
    /// ([`ShardSpec::shard_vector`] returns `Some` exactly when this is
    /// true: everywhere on Seq/1-D, mesh row 0 on 2-D, the role's
    /// direction diagonal on 3-D.)
    pub fn owns_vector(&self, role: VecRole) -> bool {
        match &self.mesh {
            MeshSpec::Point | MeshSpec::Line(_) => true,
            MeshSpec::Grid(mesh) => mesh.coord_of(self.rank).0 == 0,
            MeshSpec::Cube(cube, _) => {
                DiagVec3D::for_dirs(self.vec_dirs(role)).owns(cube.coord_of(self.rank))
            }
            // Grid row 0 of every depth layer (Expand biases: each layer
            // owns its own slab; Reduce/Norm vectors: replicated copies).
            MeshSpec::Tess(..) => self.tess_coords().1 == 0,
            MeshSpec::Hybrid(..) => self.hybrid_parts().1.owns_vector(role),
            MeshSpec::Pipeline(..) => self.pipeline_parts().1.owns_vector(role),
        }
    }

    /// This rank's chunk of a `role` vector (`None` when this rank owns no
    /// chunk: off mesh row 0 in 2-D, off the diagonal in 3-D).
    pub fn shard_vector(&self, role: VecRole, v: &Tensor) -> Option<Tensor> {
        let n = v.numel();
        match &self.mesh {
            MeshSpec::Point => Some(v.clone()),
            MeshSpec::Line(p) => match role {
                // Expand-linear outputs are column-sharded → so is the
                // bias; one row-vector column shard via the same Layout1D
                // the weights use.
                VecRole::ExpandBias => Some(
                    Layout1D::ColShard
                        .shard_of(*p, self.rank, &v.reshape(&[1, n]))
                        .into_reshape(&[n / p]),
                ),
                // Entry-layout activations are replicated → full vectors.
                VecRole::ReduceBias | VecRole::Norm => Some(v.clone()),
            },
            MeshSpec::Grid(mesh) => {
                let q = mesh.edge();
                let (row, col) = mesh.coord_of(self.rank);
                (row == 0).then(|| {
                    assert_eq!(n % q, 0, "vector len {n} not divisible by q = {q}");
                    v.reshape(&[1, n])
                        .block(0, col * (n / q), 1, n / q)
                        .into_reshape(&[n / q])
                        .compact()
                })
            }
            MeshSpec::Cube(cube, _) => {
                let diag = DiagVec3D::for_dirs(self.vec_dirs(role));
                diag.shard_of(cube, cube.coord_of(self.rank), v)
            }
            MeshSpec::Tess(mesh, d) => {
                let p = mesh.edge();
                let (layer, row, col) = self.tess_coords();
                (row == 0).then(|| {
                    let (off, chunk) = match role {
                        // Expand outputs are depth-slabbed → so is the bias:
                        // this layer's slab, grid-column chunk within it.
                        VecRole::ExpandBias => {
                            assert_eq!(n % (d * p), 0, "vector len {n} not divisible by d·p");
                            (layer * (n / d) + col * (n / (d * p)), n / (d * p))
                        }
                        // Entry-layout activations replicate across depth →
                        // every layer stores the same grid-chunked vector.
                        VecRole::ReduceBias | VecRole::Norm => {
                            assert_eq!(n % p, 0, "vector len {n} not divisible by p = {p}");
                            (col * (n / p), n / p)
                        }
                    };
                    v.reshape(&[1, n]).block(0, off, 1, chunk).into_reshape(&[chunk]).compact()
                })
            }
            MeshSpec::Hybrid(..) => self.hybrid_parts().1.shard_vector(role, v),
            MeshSpec::Pipeline(..) => self.pipeline_parts().1.shard_vector(role, v),
        }
    }

    /// Reassemble a length-`n` `role` vector from all ranks' chunks in
    /// rank order (`None` entries = non-owners).
    pub fn assemble_vector(&self, role: VecRole, parts: &[Option<Tensor>], n: usize) -> Tensor {
        match &self.mesh {
            MeshSpec::Point => parts[0].clone().expect("Seq rank owns every vector"),
            MeshSpec::Line(p) => match role {
                VecRole::ExpandBias => {
                    let chunks: Vec<Tensor> = parts
                        .iter()
                        .map(|c| {
                            c.clone().expect("1-D rank owns its bias chunk").reshape(&[1, n / p])
                        })
                        .collect();
                    Tensor::concat_cols(&chunks).into_reshape(&[n])
                }
                VecRole::ReduceBias | VecRole::Norm => {
                    parts[0].clone().expect("1-D replicated vector")
                }
            },
            MeshSpec::Grid(mesh) => {
                let q = mesh.edge();
                let chunks: Vec<Tensor> = (0..q)
                    .map(|col| {
                        parts[mesh.rank_of(0, col)]
                            .clone()
                            .expect("mesh row-0 rank owns its vector chunk")
                            .reshape(&[1, n / q])
                    })
                    .collect();
                Tensor::concat_cols(&chunks).into_reshape(&[n])
            }
            MeshSpec::Cube(cube, _) => {
                DiagVec3D::for_dirs(self.vec_dirs(role)).gather(cube, parts, n)
            }
            MeshSpec::Tess(mesh, d) => {
                let p = mesh.edge();
                assert_eq!(parts.len(), mesh.size() * d, "need one entry per rank");
                match role {
                    // Depth-major slabs, grid-column chunks within each.
                    VecRole::ExpandBias => {
                        let chunk = n / (d * p);
                        let chunks: Vec<Tensor> = (0..*d)
                            .flat_map(|layer| {
                                (0..p).map(move |col| layer * mesh.size() + mesh.rank_of(0, col))
                            })
                            .map(|rank| {
                                parts[rank]
                                    .clone()
                                    .expect("grid row-0 rank owns its bias chunk")
                                    .reshape(&[1, chunk])
                            })
                            .collect();
                        Tensor::concat_cols(&chunks).into_reshape(&[n])
                    }
                    // Replicated across depth: layer 0's grid row suffices.
                    VecRole::ReduceBias | VecRole::Norm => {
                        let chunks: Vec<Tensor> = (0..p)
                            .map(|col| {
                                parts[mesh.rank_of(0, col)]
                                    .clone()
                                    .expect("grid row-0 rank owns its vector chunk")
                                    .reshape(&[1, n / p])
                            })
                            .collect();
                        Tensor::concat_cols(&chunks).into_reshape(&[n])
                    }
                }
            }
            MeshSpec::Hybrid(r, inner) => {
                let iw = inner.world();
                assert_eq!(parts.len(), r * iw, "need one entry per rank");
                let inner0 = ShardSpec { mesh: inner.as_ref().clone(), rank: 0 };
                inner0.assemble_vector(role, &parts[..iw], n)
            }
            MeshSpec::Pipeline(s, _, inner) => {
                let iw = inner.world();
                assert_eq!(parts.len(), s * iw, "need one entry per rank");
                let inner0 = ShardSpec { mesh: inner.as_ref().clone(), rank: 0 };
                inner0.assemble_vector(role, &parts[..iw], n)
            }
        }
    }

    /// The direction triple a `role` vector's diagonal lives on (3-D only).
    fn vec_dirs(&self, role: VecRole) -> Dirs {
        let MeshSpec::Cube(_, d0) = &self.mesh else {
            panic!("vec_dirs is only meaningful on the cube");
        };
        match role {
            VecRole::ExpandBias => d0.swapped(),
            VecRole::ReduceBias | VecRole::Norm => *d0,
        }
    }
}

/// One rank's shard of a distributed tensor, paired with the layout it was
/// cut under — the self-describing handle used at the model boundary and by
/// the cross-parallelism parity tests. Assembly is pure: given every rank's
/// `DistTensor` (in rank order), the global tensor is reconstructed without
/// knowing which parallelism produced it.
#[derive(Clone, Debug)]
pub struct DistTensor {
    /// This rank's shard.
    pub local: Tensor,
    /// The layout that places the shard in the global tensor.
    pub spec: ShardSpec,
}

impl DistTensor {
    /// Cut this rank's activation shard from a global matrix.
    pub fn from_global_activation(spec: &ShardSpec, global: &Tensor) -> DistTensor {
        DistTensor { local: spec.shard_activation(global), spec: spec.clone() }
    }

    /// Wrap an already-local activation shard.
    pub fn from_local(spec: &ShardSpec, local: Tensor) -> DistTensor {
        DistTensor { local, spec: spec.clone() }
    }

    /// Reassemble the global `(rows, cols)` activation from every rank's
    /// handle (rank order). All parts must share one mesh shape.
    pub fn assemble_activation(parts: &[DistTensor], rows: usize, cols: usize) -> Tensor {
        assert!(!parts.is_empty());
        let spec = &parts[0].spec;
        assert_eq!(parts.len(), spec.world(), "need one shard per rank");
        let locals: Vec<Tensor> = parts.iter().map(|p| p.local.clone()).collect();
        spec.assemble_activation(&locals, rows, cols)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn randt(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        Tensor::randn(shape, 1.0, &mut rng)
    }

    #[test]
    fn canonical_dirs_match_paper_roles() {
        let d = Dirs::canonical();
        assert_eq!(d.a, Axis::Y);
        assert_eq!(d.b, Axis::X);
        assert_eq!(d.c, Axis::Z);
        d.assert_distinct();
        let s = d.swapped();
        assert_eq!(s, Dirs { a: Axis::Z, b: Axis::X, c: Axis::Y });
        assert_eq!(s.swapped(), d);
    }

    #[test]
    fn output_equals_swapped_input() {
        let d = Dirs::canonical();
        assert_eq!(Layout3D::output(d), Layout3D::input(d.swapped()));
    }

    #[test]
    fn layout3d_shard_shapes_are_balanced() {
        let d = Dirs::canonical();
        for p in [1usize, 2, 3] {
            let (rows, cols) = (p * p * 3, p * p * 5);
            for layout in [Layout3D::input(d), Layout3D::weight(d), Layout3D::output(d)] {
                let (r, c) = layout.shard_shape(p, rows, cols);
                assert_eq!(r * c * p * p * p, rows * cols, "p={p} layout {layout:?}");
                assert_eq!(layout.bytes_per_rank(p, rows, cols), r * c * 4);
            }
        }
    }

    #[test]
    fn layout3d_scatter_gather_round_trip() {
        let d = Dirs::canonical();
        let cube = Cube::new(2);
        let t = randt(&[8, 12], 1);
        for layout in [Layout3D::input(d), Layout3D::weight(d), Layout3D::output(d)] {
            let shards = layout.scatter(&cube, &t);
            assert_eq!(shards.len(), 8);
            assert_eq!(layout.gather(&cube, &shards, 8, 12), t);
        }
    }

    #[test]
    fn layout3d_phantom_flows() {
        let d = Dirs::canonical();
        let cube = Cube::new(2);
        let t = Tensor::phantom(&[8, 12]);
        let shards = Layout3D::input(d).scatter(&cube, &t);
        assert!(shards.iter().all(|s| s.is_phantom()));
        assert!(Layout3D::input(d).gather(&cube, &shards, 8, 12).is_phantom());
    }

    #[test]
    fn diag_vec_round_trip_and_ownership() {
        let cube = Cube::new(2);
        for d in [Dirs::canonical(), Dirs::canonical().swapped()] {
            let spec = DiagVec3D::for_dirs(d);
            let v = randt(&[12], 2);
            let shards = spec.scatter(&cube, &v);
            let owners = shards.iter().filter(|s| s.is_some()).count();
            assert_eq!(owners, 4, "p² diagonal owners");
            for (rank, s) in shards.iter().enumerate() {
                assert_eq!(s.is_some(), spec.owns(cube.coord_of(rank)));
                if let Some(t) = s {
                    assert_eq!(t.numel(), 12 / 4);
                }
            }
            assert_eq!(spec.gather(&cube, &shards, 12), v);
        }
    }

    #[test]
    fn layout1d_round_trips_both_ways() {
        let t = randt(&[6, 8], 3);
        for layout in [Layout1D::ColShard, Layout1D::RowShard] {
            let parts = layout.scatter(2, &t);
            assert_eq!(parts.len(), 2);
            assert_eq!(layout.gather(&parts), t);
            assert_eq!(parts[1], layout.shard_of(2, 1, &t));
        }
    }

    #[test]
    fn layout2d_round_trip() {
        let mesh = Mesh::new(2);
        let t = randt(&[8, 6], 4);
        let parts = Layout2D::scatter(&mesh, &t);
        assert_eq!(parts.len(), 4);
        assert_eq!(parts[3], t.block(4, 3, 4, 3));
        assert_eq!(Layout2D::gather(&mesh, &parts, 8, 6), t);
    }

    fn all_specs() -> Vec<Vec<ShardSpec>> {
        vec![
            vec![ShardSpec::seq()],
            (0..4).map(|r| ShardSpec::oned(4, r)).collect(),
            (0..4).map(|r| ShardSpec::twod(2, r)).collect(),
            (0..8).map(|r| ShardSpec::threed(2, r)).collect(),
            (0..8).map(|r| ShardSpec::twofived(2, 2, r)).collect(),
            (0..4).map(|r| ShardSpec::hybrid(2, MeshSpec::Line(2), r)).collect(),
            (0..4).map(|r| ShardSpec::pipeline(2, 4, MeshSpec::Line(2), r)).collect(),
            (0..8).map(|r| ShardSpec::pipeline(2, 4, MeshSpec::Grid(Mesh::new(2)), r)).collect(),
        ]
    }

    #[test]
    fn shard_spec_weight_round_trips_every_mesh_and_stage() {
        let w = randt(&[8, 16], 10);
        for ranks in all_specs() {
            for stage in [Stage::Expand, Stage::Reduce] {
                let parts: Vec<Tensor> =
                    ranks.iter().map(|s| s.shard_weight(stage, &w)).collect();
                let total: usize = parts.iter().map(|p| p.numel()).sum();
                // Pure tensor meshes tile the weight exactly once; hybrid
                // meshes store one full copy per data-parallel replica.
                assert_eq!(
                    total,
                    w.numel() * ranks[0].weight_replicas(),
                    "{:?} {stage:?} must tile exactly (× replicas)",
                    ranks[0].mesh
                );
                let back = ranks[0].assemble_weight(stage, &parts, 8, 16);
                assert_eq!(back, w, "{:?} {stage:?}", ranks[0].mesh);
            }
        }
    }

    #[test]
    fn shard_spec_vector_round_trips_every_mesh_and_role() {
        let n = 16usize;
        let v = randt(&[n], 11);
        for ranks in all_specs() {
            for role in [VecRole::ExpandBias, VecRole::ReduceBias, VecRole::Norm] {
                let parts: Vec<Option<Tensor>> =
                    ranks.iter().map(|s| s.shard_vector(role, &v)).collect();
                assert!(parts.iter().any(|p| p.is_some()));
                for (s, p) in ranks.iter().zip(parts.iter()) {
                    assert_eq!(p.is_some(), s.owns_vector(role), "{:?} {role:?}", s.mesh);
                }
                let back = ranks[0].assemble_vector(role, &parts, n);
                assert_eq!(back, v, "{:?} {role:?}", ranks[0].mesh);
            }
        }
    }

    #[test]
    fn shard_spec_activation_round_trips_and_dist_tensor_assembles() {
        let (rows, cols) = (8, 16);
        let x = randt(&[rows, cols], 12);
        for ranks in all_specs() {
            let parts: Vec<DistTensor> = ranks
                .iter()
                .map(|s| DistTensor::from_global_activation(s, &x))
                .collect();
            for (s, p) in ranks.iter().zip(parts.iter()) {
                assert_eq!(
                    p.local.shape(),
                    &[
                        s.activation_shape(rows, cols).0,
                        s.activation_shape(rows, cols).1
                    ]
                );
            }
            let back = DistTensor::assemble_activation(&parts, rows, cols);
            assert_eq!(back, x, "{:?}", ranks[0].mesh);
        }
    }

    #[test]
    fn shard_spec_matches_legacy_layouts() {
        // The unified algebra must cut the *same* shards the per-dimension
        // layouts cut — spot-check one rank per mesh.
        let w = randt(&[8, 16], 13);
        let s1 = ShardSpec::oned(4, 2);
        assert_eq!(s1.shard_weight(Stage::Expand, &w), Layout1D::ColShard.shard_of(4, 2, &w));
        assert_eq!(s1.shard_weight(Stage::Reduce, &w), Layout1D::RowShard.shard_of(4, 2, &w));
        let mesh = Mesh::new(2);
        let s2 = ShardSpec::twod(2, 3);
        assert_eq!(s2.shard_weight(Stage::Expand, &w), Layout2D::shard_of(&mesh, 3, &w));
        let cube = Cube::new(2);
        let d0 = Dirs::canonical();
        let s3 = ShardSpec::threed(2, 5);
        assert_eq!(
            s3.shard_weight(Stage::Reduce, &w),
            Layout3D::weight(d0.swapped()).shard_of(&cube, cube.coord_of(5), &w)
        );
        let v = randt(&[16], 14);
        assert_eq!(
            s3.shard_vector(VecRole::Norm, &v),
            DiagVec3D::for_dirs(d0).shard_of(&cube, cube.coord_of(5), &v)
        );
    }

    #[test]
    fn tess_weight_slabs_match_megatron_of_summa() {
        // The 2.5-D layout is 1-D (depth) ∘ 2-D (grid): the Expand weight's
        // layer-l slab equals the ColShard slab, and the block within it the
        // Layout2D block of that slab.
        let (p, d) = (2usize, 2usize);
        let mesh = Mesh::new(p);
        let w = randt(&[8, 16], 20);
        for layer in 0..d {
            let slab = Layout1D::ColShard.shard_of(d, layer, &w);
            for grid_rank in 0..mesh.size() {
                let spec = ShardSpec::twofived(p, d, layer * mesh.size() + grid_rank);
                assert_eq!(
                    spec.shard_weight(Stage::Expand, &w),
                    Layout2D::shard_of(&mesh, grid_rank, &slab),
                    "layer {layer} grid {grid_rank}"
                );
            }
            let rslab = Layout1D::RowShard.shard_of(d, layer, &w);
            for grid_rank in 0..mesh.size() {
                let spec = ShardSpec::twofived(p, d, layer * mesh.size() + grid_rank);
                assert_eq!(
                    spec.shard_weight(Stage::Reduce, &w),
                    Layout2D::shard_of(&mesh, grid_rank, &rslab),
                    "layer {layer} grid {grid_rank}"
                );
            }
        }
    }

    #[test]
    fn hybrid_replicas_share_weights_and_split_rows() {
        let spec_a = ShardSpec::hybrid(2, MeshSpec::Line(2), 1); // replica 0, line 1
        let spec_b = ShardSpec::hybrid(2, MeshSpec::Line(2), 3); // replica 1, line 1
        let w = randt(&[8, 16], 21);
        assert_eq!(
            spec_a.shard_weight(Stage::Expand, &w),
            spec_b.shard_weight(Stage::Expand, &w),
            "replicas hold identical weight copies"
        );
        assert_eq!(spec_a.weight_replicas(), 2);
        let x = randt(&[8, 16], 22);
        // Replica 0 gets rows 0..4, replica 1 rows 4..8 (inner 1-D
        // replicates within the replica).
        assert_eq!(spec_a.activation_bounds(8, 16), (0, 0, 4, 16));
        assert_eq!(spec_b.activation_bounds(8, 16), (4, 0, 4, 16));
        assert_eq!(spec_a.shard_activation(&x), x.block(0, 0, 4, 16).compact());
        assert_eq!(spec_b.shard_activation(&x), x.block(4, 0, 4, 16).compact());
    }

    #[test]
    fn pipeline_stage_groups_are_layout_identical() {
        // rank and rank + inner_world sit at the same inner position of
        // adjacent stage groups: identical activation windows, identical
        // weight shards (of whatever layer each stage happens to own).
        let x = randt(&[8, 16], 30);
        let w = randt(&[8, 16], 31);
        for r in 0..4 {
            let s0 = ShardSpec::pipeline(2, 4, MeshSpec::Grid(Mesh::new(2)), r);
            let s1 = ShardSpec::pipeline(2, 4, MeshSpec::Grid(Mesh::new(2)), r + 4);
            assert_eq!(s0.shard_activation(&x), s1.shard_activation(&x), "rank {r}");
            assert_eq!(
                s0.shard_weight(Stage::Expand, &w),
                s1.shard_weight(Stage::Expand, &w),
                "rank {r}"
            );
            assert_eq!(s0.activation_bounds(8, 16), s1.activation_bounds(8, 16));
        }
        // Stages never split attention heads.
        assert_eq!(ShardSpec::pipeline(4, 8, MeshSpec::Line(2), 0).head_divisor(), 2);
    }

    #[test]
    fn pipeline_kind_round_trips_including_hybrid_inner() {
        let par = Parallelism::Pipeline {
            stages: 2,
            micro_batches: 4,
            inner: PipelineInner::OneD,
        };
        assert_eq!(ShardSpec::for_parallelism(par, 2, 3).kind(), par);
        // The 5-D production shape: PP × DP × TP.
        let par5d = Parallelism::Pipeline {
            stages: 2,
            micro_batches: 4,
            inner: PipelineInner::Hybrid { replicas: 2, inner: HybridInner::TwoD },
        };
        let spec = ShardSpec::for_parallelism(par5d, 2, 9);
        assert_eq!(spec.world(), 16);
        assert_eq!(spec.kind(), par5d);
        // Dist-level replication: stages × hybrid replicas full copies.
        assert_eq!(spec.weight_replicas(), 4);
    }

    #[test]
    fn local_heads_rejects_non_dividing_meshes() {
        // The satellite fix: no silent truncation of head counts.
        assert_eq!(ShardSpec::twofived(2, 2, 0).head_divisor(), 4);
        assert_eq!(ShardSpec::twofived(2, 2, 0).local_heads(8), 2);
        assert_eq!(
            ShardSpec::hybrid(2, MeshSpec::Line(4), 0).head_divisor(),
            4,
            "replicas do not split heads"
        );
        let result = std::panic::catch_unwind(|| ShardSpec::twofived(2, 2, 0).local_heads(6));
        assert!(result.is_err(), "6 heads on a 2x2x2 mesh must panic, not truncate");
        let result = std::panic::catch_unwind(|| ShardSpec::oned(3, 0).local_heads(4));
        assert!(result.is_err(), "4 heads on a 3-line must panic, not truncate");
    }

    #[test]
    fn shard_spec_phantom_flows_through_sharding() {
        let w = Tensor::phantom(&[8, 16]);
        let v = Tensor::phantom(&[16]);
        for ranks in all_specs() {
            for s in &ranks {
                assert!(s.shard_weight(Stage::Expand, &w).is_phantom());
                if let Some(c) = s.shard_vector(VecRole::Norm, &v) {
                    assert!(c.is_phantom());
                }
                let a = Tensor::phantom(&[8, 16]);
                assert!(s.shard_activation(&a).is_phantom());
            }
        }
    }
}
