//! Process topologies: the 1-D line, 2-D mesh, and 3-D cube the three
//! parallelisms run on.
//!
//! The paper (§2.3, Figure 1) stacks `P = p³` processors into a cube with
//! coordinates `(i, j, l)` and directions `x` (varying `i`), `y` (varying
//! `j`), `z` (varying `l`). Collectives run along axis-aligned *lines* of the
//! cube: e.g. "all-gather A_{il} in the y direction" is an all-gather over
//! the `p` ranks `{(i, j, l) : 0 ≤ j < p}`.
//!
//! This module owns rank ↔ coordinate maps and group enumeration for all
//! three topologies, plus the rank → node map used by the hierarchical
//! network model (4 GPUs per node on TACC Longhorn).

/// Axis of a 3-D cube, named exactly as in the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Axis {
    /// Varies `i` (the paper's x direction — weight matrices travel here).
    X,
    /// Varies `j` (the paper's y direction — inputs gathered here).
    Y,
    /// Varies `l` (the paper's z direction — outputs reduce-scattered here).
    Z,
}

/// Coordinate in a `p × p × p` cube.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Coord {
    pub i: usize,
    pub j: usize,
    pub l: usize,
}

impl Coord {
    pub fn axis(&self, axis: Axis) -> usize {
        match axis {
            Axis::X => self.i,
            Axis::Y => self.j,
            Axis::Z => self.l,
        }
    }

    pub fn with_axis(mut self, axis: Axis, v: usize) -> Coord {
        match axis {
            Axis::X => self.i = v,
            Axis::Y => self.j = v,
            Axis::Z => self.l = v,
        }
        self
    }
}

/// `p³` processor cube (the 3-D parallelism substrate).
#[derive(Clone, Debug)]
pub struct Cube {
    p: usize,
}

impl Cube {
    pub fn new(p: usize) -> Self {
        assert!(p >= 1, "cube edge must be >= 1");
        Self { p }
    }

    /// Edge length `p`.
    pub fn edge(&self) -> usize {
        self.p
    }

    /// Total ranks `P = p³`.
    pub fn size(&self) -> usize {
        self.p * self.p * self.p
    }

    /// Rank layout: `rank = (i·p + j)·p + l`. The z (l) axis is innermost so
    /// that z-lines are contiguous ranks — on Longhorn-style packing (4 GPUs
    /// per node) this keeps the output reduce-scatter mostly intra-node for
    /// p ≥ 4, mirroring how the authors would map ranks with contiguous
    /// allocation.
    pub fn rank_of(&self, c: Coord) -> usize {
        debug_assert!(c.i < self.p && c.j < self.p && c.l < self.p,
            "coord {:?} out of bounds for p={}", c, self.p);
        (c.i * self.p + c.j) * self.p + c.l
    }

    pub fn coord_of(&self, rank: usize) -> Coord {
        debug_assert!(rank < self.size());
        Coord {
            i: rank / (self.p * self.p),
            j: (rank / self.p) % self.p,
            l: rank % self.p,
        }
    }

    /// The `p` ranks on the axis-aligned line through `c` along `axis`,
    /// ordered by their coordinate on that axis. `c` itself is included at
    /// position `c.axis(axis)`.
    pub fn line(&self, c: Coord, axis: Axis) -> Vec<usize> {
        (0..self.p)
            .map(|v| self.rank_of(c.with_axis(axis, v)))
            .collect()
    }

    /// Position of `c` within its own `line(c, axis)`.
    pub fn pos_in_line(&self, c: Coord, axis: Axis) -> usize {
        c.axis(axis)
    }

    /// All axis-aligned lines along `axis` (each of length `p`), i.e. `p²`
    /// disjoint groups covering the cube.
    pub fn all_lines(&self, axis: Axis) -> Vec<Vec<usize>> {
        let mut out = Vec::with_capacity(self.p * self.p);
        for a in 0..self.p {
            for b in 0..self.p {
                let c = match axis {
                    Axis::X => Coord { i: 0, j: a, l: b },
                    Axis::Y => Coord { i: a, j: 0, l: b },
                    Axis::Z => Coord { i: a, j: b, l: 0 },
                };
                out.push(self.line(c, axis));
            }
        }
        out
    }
}

/// `q × q` processor mesh (the 2-D SUMMA substrate, Optimus [21]).
#[derive(Clone, Debug)]
pub struct Mesh {
    q: usize,
}

impl Mesh {
    pub fn new(q: usize) -> Self {
        assert!(q >= 1);
        Self { q }
    }

    pub fn edge(&self) -> usize {
        self.q
    }

    pub fn size(&self) -> usize {
        self.q * self.q
    }

    /// Row-major: `rank = row·q + col`.
    pub fn rank_of(&self, row: usize, col: usize) -> usize {
        debug_assert!(row < self.q && col < self.q);
        row * self.q + col
    }

    pub fn coord_of(&self, rank: usize) -> (usize, usize) {
        debug_assert!(rank < self.size());
        (rank / self.q, rank % self.q)
    }

    /// Ranks in `row`, ordered by column.
    pub fn row_group(&self, row: usize) -> Vec<usize> {
        (0..self.q).map(|c| self.rank_of(row, c)).collect()
    }

    /// Ranks in `col`, ordered by row.
    pub fn col_group(&self, col: usize) -> Vec<usize> {
        (0..self.q).map(|r| self.rank_of(r, col)).collect()
    }
}

/// 1-D line of `P` ranks (the Megatron tensor-parallel group).
#[derive(Clone, Debug)]
pub struct Line {
    p: usize,
}

impl Line {
    pub fn new(p: usize) -> Self {
        assert!(p >= 1);
        Self { p }
    }

    pub fn size(&self) -> usize {
        self.p
    }

    pub fn group(&self) -> Vec<usize> {
        (0..self.p).collect()
    }
}

/// Which parallelism a model/run uses; carried through configs and the CLI.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Parallelism {
    /// Single-device sequential execution (reference).
    Seq,
    /// Megatron-style 1-D tensor parallelism [17].
    OneD,
    /// Optimus / SUMMA 2-D tensor parallelism [21].
    TwoD,
    /// The paper's load-balanced 3-D tensor parallelism.
    ThreeD,
}

impl Parallelism {
    /// World size for a given "edge" parameter: 1-D uses `P = edge`, 2-D
    /// `P = edge²`, 3-D `P = edge³`.
    pub fn world_size(&self, edge: usize) -> usize {
        match self {
            Parallelism::Seq => 1,
            Parallelism::OneD => edge,
            Parallelism::TwoD => edge * edge,
            Parallelism::ThreeD => edge * edge * edge,
        }
    }

    /// Edge parameter for a given world size; `None` if the world size is
    /// not a perfect square/cube as required.
    pub fn edge_for_world(&self, world: usize) -> Option<usize> {
        match self {
            Parallelism::Seq => (world == 1).then_some(1),
            Parallelism::OneD => Some(world),
            Parallelism::TwoD => {
                let q = (world as f64).sqrt().round() as usize;
                (q * q == world).then_some(q)
            }
            Parallelism::ThreeD => {
                let p = (world as f64).cbrt().round() as usize;
                (p * p * p == world).then_some(p)
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Parallelism::Seq => "seq",
            Parallelism::OneD => "1d",
            Parallelism::TwoD => "2d",
            Parallelism::ThreeD => "3d",
        }
    }

    pub fn parse(s: &str) -> Option<Parallelism> {
        match s {
            "seq" => Some(Parallelism::Seq),
            "1d" | "oned" => Some(Parallelism::OneD),
            "2d" | "twod" => Some(Parallelism::TwoD),
            "3d" | "threed" => Some(Parallelism::ThreeD),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cube_rank_coord_round_trip() {
        let cube = Cube::new(3);
        assert_eq!(cube.size(), 27);
        for r in 0..27 {
            assert_eq!(cube.rank_of(cube.coord_of(r)), r);
        }
    }

    #[test]
    fn cube_lines_have_right_members() {
        let cube = Cube::new(2);
        let c = Coord { i: 1, j: 0, l: 1 };
        // y line through (1, *, 1)
        let y = cube.line(c, Axis::Y);
        assert_eq!(y, vec![
            cube.rank_of(Coord { i: 1, j: 0, l: 1 }),
            cube.rank_of(Coord { i: 1, j: 1, l: 1 }),
        ]);
        assert_eq!(cube.pos_in_line(c, Axis::Y), 0);
        // x line through (*, 0, 1)
        let x = cube.line(c, Axis::X);
        assert_eq!(x, vec![
            cube.rank_of(Coord { i: 0, j: 0, l: 1 }),
            cube.rank_of(Coord { i: 1, j: 0, l: 1 }),
        ]);
        assert_eq!(cube.pos_in_line(c, Axis::X), 1);
    }

    #[test]
    fn cube_all_lines_partition_the_cube() {
        let cube = Cube::new(3);
        for axis in [Axis::X, Axis::Y, Axis::Z] {
            let lines = cube.all_lines(axis);
            assert_eq!(lines.len(), 9);
            let mut seen = vec![false; 27];
            for line in &lines {
                assert_eq!(line.len(), 3);
                for &r in line {
                    assert!(!seen[r], "rank {r} in two {axis:?} lines");
                    seen[r] = true;
                }
            }
            assert!(seen.iter().all(|&s| s));
        }
    }

    #[test]
    fn z_lines_are_contiguous_ranks() {
        let cube = Cube::new(4);
        let line = cube.line(Coord { i: 2, j: 3, l: 0 }, Axis::Z);
        for w in line.windows(2) {
            assert_eq!(w[1], w[0] + 1);
        }
    }

    #[test]
    fn mesh_groups() {
        let mesh = Mesh::new(3);
        assert_eq!(mesh.size(), 9);
        for r in 0..9 {
            let (row, col) = mesh.coord_of(r);
            assert_eq!(mesh.rank_of(row, col), r);
        }
        assert_eq!(mesh.row_group(1), vec![3, 4, 5]);
        assert_eq!(mesh.col_group(2), vec![2, 5, 8]);
    }

    #[test]
    fn parallelism_world_size_and_edge() {
        assert_eq!(Parallelism::OneD.world_size(8), 8);
        assert_eq!(Parallelism::TwoD.world_size(4), 16);
        assert_eq!(Parallelism::ThreeD.world_size(4), 64);
        assert_eq!(Parallelism::TwoD.edge_for_world(36), Some(6));
        assert_eq!(Parallelism::TwoD.edge_for_world(12), None);
        assert_eq!(Parallelism::ThreeD.edge_for_world(64), Some(4));
        assert_eq!(Parallelism::ThreeD.edge_for_world(10), None);
        assert_eq!(Parallelism::parse("3d"), Some(Parallelism::ThreeD));
        assert_eq!(Parallelism::parse("bogus"), None);
    }
}
