//! Process topologies: the 1-D line, 2-D mesh, and 3-D cube the three
//! parallelisms run on.
//!
//! The paper (§2.3, Figure 1) stacks `P = p³` processors into a cube with
//! coordinates `(i, j, l)` and directions `x` (varying `i`), `y` (varying
//! `j`), `z` (varying `l`). Collectives run along axis-aligned *lines* of the
//! cube: e.g. "all-gather A_{il} in the y direction" is an all-gather over
//! the `p` ranks `{(i, j, l) : 0 ≤ j < p}`.
//!
//! This module owns rank ↔ coordinate maps and group enumeration for all
//! three topologies, plus the rank → node map used by the hierarchical
//! network model (4 GPUs per node on TACC Longhorn).

/// Axis of a 3-D cube, named exactly as in the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Axis {
    /// Varies `i` (the paper's x direction — weight matrices travel here).
    X,
    /// Varies `j` (the paper's y direction — inputs gathered here).
    Y,
    /// Varies `l` (the paper's z direction — outputs reduce-scattered here).
    Z,
}

/// Coordinate in a `p × p × p` cube.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Coord {
    pub i: usize,
    pub j: usize,
    pub l: usize,
}

impl Coord {
    pub fn axis(&self, axis: Axis) -> usize {
        match axis {
            Axis::X => self.i,
            Axis::Y => self.j,
            Axis::Z => self.l,
        }
    }

    pub fn with_axis(mut self, axis: Axis, v: usize) -> Coord {
        match axis {
            Axis::X => self.i = v,
            Axis::Y => self.j = v,
            Axis::Z => self.l = v,
        }
        self
    }
}

/// `p³` processor cube (the 3-D parallelism substrate).
#[derive(Clone, Debug)]
pub struct Cube {
    p: usize,
}

impl Cube {
    pub fn new(p: usize) -> Self {
        assert!(p >= 1, "cube edge must be >= 1");
        Self { p }
    }

    /// Edge length `p`.
    pub fn edge(&self) -> usize {
        self.p
    }

    /// Total ranks `P = p³`.
    pub fn size(&self) -> usize {
        self.p * self.p * self.p
    }

    /// Rank layout: `rank = (i·p + j)·p + l`. The z (l) axis is innermost so
    /// that z-lines are contiguous ranks — on Longhorn-style packing (4 GPUs
    /// per node) this keeps the output reduce-scatter mostly intra-node for
    /// p ≥ 4, mirroring how the authors would map ranks with contiguous
    /// allocation.
    pub fn rank_of(&self, c: Coord) -> usize {
        debug_assert!(c.i < self.p && c.j < self.p && c.l < self.p,
            "coord {:?} out of bounds for p={}", c, self.p);
        (c.i * self.p + c.j) * self.p + c.l
    }

    pub fn coord_of(&self, rank: usize) -> Coord {
        debug_assert!(rank < self.size());
        Coord {
            i: rank / (self.p * self.p),
            j: (rank / self.p) % self.p,
            l: rank % self.p,
        }
    }

    /// The `p` ranks on the axis-aligned line through `c` along `axis`,
    /// ordered by their coordinate on that axis. `c` itself is included at
    /// position `c.axis(axis)`.
    pub fn line(&self, c: Coord, axis: Axis) -> Vec<usize> {
        (0..self.p)
            .map(|v| self.rank_of(c.with_axis(axis, v)))
            .collect()
    }

    /// Position of `c` within its own `line(c, axis)`.
    pub fn pos_in_line(&self, c: Coord, axis: Axis) -> usize {
        c.axis(axis)
    }

    /// All axis-aligned lines along `axis` (each of length `p`), i.e. `p²`
    /// disjoint groups covering the cube.
    pub fn all_lines(&self, axis: Axis) -> Vec<Vec<usize>> {
        let mut out = Vec::with_capacity(self.p * self.p);
        for a in 0..self.p {
            for b in 0..self.p {
                let c = match axis {
                    Axis::X => Coord { i: 0, j: a, l: b },
                    Axis::Y => Coord { i: a, j: 0, l: b },
                    Axis::Z => Coord { i: a, j: b, l: 0 },
                };
                out.push(self.line(c, axis));
            }
        }
        out
    }
}

/// `q × q` processor mesh (the 2-D SUMMA substrate, Optimus [21]).
#[derive(Clone, Debug)]
pub struct Mesh {
    q: usize,
}

impl Mesh {
    pub fn new(q: usize) -> Self {
        assert!(q >= 1);
        Self { q }
    }

    pub fn edge(&self) -> usize {
        self.q
    }

    pub fn size(&self) -> usize {
        self.q * self.q
    }

    /// Row-major: `rank = row·q + col`.
    pub fn rank_of(&self, row: usize, col: usize) -> usize {
        debug_assert!(row < self.q && col < self.q);
        row * self.q + col
    }

    pub fn coord_of(&self, rank: usize) -> (usize, usize) {
        debug_assert!(rank < self.size());
        (rank / self.q, rank % self.q)
    }

    /// Ranks in `row`, ordered by column.
    pub fn row_group(&self, row: usize) -> Vec<usize> {
        (0..self.q).map(|c| self.rank_of(row, c)).collect()
    }

    /// Ranks in `col`, ordered by row.
    pub fn col_group(&self, col: usize) -> Vec<usize> {
        (0..self.q).map(|r| self.rank_of(r, col)).collect()
    }
}

/// 1-D line of `P` ranks (the Megatron tensor-parallel group).
#[derive(Clone, Debug)]
pub struct Line {
    p: usize,
}

impl Line {
    pub fn new(p: usize) -> Self {
        assert!(p >= 1);
        Self { p }
    }

    pub fn size(&self) -> usize {
        self.p
    }

    pub fn group(&self) -> Vec<usize> {
        (0..self.p).collect()
    }
}

/// The inner tensor mesh of a hybrid data×tensor decomposition. A strict
/// subset of [`Parallelism`] (no `Seq`, no nested hybrids) so hybrid specs
/// stay one level deep and `Copy`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HybridInner {
    /// 1-D Megatron line.
    OneD,
    /// 2-D SUMMA grid.
    TwoD,
    /// 3-D cube.
    ThreeD,
    /// 2.5-D Tesseract (`depth` stacked SUMMA grids).
    TwoFiveD { depth: usize },
}

impl HybridInner {
    /// The stand-alone parallelism this inner mesh corresponds to.
    pub fn as_parallelism(&self) -> Parallelism {
        match self {
            HybridInner::OneD => Parallelism::OneD,
            HybridInner::TwoD => Parallelism::TwoD,
            HybridInner::ThreeD => Parallelism::ThreeD,
            HybridInner::TwoFiveD { depth } => Parallelism::TwoFiveD { depth: *depth },
        }
    }
}

/// The inner mesh of one pipeline stage group. Every leaf (and the hybrid
/// wrapper) is allowed; `Seq` and nested pipelines are excluded so pipeline
/// specs stay one level deep and `Copy`. With `Hybrid` as an inner this
/// spans the full 5-D product space: `Pipeline(s, Hybrid(r, Tess(p, d)))`
/// is PP × DP × 2.5-D — the Megatron-LM-v2 / DeepSeek-V3 production stack.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PipelineInner {
    /// 1-D Megatron line.
    OneD,
    /// 2-D SUMMA grid.
    TwoD,
    /// 3-D cube.
    ThreeD,
    /// 2.5-D Tesseract (`depth` stacked SUMMA grids).
    TwoFiveD { depth: usize },
    /// Data-parallel replicas around a tensor mesh, per stage.
    Hybrid { replicas: usize, inner: HybridInner },
}

impl PipelineInner {
    /// The stand-alone parallelism this inner mesh corresponds to.
    pub fn as_parallelism(&self) -> Parallelism {
        match self {
            PipelineInner::OneD => Parallelism::OneD,
            PipelineInner::TwoD => Parallelism::TwoD,
            PipelineInner::ThreeD => Parallelism::ThreeD,
            PipelineInner::TwoFiveD { depth } => Parallelism::TwoFiveD { depth: *depth },
            PipelineInner::Hybrid { replicas, inner } => {
                Parallelism::Hybrid { replicas: *replicas, inner: *inner }
            }
        }
    }
}

/// Which parallelism a model/run uses; carried through configs and the CLI.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Parallelism {
    /// Single-device sequential execution (reference).
    Seq,
    /// Megatron-style 1-D tensor parallelism [17].
    OneD,
    /// Optimus / SUMMA 2-D tensor parallelism [21].
    TwoD,
    /// The paper's load-balanced 3-D tensor parallelism.
    ThreeD,
    /// Tesseract-style 2.5-D: `depth` stacked `edge × edge` SUMMA grids.
    /// Weights shard across the depth axis, activations replicate per layer.
    TwoFiveD { depth: usize },
    /// Data-parallel outer group of `replicas` around an inner tensor mesh
    /// (the inner mesh uses the run's `edge` parameter).
    Hybrid { replicas: usize, inner: HybridInner },
    /// Inter-layer (pipeline) parallelism: the layer stack splits into
    /// `stages` contiguous stages, each run on its own copy of the inner
    /// mesh, streaming `micro_batches` micro-batches through a GPipe-style
    /// schedule (bubble fraction `(s−1)/(m+s−1)`).
    Pipeline { stages: usize, micro_batches: usize, inner: PipelineInner },
}

impl Parallelism {
    /// World size for a given "edge" parameter: 1-D uses `P = edge`, 2-D
    /// `P = edge²`, 3-D `P = edge³`, 2.5-D `P = edge²·depth`, hybrid
    /// `P = replicas · inner(edge)`.
    pub fn world_size(&self, edge: usize) -> usize {
        match self {
            Parallelism::Seq => 1,
            Parallelism::OneD => edge,
            Parallelism::TwoD => edge * edge,
            Parallelism::ThreeD => edge * edge * edge,
            Parallelism::TwoFiveD { depth } => edge * edge * depth,
            Parallelism::Hybrid { replicas, inner } => {
                replicas * inner.as_parallelism().world_size(edge)
            }
            Parallelism::Pipeline { stages, inner, .. } => {
                stages * inner.as_parallelism().world_size(edge)
            }
        }
    }

    /// Edge parameter for a given world size; `None` if the world size does
    /// not factor as the kind requires (square, cube, `p²·depth`, …).
    pub fn edge_for_world(&self, world: usize) -> Option<usize> {
        match self {
            Parallelism::Seq => (world == 1).then_some(1),
            Parallelism::OneD => Some(world),
            Parallelism::TwoD => {
                let q = (world as f64).sqrt().round() as usize;
                (q * q == world).then_some(q)
            }
            Parallelism::ThreeD => {
                let p = (world as f64).cbrt().round() as usize;
                (p * p * p == world).then_some(p)
            }
            Parallelism::TwoFiveD { depth } => {
                if *depth == 0 || world % depth != 0 {
                    return None;
                }
                Parallelism::TwoD.edge_for_world(world / depth)
            }
            Parallelism::Hybrid { replicas, inner } => {
                if *replicas == 0 || world % replicas != 0 {
                    return None;
                }
                inner.as_parallelism().edge_for_world(world / replicas)
            }
            Parallelism::Pipeline { stages, inner, .. } => {
                if *stages == 0 || world % stages != 0 {
                    return None;
                }
                inner.as_parallelism().edge_for_world(world / stages)
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Parallelism::Seq => "seq",
            Parallelism::OneD => "1d",
            Parallelism::TwoD => "2d",
            Parallelism::ThreeD => "3d",
            Parallelism::TwoFiveD { .. } => "2.5d",
            Parallelism::Hybrid { .. } => "hybrid",
            Parallelism::Pipeline { .. } => "pipeline",
        }
    }

    /// Human description of the device mesh at a given edge, e.g. `8x8`,
    /// `4x4x4`, `4x4x2` (2.5-D), `2x(4x4)` (hybrid), `2pp(4x4)` (pipeline).
    pub fn mesh_desc(&self, edge: usize) -> String {
        match self {
            Parallelism::Seq => "1".to_string(),
            Parallelism::OneD => edge.to_string(),
            Parallelism::TwoD => format!("{edge}x{edge}"),
            Parallelism::ThreeD => format!("{edge}x{edge}x{edge}"),
            Parallelism::TwoFiveD { depth } => format!("{edge}x{edge}x{depth}"),
            Parallelism::Hybrid { replicas, inner } => {
                format!("{replicas}x({})", inner.as_parallelism().mesh_desc(edge))
            }
            Parallelism::Pipeline { stages, inner, .. } => {
                format!("{stages}pp({})", inner.as_parallelism().mesh_desc(edge))
            }
        }
    }

    /// Override the 2.5-D depth (including a hybrid's 2.5-D inner) — the
    /// one implementation behind the `--depth` CLI flag and the
    /// `[parallel] depth` TOML key, so their kind checks cannot drift.
    pub fn set_depth(&mut self, d: usize) -> Result<(), String> {
        if d == 0 {
            return Err("2.5-D depth must be >= 1".into());
        }
        match self {
            Parallelism::TwoFiveD { depth }
            | Parallelism::Hybrid { inner: HybridInner::TwoFiveD { depth }, .. }
            | Parallelism::Pipeline { inner: PipelineInner::TwoFiveD { depth }, .. }
            | Parallelism::Pipeline {
                inner: PipelineInner::Hybrid { inner: HybridInner::TwoFiveD { depth }, .. },
                ..
            } => {
                *depth = d;
                Ok(())
            }
            _ => Err("depth only applies to 2.5d kinds (incl. hybrid2.5d)".into()),
        }
    }

    /// Data-parallel replica count of the *top-level* mesh: `r` for
    /// [`Parallelism::Hybrid`], 1 for every other kind — the divisor ZeRO
    /// (`[parallel] zero_stage` / `--zero-stage`) partitions optimizer
    /// state by. Pipeline-wrapped hybrids report 1 here: their stage-local
    /// replica groups are not ZeRO-partitionable yet (config rejects the
    /// combination).
    pub fn data_replicas(&self) -> usize {
        match self {
            Parallelism::Hybrid { replicas, .. } => *replicas,
            _ => 1,
        }
    }

    /// Override the hybrid replica count — shared by `--replicas` and the
    /// `[parallel] replicas` TOML key.
    pub fn set_replicas(&mut self, r: usize) -> Result<(), String> {
        if r == 0 {
            return Err("hybrid replicas must be >= 1".into());
        }
        match self {
            Parallelism::Hybrid { replicas, .. }
            | Parallelism::Pipeline { inner: PipelineInner::Hybrid { replicas, .. }, .. } => {
                *replicas = r;
                Ok(())
            }
            _ => Err("replicas only applies to hybrid kinds".into()),
        }
    }

    /// Override the pipeline stage count — shared by `--stages` and the
    /// `[parallel] stages` TOML key.
    pub fn set_stages(&mut self, s: usize) -> Result<(), String> {
        if s == 0 {
            return Err("pipeline stages must be >= 1".into());
        }
        match self {
            Parallelism::Pipeline { stages, .. } => {
                *stages = s;
                Ok(())
            }
            _ => Err("stages only applies to pipeline kinds".into()),
        }
    }

    /// Override the pipeline micro-batch count — shared by
    /// `--micro-batches` and the `[parallel] micro_batches` TOML key.
    pub fn set_micro_batches(&mut self, m: usize) -> Result<(), String> {
        if m == 0 {
            return Err("pipeline micro_batches must be >= 1".into());
        }
        match self {
            Parallelism::Pipeline { micro_batches, .. } => {
                *micro_batches = m;
                Ok(())
            }
            _ => Err("micro_batches only applies to pipeline kinds".into()),
        }
    }

    /// Parse a CLI/config spelling. 2.5-D defaults to depth 2 and hybrid to
    /// 2 replicas; `[parallel] depth`/`replicas` config keys (or the
    /// matching CLI flags) override the defaults after parsing.
    pub fn parse(s: &str) -> Option<Parallelism> {
        match s {
            "seq" => Some(Parallelism::Seq),
            "1d" | "oned" => Some(Parallelism::OneD),
            "2d" | "twod" => Some(Parallelism::TwoD),
            "3d" | "threed" => Some(Parallelism::ThreeD),
            "2.5d" | "25d" | "tess" | "twofived" => Some(Parallelism::TwoFiveD { depth: 2 }),
            "hybrid" | "hybrid1d" => {
                Some(Parallelism::Hybrid { replicas: 2, inner: HybridInner::OneD })
            }
            "hybrid2d" => Some(Parallelism::Hybrid { replicas: 2, inner: HybridInner::TwoD }),
            "hybrid3d" => Some(Parallelism::Hybrid { replicas: 2, inner: HybridInner::ThreeD }),
            "hybrid2.5d" => Some(Parallelism::Hybrid {
                replicas: 2,
                inner: HybridInner::TwoFiveD { depth: 2 },
            }),
            // Pipeline defaults: 2 stages, 4 micro-batches; `[parallel]
            // stages`/`micro_batches` (or --stages/--micro-batches) override.
            "pipeline" | "pp" | "pipeline1d" => Some(Parallelism::Pipeline {
                stages: 2,
                micro_batches: 4,
                inner: PipelineInner::OneD,
            }),
            "pipeline2d" => Some(Parallelism::Pipeline {
                stages: 2,
                micro_batches: 4,
                inner: PipelineInner::TwoD,
            }),
            "pipeline3d" => Some(Parallelism::Pipeline {
                stages: 2,
                micro_batches: 4,
                inner: PipelineInner::ThreeD,
            }),
            "pipeline2.5d" => Some(Parallelism::Pipeline {
                stages: 2,
                micro_batches: 4,
                inner: PipelineInner::TwoFiveD { depth: 2 },
            }),
            "pipelinehybrid" | "pipelinehybrid2d" => Some(Parallelism::Pipeline {
                stages: 2,
                micro_batches: 4,
                inner: PipelineInner::Hybrid { replicas: 2, inner: HybridInner::TwoD },
            }),
            _ => None,
        }
    }
}

/// One parallelism kind with a concrete decomposition at some world size —
/// a row of the `cubic plan --world N` comparison table.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PlanCandidate {
    pub par: Parallelism,
    pub edge: usize,
}

impl PlanCandidate {
    pub fn world(&self) -> usize {
        self.par.world_size(self.edge)
    }
}

/// Enumerate, for every parallelism kind the crate implements, a canonical
/// decomposition at exactly `world` ranks (the `Seq` baseline is always
/// included at world 1). Kinds with no exact decomposition at `world` are
/// omitted:
///
/// * 2-D needs a square, 3-D a cube;
/// * 2.5-D picks the largest grid edge `p ≥ 2` with `p² | world` and a
///   depth `world / p² ≥ 2` (depth 1 would just be 2-D);
/// * hybrid picks the smallest replica count `r ≥ 2` whose inner world
///   `world / r` is a square (inner 2-D), then a cube (inner 3-D), then —
///   for even worlds — falls back to `2 × 1-D`.
pub fn plan_candidates(world: usize) -> Vec<PlanCandidate> {
    let mut out = vec![PlanCandidate { par: Parallelism::Seq, edge: 1 }];
    if world >= 2 {
        out.push(PlanCandidate { par: Parallelism::OneD, edge: world });
    }
    if let Some(q) = Parallelism::TwoD.edge_for_world(world) {
        if q >= 2 {
            out.push(PlanCandidate { par: Parallelism::TwoD, edge: q });
        }
    }
    if let Some(p) = Parallelism::ThreeD.edge_for_world(world) {
        if p >= 2 {
            out.push(PlanCandidate { par: Parallelism::ThreeD, edge: p });
        }
    }
    // 2.5-D: largest p with p² | world and depth ≥ 2.
    let mut best: Option<(usize, usize)> = None;
    for p in 2..=world {
        if p * p > world {
            break;
        }
        if world % (p * p) == 0 && world / (p * p) >= 2 {
            best = Some((p, world / (p * p)));
        }
    }
    if let Some((p, depth)) = best {
        out.push(PlanCandidate { par: Parallelism::TwoFiveD { depth }, edge: p });
    }
    // Hybrid: smallest r ≥ 2 with a square inner, then a cubic inner, then
    // 2 × 1-D for even worlds.
    let hybrid = (2..=world / 2)
        .filter(|r| world % r == 0)
        .find_map(|r| {
            Parallelism::TwoD.edge_for_world(world / r).and_then(|q| {
                (q >= 2).then_some(PlanCandidate {
                    par: Parallelism::Hybrid { replicas: r, inner: HybridInner::TwoD },
                    edge: q,
                })
            })
        })
        .or_else(|| {
            (2..=world / 2).filter(|r| world % r == 0).find_map(|r| {
                Parallelism::ThreeD.edge_for_world(world / r).and_then(|p| {
                    (p >= 2).then_some(PlanCandidate {
                        par: Parallelism::Hybrid { replicas: r, inner: HybridInner::ThreeD },
                        edge: p,
                    })
                })
            })
        })
        .or_else(|| {
            (world % 2 == 0 && world >= 4).then_some(PlanCandidate {
                par: Parallelism::Hybrid { replicas: 2, inner: HybridInner::OneD },
                edge: world / 2,
            })
        });
    if let Some(h) = hybrid {
        out.push(h);
    }
    // Pipeline: smallest stage count s ≥ 2 dividing world whose per-stage
    // world `world / s` decomposes as an inner mesh, preferring 2-D, then
    // the largest 2.5-D grid, then 3-D, then 1-D. Canonical micro-batch
    // count 4 (bubble fraction (s−1)/(m+s−1) = 1/5 at s = 2).
    let pipeline = (2..=world / 2).filter(|s| world % s == 0).find_map(|s| {
        let iw = world / s;
        if iw < 2 {
            return None;
        }
        let inner = Parallelism::TwoD
            .edge_for_world(iw)
            .filter(|q| *q >= 2)
            .map(|q| (PipelineInner::TwoD, q))
            .or_else(|| {
                // Largest p ≥ 2 with p² | iw and depth ≥ 2.
                let mut best = None;
                for p in 2..=iw {
                    if p * p > iw {
                        break;
                    }
                    if iw % (p * p) == 0 && iw / (p * p) >= 2 {
                        best = Some((PipelineInner::TwoFiveD { depth: iw / (p * p) }, p));
                    }
                }
                best
            })
            .or_else(|| {
                Parallelism::ThreeD
                    .edge_for_world(iw)
                    .filter(|p| *p >= 2)
                    .map(|p| (PipelineInner::ThreeD, p))
            })
            .or(Some((PipelineInner::OneD, iw)));
        inner.map(|(inner, edge)| PlanCandidate {
            par: Parallelism::Pipeline { stages: s, micro_batches: 4, inner },
            edge,
        })
    });
    if let Some(p) = pipeline {
        out.push(p);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cube_rank_coord_round_trip() {
        let cube = Cube::new(3);
        assert_eq!(cube.size(), 27);
        for r in 0..27 {
            assert_eq!(cube.rank_of(cube.coord_of(r)), r);
        }
    }

    #[test]
    fn cube_lines_have_right_members() {
        let cube = Cube::new(2);
        let c = Coord { i: 1, j: 0, l: 1 };
        // y line through (1, *, 1)
        let y = cube.line(c, Axis::Y);
        assert_eq!(y, vec![
            cube.rank_of(Coord { i: 1, j: 0, l: 1 }),
            cube.rank_of(Coord { i: 1, j: 1, l: 1 }),
        ]);
        assert_eq!(cube.pos_in_line(c, Axis::Y), 0);
        // x line through (*, 0, 1)
        let x = cube.line(c, Axis::X);
        assert_eq!(x, vec![
            cube.rank_of(Coord { i: 0, j: 0, l: 1 }),
            cube.rank_of(Coord { i: 1, j: 0, l: 1 }),
        ]);
        assert_eq!(cube.pos_in_line(c, Axis::X), 1);
    }

    #[test]
    fn cube_all_lines_partition_the_cube() {
        let cube = Cube::new(3);
        for axis in [Axis::X, Axis::Y, Axis::Z] {
            let lines = cube.all_lines(axis);
            assert_eq!(lines.len(), 9);
            let mut seen = vec![false; 27];
            for line in &lines {
                assert_eq!(line.len(), 3);
                for &r in line {
                    assert!(!seen[r], "rank {r} in two {axis:?} lines");
                    seen[r] = true;
                }
            }
            assert!(seen.iter().all(|&s| s));
        }
    }

    #[test]
    fn z_lines_are_contiguous_ranks() {
        let cube = Cube::new(4);
        let line = cube.line(Coord { i: 2, j: 3, l: 0 }, Axis::Z);
        for w in line.windows(2) {
            assert_eq!(w[1], w[0] + 1);
        }
    }

    #[test]
    fn mesh_groups() {
        let mesh = Mesh::new(3);
        assert_eq!(mesh.size(), 9);
        for r in 0..9 {
            let (row, col) = mesh.coord_of(r);
            assert_eq!(mesh.rank_of(row, col), r);
        }
        assert_eq!(mesh.row_group(1), vec![3, 4, 5]);
        assert_eq!(mesh.col_group(2), vec![2, 5, 8]);
    }

    #[test]
    fn parallelism_world_size_and_edge() {
        assert_eq!(Parallelism::OneD.world_size(8), 8);
        assert_eq!(Parallelism::TwoD.world_size(4), 16);
        assert_eq!(Parallelism::ThreeD.world_size(4), 64);
        assert_eq!(Parallelism::TwoD.edge_for_world(36), Some(6));
        assert_eq!(Parallelism::TwoD.edge_for_world(12), None);
        assert_eq!(Parallelism::ThreeD.edge_for_world(64), Some(4));
        assert_eq!(Parallelism::ThreeD.edge_for_world(10), None);
        assert_eq!(Parallelism::parse("3d"), Some(Parallelism::ThreeD));
        assert_eq!(Parallelism::parse("bogus"), None);
    }

    #[test]
    fn two_five_d_and_hybrid_world_size_and_edge() {
        let tess = Parallelism::TwoFiveD { depth: 2 };
        assert_eq!(tess.world_size(4), 32);
        assert_eq!(tess.edge_for_world(32), Some(4));
        assert_eq!(tess.edge_for_world(12), None);
        let hyb = Parallelism::Hybrid { replicas: 2, inner: HybridInner::TwoD };
        assert_eq!(hyb.world_size(4), 32);
        assert_eq!(hyb.edge_for_world(32), Some(4));
        assert_eq!(hyb.edge_for_world(30), None);
        assert_eq!(Parallelism::parse("2.5d"), Some(Parallelism::TwoFiveD { depth: 2 }));
        assert_eq!(
            Parallelism::parse("hybrid"),
            Some(Parallelism::Hybrid { replicas: 2, inner: HybridInner::OneD })
        );
        assert_eq!(tess.name(), "2.5d");
        assert_eq!(hyb.name(), "hybrid");
        assert_eq!(tess.mesh_desc(4), "4x4x2");
        assert_eq!(hyb.mesh_desc(4), "2x(4x4)");
    }

    #[test]
    fn plan_candidates_cover_all_kinds_at_64() {
        let cands = plan_candidates(64);
        let names: Vec<&str> = cands.iter().map(|c| c.par.name()).collect();
        for want in ["seq", "1d", "2d", "3d", "2.5d", "hybrid", "pipeline"] {
            assert!(names.contains(&want), "missing {want} in {names:?}");
        }
        for c in &cands {
            if c.par != Parallelism::Seq {
                assert_eq!(c.world(), 64, "{:?}", c.par);
            }
        }
        // Canonical picks: the largest 2.5-D grid, the smallest square
        // hybrid replica group, and a 2-stage pipeline around the largest
        // per-stage 2.5-D grid (PP × Tesseract at equal world size).
        assert!(cands
            .contains(&PlanCandidate { par: Parallelism::TwoFiveD { depth: 4 }, edge: 4 }));
        assert!(cands.contains(&PlanCandidate {
            par: Parallelism::Hybrid { replicas: 4, inner: HybridInner::TwoD },
            edge: 4,
        }));
        assert!(cands.contains(&PlanCandidate {
            par: Parallelism::Pipeline {
                stages: 2,
                micro_batches: 4,
                inner: PipelineInner::TwoFiveD { depth: 2 },
            },
            edge: 4,
        }));
    }

    #[test]
    fn pipeline_world_size_edge_and_knobs() {
        let pp = Parallelism::Pipeline {
            stages: 2,
            micro_batches: 4,
            inner: PipelineInner::OneD,
        };
        assert_eq!(pp.world_size(2), 4);
        assert_eq!(pp.edge_for_world(4), Some(2));
        assert_eq!(pp.edge_for_world(5), None);
        assert_eq!(pp.name(), "pipeline");
        assert_eq!(pp.mesh_desc(2), "2pp(2)");
        let pp2d = Parallelism::Pipeline {
            stages: 2,
            micro_batches: 4,
            inner: PipelineInner::TwoD,
        };
        assert_eq!(pp2d.world_size(2), 8);
        assert_eq!(pp2d.mesh_desc(4), "2pp(4x4)");
        let deep = Parallelism::Pipeline {
            stages: 4,
            micro_batches: 8,
            inner: PipelineInner::Hybrid { replicas: 2, inner: HybridInner::TwoD },
        };
        assert_eq!(deep.world_size(2), 4 * 2 * 4);
        assert_eq!(deep.mesh_desc(2), "4pp(2x(2x2))");
        assert_eq!(Parallelism::parse("pipeline"), Some(pp));
        assert_eq!(Parallelism::parse("pp"), Some(pp));
        assert_eq!(Parallelism::parse("pipeline2d"), Some(pp2d));
        let mut p = pp;
        p.set_stages(4).unwrap();
        p.set_micro_batches(8).unwrap();
        assert_eq!(
            p,
            Parallelism::Pipeline { stages: 4, micro_batches: 8, inner: PipelineInner::OneD }
        );
        assert!(p.set_stages(0).is_err());
        assert!(Parallelism::TwoD.set_stages(2).is_err());
        assert!(Parallelism::TwoD.set_micro_batches(2).is_err());
        // The replica/depth knobs reach through the pipeline wrapper.
        let mut ph = Parallelism::parse("pipelinehybrid").unwrap();
        ph.set_replicas(4).unwrap();
        assert_eq!(
            ph,
            Parallelism::Pipeline {
                stages: 2,
                micro_batches: 4,
                inner: PipelineInner::Hybrid { replicas: 4, inner: HybridInner::TwoD },
            }
        );
        let mut pt = Parallelism::parse("pipeline2.5d").unwrap();
        pt.set_depth(4).unwrap();
        assert_eq!(
            pt,
            Parallelism::Pipeline {
                stages: 2,
                micro_batches: 4,
                inner: PipelineInner::TwoFiveD { depth: 4 },
            }
        );
    }

    #[test]
    fn plan_candidates_fall_back_to_1d_hybrid() {
        // world 8: no square inner (4 is square → r=2 works actually), use 24:
        // 24/r square needs r=6 (4=2²); check the scan finds it.
        let cands = plan_candidates(24);
        assert!(cands.contains(&PlanCandidate {
            par: Parallelism::Hybrid { replicas: 6, inner: HybridInner::TwoD },
            edge: 2,
        }));
        // world 6: 3 is neither square nor cube → 2 × 1-D(3).
        let cands = plan_candidates(6);
        assert!(cands.contains(&PlanCandidate {
            par: Parallelism::Hybrid { replicas: 2, inner: HybridInner::OneD },
            edge: 3,
        }));
    }
}
