//! Optimizers over per-rank parameter shards.
//!
//! Because every parallelism assigns each parameter shard to exactly one
//! owner (vectors) or an exclusive shard per rank (matrices) — and
//! replicated parameters receive bit-identical gradients on every replica —
//! a purely local optimizer step keeps the distributed model consistent.
//! This is asserted end-to-end by the cross-parallelism training parity
//! test in `rust/tests/`.

use crate::config::{OptimizerKind, TrainConfig};
use crate::tensor::Tensor;

/// Learning-rate schedule: linear warmup then cosine decay to 10%.
pub fn lr_at(cfg: &TrainConfig, step: usize) -> f32 {
    let base = cfg.lr;
    if cfg.warmup > 0 && step < cfg.warmup {
        return base * (step + 1) as f32 / cfg.warmup as f32;
    }
    if cfg.steps <= cfg.warmup {
        return base;
    }
    let t = (step - cfg.warmup) as f32 / (cfg.steps - cfg.warmup).max(1) as f32;
    let min = 0.1 * base;
    min + 0.5 * (base - min) * (1.0 + (std::f32::consts::PI * t.min(1.0)).cos())
}

/// Global-norm gradient clipping over a set of local grads.
///
/// NOTE: the norm here is over the *local* shards; in distributed runs the
/// trainer all-reduces the squared norm first and passes the global value
/// via `pre_reduced_sq_norm`.
pub fn clip_grads(grads: &mut [&mut Tensor], max_norm: f32, pre_reduced_sq_norm: Option<f32>) {
    if max_norm <= 0.0 {
        return;
    }
    let sq: f32 = match pre_reduced_sq_norm {
        Some(v) => v,
        None => grads
            .iter()
            .map(|g| g.try_data().map_or(0.0, |d| d.iter().map(|&x| x * x).sum::<f32>()))
            .sum(),
    };
    let norm = sq.sqrt();
    if norm > max_norm {
        let scale = max_norm / (norm + 1e-6);
        for g in grads.iter_mut() {
            if !g.is_phantom() {
                for v in g.data_mut() {
                    *v *= scale;
                }
            }
        }
    }
}

/// Sum of squared gradient entries (local contribution to the global norm).
pub fn local_sq_norm(grads: &[&Tensor]) -> f32 {
    grads
        .iter()
        .map(|g| g.try_data().map_or(0.0, |d| d.iter().map(|&x| x * x).sum::<f32>()))
        .sum()
}

/// Optimizer state for one ordered parameter list. The parameter order must
/// be identical every step (it is: `BlockTensors::pairs_mut` is stable).
pub enum Optimizer {
    Sgd {
        momentum: f32,
        velocity: Vec<Tensor>,
    },
    Adam {
        beta1: f32,
        beta2: f32,
        eps: f32,
        weight_decay: f32,
        t: u64,
        m: Vec<Tensor>,
        v: Vec<Tensor>,
    },
}

impl Optimizer {
    pub fn new(cfg: &TrainConfig, param_shapes: &[Vec<usize>]) -> Optimizer {
        match cfg.optimizer {
            OptimizerKind::Sgd => Optimizer::Sgd {
                momentum: 0.9,
                velocity: param_shapes.iter().map(|s| Tensor::zeros(s)).collect(),
            },
            OptimizerKind::Adam => Optimizer::Adam {
                beta1: cfg.adam_beta1,
                beta2: cfg.adam_beta2,
                eps: 1e-8,
                weight_decay: cfg.weight_decay,
                t: 0,
                m: param_shapes.iter().map(|s| Tensor::zeros(s)).collect(),
                v: param_shapes.iter().map(|s| Tensor::zeros(s)).collect(),
            },
        }
    }

    /// Apply one update to `pairs` (param, grad) with learning rate `lr`.
    pub fn step(&mut self, pairs: &mut [(&mut Tensor, &Tensor)], lr: f32) {
        match self {
            Optimizer::Sgd { momentum, velocity } => {
                assert_eq!(pairs.len(), velocity.len(), "param count changed");
                for ((p, g), vel) in pairs.iter_mut().zip(velocity.iter_mut()) {
                    if p.is_phantom() || g.is_phantom() {
                        continue;
                    }
                    let gd = g.data();
                    let vd = vel.data_mut();
                    let pd = p.data_mut();
                    for i in 0..pd.len() {
                        vd[i] = *momentum * vd[i] + gd[i];
                        pd[i] -= lr * vd[i];
                    }
                }
            }
            Optimizer::Adam { beta1, beta2, eps, weight_decay, t, m, v } => {
                assert_eq!(pairs.len(), m.len(), "param count changed");
                *t += 1;
                let b1t = 1.0 - (*beta1).powi(*t as i32);
                let b2t = 1.0 - (*beta2).powi(*t as i32);
                for (k, (p, g)) in pairs.iter_mut().enumerate() {
                    if p.is_phantom() || g.is_phantom() {
                        continue;
                    }
                    let gd = g.data();
                    let md = m[k].data_mut();
                    let pd = p.data_mut();
                    // split borrows: v after m
                    let vd = v[k].data_mut();
                    for i in 0..pd.len() {
                        let gi = gd[i] + *weight_decay * pd[i];
                        md[i] = *beta1 * md[i] + (1.0 - *beta1) * gi;
                        vd[i] = *beta2 * vd[i] + (1.0 - *beta2) * gi * gi;
                        let mhat = md[i] / b1t;
                        let vhat = vd[i] / b2t;
                        pd[i] -= lr * mhat / (vhat.sqrt() + *eps);
                    }
                }
            }
        }
    }

    /// The optimizer's state tensors in a stable order (Sgd: velocities;
    /// Adam: all first moments, then all second moments). Checkpointing
    /// and replica donation serialize exactly this sequence.
    pub fn state_tensors(&self) -> Vec<&Tensor> {
        match self {
            Optimizer::Sgd { velocity, .. } => velocity.iter().collect(),
            Optimizer::Adam { m, v, .. } => m.iter().chain(v.iter()).collect(),
        }
    }

    /// Mutable view of [`Optimizer::state_tensors`], same order.
    pub fn state_tensors_mut(&mut self) -> Vec<&mut Tensor> {
        match self {
            Optimizer::Sgd { velocity, .. } => velocity.iter_mut().collect(),
            Optimizer::Adam { m, v, .. } => m.iter_mut().chain(v.iter_mut()).collect(),
        }
    }

    /// Adam's bias-correction timestep (0 for Sgd, which has none).
    pub fn timestep(&self) -> u64 {
        match self {
            Optimizer::Sgd { .. } => 0,
            Optimizer::Adam { t, .. } => *t,
        }
    }

    /// Restore the bias-correction timestep (no-op for Sgd).
    pub fn set_timestep(&mut self, new_t: u64) {
        if let Optimizer::Adam { t, .. } = self {
            *t = new_t;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn quad_loss(p: &Tensor) -> (f32, Tensor) {
        // L = 0.5‖p − 3‖²; grad = p − 3.
        let g = p.map(|v| v - 3.0);
        let l = 0.5 * g.data().iter().map(|&x| x * x).sum::<f32>();
        (l, g)
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        let cfg = TrainConfig { optimizer: OptimizerKind::Sgd, lr: 0.1, ..Default::default() };
        let mut p = Tensor::zeros(&[4]);
        let mut opt = Optimizer::new(&cfg, &[vec![4]]);
        for _ in 0..200 {
            let (_, g) = quad_loss(&p);
            opt.step(&mut [(&mut p, &g)], 0.1);
        }
        for &v in p.data() {
            assert!((v - 3.0).abs() < 1e-3, "{v}");
        }
    }

    #[test]
    fn adam_converges_on_quadratic() {
        let cfg = TrainConfig::default();
        let mut p = Tensor::zeros(&[4]);
        let mut opt = Optimizer::new(&cfg, &[vec![4]]);
        for _ in 0..500 {
            let (_, g) = quad_loss(&p);
            opt.step(&mut [(&mut p, &g)], 0.05);
        }
        for &v in p.data() {
            assert!((v - 3.0).abs() < 1e-2, "{v}");
        }
    }

    #[test]
    fn adam_is_deterministic() {
        let cfg = TrainConfig::default();
        let mut rng = Xoshiro256::seed_from_u64(1);
        let g = Tensor::randn(&[8], 1.0, &mut rng);
        let mut p1 = Tensor::ones(&[8]);
        let mut p2 = Tensor::ones(&[8]);
        let mut o1 = Optimizer::new(&cfg, &[vec![8]]);
        let mut o2 = Optimizer::new(&cfg, &[vec![8]]);
        for _ in 0..10 {
            o1.step(&mut [(&mut p1, &g)], 1e-3);
            o2.step(&mut [(&mut p2, &g)], 1e-3);
        }
        assert_eq!(p1, p2);
    }

    #[test]
    fn state_round_trip_resumes_bitwise() {
        // Copying state tensors + timestep into a fresh optimizer must make
        // it bit-identical to one that never stopped — the checkpoint /
        // replica-donation contract.
        let cfg = TrainConfig::default();
        let mut rng = Xoshiro256::seed_from_u64(2);
        let g = Tensor::randn(&[8], 1.0, &mut rng);
        let mut p_ref = Tensor::ones(&[8]);
        let mut opt_ref = Optimizer::new(&cfg, &[vec![8]]);
        for _ in 0..5 {
            opt_ref.step(&mut [(&mut p_ref, &g)], 1e-3);
        }
        let mut p_res = p_ref.clone();
        let mut opt_res = Optimizer::new(&cfg, &[vec![8]]);
        for (dst, src) in opt_res.state_tensors_mut().into_iter().zip(opt_ref.state_tensors()) {
            *dst = src.clone();
        }
        opt_res.set_timestep(opt_ref.timestep());
        assert_eq!(opt_res.timestep(), 5);
        for _ in 0..5 {
            opt_ref.step(&mut [(&mut p_ref, &g)], 1e-3);
            opt_res.step(&mut [(&mut p_res, &g)], 1e-3);
        }
        assert_eq!(p_ref, p_res);
    }

    #[test]
    fn lr_schedule_shape() {
        let cfg = TrainConfig { lr: 1.0, warmup: 10, steps: 110, ..Default::default() };
        assert!((lr_at(&cfg, 0) - 0.1).abs() < 1e-6);
        assert!((lr_at(&cfg, 9) - 1.0).abs() < 1e-6);
        assert!(lr_at(&cfg, 60) < 1.0);
        assert!(lr_at(&cfg, 109) >= 0.1 - 1e-6);
        // Monotone decay after warmup.
        assert!(lr_at(&cfg, 30) > lr_at(&cfg, 80));
    }

    #[test]
    fn clipping_caps_global_norm() {
        let mut g1 = Tensor::full(&[4], 3.0);
        let mut g2 = Tensor::full(&[4], 4.0);
        // ‖g‖ = sqrt(4·9 + 4·16) = 10.
        clip_grads(&mut [&mut g1, &mut g2], 5.0, None);
        let sq = g1.data().iter().chain(g2.data()).map(|&x| x * x).sum::<f32>();
        assert!((sq.sqrt() - 5.0).abs() < 1e-3);
        // Under the cap: untouched.
        let mut g3 = Tensor::full(&[2], 0.1);
        clip_grads(&mut [&mut g3], 5.0, None);
        assert_eq!(g3, Tensor::full(&[2], 0.1));
    }
}
