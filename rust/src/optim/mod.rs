//! Optimizers over per-rank parameter shards.
//!
//! Because every parallelism assigns each parameter shard to exactly one
//! owner (vectors) or an exclusive shard per rank (matrices) — and
//! replicated parameters receive bit-identical gradients on every replica —
//! a purely local optimizer step keeps the distributed model consistent.
//! This is asserted end-to-end by the cross-parallelism training parity
//! test in `rust/tests/`.
//!
//! ## Partitioned (ZeRO stage 1/2) mode
//!
//! Under `Hybrid(r, inner)` data parallelism the local shards above are
//! additionally *replicated* `r` times — and so are the optimizer moments,
//! which for Adam are 2× the parameter bytes. [`Optimizer::new_partitioned`]
//! removes that redundancy (ZeRO, arXiv:1910.02054): each replica keeps
//! moments only for its owned `1/r` slice of every parameter, described by a
//! [`ParamPartition`] map whose chunk boundaries are exactly the
//! `ceil(n/r)` cuts of [`crate::collectives::flat_chunks`]. The gradient
//! arriving at [`Optimizer::step`] is then the reduce-scattered chunk (not
//! the full tensor), the update touches only `param[offset .. offset+len]`,
//! and the trainer all-gathers the updated slices back
//! ([`crate::collectives::all_gather_into`]) before the next forward.
//! Because Adam/SGD updates are elementwise and the reduce-scatter performs
//! the same chunked ring reduction the all-reduce would, the partitioned
//! path is **bit-identical** to the replicated one — pinned by the
//! `partitioned_adam_matches_full_adam_*` tests below and end-to-end by the
//! hybrid ZeRO parity tests in `rust/tests/model_parity.rs`.

use crate::config::{OptimizerKind, TrainConfig};
use crate::tensor::Tensor;

/// Learning-rate schedule: linear warmup then cosine decay to 10%.
pub fn lr_at(cfg: &TrainConfig, step: usize) -> f32 {
    let base = cfg.lr;
    if cfg.warmup > 0 && step < cfg.warmup {
        return base * (step + 1) as f32 / cfg.warmup as f32;
    }
    if cfg.steps <= cfg.warmup {
        return base;
    }
    let t = (step - cfg.warmup) as f32 / (cfg.steps - cfg.warmup).max(1) as f32;
    let min = 0.1 * base;
    min + 0.5 * (base - min) * (1.0 + (std::f32::consts::PI * t.min(1.0)).cos())
}

/// Global-norm gradient clipping over a set of local grads.
///
/// NOTE: the norm here is over the *local* shards; in distributed runs the
/// trainer all-reduces the squared norm first and passes the global value
/// via `pre_reduced_sq_norm`.
pub fn clip_grads(grads: &mut [&mut Tensor], max_norm: f32, pre_reduced_sq_norm: Option<f32>) {
    if max_norm <= 0.0 {
        return;
    }
    let sq: f32 = match pre_reduced_sq_norm {
        Some(v) => v,
        None => grads
            .iter()
            .map(|g| g.try_data().map_or(0.0, |d| d.iter().map(|&x| x * x).sum::<f32>()))
            .sum(),
    };
    let norm = sq.sqrt();
    if norm > max_norm {
        let scale = max_norm / (norm + 1e-6);
        for g in grads.iter_mut() {
            if !g.is_phantom() {
                for v in g.data_mut() {
                    *v *= scale;
                }
            }
        }
    }
}

/// Sum of squared gradient entries (local contribution to the global norm).
pub fn local_sq_norm(grads: &[&Tensor]) -> f32 {
    grads
        .iter()
        .map(|g| g.try_data().map_or(0.0, |d| d.iter().map(|&x| x * x).sum::<f32>()))
        .sum()
}

/// One parameter's owned span under a ZeRO-style partition over `r`
/// data-parallel replicas: the flat slice `[offset, offset + len)` of the
/// full parameter that this replica updates, with moment tensors of length
/// `padded = ceil(numel / r)` (the last chunk's `len` may fall short of
/// `padded`; the pad positions carry zero gradient by construction and
/// never touch the parameter).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ParamPartition {
    /// First owned flat element of the full parameter (`index * padded`).
    pub offset: usize,
    /// Number of valid owned elements (`min(padded, numel - offset)`).
    pub len: usize,
    /// Chunk length `ceil(numel / replicas)` — the state-tensor size and
    /// the reduce-scatter chunk size.
    pub padded: usize,
}

/// Build the per-parameter partition map for replica `index` of `replicas`.
///
/// The chunk boundaries are exactly the `ceil(n/r)` zero-padded cuts of
/// [`crate::collectives::flat_chunks`] — the deterministic partition
/// contract that makes the reduce-scattered gradient chunk bitwise equal to
/// the corresponding slice of the all-reduced gradient.
pub fn zero_partition(
    param_shapes: &[Vec<usize>],
    replicas: usize,
    index: usize,
) -> Vec<ParamPartition> {
    assert!(replicas >= 1 && index < replicas, "replica {index} of {replicas}");
    param_shapes
        .iter()
        .map(|s| {
            let n: usize = s.iter().product();
            let padded = n.div_ceil(replicas);
            let offset = (index * padded).min(n);
            let len = ((index + 1) * padded).min(n).saturating_sub(offset);
            ParamPartition { offset, len, padded }
        })
        .collect()
}

/// Which optimizer algorithm an [`Optimizer`] runs, with its state tensors
/// (full-shape when replicated, `[padded]` chunks when partitioned).
enum OptState {
    Sgd {
        momentum: f32,
        velocity: Vec<Tensor>,
    },
    Adam {
        beta1: f32,
        beta2: f32,
        eps: f32,
        weight_decay: f32,
        t: u64,
        m: Vec<Tensor>,
        v: Vec<Tensor>,
    },
}

/// Optimizer state for one ordered parameter list. The parameter order must
/// be identical every step (it is: `BlockTensors::pairs_mut` is stable).
///
/// Two modes share every code path below:
/// * **replicated** ([`Optimizer::new`]) — state tensors match the
///   parameter shapes, gradients arrive full-shape;
/// * **partitioned** ([`Optimizer::new_partitioned`], ZeRO stage 1/2) —
///   state tensors are `[ceil(n/r)]` chunks, gradients arrive as this
///   replica's reduce-scattered chunk, and only the owned slice of each
///   parameter is updated.
pub struct Optimizer {
    kind: OptState,
    partition: Option<Vec<ParamPartition>>,
}

impl Optimizer {
    /// Replicated-state optimizer: one state tensor per parameter, shaped
    /// like the parameter.
    pub fn new(cfg: &TrainConfig, param_shapes: &[Vec<usize>]) -> Optimizer {
        Optimizer { kind: Self::state_for(cfg, param_shapes), partition: None }
    }

    /// Partitioned-state optimizer (ZeRO stage 1/2): replica `index` of
    /// `replicas` keeps moments only for its [`zero_partition`] slice of
    /// every parameter, shrinking per-rank optimizer-state memory by
    /// exactly `ceil(n/r)/n` per parameter (`1/r` when `r | n`).
    pub fn new_partitioned(
        cfg: &TrainConfig,
        param_shapes: &[Vec<usize>],
        replicas: usize,
        index: usize,
    ) -> Optimizer {
        let partition = zero_partition(param_shapes, replicas, index);
        let chunk_shapes: Vec<Vec<usize>> =
            partition.iter().map(|p| vec![p.padded]).collect();
        Optimizer {
            kind: Self::state_for(cfg, &chunk_shapes),
            partition: Some(partition),
        }
    }

    fn state_for(cfg: &TrainConfig, shapes: &[Vec<usize>]) -> OptState {
        match cfg.optimizer {
            OptimizerKind::Sgd => OptState::Sgd {
                momentum: 0.9,
                velocity: shapes.iter().map(|s| Tensor::zeros(s)).collect(),
            },
            OptimizerKind::Adam => OptState::Adam {
                beta1: cfg.adam_beta1,
                beta2: cfg.adam_beta2,
                eps: 1e-8,
                weight_decay: cfg.weight_decay,
                t: 0,
                m: shapes.iter().map(|s| Tensor::zeros(s)).collect(),
                v: shapes.iter().map(|s| Tensor::zeros(s)).collect(),
            },
        }
    }

    /// The partition map when this optimizer runs in ZeRO mode (`None` for
    /// replicated state). The trainer uses it to all-gather updated weight
    /// slices after [`Optimizer::step`].
    pub fn partition(&self) -> Option<&[ParamPartition]> {
        self.partition.as_deref()
    }

    /// Apply one update to `pairs` (param, grad) with learning rate `lr`.
    ///
    /// Replicated mode: `grad` is full-shape and the whole parameter is
    /// updated. Partitioned mode: `grad` is this replica's reduce-scattered
    /// `[padded]` chunk and only `param[offset .. offset+len]` is updated —
    /// elementwise identical arithmetic, so the two modes agree bitwise on
    /// the owned slice.
    pub fn step(&mut self, pairs: &mut [(&mut Tensor, &Tensor)], lr: f32) {
        let partition = self.partition.as_deref();
        // (owned start, owned len) in the full parameter for pair k; the
        // grad/state index of element j is `j` in partitioned mode (chunk
        // coordinates) and `offset + j == j` in replicated mode (offset 0).
        let span = |k: usize, pd_len: usize| match partition {
            Some(parts) => {
                let p = parts[k];
                (p.offset, p.len)
            }
            None => (0usize, pd_len),
        };
        match &mut self.kind {
            OptState::Sgd { momentum, velocity } => {
                assert_eq!(pairs.len(), velocity.len(), "param count changed");
                for (k, (p, g)) in pairs.iter_mut().enumerate() {
                    if p.is_phantom() || g.is_phantom() {
                        continue;
                    }
                    let gd = g.data();
                    let vd = velocity[k].data_mut();
                    let pd = p.data_mut();
                    let (off, len) = span(k, pd.len());
                    for i in 0..len {
                        vd[i] = *momentum * vd[i] + gd[i];
                        pd[off + i] -= lr * vd[i];
                    }
                }
            }
            OptState::Adam { beta1, beta2, eps, weight_decay, t, m, v } => {
                assert_eq!(pairs.len(), m.len(), "param count changed");
                *t += 1;
                let b1t = 1.0 - (*beta1).powi(*t as i32);
                let b2t = 1.0 - (*beta2).powi(*t as i32);
                for (k, (p, g)) in pairs.iter_mut().enumerate() {
                    if p.is_phantom() || g.is_phantom() {
                        continue;
                    }
                    let gd = g.data();
                    let md = m[k].data_mut();
                    let pd = p.data_mut();
                    // split borrows: v after m
                    let vd = v[k].data_mut();
                    let (off, len) = span(k, pd.len());
                    for i in 0..len {
                        let gi = gd[i] + *weight_decay * pd[off + i];
                        md[i] = *beta1 * md[i] + (1.0 - *beta1) * gi;
                        vd[i] = *beta2 * vd[i] + (1.0 - *beta2) * gi * gi;
                        let mhat = md[i] / b1t;
                        let vhat = vd[i] / b2t;
                        pd[off + i] -= lr * mhat / (vhat.sqrt() + *eps);
                    }
                }
            }
        }
    }

    /// The optimizer's state tensors in a stable order (Sgd: velocities;
    /// Adam: all first moments, then all second moments). Checkpointing
    /// and replica donation serialize exactly this sequence. In partitioned
    /// mode these are the `[padded]` chunks — each rank checkpoints only
    /// its own slice, and a restore rebuilds the same shapes from the same
    /// config, so the round-trip needs no special casing.
    pub fn state_tensors(&self) -> Vec<&Tensor> {
        match &self.kind {
            OptState::Sgd { velocity, .. } => velocity.iter().collect(),
            OptState::Adam { m, v, .. } => m.iter().chain(v.iter()).collect(),
        }
    }

    /// Mutable view of [`Optimizer::state_tensors`], same order.
    pub fn state_tensors_mut(&mut self) -> Vec<&mut Tensor> {
        match &mut self.kind {
            OptState::Sgd { velocity, .. } => velocity.iter_mut().collect(),
            OptState::Adam { m, v, .. } => m.iter_mut().chain(v.iter_mut()).collect(),
        }
    }

    /// Adam's bias-correction timestep (0 for Sgd, which has none).
    pub fn timestep(&self) -> u64 {
        match &self.kind {
            OptState::Sgd { .. } => 0,
            OptState::Adam { t, .. } => *t,
        }
    }

    /// Restore the bias-correction timestep (no-op for Sgd).
    pub fn set_timestep(&mut self, new_t: u64) {
        if let OptState::Adam { t, .. } = &mut self.kind {
            *t = new_t;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Xoshiro256;

    fn quad_loss(p: &Tensor) -> (f32, Tensor) {
        // L = 0.5‖p − 3‖²; grad = p − 3.
        let g = p.map(|v| v - 3.0);
        let l = 0.5 * g.data().iter().map(|&x| x * x).sum::<f32>();
        (l, g)
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        let cfg = TrainConfig { optimizer: OptimizerKind::Sgd, lr: 0.1, ..Default::default() };
        let mut p = Tensor::zeros(&[4]);
        let mut opt = Optimizer::new(&cfg, &[vec![4]]);
        for _ in 0..200 {
            let (_, g) = quad_loss(&p);
            opt.step(&mut [(&mut p, &g)], 0.1);
        }
        for &v in p.data() {
            assert!((v - 3.0).abs() < 1e-3, "{v}");
        }
    }

    #[test]
    fn adam_converges_on_quadratic() {
        let cfg = TrainConfig::default();
        let mut p = Tensor::zeros(&[4]);
        let mut opt = Optimizer::new(&cfg, &[vec![4]]);
        for _ in 0..500 {
            let (_, g) = quad_loss(&p);
            opt.step(&mut [(&mut p, &g)], 0.05);
        }
        for &v in p.data() {
            assert!((v - 3.0).abs() < 1e-2, "{v}");
        }
    }

    #[test]
    fn adam_is_deterministic() {
        let cfg = TrainConfig::default();
        let mut rng = Xoshiro256::seed_from_u64(1);
        let g = Tensor::randn(&[8], 1.0, &mut rng);
        let mut p1 = Tensor::ones(&[8]);
        let mut p2 = Tensor::ones(&[8]);
        let mut o1 = Optimizer::new(&cfg, &[vec![8]]);
        let mut o2 = Optimizer::new(&cfg, &[vec![8]]);
        for _ in 0..10 {
            o1.step(&mut [(&mut p1, &g)], 1e-3);
            o2.step(&mut [(&mut p2, &g)], 1e-3);
        }
        assert_eq!(p1, p2);
    }

    #[test]
    fn state_round_trip_resumes_bitwise() {
        // Copying state tensors + timestep into a fresh optimizer must make
        // it bit-identical to one that never stopped — the checkpoint /
        // replica-donation contract.
        let cfg = TrainConfig::default();
        let mut rng = Xoshiro256::seed_from_u64(2);
        let g = Tensor::randn(&[8], 1.0, &mut rng);
        let mut p_ref = Tensor::ones(&[8]);
        let mut opt_ref = Optimizer::new(&cfg, &[vec![8]]);
        for _ in 0..5 {
            opt_ref.step(&mut [(&mut p_ref, &g)], 1e-3);
        }
        let mut p_res = p_ref.clone();
        let mut opt_res = Optimizer::new(&cfg, &[vec![8]]);
        for (dst, src) in opt_res.state_tensors_mut().into_iter().zip(opt_ref.state_tensors()) {
            *dst = src.clone();
        }
        opt_res.set_timestep(opt_ref.timestep());
        assert_eq!(opt_res.timestep(), 5);
        for _ in 0..5 {
            opt_ref.step(&mut [(&mut p_ref, &g)], 1e-3);
            opt_res.step(&mut [(&mut p_res, &g)], 1e-3);
        }
        assert_eq!(p_ref, p_res);
    }

    #[test]
    fn lr_schedule_shape() {
        let cfg = TrainConfig { lr: 1.0, warmup: 10, steps: 110, ..Default::default() };
        assert!((lr_at(&cfg, 0) - 0.1).abs() < 1e-6);
        assert!((lr_at(&cfg, 9) - 1.0).abs() < 1e-6);
        assert!(lr_at(&cfg, 60) < 1.0);
        assert!(lr_at(&cfg, 109) >= 0.1 - 1e-6);
        // Monotone decay after warmup.
        assert!(lr_at(&cfg, 30) > lr_at(&cfg, 80));
    }

    /// Test-local mirror of `collectives::flat_chunks` boundaries: chunk
    /// `k` of `t` under an `r`-way partition, zero-padded to `ceil(n/r)`.
    fn chunk_of(t: &Tensor, r: usize, k: usize) -> Tensor {
        let n = t.numel();
        let padded = n.div_ceil(r);
        let mut v = vec![0.0f32; padded];
        let lo = (k * padded).min(n);
        let hi = ((k + 1) * padded).min(n);
        v[..hi - lo].copy_from_slice(&t.data()[lo..hi]);
        Tensor::from_vec(&[padded], v)
    }

    #[test]
    fn zero_partition_boundaries() {
        // Divisible: exact 1/r chunks.
        let p = zero_partition(&[vec![8], vec![2, 3]], 2, 1);
        assert_eq!(p[0], ParamPartition { offset: 4, len: 4, padded: 4 });
        assert_eq!(p[1], ParamPartition { offset: 3, len: 3, padded: 3 });
        // Padded boundary: n = 7, r = 2 → chunks of 4; the tail owner holds
        // 3 valid elements and one pad slot.
        let p = zero_partition(&[vec![7]], 2, 0);
        assert_eq!(p[0], ParamPartition { offset: 0, len: 4, padded: 4 });
        let p = zero_partition(&[vec![7]], 2, 1);
        assert_eq!(p[0], ParamPartition { offset: 4, len: 3, padded: 4 });
        // More replicas than elements: trailing replicas own empty spans.
        let p = zero_partition(&[vec![3]], 4, 3);
        assert_eq!(p[0], ParamPartition { offset: 3, len: 0, padded: 1 });
        // r = 1 degenerates to the full parameter.
        let p = zero_partition(&[vec![5]], 1, 0);
        assert_eq!(p[0], ParamPartition { offset: 0, len: 5, padded: 5 });
    }

    #[test]
    fn partitioned_adam_matches_full_adam_bitwise() {
        // r partitioned optimizers, each updating its owned slice of a
        // shared parameter set, must reproduce the replicated Adam update
        // bitwise — across divisible AND padded (n % r != 0) param counts.
        let cfg = TrainConfig::default();
        let mut rng = Xoshiro256::seed_from_u64(3);
        for shapes in [
            vec![vec![8], vec![2, 3]], // 8 % 2 == 0, 6 % 2 == 0
            vec![vec![7]],             // padded boundary
            vec![vec![5], vec![3, 3]], // both padded
        ] {
            let r = 2;
            let grads: Vec<Tensor> =
                shapes.iter().map(|s| Tensor::randn(s, 1.0, &mut rng)).collect();
            let mut full: Vec<Tensor> = shapes.iter().map(|s| Tensor::ones(s)).collect();
            let mut part: Vec<Tensor> = shapes.iter().map(|s| Tensor::ones(s)).collect();
            let mut opt_full = Optimizer::new(&cfg, &shapes);
            let mut opts: Vec<Optimizer> =
                (0..r).map(|k| Optimizer::new_partitioned(&cfg, &shapes, r, k)).collect();
            for _ in 0..4 {
                let mut pairs: Vec<(&mut Tensor, &Tensor)> =
                    full.iter_mut().zip(grads.iter()).collect();
                opt_full.step(&mut pairs, 1e-2);
                for (k, opt) in opts.iter_mut().enumerate() {
                    let chunks: Vec<Tensor> =
                        grads.iter().map(|g| chunk_of(g, r, k)).collect();
                    let mut pairs: Vec<(&mut Tensor, &Tensor)> =
                        part.iter_mut().zip(chunks.iter()).collect();
                    opt.step(&mut pairs, 1e-2);
                }
                for (f, p) in full.iter().zip(part.iter()) {
                    assert_eq!(f.data(), p.data(), "shapes {shapes:?}");
                }
            }
            // Per-rank optimizer-state memory is exactly Σ 2·ceil(n/r) f32s.
            let want: usize =
                shapes.iter().map(|s| 2 * s.iter().product::<usize>().div_ceil(r)).sum();
            for opt in &opts {
                let got: usize = opt.state_tensors().iter().map(|t| t.numel()).sum();
                assert_eq!(got, want);
            }
        }
    }

    #[test]
    fn partitioned_sgd_matches_full_sgd_bitwise() {
        let cfg = TrainConfig { optimizer: OptimizerKind::Sgd, ..Default::default() };
        let mut rng = Xoshiro256::seed_from_u64(4);
        let shapes = vec![vec![7], vec![4]];
        let r = 3;
        let grads: Vec<Tensor> = shapes.iter().map(|s| Tensor::randn(s, 1.0, &mut rng)).collect();
        let mut full: Vec<Tensor> = shapes.iter().map(|s| Tensor::ones(s)).collect();
        let mut part: Vec<Tensor> = shapes.iter().map(|s| Tensor::ones(s)).collect();
        let mut opt_full = Optimizer::new(&cfg, &shapes);
        let mut opts: Vec<Optimizer> =
            (0..r).map(|k| Optimizer::new_partitioned(&cfg, &shapes, r, k)).collect();
        for _ in 0..3 {
            let mut pairs: Vec<(&mut Tensor, &Tensor)> =
                full.iter_mut().zip(grads.iter()).collect();
            opt_full.step(&mut pairs, 0.1);
            for (k, opt) in opts.iter_mut().enumerate() {
                let chunks: Vec<Tensor> = grads.iter().map(|g| chunk_of(g, r, k)).collect();
                let mut pairs: Vec<(&mut Tensor, &Tensor)> =
                    part.iter_mut().zip(chunks.iter()).collect();
                opt.step(&mut pairs, 0.1);
            }
        }
        for (f, p) in full.iter().zip(part.iter()) {
            assert_eq!(f.data(), p.data());
        }
    }

    #[test]
    fn single_replica_partition_is_a_bitwise_noop() {
        // r = 1: the "partition" is the whole parameter; the partitioned
        // optimizer must be indistinguishable from the replicated one.
        let cfg = TrainConfig::default();
        let mut rng = Xoshiro256::seed_from_u64(5);
        let g = Tensor::randn(&[2, 3], 1.0, &mut rng);
        // The r=1 grad "chunk" is the flat view of the same data.
        let g_flat = Tensor::from_vec(&[6], g.data().to_vec());
        let mut p1 = Tensor::ones(&[2, 3]);
        let mut p2 = Tensor::ones(&[2, 3]);
        let mut o1 = Optimizer::new(&cfg, &[vec![2, 3]]);
        let mut o2 = Optimizer::new_partitioned(&cfg, &[vec![2, 3]], 1, 0);
        for _ in 0..6 {
            o1.step(&mut [(&mut p1, &g)], 1e-2);
            o2.step(&mut [(&mut p2, &g_flat)], 1e-2);
        }
        assert_eq!(p1.data(), p2.data());
    }

    #[test]
    fn clipping_caps_global_norm() {
        let mut g1 = Tensor::full(&[4], 3.0);
        let mut g2 = Tensor::full(&[4], 4.0);
        // ‖g‖ = sqrt(4·9 + 4·16) = 10.
        clip_grads(&mut [&mut g1, &mut g2], 5.0, None);
        let sq = g1.data().iter().chain(g2.data()).map(|&x| x * x).sum::<f32>();
        assert!((sq.sqrt() - 5.0).abs() < 1e-3);
        // Under the cap: untouched.
        let mut g3 = Tensor::full(&[2], 0.1);
        clip_grads(&mut [&mut g3], 5.0, None);
        assert_eq!(g3, Tensor::full(&[2], 0.1));
    }
}
