//! Minimal SPMD launcher: run one closure per rank on its own thread with a
//! connected [`Endpoint`]. This is the primitive beneath [`crate::engine`]
//! and the scaffolding used by every distributed test in the repo.

use crate::comm::fault::{CommAbort, FaultPlan};
use crate::comm::{Endpoint, NetModel, World};
use std::sync::Arc;
use std::thread;

/// Launch `n` ranks, each running `f(rank, endpoint)`; returns per-rank
/// results in rank order. A panicking rank propagates its panic to the
/// caller (after all threads have been joined), so distributed assertion
/// failures surface as ordinary test failures.
pub fn run_spmd<T: Send + 'static>(
    n: usize,
    net: NetModel,
    f: impl Fn(usize, &mut Endpoint) -> T + Send + Sync + 'static,
) -> Vec<T> {
    run_spmd_owned(n, net, None, vec![(); n], move |rank, (), ep| f(rank, ep))
}

/// The general launcher beneath [`run_spmd`]: each rank receives an owned
/// per-rank seed value (how the supervision loop threads trainer state
/// across restart generations without `Clone`), and an optional
/// [`FaultPlan`] is installed on the world before any endpoint is taken.
pub fn run_spmd_owned<S: Send + 'static, T: Send + 'static>(
    n: usize,
    net: NetModel,
    faults: Option<FaultPlan>,
    states: Vec<S>,
    f: impl Fn(usize, S, &mut Endpoint) -> T + Send + Sync + 'static,
) -> Vec<T> {
    assert_eq!(states.len(), n, "need exactly one seed state per rank");
    let mut world = World::new(n, net);
    if let Some(plan) = faults {
        world.install_faults(plan);
    }
    let f = Arc::new(f);
    let mut handles = Vec::with_capacity(n);
    for ((rank, mut ep), state) in world.endpoints().into_iter().enumerate().zip(states) {
        let f = f.clone();
        let builder = thread::Builder::new()
            .name(format!("cubic-rank-{rank}"))
            // Deep transformer stacks recurse through per-layer backward
            // closures; give workers a roomy stack.
            .stack_size(16 << 20);
        handles.push(
            builder
                .spawn(move || f(rank, state, &mut ep))
                .expect("failed to spawn worker thread"),
        );
    }
    let results: Vec<thread::Result<T>> = handles.into_iter().map(|h| h.join()).collect();
    results
        .into_iter()
        .enumerate()
        .map(|(rank, r)| match r {
            Ok(v) => v,
            Err(e) => {
                let msg = e
                    .downcast_ref::<String>()
                    .map(|s| s.as_str())
                    .or_else(|| e.downcast_ref::<&str>().copied())
                    .map(str::to_owned)
                    .or_else(|| e.downcast_ref::<CommAbort>().map(|a| a.0.to_string()))
                    .unwrap_or_else(|| "<non-string panic>".to_owned());
                panic!("rank {rank} panicked: {msg}");
            }
        })
        .collect()
}

/// Like [`run_spmd`] but also returns each rank's final [`Endpoint`] state
/// (virtual clock + comm stats) for the metrics layer.
pub fn run_spmd_with_stats<T: Send + 'static>(
    n: usize,
    net: NetModel,
    f: impl Fn(usize, &mut Endpoint) -> T + Send + Sync + 'static,
) -> Vec<(T, f64, crate::comm::CommStats)> {
    run_spmd(n, net, move |rank, ep| {
        let v = f(rank, ep);
        (v, ep.clock, ep.stats.clone())
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_all_ranks_and_orders_results() {
        let out = run_spmd(5, NetModel::zero(), |rank, _| rank * 2);
        assert_eq!(out, vec![0, 2, 4, 6, 8]);
    }

    #[test]
    #[should_panic(expected = "rank 2 panicked")]
    fn worker_panic_propagates() {
        run_spmd(4, NetModel::zero(), |rank, _| {
            if rank == 2 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn owned_states_are_threaded_per_rank() {
        let states: Vec<Vec<usize>> = (0..3).map(|r| vec![r * 10]).collect();
        let out = run_spmd_owned(3, NetModel::zero(), None, states, |rank, mut s, _| {
            s.push(rank);
            s
        });
        assert_eq!(out, vec![vec![0, 0], vec![10, 1], vec![20, 2]]);
    }

    #[test]
    fn comm_abort_panics_carry_the_typed_error() {
        use crate::comm::fault::{CommError, FaultPlan};
        let caught = std::panic::catch_unwind(|| {
            run_spmd_owned(
                2,
                NetModel::zero(),
                Some(FaultPlan { crashes: vec![(1, 0)], ..Default::default() }),
                vec![(), ()],
                |_, (), ep| {
                    ep.maybe_crash(0);
                },
            )
        })
        .unwrap_err();
        let msg = caught.downcast_ref::<String>().unwrap();
        assert!(msg.contains("rank 1 panicked"), "got: {msg}");
        assert!(msg.contains(&CommError::Crashed { rank: 1, step: 0 }.to_string()), "got: {msg}");
    }

    #[test]
    fn stats_variant_reports_clocks() {
        let out = run_spmd_with_stats(2, NetModel::flat(0.0, 1e9, 1e9), |_, ep| {
            ep.charge_flops(3e9);
        });
        for (_, clock, stats) in out {
            assert!((clock - 3.0).abs() < 1e-9);
            assert!((stats.compute_time - 3.0).abs() < 1e-9);
        }
    }
}
