//! `cubic` — launcher CLI for the 3-D tensor-parallel training framework.
//!
//! Subcommands:
//!   train         train a model on the simulated cluster (real numerics)
//!   bench-table1  regenerate paper Table 1 (weak scaling)
//!   bench-table2  regenerate paper Table 2 (strong scaling + headline)
//!   plan          print the shard plan for a config (no execution)
//!   serve         KV-cached decode + continuous batching (virtual clock)
//!   artifacts     list + smoke-test the AOT artifact bundle
//!   help          this text

use cubic::bench;
use cubic::cli::Args;
use cubic::comm::NetModel;
use cubic::config::{describe, CubicConfig};
use cubic::engine::run_training;
use cubic::model::ParEnv;
use cubic::rng::Xoshiro256;
use cubic::runtime::Runtime;
use cubic::tensor::Tensor;
use cubic::topology::Parallelism;

const HELP: &str = r#"cubic — 3-D tensor-parallel distributed training (Bian et al. 2021)

USAGE: cubic <command> [options]

COMMANDS
  train           train on the simulated cluster with real numerics
                    --config <file.toml>     load a config file
                    --save-dir <dir>         write rank-sharded checkpoints
                    --parallelism seq|1d|2d|3d|2.5d|hybrid[1d|2d|3d]|
                                  pipeline[1d|2d|3d|2.5d|hybrid] (default 3d)
                    --edge <n>               topology edge (default 2)
                    --depth <n>              2.5-D depth layers (default 2)
                    --replicas <n>           hybrid data-parallel replicas (default 2)
                    --zero-stage <0|1|2>     ZeRO optimizer-state sharding over the
                                             hybrid replicas (default 0 = replicated;
                                             numerics bit-identical either way)
                    --stages <n>             pipeline stages (default 2)
                    --micro-batches <n>      pipeline micro-batches (default 4)
                    --model tiny|charlm|large100m (default tiny)
                    --steps <n> --lr <f> --seed <n>
                    --ckpt-every <n>         checkpoint every n steps (0 = final only)
                    --fault-seed <n>         seed the deterministic fault injector
                    --drop-p <f>             per-attempt message drop probability
                    --crash-at R@S           crash rank R entering step S (recovers
                                             from checkpoint or a hybrid replica)
                                             (CUBIC_FAULTS env spec overrides all)
  bench-table1    regenerate paper Table 1 (weak scaling)
  bench-table2    regenerate paper Table 2 (strong scaling + speedups)
  plan            print the per-rank shard plan for a config, or — with
                  --world <n> — the cross-kind comparison table (every
                  parallelism kind decomposed at exactly n ranks, ranked
                  by phantom-mode step time; the opt/rank memory column
                  includes a hybrid+zero1 candidate showing the ZeRO
                  optimizer-state saving at identical step time)
  serve           KV-cached autoregressive inference with continuous
                  batching (see the serve module docs). Measures prefill +
                  per-step decode cost on the virtual clock, then replays a
                  seeded open-loop synthetic trace through the scheduler and
                  reports tokens/sec/rank with p50/p99 latency.
                    --world <n>              sweep every parallelism kind
                                             decomposed at exactly n ranks
                                             (phantom mode; paper-scale model)
                    --phantom                shape-only tensors + analytic
                                             compute charges (any world size)
                    --slots <n>              concurrent batch slots (default:
                                             world in sweep mode, else 4)
                    --max-seq <n>            KV rows per slot (default 64)
                    --prompt-len <n>         padded prefill length (default 16)
                    --gen-len <n>            decode steps (default 16)
                    --requests <n>           synthetic requests (default 64)
                    --arrival-rate <f>       open-loop req/s of virtual time
                                             (0 = auto-sweep 0.5/1/2 x the
                                             measured service rate)
                    --serve-seed <n>         traffic seed (default 9)
                  also honors --model/--parallelism/--edge/--depth/--replicas/
                  --stages (single-mesh mode when --world is absent)
  artifacts       list the AOT bundle and smoke-run one artifact
                    --dir <artifacts dir> (default ./artifacts)
  help            show this text

GLOBAL OPTIONS
  --threads <n>   cores for the multi-threaded gemm driver (0 = auto,
                  default; the CUBIC_THREADS env var overrides this)
  --overlap <0|1> overlap deferred collectives with compute on the virtual
                  clock (default 1; the CUBIC_OVERLAP env var overrides
                  this; numerics are bit-identical either way)
"#;

fn build_config(args: &Args) -> Result<CubicConfig, String> {
    let mut cfg = if let Some(path) = args.get("config") {
        CubicConfig::from_file(&path).map_err(|e| e.to_string())?
    } else {
        CubicConfig::default()
    };
    if let Some(m) = args.get("model") {
        cfg.model = match m.as_str() {
            "tiny" => cubic::config::ModelConfig::tiny(),
            "charlm" => cubic::config::ModelConfig::charlm(),
            "large100m" => cubic::config::ModelConfig::large100m(),
            other => return Err(format!("unknown model preset {other:?}")),
        };
    }
    if let Some(p) = args.get("parallelism") {
        cfg.parallelism =
            Parallelism::parse(&p).ok_or_else(|| format!("unknown parallelism {p:?}"))?;
    }
    if let Some(d) = args.get("depth") {
        let d: usize = d.parse().map_err(|e| format!("--depth {d:?}: {e}"))?;
        cfg.parallelism.set_depth(d).map_err(|e| format!("--depth: {e}"))?;
    }
    if let Some(r) = args.get("replicas") {
        let r: usize = r.parse().map_err(|e| format!("--replicas {r:?}: {e}"))?;
        cfg.parallelism.set_replicas(r).map_err(|e| format!("--replicas: {e}"))?;
    }
    if let Some(z) = args.get("zero-stage") {
        cfg.zero_stage = z.parse().map_err(|e| format!("--zero-stage {z:?}: {e}"))?;
    }
    if let Some(s) = args.get("stages") {
        let s: usize = s.parse().map_err(|e| format!("--stages {s:?}: {e}"))?;
        cfg.parallelism.set_stages(s).map_err(|e| format!("--stages: {e}"))?;
    }
    if let Some(m) = args.get("micro-batches") {
        let m: usize = m.parse().map_err(|e| format!("--micro-batches {m:?}: {e}"))?;
        cfg.parallelism.set_micro_batches(m).map_err(|e| format!("--micro-batches: {e}"))?;
    }
    cfg.edge = args.get_usize("edge", cfg.edge)?;
    cfg.train.steps = args.get_usize("steps", cfg.train.steps)?;
    cfg.train.lr = args.get_f64("lr", cfg.train.lr as f64)? as f32;
    cfg.train.seed = args.get_usize("seed", cfg.train.seed as usize)? as u64;
    cfg.threads = args.get_usize("threads", cfg.threads)?;
    if cfg.threads > 0 {
        cubic::tensor::kernel::threads::request_threads(cfg.threads);
    }
    cfg.overlap = args.get_usize("overlap", cfg.overlap as usize)? != 0;
    cfg.train.ckpt_every = args.get_usize("ckpt-every", cfg.train.ckpt_every)?;
    cfg.faults.seed = args.get_usize("fault-seed", cfg.faults.seed as usize)? as u64;
    cfg.faults.drop_p = args.get_f64("drop-p", cfg.faults.drop_p)?;
    if !(0.0..=1.0).contains(&cfg.faults.drop_p) {
        return Err(format!("--drop-p {} not in [0, 1]", cfg.faults.drop_p));
    }
    if let Some(spec) = args.get("crash-at") {
        let (r, s) = spec
            .split_once('@')
            .ok_or_else(|| format!("--crash-at {spec:?}: want R@S"))?;
        cfg.faults.crash = Some((
            r.parse().map_err(|e| format!("--crash-at rank {r:?}: {e}"))?,
            s.parse().map_err(|e| format!("--crash-at step {s:?}: {e}"))?,
        ));
    }
    // Env spec wins over flags and file, mirroring CUBIC_THREADS/OVERLAP.
    cfg.faults.apply_env()?;
    cfg.model
        .validate(cfg.parallelism, cfg.edge)
        .map_err(|e| format!("invalid config: {e}"))?;
    cfg.validate_zero().map_err(|e| format!("invalid config: {e}"))?;
    Ok(cfg)
}

fn cmd_train(args: &Args) -> Result<(), String> {
    let cfg = build_config(args)?;
    let save_dir = args.get("save-dir");
    eprintln!("training {}", describe(&cfg));
    let mut net = NetModel::longhorn_v100();
    net.set_overlap(cfg.overlap);
    let report = if let Some(dir) = save_dir {
        cubic::engine::run_training_with_checkpoint(&cfg, net, std::path::Path::new(&dir))
            .map_err(|e| e.to_string())?
    } else if cfg.faults.is_active() {
        cubic::engine::run_training_supervised(&cfg, net, None).map_err(|e| e.to_string())?
    } else {
        run_training(&cfg, net).map_err(|e| e.to_string())?
    };
    if report.recoveries > 0 {
        println!(
            "recovered from {} failure{} ({} retried sends, {} timeouts)",
            report.recoveries,
            if report.recoveries == 1 { "" } else { "s" },
            report.metrics.retries,
            report.metrics.timeouts,
        );
    }
    for (s, loss) in report.losses.iter().enumerate() {
        if s % cfg.train.log_every == 0 || s + 1 == report.losses.len() {
            println!("step {s:4}  loss {loss:.4}");
        }
    }
    println!(
        "done: {} steps, final loss {:.4}, {:.2} virtual ms/step, host {:.1}s",
        report.losses.len(),
        report.losses.last().unwrap(),
        1e3 * report.avg_step_virtual,
        report.metrics.host_seconds,
    );
    Ok(())
}

fn cmd_plan(args: &Args) -> Result<(), String> {
    let world = args.get_usize("world", 0)?;
    if world > 0 {
        let overlap = args.get_usize("overlap", 1)? != 0;
        return cmd_plan_world(world, overlap);
    }
    let cfg = build_config(args)?;
    println!("plan for {}", describe(&cfg));
    if cfg.zero_stage > 0 {
        println!(
            "zero stage {}: optimizer state partitioned 1/{} across the replica group",
            cfg.zero_stage,
            cfg.parallelism.data_replicas(),
        );
    }
    let world = cfg.parallelism.world_size(cfg.edge);
    let rows = cfg.model.batch * cfg.model.seq;
    for rank in 0..world {
        let env = ParEnv::new(cfg.parallelism, cfg.edge, rank);
        let block = env.phantom_block(&cfg.model);
        let (ar, ac) = env.activation_shape(rows, cfg.model.hidden);
        println!(
            "rank {rank:3}: activation {ar}x{ac}, block params {} ({} bytes), w_qkv {:?}",
            block.numel(),
            block.numel() * 4,
            block.w_qkv.shape(),
        );
    }
    Ok(())
}

/// `plan --world N`: one row per parallelism kind with an exact
/// decomposition at `N` ranks (plus the `seq` single-device baseline),
/// ranked by phantom-mode virtual step time on the calibrated network —
/// per-rank memory from the real shard shapes, per-rank communication from
/// the engine's traffic ledger. This is how 2-D vs 2.5-D vs 3-D vs hybrid
/// compare at equal world size before committing to a topology.
fn cmd_plan_world(world: usize, overlap: bool) -> Result<(), String> {
    use cubic::metrics::{fmt_bytes, Table};
    let cfg = cubic::config::ModelConfig::paper(4096, world.max(16));
    let rows = cfg.batch * cfg.seq;
    let mut net = NetModel::longhorn_v100();
    net.set_overlap(overlap);
    println!(
        "plan comparison at world size {world} (hidden {}, batch {}, seq {}, 1 layer; pipeline rows use 1 layer/stage)\n\
         ranked by {} step time{}\n",
        cfg.hidden,
        cfg.batch,
        cfg.seq,
        if net.overlap { "overlapped" } else { "serialized" },
        if net.overlap { " (deferred grad syncs hidden behind compute)" } else { "" },
    );
    let mut t = Table::new(&[
        "Kind", "Mesh", "Ranks", "weights/rank", "opt/rank", "acts/rank", "comm bytes/rank",
        "exposed comm", "bubble", "virtual step",
    ]);
    let mut rows_out: Vec<(f64, [String; 10])> = Vec::new();
    for cand in cubic::topology::plan_candidates(world) {
        let (par, edge) = (cand.par, cand.edge);
        // Pipeline rows need one layer per stage (the single-layer paper
        // shape cannot split); everything else keeps the 1-layer probe.
        let cfg_c = if let Parallelism::Pipeline { stages, .. } = par {
            cubic::config::ModelConfig { layers: stages, ..cfg.clone() }
        } else {
            cfg.clone()
        };
        if let Err(e) = cfg_c.validate(par, edge) {
            println!("  (skipping {} {}: {e})", par.name(), par.mesh_desc(edge));
            continue;
        }
        let w = par.world_size(edge);
        let r = par.data_replicas() as u64;
        let mut w_max = 0usize;
        let mut a_max = 0usize;
        let mut o_max = 0u64; // optimizer bytes (grads + Adam moments), replicated
        let mut oz_max = 0u64; // same under ZeRO stage 1
        for rank in 0..w {
            let env = ParEnv::new(par, edge, rank);
            let block = env.phantom_block(&cfg_c);
            w_max = w_max.max(block.numel() * 4);
            let numels = block.param_numels();
            o_max = o_max.max(cubic::costmodel::optimizer_bytes_per_rank(&numels, r, 0));
            oz_max = oz_max.max(cubic::costmodel::optimizer_bytes_per_rank(&numels, r, 1));
            let (ar, ac) = env.activation_shape(rows, cfg_c.hidden);
            a_max = a_max.max(ar * ac * 4);
        }
        let timing = cubic::engine::time_core_step(&cfg_c, par, edge, net.clone())
            .map_err(|e| e.to_string())?;
        let step = timing.forward_s + timing.backward_s;
        let bubble = if let Parallelism::Pipeline { stages, micro_batches, .. } = par {
            format!(
                "{:.2}",
                cubic::costmodel::pipeline_bubble_fraction(stages as u64, micro_batches as u64)
            )
        } else {
            "-".to_string()
        };
        let cells = [
            par.name().to_string(),
            par.mesh_desc(edge),
            w.to_string(),
            fmt_bytes(w_max as u64),
            fmt_bytes(o_max),
            fmt_bytes(a_max as u64),
            fmt_bytes(timing.metrics.total_bytes / w.max(1) as u64),
            format!("{:.4}s", timing.metrics.exposed_comm_time),
            bubble,
            format!("{step:.4}s"),
        ];
        if matches!(par, Parallelism::Hybrid { .. }) {
            // The ZeRO stage-1 candidate: identical timing (the grad
            // reduce-scatter plus the post-step weight all-gather send
            // exactly the bytes of the all-reduce they replace), 1/r the
            // optimizer-moment memory.
            let mut zcells = cells.clone();
            zcells[0] = "hybrid+zero1".to_string();
            zcells[4] = fmt_bytes(oz_max);
            rows_out.push((step, zcells));
        }
        rows_out.push((step, cells));
    }
    // Fastest mesh first — the documented ranking.
    rows_out.sort_by(|a, b| a.0.total_cmp(&b.0));
    for (_, cells) in &rows_out {
        t.row(cells);
    }
    println!("{}", t.to_markdown());
    Ok(())
}

/// `cubic serve`: measure one serving window per mesh on the virtual clock
/// (prefill + `gen_len` decode steps), then replay a seeded open-loop trace
/// through the continuous-batching scheduler at one or three arrival rates.
/// With `--world N` every plan candidate at exactly `N` ranks is swept in
/// phantom mode (invalid serve shapes are skipped with a note); without it
/// the configured single parallelism runs, with real numerics unless
/// `--phantom` is given.
fn cmd_serve(args: &Args) -> Result<(), String> {
    use cubic::metrics::{fmt_bytes, Table};
    let world = args.get_usize("world", 0)?;
    let sweep = world > 0;
    let phantom = args.flag("phantom") || sweep;
    let mut cfg = build_config(args)?;
    cfg.serve.slots =
        args.get_usize("slots", if sweep { world } else { cfg.serve.slots })?;
    cfg.serve.max_seq = args.get_usize("max-seq", cfg.serve.max_seq)?;
    cfg.serve.prompt_len = args.get_usize("prompt-len", cfg.serve.prompt_len)?;
    cfg.serve.gen_len = args.get_usize("gen-len", cfg.serve.gen_len)?;
    cfg.serve.requests = args.get_usize("requests", cfg.serve.requests)?;
    cfg.serve.arrival_rate = args.get_f64("arrival-rate", cfg.serve.arrival_rate)?;
    cfg.serve.seed = args.get_usize("serve-seed", cfg.serve.seed as usize)? as u64;
    let mut net = NetModel::longhorn_v100();
    net.set_overlap(cfg.overlap);
    // Sweep mode probes the paper-scale model (the tiny default cannot
    // split 64 ways); single-mesh mode serves the configured model.
    let model = if sweep { cubic::config::ModelConfig::paper(4096, 16) } else { cfg.model.clone() };
    let candidates: Vec<(Parallelism, usize)> = if sweep {
        cubic::topology::plan_candidates(world).into_iter().map(|c| (c.par, c.edge)).collect()
    } else {
        vec![(cfg.parallelism, cfg.edge)]
    };
    println!(
        "serve: slots {}, prompt {}, gen {}, max_seq {}, {} requests, seed {}{}",
        cfg.serve.slots,
        cfg.serve.prompt_len,
        cfg.serve.gen_len,
        cfg.serve.max_seq,
        cfg.serve.requests,
        cfg.serve.seed,
        if phantom { " (phantom)" } else { "" },
    );
    let mut t = Table::new(&[
        "Kind", "Mesh", "Ranks", "tok/s/rank", "KV/rank", "rate req/s", "p50(s)", "p99(s)",
        "mean(s)",
    ]);
    let mut trace: Option<(String, f64, Vec<String>)> = None;
    let mut any = false;
    for (par, edge) in candidates {
        // Pipeline stages each own a contiguous layer slice; the 1-layer
        // paper probe cannot split, so give it one layer per stage.
        let cfg_c = if let Parallelism::Pipeline { stages, .. } = par {
            cubic::config::ModelConfig { layers: model.layers.max(stages), ..model.clone() }
        } else {
            model.clone()
        };
        if let Err(e) = cfg_c.validate_serve(par, edge, &cfg.serve) {
            println!("  (skipping {} {}: {e})", par.name(), par.mesh_desc(edge));
            continue;
        }
        let m = cubic::engine::time_serve(
            &cfg_c, &cfg.serve, par, edge, net.clone(), phantom, cfg.train.seed,
        )
        .map_err(|e| e.to_string())?;
        let w = par.world_size(edge);
        let head_dim = cfg_c.hidden / cfg_c.heads;
        let kv_bytes = cfg_c.layers as u64
            * cubic::costmodel::kv_cache_bytes_per_rank(
                par,
                edge,
                0,
                cfg.serve.slots as u64,
                cfg_c.heads as u64,
                head_dim as u64,
                cfg.serve.max_seq as u64,
            );
        // Open-loop rates: the user's, or a 0.5/1/2x sweep around the
        // measured steady-state service rate of the slot grid.
        let window = m.prefill_s + m.decode_total_s;
        let service_rate = cfg.serve.slots as f64 / window.max(1e-12);
        let rates: Vec<f64> = if cfg.serve.arrival_rate > 0.0 {
            vec![cfg.serve.arrival_rate]
        } else {
            vec![0.5 * service_rate, service_rate, 2.0 * service_rate]
        };
        for rate in rates {
            let sv = cubic::config::ServeConfig { arrival_rate: rate, ..cfg.serve.clone() };
            let sim = cubic::serve::simulate(&sv, m.prefill_s, &m.decode_step_s);
            t.row(&[
                par.name().to_string(),
                par.mesh_desc(edge),
                w.to_string(),
                format!("{:.1}", m.tokens_per_sec_per_rank),
                fmt_bytes(kv_bytes),
                format!("{rate:.2}"),
                format!("{:.4}", sim.p50),
                format!("{:.4}", sim.p99),
                format!("{:.4}", sim.mean),
            ]);
            if trace.is_none() {
                trace = Some((
                    format!("{} {}", par.name(), par.mesh_desc(edge)),
                    rate,
                    sim.requests.iter().take(10).map(|r| r.trace_line()).collect(),
                ));
            }
            any = true;
        }
    }
    if !any {
        return Err("no parallelism kind admits this serve config".into());
    }
    println!("{}", t.to_markdown());
    if let Some((mesh, rate, lines)) = trace {
        println!("request trace ({mesh}, rate {rate:.2} req/s, first {}):", lines.len());
        for l in &lines {
            println!("{l}");
        }
    }
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<(), String> {
    let dir = args.get("dir").unwrap_or_else(|| "artifacts".into());
    let rt = Runtime::load(&dir).map_err(|e| e.to_string())?;
    let names = rt.manifest.names();
    println!("{} artifacts in {dir}:", names.len());
    for n in &names {
        let e = rt.manifest.get(n).unwrap();
        println!("  {n}  in={:?} out={:?}", e.in_shapes, e.out_shape);
    }
    if let Some(name) = names.iter().find(|n| n.starts_with("mm_nn_")) {
        let e = rt.manifest.get(name).unwrap().clone();
        let mut rng = Xoshiro256::seed_from_u64(0);
        let a = Tensor::randn(&e.in_shapes[0], 1.0, &mut rng);
        let b = Tensor::randn(&e.in_shapes[1], 1.0, &mut rng);
        let got = rt
            .handle()
            .execute(name, &[a.clone(), b.clone()])
            .map_err(|e| e.to_string())?;
        let diff = got.max_abs_diff(&a.matmul(&b));
        println!("smoke {name}: PJRT vs native max diff {diff:.2e}");
        if diff > 1e-3 {
            return Err("artifact smoke test FAILED".into());
        }
    }
    Ok(())
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(args) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{HELP}");
            std::process::exit(2);
        }
    };
    // Gemm thread count for commands that don't build a config (the bench
    // tables); train/plan apply it through `build_config` so the file knob
    // participates too. Selection latches on first matmul — see
    // `kernel::threads::selected_threads`.
    match args.get_usize("threads", 0) {
        Ok(0) => {}
        Ok(n) => cubic::tensor::kernel::threads::request_threads(n),
        Err(e) => {
            eprintln!("error: {e}\n\n{HELP}");
            std::process::exit(2);
        }
    }
    let result = match args.command.as_deref() {
        Some("train") => cmd_train(&args),
        Some("bench-table1") => {
            let results = bench::run_rows(&bench::table1_rows(), &NetModel::longhorn_v100());
            println!("{}", bench::render("Table 1 — weak scaling", &results));
            Ok(())
        }
        Some("bench-table2") => {
            let results = bench::run_rows(&bench::table2_rows(), &NetModel::longhorn_v100());
            println!("{}", bench::render("Table 2 — strong scaling", &results));
            let (s1, s2) = bench::strong_scaling_speedups(&results);
            println!("3-D speedup at 64 GPUs: {s1:.2}x vs 1-D (paper 2.32x), {s2:.2}x vs 2-D (paper 1.57x)");
            Ok(())
        }
        Some("plan") => cmd_plan(&args),
        Some("serve") => cmd_serve(&args),
        Some("artifacts") => cmd_artifacts(&args),
        Some("help") | None => {
            println!("{HELP}");
            Ok(())
        }
        Some(other) => Err(format!("unknown command {other:?}\n\n{HELP}")),
    };
    let unknown = args.unknown();
    if !unknown.is_empty() {
        eprintln!("warning: unused options: {unknown:?}");
    }
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
