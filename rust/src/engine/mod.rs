//! Cluster engine: the leader that spawns the worker ranks, runs training
//! or timing workloads over them, and aggregates results.
//!
//! Two entry points:
//!
//! * [`run_training`] — materialized numerics: spawn `P` workers, each a
//!   [`crate::train::TrainerRank`], run the configured steps, return the
//!   loss curve plus run metrics. This is what `cubic train` and the e2e
//!   example drive.
//! * [`time_core_step`] — the paper's measurement: one forward + backward
//!   of the Transformer core in phantom mode (shape-only tensors, analytic
//!   compute charges, real collective schedules) on the virtual-clock
//!   cluster. Benches regenerating Tables 1 & 2 call this per row.

use crate::comm::NetModel;
use crate::config::CubicConfig;
use crate::metrics::{RunMetrics, Stopwatch};
use crate::model::{core_bwd, core_fwd, BlockTensors, ParEnv};
use crate::spmd::run_spmd_with_stats;
use crate::tensor::Tensor;
use crate::topology::Parallelism;
use crate::train::TrainerRank;
use anyhow::{bail, Result};

/// Aggregated result of a training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub losses: Vec<f32>,
    /// Virtual seconds per step (max over ranks, averaged over steps).
    pub avg_step_virtual: f64,
    pub metrics: RunMetrics,
}

/// Train the configured model on a simulated cluster with real numerics.
pub fn run_training(cfg: &CubicConfig, net: NetModel) -> Result<TrainReport> {
    cfg.model
        .validate(cfg.parallelism, cfg.edge)
        .map_err(|e| anyhow::anyhow!("invalid config: {e}"))?;
    let world = cfg.parallelism.world_size(cfg.edge);
    let cfg2 = cfg.clone();
    let sw = Stopwatch::start();
    let results = run_spmd_with_stats(world, net, move |rank, ep| {
        let mut trainer = TrainerRank::new(&cfg2, rank);
        trainer.run(ep)
    });
    let host = sw.seconds();
    let (report0, _, _) = &results[0];
    // Loss must be identical on every rank (replicated head) — a cheap
    // whole-system consistency check we always enforce.
    for (r, (rep, _, _)) in results.iter().enumerate() {
        if rep.losses != report0.losses {
            bail!("rank {r} diverged from rank 0 loss curve");
        }
    }
    let per_rank: Vec<(f64, crate::comm::CommStats)> =
        results.iter().map(|(_, c, s)| (*c, s.clone())).collect();
    let metrics = RunMetrics::from_ranks(&per_rank, host);
    let steps = report0.losses.len().max(1) as f64;
    Ok(TrainReport {
        losses: report0.losses.clone(),
        avg_step_virtual: metrics.virtual_time / steps,
        metrics,
    })
}

/// Like [`run_training`] but each rank writes a rank-sharded checkpoint of
/// its final model shards (plus the replicated boundary layers on rank 0)
/// to `dir` — the Megatron-style persistence layout.
pub fn run_training_with_checkpoint(
    cfg: &CubicConfig,
    net: NetModel,
    dir: &std::path::Path,
) -> Result<TrainReport> {
    cfg.model
        .validate(cfg.parallelism, cfg.edge)
        .map_err(|e| anyhow::anyhow!("invalid config: {e}"))?;
    let world = cfg.parallelism.world_size(cfg.edge);
    let cfg2 = cfg.clone();
    let dir2 = dir.to_path_buf();
    let sw = Stopwatch::start();
    let results = run_spmd_with_stats(world, net, move |rank, ep| {
        let mut trainer = TrainerRank::new(&cfg2, rank);
        let report = trainer.run(ep);
        let extra: Vec<(String, &crate::tensor::Tensor)> = if rank == 0 {
            vec![
                ("emb.table".into(), &trainer.emb.table),
                ("emb.pos".into(), &trainer.emb.pos),
                ("head.ln_g".into(), &trainer.head.ln_g),
                ("head.ln_b".into(), &trainer.head.ln_b),
                ("head.w".into(), &trainer.head.w),
                ("head.b".into(), &trainer.head.b),
            ]
        } else {
            Vec::new()
        };
        crate::train::checkpoint::save_rank(&dir2, rank, &trainer.blocks, &extra)
            .expect("checkpoint save failed");
        report
    });
    let host = sw.seconds();
    let per_rank: Vec<(f64, crate::comm::CommStats)> =
        results.iter().map(|(_, c, s)| (*c, s.clone())).collect();
    let metrics = RunMetrics::from_ranks(&per_rank, host);
    let report0 = results[0].0.clone();
    let steps = report0.losses.len().max(1) as f64;
    Ok(TrainReport {
        losses: report0.losses,
        avg_step_virtual: metrics.virtual_time / steps,
        metrics,
    })
}

/// Result of a phantom-mode timing run of the core (the paper's measured
/// quantity: forward + backward of the consecutive Transformer layers).
#[derive(Clone, Debug)]
pub struct CoreTiming {
    /// Virtual seconds for the forward passes of all layers.
    pub forward_s: f64,
    /// Virtual seconds for the backward passes.
    pub backward_s: f64,
    pub metrics: RunMetrics,
}

impl CoreTiming {
    /// The paper's Eq. 6: (fwd + bwd) / batch.
    pub fn avg_step_time(&self, batch: usize) -> f64 {
        (self.forward_s + self.backward_s) / batch as f64
    }
}

/// Time one fwd+bwd of the Transformer core in phantom mode.
///
/// `repeats` forward/backward passes are timed (the paper runs multiple
/// iterations; virtual time is deterministic so 1 is exact, but repeats
/// exercise steady-state tag reuse).
///
/// NOTE: intentionally does *not* call `ModelConfig::validate` — the
/// paper's own Table 2 configs (e.g. batch 24 on a 4³ cube) split
/// sequences across ranks, which the timing path models analytically
/// (see `model::attention`).
pub fn time_core_step(
    cfg: &crate::config::ModelConfig,
    par: Parallelism,
    edge: usize,
    net: NetModel,
) -> Result<CoreTiming> {
    let world = par.world_size(edge);
    let cfg2 = cfg.clone();
    let rows = cfg.batch * cfg.seq;
    let sw = Stopwatch::start();
    let results = run_spmd_with_stats(world, net, move |rank, ep| {
        let env = ParEnv::new(par, edge, rank);
        let blocks: Vec<BlockTensors> =
            (0..cfg2.layers).map(|_| env.phantom_block(&cfg2)).collect();
        let (lr, lc) = env.activation_shape(rows, cfg2.hidden);
        let x = Tensor::phantom(&[lr, lc]);
        let (y, caches) = core_fwd(ep, env.ops(), &blocks, &x, &cfg2);
        let fwd_clock = ep.clock;
        let dy = Tensor::phantom(y.shape());
        let _ = core_bwd(ep, env.ops(), &blocks, &caches, &dy, &cfg2);
        // The optimizer boundary: deferred grad syncs still in flight must
        // land before the step ends, so backward time includes whatever
        // communication the compute could not hide.
        ep.join_all();
        let bwd_clock = ep.clock;
        (fwd_clock, bwd_clock)
    });
    let host = sw.seconds();
    let fwd = results.iter().map(|((f, _), _, _)| *f).fold(0.0, f64::max);
    let total = results.iter().map(|((_, b), _, _)| *b).fold(0.0, f64::max);
    let per_rank: Vec<(f64, crate::comm::CommStats)> =
        results.iter().map(|(_, c, s)| (*c, s.clone())).collect();
    Ok(CoreTiming {
        forward_s: fwd,
        backward_s: total - fwd,
        metrics: RunMetrics::from_ranks(&per_rank, host),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CubicConfig, ModelConfig, TrainConfig};

    #[test]
    fn tiny_training_runs_and_loss_drops_seq() {
        let cfg = CubicConfig {
            model: ModelConfig {
                layers: 1,
                ..ModelConfig::tiny()
            },
            train: TrainConfig { steps: 12, lr: 3e-3, warmup: 2, ..Default::default() },
            parallelism: Parallelism::Seq,
            edge: 1,
            ..CubicConfig::default()
        };
        let rep = run_training(&cfg, NetModel::zero()).unwrap();
        assert_eq!(rep.losses.len(), 12);
        let first = rep.losses[0];
        let last = *rep.losses.last().unwrap();
        assert!(
            last < first,
            "loss should drop: {first} -> {last} ({:?})",
            rep.losses
        );
    }

    #[test]
    fn invalid_config_is_rejected() {
        let mut cfg = CubicConfig::default();
        cfg.model.batch = 3; // 3 % 4 != 0 for p=2 cube
        assert!(run_training(&cfg, NetModel::zero()).is_err());
    }

    #[test]
    fn phantom_timing_runs_at_paper_scale_3d() {
        // Table 2's 3-D row: 64 GPUs (p=4), batch 24, hidden 3072, seq 512.
        let cfg = ModelConfig::paper(3072, 24);
        let t = time_core_step(&cfg, Parallelism::ThreeD, 4, NetModel::longhorn_v100())
            .unwrap();
        assert!(t.forward_s > 0.0);
        assert!(t.backward_s > 0.8 * t.forward_s, "bwd should be comparable to fwd");
        assert!(t.metrics.total_bytes > 0);
    }

    #[test]
    fn phantom_timing_backward_roughly_double_forward() {
        let cfg = ModelConfig::paper(1024, 8);
        for (par, edge) in [
            (Parallelism::OneD, 8),
            (Parallelism::TwoD, 2),
            (Parallelism::ThreeD, 2),
            (Parallelism::TwoFiveD { depth: 2 }, 2),
            (
                Parallelism::Hybrid {
                    replicas: 2,
                    inner: crate::topology::HybridInner::TwoD,
                },
                2,
            ),
        ] {
            let t = time_core_step(&cfg, par, edge, NetModel::longhorn_v100()).unwrap();
            let ratio = t.backward_s / t.forward_s;
            assert!(
                (1.05..4.0).contains(&ratio),
                "{par:?}: bwd/fwd ratio {ratio} out of range"
            );
        }
    }
}

#[cfg(test)]
mod checkpoint_tests {
    use super::*;
    use crate::config::{CubicConfig, ModelConfig, TrainConfig};

    #[test]
    fn training_with_checkpoint_writes_all_rank_files() {
        let dir = std::env::temp_dir().join(format!("cubic-engine-ckpt-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = CubicConfig {
            model: ModelConfig { layers: 1, ..ModelConfig::tiny() },
            train: TrainConfig { steps: 3, ..Default::default() },
            parallelism: crate::topology::Parallelism::ThreeD,
            edge: 2,
            ..CubicConfig::default()
        };
        let rep = run_training_with_checkpoint(&cfg, NetModel::zero(), &dir).unwrap();
        assert_eq!(rep.losses.len(), 3);
        for rank in 0..8 {
            let path = dir.join(format!("rank-{rank}.bin"));
            assert!(path.exists(), "missing {}", path.display());
        }
        // Shards restore into a matching topology.
        let dense = crate::model::init_dense_blocks(&cfg.model, 123);
        let env = crate::model::ParEnv::new(crate::topology::Parallelism::ThreeD, 2, 3);
        let mut blocks = env.shard_blocks(&dense);
        crate::train::checkpoint::load_rank(&dir, 3, &mut blocks).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }
}
