//! Cluster engine: the leader that spawns the worker ranks, runs training
//! or timing workloads over them, and aggregates results.
//!
//! Two entry points:
//!
//! * [`run_training`] — materialized numerics: spawn `P` workers, each a
//!   [`crate::train::TrainerRank`], run the configured steps, return the
//!   loss curve plus run metrics. This is what `cubic train` and the e2e
//!   example drive.
//! * [`time_core_step`] — the paper's measurement: one forward + backward
//!   of the Transformer core in phantom mode (shape-only tensors, analytic
//!   compute charges, real collective schedules) on the virtual-clock
//!   cluster. Benches regenerating Tables 1 & 2 call this per row.

use crate::comm::fault::CommError;
use crate::comm::{CommStats, NetModel};
use crate::config::CubicConfig;
use crate::metrics::{RunMetrics, Stopwatch};
use crate::model::{core_bwd, core_fwd, BlockTensors, ParEnv};
use crate::spmd::{run_spmd_owned, run_spmd_with_stats};
use crate::tensor::Tensor;
use crate::topology::Parallelism;
use crate::train::{RankOutcome, TrainerRank};
use anyhow::{bail, Result};
use std::collections::HashMap;

/// Aggregated result of a training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub losses: Vec<f32>,
    /// Virtual seconds per step (max over ranks, averaged over steps).
    pub avg_step_virtual: f64,
    pub metrics: RunMetrics,
    /// Restart generations the supervision loop needed (0 = clean run).
    pub recoveries: usize,
}

/// Train the configured model on a simulated cluster with real numerics.
pub fn run_training(cfg: &CubicConfig, net: NetModel) -> Result<TrainReport> {
    cfg.model
        .validate(cfg.parallelism, cfg.edge)
        .map_err(|e| anyhow::anyhow!("invalid config: {e}"))?;
    let world = cfg.parallelism.world_size(cfg.edge);
    let cfg2 = cfg.clone();
    let sw = Stopwatch::start();
    let results = run_spmd_with_stats(world, net, move |rank, ep| {
        let mut trainer = TrainerRank::new(&cfg2, rank);
        trainer.run(ep)
    });
    let host = sw.seconds();
    let (report0, _, _) = &results[0];
    // Loss must be identical on every rank (replicated head) — a cheap
    // whole-system consistency check we always enforce.
    for (r, (rep, _, _)) in results.iter().enumerate() {
        if rep.losses != report0.losses {
            bail!("rank {r} diverged from rank 0 loss curve");
        }
    }
    let per_rank: Vec<(f64, crate::comm::CommStats)> =
        results.iter().map(|(_, c, s)| (*c, s.clone())).collect();
    let metrics = RunMetrics::from_ranks(&per_rank, host);
    let steps = report0.losses.len().max(1) as f64;
    Ok(TrainReport {
        losses: report0.losses.clone(),
        avg_step_virtual: metrics.virtual_time / steps,
        metrics,
        recoveries: 0,
    })
}

/// Per-rank seed for one supervision generation: how this rank obtains the
/// trainer state it resumes from.
enum RankSeed {
    /// Fresh trainer at step 0 (first generation, or no checkpoint survived).
    Fresh,
    /// Continue with the in-memory state a surviving rank carried over.
    Keep(Box<TrainerRank>, Vec<f32>),
    /// Reload blocks + optimizer state from the checkpoint directory.
    Restore,
    /// Fresh trainer that adopts the full state a healthy replica donates
    /// over comm before training resumes (Hybrid recovery, no disk).
    Adopt { from: usize },
    /// Survivor that first streams its state to each restarted rank in
    /// `to`, then continues with it.
    Donate(Box<TrainerRank>, Vec<f32>, Vec<usize>),
}

/// Train under fault supervision: run generations of [`TrainerRank::run_supervised`]
/// until every rank completes, recovering from typed comm failures between
/// generations. Recovery prefers, in order:
///
/// 1. **Keep** — no rank crashed (drops/timeouts only): every rank still
///    holds valid state at the common failed step; resume in place.
/// 2. **Replica donation** — `Hybrid` meshes with a healthy counterpart
///    (same inner rank, another replica): the crashed rank restarts fresh
///    and receives weights + optimizer state over comm.
/// 3. **Checkpoint restore** — rewind *all* ranks to the last completed
///    checkpoint boundary in `dir`.
/// 4. **Fresh** — no checkpoint yet: restart from step 0.
///
/// Replay is deterministic, so a recovered run is bit-identical in its loss
/// curve to the fault-free run (crashes only fire in generation 0; the
/// generation salt reshuffles drop coins so a restart cannot re-fail
/// identically). Virtual time accumulates across generations via
/// [`RunMetrics::chain`] — the recovery overhead is visible, not hidden.
pub fn run_training_supervised(
    cfg: &CubicConfig,
    net: NetModel,
    dir: Option<&std::path::Path>,
) -> Result<TrainReport> {
    cfg.model
        .validate(cfg.parallelism, cfg.edge)
        .map_err(|e| anyhow::anyhow!("invalid config: {e}"))?;
    let world = cfg.parallelism.world_size(cfg.edge);
    let steps = cfg.train.steps;
    let ckpt_every = cfg.train.ckpt_every;
    let base_plan = cfg.faults.is_active().then(|| cfg.faults.to_plan());
    let max_recoveries = base_plan.as_ref().map_or(0, |p| p.max_recoveries);
    let dir_buf = dir.map(std::path::Path::to_path_buf);
    let sw = Stopwatch::start();

    let mut seeds: Vec<RankSeed> = (0..world).map(|_| RankSeed::Fresh).collect();
    let mut start = 0usize;
    let mut generation = 0u64;
    let mut recoveries = 0usize;
    let mut acc: Option<RunMetrics> = None;
    loop {
        let cfg2 = cfg.clone();
        let dir2 = dir_buf.clone();
        let gen_start = start;
        let plan = base_plan.clone().map(|p| p.with_generation(generation));
        let results = run_spmd_owned(
            world,
            net.clone(),
            plan,
            std::mem::take(&mut seeds),
            move |rank, seed, ep: &mut crate::comm::Endpoint| {
                let (trainer, losses) = match seed {
                    RankSeed::Fresh => (Box::new(TrainerRank::new(&cfg2, rank)), Vec::new()),
                    RankSeed::Keep(t, l) => (t, l),
                    RankSeed::Restore => {
                        let d = dir2.as_ref().expect("restore planned without a checkpoint dir");
                        let (t, done, l) = TrainerRank::load_checkpoint(&cfg2, rank, d)
                            .expect("checkpoint restore failed");
                        assert_eq!(done, gen_start, "checkpoint not at the planned restart step");
                        (t, l)
                    }
                    RankSeed::Adopt { from } => {
                        let mut t = Box::new(TrainerRank::new(&cfg2, rank));
                        let l = t.receive_donation(ep, from, gen_start);
                        (t, l)
                    }
                    RankSeed::Donate(t, l, targets) => {
                        for to in targets {
                            t.send_donation(ep, to, &l);
                        }
                        (t, l)
                    }
                };
                let out = trainer.run_supervised(
                    ep,
                    gen_start,
                    steps,
                    ckpt_every,
                    dir2.as_deref(),
                    losses,
                    Vec::new(),
                );
                (out, ep.clock, ep.stats.clone())
            },
        );
        let per_rank: Vec<(f64, CommStats)> =
            results.iter().map(|(_, c, s)| (*c, s.clone())).collect();
        let gen_metrics = RunMetrics::from_ranks(&per_rank, 0.0);
        match &mut acc {
            None => acc = Some(gen_metrics),
            Some(m) => m.chain(&gen_metrics),
        }
        let outcomes: Vec<RankOutcome> = results.into_iter().map(|(o, _, _)| o).collect();

        if outcomes.iter().all(|o| o.completed) {
            let losses0 = outcomes[0].losses.clone();
            for (r, o) in outcomes.iter().enumerate() {
                if o.losses != losses0 {
                    bail!("rank {r} diverged from rank 0 loss curve");
                }
            }
            let mut metrics = acc.expect("at least one generation ran");
            metrics.host_seconds = sw.seconds();
            let n = losses0.len().max(1) as f64;
            return Ok(TrainReport {
                losses: losses0,
                avg_step_virtual: metrics.virtual_time / n,
                metrics,
                recoveries,
            });
        }

        // A generation failed: decide how the next one resumes.
        if recoveries >= max_recoveries {
            let errs: Vec<String> = outcomes
                .iter()
                .enumerate()
                .filter_map(|(r, o)| o.error.as_ref().map(|e| format!("rank {r}: {e}")))
                .collect();
            bail!(
                "training failed after {recoveries} recoveries (budget {max_recoveries}): {}",
                errs.join("; ")
            );
        }
        recoveries += 1;
        generation += 1;
        let failed_step = outcomes.iter().map(|o| o.losses.len()).min().unwrap_or(0);
        let aligned = outcomes.iter().all(|o| o.losses.len() == failed_step);
        let crashed: Vec<usize> = outcomes
            .iter()
            .enumerate()
            .filter(|(_, o)| matches!(o.error, Some(CommError::Crashed { .. })))
            .map(|(r, _)| r)
            .collect();
        let survivors_hold_state = aligned
            && outcomes
                .iter()
                .enumerate()
                .all(|(r, o)| crashed.contains(&r) || o.trainer.is_some());

        if survivors_hold_state && crashed.is_empty() {
            // Drops/timeouts only: every rank resumes in place.
            seeds = outcomes
                .into_iter()
                .map(|o| RankSeed::Keep(o.trainer.expect("survivor holds state"), o.losses))
                .collect();
            start = failed_step;
            continue;
        }

        if survivors_hold_state && cfg.zero_stage == 0 {
            // Crashes with survivors: try replica donation on Hybrid meshes.
            // Under ZeRO the dead rank's optimizer-moment partition died
            // with it — no single surviving replica holds the full state a
            // donation needs (reassembling it would take the whole replica
            // group), so recovery falls through to the checkpoint Restore
            // path below.
            if let Parallelism::Hybrid { replicas, .. } = cfg.parallelism {
                let iw = world / replicas;
                let mut donors: HashMap<usize, usize> = HashMap::new(); // crashed -> donor
                let all_covered = crashed.iter().all(|&cr| {
                    let j = cr % iw;
                    match (0..replicas).map(|c| c * iw + j).find(|d| !crashed.contains(d)) {
                        Some(d) => {
                            donors.insert(cr, d);
                            true
                        }
                        None => false,
                    }
                });
                if all_covered {
                    let mut targets: HashMap<usize, Vec<usize>> = HashMap::new();
                    for (&cr, &d) in &donors {
                        targets.entry(d).or_default().push(cr);
                    }
                    // Deterministic donation order regardless of map iteration.
                    for ts in targets.values_mut() {
                        ts.sort_unstable();
                    }
                    seeds = outcomes
                        .into_iter()
                        .enumerate()
                        .map(|(r, o)| {
                            if let Some(&from) = donors.get(&r) {
                                RankSeed::Adopt { from }
                            } else {
                                let t = o.trainer.expect("survivor holds state");
                                match targets.remove(&r) {
                                    Some(ts) => RankSeed::Donate(t, o.losses, ts),
                                    None => RankSeed::Keep(t, o.losses),
                                }
                            }
                        })
                        .collect();
                    start = failed_step;
                    continue;
                }
            }
        }

        // Disk recovery: rewind everyone to the last checkpoint boundary.
        let ckpt_step = if dir_buf.is_some() && ckpt_every > 0 {
            (failed_step / ckpt_every) * ckpt_every
        } else {
            0
        };
        if ckpt_step > 0 {
            seeds = (0..world).map(|_| RankSeed::Restore).collect();
            start = ckpt_step;
        } else {
            seeds = (0..world).map(|_| RankSeed::Fresh).collect();
            start = 0;
        }
    }
}

/// Like [`run_training`] but under the supervision loop with `dir` as the
/// checkpoint directory: every rank writes a rank-sharded checkpoint of its
/// model shards + optimizer state (plus the replicated boundary layers on
/// rank 0) — the Megatron-style persistence layout — at every
/// `train.ckpt_every` boundary and at the end, and recovers from injected
/// faults when a [`crate::comm::fault::FaultPlan`] is configured.
pub fn run_training_with_checkpoint(
    cfg: &CubicConfig,
    net: NetModel,
    dir: &std::path::Path,
) -> Result<TrainReport> {
    run_training_supervised(cfg, net, Some(dir))
}

/// Result of a phantom-mode timing run of the core (the paper's measured
/// quantity: forward + backward of the consecutive Transformer layers).
#[derive(Clone, Debug)]
pub struct CoreTiming {
    /// Virtual seconds for the forward passes of all layers.
    pub forward_s: f64,
    /// Virtual seconds for the backward passes.
    pub backward_s: f64,
    pub metrics: RunMetrics,
}

impl CoreTiming {
    /// The paper's Eq. 6: (fwd + bwd) / batch.
    pub fn avg_step_time(&self, batch: usize) -> f64 {
        (self.forward_s + self.backward_s) / batch as f64
    }
}

/// Time one fwd+bwd of the Transformer core in phantom mode.
///
/// `repeats` forward/backward passes are timed (the paper runs multiple
/// iterations; virtual time is deterministic so 1 is exact, but repeats
/// exercise steady-state tag reuse).
///
/// NOTE: intentionally does *not* call `ModelConfig::validate` — the
/// paper's own Table 2 configs (e.g. batch 24 on a 4³ cube) split
/// sequences across ranks, which the timing path models analytically
/// (see `model::attention`).
pub fn time_core_step(
    cfg: &crate::config::ModelConfig,
    par: Parallelism,
    edge: usize,
    net: NetModel,
) -> Result<CoreTiming> {
    let world = par.world_size(edge);
    let cfg2 = cfg.clone();
    let rows = cfg.batch * cfg.seq;
    let sw = Stopwatch::start();
    let results = run_spmd_with_stats(world, net, move |rank, ep| {
        if let Parallelism::Pipeline { stages, micro_batches, inner } = par {
            // Pipelined timing runs the real micro-batch schedule with
            // phantom tensors: each stage owns its layer slice, boundary
            // activations/gradients move point-to-point, and the bubble
            // shows up on the virtual clock (pinned bitwise against the
            // cost model's recurrence).
            let pipe =
                crate::parallel::pipeline::Pipeline::for_kind(stages, micro_batches, inner, edge, rank);
            let blocks: Vec<BlockTensors> = pipe
                .layer_range(cfg2.layers)
                .map(|_| pipe.phantom_block(&cfg2))
                .collect();
            let x = Tensor::phantom(&[rows, cfg2.hidden]);
            let out = crate::parallel::pipeline::pipeline_core_step(
                ep,
                &pipe,
                &blocks,
                &x,
                &cfg2,
                &mut |_ep, y| Tensor::phantom(y.shape()),
            );
            ep.join_all();
            return (out.fwd_done_clock, ep.clock);
        }
        let env = ParEnv::new(par, edge, rank);
        let blocks: Vec<BlockTensors> =
            (0..cfg2.layers).map(|_| env.phantom_block(&cfg2)).collect();
        let (lr, lc) = env.activation_shape(rows, cfg2.hidden);
        let x = Tensor::phantom(&[lr, lc]);
        let (y, caches) = core_fwd(ep, env.ops(), &blocks, &x, &cfg2);
        let fwd_clock = ep.clock;
        let dy = Tensor::phantom(y.shape());
        let _ = core_bwd(ep, env.ops(), &blocks, &caches, &dy, &cfg2);
        // The optimizer boundary: deferred grad syncs still in flight must
        // land before the step ends, so backward time includes whatever
        // communication the compute could not hide.
        ep.join_all();
        let bwd_clock = ep.clock;
        (fwd_clock, bwd_clock)
    });
    let host = sw.seconds();
    let fwd = results.iter().map(|((f, _), _, _)| *f).fold(0.0, f64::max);
    let total = results.iter().map(|((_, b), _, _)| *b).fold(0.0, f64::max);
    let per_rank: Vec<(f64, crate::comm::CommStats)> =
        results.iter().map(|(_, c, s)| (*c, s.clone())).collect();
    Ok(CoreTiming {
        forward_s: fwd,
        backward_s: total - fwd,
        metrics: RunMetrics::from_ranks(&per_rank, host),
    })
}

/// Time one serving window (full-batch prefill + `gen_len` decode steps)
/// on the virtual-clock cluster — the inference analogue of
/// [`time_core_step`].
///
/// Unlike `time_core_step` this *does* validate: serving shapes feed the
/// KV-cache shard math and the decode-parity chunk-alignment rules, so a
/// bad config must fail loudly here rather than deep in a collective
/// (see [`crate::config::ModelConfig::validate_serve`]). `phantom` selects
/// shape-only tensors with analytic compute charges; numerics paths use
/// real tensors seeded by `seed`.
pub fn time_serve(
    cfg: &crate::config::ModelConfig,
    serve: &crate::config::ServeConfig,
    par: Parallelism,
    edge: usize,
    net: NetModel,
    phantom: bool,
    seed: u64,
) -> Result<crate::serve::ServeMeasurement> {
    cfg.validate_serve(par, edge, serve)
        .map_err(|e| anyhow::anyhow!("invalid serve config: {e}"))?;
    Ok(crate::serve::measure_serve(cfg, serve, par, edge, net, phantom, seed))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CubicConfig, ModelConfig, TrainConfig};

    #[test]
    fn tiny_training_runs_and_loss_drops_seq() {
        let cfg = CubicConfig {
            model: ModelConfig {
                layers: 1,
                ..ModelConfig::tiny()
            },
            train: TrainConfig { steps: 12, lr: 3e-3, warmup: 2, ..Default::default() },
            parallelism: Parallelism::Seq,
            edge: 1,
            ..CubicConfig::default()
        };
        let rep = run_training(&cfg, NetModel::zero()).unwrap();
        assert_eq!(rep.losses.len(), 12);
        let first = rep.losses[0];
        let last = *rep.losses.last().unwrap();
        assert!(
            last < first,
            "loss should drop: {first} -> {last} ({:?})",
            rep.losses
        );
    }

    #[test]
    fn invalid_config_is_rejected() {
        let mut cfg = CubicConfig::default();
        cfg.model.batch = 3; // 3 % 4 != 0 for p=2 cube
        assert!(run_training(&cfg, NetModel::zero()).is_err());
    }

    #[test]
    fn tiny_training_runs_pipelined() {
        // Pipeline(2 stages, 4 micro-batches, 1-D p=2) at world 4: the
        // leader's all-rank loss-equality check doubles as the replicated
        // head consistency pin for the pipelined path.
        let cfg = CubicConfig {
            model: ModelConfig::tiny(), // layers=2 → 1 per stage
            train: TrainConfig { steps: 8, lr: 3e-3, warmup: 2, ..Default::default() },
            parallelism: Parallelism::Pipeline {
                stages: 2,
                micro_batches: 4,
                inner: crate::topology::PipelineInner::OneD,
            },
            edge: 2,
            ..CubicConfig::default()
        };
        let rep = run_training(&cfg, NetModel::zero()).unwrap();
        assert_eq!(rep.losses.len(), 8);
        let first = rep.losses[0];
        let last = *rep.losses.last().unwrap();
        assert!(last < first, "loss should drop: {first} -> {last} ({:?})", rep.losses);
    }

    #[test]
    fn pipeline_bubble_shrinks_with_more_micro_batches() {
        // Same global batch, same stages: total step time must fall as the
        // bubble fraction (s−1)/(m+s−1) falls with m.
        let cfg = ModelConfig { layers: 2, ..ModelConfig::paper(1024, 8) };
        let pp = |m| Parallelism::Pipeline {
            stages: 2,
            micro_batches: m,
            inner: crate::topology::PipelineInner::OneD,
        };
        let t1 = time_core_step(&cfg, pp(1), 4, NetModel::longhorn_v100()).unwrap();
        let t4 = time_core_step(&cfg, pp(4), 4, NetModel::longhorn_v100()).unwrap();
        let total1 = t1.forward_s + t1.backward_s;
        let total4 = t4.forward_s + t4.backward_s;
        assert!(
            total4 < total1,
            "m=4 ({total4}s) should beat m=1 ({total1}s) at equal global batch"
        );
    }

    #[test]
    fn phantom_timing_runs_at_paper_scale_3d() {
        // Table 2's 3-D row: 64 GPUs (p=4), batch 24, hidden 3072, seq 512.
        let cfg = ModelConfig::paper(3072, 24);
        let t = time_core_step(&cfg, Parallelism::ThreeD, 4, NetModel::longhorn_v100())
            .unwrap();
        assert!(t.forward_s > 0.0);
        assert!(t.backward_s > 0.8 * t.forward_s, "bwd should be comparable to fwd");
        assert!(t.metrics.total_bytes > 0);
    }

    #[test]
    fn phantom_timing_backward_roughly_double_forward() {
        let cfg = ModelConfig::paper(1024, 8);
        for (par, edge) in [
            (Parallelism::OneD, 8),
            (Parallelism::TwoD, 2),
            (Parallelism::ThreeD, 2),
            (Parallelism::TwoFiveD { depth: 2 }, 2),
            (
                Parallelism::Hybrid {
                    replicas: 2,
                    inner: crate::topology::HybridInner::TwoD,
                },
                2,
            ),
        ] {
            let t = time_core_step(&cfg, par, edge, NetModel::longhorn_v100()).unwrap();
            let ratio = t.backward_s / t.forward_s;
            assert!(
                (1.05..4.0).contains(&ratio),
                "{par:?}: bwd/fwd ratio {ratio} out of range"
            );
        }
    }

    #[test]
    fn time_serve_validates_then_times_phantom() {
        let cfg = ModelConfig::tiny();
        let serve = crate::config::ServeConfig {
            slots: 4,
            max_seq: 16,
            prompt_len: 4,
            gen_len: 4,
            ..Default::default()
        };
        // Misaligned slot count fails loudly at the engine boundary.
        let mut bad = serve.clone();
        bad.slots = 3;
        assert!(time_serve(&cfg, &bad, Parallelism::OneD, 4, NetModel::zero(), true, 1)
            .is_err());
        let m = time_serve(
            &cfg,
            &serve,
            Parallelism::OneD,
            4,
            NetModel::longhorn_v100(),
            true,
            1,
        )
        .unwrap();
        assert!(m.prefill_s > 0.0 && m.decode_total_s > 0.0);
        assert_eq!(m.decode_step_s.len(), serve.gen_len);
        assert!(m.tokens_per_sec_per_rank > 0.0);
        // A decode step is far cheaper than the full prefill pass.
        assert!(m.decode_step_s[0] < m.prefill_s, "{m:?}");
    }
}

#[cfg(test)]
mod checkpoint_tests {
    use super::*;
    use crate::config::{CubicConfig, ModelConfig, TrainConfig};

    #[test]
    fn training_with_checkpoint_writes_all_rank_files() {
        let dir = std::env::temp_dir().join(format!("cubic-engine-ckpt-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = CubicConfig {
            model: ModelConfig { layers: 1, ..ModelConfig::tiny() },
            train: TrainConfig { steps: 3, ..Default::default() },
            parallelism: crate::topology::Parallelism::ThreeD,
            edge: 2,
            ..CubicConfig::default()
        };
        let rep = run_training_with_checkpoint(&cfg, NetModel::zero(), &dir).unwrap();
        assert_eq!(rep.losses.len(), 3);
        for rank in 0..8 {
            let path = dir.join(format!("rank-{rank}.bin"));
            assert!(path.exists(), "missing {}", path.display());
        }
        // Shards restore into a matching topology.
        let dense = crate::model::init_dense_blocks(&cfg.model, 123);
        let env = crate::model::ParEnv::new(crate::topology::Parallelism::ThreeD, 2, 3);
        let mut blocks = env.shard_blocks(&dense);
        crate::train::checkpoint::load_rank(&dir, 3, &mut blocks).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }
}
