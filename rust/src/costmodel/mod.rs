//! Analytic cost model: the paper's §3.1.1–3.1.2 complexity claims as
//! closed-form, testable formulas, cross-checked against the engine's
//! measured traffic ledger.
//!
//! Quantities per *linear layer* `C(M,K) = A(M,N)·B(N,K)` on `P` devices:
//!
//! | approach | memory/rank | comm bytes sent/rank (fwd) | latency steps |
//! |----------|-------------|----------------------------|---------------|
//! | 1-D [17] | `MN/1 + NK/P` (activations replicated) | all-reduce: `2·(P−1)/P·4MK` per row-parallel layer | `2(P−1)` |
//! | 2-D [21] | `(MN+NK+MK)/q²` | SUMMA: `q` panel broadcasts of `4MN/q²` + `4NK/q²` | `2q⌈log₂q⌉` |
//! | 3-D      | `(MN+NK+MK)/p³` | `(p−1)·4(MN+NK+MK)/p³` | `3(p−1)` |
//!
//! The byte formulas are **exact** for the ring/tree algorithms in
//! [`crate::collectives`], and the unit tests pin them against the
//! engine-measured ledger, so the asymptotic table above is enforced by
//! CI rather than asserted in prose.
//!
//! # The memory model, worked at 64 ranks
//!
//! One transformer-scale linear layer `C(M,K) = A(M,N)·B(N,K)` with
//! `M = 2048` activation rows, `N = 1024`, `K = 4096`, f32, Adam — plus the
//! serving-side KV cache for `slots = 64`, `heads = 16`, `head_dim = 64`,
//! `max_seq = 2048`. Every cell below comes from the *same* functions
//! `cubic plan` calls ([`weight_bytes_per_rank`] and its per-mesh variants,
//! [`adam_state_bytes_per_rank`] / [`zero_adam_state_bytes_per_rank`],
//! [`grad_bytes_per_rank`], [`activation_bytes_per_rank`] variants,
//! [`kv_cache_bytes_per_rank`]), and the doc-test underneath recomputes the
//! 3-D and hybrid rows so the table cannot rot:
//!
//! | kind (64 ranks) | weights/rank | grads/rank | Adam moments/rank | acts/rank | KV/rank/layer |
//! |---|---|---|---|---|---|
//! | seq (1 rank, for scale) | 16 MiB | 16 MiB | 32 MiB | 8 MiB | 1 GiB |
//! | 1-D, `P = 64` | 256 KiB | 256 KiB | 512 KiB | 8 MiB (replicated) | 16 MiB |
//! | 2-D, `q = 8` | 256 KiB | 256 KiB | 512 KiB | 128 KiB | 16 MiB |
//! | 3-D, `p = 4` | 256 KiB | 256 KiB | 512 KiB | 128 KiB | 16 MiB |
//! | 2.5-D, `p = 4, d = 4` | 256 KiB | 256 KiB | 512 KiB | 512 KiB | 16 MiB |
//! | hybrid `4×(4×4)`, ZeRO off | 1 MiB | 1 MiB | 2 MiB | 128 KiB | 16 MiB |
//! | hybrid `4×(4×4)`, ZeRO 1 | 1 MiB | 1 MiB | **512 KiB** | 128 KiB | 16 MiB |
//! | hybrid `4×(4×4)`, ZeRO 2 | 1 MiB | **256 KiB** | **512 KiB** | 128 KiB | 16 MiB |
//! | pipeline `4pp(4×4)` | 256 KiB¹ | 256 KiB¹ | 512 KiB¹ | 512 KiB² | 16 MiB¹ |
//!
//! ¹ per layer of the *full* stack: a stage holds `1/s` of the layers, each
//! sharded `1/iw` by its inner mesh ([`pipeline_weight_bytes_per_rank`]).
//! ² the GPipe stash high-water mark — all micro-batches stay cached until
//! the flush ([`pipeline_activation_bytes_per_rank`]).
//!
//! The story in one sentence: every pure tensor mesh lands on the same
//! balanced `1/64` weight/optimizer split and differs only in activations,
//! while the hybrid's `r = 4` replication costs `4×` on weights, grads and
//! moments — and ZeRO stage 1/2 claws the moment (and grad) redundancy back
//! to the tensor-mesh figure at **zero** extra communication volume
//! (reduce-scatter + all-gather *is* the all-reduce it replaces, see
//! [`crate::parallel::hybrid`]).
//!
//! ```
//! use cubic::costmodel::*;
//! use cubic::topology::{HybridInner, Parallelism};
//! let (m, n, k) = (2048u64, 1024u64, 4096u64);
//! // 3-D row: p = 4, world 64.
//! assert_eq!(weight_bytes_per_rank(64, n, k, Approach::ThreeD), 256 * 1024);
//! assert_eq!(adam_state_bytes_per_rank(&[n * k / 64]), 512 * 1024);
//! assert_eq!(activation_bytes_per_rank(64, m, n, Approach::ThreeD), 128 * 1024);
//! assert_eq!(
//!     kv_cache_bytes_per_rank(Parallelism::ThreeD, 4, 0, 64, 16, 64, 2048),
//!     16 * 1024 * 1024
//! );
//! // Hybrid row: r = 4 replicas of a 4×4 SUMMA grid (inner world 16).
//! let local = [n * k / 16]; // this rank's weight-shard elements
//! assert_eq!(hybrid_weight_bytes_per_rank(16, n, k), 1024 * 1024);
//! assert_eq!(adam_state_bytes_per_rank(&local), 2 * 1024 * 1024); // ZeRO off
//! assert_eq!(zero_adam_state_bytes_per_rank(&local, 4), 512 * 1024); // ZeRO 1/2
//! assert_eq!(grad_bytes_per_rank(&local, 4, 0), 1024 * 1024); // stages 0-1
//! assert_eq!(grad_bytes_per_rank(&local, 4, 2), 256 * 1024); // stage 2
//! assert_eq!(hybrid_activation_bytes_per_rank(4, 16, m, n), 128 * 1024);
//! let hybrid = Parallelism::Hybrid { replicas: 4, inner: HybridInner::TwoD };
//! assert_eq!(kv_cache_bytes_per_rank(hybrid, 4, 0, 64, 16, 64, 2048), 16 * 1024 * 1024);
//! ```

use crate::comm::NetModel;

/// f32 bytes.
const W: u64 = 4;

/// Per-rank bytes *sent* by a ring all-gather of per-rank shards of
/// `shard_elems` elements over `g` ranks.
pub fn ring_all_gather_bytes(g: u64, shard_elems: u64) -> u64 {
    if g <= 1 {
        0
    } else {
        (g - 1) * shard_elems * W
    }
}

/// Per-rank bytes sent by a ring reduce-scatter of a `total_elems` partial
/// split into `g` chunks.
pub fn ring_reduce_scatter_bytes(g: u64, total_elems: u64) -> u64 {
    if g <= 1 {
        0
    } else {
        (g - 1) * (total_elems / g) * W
    }
}

/// Per-rank bytes sent by a ring all-reduce of `elems` elements
/// (reduce-scatter + all-gather on padded chunks).
pub fn ring_all_reduce_bytes(g: u64, elems: u64) -> u64 {
    if g <= 1 {
        0
    } else {
        2 * (g - 1) * elems.div_ceil(g) * W
    }
}

/// **3-D forward matmul (Algorithm 1)**: exact per-rank bytes sent for
/// `C(M,K) = A(M,N)·B(N,K)` on a `p³` cube.
pub fn mm3d_fwd_bytes_per_rank(p: u64, m: u64, n: u64, k: u64) -> u64 {
    let a_shard = (m * n) / (p * p * p); // (M/p², N/p)
    let b_shard = (n * k) / (p * p * p);
    let c_partial = (m / p) * (k / p);
    ring_all_gather_bytes(p, a_shard)
        + ring_all_gather_bytes(p, b_shard)
        + ring_reduce_scatter_bytes(p, c_partial)
}

/// 3-D backward (Algorithm 2): gathers Ċ, B, A and reduce-scatters Ȧ, Ḃ.
pub fn mm3d_bwd_bytes_per_rank(p: u64, m: u64, n: u64, k: u64) -> u64 {
    let a_shard = (m * n) / (p * p * p);
    let b_shard = (n * k) / (p * p * p);
    let c_shard = (m * k) / (p * p * p);
    ring_all_gather_bytes(p, c_shard)        // Ċ along dC
        + ring_all_gather_bytes(p, b_shard)  // B along dB
        + ring_reduce_scatter_bytes(p, (m / p) * (n / p)) // Ȧ
        + ring_all_gather_bytes(p, a_shard)  // A along dA
        + ring_reduce_scatter_bytes(p, (n / p) * (k / p)) // Ḃ
}

/// **2-D SUMMA forward**: per-rank bytes sent for the same product on a
/// `q²` mesh. Each of the `q` steps broadcasts an A panel along the row and
/// a B panel along the column (binomial tree: a rank sends ≤ ⌈log₂q⌉
/// copies; the *average* per rank is (q−1)/q ≈ 1 copies per broadcast —
/// we report the root-rank worst case used by the makespan).
pub fn summa_fwd_bytes_root(q: u64, m: u64, n: u64, k: u64) -> u64 {
    let a_block = (m / q) * (n / q);
    let b_block = (n / q) * (k / q);
    // Each step one root per row/col sends ⌈log₂ q⌉ copies.
    let log2q = 64 - (q - 1).leading_zeros() as u64;
    q * log2q * (a_block + b_block) * W / q // amortized over the q roots
}

/// **1-D Megatron forward**: per-rank bytes for one column- + one
/// row-parallel pair (a whole MLP): one all-reduce of the `(M, K)` output.
pub fn oned_fwd_bytes_per_rank(p: u64, m: u64, k: u64) -> u64 {
    ring_all_reduce_bytes(p, m * k)
}

/// Per-rank parameter memory for one `N×K` weight under each approach.
pub fn weight_bytes_per_rank(world: u64, n: u64, k: u64, approach: Approach) -> u64 {
    match approach {
        Approach::OneD => n * k * W / world,
        Approach::TwoD => n * k * W / world,
        Approach::ThreeD => n * k * W / world,
        Approach::Seq => n * k * W,
    }
}

/// Per-rank *activation* memory for an `M×N` activation — where the three
/// approaches genuinely differ (the paper's §3.1.1 imbalance argument).
pub fn activation_bytes_per_rank(world: u64, m: u64, n: u64, approach: Approach) -> u64 {
    match approach {
        Approach::Seq => m * n * W,
        // Megatron replicates activations on every rank.
        Approach::OneD => m * n * W,
        Approach::TwoD => m * n * W / world,
        Approach::ThreeD => m * n * W / world,
    }
}

/// The paper's three distributed-matmul approaches plus the dense
/// baseline, as a selector for the per-rank memory forms above.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Approach {
    /// Dense single-device baseline (`P = 1`).
    Seq,
    /// 1-D Megatron-style row/column parallelism \[17\].
    OneD,
    /// 2-D SUMMA on a `q × q` grid \[21\].
    TwoD,
    /// The paper's 3-D decomposition on a `p × p × p` cube.
    ThreeD,
}

/// Per-rank bytes sent by one `broadcast_bw` (scatter + ring all-gather) of
/// `elems` elements over `g` ranks. Every member forwards `g−1` chunks on
/// the gather ring; the root additionally sends `g−1` chunks in the scatter
/// phase. Averaged over a full SUMMA sweep each rank roots exactly once, so
/// the `root` flag lets callers sum the two roles exactly.
pub fn broadcast_bw_bytes_per_rank(g: u64, elems: u64, root: bool) -> u64 {
    if g <= 1 {
        return 0;
    }
    let chunk = elems.div_ceil(g);
    let gather = (g - 1) * chunk * W;
    if root {
        2 * gather
    } else {
        gather
    }
}

/// **2-D SUMMA forward matmul**: exact per-rank bytes sent by `summa_nn`
/// for per-rank operand blocks of `a_blk`/`b_blk` elements on a `q × q`
/// grid. Each of the `q` steps broadcasts one A panel along the row and one
/// B panel along the column via `broadcast_bw`; every rank is the A-root
/// exactly once (`t == col`) and the B-root exactly once (`t == row`), so
/// the total is uniform across ranks.
pub fn summa_nn_bytes_per_rank(q: u64, a_blk: u64, b_blk: u64) -> u64 {
    let non_root = (q - 1)
        * (broadcast_bw_bytes_per_rank(q, a_blk, false)
            + broadcast_bw_bytes_per_rank(q, b_blk, false));
    let root = broadcast_bw_bytes_per_rank(q, a_blk, true)
        + broadcast_bw_bytes_per_rank(q, b_blk, true);
    non_root + root
}

/// Which linear of a residual branch a 2.5-D matmul runs as (mirrors
/// `crate::dist::Stage` without importing the layout module into every
/// formula call site).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TessStage {
    /// Depth-column-slabbed weight: per-layer SUMMA only.
    Expand,
    /// Depth-row-slabbed weight: per-layer SUMMA + depth all-reduce.
    Reduce,
}

/// **2.5-D Tesseract forward matmul**: exact per-rank bytes sent for
/// `C(M,K) = A(M,N)·B(N,K)` on a `p × p × d` mesh.
///
/// `Expand` runs SUMMA on the layer's column slab (`B` blocks are
/// `(N/p, K/(d·p))`) with no depth traffic; `Reduce` runs SUMMA on the
/// row slab (`A` blocks `(M/p, N/(d·p))`, `B` blocks `(N/(d·p), K/p)`)
/// and closes with a ring all-reduce of the `(M/p, K/p)` output block
/// over the `d` depth layers — the Tesseract trade: the slab SUMMA moves
/// `1/d` of 2-D's weight-side panel bytes, the depth all-reduce adds an
/// activation-sized term 2-D does not have.
pub fn mm25d_fwd_bytes_per_rank(p: u64, d: u64, m: u64, n: u64, k: u64, stage: TessStage) -> u64 {
    match stage {
        TessStage::Expand => {
            let a_blk = (m / p) * (n / p);
            let b_blk = (n / p) * (k / (d * p));
            summa_nn_bytes_per_rank(p, a_blk, b_blk)
        }
        TessStage::Reduce => {
            let a_blk = (m / p) * (n / (d * p));
            let b_blk = (n / (d * p)) * (k / p);
            let c_blk = (m / p) * (k / p);
            summa_nn_bytes_per_rank(p, a_blk, b_blk) + ring_all_reduce_bytes(d, c_blk)
        }
    }
}

/// **Hybrid gradient sync**: per-rank bytes sent by the replica-group
/// all-reduce of a weight/vector gradient shard of `elems` elements over
/// `r` replicas — the only communication the hybrid wrapper adds on top of
/// its inner mesh.
pub fn hybrid_grad_sync_bytes_per_rank(r: u64, elems: u64) -> u64 {
    ring_all_reduce_bytes(r, elems)
}

/// 2.5-D per-rank weight memory: `1/(p²·d)` of every weight (perfect
/// balance, like every tensor mesh).
pub fn mm25d_weight_bytes_per_rank(p: u64, d: u64, n: u64, k: u64) -> u64 {
    n * k * W / (p * p * d)
}

/// 2.5-D per-rank *activation* memory: `1/p²` of the global activation —
/// replicated `d` times across depth layers. At equal world size this is
/// `d ×` the 2-D figure: the memory side of the Tesseract trade-off.
pub fn mm25d_activation_bytes_per_rank(p: u64, _d: u64, m: u64, n: u64) -> u64 {
    m * n * W / (p * p)
}

/// Hybrid per-rank weight memory: replicas do not shard weights, so each
/// rank stores `1/inner_world` of every weight regardless of `r`.
pub fn hybrid_weight_bytes_per_rank(inner_world: u64, n: u64, k: u64) -> u64 {
    n * k * W / inner_world
}

/// Hybrid per-rank activation memory: batch rows split `r` ways, then the
/// inner mesh's activation division (`inner_act_div` = 1 for a 1-D inner,
/// `q²` for 2-D, `p³` for 3-D, `p²` for 2.5-D).
pub fn hybrid_activation_bytes_per_rank(r: u64, inner_act_div: u64, m: u64, n: u64) -> u64 {
    m * n * W / (r * inner_act_div)
}

/// Per-rank **Adam moment** bytes for replicated (ZeRO-off) optimizer
/// state: two f32 moments (`m`, `v`) per local parameter element.
/// `local_param_numels` are the element counts of the parameters this rank
/// stores — its *shard* shapes, not the dense model's.
pub fn adam_state_bytes_per_rank(local_param_numels: &[u64]) -> u64 {
    local_param_numels.iter().map(|&n| 2 * n * W).sum()
}

/// Per-rank Adam moment bytes under ZeRO stage ≥ 1: each of the `r`
/// replicas keeps moments only for its owned `⌈n/r⌉` slice of every local
/// parameter — the same padded chunk boundary as
/// [`crate::collectives::flat_chunks`], which is exactly what
/// [`crate::optim::Optimizer::new_partitioned`] allocates (pinned in the
/// tests). Exactly `1/r` of [`adam_state_bytes_per_rank`] whenever `r`
/// divides every parameter; the pad rounds *up* otherwise.
pub fn zero_adam_state_bytes_per_rank(local_param_numels: &[u64], r: u64) -> u64 {
    local_param_numels.iter().map(|&n| 2 * n.div_ceil(r) * W).sum()
}

/// Per-rank gradient bytes resident at the optimizer boundary. ZeRO
/// stage ≥ 2 frees the full gradients once the reduce-scatter lands and
/// keeps only the owned `⌈n/r⌉` chunks; stages 0–1 hold full local-shard
/// gradients until the update.
pub fn grad_bytes_per_rank(local_param_numels: &[u64], r: u64, zero_stage: usize) -> u64 {
    if zero_stage >= 2 {
        local_param_numels.iter().map(|&n| n.div_ceil(r) * W).sum()
    } else {
        local_param_numels.iter().map(|&n| n * W).sum()
    }
}

/// Total per-rank optimizer-side bytes — resident gradients plus Adam
/// moments — the `opt/rank` column of `cubic plan --world N`. With
/// `zero_stage = 0` (or `r = 1`) this is the replicated figure; ZeRO
/// divides the moment (stage ≥ 1) and gradient (stage ≥ 2) terms by `r`.
/// The *step time* is unchanged either way: the reduce-scatter plus the
/// post-step weight all-gather send exactly the bytes of the all-reduce
/// they replace ([`ring_all_reduce_bytes`] is literally the sum of its two
/// phases), so `plan` reuses the ZeRO-off timing for ZeRO rows.
pub fn optimizer_bytes_per_rank(local_param_numels: &[u64], r: u64, zero_stage: usize) -> u64 {
    let moments = if zero_stage >= 1 {
        zero_adam_state_bytes_per_rank(local_param_numels, r)
    } else {
        adam_state_bytes_per_rank(local_param_numels)
    };
    grad_bytes_per_rank(local_param_numels, r, zero_stage) + moments
}

/// **Pipeline bubble fraction** of the GPipe flush schedule: with `s`
/// stages and `m` micro-batches, `(m + s − 1)` micro-batch slots pass a
/// stage per sweep but only `m` carry work, so the idle share is
/// `(s − 1)/(m + s − 1)` — the classic GPipe/1F1B bubble (both schedules
/// share it; 1F1B only changes the stash high-water mark).
pub fn pipeline_bubble_fraction(s: u64, m: u64) -> f64 {
    if s <= 1 {
        0.0
    } else {
        (s - 1) as f64 / (m + s - 1) as f64
    }
}

/// Completion time of the pipelined core step — the exact dependency
/// recurrence of [`crate::parallel::pipeline::pipeline_core_step`]'s
/// schedule: `s` stages, `m` micro-batches, per-micro-batch forward `f`
/// and backward `b` per stage, boundary transfer `c` per hop, and a
/// per-stage weight-gradient flush `w`.
///
/// Forward: `F[k][u] = max(F[k][u−1], F[k−1][u] + c) + f` (stages compute
/// their micro-batches in order, each needing the boundary activation from
/// the stage below). The last stage finishes at `t_f = F[s−1][m−1]`; the
/// output relay and replicated head ride on top of it. Backward runs in
/// reverse micro-batch order, `B[k][u] = max(B[k][u+1], B[k+1][u] + c) + b`,
/// and every stage closes with its flush (stage 0 relays the embedding
/// gradient first; the others receive it after flushing). With `c = w = 0`
/// this telescopes to `(m + s − 1)(f + b)`, whose idle share is
/// [`pipeline_bubble_fraction`] — and the unit tests pin the recurrence
/// *bitwise* against the engine clock on a dyadic network.
pub fn pipeline_step_time(s: usize, m: usize, f: f64, b: f64, c: f64, w: f64) -> f64 {
    assert!(s >= 1 && m >= 1);
    let mut fw = vec![vec![0.0f64; m]; s];
    for k in 0..s {
        let mut t = 0.0f64;
        for u in 0..m {
            let ready = if k == 0 { t } else { fw[k - 1][u] + c };
            t = t.max(ready) + f;
            fw[k][u] = t;
        }
    }
    let t_f = fw[s - 1][m - 1];
    let mut bw = vec![vec![0.0f64; m]; s];
    for k in (0..s).rev() {
        let mut t = if k == s - 1 { t_f } else { fw[k][m - 1].max(t_f + c) };
        for u in (0..m).rev() {
            let ready = if k == s - 1 { t } else { bw[k + 1][u] + c };
            t = t.max(ready) + b;
            bw[k][u] = t;
        }
    }
    let mut end = 0.0f64;
    for k in 0..s {
        let e = if k == 0 {
            bw[0][0] + w
        } else {
            (bw[k][0] + w).max(bw[0][0] + c)
        };
        end = end.max(e);
    }
    end
}

/// Pipeline per-rank weight memory: a stage holds `1/s` of the layer
/// stack, sharded by the inner mesh as usual.
pub fn pipeline_weight_bytes_per_rank(s: u64, inner_world: u64, n: u64, k: u64) -> u64 {
    n * k * W / (s * inner_world)
}

/// Pipeline per-rank activation memory at the stash high-water mark: all
/// `m` micro-batch caches stay alive until the weight-gradient flush, so
/// the stash equals the *full-batch* activation under the inner mesh's
/// row/column division — micro-batching pipelines time, not activation
/// memory (GPipe without recomputation).
pub fn pipeline_activation_bytes_per_rank(inner_act_div: u64, rows: u64, n: u64) -> u64 {
    rows * n * W / inner_act_div
}

/// Predicted virtual time of the 3-D forward matmul under `net` — the
/// closed form the engine's emergent ring timing should approach on a flat
/// network (unit-tested to a few percent).
pub fn mm3d_fwd_time_flat(net: &NetModel, p: u64, m: u64, n: u64, k: u64) -> f64 {
    let flops = 2.0 * (m as f64 / p as f64) * (n as f64 / p as f64) * (k as f64 / p as f64);
    let compute = net.compute_cost(flops);
    let hops = 3.0 * (p as f64 - 1.0);
    let bytes = mm3d_fwd_bytes_per_rank(p, m, n, k) as f64;
    compute + hops * net.alpha_intra + bytes / net.beta_intra
}

/// **Overlap-aware exposed communication** — the closed form of the
/// two-timeline scheme in [`crate::comm`].
///
/// Each deferred collective is a boundary `(t_i, c_i)`: issued when the
/// compute clock reads `t_i`, occupying the (serial) comm timeline for
/// `c_i` seconds. The comm timeline's backlog obeys
///
/// ```text
/// f_i = max(f_{i−1}, t_i) + c_i        (f_0 = 0)
/// ```
///
/// and the only communication the compute clock ever stalls on is the
/// backlog still unfinished at the join point:
///
/// ```text
/// exposed = max(0, f_last − t_join)
/// ```
///
/// This is exactly what [`crate::comm::Endpoint::defer`] +
/// `join_all` compute incrementally, and the unit tests below pin the two
/// against each other on the engine's own clock — with power-of-two hop
/// costs the equality is required to be *bitwise*.
pub fn overlapped_exposed_comm(boundaries: &[(f64, f64)], t_join: f64) -> f64 {
    let mut f = 0.0f64;
    for &(t_i, c_i) in boundaries {
        f = f.max(t_i) + c_i;
    }
    (f - t_join).max(0.0)
}

/// Scalar per-boundary special case of [`overlapped_exposed_comm`] for
/// uniform layers: if every boundary issues `comm` seconds of deferred
/// communication and the compute between consecutive boundaries (the
/// hideable window) is `hideable` seconds, the backlog recurrence
/// telescopes to `max(0, comm − hideable)` exposed per boundary — the
/// steady-state rate at which communication outruns the compute that
/// could hide it.
pub fn exposed_comm_uniform(comm: f64, hideable: f64) -> f64 {
    (comm - hideable).max(0.0)
}

/// Overlap-aware step time: serialized step `compute + comm` collapses to
/// `t_join + exposed` when deferred collectives ride behind compute.
/// `t_join` is the compute-only clock at the optimizer boundary.
pub fn overlapped_step_time(t_join: f64, boundaries: &[(f64, f64)]) -> f64 {
    t_join + overlapped_exposed_comm(boundaries, t_join)
}

// --- inference serving (`crate::serve`) ---------------------------------

/// **KV-cache bytes per rank per layer**: the serving-memory analogue of
/// [`activation_bytes_per_rank`]. The cache holds K and V rows for every
/// (local slot, local head) pair at the full `max_seq` extent, so per rank
/// it is `2 · slots_loc · heads_loc · max_seq · head_dim · 4` — slots split
/// by the mesh's activation-row division, heads by its column split
/// (`ShardSpec::head_divisor`). Pinned bitwise against
/// `attention::DecodeKv::nominal_bytes` for every mesh kind.
pub fn kv_cache_bytes_per_rank(
    par: crate::topology::Parallelism,
    edge: usize,
    rank: usize,
    slots: u64,
    heads: u64,
    head_dim: u64,
    max_seq: u64,
) -> u64 {
    let spec = crate::dist::ShardSpec::for_parallelism(par, edge, rank);
    let slots_loc = spec.activation_rows(slots as usize) as u64;
    let heads_loc = spec.local_heads(heads as usize) as u64;
    2 * slots_loc * heads_loc * max_seq * head_dim * W
}

/// **Decode-step comm bytes per rank**: exact per-rank bytes sent by the
/// four linears of each layer (qkv Expand, proj Reduce, fc1 Expand, fc2
/// Reduce) during one decode step over a `slots`-row grid. The attention
/// itself is communication-free at decode time — each rank holds the full
/// KV history for its local (slot, head) pairs — so the linears are the
/// whole per-layer traffic on every leaf. Hybrid recurses at the
/// per-replica slot count (replica all-reduces only run on gradients);
/// Pipeline recurses at the per-stage layer count and full slots, with the
/// stage relay accounted separately by [`serve_relay_bytes_per_step`].
pub fn decode_step_comm_bytes_per_rank(
    par: crate::topology::Parallelism,
    edge: u64,
    slots: u64,
    hidden: u64,
    ffn: u64,
    layers: u64,
) -> u64 {
    use crate::topology::Parallelism;
    let p = edge;
    // (n_in, n_out, stage) of the four linears of one block.
    let linears =
        [(hidden, 3 * hidden, TessStage::Expand), (hidden, hidden, TessStage::Reduce),
         (hidden, ffn, TessStage::Expand), (ffn, hidden, TessStage::Reduce)];
    match par {
        Parallelism::Seq => 0,
        // Column-parallel Expand moves nothing; each Reduce all-reduces its
        // (slots, hidden) output.
        Parallelism::OneD => layers * 2 * ring_all_reduce_bytes(p, slots * hidden),
        Parallelism::TwoD => {
            let per_layer: u64 = linears
                .iter()
                .map(|&(n, k, _)| {
                    summa_nn_bytes_per_rank(p, (slots / p) * (n / p), (n / p) * (k / p))
                })
                .sum();
            layers * per_layer
        }
        Parallelism::ThreeD => {
            let per_layer: u64 = linears
                .iter()
                .map(|&(n, k, _)| mm3d_fwd_bytes_per_rank(p, slots, n, k))
                .sum();
            layers * per_layer
        }
        Parallelism::TwoFiveD { depth } => {
            let per_layer: u64 = linears
                .iter()
                .map(|&(n, k, stage)| {
                    mm25d_fwd_bytes_per_rank(p, depth as u64, slots, n, k, stage)
                })
                .sum();
            layers * per_layer
        }
        Parallelism::Hybrid { replicas, inner } => decode_step_comm_bytes_per_rank(
            inner.as_parallelism(),
            edge,
            slots / replicas as u64,
            hidden,
            ffn,
            layers,
        ),
        Parallelism::Pipeline { stages, inner, .. } => decode_step_comm_bytes_per_rank(
            inner.as_parallelism(),
            edge,
            slots,
            hidden,
            ffn,
            layers / stages as u64,
        ),
    }
}

/// Per-rank bytes sent by the pipeline's serve relay during one prefill or
/// decode step: interior stages forward their boundary activation shard of
/// `local_elems` elements one hop up; the last stage fans the final hidden
/// state out to the other `s − 1` stage groups (decode feeds it back as
/// the next step's input on every stage). `last_stage` selects the role.
pub fn serve_relay_bytes_per_step(s: u64, local_elems: u64, last_stage: bool) -> u64 {
    if s <= 1 {
        return 0;
    }
    if last_stage {
        (s - 1) * local_elems * W
    } else {
        local_elems * W
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::NetModel;
    use crate::dist::{Dirs, Layout3D};
    use crate::parallel::threed::{mm_nn, mm_nn_backward, Ctx3D};
    use crate::spmd::run_spmd;
    use crate::tensor::Tensor;
    use crate::topology::Cube;

    #[test]
    fn mm3d_fwd_bytes_match_engine_ledger_exactly() {
        // Run Algorithm 1 in phantom mode and compare the measured bytes
        // sent per rank with the closed form.
        let p = 2usize;
        let (m, n, k) = (16usize, 32usize, 64usize);
        let dirs = Dirs::canonical();
        let a_shape = Layout3D::input(dirs).shard_shape(p, m, n);
        let b_shape = Layout3D::weight(dirs).shard_shape(p, n, k);
        let measured = run_spmd(8, NetModel::flat(0.0, 1e9, f64::INFINITY), move |rank, ep| {
            let ctx = Ctx3D::new(Cube::new(p), rank);
            let a = Tensor::phantom(&[a_shape.0, a_shape.1]);
            let b = Tensor::phantom(&[b_shape.0, b_shape.1]);
            let _ = mm_nn(ep, &ctx, &a, &b, dirs);
            ep.stats.bytes_sent
        });
        let want = mm3d_fwd_bytes_per_rank(p as u64, m as u64, n as u64, k as u64);
        for (rank, &got) in measured.iter().enumerate() {
            assert_eq!(got, want, "rank {rank}");
        }
    }

    #[test]
    fn mm3d_bwd_bytes_match_engine_ledger_exactly() {
        let p = 2usize;
        let (m, n, k) = (16usize, 32usize, 64usize);
        let dirs = Dirs::canonical();
        let a_shape = Layout3D::input(dirs).shard_shape(p, m, n);
        let b_shape = Layout3D::weight(dirs).shard_shape(p, n, k);
        let c_shape = Layout3D::output(dirs).shard_shape(p, m, k);
        let measured = run_spmd(8, NetModel::flat(0.0, 1e9, f64::INFINITY), move |rank, ep| {
            let ctx = Ctx3D::new(Cube::new(p), rank);
            let a = Tensor::phantom(&[a_shape.0, a_shape.1]);
            let b = Tensor::phantom(&[b_shape.0, b_shape.1]);
            let dc = Tensor::phantom(&[c_shape.0, c_shape.1]);
            let _ = mm_nn_backward(ep, &ctx, &dc, &a, &b, dirs);
            ep.stats.bytes_sent
        });
        let want = mm3d_bwd_bytes_per_rank(p as u64, m as u64, n as u64, k as u64);
        for (rank, &got) in measured.iter().enumerate() {
            assert_eq!(got, want, "rank {rank}");
        }
    }

    #[test]
    fn mm25d_fwd_bytes_match_engine_ledger_exactly() {
        // Run the 2.5-D trait matmul in phantom mode for both stages and
        // compare the measured per-rank bytes with the closed form — the
        // costmodel-vs-measured pin for the Tesseract mesh.
        use crate::dist::{ShardSpec, Stage};
        use crate::parallel::twofived::Ctx25D;
        use crate::parallel::ParallelOps;
        let (p, d) = (2usize, 2usize);
        let world = p * p * d;
        let (m, n, k) = (16usize, 32usize, 64usize);
        for (stage, tess_stage) in
            [(Stage::Expand, TessStage::Expand), (Stage::Reduce, TessStage::Reduce)]
        {
            let measured =
                run_spmd(world, NetModel::flat(0.0, 1e9, f64::INFINITY), move |rank, ep| {
                    let ctx = Ctx25D::new(p, d, rank);
                    let spec = ShardSpec::twofived(p, d, rank);
                    // Shape-only operands cut by the same layout algebra
                    // the model uses.
                    let x_shape = match stage {
                        Stage::Expand => (m / p, n / p),
                        Stage::Reduce => (m / p, n / (d * p)),
                    };
                    let x = Tensor::phantom(&[x_shape.0, x_shape.1]);
                    let w = spec.shard_weight(stage, &Tensor::phantom(&[n, k]));
                    let _ = ctx.matmul_nn(ep, &x, &w, stage);
                    ep.stats.bytes_sent
                });
            let want = mm25d_fwd_bytes_per_rank(
                p as u64, d as u64, m as u64, n as u64, k as u64, tess_stage,
            );
            for (rank, &got) in measured.iter().enumerate() {
                assert_eq!(got, want, "rank {rank} stage {stage:?}");
            }
        }
    }

    #[test]
    fn hybrid_grad_sync_bytes_match_engine_ledger_exactly() {
        // Inner 1-D weight-grad forms are communication-free, so the entire
        // matmul_tn traffic of the hybrid leaf is the replica all-reduce —
        // measure it and pin the closed form.
        use crate::dist::Stage;
        use crate::parallel::hybrid::Hybrid;
        use crate::parallel::ParallelOps;
        use crate::topology::HybridInner;
        let (r, e) = (2usize, 2usize);
        let world = r * e;
        let (m, n, k) = (8usize, 16usize, 32usize);
        let measured = run_spmd(world, NetModel::flat(0.0, 1e9, f64::INFINITY), move |rank, ep| {
            let ops = Hybrid::for_kind(r, HybridInner::OneD, e, rank);
            let x = Tensor::phantom(&[m / r, n]);
            let dy = Tensor::phantom(&[m / r, k / e]);
            let _ = ops.matmul_tn(ep, &x, &dy, Stage::Expand);
            ep.stats.bytes_sent
        });
        let want = hybrid_grad_sync_bytes_per_rank(r as u64, (n * k / e) as u64);
        assert!(want > 0);
        for (rank, &got) in measured.iter().enumerate() {
            assert_eq!(got, want, "rank {rank}");
        }
    }

    #[test]
    fn new_mesh_memory_formulas_match_shard_shapes() {
        // The closed-form memory predictions must agree with the shapes the
        // layout algebra actually cuts.
        use crate::dist::{MeshSpec, ShardSpec, Stage};
        let (p, d) = (2u64, 2u64);
        let (m, n, k) = (16u64, 32u64, 64u64);
        let spec = ShardSpec::twofived(p as usize, d as usize, 0);
        let w = Tensor::phantom(&[n as usize, k as usize]);
        let shard = spec.shard_weight(Stage::Expand, &w);
        assert_eq!(shard.numel() as u64 * 4, mm25d_weight_bytes_per_rank(p, d, n, k));
        let (ar, ac) = spec.activation_shape(m as usize, n as usize);
        assert_eq!((ar * ac) as u64 * 4, mm25d_activation_bytes_per_rank(p, d, m, n));
        let hspec = ShardSpec::hybrid(2, MeshSpec::Line(2), 0);
        let hshard = hspec.shard_weight(Stage::Expand, &w);
        assert_eq!(hshard.numel() as u64 * 4, hybrid_weight_bytes_per_rank(2, n, k));
        let (hr, hc) = hspec.activation_shape(m as usize, n as usize);
        assert_eq!((hr * hc) as u64 * 4, hybrid_activation_bytes_per_rank(2, 1, m, n));
    }

    #[test]
    fn zero_optimizer_state_bytes_shrink_by_exactly_one_rth() {
        // Acceptance pin: the closed forms must match the bytes the *real*
        // partitioned optimizer allocates, and shrink by exactly 1/r vs
        // replication when r divides every parameter.
        use crate::config::{OptimizerKind, TrainConfig};
        use crate::optim::Optimizer;
        let shapes: Vec<Vec<usize>> = vec![
            vec![64, 96],
            vec![32, 64],
            vec![64, 128],
            vec![128, 64],
            vec![64],
            vec![64],
        ];
        let numels: Vec<u64> =
            shapes.iter().map(|s| s.iter().product::<usize>() as u64).collect();
        let cfg = TrainConfig { optimizer: OptimizerKind::Adam, ..TrainConfig::default() };
        let full = Optimizer::new(&cfg, &shapes);
        let full_bytes: u64 =
            full.state_tensors().iter().map(|t| t.numel() as u64 * 4).sum();
        assert_eq!(full_bytes, adam_state_bytes_per_rank(&numels));
        for r in [2usize, 4, 8] {
            for idx in 0..r {
                let part = Optimizer::new_partitioned(&cfg, &shapes, r, idx);
                let part_bytes: u64 =
                    part.state_tensors().iter().map(|t| t.numel() as u64 * 4).sum();
                assert_eq!(part_bytes, zero_adam_state_bytes_per_rank(&numels, r as u64));
                assert_eq!(part_bytes * r as u64, full_bytes, "exact 1/{r} shrink");
            }
        }
        // Non-divisible parameter: the pad rounds up to ceil(7/2) = 4
        // moment pairs per replica (the flat_chunks boundary).
        let part = Optimizer::new_partitioned(&cfg, &[vec![7usize]], 2, 1);
        let part_bytes: u64 =
            part.state_tensors().iter().map(|t| t.numel() as u64 * 4).sum();
        assert_eq!(part_bytes, zero_adam_state_bytes_per_rank(&[7], 2));
        assert_eq!(part_bytes, 2 * 4 * 4);
        // The composite plan column decomposes as documented.
        assert_eq!(
            optimizer_bytes_per_rank(&numels, 4, 0),
            grad_bytes_per_rank(&numels, 4, 0) + adam_state_bytes_per_rank(&numels)
        );
        assert_eq!(
            optimizer_bytes_per_rank(&numels, 4, 1),
            grad_bytes_per_rank(&numels, 4, 0) + zero_adam_state_bytes_per_rank(&numels, 4)
        );
        assert_eq!(
            optimizer_bytes_per_rank(&numels, 4, 2),
            grad_bytes_per_rank(&numels, 4, 2) + zero_adam_state_bytes_per_rank(&numels, 4)
        );
    }

    #[test]
    fn paper_complexity_claims_hold() {
        // §3.1.2: 3-D comm volume per rank is O(P^{-2/3}) = O(1/p²): growing
        // p at fixed problem size divides bytes by ~p² (up to the (p−1)/p
        // ring factor).
        let (m, n, k) = (512, 512, 512);
        let b2 = mm3d_fwd_bytes_per_rank(2, m, n, k) as f64;
        let b4 = mm3d_fwd_bytes_per_rank(4, m, n, k) as f64;
        let ratio = b2 / b4;
        // ((2-1)/8) / ((4-1)/64) = 8/3 ≈ 2.67 ; O(1/p²) alone predicts 4.
        assert!((2.2..4.2).contains(&ratio), "ratio {ratio}");
        // §3.1.1: memory O(1/P).
        assert_eq!(
            activation_bytes_per_rank(64, m, n, Approach::ThreeD) * 64,
            activation_bytes_per_rank(1, m, n, Approach::Seq)
        );
        // 1-D replicates activations: no scaling.
        assert_eq!(
            activation_bytes_per_rank(64, m, n, Approach::OneD),
            activation_bytes_per_rank(1, m, n, Approach::Seq)
        );
    }

    #[test]
    fn flat_network_prediction_matches_engine_within_5pct() {
        let p = 2usize;
        let (m, n, k) = (64usize, 64usize, 64usize);
        let dirs = Dirs::canonical();
        let net = NetModel::flat(1e-6, 1e9, 1e12);
        let net2 = net.clone();
        let a_shape = Layout3D::input(dirs).shard_shape(p, m, n);
        let b_shape = Layout3D::weight(dirs).shard_shape(p, n, k);
        let clocks = run_spmd(8, net, move |rank, ep| {
            let ctx = Ctx3D::new(Cube::new(p), rank);
            let a = Tensor::phantom(&[a_shape.0, a_shape.1]);
            let b = Tensor::phantom(&[b_shape.0, b_shape.1]);
            let _ = mm_nn(ep, &ctx, &a, &b, dirs);
            ep.clock
        });
        let makespan = clocks.into_iter().fold(0.0f64, f64::max);
        let predicted = mm3d_fwd_time_flat(&net2, p as u64, m as u64, n as u64, k as u64);
        let rel = (makespan - predicted).abs() / predicted;
        assert!(rel < 0.05, "engine {makespan} vs model {predicted} (rel {rel})");
    }

    #[test]
    fn overlap_recurrence_pins_hand_computed_backlog() {
        // f: max(0,0)+2 = 2; max(2,1)+3 = 5; max(5,7)+1 = 8.
        let boundaries = [(0.0, 2.0), (1.0, 3.0), (7.0, 1.0)];
        assert_eq!(overlapped_exposed_comm(&boundaries, 9.0), 0.0);
        assert_eq!(overlapped_exposed_comm(&boundaries, 6.0), 2.0);
        assert_eq!(overlapped_exposed_comm(&[], 5.0), 0.0);
        assert_eq!(overlapped_step_time(6.0, &boundaries), 8.0);
        // Uniform special case: comm outruns the hideable window by the
        // difference, or hides entirely.
        assert_eq!(exposed_comm_uniform(3.0, 1.0), 2.0);
        assert_eq!(exposed_comm_uniform(1.0, 3.0), 0.0);
    }

    #[test]
    fn overlap_recurrence_matches_endpoint_clock_exactly() {
        use crate::collectives::all_reduce;
        // Dyadic virtual time: a phantom [256] all-reduce over 2 ranks moves
        // 512-byte chunks, so beta = 512·2²⁰ B/s makes every hop exactly
        // 2⁻²⁰ s. With a zero latency term, zero launch overhead and an
        // infinite flop rate, every clock advance inside a defer window is a
        // dyadic comm charge — f64 arithmetic is exact and the backlog
        // recurrence must equal the engine clock *bitwise*.
        const TICK: f64 = 1.0 / (1 << 20) as f64;
        let beta = 512.0 * (1 << 20) as f64;
        let mk_net = |overlap: bool| {
            let mut net = NetModel::flat(0.0, beta, f64::INFINITY);
            net.overlap = overlap; // pin regardless of CUBIC_OVERLAP
            net
        };
        // Measure one serialized window's duration (identical windows).
        let c = run_spmd(2, mk_net(false), move |_rank, ep| {
            let t = Tensor::phantom(&[256]);
            let t0 = ep.clock;
            let _ = all_reduce(ep, &[0, 1], &t);
            ep.clock - t0
        })[0];
        assert!(c > 0.0);
        // Three deferred windows issued one tick apart: an all-reduce is at
        // least two sequential hops (reduce-scatter + all-gather), so c ≥ 2
        // ticks and the comm timeline provably backs up past the join.
        let gaps = [1.0 * TICK, 1.0 * TICK, 1.0 * TICK];
        let tail = 1.0 * TICK;
        let got = run_spmd(2, mk_net(true), move |_rank, ep| {
            let t = Tensor::phantom(&[256]);
            let mut issues = Vec::new();
            for g in gaps {
                ep.clock += g; // stand-in for charge_flops at an ∞ flop rate
                issues.push(ep.clock);
                let (_y, ticket) = ep.defer(|ep| all_reduce(ep, &[0, 1], &t));
                assert!(ticket.is_some(), "overlap on: window must defer");
            }
            ep.clock += tail;
            let t_join = ep.clock;
            ep.join_all();
            (issues, t_join, ep.clock, ep.stats.clone())
        });
        for (rank, (issues, t_join, clock, stats)) in got.iter().enumerate() {
            let boundaries: Vec<(f64, f64)> = issues.iter().map(|&t| (t, c)).collect();
            let exposed = overlapped_exposed_comm(&boundaries, *t_join);
            assert!(exposed > 0.0, "rank {rank}: backlog should outlive the join");
            assert_eq!(*clock, overlapped_step_time(*t_join, &boundaries), "rank {rank}");
            // Ledger partition: the engine's exposed share equals the closed
            // form exactly, and exposed + overlapped == comm_time.
            assert_eq!(stats.exposed_comm_time, exposed, "rank {rank}");
            assert_eq!(
                stats.exposed_comm_time + stats.overlapped_comm_time,
                stats.comm_time,
                "rank {rank}"
            );
        }
    }

    #[test]
    fn pipeline_schedule_closed_forms() {
        // c = w = 0: the flush schedule telescopes to (m+s−1)(f+b), and the
        // idle share is exactly the closed-form bubble fraction.
        for (s, m) in [(1usize, 1usize), (2, 4), (4, 4), (3, 8)] {
            let t = pipeline_step_time(s, m, 1.0, 0.5, 0.0, 0.0);
            assert_eq!(t, (m + s - 1) as f64 * 1.5, "s={s} m={m}");
            assert_eq!(
                (t - m as f64 * 1.5) / t,
                pipeline_bubble_fraction(s as u64, m as u64),
                "s={s} m={m}"
            );
        }
        assert_eq!(pipeline_bubble_fraction(1, 8), 0.0);
        // More micro-batches shrink the bubble; more stages grow it.
        assert!(pipeline_bubble_fraction(4, 16) < pipeline_bubble_fraction(4, 4));
        assert!(pipeline_bubble_fraction(8, 8) > pipeline_bubble_fraction(2, 8));
        // A boundary transfer cost delays every stage handoff.
        assert!(
            pipeline_step_time(2, 4, 1.0, 1.0, 0.25, 0.0)
                > pipeline_step_time(2, 4, 1.0, 1.0, 0.0, 0.0)
        );
    }

    #[test]
    fn pipeline_recurrence_matches_engine_clock_bitwise() {
        use crate::config::ModelConfig;
        use crate::engine::time_core_step;
        use crate::topology::{Parallelism, PipelineInner};
        // Dyadic pin: communication exactly free (alpha 0, beta ∞, zero
        // launch overhead), flop rate a power of two — every clock charge
        // is an exact dyadic rational, f64 arithmetic on them is exact,
        // and the schedule recurrence must equal the engine clock bitwise.
        let mut net = NetModel::flat(0.0, f64::INFINITY, (1u64 << 33) as f64);
        net.overlap = false; // pin regardless of CUBIC_OVERLAP
        let cfg = ModelConfig::tiny(); // layers 2, batch 4: s=2, m ≤ 4
        let t = |m: usize| {
            let par = Parallelism::Pipeline {
                stages: 2,
                micro_batches: m,
                inner: PipelineInner::OneD,
            };
            let r = time_core_step(&cfg, par, 2, net.clone()).unwrap();
            r.forward_s + r.backward_s
        };
        let (t1, t2, t4) = (t(1), t(2), t(4));
        // With c = 0 the makespan is T(m) = (m+s−1)·(f+b) + w, where
        // f + b = P/m for stage compute P. Solve for P and w from two
        // measurements; the recurrence must then reproduce all of them.
        let p = 2.0 * (t1 - t2);
        let w = t1 - 2.0 * p;
        assert!(p > 0.0 && w > 0.0, "P {p}, w {w}");
        assert_eq!(t1, pipeline_step_time(2, 1, p / 2.0, p / 2.0, 0.0, w));
        assert_eq!(t2, pipeline_step_time(2, 2, p / 4.0, p / 4.0, 0.0, w));
        assert_eq!(t4, pipeline_step_time(2, 4, p / 8.0, p / 8.0, 0.0, w));
        // The measured idle share of the schedule portion (flush excluded)
        // is exactly the closed-form bubble fraction.
        assert_eq!((t2 - w - p) / (t2 - w), pipeline_bubble_fraction(2, 2));
        assert_eq!((t4 - w - p) / (t4 - w), pipeline_bubble_fraction(2, 4));
    }

    #[test]
    fn pipeline_memory_formulas() {
        // A stage holds 1/s of the layers (inner-sharded); the stash keeps
        // every micro-batch cache alive until the flush, so activations
        // match the full batch regardless of m.
        assert_eq!(pipeline_weight_bytes_per_rank(4, 2, 64, 256), 64 * 256 * 4 / 8);
        assert_eq!(
            pipeline_activation_bytes_per_rank(1, 128, 64),
            activation_bytes_per_rank(1, 128, 64, Approach::Seq)
        );
        assert_eq!(pipeline_activation_bytes_per_rank(4, 128, 64), 128 * 64 * 4 / 4);
    }

    #[test]
    fn overlapped_hybrid_step_beats_serialized_and_splits_the_ledger() {
        use crate::config::ModelConfig;
        use crate::engine::time_core_step;
        use crate::topology::{HybridInner, Parallelism};
        let cfg = ModelConfig::paper(1024, 8);
        let par = Parallelism::Hybrid { replicas: 2, inner: HybridInner::TwoD };
        let mut on = NetModel::longhorn_v100();
        on.overlap = true;
        let mut off = on.clone();
        off.overlap = false;
        let t_on = time_core_step(&cfg, par, 2, on).unwrap();
        let t_off = time_core_step(&cfg, par, 2, off).unwrap();
        // Hybrid ranks are symmetric, so the independently max-merged
        // metrics still satisfy the per-rank ledger partition.
        let m = &t_on.metrics;
        assert!(
            (m.exposed_comm_time + m.overlapped_comm_time - m.comm_time).abs()
                <= 1e-9 * m.comm_time,
            "exposed {} + overlapped {} != comm {}",
            m.exposed_comm_time,
            m.overlapped_comm_time,
            m.comm_time
        );
        assert!(m.overlapped_comm_time > 0.0, "replica syncs should hide");
        assert!(m.exposed_comm_time < m.comm_time);
        // A serialized schedule exposes every comm second.
        let s = &t_off.metrics;
        assert_eq!(s.overlapped_comm_time, 0.0);
        assert_eq!(s.exposed_comm_time, s.comm_time);
        // Hiding communication can only shorten the step — and for the
        // hybrid's off-critical-path replica syncs it strictly must.
        let step_on = t_on.forward_s + t_on.backward_s;
        let step_off = t_off.forward_s + t_off.backward_s;
        assert!(
            step_on < step_off,
            "overlapped {step_on} should beat serialized {step_off}"
        );
    }

    #[test]
    fn kv_cache_bytes_match_decode_kv_nominal_every_kind() {
        use crate::dist::ShardSpec;
        use crate::model::attention::DecodeKv;
        use crate::topology::{HybridInner, Parallelism, PipelineInner};
        let (slots, heads, head_dim, max_seq) = (8usize, 8usize, 16usize, 32usize);
        let envs: [(Parallelism, usize); 7] = [
            (Parallelism::Seq, 1),
            (Parallelism::OneD, 4),
            (Parallelism::TwoD, 2),
            (Parallelism::ThreeD, 2),
            (Parallelism::TwoFiveD { depth: 2 }, 2),
            (Parallelism::Hybrid { replicas: 2, inner: HybridInner::OneD }, 2),
            (
                Parallelism::Pipeline {
                    stages: 2,
                    micro_batches: 4,
                    inner: PipelineInner::OneD,
                },
                2,
            ),
        ];
        for (par, edge) in envs {
            for rank in 0..par.world_size(edge) {
                let spec = ShardSpec::for_parallelism(par, edge, rank);
                // Build the cache exactly as `serve::build_kv` does — local
                // slots from the activation-row division, local heads from
                // the column split — and pin the closed form against it.
                let kv = DecodeKv::new(
                    spec.activation_rows(slots),
                    spec.local_heads(heads),
                    head_dim,
                    max_seq,
                    true,
                );
                assert_eq!(
                    kv.nominal_bytes(),
                    kv_cache_bytes_per_rank(
                        par,
                        edge,
                        rank,
                        slots as u64,
                        heads as u64,
                        head_dim as u64,
                        max_seq as u64
                    ),
                    "{par:?} rank {rank}"
                );
            }
        }
    }

    #[test]
    fn decode_linear_bytes_match_engine_ledger_exactly() {
        // Run the four decode linears of one layer in phantom mode on each
        // leaf mesh and pin the measured per-rank bytes against the closed
        // form — the serve analogue of the training matmul pins above.
        use crate::config::ModelConfig;
        use crate::dist::Stage;
        use crate::parallel::{ops_for, ParallelOps};
        use crate::topology::Parallelism;
        let cfg = ModelConfig { hidden: 32, ffn: 64, heads: 4, ..ModelConfig::tiny() };
        let slots = 8usize;
        for (par, edge) in [
            (Parallelism::Seq, 1),
            (Parallelism::OneD, 4),
            (Parallelism::TwoD, 2),
            (Parallelism::ThreeD, 2),
            (Parallelism::TwoFiveD { depth: 2 }, 2),
        ] {
            let world = par.world_size(edge);
            let cfg2 = cfg.clone();
            let measured =
                run_spmd(world, NetModel::flat(0.0, 1e9, f64::INFINITY), move |rank, ep| {
                    let ops = ops_for(par, edge, rank);
                    let blk = ops.phantom_block(&cfg2);
                    let (lr, lc) = ops.activation_shape(slots, cfg2.hidden);
                    let x = Tensor::phantom(&[lr, lc]);
                    let qkv = ops.linear_fwd(ep, &x, &blk.w_qkv, None, Stage::Expand);
                    let attn = Tensor::phantom(&[lr, qkv.dims2().1 / 3]);
                    let _ = ops.linear_fwd(ep, &attn, &blk.w_proj, None, Stage::Reduce);
                    let h = ops.linear_fwd(ep, &x, &blk.w_fc1, None, Stage::Expand);
                    let _ = ops.linear_fwd(ep, &h, &blk.w_fc2, None, Stage::Reduce);
                    ep.join_all();
                    ep.stats.bytes_sent
                });
            let want = decode_step_comm_bytes_per_rank(
                par,
                edge as u64,
                slots as u64,
                cfg.hidden as u64,
                cfg.ffn as u64,
                1,
            );
            for (rank, &got) in measured.iter().enumerate() {
                assert_eq!(got, want, "{par:?} rank {rank}");
            }
        }
    }
}
