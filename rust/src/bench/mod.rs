//! Benchmark library: the paper's Tables 1–2 row specifications and the
//! shared runner used by both `cargo bench` targets and the `cubic bench-*`
//! CLI subcommands.
//!
//! Every row runs [`crate::engine::time_core_step`] — one forward+backward
//! of the Transformer core in phantom mode on the virtual-clock cluster
//! calibrated to the paper's testbed ([`NetModel::longhorn_v100`]) — and
//! prints measured values next to the paper's, so shape fidelity (who wins,
//! by what factor, where the crossovers sit) is visible at a glance.
//!
//! Absolute numbers are *not* expected to match the paper: the authors
//! timed an unspecified stack of layers for an unspecified iteration count
//! on real V100s; we time `LAYERS` layers once on an α-β model. Ratios
//! within each table are the reproduction target (EXPERIMENTS.md).

use crate::comm::NetModel;
use crate::config::ModelConfig;
use crate::engine::{time_core_step, CoreTiming};
use crate::metrics::{fmt_s, Table};
use crate::topology::Parallelism;

/// Layer count used by all table rows ("the consecutive Transformer
/// layers"); ratios are invariant to this choice.
pub const LAYERS: usize = 4;

/// One table row: the paper's configuration and its reported numbers.
#[derive(Clone, Debug)]
pub struct RowSpec {
    pub approach: Parallelism,
    pub gpus: usize,
    pub edge: usize,
    pub batch: usize,
    pub hidden: usize,
    pub paper_fwd: f64,
    pub paper_bwd: f64,
    pub paper_avg: f64,
}

impl RowSpec {
    pub fn model(&self) -> ModelConfig {
        ModelConfig {
            layers: LAYERS,
            ..ModelConfig::paper(self.hidden, self.batch)
        }
    }
}

/// Paper Table 1 (weak scaling): per-approach batch/hidden grow with GPUs.
pub fn table1_rows() -> Vec<RowSpec> {
    use Parallelism::*;
    let r = |approach, gpus, edge, batch, hidden, pf, pb, pa| RowSpec {
        approach, gpus, edge, batch, hidden,
        paper_fwd: pf, paper_bwd: pb, paper_avg: pa,
    };
    vec![
        r(OneD, 8, 8, 60, 2048, 4.759, 15.676, 0.341),
        r(OneD, 16, 16, 60, 4096, 12.488, 30.894, 0.723),
        r(OneD, 36, 36, 40, 6120, 13.515, 31.822, 1.133),
        r(OneD, 64, 64, 30, 8192, 13.915, 32.890, 1.560),
        r(TwoD, 16, 4, 192, 4096, 33.860, 101.981, 0.708),
        r(TwoD, 36, 6, 288, 6120, 54.760, 165.850, 0.766),
        r(TwoD, 64, 8, 384, 8192, 99.419, 304.707, 1.052),
        r(ThreeD, 8, 2, 192, 2048, 30.096, 81.212, 0.580),
        r(ThreeD, 64, 4, 384, 8192, 79.349, 125.037, 0.672),
    ]
}

/// Paper Table 2 (strong scaling): fixed problem (hidden 3072), 8→64 GPUs.
pub fn table2_rows() -> Vec<RowSpec> {
    use Parallelism::*;
    let r = |approach, gpus, edge, batch, pf, pb, pa| RowSpec {
        approach, gpus, edge, batch, hidden: 3072,
        paper_fwd: pf, paper_bwd: pb, paper_avg: pa,
    };
    vec![
        r(OneD, 8, 8, 12, 1.470, 5.699, 0.597),
        r(OneD, 16, 16, 12, 1.371, 5.152, 0.544),
        r(OneD, 36, 36, 12, 1.455, 5.414, 0.572),
        r(OneD, 64, 64, 12, 1.433, 5.167, 0.550),
        r(TwoD, 16, 4, 24, 4.680, 13.698, 0.766),
        r(TwoD, 36, 6, 24, 3.900, 11.433, 0.639),
        r(TwoD, 64, 8, 24, 3.007, 8.920, 0.497),
        r(ThreeD, 8, 2, 24, 3.249, 9.120, 0.515),
        r(ThreeD, 64, 4, 24, 2.494, 6.129, 0.359),
    ]
}

/// Measured results for one row.
#[derive(Clone, Debug)]
pub struct RowResult {
    pub spec: RowSpec,
    pub timing: CoreTiming,
}

impl RowResult {
    pub fn avg_step(&self) -> f64 {
        self.timing.avg_step_time(self.spec.batch)
    }
}

/// Run every row of a table on the calibrated network model.
pub fn run_rows(rows: &[RowSpec], net: &NetModel) -> Vec<RowResult> {
    rows.iter()
        .map(|spec| {
            let timing = time_core_step(&spec.model(), spec.approach, spec.edge, net.clone())
                .expect("timing run failed");
            RowResult { spec: spec.clone(), timing }
        })
        .collect()
}

/// Render results as a paper-style markdown table with the paper's numbers
/// alongside.
pub fn render(title: &str, results: &[RowResult]) -> String {
    let mut t = Table::new(&[
        "Approach", "# GPUs", "Batch", "Hidden",
        "Fwd (s)", "Bwd (s)", "Avg step (s)", "Paper avg (s)",
    ]);
    for r in results {
        t.row(&[
            r.spec.approach.name().to_string(),
            r.spec.gpus.to_string(),
            r.spec.batch.to_string(),
            r.spec.hidden.to_string(),
            fmt_s(r.timing.forward_s),
            fmt_s(r.timing.backward_s),
            format!("{:.4}", r.avg_step()),
            format!("{:.3}", r.spec.paper_avg),
        ]);
    }
    format!("## {title}\n\n{}", t.to_markdown())
}

/// The paper's headline: 3-D speedup over 1-D and 2-D at 64 GPUs in the
/// strong-scaling table. Returns `(speedup_vs_1d, speedup_vs_2d)`.
pub fn strong_scaling_speedups(results: &[RowResult]) -> (f64, f64) {
    let avg = |par: Parallelism| {
        results
            .iter()
            .find(|r| r.spec.approach == par && r.spec.gpus == 64)
            .map(|r| r.avg_step())
            .expect("missing 64-GPU row")
    };
    let d3 = avg(Parallelism::ThreeD);
    (avg(Parallelism::OneD) / d3, avg(Parallelism::TwoD) / d3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn row_specs_match_paper_values() {
        let t1 = table1_rows();
        assert_eq!(t1.len(), 9);
        assert_eq!(t1[0].paper_avg, 0.341);
        assert_eq!(t1[8].hidden, 8192);
        let t2 = table2_rows();
        assert_eq!(t2.len(), 9);
        // Paper headline: 0.550/0.359 = 2.32x? No — the paper compares
        // 1-D's *best* 64-GPU step (0.550) vs 3-D (0.359)... actually
        // 0.550/0.359 ≈ 1.53 and 0.497/0.359 ≈ 1.38; the 2.32X/1.57X
        // quoted in the abstract uses different normalization (per-sample
        // at equal batch: 1-D runs batch 12, 2/3-D batch 24). Eq. 6
        // already divides by batch, so per-sequence: 1-D 0.550 vs 3-D
        // 0.359·... — we simply pin the raw table values here.
        assert_eq!(t2[3].paper_avg, 0.550);
        assert_eq!(t2[8].paper_avg, 0.359);
    }

    #[test]
    fn weak_scaling_3d_rises_slowest() {
        // Cheap smoke on a scaled-down variant of Table 1 (hidden/seq
        // reduced 4x to keep test time tiny; ratios preserved).
        let net = NetModel::longhorn_v100();
        let shrink = |mut r: RowSpec| {
            r.hidden /= 4;
            r
        };
        let rows: Vec<RowSpec> = table1_rows().into_iter().map(shrink).collect();
        let results = run_rows(&rows, &net);
        let growth = |par: Parallelism| {
            let rs: Vec<&RowResult> =
                results.iter().filter(|r| r.spec.approach == par).collect();
            rs.last().unwrap().avg_step() / rs[0].avg_step()
        };
        let g1 = growth(Parallelism::OneD);
        let g3 = growth(Parallelism::ThreeD);
        assert!(
            g3 < g1,
            "3-D avg-step growth {g3} should be below 1-D {g1}"
        );
    }

    #[test]
    fn strong_scaling_3d_wins_at_64() {
        let net = NetModel::longhorn_v100();
        let results = run_rows(&table2_rows(), &net);
        let (s1, s2) = strong_scaling_speedups(&results);
        assert!(s1 > 1.0, "3-D should beat 1-D at 64 GPUs (got {s1})");
        assert!(s2 > 1.0, "3-D should beat 2-D at 64 GPUs (got {s2})");
    }
}
