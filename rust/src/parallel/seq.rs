//! The dense single-device implementation of [`ParallelOps`] — no
//! communication, plain local linear algebra. This is the reference every
//! distributed implementation is verified against shard-for-shard, *and* an
//! ordinary leaf of the same trait: the generic block in
//! [`crate::model::block`] cannot tell it apart from the 3-D cube.
//!
//! **Overlap.** This leaf performs no communication at all, so the
//! compute/comm overlap machinery ([`crate::comm::Endpoint::defer`]) is a
//! no-op here: `CUBIC_OVERLAP` cannot change its clock, which is what makes
//! it the stable baseline of the `plan` table under either schedule.

use crate::comm::Endpoint;
use crate::dist::{ShardSpec, Stage};
use crate::model::{local_layernorm, local_layernorm_backward, local_layernorm_backward_dx};
use crate::parallel::ParallelOps;
use crate::tensor::Tensor;

/// Single-device environment: every tensor is global, every op local.
pub struct Seq {
    spec: ShardSpec,
}

impl Seq {
    /// The single-device context (rank 0 of a one-rank world).
    pub fn new() -> Seq {
        Seq { spec: ShardSpec::seq() }
    }
}

impl Default for Seq {
    fn default() -> Self {
        Self::new()
    }
}

fn charge_mm(ep: &mut Endpoint, m: usize, n: usize, k: usize) {
    ep.charge_flops(2.0 * m as f64 * n as f64 * k as f64);
}

fn req<'a>(t: Option<&'a Tensor>, name: &str) -> &'a Tensor {
    t.unwrap_or_else(|| panic!("replicated rank owns every vector; missing {name}"))
}

// Local ops over replicated (fully rank-local) activations — shared by the
// `Seq` implementation and `Ctx1D` (whose block-entry activations are also
// replicated), so the cost charges and layernorm semantics cannot drift
// between the two.

pub(crate) fn replicated_vec_op(
    ep: &mut Endpoint,
    a: &Tensor,
    v: Option<&Tensor>,
    mul: bool,
) -> Tensor {
    ep.charge_memop(a.nominal_bytes() as f64);
    let v = req(v, "vec_op vector");
    if mul {
        a.mul_row_vector(v)
    } else {
        a.add_row_vector(v)
    }
}

pub(crate) fn replicated_layernorm(
    ep: &mut Endpoint,
    x: &Tensor,
    gamma: Option<&Tensor>,
    beta: Option<&Tensor>,
    eps: f32,
) -> (Tensor, Tensor, Tensor) {
    ep.charge_memop(4.0 * x.nominal_bytes() as f64);
    local_layernorm(x, req(gamma, "ln γ"), req(beta, "ln β"), eps)
}

pub(crate) fn replicated_layernorm_backward(
    ep: &mut Endpoint,
    dy: &Tensor,
    xhat: &Tensor,
    inv_std: &Tensor,
    gamma: Option<&Tensor>,
) -> (Tensor, Option<Tensor>, Option<Tensor>) {
    ep.charge_memop(4.0 * dy.nominal_bytes() as f64);
    let (dx, dg, db) = local_layernorm_backward(dy, xhat, inv_std, req(gamma, "ln γ"));
    (dx, Some(dg), Some(db))
}

/// The `dx` half of [`replicated_layernorm_backward`] on its own — the
/// default [`ParallelOps::layernorm_backward_dx`] for replicated meshes
/// (Seq, 1-D). Bit-identical `dx` to the joint routine.
pub(crate) fn replicated_layernorm_backward_dx(
    ep: &mut Endpoint,
    dy: &Tensor,
    xhat: &Tensor,
    inv_std: &Tensor,
    gamma: Option<&Tensor>,
) -> Tensor {
    ep.charge_memop(4.0 * dy.nominal_bytes() as f64);
    local_layernorm_backward_dx(dy, xhat, inv_std, req(gamma, "ln γ"))
}

/// The `(dγ, dβ)` half of [`replicated_layernorm_backward`] — the same
/// `dy ⊙ xhat` / plain column sums the joint routine computes, so grads
/// from concatenated micro-batch rows are bit-identical to full-batch.
pub(crate) fn replicated_layernorm_param_grads(
    ep: &mut Endpoint,
    dy: &Tensor,
    xhat: &Tensor,
) -> (Option<Tensor>, Option<Tensor>) {
    ep.charge_memop(2.0 * dy.nominal_bytes() as f64);
    (Some(dy.mul(xhat).sum_rows()), Some(dy.sum_rows()))
}

impl ParallelOps for Seq {
    fn spec(&self) -> &ShardSpec {
        &self.spec
    }

    fn matmul_nn(&self, ep: &mut Endpoint, x: &Tensor, w: &Tensor, _stage: Stage) -> Tensor {
        let (m, n) = x.dims2();
        let k = w.dims2().1;
        charge_mm(ep, m, k, n);
        x.matmul(w)
    }

    fn matmul_nt(&self, ep: &mut Endpoint, dy: &Tensor, w: &Tensor, _stage: Stage) -> Tensor {
        let (m, k) = dy.dims2();
        let n = w.dims2().0;
        charge_mm(ep, m, n, k);
        dy.matmul_nt(w)
    }

    fn matmul_tn(&self, ep: &mut Endpoint, x: &Tensor, dy: &Tensor, _stage: Stage) -> Tensor {
        let (m, n) = x.dims2();
        let k = dy.dims2().1;
        charge_mm(ep, n, k, m);
        x.matmul_tn(dy)
    }

    fn linear_fwd(
        &self,
        ep: &mut Endpoint,
        x: &Tensor,
        w: &Tensor,
        b: Option<&Tensor>,
        stage: Stage,
    ) -> Tensor {
        self.matmul_nn(ep, x, w, stage).add_row_vector(req(b, "bias"))
    }

    fn linear_bwd(
        &self,
        ep: &mut Endpoint,
        dy: &Tensor,
        x: &Tensor,
        w: &Tensor,
        stage: Stage,
    ) -> (Tensor, Tensor, Option<Tensor>) {
        let db = dy.sum_rows();
        let dx = self.matmul_nt(ep, dy, w, stage);
        let dw = self.matmul_tn(ep, x, dy, stage);
        (dx, dw, Some(db))
    }

    fn vec_op(&self, ep: &mut Endpoint, a: &Tensor, v: Option<&Tensor>, mul: bool) -> Tensor {
        replicated_vec_op(ep, a, v, mul)
    }

    fn layernorm(
        &self,
        ep: &mut Endpoint,
        x: &Tensor,
        gamma: Option<&Tensor>,
        beta: Option<&Tensor>,
        eps: f32,
        _hidden: usize,
    ) -> (Tensor, Tensor, Tensor) {
        replicated_layernorm(ep, x, gamma, beta, eps)
    }

    fn layernorm_backward(
        &self,
        ep: &mut Endpoint,
        dy: &Tensor,
        xhat: &Tensor,
        inv_std: &Tensor,
        gamma: Option<&Tensor>,
        _hidden: usize,
    ) -> (Tensor, Option<Tensor>, Option<Tensor>) {
        replicated_layernorm_backward(ep, dy, xhat, inv_std, gamma)
    }
}
