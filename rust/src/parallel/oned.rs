//! 1-D tensor parallelism — the Megatron-LM baseline [17].
//!
//! Weights are split along a single dimension across the `P`-rank group;
//! activations are replicated. A Transformer block pairs a *column-parallel*
//! linear (no forward communication; the output is column-sharded, so the
//! following elementwise ops run on shards) with a *row-parallel* linear
//! (one all-reduce to sum the partial products). Backward mirrors this with
//! one all-reduce for the input gradient of the column-parallel layer.
//!
//! Per-block communication: 2 all-reduces of the full activation forward,
//! 2 backward — the `O(1)`-in-`P` bandwidth profile the paper's Tables 1–2
//! show losing to 2-D/3-D at large `P`.
//!
//! **Overlap.** Both all-reduces sum *activation* partials that the very
//! next op consumes, and the weight gradients are rank-local (each rank
//! owns its shard outright) — there is nothing to defer, so this leaf's
//! clock is identical under `CUBIC_OVERLAP=0` and `=1`. The hideable
//! boundary only appears when the hybrid wrapper adds replica grad syncs
//! around this mesh.

use crate::collectives::all_reduce;
use crate::comm::Endpoint;
use crate::dist::{ShardSpec, Stage};
use crate::parallel::seq::{
    replicated_layernorm, replicated_layernorm_backward, replicated_vec_op,
};
use crate::parallel::ParallelOps;
use crate::tensor::Tensor;

/// Per-rank context: the ordered tensor-parallel group and this rank's
/// position in it.
pub struct Ctx1D {
    /// Global ranks of the tensor-parallel line, in order.
    pub group: Vec<usize>,
    /// This rank's position in `group`.
    pub pos: usize,
    spec: ShardSpec,
}

impl Ctx1D {
    /// Context for `rank` of a stand-alone `world`-rank line (base 0).
    pub fn new(world: usize, rank: usize) -> Self {
        Self::with_base(world, rank, 0)
    }

    /// Like [`Ctx1D::new`] but the `world` group occupies global ranks
    /// `base..base + world` — the hook that lets an outer mesh (a hybrid
    /// replica group) embed 1-D lines anywhere in the rank space. `rank` is
    /// the line-local position; the endpoint's global rank must be
    /// `base + rank`.
    pub fn with_base(world: usize, rank: usize, base: usize) -> Self {
        Ctx1D {
            group: (base..base + world).collect(),
            pos: rank,
            spec: ShardSpec::oned(world, rank),
        }
    }

    /// Ranks in the line.
    pub fn world(&self) -> usize {
        self.group.len()
    }
}

fn charge_mm(ep: &mut Endpoint, m: usize, n: usize, k: usize) {
    ep.charge_flops(2.0 * m as f64 * n as f64 * k as f64);
}

/// Column-parallel linear forward: `Y_i = X·W_i + b_i`.
///
/// `x` is replicated `(M, N)`; `w_shard` is the rank's column slice
/// `(N, K/P)`; `b_shard` its bias slice `(K/P)`. Returns the column shard
/// `(M, K/P)` of `Y` — no communication.
pub fn col_linear_fwd(
    ep: &mut Endpoint,
    _ctx: &Ctx1D,
    x: &Tensor,
    w_shard: &Tensor,
    b_shard: Option<&Tensor>,
) -> Tensor {
    let (m, n) = x.dims2();
    let k = w_shard.dims2().1;
    charge_mm(ep, m, k, n);
    let y = x.matmul(w_shard);
    match b_shard {
        Some(b) => {
            ep.charge_memop(y.nominal_bytes() as f64);
            y.add_row_vector(b)
        }
        None => y,
    }
}

/// Column-parallel linear backward. Returns `(dX, dW_i, db_i)`; `dX` is the
/// full replicated gradient (one all-reduce over the group).
pub fn col_linear_bwd(
    ep: &mut Endpoint,
    ctx: &Ctx1D,
    dy_shard: &Tensor,
    x: &Tensor,
    w_shard: &Tensor,
) -> (Tensor, Tensor, Tensor) {
    let (m, n) = x.dims2();
    let k = dy_shard.dims2().1;
    charge_mm(ep, m, n, k);
    let dx_partial = dy_shard.matmul_nt(w_shard); // (M, N) partial
    let dx = all_reduce(ep, &ctx.group, &dx_partial);
    charge_mm(ep, n, k, m);
    let dw = x.matmul_tn(dy_shard); // (N, K/P)
    ep.charge_memop(dy_shard.nominal_bytes() as f64);
    let db = dy_shard.sum_rows();
    (dx, dw, db)
}

/// Row-parallel linear forward: `Y = Σ_i X_i·W_i + b`.
///
/// `x_shard` is the rank's column slice `(M, N/P)` of the input (as produced
/// by a preceding column-parallel layer); `w_shard` the row slice
/// `(N/P, K)`. One all-reduce; returns the replicated `(M, K)` output.
pub fn row_linear_fwd(
    ep: &mut Endpoint,
    ctx: &Ctx1D,
    x_shard: &Tensor,
    w_shard: &Tensor,
    b: Option<&Tensor>,
) -> Tensor {
    let (m, n) = x_shard.dims2();
    let k = w_shard.dims2().1;
    charge_mm(ep, m, k, n);
    let y_partial = x_shard.matmul(w_shard);
    let y = all_reduce(ep, &ctx.group, &y_partial);
    match b {
        Some(b) => {
            ep.charge_memop(y.nominal_bytes() as f64);
            y.add_row_vector(b)
        }
        None => y,
    }
}

/// Row-parallel linear backward. Returns `(dX_i, dW_i, db)`; no collective
/// needed (`dX_i = dY·W_iᵀ` is local because `dY` is replicated; `db` is the
/// replicated column-sum every rank computes identically).
pub fn row_linear_bwd(
    ep: &mut Endpoint,
    _ctx: &Ctx1D,
    dy: &Tensor,
    x_shard: &Tensor,
    w_shard: &Tensor,
) -> (Tensor, Tensor, Tensor) {
    let (m, k) = dy.dims2();
    let n = w_shard.dims2().0;
    charge_mm(ep, m, n, k);
    let dx = dy.matmul_nt(w_shard); // (M, N/P)
    charge_mm(ep, n, k, m);
    let dw = x_shard.matmul_tn(dy); // (N/P, K)
    ep.charge_memop(dy.nominal_bytes() as f64);
    let db = dy.sum_rows();
    (dx, dw, db)
}

fn req<'a>(t: Option<&'a Tensor>, name: &str) -> &'a Tensor {
    t.unwrap_or_else(|| panic!("1-D rank owns this vector; missing {name}"))
}

/// Megatron semantics for the trait: `Expand` is the column-parallel form
/// (no forward comm, column-sharded output), `Reduce` the row-parallel form
/// (one all-reduce, replicated output). Activations at block entry are
/// replicated, so layernorm and `vec_op` are purely local.
impl ParallelOps for Ctx1D {
    fn spec(&self) -> &ShardSpec {
        &self.spec
    }

    fn matmul_nn(&self, ep: &mut Endpoint, x: &Tensor, w: &Tensor, stage: Stage) -> Tensor {
        match stage {
            Stage::Expand => col_linear_fwd(ep, self, x, w, None),
            Stage::Reduce => row_linear_fwd(ep, self, x, w, None),
        }
    }

    fn matmul_nt(&self, ep: &mut Endpoint, dy: &Tensor, w: &Tensor, stage: Stage) -> Tensor {
        let (m, k) = dy.dims2();
        let n = w.dims2().0;
        charge_mm(ep, m, n, k);
        let dx = dy.matmul_nt(w);
        match stage {
            // Column-parallel: per-rank partials of the full dX sum up.
            Stage::Expand => all_reduce(ep, &self.group, &dx),
            // Row-parallel: dY is replicated; dX is this rank's column shard.
            Stage::Reduce => dx,
        }
    }

    fn matmul_tn(&self, ep: &mut Endpoint, x: &Tensor, dy: &Tensor, _stage: Stage) -> Tensor {
        // Both forms are local: the sharded operand pair always lines up
        // (Expand: full X × dY column shard; Reduce: X column shard × full
        // dY), yielding this rank's dW shard directly.
        let (m, n) = x.dims2();
        let k = dy.dims2().1;
        charge_mm(ep, n, k, m);
        x.matmul_tn(dy)
    }

    fn linear_fwd(
        &self,
        ep: &mut Endpoint,
        x: &Tensor,
        w: &Tensor,
        b: Option<&Tensor>,
        stage: Stage,
    ) -> Tensor {
        match stage {
            Stage::Expand => col_linear_fwd(ep, self, x, w, Some(req(b, "bias shard"))),
            Stage::Reduce => row_linear_fwd(ep, self, x, w, Some(req(b, "bias"))),
        }
    }

    fn linear_bwd(
        &self,
        ep: &mut Endpoint,
        dy: &Tensor,
        x: &Tensor,
        w: &Tensor,
        stage: Stage,
    ) -> (Tensor, Tensor, Option<Tensor>) {
        let (dx, dw, db) = match stage {
            Stage::Expand => col_linear_bwd(ep, self, dy, x, w),
            Stage::Reduce => row_linear_bwd(ep, self, dy, x, w),
        };
        (dx, dw, Some(db))
    }

    fn vec_op(&self, ep: &mut Endpoint, a: &Tensor, v: Option<&Tensor>, mul: bool) -> Tensor {
        replicated_vec_op(ep, a, v, mul)
    }

    fn layernorm(
        &self,
        ep: &mut Endpoint,
        x: &Tensor,
        gamma: Option<&Tensor>,
        beta: Option<&Tensor>,
        eps: f32,
        _hidden: usize,
    ) -> (Tensor, Tensor, Tensor) {
        replicated_layernorm(ep, x, gamma, beta, eps)
    }

    fn layernorm_backward(
        &self,
        ep: &mut Endpoint,
        dy: &Tensor,
        xhat: &Tensor,
        inv_std: &Tensor,
        gamma: Option<&Tensor>,
        _hidden: usize,
    ) -> (Tensor, Option<Tensor>, Option<Tensor>) {
        replicated_layernorm_backward(ep, dy, xhat, inv_std, gamma)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::NetModel;
    use crate::dist::Layout1D;
    use crate::rng::Xoshiro256;
    use crate::spmd::run_spmd;

    fn randt(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        Tensor::randn(shape, 1.0, &mut rng)
    }

    #[test]
    fn col_then_row_equals_dense_two_layer() {
        // Megatron MLP pattern: Y = (X·W1 + b1)·W2 + b2 with W1 col-split,
        // W2 row-split.
        let world = 4;
        let (m, h, f) = (6, 8, 16);
        let x = randt(&[m, h], 1);
        let w1 = randt(&[h, f], 2);
        let b1 = randt(&[f], 3);
        let w2 = randt(&[f, h], 4);
        let b2 = randt(&[h], 5);
        let y_ref = x.matmul(&w1).add_row_vector(&b1).matmul(&w2).add_row_vector(&b2);
        let w1s = Layout1D::ColShard.scatter(world, &w1);
        let b1s = Layout1D::ColShard.scatter(world, &b1.reshape(&[1, f]));
        let w2s = Layout1D::RowShard.scatter(world, &w2);
        let out = run_spmd(world, NetModel::zero(), move |rank, ep| {
            let ctx = Ctx1D::new(world, rank);
            let b1r = b1s[rank].reshape(&[f / world]);
            let h1 = col_linear_fwd(ep, &ctx, &x, &w1s[rank], Some(&b1r));
            row_linear_fwd(ep, &ctx, &h1, &w2s[rank], Some(&b2))
        });
        for y in out {
            assert!(y.max_abs_diff(&y_ref) < 1e-3);
        }
    }

    #[test]
    fn col_linear_backward_matches_dense() {
        let world = 2;
        let (m, n, k) = (4, 6, 8);
        let x = randt(&[m, n], 6);
        let w = randt(&[n, k], 7);
        let dy = randt(&[m, k], 8);
        let dx_ref = dy.matmul_nt(&w);
        let dw_ref = x.matmul_tn(&dy);
        let db_ref = dy.sum_rows();
        let ws = Layout1D::ColShard.scatter(world, &w);
        let dys = Layout1D::ColShard.scatter(world, &dy);
        let out = run_spmd(world, NetModel::zero(), move |rank, ep| {
            let ctx = Ctx1D::new(world, rank);
            col_linear_bwd(ep, &ctx, &dys[rank], &x, &ws[rank])
        });
        let dw = Layout1D::ColShard.gather(&out.iter().map(|o| o.1.clone()).collect::<Vec<_>>());
        let db = Layout1D::ColShard.gather(
            &out.iter().map(|o| o.2.reshape(&[1, k / world])).collect::<Vec<_>>(),
        );
        for (dx, _, _) in &out {
            assert!(dx.max_abs_diff(&dx_ref) < 1e-3);
        }
        assert!(dw.max_abs_diff(&dw_ref) < 1e-3);
        assert!(db.max_abs_diff(&db_ref.reshape(&[1, k])) < 1e-3);
    }

    #[test]
    fn row_linear_backward_matches_dense() {
        let world = 2;
        let (m, n, k) = (4, 6, 8);
        let x = randt(&[m, n], 9);
        let w = randt(&[n, k], 10);
        let dy = randt(&[m, k], 11);
        let dx_ref = dy.matmul_nt(&w);
        let dw_ref = x.matmul_tn(&dy);
        let db_ref = dy.sum_rows();
        let xs = Layout1D::ColShard.scatter(world, &x);
        let ws = Layout1D::RowShard.scatter(world, &w);
        let out = run_spmd(world, NetModel::zero(), move |rank, ep| {
            let ctx = Ctx1D::new(world, rank);
            row_linear_bwd(ep, &ctx, &dy, &xs[rank], &ws[rank])
        });
        let dx = Layout1D::ColShard.gather(&out.iter().map(|o| o.0.clone()).collect::<Vec<_>>());
        let dw = Layout1D::RowShard.gather(&out.iter().map(|o| o.1.clone()).collect::<Vec<_>>());
        assert!(dx.max_abs_diff(&dx_ref) < 1e-3);
        assert!(dw.max_abs_diff(&dw_ref) < 1e-3);
        for (_, _, db) in &out {
            assert!(db.max_abs_diff(&db_ref) < 1e-3);
        }
    }

    #[test]
    fn forward_comm_volume_is_one_allreduce_per_row_linear() {
        let world = 4;
        let (m, n, k) = (8, 8, 8);
        let out = run_spmd(world, NetModel::flat(0.0, 1e9, f64::INFINITY), move |rank, ep| {
            let ctx = Ctx1D::new(world, rank);
            let x = Tensor::phantom(&[m, n / world]);
            let w = Tensor::phantom(&[n / world, k]);
            let _ = row_linear_fwd(ep, &ctx, &x, &w, None);
            ep.stats.bytes_sent
        });
        // Ring all-reduce of (m, k) f32: 2·(g-1)/g·n_bytes per rank.
        let n_bytes = (m * k * 4) as u64;
        for b in out {
            assert_eq!(b, 2 * 3 * (n_bytes / 4));
        }
    }
}
