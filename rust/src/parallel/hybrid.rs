//! Hybrid data×tensor parallelism: `r` data-parallel replicas around any
//! inner tensor mesh.
//!
//! This is the wrapper pattern for composing parallelisms (the guide in
//! [`crate::parallel`] uses it as the worked example): [`Hybrid`] boxes an
//! inner [`ParallelOps`] — 1-D line, 2-D grid, 3-D cube or 2.5-D Tesseract,
//! each constructed with a rank *base* so its collectives address the
//! replica's slice of the global rank space — and adds exactly one thing:
//! a **gradient all-reduce over the replica group** at every weight-grad
//! boundary of `block_bwd` (`linear_bwd`'s `dW`/`db`, `matmul_tn`,
//! `layernorm_backward`'s `dγ`/`dβ`).
//!
//! Everything else delegates: batch rows are split across replicas by the
//! layout algebra ([`crate::dist::MeshSpec::Hybrid`]), so each replica's
//! forward/backward is the inner mesh's unchanged code on `1/r` of the
//! batch, and the summed gradients equal the full-batch gradients of the
//! dense reference — which is what keeps replicas bit-consistent step to
//! step and lets the generic parity loop verify this leaf shard-for-shard.
//!
//! The replica groups are `{k·iw + inner_rank : k < r}` for each inner
//! rank, i.e. `iw` disjoint all-reduce rings of size `r` — the Megatron-LM
//! data-parallel group layout (Narayanan et al., "Efficient Large-Scale
//! Language Model Training on GPU Clusters").
//!
//! **Overlap.** The replica gradient all-reduce is the one collective in
//! the whole tree whose result is not needed until the optimizer step, so
//! [`Hybrid::grad_sync`] issues it as a deferred collective
//! ([`crate::comm::Endpoint::defer`]): on the virtual clock it rides the
//! endpoint's comm timeline behind the next layer's backward GEMMs instead
//! of stalling the compute timeline. Every inner-mesh collective delegated
//! below stays blocking — those sit on the critical path (see the overlap
//! notes in each leaf module).
//!
//! **ZeRO (stage 1/2).** With [`Hybrid::with_zero_stage`] the weight-grad
//! sync becomes a **reduce-scatter**: each replica keeps only its owned
//! `ceil(n/r)` gradient chunk (the [`crate::collectives::flat_chunks`]
//! boundaries), feeds it to a partitioned optimizer
//! ([`crate::optim::Optimizer::new_partitioned`]), and the trainer
//! all-gathers the updated weight slices back before the next forward.
//! Since `all_reduce` *is* reduce-scatter + all-gather on those exact chunk
//! boundaries, the chunk this path returns is bitwise equal to the
//! corresponding slice of the all-reduced gradient — same ring, same fold
//! order — which is what makes ZeRO-on numerics bit-identical to ZeRO-off
//! (pinned in `rust/tests/model_parity.rs`). Communication volume is
//! unchanged (RS + the trainer's later AG = the all-reduce's two phases);
//! only the `2/r` optimizer-moment memory and the grad residency shrink.

use crate::collectives::{all_reduce, flat_chunks, reduce_scatter};
use crate::comm::Endpoint;
use crate::dist::{mesh_for_inner, ShardSpec, Stage};
use crate::parallel::{oned::Ctx1D, threed::Ctx3D, twod::Ctx2D, twofived::Ctx25D, ParallelOps};
use crate::tensor::Tensor;
use crate::topology::{Cube, HybridInner, Mesh};

/// `r` data-parallel replicas wrapping a boxed inner tensor-mesh leaf.
pub struct Hybrid {
    inner: Box<dyn ParallelOps>,
    /// The ranks holding this rank's inner position on every replica,
    /// ordered by replica — the gradient all-reduce group.
    replica_group: Vec<usize>,
    /// ZeRO stage (0 = off): `>= 1` switches [`Hybrid::grad_sync`] from
    /// all-reduce to reduce-scatter, returning this replica's owned
    /// gradient chunk for a partitioned optimizer.
    zero_stage: usize,
    spec: ShardSpec,
}

impl Hybrid {
    /// Build the leaf for `rank` of an `replicas × inner(edge)` mesh.
    pub fn for_kind(replicas: usize, inner: HybridInner, edge: usize, rank: usize) -> Hybrid {
        Self::with_base(replicas, inner, edge, rank, 0)
    }

    /// Like [`Hybrid::for_kind`] but the whole hybrid mesh occupies global
    /// ranks `base..base + replicas·iw` — the hook that lets a pipeline
    /// stage embed a replica group anywhere in the rank space (the same
    /// contract as the inner leaves' `with_base` constructors). `rank` is
    /// hybrid-local; the endpoint's global rank must be `base + rank`.
    pub fn with_base(
        replicas: usize,
        inner: HybridInner,
        edge: usize,
        rank: usize,
        base: usize,
    ) -> Hybrid {
        assert!(replicas >= 1, "hybrid needs at least one replica");
        let iw = inner.as_parallelism().world_size(edge);
        assert!(rank < replicas * iw);
        let replica = rank / iw;
        let inner_rank = rank % iw;
        let inner_base = base + replica * iw;
        let inner_ops: Box<dyn ParallelOps> = match inner {
            HybridInner::OneD => Box::new(Ctx1D::with_base(edge, inner_rank, inner_base)),
            HybridInner::TwoD => {
                Box::new(Ctx2D::with_base(Mesh::new(edge), inner_rank, inner_base))
            }
            HybridInner::ThreeD => Box::new(Ctx3D::with_dirs_base(
                Cube::new(edge),
                inner_rank,
                crate::dist::Dirs::canonical(),
                inner_base,
            )),
            HybridInner::TwoFiveD { depth } => {
                Box::new(Ctx25D::with_base(edge, depth, inner_rank, inner_base))
            }
        };
        let replica_group = (0..replicas).map(|k| base + k * iw + inner_rank).collect();
        let spec = ShardSpec::hybrid(replicas, mesh_for_inner(inner, edge), rank);
        Hybrid { inner: inner_ops, replica_group, zero_stage: 0, spec }
    }

    /// Enable ZeRO stage 1/2 on the replica axis (builder style; stage 0
    /// is the replicated default). The caller (the trainer) must pair this
    /// with a partitioned optimizer and a post-step weight all-gather —
    /// `grad_sync` then returns `ceil(n/r)` chunks, not full tensors.
    pub fn with_zero_stage(mut self, stage: usize) -> Hybrid {
        self.zero_stage = stage;
        self
    }

    /// Number of data-parallel replicas `r` (= the replica group size).
    pub fn replicas(&self) -> usize {
        self.replica_group.len()
    }

    /// The configured ZeRO stage (0 when the replicated all-reduce path is
    /// active).
    pub fn zero_stage(&self) -> usize {
        self.zero_stage
    }

    /// The ordered gradient-sync group: the ranks holding this rank's
    /// inner-mesh position on each replica, ordered by replica index — so
    /// group order *is* ZeRO partition order.
    pub fn replica_group(&self) -> &[usize] {
        &self.replica_group
    }

    /// Sum a weight/vector gradient over the replica group — the one piece
    /// of communication this wrapper adds.
    ///
    /// This is the hideable boundary of the backward pass: the summed
    /// gradient is not needed until the optimizer, so the all-reduce is
    /// issued as a *deferred* collective ([`Endpoint::defer`]) — the data
    /// moves now (bit-identical reduction order) and the returned tensor
    /// is immediately valid, while the clock cost rides the endpoint's
    /// comm timeline behind layer `L−1`'s GEMMs. `core_bwd` retires
    /// finished tickets between layers and the trainer's
    /// [`Endpoint::join_all`] at the optimizer boundary catches the rest.
    /// With `CUBIC_OVERLAP=0` this is exactly the old blocking all-reduce.
    ///
    /// Under ZeRO (`zero_stage >= 1`) the all-reduce is cut at its midpoint:
    /// only the reduce-scatter phase runs, and the returned tensor is this
    /// replica's fully reduced `ceil(n/r)` chunk — bitwise the slice the
    /// all-reduce would have produced, at half the sync's wire bytes (the
    /// other half moves later as the trainer's weight all-gather).
    fn grad_sync(&self, ep: &mut Endpoint, g: &Tensor) -> Tensor {
        if self.zero_stage >= 1 {
            let (chunk, _ticket) = ep.defer(|ep| {
                let contrib = flat_chunks(ep, g, self.replica_group.len());
                reduce_scatter(ep, &self.replica_group, contrib)
            });
            return chunk;
        }
        let (summed, _ticket) = ep.defer(|ep| all_reduce(ep, &self.replica_group, g));
        summed
    }
}

impl ParallelOps for Hybrid {
    fn spec(&self) -> &ShardSpec {
        &self.spec
    }

    fn matmul_nn(&self, ep: &mut Endpoint, x: &Tensor, w: &Tensor, stage: Stage) -> Tensor {
        self.inner.matmul_nn(ep, x, w, stage)
    }

    fn matmul_nt(&self, ep: &mut Endpoint, dy: &Tensor, w: &Tensor, stage: Stage) -> Tensor {
        self.inner.matmul_nt(ep, dy, w, stage)
    }

    fn matmul_tn(&self, ep: &mut Endpoint, x: &Tensor, dy: &Tensor, stage: Stage) -> Tensor {
        let dw = self.inner.matmul_tn(ep, x, dy, stage);
        self.grad_sync(ep, &dw)
    }

    fn matmul_nn_backward(
        &self,
        ep: &mut Endpoint,
        dy: &Tensor,
        x: &Tensor,
        w: &Tensor,
        stage: Stage,
    ) -> (Tensor, Tensor) {
        let (dx, dw) = self.inner.matmul_nn_backward(ep, dy, x, w, stage);
        (dx, self.grad_sync(ep, &dw))
    }

    fn linear_fwd(
        &self,
        ep: &mut Endpoint,
        x: &Tensor,
        w: &Tensor,
        b: Option<&Tensor>,
        stage: Stage,
    ) -> Tensor {
        self.inner.linear_fwd(ep, x, w, b, stage)
    }

    fn linear_bwd(
        &self,
        ep: &mut Endpoint,
        dy: &Tensor,
        x: &Tensor,
        w: &Tensor,
        stage: Stage,
    ) -> (Tensor, Tensor, Option<Tensor>) {
        let (dx, dw, db) = self.inner.linear_bwd(ep, dy, x, w, stage);
        let dw = self.grad_sync(ep, &dw);
        let db = db.map(|b| self.grad_sync(ep, &b));
        (dx, dw, db)
    }

    fn vec_op(&self, ep: &mut Endpoint, a: &Tensor, v: Option<&Tensor>, mul: bool) -> Tensor {
        self.inner.vec_op(ep, a, v, mul)
    }

    fn layernorm(
        &self,
        ep: &mut Endpoint,
        x: &Tensor,
        gamma: Option<&Tensor>,
        beta: Option<&Tensor>,
        eps: f32,
        hidden: usize,
    ) -> (Tensor, Tensor, Tensor) {
        self.inner.layernorm(ep, x, gamma, beta, eps, hidden)
    }

    fn layernorm_backward(
        &self,
        ep: &mut Endpoint,
        dy: &Tensor,
        xhat: &Tensor,
        inv_std: &Tensor,
        gamma: Option<&Tensor>,
        hidden: usize,
    ) -> (Tensor, Option<Tensor>, Option<Tensor>) {
        let (dx, dg, db) = self.inner.layernorm_backward(ep, dy, xhat, inv_std, gamma, hidden);
        let dg = dg.map(|g| self.grad_sync(ep, &g));
        let db = db.map(|b| self.grad_sync(ep, &b));
        (dx, dg, db)
    }

    // Split backward halves (micro-batch pipelining): input-grad halves
    // delegate untouched — replicas never communicate on the activation
    // path — and every weight/vector gradient gets the same replica
    // `grad_sync` the joint methods apply.

    fn linear_bwd_dx(&self, ep: &mut Endpoint, dy: &Tensor, w: &Tensor, stage: Stage) -> Tensor {
        self.inner.linear_bwd_dx(ep, dy, w, stage)
    }

    fn linear_bwd_dw(
        &self,
        ep: &mut Endpoint,
        dy: &Tensor,
        x: &Tensor,
        stage: Stage,
    ) -> (Tensor, Option<Tensor>) {
        let (dw, db) = self.inner.linear_bwd_dw(ep, dy, x, stage);
        let dw = self.grad_sync(ep, &dw);
        let db = db.map(|b| self.grad_sync(ep, &b));
        (dw, db)
    }

    fn layernorm_backward_dx(
        &self,
        ep: &mut Endpoint,
        dy: &Tensor,
        xhat: &Tensor,
        inv_std: &Tensor,
        gamma: Option<&Tensor>,
        hidden: usize,
    ) -> Tensor {
        self.inner.layernorm_backward_dx(ep, dy, xhat, inv_std, gamma, hidden)
    }

    fn layernorm_param_grads(
        &self,
        ep: &mut Endpoint,
        dy: &Tensor,
        xhat: &Tensor,
    ) -> (Option<Tensor>, Option<Tensor>) {
        let (dg, db) = self.inner.layernorm_param_grads(ep, dy, xhat);
        let dg = dg.map(|g| self.grad_sync(ep, &g));
        let db = db.map(|b| self.grad_sync(ep, &b));
        (dg, db)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::NetModel;
    use crate::dist::DistTensor;
    use crate::rng::Xoshiro256;
    use crate::spmd::run_spmd;

    fn randt(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        Tensor::randn(shape, 1.0, &mut rng)
    }

    #[test]
    fn hybrid_1d_linear_grads_match_full_batch_dense() {
        // 2 replicas × 1-D line of 2: each replica sees half the rows, but
        // the synced dW/db must equal the full-batch dense gradients.
        let (r, e) = (2usize, 2usize);
        let world = r * e;
        let (m, n, k) = (8usize, 16usize, 32usize);
        let x = randt(&[m, n], 1);
        let w = randt(&[n, k], 2);
        let dy = randt(&[m, k], 3);
        let dw_ref = x.matmul_tn(&dy);
        let db_ref = dy.sum_rows();
        let (x2, wc, dy2) = (x.clone(), w.clone(), dy.clone());
        let out = run_spmd(world, NetModel::zero(), move |rank, ep| {
            let ops = Hybrid::for_kind(r, HybridInner::OneD, e, rank);
            let xl = ops.scatter_activation(ep, &x2);
            // dY of an Expand (column-parallel) linear is column-sharded
            // within the replica: line rank 0 takes cols 0..k/2, rank 1 the
            // rest (on this replica's row slab).
            let dyl = {
                let full = ops.scatter_activation(ep, &dy2);
                let (rows, cols) = full.dims2();
                full.block(0, (rank % e) * (cols / e), rows, cols / e).compact()
            };
            let ws = ops.spec().shard_weight(Stage::Expand, &wc);
            ops.linear_bwd(ep, &dyl, &xl, &ws, Stage::Expand)
        });
        // Weight grads reassemble to the dense full-batch gradient from any
        // single replica's shards.
        let spec0 = ShardSpec::for_parallelism(
            crate::topology::Parallelism::Hybrid { replicas: r, inner: HybridInner::OneD },
            e,
            0,
        );
        let dw_parts: Vec<Tensor> = out.iter().map(|(_, dw, _)| dw.clone()).collect();
        let dw = spec0.assemble_weight(Stage::Expand, &dw_parts, n, k);
        assert!(dw.max_abs_diff(&dw_ref) < 1e-3, "{}", dw.max_abs_diff(&dw_ref));
        // Bias grads: each rank's chunk is the full-batch column sum.
        let db0 = out[0].2.as_ref().unwrap();
        let db1 = out[1].2.as_ref().unwrap();
        let db = Tensor::concat_cols(&[
            db0.reshape(&[1, k / e]),
            db1.reshape(&[1, k / e]),
        ]);
        assert!(db.max_abs_diff(&db_ref.reshape(&[1, k])) < 1e-3);
        // Replicas ended bit-identical.
        assert_eq!(out[0].1, out[2].1, "replica weight grads must match after sync");
    }

    #[test]
    fn hybrid_forward_assembles_row_slabs() {
        let (r, e) = (2usize, 2usize);
        let world = r * e;
        let (m, n, k) = (8usize, 16usize, 16usize);
        let x = randt(&[m, n], 4);
        let w1 = randt(&[n, k], 5);
        let w2 = randt(&[k, n], 6);
        let y_ref = x.matmul(&w1).matmul(&w2);
        let (x2, w1c, w2c) = (x.clone(), w1.clone(), w2.clone());
        let out = run_spmd(world, NetModel::zero(), move |rank, ep| {
            let ops = Hybrid::for_kind(r, HybridInner::OneD, e, rank);
            let xl = ops.scatter_activation(ep, &x2);
            let w1s = ops.spec().shard_weight(Stage::Expand, &w1c);
            let w2s = ops.spec().shard_weight(Stage::Reduce, &w2c);
            let h = ops.matmul_nn(ep, &xl, &w1s, Stage::Expand);
            ops.matmul_nn(ep, &h, &w2s, Stage::Reduce)
        });
        let par = crate::topology::Parallelism::Hybrid { replicas: r, inner: HybridInner::OneD };
        let parts: Vec<DistTensor> = out
            .into_iter()
            .enumerate()
            .map(|(rank, t)| {
                DistTensor::from_local(&ShardSpec::for_parallelism(par, e, rank), t)
            })
            .collect();
        let y = DistTensor::assemble_activation(&parts, m, n);
        assert!(y.max_abs_diff(&y_ref) < 1e-3, "{}", y.max_abs_diff(&y_ref));
    }

    #[test]
    fn zero_grad_sync_chunks_equal_all_reduce_slices_bitwise() {
        // Run the same linear backward with ZeRO off (all-reduced full
        // grads) and on (reduce-scattered chunks): each rank's chunk must
        // be the bitwise slice of the full synced gradient that its replica
        // index owns — the partition contract the ZeRO loss-parity pin
        // rests on.
        let (r, e) = (2usize, 2usize);
        let world = r * e;
        let (m, n, k) = (8usize, 16usize, 32usize);
        let x = randt(&[m, n], 1);
        let w = randt(&[n, k], 2);
        let dy = randt(&[m, k], 3);
        let run = |zero: usize| {
            let (x2, wc, dy2) = (x.clone(), w.clone(), dy.clone());
            run_spmd(world, NetModel::zero(), move |rank, ep| {
                let ops =
                    Hybrid::for_kind(r, HybridInner::OneD, e, rank).with_zero_stage(zero);
                let xl = ops.scatter_activation(ep, &x2);
                let dyl = {
                    let full = ops.scatter_activation(ep, &dy2);
                    let (rows, cols) = full.dims2();
                    full.block(0, (rank % e) * (cols / e), rows, cols / e).compact()
                };
                let ws = ops.spec().shard_weight(Stage::Expand, &wc);
                ops.linear_bwd(ep, &dyl, &xl, &ws, Stage::Expand)
            })
        };
        let full = run(0);
        let zero = run(1);
        for rank in 0..world {
            let replica = rank / e;
            for (got, want) in [
                (&zero[rank].1, &full[rank].1),
                (zero[rank].2.as_ref().unwrap(), full[rank].2.as_ref().unwrap()),
            ] {
                let nfull = want.numel();
                let padded = nfull.div_ceil(r);
                let lo = (replica * padded).min(nfull);
                let hi = ((replica + 1) * padded).min(nfull);
                assert_eq!(got.numel(), padded, "rank {rank}: chunk shape");
                assert_eq!(
                    &got.data()[..hi - lo],
                    &want.data()[lo..hi],
                    "rank {rank}: chunk must be the owned all-reduce slice"
                );
            }
        }
    }

    #[test]
    fn replica_groups_are_disjoint_rings() {
        let ops = Hybrid::for_kind(3, HybridInner::OneD, 2, 4); // replica 2, line 0
        assert_eq!(ops.replicas(), 3);
        assert_eq!(ops.replica_group, vec![0, 2, 4]);
        let ops = Hybrid::for_kind(2, HybridInner::TwoD, 2, 5); // replica 1, grid 1
        assert_eq!(ops.replica_group, vec![1, 5]);
    }
}
