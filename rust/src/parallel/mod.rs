//! Tensor-parallel linear algebra: the paper's 3-D algorithms plus the 1-D
//! (Megatron [17]) and 2-D (Optimus/SUMMA [21]) baselines it compares with.
//!
//! Each submodule implements forward *and* backward of the distributed
//! linear operations used by the Transformer model in [`crate::model`],
//! verified shard-for-shard against dense references in `rust/tests/`.

pub mod oned;
pub mod threed;
pub mod twod;
