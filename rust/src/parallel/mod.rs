//! Tensor-parallel linear algebra behind one trait: [`ParallelOps`].
//!
//! The paper's core observation is that 1-D (Megatron [17]), 2-D
//! (Optimus/SUMMA [21]) and the paper's 3-D parallelism are *points on one
//! spectrum of shard layouts* — the same transformer block, the same six
//! distributed matmul forms, the same vector/normalization ops, differing
//! only in where shards live and which collectives move them. This module
//! encodes that spectrum: the layout algebra is
//! [`crate::dist::ShardSpec`]; the *communicating* operations are the
//! [`ParallelOps`] trait, implemented once per parallelism:
//!
//! * [`seq::Seq`] — dense single device (the parity reference);
//! * [`oned::Ctx1D`] — replicated activations, column/row-parallel linears;
//! * [`twod::Ctx2D`] — everything block-distributed, SUMMA matmuls;
//! * [`threed::Ctx3D`] — the paper's Algorithms 1–8 on the `p³` cube;
//! * [`twofived::Ctx25D`] — Tesseract-style 2.5-D: `d` stacked SUMMA
//!   grids, weights depth-slabbed, one depth all-reduce per residual
//!   branch;
//! * [`hybrid::Hybrid`] — `r` data-parallel replicas wrapping any boxed
//!   inner leaf, adding replica-group gradient all-reduces;
//! * [`pipeline::Pipeline`] — `s` pipeline stages wrapping any boxed
//!   inner leaf, splitting the layer stack and streaming `m`
//!   micro-batches through stage-boundary point-to-point sends.
//!
//! The generic transformer block in [`crate::model::block`] is written
//! against `&dyn ParallelOps` only; `crate::model::ParEnv` is the thin
//! boxed dispatcher that picks the implementation at run time. Every
//! implementation is verified shard-for-shard against the dense reference
//! by `rust/tests/model_parity.rs` — one generic test over all seven kinds.
//! The whole-repo view — layer map, per-mesh memory/comm formulas, the
//! determinism contract — lives in `ARCHITECTURE.md` at the repo root;
//! this module doc stays the authority on the leaf-writing workflow.
//!
//! ## Adding a new parallelism
//!
//! A new decomposition is a *leaf*, not a fork. The three newest leaves
//! are worked examples of the shapes a leaf can take — a genuinely new
//! mesh (2.5-D), a wrapper around existing leaves (hybrid), and a
//! wrapper that changes the *schedule* rather than the layout
//! (pipeline):
//!
//! 1. **Layout** — add a [`crate::dist::MeshSpec`] arm and teach
//!    [`crate::dist::ShardSpec`]'s `shard_*`/`assemble_*` methods where
//!    weights ([`Stage`]), vectors ([`crate::dist::VecRole`]) and
//!    activations live on the new mesh. The dist tests
//!    (`shard_spec_*_round_trips*`) then pin `gather ∘ scatter = id` for
//!    free. *2.5-D example:* `MeshSpec::Tess(grid, depth)` composes two
//!    existing layouts — `Layout1D` slabs across depth, `Layout2D` blocks
//!    within a layer — so `tess_weight_bounds` is ~20 lines of offset
//!    arithmetic. *Hybrid example:* `MeshSpec::Hybrid(r, inner)` delegates
//!    every question to the boxed inner mesh after peeling the replica
//!    index off the rank (plus a row-slab offset for activations); if your
//!    mesh replicates weights, also override `weight_replicas` so the
//!    parity tiling checks stay exact.
//! 2. **Ops** — write a context type holding the mesh + this rank's
//!    coordinate and implement [`ParallelOps`]: the six matmul forms (or
//!    at minimum `matmul_nn`/`matmul_nt`/`matmul_tn`), `linear_fwd/bwd`,
//!    `vec_op`, and the layernorm pair. Provided methods (activation
//!    scatter/gather, block sharding, phantom blocks) come from the
//!    `ShardSpec` automatically. *2.5-D example:* [`twofived::Ctx25D`]
//!    reuses the 2-D module's SUMMA free functions on a grid embedded at
//!    rank base `layer·p²` and adds one depth all-reduce per `Reduce`
//!    forward / `Expand` backward. *Hybrid example:* [`hybrid::Hybrid`]
//!    holds a `Box<dyn ParallelOps>` built with a rank base of
//!    `replica·inner_world` (every leaf has a `with_base` constructor for
//!    exactly this) and post-processes only the weight/vector gradients
//!    with `all_reduce` over the replica group — the wrapper pattern: no
//!    inner code changes at all.
//! 3. **Dispatch** — add the arm to [`ops_for`] (and
//!    `topology::Parallelism` if it is a genuinely new kind; parameterized
//!    kinds like `TwoFiveD { depth }` carry their extra shape data in the
//!    enum so every `(kind, edge)` call site keeps working).
//! 4. **Verify** — add the `(kind, edge)` pair to `ALL_ENVS` in
//!    `rust/tests/model_parity.rs` and (for fast CI fail) a
//!    `new_leaf_*`-prefixed test naming it. No model code changes: the
//!    block, trainer, engine and benches are already generic. If the mesh
//!    has a nontrivial comm profile, mirror it in `crate::costmodel` and
//!    pin the formula against the phantom-mode ledger like
//!    `mm25d_fwd_bytes_match_engine_ledger_exactly` does. Inference comes
//!    for free too: the provided `serve_prefill`/`serve_decode` methods
//!    drive the same `linear_fwd`/`layernorm` kernels token-by-token, so a
//!    new leaf only overrides them if it changes the *schedule* (pipeline
//!    does); add the `(kind, edge)` pair to `tests/serve_parity.rs` and
//!    the decode-vs-full-forward bitwise pin covers it.
//!
//! *Pipeline example* (the third worked example — a **schedule**
//! wrapper): [`pipeline::Pipeline`] boxes an inner leaf built at rank
//! base `stage·inner_world` — the same `with_base` hook the hybrid
//! wrapper uses — and overrides nothing about the math. What it changes
//! is the *step*: [`pipeline::pipeline_core_step`] slices the batch into
//! `m` micro-batches, runs this stage's contiguous slice of the layer
//! stack per micro-batch, and moves only the stage-boundary activation
//! (forward) and its gradient (backward) point-to-point between stage
//! groups. Two things made this possible without forking the block:
//! the split-backward trait halves (`linear_bwd_dx`/`linear_bwd_dw`,
//! `layernorm_backward_dx`/`layernorm_param_grads`) so weight gradients
//! are computed once on the micro-batches' concatenated rows (bitwise
//! equal to the unpipelined full-batch gradients — per-micro-batch dW
//! sums would reorder float additions), and a `gather_activation`
//! override that gathers over the *stage group* instead of the world
//! (the default would deadlock across stages). The schedule cost is the
//! GPipe flush bubble, mirrored in closed form by
//! `crate::costmodel::pipeline_bubble_fraction`:
//!
//! | stages s | micro-batches m | bubble (s−1)/(m+s−1) |
//! |----------|-----------------|----------------------|
//! | 2        | 4               | 0.20                 |
//! | 2        | 8               | 0.11                 |
//! | 4        | 4               | 0.43                 |
//! | 4        | 16              | 0.16                 |
//! | 8        | 32              | 0.18                 |
//!
//! More micro-batches shrink the bubble but also shrink each
//! micro-batch's GEMMs (and grow the activation stash: `m` caches live
//! simultaneously); more stages cut per-rank weight memory `1/s` but
//! deepen the bubble. `cubic plan` ranks these trade-offs against the
//! pure tensor meshes honestly (the `bubble` column at `--world N`).
//!
//! ## Conventions shared by all implementations
//!
//! * Activations enter every block in the mesh's *entry layout*
//!   (replicated / 2-D blocks / 3-D `input(d0)`); each residual branch
//!   runs an `Expand` then a `Reduce` linear ([`Stage`]), which returns
//!   the activation to the entry layout, so blocks stack.
//! * Vector parameters may be owned by a subset of ranks
//!   (`Option<Tensor>` in `BlockTensors`); non-owners pass `None` and
//!   still participate in the collectives that materialize the vector.
//! * Every op charges the virtual clock (`2·m·n·k` flops per matmul plus
//!   the memory-pass costs), so phantom-mode timing is identical to the
//!   pre-trait per-dimension implementations.

pub mod hybrid;
pub mod oned;
pub mod pipeline;
pub mod seq;
pub mod threed;
pub mod twod;
pub mod twofived;

use crate::comm::Endpoint;
use crate::config::ModelConfig;
use crate::dist::{ShardSpec, Stage};
use crate::model::{BlockTensors, DenseBlock};
use crate::tensor::Tensor;
use crate::topology::Parallelism;

/// The distributed-operation vocabulary of one parallelism point. Object
/// safe: the model drives `&dyn ParallelOps`, so new parallelisms plug in
/// without touching the block.
///
/// Required methods are the communicating kernels; provided methods are
/// pure layout plumbing derived from [`ParallelOps::spec`].
pub trait ParallelOps: Send + Sync {
    /// The layout algebra of this environment (mesh shape + this rank).
    fn spec(&self) -> &ShardSpec;

    // --- distributed matmul forms ------------------------------------

    /// `Y = X · W` with `x` in the stage's input layout and `w` in the
    /// stage's weight layout; returns the stage's output-layout shard.
    fn matmul_nn(&self, ep: &mut Endpoint, x: &Tensor, w: &Tensor, stage: Stage) -> Tensor;

    /// `dX = dY · Wᵀ` — the input-gradient form of a stage linear.
    fn matmul_nt(&self, ep: &mut Endpoint, dy: &Tensor, w: &Tensor, stage: Stage) -> Tensor;

    /// `dW = Xᵀ · dY` — the weight-gradient form of a stage linear.
    fn matmul_tn(&self, ep: &mut Endpoint, x: &Tensor, dy: &Tensor, stage: Stage) -> Tensor;

    /// Fused backward of [`ParallelOps::matmul_nn`]: `(dX, dW)`.
    /// Implementations that can share work between the two halves (3-D
    /// shares the `dY` gather) override this.
    fn matmul_nn_backward(
        &self,
        ep: &mut Endpoint,
        dy: &Tensor,
        x: &Tensor,
        w: &Tensor,
        stage: Stage,
    ) -> (Tensor, Tensor) {
        let dx = self.matmul_nt(ep, dy, w, stage);
        let dw = self.matmul_tn(ep, x, dy, stage);
        (dx, dw)
    }

    // --- linear layers -----------------------------------------------

    /// `Y = X·W + b`. `b` is this rank's bias shard — `None` on ranks
    /// that own no chunk (2-D off row 0, 3-D off the diagonal); those
    /// ranks still join the collectives that materialize the bias.
    fn linear_fwd(
        &self,
        ep: &mut Endpoint,
        x: &Tensor,
        w: &Tensor,
        b: Option<&Tensor>,
        stage: Stage,
    ) -> Tensor;

    /// Backward of [`ParallelOps::linear_fwd`]: `(dX, dW, db)` with `db`
    /// `Some` exactly on bias-owning ranks.
    fn linear_bwd(
        &self,
        ep: &mut Endpoint,
        dy: &Tensor,
        x: &Tensor,
        w: &Tensor,
        stage: Stage,
    ) -> (Tensor, Tensor, Option<Tensor>);

    // --- vector / normalization ops ----------------------------------

    /// `C = A + v` (`mul = false`) or `C = A ⊙ v` per column (`mul =
    /// true`) for an entry-layout activation `a` and a `Norm`-placed
    /// vector chunk `v` (3-D: Algorithm 7).
    fn vec_op(&self, ep: &mut Endpoint, a: &Tensor, v: Option<&Tensor>, mul: bool) -> Tensor;

    /// Layernorm over the hidden axis of an entry-layout activation.
    /// Returns `(y, xhat, inv_std)`; `hidden` is the *global* column
    /// count (shards only see `hidden / head_divisor` columns).
    #[allow(clippy::too_many_arguments)]
    fn layernorm(
        &self,
        ep: &mut Endpoint,
        x: &Tensor,
        gamma: Option<&Tensor>,
        beta: Option<&Tensor>,
        eps: f32,
        hidden: usize,
    ) -> (Tensor, Tensor, Tensor);

    /// Backward of [`ParallelOps::layernorm`]: `(dx, dγ, dβ)` with the
    /// vector grads `Some` exactly on γ/β-owning ranks.
    #[allow(clippy::too_many_arguments)]
    fn layernorm_backward(
        &self,
        ep: &mut Endpoint,
        dy: &Tensor,
        xhat: &Tensor,
        inv_std: &Tensor,
        gamma: Option<&Tensor>,
        hidden: usize,
    ) -> (Tensor, Option<Tensor>, Option<Tensor>);

    // --- split backward halves (micro-batch pipelining) --------------
    //
    // A pipelined backward runs the *input*-gradient chain once per
    // micro-batch but computes *parameter* gradients once, on the rows of
    // all micro-batches concatenated in order — that is what keeps the
    // accumulated gradients bit-identical to the unpipelined full-batch
    // run (per-micro-batch dW sums would reorder float additions).
    // These four methods split `linear_bwd` / `layernorm_backward` into
    // exactly those halves. Defaults cover the meshes whose parameter
    // gradients need no extra communication (Seq, 1-D); meshes that
    // reduce vector grads to owner subsets (2-D, 2.5-D, 3-D) and the
    // hybrid wrapper (replica grad sync) override the parameter halves.

    /// `dX = dY·Wᵀ` of a stage linear — [`ParallelOps::linear_bwd`]
    /// without the weight/bias gradients.
    fn linear_bwd_dx(&self, ep: &mut Endpoint, dy: &Tensor, w: &Tensor, stage: Stage) -> Tensor {
        self.matmul_nt(ep, dy, w, stage)
    }

    /// `(dW, db)` of a stage linear — [`ParallelOps::linear_bwd`] without
    /// the input gradient. `db` is `Some` exactly on bias-owning ranks.
    fn linear_bwd_dw(
        &self,
        ep: &mut Endpoint,
        dy: &Tensor,
        x: &Tensor,
        stage: Stage,
    ) -> (Tensor, Option<Tensor>) {
        let dw = self.matmul_tn(ep, x, dy, stage);
        ep.charge_memop(dy.nominal_bytes() as f64);
        (dw, Some(dy.sum_rows()))
    }

    /// The `dx` half of [`ParallelOps::layernorm_backward`].
    #[allow(clippy::too_many_arguments)]
    fn layernorm_backward_dx(
        &self,
        ep: &mut Endpoint,
        dy: &Tensor,
        xhat: &Tensor,
        inv_std: &Tensor,
        gamma: Option<&Tensor>,
        _hidden: usize,
    ) -> Tensor {
        seq::replicated_layernorm_backward_dx(ep, dy, xhat, inv_std, gamma)
    }

    /// The `(dγ, dβ)` half of [`ParallelOps::layernorm_backward`], with
    /// the vector grads `Some` exactly on γ/β-owning ranks.
    fn layernorm_param_grads(
        &self,
        ep: &mut Endpoint,
        dy: &Tensor,
        xhat: &Tensor,
    ) -> (Option<Tensor>, Option<Tensor>) {
        seq::replicated_layernorm_param_grads(ep, dy, xhat)
    }

    // --- provided: layout plumbing derived from the spec -------------

    /// The parallelism point this context implements.
    fn kind(&self) -> Parallelism {
        self.spec().kind()
    }

    /// Attention heads this rank computes locally.
    fn local_heads(&self, cfg: &ModelConfig) -> usize {
        self.spec().local_heads(cfg.heads)
    }

    /// Shape of this rank's shard of a global `(rows, cols)` activation.
    fn activation_shape(&self, rows: usize, cols: usize) -> (usize, usize) {
        self.spec().activation_shape(rows, cols)
    }

    /// This rank's shard of a global activation. The shard is written into
    /// a recycled pool buffer (this runs twice per training step — the
    /// embedding output and the head gradient — so it must not allocate in
    /// the steady state). Replicated meshes return a zero-copy handle.
    fn scatter_activation(&self, ep: &mut Endpoint, global: &Tensor) -> Tensor {
        let spec = self.spec();
        if !spec.shards_activation() {
            return global.clone();
        }
        let (rows, cols) = global.dims2();
        let (r0, c0, sr, sc) = spec.activation_bounds(rows, cols);
        if global.is_phantom() {
            return Tensor::phantom(&[sr, sc]);
        }
        let mut out = ep.pooled_tensor(&[sr, sc]);
        global.block_into(r0, c0, sr, sc, &mut out);
        out
    }

    /// Reassemble the global activation on every rank (one all-gather over
    /// the world; only used at the model boundary — embedding/head — which
    /// the paper excludes from the parallelized region). The assembly is
    /// written into a recycled pool buffer; phantom shards drive the same
    /// collective and return a phantom.
    fn gather_activation(
        &self,
        ep: &mut Endpoint,
        local: &Tensor,
        rows: usize,
        cols: usize,
    ) -> Tensor {
        let spec = self.spec();
        if !spec.shards_activation() {
            return local.clone();
        }
        let world: Vec<usize> = (0..spec.world()).collect();
        let parts = crate::collectives::all_gather(ep, &world, local);
        if parts.iter().any(|p| p.is_phantom()) {
            return Tensor::phantom(&[rows, cols]);
        }
        let mut out = ep.pooled_tensor(&[rows, cols]);
        spec.assemble_activation_into(&parts, rows, cols, &mut out);
        out
    }

    /// This rank's shards of one dense block.
    fn shard_block(&self, dense: &DenseBlock) -> BlockTensors {
        dense.shard(self.spec())
    }

    /// Shape-only (phantom) block shards — the timing path at paper scale,
    /// where materializing hidden-8192 weights would be pointless. Shapes
    /// and vector ownership are identical to the materialized sharding
    /// because both flow through the same `DenseBlock::shard`.
    fn phantom_block(&self, cfg: &ModelConfig) -> BlockTensors {
        DenseBlock::phantom(cfg).shard(self.spec())
    }

    // --- inference serving (see the "Serving model" docs in
    //     `crate::serve`) ----------------------------------------------

    /// Prefill the prompt batch through this rank's layer slice and
    /// harvest the per-layer KV caches. `x` is the entry-layout shard of
    /// the padded `(slots · cfg.seq, hidden)` prompt activation; `lens`
    /// are this rank's *local* per-slot prompt lengths. The default runs
    /// [`crate::model::block::prefill_block_fwd`] per layer — a plain
    /// forward with the backward stash dropped — which is exactly right
    /// for every tensor mesh; the pipeline wrapper overrides it with a
    /// stage-relay schedule.
    fn serve_prefill(
        &self,
        ep: &mut Endpoint,
        blocks: &[BlockTensors],
        x: &Tensor,
        cfg: &ModelConfig,
        lens: &[usize],
        kv: &mut [crate::model::attention::DecodeKv],
    ) -> Tensor {
        assert_eq!(blocks.len(), kv.len());
        let mut h = x.clone();
        for (p, kvl) in blocks.iter().zip(kv.iter_mut()) {
            h = crate::model::block::prefill_block_fwd(ep, self, p, &h, cfg, kvl, lens);
        }
        h
    }

    /// One decode step: `x` holds one new token per local slot in entry
    /// layout (`(slots_local, hidden_local)`); returns the block-stack
    /// output in the same layout, which *is* the next step's input —
    /// autoregression never leaves the sharded domain. The default folds
    /// [`crate::model::block::decode_block_fwd`] over this rank's layers;
    /// the pipeline wrapper overrides it to relay the single-token
    /// activation through the stage chain.
    fn serve_decode(
        &self,
        ep: &mut Endpoint,
        blocks: &[BlockTensors],
        x: &Tensor,
        cfg: &ModelConfig,
        kv: &mut [crate::model::attention::DecodeKv],
    ) -> Tensor {
        assert_eq!(blocks.len(), kv.len());
        let mut h = x.clone();
        for (p, kvl) in blocks.iter().zip(kv.iter_mut()) {
            h = crate::model::block::decode_block_fwd(ep, self, p, &h, cfg, kvl);
        }
        h
    }
}

/// Construct the [`ParallelOps`] implementation for a parallelism point —
/// the single dispatch site `crate::model::ParEnv` wraps.
pub fn ops_for(par: Parallelism, edge: usize, rank: usize) -> Box<dyn ParallelOps> {
    match par {
        Parallelism::Seq => Box::new(seq::Seq::new()),
        Parallelism::OneD => Box::new(oned::Ctx1D::new(edge, rank)),
        Parallelism::TwoD => Box::new(twod::Ctx2D::new(crate::topology::Mesh::new(edge), rank)),
        Parallelism::ThreeD => {
            Box::new(threed::Ctx3D::new(crate::topology::Cube::new(edge), rank))
        }
        Parallelism::TwoFiveD { depth } => Box::new(twofived::Ctx25D::new(edge, depth, rank)),
        Parallelism::Hybrid { replicas, inner } => {
            Box::new(hybrid::Hybrid::for_kind(replicas, inner, edge, rank))
        }
        Parallelism::Pipeline { stages, micro_batches, inner } => {
            Box::new(pipeline::Pipeline::for_kind(stages, micro_batches, inner, edge, rank))
        }
    }
}
