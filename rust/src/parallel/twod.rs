//! 2-D tensor parallelism — the Optimus/SUMMA baseline [21, 19].
//!
//! All matrices (weights *and* activations) are block-distributed on a
//! `q × q` mesh: rank `(i, j)` holds block `(i, j)` of every `(R/q, C/q)`
//! blocking. Matmuls run as SUMMA: `q` steps, each broadcasting a block
//! panel along mesh rows and/or columns and accumulating a local product.
//!
//! Three SUMMA variants cover forward and backward (van de Geijn & Watts):
//! * [`summa_nn`] — `C = A·B`   (broadcast A panel along rows, B panel
//!   along cols, accumulate locally);
//! * [`summa_nt`] — `C = A·Bᵀ`  (broadcast B panel along cols, local NT
//!   product, *reduce* the partial along rows to the panel owner);
//! * [`summa_tn`] — `C = Aᵀ·B`  (broadcast A panel along rows, local TN
//!   product, reduce along cols to the panel owner).
//!
//! Bias vectors are stored on mesh row 0 (split by column block) and
//! broadcast down columns when needed, matching Optimus.
//!
//! **Overlap.** SUMMA's broadcasts and reduces are critical-path by
//! construction — step `s+1`'s local product consumes step `s`'s panels,
//! and the weight-grad reduces (`summa_tn`) deliver the shard the owner
//! rank needs before the optimizer's *next* use of the same buffer in the
//! following SUMMA step. None of it is deferrable, so this leaf's clock is
//! `CUBIC_OVERLAP`-invariant; overlap wins come from the hybrid wrapper's
//! replica grad syncs around the grid.

use crate::collectives::{all_reduce, broadcast, broadcast_bw, reduce_bw};
use crate::comm::Endpoint;
use crate::dist::{ShardSpec, Stage};
use crate::parallel::ParallelOps;
use crate::tensor::Tensor;
use crate::topology::Mesh;

/// Per-rank context on the `q × q` mesh. `base` offsets the grid's ranks
/// into the global rank space (0 for the stand-alone 2-D leaf; the 2.5-D
/// Tesseract and hybrid wrappers embed grids at non-zero bases).
pub struct Ctx2D {
    /// The `q × q` mesh geometry.
    pub mesh: Mesh,
    /// This rank's mesh row.
    pub row: usize,
    /// This rank's mesh column.
    pub col: usize,
    base: usize,
    spec: ShardSpec,
}

impl Ctx2D {
    /// Context for `rank` of a stand-alone grid (base 0).
    pub fn new(mesh: Mesh, rank: usize) -> Self {
        Self::with_base(mesh, rank, 0)
    }

    /// Like [`Ctx2D::new`] but the grid occupies global ranks
    /// `base..base + q²` (row-major). `rank` is the grid-local rank; the
    /// endpoint's global rank must be `base + rank`.
    pub fn with_base(mesh: Mesh, rank: usize, base: usize) -> Self {
        let (row, col) = mesh.coord_of(rank);
        let spec = ShardSpec::twod(mesh.edge(), rank);
        Ctx2D { mesh, row, col, base, spec }
    }

    /// The mesh edge `q`.
    pub fn q(&self) -> usize {
        self.mesh.edge()
    }

    fn row_group(&self) -> Vec<usize> {
        self.mesh.row_group(self.row).into_iter().map(|r| r + self.base).collect()
    }

    fn col_group(&self) -> Vec<usize> {
        self.mesh.col_group(self.col).into_iter().map(|r| r + self.base).collect()
    }
}

fn charge_mm(ep: &mut Endpoint, m: usize, n: usize, k: usize) {
    ep.charge_flops(2.0 * m as f64 * n as f64 * k as f64);
}

/// SUMMA `C = A·B`: `a` is this rank's `(M/q, N/q)` block, `b` its
/// `(N/q, K/q)` block; returns the `(M/q, K/q)` block of `C`.
pub fn summa_nn(ep: &mut Endpoint, ctx: &Ctx2D, a: &Tensor, b: &Tensor) -> Tensor {
    let q = ctx.q();
    let (ma, _na) = a.dims2();
    let (_nb, kb) = b.dims2();
    let mut c = Tensor::zeros(&[ma, kb]);
    for t in 0..q {
        // Panel A[·, t] travels along mesh rows from column t.
        let a_panel = broadcast_bw(ep, &ctx.row_group(), t, (ctx.col == t).then(|| a.clone()), a.shape());
        // Panel B[t, ·] travels along mesh columns from row t.
        let b_panel = broadcast_bw(ep, &ctx.col_group(), t, (ctx.row == t).then(|| b.clone()), b.shape());
        let (m, n) = a_panel.dims2();
        let k = b_panel.dims2().1;
        charge_mm(ep, m, k, n);
        let prod = a_panel.matmul(&b_panel);
        if prod.is_phantom() {
            c = Tensor::phantom(&[ma, kb]);
        } else {
            c.add_assign(&prod);
        }
    }
    c
}

/// SUMMA `C = A·Bᵀ`: `a` is the `(M/q, N/q)` block, `b` the `(K/q, N/q)`
/// block of `B` (global `(K, N)`); returns the `(M/q, K/q)` block of `C`.
///
/// Step `t`: broadcast `B[t, j]` down columns from row `t`; every rank
/// computes `A[i,j]·B[t,j]ᵀ` (a contribution to `C[i,t]`) and the partials
/// are reduced along mesh rows to the owner column `t`.
pub fn summa_nt(ep: &mut Endpoint, ctx: &Ctx2D, a: &Tensor, b: &Tensor) -> Tensor {
    let q = ctx.q();
    let (ma, _) = a.dims2();
    let (kb, _) = b.dims2();
    let mut c: Option<Tensor> = None;
    for t in 0..q {
        let b_panel = broadcast_bw(ep, &ctx.col_group(), t, (ctx.row == t).then(|| b.clone()), b.shape());
        let (m, n) = a.dims2();
        let k = b_panel.dims2().0;
        charge_mm(ep, m, k, n);
        let partial = a.matmul_nt(&b_panel); // (M/q, K/q) contribution to C[i, t]
        if let Some(summed) = reduce_bw(ep, &ctx.row_group(), t, &partial) {
            c = Some(summed);
        }
    }
    c.unwrap_or_else(|| Tensor::phantom(&[ma, kb]))
}

/// SUMMA `C = Aᵀ·B`: `a` is the `(N/q, M/q)` block of `A` (global
/// `(N, M)`), `b` the `(N/q, K/q)` block of `B`; returns the `(M/q, K/q)`
/// block of `C`.
///
/// Step `t`: broadcast `A[i, t]` along rows from column `t`; every rank
/// computes `A[i,t]ᵀ·B[i,j]` (a contribution to `C[t,j]`) and partials are
/// reduced along mesh columns to the owner row `t`.
pub fn summa_tn(ep: &mut Endpoint, ctx: &Ctx2D, a: &Tensor, b: &Tensor) -> Tensor {
    let q = ctx.q();
    let (_, ma) = a.dims2();
    let (_, kb) = b.dims2();
    let mut c: Option<Tensor> = None;
    for t in 0..q {
        let a_panel = broadcast_bw(ep, &ctx.row_group(), t, (ctx.col == t).then(|| a.clone()), a.shape());
        let (n, m) = a_panel.dims2();
        let k = b.dims2().1;
        charge_mm(ep, m, k, n);
        let partial = a_panel.matmul_tn(b); // (M/q, K/q) contribution to C[t, j]
        if let Some(summed) = reduce_bw(ep, &ctx.col_group(), t, &partial) {
            c = Some(summed);
        }
    }
    c.unwrap_or_else(|| Tensor::phantom(&[ma, kb]))
}

/// Materialize this rank's column-block slice of a bias vector stored on
/// mesh row 0 (`b_chunk` is `Some` only at `row == 0`).
pub fn bcast_bias(ep: &mut Endpoint, ctx: &Ctx2D, b_chunk: Option<&Tensor>) -> Tensor {
    broadcast(ep, &ctx.col_group(), 0, b_chunk.map(|b| b.clone()))
}

/// `C = A + v` / `C = A ⊙ v` for a block-distributed activation and a
/// row-0-stored vector: broadcast the chunk down the mesh column, apply
/// locally. Shared by the 2-D leaf and the 2.5-D leaf (whose per-layer
/// grids use the same placement), so the cost accounting cannot drift.
pub fn vec_op(
    ep: &mut Endpoint,
    ctx: &Ctx2D,
    a: &Tensor,
    v: Option<&Tensor>,
    mul: bool,
) -> Tensor {
    let full = bcast_bias(ep, ctx, v);
    ep.charge_memop(a.nominal_bytes() as f64);
    if mul {
        a.mul_row_vector(&full)
    } else {
        a.add_row_vector(&full)
    }
}

/// 2-D linear forward `Y = X·W + b`. All blocks `(·/q, ·/q)`; bias stored on
/// row 0 (`b_chunk` is `Some` exactly on row-0 ranks of biased layers;
/// `has_bias` tells every rank whether to join the broadcast). Returns this
/// rank's block of `Y`.
pub fn linear_fwd(
    ep: &mut Endpoint,
    ctx: &Ctx2D,
    x: &Tensor,
    w: &Tensor,
    b_chunk: Option<&Tensor>,
    has_bias: bool,
) -> Tensor {
    let y = summa_nn(ep, ctx, x, w);
    if has_bias {
        let b = bcast_bias(ep, ctx, b_chunk);
        ep.charge_memop(y.nominal_bytes() as f64);
        y.add_row_vector(&b)
    } else {
        assert!(b_chunk.is_none());
        y
    }
}

/// 2-D linear backward: returns `(dX, dW, db_chunk)` with `db_chunk` only on
/// mesh row 0 (where the bias lives).
pub fn linear_bwd(
    ep: &mut Endpoint,
    ctx: &Ctx2D,
    dy: &Tensor,
    x: &Tensor,
    w: &Tensor,
) -> (Tensor, Tensor, Option<Tensor>) {
    let dx = summa_nt(ep, ctx, dy, w); // dX = dY·Wᵀ  (W global (N,K) → blocks (N/q,K/q))
    let dw = summa_tn(ep, ctx, x, dy); // dW = Xᵀ·dY
    // db = column-sum of dY, reduced along mesh columns to row 0.
    ep.charge_memop(dy.nominal_bytes() as f64);
    let local = dy.sum_rows();
    let db = reduce_bw(ep, &ctx.col_group(), 0, &local);
    (dx, dw, db)
}

/// The `(dW, db)` half of [`linear_bwd`] on its own — the micro-batch
/// pipelining path ([`crate::parallel::ParallelOps::linear_bwd_dw`]),
/// computing the same `summa_tn` and row-0 bias reduction as the joint
/// routine but without the `dX` SUMMA.
pub(crate) fn linear_bwd_dw(
    ep: &mut Endpoint,
    ctx: &Ctx2D,
    dy: &Tensor,
    x: &Tensor,
) -> (Tensor, Option<Tensor>) {
    let dw = summa_tn(ep, ctx, x, dy); // dW = Xᵀ·dY
    ep.charge_memop(dy.nominal_bytes() as f64);
    let db = reduce_bw(ep, &ctx.col_group(), 0, &dy.sum_rows());
    (dw, db)
}

/// The `(dγ, dβ)` half of [`layernorm_backward`] on its own
/// ([`crate::parallel::ParallelOps::layernorm_param_grads`]): the same
/// column sums reduced along mesh columns to row 0.
pub(crate) fn layernorm_param_grads(
    ep: &mut Endpoint,
    ctx: &Ctx2D,
    dy: &Tensor,
    xhat: &Tensor,
) -> (Option<Tensor>, Option<Tensor>) {
    ep.charge_memop(3.0 * dy.nominal_bytes() as f64);
    let dbeta = reduce_bw(ep, &ctx.col_group(), 0, &dy.sum_rows());
    let dgamma = reduce_bw(ep, &ctx.col_group(), 0, &dy.mul(xhat).sum_rows());
    (dgamma, dbeta)
}

/// The `dx` half of [`layernorm_backward`] on its own
/// ([`crate::parallel::ParallelOps::layernorm_backward_dx`]). The float
/// operations duplicate the joint routine's `dx` part verbatim — the
/// joint path is deliberately left untouched so its clock charges stay
/// bit-stable for the costmodel pins.
pub(crate) fn layernorm_backward_dx(
    ep: &mut Endpoint,
    ctx: &Ctx2D,
    dy: &Tensor,
    xhat: &Tensor,
    inv_std: &Tensor,
    gamma_chunk: Option<&Tensor>,
    n_global_cols: usize,
) -> Tensor {
    let (rows, cols) = dy.dims2();
    let gamma = bcast_bias(ep, ctx, gamma_chunk);
    let g = dy.mul_row_vector(&gamma);
    let stats = if g.is_phantom() || xhat.is_phantom() {
        Tensor::phantom(&[2, rows])
    } else {
        let mut s = Tensor::zeros(&[2, rows]);
        s.set_block(0, 0, &g.sum_cols().reshape(&[1, rows]));
        s.set_block(1, 0, &g.mul(xhat).sum_cols().reshape(&[1, rows]));
        s
    };
    let stats = all_reduce(ep, &ctx.row_group(), &stats);
    let n = n_global_cols as f32;
    let dx = if g.is_phantom() || stats.is_phantom() || inv_std.is_phantom() {
        Tensor::phantom(dy.shape())
    } else {
        let sd = stats.data();
        let istd = inv_std.data();
        let gd = g.data();
        let xd = xhat.data();
        let mut out = vec![0.0f32; rows * cols];
        for r in 0..rows {
            let c0 = istd[r] / n;
            for c in 0..cols {
                let idx = r * cols + c;
                out[idx] = c0 * (n * gd[idx] - sd[r] - xd[idx] * sd[rows + r]);
            }
        }
        Tensor::from_vec(&[rows, cols], out)
    };
    ep.charge_memop(2.0 * dy.nominal_bytes() as f64);
    dx
}

/// 2-D layernorm forward over the hidden (column) dimension. Row statistics
/// are all-reduced along mesh rows; γ/β live on mesh row 0 (column-block
/// split) and are broadcast down columns.
///
/// Returns `(y, xhat, inv_std)`.
#[allow(clippy::too_many_arguments)]
pub fn layernorm(
    ep: &mut Endpoint,
    ctx: &Ctx2D,
    x: &Tensor,
    gamma_chunk: Option<&Tensor>,
    beta_chunk: Option<&Tensor>,
    eps: f32,
    n_global_cols: usize,
) -> (Tensor, Tensor, Tensor) {
    let (rows, _cols) = x.dims2();
    let stats = if x.is_phantom() {
        Tensor::phantom(&[2, rows])
    } else {
        let mut s = Tensor::zeros(&[2, rows]);
        s.set_block(0, 0, &x.sum_cols().reshape(&[1, rows]));
        s.set_block(1, 0, &x.map(|v| v * v).sum_cols().reshape(&[1, rows]));
        s
    };
    ep.charge_memop(2.0 * x.nominal_bytes() as f64);
    let stats = all_reduce(ep, &ctx.row_group(), &stats);
    let n = n_global_cols as f32;
    let (xhat, inv_std) = if stats.is_phantom() || x.is_phantom() {
        (Tensor::phantom(x.shape()), Tensor::phantom(&[rows]))
    } else {
        let mut xh = x.clone();
        let mut istd = vec![0.0f32; rows];
        let sd = stats.data().to_vec();
        let cols = x.dims2().1;
        let xd = xh.data_mut();
        for r in 0..rows {
            let mean = sd[r] / n;
            let var = (sd[rows + r] / n - mean * mean).max(0.0);
            let inv = 1.0 / (var + eps).sqrt();
            istd[r] = inv;
            for c in 0..cols {
                xd[r * cols + c] = (xd[r * cols + c] - mean) * inv;
            }
        }
        (xh, Tensor::from_vec(&[rows], istd))
    };
    ep.charge_memop(2.0 * x.nominal_bytes() as f64);
    let gamma = bcast_bias(ep, ctx, gamma_chunk);
    let beta = bcast_bias(ep, ctx, beta_chunk);
    let y = xhat.mul_row_vector(&gamma).add_row_vector(&beta);
    (y, xhat, inv_std)
}

/// 2-D layernorm backward; `(dx, dγ_chunk, dβ_chunk)` with vector grads on
/// mesh row 0 only.
#[allow(clippy::too_many_arguments)]
pub fn layernorm_backward(
    ep: &mut Endpoint,
    ctx: &Ctx2D,
    dy: &Tensor,
    xhat: &Tensor,
    inv_std: &Tensor,
    gamma_chunk: Option<&Tensor>,
    n_global_cols: usize,
) -> (Tensor, Option<Tensor>, Option<Tensor>) {
    let (rows, cols) = dy.dims2();
    ep.charge_memop(3.0 * dy.nominal_bytes() as f64);
    let dbeta = reduce_bw(ep, &ctx.col_group(), 0, &dy.sum_rows());
    let dgamma = reduce_bw(ep, &ctx.col_group(), 0, &dy.mul(xhat).sum_rows());
    let gamma = bcast_bias(ep, ctx, gamma_chunk);
    let g = dy.mul_row_vector(&gamma);
    let stats = if g.is_phantom() || xhat.is_phantom() {
        Tensor::phantom(&[2, rows])
    } else {
        let mut s = Tensor::zeros(&[2, rows]);
        s.set_block(0, 0, &g.sum_cols().reshape(&[1, rows]));
        s.set_block(1, 0, &g.mul(xhat).sum_cols().reshape(&[1, rows]));
        s
    };
    let stats = all_reduce(ep, &ctx.row_group(), &stats);
    let n = n_global_cols as f32;
    let dx = if g.is_phantom() || stats.is_phantom() || inv_std.is_phantom() {
        Tensor::phantom(dy.shape())
    } else {
        let sd = stats.data();
        let istd = inv_std.data();
        let gd = g.data();
        let xd = xhat.data();
        let mut out = vec![0.0f32; rows * cols];
        for r in 0..rows {
            let c0 = istd[r] / n;
            for c in 0..cols {
                let idx = r * cols + c;
                out[idx] = c0 * (n * gd[idx] - sd[r] - xd[idx] * sd[rows + r]);
            }
        }
        Tensor::from_vec(&[rows, cols], out)
    };
    ep.charge_memop(2.0 * dy.nominal_bytes() as f64);
    (dx, dgamma, dbeta)
}

/// SUMMA semantics for the trait: both stages run the same block-distributed
/// forms (the mesh has no column/row asymmetry); biases and γ/β live on mesh
/// row 0 and are broadcast down columns on use.
impl ParallelOps for Ctx2D {
    fn spec(&self) -> &ShardSpec {
        &self.spec
    }

    fn matmul_nn(&self, ep: &mut Endpoint, x: &Tensor, w: &Tensor, _stage: Stage) -> Tensor {
        summa_nn(ep, self, x, w)
    }

    fn matmul_nt(&self, ep: &mut Endpoint, dy: &Tensor, w: &Tensor, _stage: Stage) -> Tensor {
        summa_nt(ep, self, dy, w)
    }

    fn matmul_tn(&self, ep: &mut Endpoint, x: &Tensor, dy: &Tensor, _stage: Stage) -> Tensor {
        summa_tn(ep, self, x, dy)
    }

    fn linear_fwd(
        &self,
        ep: &mut Endpoint,
        x: &Tensor,
        w: &Tensor,
        b: Option<&Tensor>,
        _stage: Stage,
    ) -> Tensor {
        linear_fwd(ep, self, x, w, b, true)
    }

    fn linear_bwd(
        &self,
        ep: &mut Endpoint,
        dy: &Tensor,
        x: &Tensor,
        w: &Tensor,
        _stage: Stage,
    ) -> (Tensor, Tensor, Option<Tensor>) {
        linear_bwd(ep, self, dy, x, w)
    }

    fn vec_op(&self, ep: &mut Endpoint, a: &Tensor, v: Option<&Tensor>, mul: bool) -> Tensor {
        vec_op(ep, self, a, v, mul)
    }

    fn layernorm(
        &self,
        ep: &mut Endpoint,
        x: &Tensor,
        gamma: Option<&Tensor>,
        beta: Option<&Tensor>,
        eps: f32,
        hidden: usize,
    ) -> (Tensor, Tensor, Tensor) {
        layernorm(ep, self, x, gamma, beta, eps, hidden)
    }

    fn layernorm_backward(
        &self,
        ep: &mut Endpoint,
        dy: &Tensor,
        xhat: &Tensor,
        inv_std: &Tensor,
        gamma: Option<&Tensor>,
        hidden: usize,
    ) -> (Tensor, Option<Tensor>, Option<Tensor>) {
        layernorm_backward(ep, self, dy, xhat, inv_std, gamma, hidden)
    }

    fn linear_bwd_dw(
        &self,
        ep: &mut Endpoint,
        dy: &Tensor,
        x: &Tensor,
        _stage: Stage,
    ) -> (Tensor, Option<Tensor>) {
        linear_bwd_dw(ep, self, dy, x)
    }

    fn layernorm_backward_dx(
        &self,
        ep: &mut Endpoint,
        dy: &Tensor,
        xhat: &Tensor,
        inv_std: &Tensor,
        gamma: Option<&Tensor>,
        hidden: usize,
    ) -> Tensor {
        layernorm_backward_dx(ep, self, dy, xhat, inv_std, gamma, hidden)
    }

    fn layernorm_param_grads(
        &self,
        ep: &mut Endpoint,
        dy: &Tensor,
        xhat: &Tensor,
    ) -> (Option<Tensor>, Option<Tensor>) {
        layernorm_param_grads(ep, self, dy, xhat)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::NetModel;
    use crate::dist::Layout2D;
    use crate::rng::Xoshiro256;
    use crate::spmd::run_spmd;

    fn randt(shape: &[usize], seed: u64) -> Tensor {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        Tensor::randn(shape, 1.0, &mut rng)
    }

    fn scatter_bias_row0(mesh: &Mesh, v: &Tensor) -> Vec<Option<Tensor>> {
        let q = mesh.edge();
        let n = v.numel();
        (0..mesh.size())
            .map(|r| {
                let (row, col) = mesh.coord_of(r);
                (row == 0).then(|| {
                    v.reshape(&[1, n]).block(0, col * (n / q), 1, n / q).into_reshape(&[n / q])
                })
            })
            .collect()
    }

    #[test]
    fn summa_nn_matches_dense() {
        for q in [2usize, 3] {
            let mesh = Mesh::new(q);
            let (m, n, k) = (6 * q, 4 * q, 2 * q);
            let a = randt(&[m, n], 1);
            let b = randt(&[n, k], 2);
            let c_ref = a.matmul(&b);
            let a_s = Layout2D::scatter(&mesh, &a);
            let b_s = Layout2D::scatter(&mesh, &b);
            let out = run_spmd(q * q, NetModel::zero(), move |rank, ep| {
                let ctx = Ctx2D::new(Mesh::new(q), rank);
                summa_nn(ep, &ctx, &a_s[rank], &b_s[rank])
            });
            let got = Layout2D::gather(&mesh, &out, m, k);
            assert!(got.max_abs_diff(&c_ref) < 1e-3, "q={q}");
        }
    }

    #[test]
    fn summa_nt_matches_dense() {
        let q = 2;
        let mesh = Mesh::new(q);
        let (m, n, k) = (8, 6, 4);
        let a = randt(&[m, n], 3);
        let b = randt(&[k, n], 4);
        let c_ref = a.matmul_nt(&b);
        let a_s = Layout2D::scatter(&mesh, &a);
        let b_s = Layout2D::scatter(&mesh, &b);
        let out = run_spmd(q * q, NetModel::zero(), move |rank, ep| {
            let ctx = Ctx2D::new(Mesh::new(q), rank);
            summa_nt(ep, &ctx, &a_s[rank], &b_s[rank])
        });
        let got = Layout2D::gather(&mesh, &out, m, k);
        assert!(got.max_abs_diff(&c_ref) < 1e-3);
    }

    #[test]
    fn summa_tn_matches_dense() {
        let q = 2;
        let mesh = Mesh::new(q);
        let (m, n, k) = (8, 6, 4); // A (n, m), B (n, k)
        let a = randt(&[n, m], 5);
        let b = randt(&[n, k], 6);
        let c_ref = a.matmul_tn(&b);
        let a_s = Layout2D::scatter(&mesh, &a);
        let b_s = Layout2D::scatter(&mesh, &b);
        let out = run_spmd(q * q, NetModel::zero(), move |rank, ep| {
            let ctx = Ctx2D::new(Mesh::new(q), rank);
            summa_tn(ep, &ctx, &a_s[rank], &b_s[rank])
        });
        let got = Layout2D::gather(&mesh, &out, m, k);
        assert!(got.max_abs_diff(&c_ref) < 1e-3);
    }

    #[test]
    fn linear_fwd_bwd_matches_dense() {
        let q = 2;
        let mesh = Mesh::new(q);
        let (m, n, k) = (8, 6, 4);
        let x = randt(&[m, n], 7);
        let w = randt(&[n, k], 8);
        let bias = randt(&[k], 9);
        let dy = randt(&[m, k], 10);
        let y_ref = x.matmul(&w).add_row_vector(&bias);
        let dx_ref = dy.matmul_nt(&w);
        let dw_ref = x.matmul_tn(&dy);
        let db_ref = dy.sum_rows();
        let x_s = Layout2D::scatter(&mesh, &x);
        let w_s = Layout2D::scatter(&mesh, &w);
        let b_s = scatter_bias_row0(&mesh, &bias);
        let dy_s = Layout2D::scatter(&mesh, &dy);
        let out = run_spmd(q * q, NetModel::zero(), move |rank, ep| {
            let ctx = Ctx2D::new(Mesh::new(q), rank);
            let y = linear_fwd(ep, &ctx, &x_s[rank], &w_s[rank], b_s[rank].as_ref(), true);
            let (dx, dw, db) = linear_bwd(ep, &ctx, &dy_s[rank], &x_s[rank], &w_s[rank]);
            (y, dx, dw, db)
        });
        let y = Layout2D::gather(&mesh, &out.iter().map(|o| o.0.clone()).collect::<Vec<_>>(), m, k);
        let dx = Layout2D::gather(&mesh, &out.iter().map(|o| o.1.clone()).collect::<Vec<_>>(), m, n);
        let dw = Layout2D::gather(&mesh, &out.iter().map(|o| o.2.clone()).collect::<Vec<_>>(), n, k);
        assert!(y.max_abs_diff(&y_ref) < 1e-3);
        assert!(dx.max_abs_diff(&dx_ref) < 1e-3);
        assert!(dw.max_abs_diff(&dw_ref) < 1e-3);
        // db chunks live on mesh row 0.
        let db0 = out[0].3.as_ref().unwrap();
        let db1 = out[1].3.as_ref().unwrap();
        let db = Tensor::concat_cols(&[db0.reshape(&[1, k / q]), db1.reshape(&[1, k / q])]);
        assert!(db.max_abs_diff(&db_ref.reshape(&[1, k])) < 1e-3);
        assert!(out[2].3.is_none() && out[3].3.is_none());
    }

    #[test]
    fn layernorm_2d_matches_dense() {
        let q = 2;
        let mesh = Mesh::new(q);
        let (m, n) = (8, 12);
        let x = randt(&[m, n], 11);
        let gamma = randt(&[n], 12).map(|v| 1.0 + 0.1 * v);
        let beta = randt(&[n], 13).scale(0.1);
        let eps = 1e-5f32;
        let mut y_ref = Tensor::zeros(&[m, n]);
        for r in 0..m {
            let row: Vec<f32> = (0..n).map(|c| x.at2(r, c)).collect();
            let mean = row.iter().sum::<f32>() / n as f32;
            let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / n as f32;
            let inv = 1.0 / (var + eps).sqrt();
            for c in 0..n {
                y_ref.data_mut()[r * n + c] =
                    (row[c] - mean) * inv * gamma.data()[c] + beta.data()[c];
            }
        }
        let x_s = Layout2D::scatter(&mesh, &x);
        let g_s = scatter_bias_row0(&mesh, &gamma);
        let b_s = scatter_bias_row0(&mesh, &beta);
        let out = run_spmd(q * q, NetModel::zero(), move |rank, ep| {
            let ctx = Ctx2D::new(Mesh::new(q), rank);
            layernorm(ep, &ctx, &x_s[rank], g_s[rank].as_ref(), b_s[rank].as_ref(), eps, n).0
        });
        let got = Layout2D::gather(&mesh, &out, m, n);
        assert!(got.max_abs_diff(&y_ref) < 1e-3);
    }

    #[test]
    fn phantom_summa_charges_time() {
        let q = 2;
        let out = run_spmd(q * q, NetModel::longhorn_v100(), move |rank, ep| {
            let ctx = Ctx2D::new(Mesh::new(q), rank);
            let a = Tensor::phantom(&[256, 256]);
            let b = Tensor::phantom(&[256, 256]);
            let c = summa_nn(ep, &ctx, &a, &b);
            (c.is_phantom(), ep.clock)
        });
        for (ph, clock) in out {
            assert!(ph);
            assert!(clock > 0.0);
        }
    }
}
